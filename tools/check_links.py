#!/usr/bin/env python3
"""Offline markdown link checker.

Verifies that every relative link target in the given markdown files exists
on disk (the build environment has no network, so http(s) links are only
syntax-checked, not fetched). Usage:

    python3 tools/check_links.py README.md DESIGN.md ...
    python3 tools/check_links.py          # checks DEFAULT_FILES

With no arguments the checker walks DEFAULT_FILES (every tracked doc with
cross-references) — add new docs there so CI picks them up in one place.
Exits non-zero listing every broken link.
"""

import os
import re
import sys

# Every doc with cross-references, relative to the repo root. CI runs the
# checker with no arguments, so this list is the single registry.
DEFAULT_FILES = [
    "README.md",
    "DESIGN.md",
    "ROADMAP.md",
    "docs/README.md",
    "docs/CHECKPOINT.md",
    "docs/CLI.md",
    "docs/DETERMINISM.md",
    "docs/O3.md",
    "docs/PERF.md",
    "docs/PLATFORMS.md",
    "docs/SWEEP.md",
    "docs/TRAFFIC.md",
    "docs/XBAR.md",
]

# [text](target) — target up to the first closing paren or whitespace.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:")


def check_file(path: str) -> list[str]:
    errors = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for lineno, line in enumerate(text.splitlines(), 1):
        for target in LINK.findall(line):
            if target.startswith(SKIP_SCHEMES):
                continue
            if target.startswith("#"):  # in-page anchor
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.join(base, rel)):
                errors.append(f"{path}:{lineno}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        # Resolve DEFAULT_FILES against the repo root (the parent of this
        # script's directory) so the checker works from any CWD.
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        argv = [os.path.join(root, f) for f in DEFAULT_FILES]
    errors = []
    for path in argv:
        if not os.path.exists(path):
            errors.append(f"{path}: file not found")
            continue
        errors.extend(check_file(path))
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} broken link(s)", file=sys.stderr)
        return 1
    print(f"ok: {len(argv)} file(s) checked")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
