#!/usr/bin/env python3
"""Merge a measured BENCH_sched.json artifact into the committed copy.

The perf-trajectory workflow (docs/PERF.md): CI runs `cargo bench --bench
kernel_micro`, which rewrites BENCH_sched.json with measured numbers and
uploads it as an artifact. This script brings those numbers back into the
repository copy — with a schema check, so a bench that silently grows,
drops or renames a row fails loudly instead of drifting:

* every key of the committed schema must be present in the artifact,
* the artifact must not contain unknown keys,
* every leaf must be a number or null (strings live only in the
  documentation keys `status` / `note`, which are exempt and preserved
  from the schema side except `status`, which the merge takes from the
  artifact).

Usage:
    tools/update_bench.py ARTIFACT.json            # validate + merge
    tools/update_bench.py --check ARTIFACT.json    # validate only (CI)
    tools/update_bench.py --repo PATH ARTIFACT.json

`--repo` points at the committed copy (default: BENCH_sched.json next to
this script's repository root); with `--check` it is only read, never
written.
"""

import argparse
import json
import numbers
import os
import sys

# Keys that carry prose, not measurements: exempt from the numeric-leaf
# rule and from the merge (except `status`, which the artifact decides).
DOC_KEYS = {"status", "note"}


def is_leaf(value):
    return value is None or isinstance(value, numbers.Number)


def schema_errors(schema, artifact, path=""):
    """Recursively compare the artifact's structure to the schema's."""
    errors = []
    for key, sval in schema.items():
        if path == "" and key in DOC_KEYS:
            continue
        here = f"{path}.{key}" if path else key
        if key not in artifact:
            errors.append(f"missing key `{here}`")
            continue
        aval = artifact[key]
        if isinstance(sval, dict):
            if not isinstance(aval, dict):
                errors.append(f"`{here}` must be an object, got {type(aval).__name__}")
            else:
                errors.extend(schema_errors(sval, aval, here))
        else:
            if not is_leaf(aval):
                errors.append(
                    f"`{here}` must be a number or null, got {type(aval).__name__}"
                )
    for key in artifact:
        if path == "" and key in DOC_KEYS:
            continue
        if key not in schema:
            here = f"{path}.{key}" if path else key
            errors.append(f"unknown key `{here}` (schema drift: update BENCH_sched.json and tools/update_bench.py together)")
    return errors


def merge(schema, artifact):
    """Return the schema structure with the artifact's leaf values."""
    out = {}
    for key, sval in schema.items():
        if key in DOC_KEYS:
            if key == "status":
                out[key] = artifact.get("status", sval)
            else:
                out[key] = sval
        elif isinstance(sval, dict):
            out[key] = merge(sval, artifact[key])
        else:
            out[key] = artifact[key]
    return out


def count_measured(node):
    """(non-null leaves, total leaves) under `node`, ignoring doc keys."""
    filled = total = 0
    for key, value in node.items():
        if key in DOC_KEYS:
            continue
        if isinstance(value, dict):
            f, t = count_measured(value)
            filled += f
            total += t
        else:
            total += 1
            filled += value is not None
    return filled, total


def main():
    repo_default = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_sched.json"
    )
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="measured BENCH_sched.json (CI artifact)")
    ap.add_argument(
        "--repo",
        default=repo_default,
        help="committed copy holding the schema (default: repo root)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate the artifact against the schema; write nothing",
    )
    args = ap.parse_args()

    with open(args.repo) as f:
        schema = json.load(f)
    with open(args.artifact) as f:
        artifact = json.load(f)

    errors = schema_errors(schema, artifact)
    if errors:
        print(f"{args.artifact}: schema check FAILED", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1

    filled, total = count_measured(artifact)
    print(f"{args.artifact}: schema ok ({filled}/{total} leaves measured)")
    if args.check:
        return 0

    merged = merge(schema, artifact)
    with open(args.repo, "w") as f:
        json.dump(merged, f, indent=1)
        f.write("\n")
    print(f"merged into {args.repo}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
