//! Design-space exploration — the use case the paper motivates: sweep
//! micro-architecture parameters under a detailed timing model,
//! accelerated by the parti PDES kernel. The whole sweep is driven by the
//! declarative [`SystemSpec`] platform API: each point is a spec edit,
//! never a hand-wired machine.
//!
//! Part 1 sweeps the private L2 capacity (cache axis); part 2 sweeps the
//! interconnect topology — star vs ring vs mesh — at fixed caches
//! (fabric axis); part 3 sweeps the synthetic [`TrafficSpec`] patterns on
//! a fixed ring fabric (workload axis, docs/TRAFFIC.md). For each point
//! the sweep reports simulated runtime, miss rates (from the serial
//! reference) and the PDES speedup + accuracy at the chosen quantum.
//!
//! ```sh
//! cargo run --release --example dse_sweep
//! ```
//!
//! [`TrafficSpec`]: parti_sim::spec::traffic::TrafficSpec

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::HostModel;
use parti_sim::sim::time::NS;
use parti_sim::spec::{platforms, traffic, Interconnect, SystemSpec};
use parti_sim::stats::{avg_miss_rate, compare};

/// Serial reference + virtual PDES on one spec; returns
/// (serial_result, speedup, sim_time_error).
fn run_point(
    spec: &SystemSpec,
    app: &str,
) -> anyhow::Result<(parti_sim::pdes::RunResult, f64, f64)> {
    spec.validate()?;
    let mut cfg = RunConfig::for_spec(spec);
    cfg.app = app.to_string();
    cfg.ops_per_core = 4096;

    let workload = make_workload(&cfg)?;
    let serial = run_with_workload(&cfg, &workload)?;

    let mut par = cfg.clone();
    par.mode = Mode::Virtual;
    par.quantum = 8 * NS;
    let pdes = run_with_workload(&par, &workload)?;

    let mut host = HostModel::default();
    host.calibrate_cost(&serial);
    let speedup = host.speedup(serial.events, pdes.work.as_ref().unwrap());
    let acc = compare(&serial, &pdes);
    anyhow::ensure!(acc.checksum_match, "functional mismatch in DSE run");
    Ok((serial, speedup, acc.sim_time_error))
}

fn main() -> anyhow::Result<()> {
    let app = "canneal"; // cache-hungry and sharing-heavy
    let base = SystemSpec { cores: 4, ..SystemSpec::default() };

    // ---- Part 1: L2 capacity (cache axis) ---------------------------
    println!("DSE 1: private L2 capacity, app={app}, 4 cores, O3+CHI-lite\n");
    println!(
        "{:>8} {:>12} {:>10} {:>10} {:>9} {:>9}",
        "L2(KiB)", "sim_time(us)", "l2_miss", "l3_miss", "speedup", "terr(%)"
    );
    for kib in [256u64, 512, 1024, 2048] {
        let mut spec = base.clone().named(
            format!("dse-l2-{kib}k"),
            "L2 capacity sweep point",
        );
        spec.l2.size_bytes = kib * 1024;
        let (serial, speedup, terr) = run_point(&spec, app)?;
        println!(
            "{:>8} {:>12.2} {:>10.4} {:>10.4} {:>8.2}x {:>9.2}",
            kib,
            serial.sim_seconds() * 1e6,
            avg_miss_rate(&serial, ".l2.miss_rate"),
            avg_miss_rate(&serial, "hnf.miss_rate"),
            speedup,
            terr * 100.0,
        );
    }

    // ---- Part 2: interconnect topology (fabric axis) ----------------
    println!(
        "\nDSE 2: interconnect topology, app={app}, 4 cores, Table 2 caches\n"
    );
    println!(
        "{:>10} {:>12} {:>12} {:>9} {:>9}",
        "fabric", "sim_time(us)", "noc_routed", "speedup", "terr(%)"
    );
    for ic in [
        Interconnect::Star,
        Interconnect::Ring,
        Interconnect::Mesh { cols: 2 },
    ] {
        let spec = SystemSpec { interconnect: ic, ..base.clone() }
            .named("dse-fabric", "topology sweep point");
        let (serial, speedup, terr) = run_point(&spec, app)?;
        println!(
            "{:>10} {:>12.2} {:>12} {:>8.2}x {:>9.2}",
            ic.describe(spec.cores),
            serial.sim_seconds() * 1e6,
            serial.stats.sum_suffix(".routed") as u64,
            speedup,
            terr * 100.0,
        );
    }
    println!(
        "\n(longer fabrics route the same coherence traffic over more \
         hops: simulated time grows, PDES still matches the serial \
         reference bit-for-bit on checksums; speedup = modeled wall-clock \
         on the paper's 64-core host)"
    );

    // ---- Part 3: synthetic traffic patterns (workload axis) ---------
    // The Table 3 apps are CPU-bound and barely load the fabric; the
    // TrafficSpec scenarios are the adversarial complement. Same ring,
    // same caches — only the traffic shape moves.
    println!("\nDSE 3: synthetic traffic patterns, ring-16 fabric\n");
    println!(
        "{:>18} {:>12} {:>9} {:>9} {:>9} {:>9}",
        "pattern", "sim_time(us)", "offered", "retries", "requeued", "speedup"
    );
    let ring = platforms::preset("ring-16").expect("registry preset");
    for t in traffic::scenarios() {
        let mut cfg = RunConfig::for_spec(&ring);
        cfg.traffic = Some(t.name.clone());
        cfg.ops_per_core = 512;
        let w = make_workload(&cfg)?;
        let serial = run_with_workload(&cfg, &w)?;

        let mut par = cfg.clone();
        par.mode = Mode::Virtual;
        par.quantum = 8 * NS;
        let pdes = run_with_workload(&par, &w)?;
        // Traffic runs race on shared lines by design (no barriers), so
        // load checksums are kernel-timing-dependent — the bit-identity
        // gate for traffic is threaded ≡ virtual (tests/traffic.rs).
        // The cross-kernel functional invariant is completion: both
        // kernels accept every offered op.
        anyhow::ensure!(
            serial.pdes.traffic_offered == pdes.pdes.traffic_offered
                && pdes.pdes.traffic_accepted == pdes.pdes.traffic_offered,
            "traffic run did not complete"
        );
        let mut host = HostModel::default();
        host.calibrate_cost(&serial);
        let speedup =
            host.speedup(serial.events, pdes.work.as_ref().unwrap());
        println!(
            "{:>18} {:>12.2} {:>9} {:>9} {:>9} {:>8.2}x",
            t.name,
            serial.sim_seconds() * 1e6,
            pdes.pdes.traffic_offered,
            pdes.pdes.traffic_retries,
            serial.stats.get("hnf.requeued").unwrap_or(0.0) as u64,
            speedup,
        );
    }
    println!(
        "\n(each row is a named TrafficSpec — `parti-sim traffic` lists \
         them, `run --traffic <name>` replays one; the hotspot row's \
         requeued column is the HN-F serialising its 8 hot lines, and \
         retries counts LSQ backpressure from the offered load)"
    );
    Ok(())
}
