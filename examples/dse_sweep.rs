//! Design-space exploration — the use case the paper motivates: sweep
//! micro-architecture parameters under a detailed timing model,
//! accelerated by the parti PDES kernel. Since the sweep layer landed,
//! each part is a named [`SweepSpec`] from the registry driven through
//! [`run_sweep`]: the spec declares the axes, the orchestrator expands,
//! schedules and journals the points, and the summary table is rendered
//! straight from the journal records (docs/SWEEP.md).
//!
//! Part 1 sweeps the private L2 capacity (cache axis, preset
//! `l2-capacity`); part 2 sweeps the interconnect topology — star vs
//! ring vs mesh — at fixed caches (fabric axis, preset `fabric-4core`);
//! part 3 sweeps the synthetic [`TrafficSpec`] patterns on a fixed ring
//! fabric (workload axis, preset `ring-traffic`, docs/TRAFFIC.md);
//! part 4 sweeps the staged O3 pipeline's width × ROB capacity (cpu
//! axes, preset `o3-capacity`, docs/O3.md).
//!
//! The same sweeps run from the CLI, journaled and resumable:
//!
//! ```sh
//! cargo run --release --example dse_sweep
//! cargo run --release -- sweep run --spec l2-capacity --journal j.jsonl
//! ```
//!
//! [`SweepSpec`]: parti_sim::spec::sweep::SweepSpec
//! [`run_sweep`]: parti_sim::harness::sweep::run_sweep
//! [`TrafficSpec`]: parti_sim::spec::traffic::TrafficSpec

use std::path::PathBuf;

use parti_sim::harness::sweep::{run_sweep, SweepOptions};
use parti_sim::harness::tables::sweep_table;
use parti_sim::spec::sweep;
use parti_sim::stats::SweepRecord;

/// A scratch journal per part (the example cleans up after itself; real
/// sweeps keep the journal — that is the resume point).
fn scratch_journal(name: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("parti_dse_{}_{name}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Run a registry sweep end to end and render its journal records.
fn run_preset(name: &str) -> anyhow::Result<Vec<SweepRecord>> {
    let spec = sweep::sweep(name)
        .ok_or_else(|| anyhow::anyhow!("unknown sweep preset `{name}`"))?;
    let journal = scratch_journal(name);
    let opts =
        SweepOptions { journal: journal.clone(), ..SweepOptions::default() };
    let out = run_sweep(&spec, &opts)?;
    anyhow::ensure!(
        out.ran == out.points,
        "sweep `{name}` ran {} of {} points",
        out.ran,
        out.points
    );
    print!("{}", sweep_table(&out.records));
    let _ = std::fs::remove_file(&journal);
    Ok(out.records)
}

fn main() -> anyhow::Result<()> {
    // ---- Part 1: L2 capacity (cache axis) ---------------------------
    println!(
        "DSE 1: private L2 capacity (sweep `l2-capacity`): app=canneal, \
         4 cores, O3+CHI-lite\n"
    );
    run_preset("l2-capacity")?;

    // ---- Part 2: interconnect topology (fabric axis) ----------------
    println!(
        "\nDSE 2: interconnect topology (sweep `fabric-4core`): \
         app=canneal, 4 cores, Table 2 caches\n"
    );
    run_preset("fabric-4core")?;
    println!(
        "\n(longer fabrics route the same coherence traffic over more \
         hops: simulated time grows while the journal's deterministic \
         counters stay host-independent — `host_*` fields are the only \
         wall-clock data, and the canonical journal strips them)"
    );

    // ---- Part 3: synthetic traffic patterns (workload axis) ---------
    // The Table 3 apps are CPU-bound and barely load the fabric; the
    // TrafficSpec scenarios are the adversarial complement. Same ring,
    // same caches — only the traffic shape moves.
    println!(
        "\nDSE 3: synthetic traffic patterns (sweep `ring-traffic`), \
         ring-16 fabric\n"
    );
    let recs = run_preset("ring-traffic")?;
    // The cross-kernel functional invariant for traffic is completion:
    // every offered op is accepted (bit-identity itself is gated by
    // tests/traffic.rs and tests/sweep.rs).
    for r in &recs {
        anyhow::ensure!(
            r.traffic_offered > 0 && r.traffic_accepted == r.traffic_offered,
            "traffic point `{}` did not complete",
            r.id
        );
    }
    println!(
        "\n(each row is a named TrafficSpec — `parti-sim traffic` lists \
         them; the whole part is one `sweep run --spec ring-traffic`, \
         journaled, shardable with --shard i/N and resumable with \
         --resume)"
    );

    // ---- Part 4: O3 pipeline capacity (cpu axes) --------------------
    // The staged O3 pipeline's geometry is a sweepable axis pair
    // (docs/O3.md §7): width × ROB size on hotspot traffic. The point
    // ids grow +w/+rob tokens because the axes are swept, and the
    // journal's pipeline counters (issued, rob_full_stalls,
    // rob_occupancy_sum) say *why* a geometry is slow, not just that
    // it is.
    println!(
        "\nDSE 4: O3 width x ROB capacity (sweep `o3-capacity`), \
         4-core star, hotspot traffic\n"
    );
    let recs = run_preset("o3-capacity")?;
    anyhow::ensure!(recs.len() == 6, "width{{1,2,4}} x rob{{8,64}} is 6 points");
    for r in &recs {
        anyhow::ensure!(
            r.id.contains("+w") && r.id.contains("+rob"),
            "swept cpu axes must stamp the point id, got `{}`",
            r.id
        );
        anyhow::ensure!(
            r.traffic_accepted == r.traffic_offered,
            "capacity point `{}` did not complete",
            r.id
        );
        anyhow::ensure!(
            r.issued >= r.traffic_offered,
            "every offered op passes the issue stage (point `{}`)",
            r.id
        );
    }
    println!(
        "\n(mean ROB occupancy per point is rob_occupancy_sum / \
         (sim_ticks x cores) — a saturated ROB means rob_size is the \
         binding constraint, docs/O3.md)"
    );
    Ok(())
}
