//! Design-space exploration — the use case the paper motivates: sweep a
//! micro-architecture parameter (here the private L2 capacity) under a
//! detailed timing model, accelerated by the parti PDES kernel.
//!
//! For each L2 size the sweep reports simulated runtime, L2/L3 miss rates
//! (from the serial reference) and the PDES speedup + accuracy at the
//! chosen quantum.
//!
//! ```sh
//! cargo run --release --example dse_sweep
//! ```

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::HostModel;
use parti_sim::sim::time::NS;
use parti_sim::stats::{avg_miss_rate, compare};

fn main() -> anyhow::Result<()> {
    let l2_sizes_kib: [u64; 4] = [256, 512, 1024, 2048];
    let app = "canneal"; // cache-hungry: reacts to L2 capacity
    println!("DSE: private L2 capacity sweep, app={app}, 4 cores, O3+CHI-lite\n");
    println!(
        "{:>8} {:>12} {:>10} {:>10} {:>9} {:>9}",
        "L2(KiB)", "sim_time(us)", "l2_miss", "l3_miss", "speedup", "terr(%)"
    );

    for kib in l2_sizes_kib {
        let mut cfg = RunConfig::default();
        cfg.app = app.to_string();
        cfg.system.cores = 4;
        cfg.ops_per_core = 4096;
        cfg.system.l2.size_bytes = kib * 1024;

        let workload = make_workload(&cfg)?;
        let serial = run_with_workload(&cfg, &workload)?;

        let mut par = cfg.clone();
        par.mode = Mode::Virtual;
        par.quantum = 8 * NS;
        let pdes = run_with_workload(&par, &workload)?;

        let mut host = HostModel::default();
        host.calibrate_cost(&serial);
        let speedup =
            host.speedup(serial.events, pdes.work.as_ref().unwrap());
        let acc = compare(&serial, &pdes);

        println!(
            "{:>8} {:>12.2} {:>10.4} {:>10.4} {:>8.2}x {:>9.2}",
            kib,
            serial.sim_seconds() * 1e6,
            avg_miss_rate(&serial, ".l2.miss_rate"),
            avg_miss_rate(&serial, "hnf.miss_rate"),
            speedup,
            acc.sim_time_error * 100.0,
        );
        assert!(acc.checksum_match, "functional mismatch in DSE run");
    }
    println!("\n(speedup = modeled wall-clock on the paper's 64-core host; accuracy vs serial reference)");
    Ok(())
}
