//! Quickstart: build a 4-core MPSoC (Table 2 defaults), run a workload on
//! the reference serial kernel and on the parti PDES kernel, and compare.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{compare_modes, run_once};
use parti_sim::pdes::HostModel;
use parti_sim::sim::time::NS;
use parti_sim::stats::Summary;

fn main() -> anyhow::Result<()> {
    // 1. Configure: 4 ARM-like O3 cores, CHI-lite Ruby hierarchy.
    let mut cfg = RunConfig::default();
    cfg.app = "blackscholes".to_string();
    cfg.system.cores = 4;
    cfg.ops_per_core = 4096;

    // 2. Reference run on the single-thread DES kernel.
    let serial = run_once(&cfg)?;
    println!("--- serial reference ---");
    println!("{}", Summary::from_result(&serial).to_json());

    // 3. parti PDES: per-core time domains + shared domain, quantum 8 ns.
    let mut par = cfg.clone();
    par.mode = Mode::Virtual; // deterministic PDES; use Parallel on a many-core host
    par.quantum = 8 * NS;
    let mut host = HostModel::default(); // models the paper's 64-core host
    let row = compare_modes(&cfg, &par, &mut host)?;

    println!("\n--- parti-sim PDES (quantum 8 ns, modeled 64-core host) ---");
    println!("speedup:            {:.2}x", row.speedup);
    println!("sim-time error:     {:.2}%", row.sim_time_error * 100.0);
    println!(
        "miss-rate err (pp): l1i={:.3} l1d={:.3} l2={:.3} l3={:.3}",
        row.miss_rate_err_pp[0],
        row.miss_rate_err_pp[1],
        row.miss_rate_err_pp[2],
        row.miss_rate_err_pp[3]
    );
    println!(
        "functional check:   load checksums {}",
        if row.checksum_match { "match" } else { "MISMATCH" }
    );
    println!(
        "pdes artefacts:     {} cross-domain events, {} postponed (t_pp mean {:.2} ns)",
        row.run.pdes.cross_events,
        row.run.pdes.postponed,
        row.run.pdes.tpp_mean() / 1000.0
    );
    Ok(())
}
