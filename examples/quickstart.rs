//! Quickstart: describe an MPSoC with the declarative [`SystemSpec`]
//! platform API, run a workload on the reference serial kernel and on the
//! parti PDES kernel, and compare.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{compare_modes, run_once};
use parti_sim::pdes::HostModel;
use parti_sim::sim::time::NS;
use parti_sim::spec::{platforms, SystemSpec};
use parti_sim::stats::Summary;

fn main() -> anyhow::Result<()> {
    // 1. Describe the platform: 4 ARM-like O3 cores, Table 2 caches,
    //    Fig. 4 star interconnect. A spec can also come from the preset
    //    registry (`platforms::preset("fig4-8")`) or a TOML file
    //    (`SystemSpec::load`); `to_toml()` below shows the file format.
    let spec = SystemSpec { cores: 4, ..SystemSpec::default() }
        .named("quickstart-4", "4-core Fig. 4 star, Table 2 geometry");
    spec.validate()?;
    println!("--- platform ---\n{}\n", spec.describe());

    // 2. Put the platform in a run configuration and pick a workload.
    let mut cfg = RunConfig::for_spec(&spec);
    cfg.app = "blackscholes".to_string();
    cfg.ops_per_core = 4096;

    // 3. Reference run on the single-thread DES kernel.
    let serial = run_once(&cfg)?;
    println!("--- serial reference ---");
    println!("{}", Summary::from_result(&serial).to_json());

    // 4. parti PDES: per-core time domains + shared domain, quantum 8 ns.
    let mut par = cfg.clone();
    par.mode = Mode::Virtual; // deterministic PDES; use Parallel on a many-core host
    par.quantum = 8 * NS;
    let mut host = HostModel::default(); // models the paper's 64-core host
    let row = compare_modes(&cfg, &par, &mut host)?;

    println!("\n--- parti-sim PDES (quantum 8 ns, modeled 64-core host) ---");
    println!("speedup:            {:.2}x", row.speedup);
    println!("sim-time error:     {:.2}%", row.sim_time_error * 100.0);
    println!(
        "miss-rate err (pp): l1i={:.3} l1d={:.3} l2={:.3} l3={:.3}",
        row.miss_rate_err_pp[0],
        row.miss_rate_err_pp[1],
        row.miss_rate_err_pp[2],
        row.miss_rate_err_pp[3]
    );
    println!(
        "functional check:   load checksums {}",
        if row.checksum_match { "match" } else { "MISMATCH" }
    );
    println!(
        "pdes artefacts:     {} cross-domain events, {} postponed (t_pp mean {:.2} ns)",
        row.run.pdes.cross_events,
        row.run.pdes.postponed,
        row.run.pdes.tpp_mean() / 1000.0
    );

    // 5. The same API drives every preset — e.g. the 16-core ring:
    let ring = platforms::preset("ring-16").expect("registry preset");
    println!(
        "\n(next: try `parti-sim run --platform {}` — {})",
        ring.name, ring.description
    );
    Ok(())
}
