//! STREAM: the paper's bandwidth-bound workload (§5.1), driven through the
//! [`SystemSpec`] platform API. Reports the simulated DRAM traffic and
//! achieved bandwidth per core count, verifies the triad payload artifact
//! against Rust-computed ground truth, shows why STREAM is the worst case
//! for PDES speedup (all traffic hits the shared domain) — sweeps the
//! spec's `mem_channels` axis to show the HN-F's line-interleaved
//! multi-channel memory spreading the same traffic, and contrasts STREAM
//! with the synthetic `TrafficSpec` patterns (docs/TRAFFIC.md) as
//! alternative bandwidth loads.
//!
//! ```sh
//! cargo run --release --example stream_bandwidth
//! ```

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::HostModel;
use parti_sim::runtime::{stream_payload, Runtime, PAYLOAD_B};
use parti_sim::sim::time::NS;
use parti_sim::spec::SystemSpec;

fn main() -> anyhow::Result<()> {
    // ---- triad payload verification through PJRT ----
    let dir = Runtime::default_dir();
    if Runtime::artifacts_available(&dir) {
        let rt = Runtime::new(dir)?;
        let b: Vec<f32> = (0..PAYLOAD_B).map(|i| i as f32).collect();
        let c: Vec<f32> = (0..PAYLOAD_B).map(|i| (i * 3) as f32).collect();
        let a = stream_payload(&rt, &b, &c, 3.0)?;
        let max_err = a
            .iter()
            .enumerate()
            .map(|(i, &x)| (x - (b[i] + 3.0 * c[i])).abs())
            .fold(0.0f32, f32::max);
        println!("triad artifact verified: max |err| = {max_err:e}\n");
        anyhow::ensure!(max_err < 1e-2, "triad artifact diverged");
    } else {
        println!("(artifacts missing; skipping triad verification)\n");
    }

    // ---- simulated bandwidth scaling over the core-count axis ----
    println!(
        "{:>6} {:>12} {:>14} {:>12} {:>9}",
        "cores", "dram_reads", "bandwidth(GB/s)", "sim_time(us)", "speedup"
    );
    for cores in [1usize, 2, 4, 8] {
        let spec = SystemSpec { cores, ..SystemSpec::default() }
            .named("stream-sweep", "STREAM bandwidth point");
        let mut cfg = RunConfig::for_spec(&spec);
        cfg.app = "stream".to_string();
        cfg.ops_per_core = 2048;
        let w = make_workload(&cfg)?;
        let serial = run_with_workload(&cfg, &w)?;

        let mut par = cfg.clone();
        par.mode = Mode::Virtual;
        par.quantum = 8 * NS;
        let pdes = run_with_workload(&par, &w)?;
        let mut host = HostModel::default();
        host.calibrate_cost(&serial);
        let speedup = host.speedup(serial.events, pdes.work.as_ref().unwrap());

        let reads = serial.stats.get("dram.reads").unwrap_or(0.0);
        let writes = serial.stats.get("dram.writes").unwrap_or(0.0);
        let bytes = (reads + writes) * 64.0;
        let gbps = bytes / serial.sim_seconds() / 1e9;
        println!(
            "{:>6} {:>12} {:>14.2} {:>12.2} {:>8.2}x",
            cores,
            reads as u64,
            gbps,
            serial.sim_seconds() * 1e6,
            speedup
        );
    }

    // ---- memory-channel axis: same 8-core STREAM, 1 vs 2 vs 4 channels
    println!(
        "\n{:>9} {:>14} {:>14} {:>12}",
        "channels", "hnf_dram_reads", "per-ch reads", "sim_time(us)"
    );
    for channels in [1usize, 2, 4] {
        let spec = SystemSpec {
            cores: 8,
            mem_channels: channels,
            ..SystemSpec::default()
        }
        .named("stream-channels", "STREAM memory-channel point");
        let mut cfg = RunConfig::for_spec(&spec);
        cfg.app = "stream".to_string();
        cfg.ops_per_core = 2048;
        let w = make_workload(&cfg)?;
        let serial = run_with_workload(&cfg, &w)?;
        // Channel-agnostic totals come from the HN-F; per-channel
        // controllers are named dram0..dramN-1 (plain "dram" when single).
        let total = serial.stats.get("hnf.dram_reads").unwrap_or(0.0);
        let per_ch: f64 = if channels == 1 {
            serial.stats.get("dram.reads").unwrap_or(0.0)
        } else {
            (0..channels)
                .filter_map(|c| serial.stats.get(&format!("dram{c}.reads")))
                .sum::<f64>()
                / channels as f64
        };
        println!(
            "{:>9} {:>14} {:>14.0} {:>12.2}",
            channels,
            total as u64,
            per_ch,
            serial.sim_seconds() * 1e6
        );
    }
    println!(
        "\nSTREAM saturates the shared domain (DRAM + HNF), so PDES gains \
         are the smallest — exactly the paper's observation (§5.2); \
         line-interleaved channels split the same traffic evenly."
    );

    // ---- traffic-pattern axis: the same 8-core machine under synthetic
    // TrafficSpec load instead of STREAM. uniform-random sprays every
    // region (DRAM-heavy), hotspot re-hits 8 lines (cache-held, snoop-
    // heavy), producer-consumer streams one-way through the home node.
    println!(
        "\n{:>18} {:>12} {:>15} {:>9} {:>9}",
        "pattern", "dram_reads", "bandwidth(GB/s)", "accepted", "retries"
    );
    for name in ["uniform-random", "hotspot", "producer-consumer"] {
        let spec = SystemSpec { cores: 8, ..SystemSpec::default() }
            .named("traffic-bw", "synthetic traffic bandwidth point");
        let mut cfg = RunConfig::for_spec(&spec);
        cfg.traffic = Some(name.to_string());
        cfg.ops_per_core = 2048;
        let w = make_workload(&cfg)?;
        let r = run_with_workload(&cfg, &w)?;
        let reads = r.stats.get("dram.reads").unwrap_or(0.0);
        let writes = r.stats.get("dram.writes").unwrap_or(0.0);
        let gbps = (reads + writes) * 64.0 / r.sim_seconds() / 1e9;
        println!(
            "{:>18} {:>12} {:>15.2} {:>9} {:>9}",
            name,
            reads as u64,
            gbps,
            r.pdes.traffic_accepted,
            r.pdes.traffic_retries,
        );
    }
    println!(
        "\n(offered == accepted on every completed run; retries counts \
         LSQ backpressure — the hotspot row trades DRAM traffic for \
         coherence traffic at the HN-F)"
    );
    Ok(())
}
