//! END-TO-END driver: the full three-layer system on a real small workload.
//!
//! This example proves all layers compose:
//!
//!   L1/L2 (build time)   Pallas addrgen + blackscholes kernels, AOT-lowered
//!                        to artifacts/*.hlo.txt
//!   runtime              Rust loads the HLO via PJRT and executes it:
//!                        traces for every core + real Black-Scholes prices
//!   L3                   the prices are *carried through the simulated
//!                        coherent memory*: a producer core stores each
//!                        price to a shared line, a barrier synchronises,
//!                        and consumer cores load + verify them (any stale
//!                        or lost data shows up as value_mismatches)
//!   PDES                 the same system runs under the serial reference
//!                        and the parti PDES kernel; speedup + accuracy are
//!                        reported like Fig. 8
//!
//! ```sh
//! make artifacts && cargo run --release --example parsec_mpsoc
//! ```

use std::sync::Arc;

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::HostModel;
use parti_sim::runtime::{blackscholes_payload, Runtime, PAYLOAD_B};
use parti_sim::sim::time::NS;
use parti_sim::spec::{platforms, SystemSpec};
use parti_sim::stats::compare;
use parti_sim::workload::gen::{squares32, SQUARES_KEY};
use parti_sim::workload::trace::NO_EXPECT;
use parti_sim::workload::{CoreTrace, Workload, FIG8_APPS};

const SHARED: u64 = 0x8000_0000;

/// Build the payload-verification workload: core 0 produces PJRT-computed
/// option prices into shared memory; the other cores consume and check.
fn blackscholes_payload_workload(
    rt: &Runtime,
    n_consumers: usize,
    n_opts: usize,
) -> anyhow::Result<Workload> {
    // Deterministic option batch (mirrors model.option_inputs()).
    let u = |i: usize, k: u64| {
        squares32(i as u64 * 5 + k, SQUARES_KEY) as f32 / u32::MAX as f32
    };
    let spot: Vec<f32> = (0..PAYLOAD_B).map(|i| 5.0 + 95.0 * u(i, 0)).collect();
    let strike: Vec<f32> = (0..PAYLOAD_B).map(|i| 5.0 + 95.0 * u(i, 1)).collect();
    let rate: Vec<f32> = (0..PAYLOAD_B).map(|i| 0.01 + 0.09 * u(i, 2)).collect();
    let vol: Vec<f32> = (0..PAYLOAD_B).map(|i| 0.05 + 0.55 * u(i, 3)).collect();
    let time: Vec<f32> = (0..PAYLOAD_B).map(|i| 0.1 + 2.9 * u(i, 4)).collect();
    let (call, _put) = blackscholes_payload(rt, &spot, &strike, &rate, &vol, &time)?;

    // Producer: store price bits to shared lines, then barrier.
    let mut p_addr = Vec::new();
    let mut p_store = Vec::new();
    let mut p_val = Vec::new();
    for i in 0..n_opts {
        p_addr.push(SHARED + i as u64 * 64);
        p_store.push(true);
        p_val.push(call[i].to_bits() as u64);
    }
    // After the barrier the producer idles on private loads.
    for i in 0..n_opts {
        p_addr.push(0x1000_0000 + i as u64 * 64);
        p_store.push(false);
        p_val.push(0);
    }
    let producer = CoreTrace {
        gap: vec![2; p_addr.len()],
        expected: vec![NO_EXPECT; p_addr.len()],
        addr: p_addr,
        is_store: p_store,
        value: p_val,
    };

    // Consumers: private warm-up until the barrier, then load + verify.
    let mut cores = vec![Arc::new(producer)];
    for c in 0..n_consumers {
        let mut addr = Vec::new();
        let mut expected = Vec::new();
        for i in 0..n_opts {
            // per-consumer private warm-up region
            addr.push(0x1_1000_0000 + ((c as u64) << 24) + i as u64 * 64);
            expected.push(NO_EXPECT);
        }
        for i in 0..n_opts {
            addr.push(SHARED + i as u64 * 64);
            expected.push(call[i].to_bits() as u64);
        }
        let n = addr.len();
        cores.push(Arc::new(CoreTrace {
            addr,
            is_store: vec![false; n],
            gap: vec![2; n],
            value: vec![0; n],
            expected,
        }));
    }
    Ok(Workload {
        cores,
        barrier_every: n_opts,
        name: "blackscholes-payload".into(),
    })
}

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    anyhow::ensure!(
        Runtime::artifacts_available(&dir),
        "artifacts/ missing — run `make artifacts` first"
    );
    let rt = Runtime::new(dir)?;

    // ---- Part 1: Black-Scholes prices through the simulated memory ----
    println!("=== Part 1: PJRT Black-Scholes payload through coherent memory ===");
    let w = blackscholes_payload_workload(&rt, 3, 512)?;
    // Platform via the declarative spec API: producer + 3 consumers on
    // the Table 2 star.
    let spec = SystemSpec { cores: w.n_cores(), ..SystemSpec::default() }
        .named("payload-4", "Black-Scholes payload machine");
    spec.validate()?;
    let cfg = RunConfig::for_spec(&spec);
    for mode in [Mode::Serial, Mode::Virtual] {
        let mut c = cfg.clone();
        c.mode = mode;
        c.quantum = 8 * NS;
        let r = run_with_workload(&c, &w)?;
        let mism = r.stats.sum_suffix(".value_mismatches");
        println!(
            "{mode:?}: {} ops committed, {} price loads verified, {} mismatches",
            r.stats.sum_suffix(".committed_ops"),
            512 * 3,
            mism
        );
        anyhow::ensure!(mism == 0.0, "payload corrupted in {mode:?} mode");
    }

    // ---- Part 2: Fig. 8-style PARSEC subset on the fig4-8 preset ----
    let fig4_8 = platforms::preset("fig4-8").expect("registry preset");
    println!(
        "\n=== Part 2: PARSEC subset + STREAM on `{}` ({}) ===",
        fig4_8.name, fig4_8.description
    );
    println!(
        "{:<14} {:>9} {:>10} {:>8}",
        "app", "speedup", "terr(%)", "csum"
    );
    for app in FIG8_APPS {
        let mut s_cfg = RunConfig::for_spec(&fig4_8);
        s_cfg.app = app.to_string();
        s_cfg.ops_per_core = 2048;
        let workload = make_workload(&s_cfg)?;
        let serial = run_with_workload(&s_cfg, &workload)?;
        let mut p_cfg = s_cfg.clone();
        p_cfg.mode = Mode::Virtual;
        p_cfg.quantum = 8 * NS;
        let pdes = run_with_workload(&p_cfg, &workload)?;
        let mut host = HostModel::default();
        host.calibrate_cost(&serial);
        let speedup = host.speedup(serial.events, pdes.work.as_ref().unwrap());
        let acc = compare(&serial, &pdes);
        println!(
            "{:<14} {:>8.2}x {:>10.2} {:>8}",
            app,
            speedup,
            acc.sim_time_error * 100.0,
            if acc.checksum_match { "ok" } else { "DIFF" }
        );
    }
    println!("\nAll layers composed: Pallas -> HLO -> PJRT -> traces/payloads -> Ruby CHI-lite -> PDES.");
    Ok(())
}
