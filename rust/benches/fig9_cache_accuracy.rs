//! Bench: regenerate Fig. 9 — absolute cache-miss-rate error per level for
//! PARSEC + STREAM on a 32-core target (paper: < 2.5 percentage points for
//! all apps and quanta).
//!
//! Scale via env: FIG9_OPS (default 2048), FIG9_CORES (default 32).

#[path = "bench_util.rs"]
mod bench_util;

use parti_sim::harness::figures::{fig9, FigureOpts};

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let opts = FigureOpts {
        ops_per_core: env_usize("FIG9_OPS", 2048),
        max_cores: env_usize("FIG9_CORES", 32),
        ..Default::default()
    };
    let t = std::time::Instant::now();
    let rows = fig9(&opts).expect("fig9");
    println!("== Fig. 9 (paper: abs miss-rate error < 2.5pp everywhere) ==\n");
    println!(
        "{:<14} {:>6} {:>8} {:>8} {:>8} {:>8}",
        "app", "q(ns)", "l1i(pp)", "l1d(pp)", "l2(pp)", "l3(pp)"
    );
    let mut worst: f64 = 0.0;
    for (app, r) in &rows {
        println!(
            "{:<14} {:>6} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
            app,
            r.quantum_ns,
            r.miss_rate_err_pp[0],
            r.miss_rate_err_pp[1],
            r.miss_rate_err_pp[2],
            r.miss_rate_err_pp[3]
        );
        for e in r.miss_rate_err_pp {
            worst = worst.max(e);
        }
    }
    println!("\nworst-case error: {worst:.3} pp (paper bound: 2.5 pp)");
    println!("bench wall time: {:.1}s", t.elapsed().as_secs_f64());
}
