//! Micro-benchmarks of the simulation kernel hot paths (the §Perf targets
//! for L3): event-queue throughput, message-buffer ops, cache-array
//! lookups, and end-to-end serial events/s.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::bench;

use parti_sim::config::RunConfig;
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::mem::{CacheArray, LineState};
use parti_sim::ruby::new_inbox;
use parti_sim::ruby::{MsgKind, RubyMsg};
use parti_sim::sim::event::{prio, EventKind};
use parti_sim::sim::ids::CompId;
use parti_sim::sim::queue::EventQueue;

fn main() {
    println!("== kernel_micro ==");

    // Event queue: schedule+pop 100k events with mixed ticks.
    bench("event_queue schedule+pop 100k", 11, || {
        let mut q = EventQueue::new();
        for i in 0..100_000u64 {
            q.schedule(
                (i.wrapping_mul(2654435761)) % 1_000_000,
                prio::DEFAULT,
                CompId(0),
                EventKind::CpuTick,
            );
        }
        while q.pop().is_some() {}
    });

    // Message buffer: enqueue/drain 100k messages across 3 buffers.
    bench("inbox push+drain 100k", 11, || {
        let inbox = new_inbox(&[usize::MAX; 3]);
        let mut ib = inbox.lock().unwrap();
        for i in 0..100_000u64 {
            let m = RubyMsg {
                kind: MsgKind::ReadShared,
                addr: i * 64,
                value: 0,
                src: CompId(0),
                dst: CompId(1),
                txn: i,
                core: 0,
                issued: 0,
            };
            ib.bufs[(i % 3) as usize].push_for_test(i % 1000, m);
        }
        let _ = ib.drain_ready(u64::MAX);
    });

    // Cache array: 1M accesses with 80/20 locality.
    bench("cache_array 1M accesses", 7, || {
        let mut c = CacheArray::new(64 * 1024, 2, 64);
        let mut hits = 0u64;
        for i in 0..1_000_000u64 {
            let addr = if i % 5 == 0 {
                (i.wrapping_mul(2654435761)) % (1 << 22)
            } else {
                (i % 512) * 64
            } & !63;
            match c.access(addr) {
                Some(_) => hits += 1,
                None => {
                    c.allocate(addr, LineState::Shared, addr);
                }
            }
        }
        std::hint::black_box(hits);
    });

    // End-to-end serial kernel throughput (the L3 §Perf headline).
    let mut cfg = RunConfig::default();
    cfg.app = "blackscholes".to_string();
    cfg.system.cores = 4;
    cfg.ops_per_core = 4096;
    let w = make_workload(&cfg).expect("workload");
    let mut events_per_sec = 0.0;
    bench("serial end-to-end 4c x 4096 ops", 5, || {
        let r = run_with_workload(&cfg, &w).unwrap();
        events_per_sec = r.events_per_sec();
    });
    println!("serial kernel throughput: {events_per_sec:.0} events/s");
}
