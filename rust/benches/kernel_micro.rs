//! Micro-benchmarks of the simulation kernel hot paths (the §Perf targets
//! for L3): event-queue throughput (heap vs bucketed), cross-domain
//! injector throughput (mutex baseline vs lock-free mailbox), message
//! buffers, cache arrays, and end-to-end kernel events/s on the paper's
//! 16-domain configuration.
//!
//! Writes the scheduler-path numbers to `BENCH_sched.json` (override the
//! path with `BENCH_SCHED_JSON`) so the perf trajectory of the `sched/`
//! layer is recorded per run.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, measure};

use std::sync::Mutex;

use parti_sim::config::RunConfig;
use parti_sim::cpu::CpuModel;
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::mem::{CacheArray, LineState};
use parti_sim::pdes::HostModel;
use parti_sim::ruby::new_inbox;
use parti_sim::ruby::{MsgKind, RubyMsg};
use parti_sim::sched::{
    BucketShape, InboxOrder, Mailbox, QuantumPolicy, QueueKind, SchedQueue,
    Scheduler, XbarArb,
};
use parti_sim::sim::event::{prio, Event, EventKind};
use parti_sim::sim::ids::CompId;
use parti_sim::spec::{platforms, Interconnect, SystemSpec};
use parti_sim::util::json::JsonObj;

/// The old `Injector` (pre-`sched/` baseline), kept here as the reference
/// point for the lock-free mailbox numbers.
#[derive(Default)]
struct MutexInjector {
    queue: Mutex<Vec<Event>>,
}

impl MutexInjector {
    fn push(&self, ev: Event) {
        self.queue.lock().unwrap().push(ev);
    }

    fn drain(&self) -> Vec<Event> {
        let mut v = std::mem::take(&mut *self.queue.lock().unwrap());
        v.sort_by_key(|e| (e.tick, e.prio, e.target.0, e.seq));
        v
    }
}

fn queue_workload(q: &mut SchedQueue, n: u64) {
    for i in 0..n {
        q.schedule(
            (i.wrapping_mul(2654435761)) % 1_000_000,
            prio::DEFAULT,
            CompId(0),
            EventKind::CpuTick,
        );
    }
    while q.pop().is_some() {}
}

fn ev(tick: u64, target: u32) -> Event {
    Event {
        tick,
        prio: prio::DEFAULT,
        seq: 0,
        target: CompId(target),
        kind: EventKind::CpuTick,
    }
}

/// 4 producer threads × `per` events each, then a border drain — the
/// mailbox's real access pattern (producers quiesce before the drain).
fn injector_round<P: Fn(Event) + Sync, D: FnOnce() -> usize>(
    per: u64,
    push: P,
    drain: D,
) {
    std::thread::scope(|s| {
        for p in 0..4u64 {
            let push = &push;
            s.spawn(move || {
                for i in 0..per {
                    push(ev(p * per + i, p as u32));
                }
            });
        }
    });
    assert_eq!(drain(), 4 * per as usize);
}

fn main() {
    println!("== kernel_micro ==");
    let mut json = JsonObj::new();

    // Event queue: schedule+pop 100k events with mixed ticks, both kinds.
    let mut queue_ns = Vec::new();
    for kind in [QueueKind::Heap, QueueKind::Bucket] {
        let (m, lo, hi) = measure(11, || {
            let mut q = SchedQueue::new(kind);
            queue_workload(&mut q, 100_000);
        });
        bench_util::report(
            &format!("event_queue[{kind:?}] schedule+pop 100k"),
            m,
            lo,
            hi,
        );
        queue_ns.push((kind, m));
    }
    json = json.obj(
        "event_queue_100k",
        JsonObj::new()
            .u64("heap_median_ns", queue_ns[0].1 as u64)
            .u64("bucket_median_ns", queue_ns[1].1 as u64),
    );

    // Bucket-queue calendar geometry calibration (`--bucket-width` /
    // `--bucket-slots`): the same 100k mixed-tick workload across shapes.
    // The default (2048×64) is the committed choice; this row is the
    // evidence for revisiting it per host (docs/PERF.md).
    let mut shapes = JsonObj::new();
    for (width, nbuckets) in [(2048u64, 64usize), (256, 16), (65536, 128)] {
        let shape = BucketShape { width, nbuckets }.validate().unwrap();
        let (m, lo, hi) = measure(11, || {
            let mut q = SchedQueue::with_shape(QueueKind::Bucket, shape);
            queue_workload(&mut q, 100_000);
        });
        bench_util::report(
            &format!("bucket_shape[{width}x{nbuckets}] schedule+pop 100k"),
            m,
            lo,
            hi,
        );
        shapes = shapes.obj(
            &format!("w{width}_s{nbuckets}"),
            JsonObj::new().u64("median_ns", m as u64),
        );
    }
    json = json.obj("bucket_shape_100k", shapes);

    // Cross-domain injector: 4 producers × 25k, then one border drain.
    let (mutex_m, lo, hi) = measure(11, || {
        let inj = MutexInjector::default();
        injector_round(25_000, |e| inj.push(e), || inj.drain().len());
    });
    bench_util::report("injector[mutex] 4x25k push+drain", mutex_m, lo, hi);
    let (mb_m, lo, hi) = measure(11, || {
        let mb = Mailbox::default();
        injector_round(25_000, |e| mb.push(e), || mb.drain().len());
    });
    bench_util::report("injector[lockfree] 4x25k push+drain", mb_m, lo, hi);
    json = json.obj(
        "injector_100k",
        JsonObj::new()
            .u64("mutex_median_ns", mutex_m as u64)
            .u64("lockfree_median_ns", mb_m as u64),
    );

    // Message buffer: enqueue/drain 100k messages across 3 buffers.
    bench("inbox push+drain 100k", 11, || {
        let inbox = new_inbox(&[usize::MAX; 3]);
        let mut ib = inbox.lock().unwrap();
        for i in 0..100_000u64 {
            let m = RubyMsg {
                kind: MsgKind::ReadShared,
                addr: i * 64,
                value: 0,
                src: CompId(0),
                dst: CompId(1),
                txn: i,
                core: 0,
                issued: 0,
            };
            ib.bufs[(i % 3) as usize].push_for_test(i % 1000, m);
        }
        let _ = ib.drain_ready(u64::MAX);
    });

    // Cache array: 1M accesses with 80/20 locality.
    bench("cache_array 1M accesses", 7, || {
        let mut c = CacheArray::new(64 * 1024, 2, 64);
        let mut hits = 0u64;
        for i in 0..1_000_000u64 {
            let addr = if i % 5 == 0 {
                (i.wrapping_mul(2654435761)) % (1 << 22)
            } else {
                (i % 512) * 64
            } & !63;
            match c.access(addr) {
                Some(_) => hits += 1,
                None => {
                    c.allocate(addr, LineState::Shared, addr);
                }
            }
        }
        std::hint::black_box(hits);
    });

    // End-to-end: the acceptance configuration — 16 domains (15 cores +
    // shared) on the deterministic PDES kernel, heap vs bucket.
    let mut e2e = JsonObj::new();
    for kind in [QueueKind::Heap, QueueKind::Bucket] {
        let mut cfg = RunConfig {
            app: "blackscholes".to_string(),
            ops_per_core: 2048,
            mode: parti_sim::config::Mode::Virtual,
            queue: kind,
            ..Default::default()
        };
        cfg.system.cores = 15; // + shared domain = 16 event queues
        let w = make_workload(&cfg).expect("workload");
        let mut events_per_sec = 0.0;
        let (m, lo, hi) = measure(5, || {
            let r = run_with_workload(&cfg, &w).unwrap();
            events_per_sec = r.events_per_sec();
        });
        bench_util::report(
            &format!("virtual 16-domain e2e [{kind:?}]"),
            m,
            lo,
            hi,
        );
        println!("  {kind:?} kernel throughput: {events_per_sec:.0} events/s");
        e2e = e2e.obj(
            &format!("{kind:?}").to_lowercase(),
            JsonObj::new()
                .u64("median_ns", m as u64)
                .f64("events_per_sec", events_per_sec),
        );
    }
    json = json.obj("virtual_16_domain_e2e", e2e);

    // Per-topology end-to-end: the same 16-core sharing workload on each
    // interconnect the SystemSpec API elaborates (star / ring / mesh).
    // Longer fabrics route the same coherence traffic over more hops, so
    // both the simulated time and the kernel wall-clock move — this row
    // tracks the elaboration overhead per topology.
    let mut topo = JsonObj::new();
    for (name, ic) in [
        ("star", Interconnect::Star),
        ("ring", Interconnect::Ring),
        ("mesh", Interconnect::Mesh { cols: 4 }),
    ] {
        let spec = SystemSpec {
            cores: 16,
            interconnect: ic,
            ..SystemSpec::default()
        }
        .named("bench-topo", "kernel_micro topology row");
        let mut cfg = RunConfig::for_spec(&spec);
        cfg.app = "canneal".to_string();
        cfg.ops_per_core = 1024;
        cfg.mode = parti_sim::config::Mode::Virtual;
        let w = make_workload(&cfg).expect("workload");
        let mut last = None;
        let (m, lo, hi) = measure(5, || {
            last = Some(run_with_workload(&cfg, &w).unwrap());
        });
        let r = last.expect("measured at least once");
        let routed = r.stats.sum_suffix(".routed");
        bench_util::report(
            &format!("virtual 16-core topology[{name}]"),
            m,
            lo,
            hi,
        );
        println!(
            "  {name}: sim_ticks={} routed_msgs={:.0} events={}",
            r.sim_ticks, routed, r.events
        );
        topo = topo.obj(
            name,
            JsonObj::new()
                .u64("median_ns", m as u64)
                .u64("sim_ticks", r.sim_ticks)
                .u64("routed_msgs", routed as u64)
                .f64("events_per_sec", r.events_per_sec()),
        );
    }
    json = json.obj("topology_16_core", topo);

    // Synthetic traffic patterns on the 16-core ring (docs/TRAFFIC.md):
    // the kernel cost of the adversarial TrafficSpec loads the Table 3
    // apps never produce. One contention row (hotspot), one geometry row
    // (transpose) and the uniform-random baseline; the pattern *shapes*
    // themselves are gated by rust/tests/traffic.rs — this row tracks
    // what they cost to simulate.
    let mut traffic_rows = JsonObj::new();
    {
        let ring = platforms::preset("ring-16").expect("ring-16 preset");
        for name in ["uniform-random", "hotspot", "transpose"] {
            let mut cfg = RunConfig::for_spec(&ring);
            cfg.traffic = Some(name.to_string());
            cfg.ops_per_core = 512;
            cfg.mode = parti_sim::config::Mode::Virtual;
            let w = make_workload(&cfg).expect("workload");
            let mut last = None;
            let (m, lo, hi) = measure(5, || {
                last = Some(run_with_workload(&cfg, &w).unwrap());
            });
            let r = last.expect("measured at least once");
            bench_util::report(
                &format!("virtual 16-core traffic[{name}]"),
                m,
                lo,
                hi,
            );
            let requeued = r.stats.get("hnf.requeued").unwrap_or(0.0);
            println!(
                "  {name}: sim_ticks={} retries={} hnf_requeued={requeued:.0}",
                r.sim_ticks, r.pdes.traffic_retries
            );
            traffic_rows = traffic_rows.obj(
                &name.replace('-', "_"),
                JsonObj::new()
                    .u64("median_ns", m as u64)
                    .u64("sim_ticks", r.sim_ticks)
                    .u64("traffic_retries", r.pdes.traffic_retries)
                    .u64("hnf_requeued", requeued as u64)
                    .f64("events_per_sec", r.events_per_sec()),
            );
        }
    }
    json = json.obj("traffic_pattern_16_core", traffic_rows);

    // CPU model cost on the 16-core ring (docs/O3.md): the staged O3
    // pipeline against the in-order Minor baseline on the same miss-heavy
    // traffic. O3's overlapped misses shrink sim_ticks (the model's whole
    // point — gated by rust/tests/o3.rs); this row tracks what the extra
    // pipeline bookkeeping costs the kernel in wall-clock per event, and
    // carries the structural-stall counter so a geometry regression (a
    // default that suddenly starves dispatch) shows up in the trajectory.
    let mut cpu_rows = JsonObj::new();
    {
        let ring = platforms::preset("ring-16").expect("ring-16 preset");
        for (name, model) in [("minor", CpuModel::Minor), ("o3", CpuModel::O3)]
        {
            let mut cfg = RunConfig::for_spec(&ring);
            cfg.cpu_model = model;
            cfg.traffic = Some("uniform-random".to_string());
            cfg.ops_per_core = 512;
            cfg.mode = parti_sim::config::Mode::Virtual;
            let w = make_workload(&cfg).expect("workload");
            let mut last = None;
            let (m, lo, hi) = measure(5, || {
                last = Some(run_with_workload(&cfg, &w).unwrap());
            });
            let r = last.expect("measured at least once");
            bench_util::report(
                &format!("virtual 16-core cpu-model[{name}]"),
                m,
                lo,
                hi,
            );
            println!(
                "  {name}: sim_ticks={} rob_full_stalls={} events={}",
                r.sim_ticks, r.pdes.rob_full_stalls, r.events
            );
            cpu_rows = cpu_rows.obj(
                name,
                JsonObj::new()
                    .u64("median_ns", m as u64)
                    .u64("sim_ticks", r.sim_ticks)
                    .u64("rob_full_stalls", r.pdes.rob_full_stalls)
                    .f64("events_per_sec", r.events_per_sec()),
            );
        }
    }
    json = json.obj("o3_pipeline_16_core", cpu_rows);

    // Adaptive quantum on the same 16-domain configuration: barrier count
    // and wall-clock, fixed vs horizon (results are bit-identical by the
    // determinism gate — only the border count may shrink), plus the
    // host-model imbalance cost of static binding vs stealing on an
    // 8-thread host (16 domains -> 2 domains per thread).
    let mut adaptive = JsonObj::new();
    for (name, qp) in
        [("fixed", QuantumPolicy::Fixed), ("horizon", QuantumPolicy::Horizon)]
    {
        let mut cfg = RunConfig {
            app: "blackscholes".to_string(),
            ops_per_core: 2048,
            mode: parti_sim::config::Mode::Virtual,
            quantum_policy: qp,
            ..Default::default()
        };
        cfg.system.cores = 15; // + shared domain = 16 event queues
        let w = make_workload(&cfg).expect("workload");
        // Time only the kernel; the host-model analysis (below) scales
        // with the window count and would bias the fixed-vs-horizon
        // comparison if it ran inside the measured closure.
        let mut last = None;
        let (m, lo, hi) = measure(5, || {
            last = Some(run_with_workload(&cfg, &w).unwrap());
        });
        let r = last.expect("measured at least once");
        let barriers = r.pdes.barriers;
        let skipped = r.pdes.quanta_skipped;
        let work = r.work.as_ref().expect("virtual records work");
        let mut host = HostModel::for_threads(8, 16);
        host.steal = true;
        let steal_wall = host.parallel_wall_ns(work);
        host.steal = false;
        let static_wall = host.parallel_wall_ns(work);
        bench_util::report(
            &format!("virtual 16-domain quantum-policy[{name}]"),
            m,
            lo,
            hi,
        );
        println!(
            "  {name}: barriers={barriers} skipped_quanta={skipped} \
             modeled wall (H=8) steal/static = {:.2} ms / {:.2} ms",
            steal_wall / 1e6,
            static_wall / 1e6
        );
        adaptive = adaptive.obj(
            name,
            JsonObj::new()
                .u64("median_ns", m as u64)
                .u64("barriers", barriers)
                .u64("quanta_skipped", skipped)
                .f64("modeled_wall_ns_h8_steal", steal_wall)
                .f64("modeled_wall_ns_h8_static", static_wall),
        );
    }
    json = json.obj("adaptive_quantum_16_domain", adaptive);

    // Threaded kernel, 16 domains oversubscribed onto 2 host threads:
    // static binding vs claim-based stealing, measured wall-clock.
    let mut threaded = JsonObj::new();
    for (name, steal) in [("static", false), ("steal", true)] {
        let mut cfg = RunConfig {
            app: "blackscholes".to_string(),
            ops_per_core: 2048,
            mode: parti_sim::config::Mode::Parallel,
            steal,
            threads: 2,
            ..Default::default()
        };
        cfg.system.cores = 15;
        let w = make_workload(&cfg).expect("workload");
        let mut steals = 0u64;
        let (m, lo, hi) = measure(5, || {
            let r = run_with_workload(&cfg, &w).unwrap();
            steals = r.pdes.steals;
        });
        bench_util::report(
            &format!("threaded 16-domain/2-thread [{name}]"),
            m,
            lo,
            hi,
        );
        threaded = threaded.obj(
            name,
            JsonObj::new().u64("median_ns", m as u64).u64("steals", steals),
        );
    }
    json = json.obj("threaded_16_domain_2_thread", threaded);

    // `--profile` breakdown of the same threaded configuration: where the
    // border protocol actually spends its wall time, summed over threads
    // (window execution vs freeze-barrier wait vs border sync vs
    // publish/verdict wait — docs/PERF.md explains how to read it).
    {
        let mut cfg = RunConfig {
            app: "blackscholes".to_string(),
            ops_per_core: 2048,
            mode: parti_sim::config::Mode::Parallel,
            threads: 2,
            profile: true,
            ..Default::default()
        };
        cfg.system.cores = 15;
        let w = make_workload(&cfg).expect("workload");
        let mut last = None;
        let (m, lo, hi) = measure(5, || {
            last = Some(run_with_workload(&cfg, &w).unwrap());
        });
        let r = last.expect("measured at least once");
        bench_util::report("threaded 16-domain/2-thread --profile", m, lo, hi);
        println!(
            "  profile: window={:.2}ms freeze={:.2}ms sync={:.2}ms \
             publish={:.2}ms (thread-summed)",
            r.pdes.prof_window_ns as f64 / 1e6,
            r.pdes.prof_freeze_wait_ns as f64 / 1e6,
            r.pdes.prof_border_sync_ns as f64 / 1e6,
            r.pdes.prof_publish_wait_ns as f64 / 1e6,
        );
        json = json.obj(
            "border_profile_16_domain_2_thread",
            JsonObj::new()
                .u64("median_ns", m as u64)
                .u64("window_ns", r.pdes.prof_window_ns)
                .u64("freeze_wait_ns", r.pdes.prof_freeze_wait_ns)
                .u64("border_sync_ns", r.pdes.prof_border_sync_ns)
                .u64("publish_wait_ns", r.pdes.prof_publish_wait_ns),
        );
    }

    // Fig. 7-style strong scaling on the paper's flagship mpsoc-120
    // platform: the threaded kernel at 1/2/4/8 host threads on a small
    // tick budget. Speedup is t1_median / tN_median; CI uploads this
    // table per push so the trajectory is visible without a local
    // many-core host.
    {
        let spec = platforms::preset("mpsoc-120").expect("mpsoc-120 preset");
        let mut scaling = JsonObj::new();
        let mut t1_median = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = RunConfig::for_spec(&spec);
            cfg.app = "blackscholes".to_string();
            cfg.ops_per_core = 64;
            cfg.mode = parti_sim::config::Mode::Parallel;
            cfg.threads = threads;
            let w = make_workload(&cfg).expect("workload");
            let (m, lo, hi) = measure(3, || {
                let r = run_with_workload(&cfg, &w).unwrap();
                std::hint::black_box(r.events);
            });
            bench_util::report(
                &format!("mpsoc-120 strong scaling [t{threads}]"),
                m,
                lo,
                hi,
            );
            let m_ns = m as f64;
            if threads == 1 {
                t1_median = m_ns;
            }
            let speedup = if m_ns > 0.0 { t1_median / m_ns } else { 0.0 };
            println!("  t{threads}: speedup vs t1 = {speedup:.2}x");
            scaling = scaling.obj(
                &format!("t{threads}"),
                JsonObj::new()
                    .u64("median_ns", m as u64)
                    .f64("speedup", speedup),
            );
        }
        json = json.obj("strong_scaling_mpsoc120", scaling);
    }

    // Inbox handoff: host order (the paper's racy consumption) vs the
    // deterministic border-ordered merge, on a sharing app where the
    // cross-domain Ruby path is hot. Virtual kernel: both runs are
    // deterministic, so the delta is the pure cost/benefit of staging +
    // canonical merge; threaded 2-thread: the end-to-end price of
    // determinism under real contention.
    let mut inbox_rows = JsonObj::new();
    for (mode_name, mode, threads) in [
        ("virtual", parti_sim::config::Mode::Virtual, 0usize),
        ("threaded_2t", parti_sim::config::Mode::Parallel, 2),
    ] {
        let mut pair = JsonObj::new();
        for (name, order) in
            [("host", InboxOrder::Host), ("border", InboxOrder::Border)]
        {
            let mut cfg = RunConfig {
                app: "canneal".to_string(),
                ops_per_core: 2048,
                mode,
                threads,
                inbox_order: order,
                ..Default::default()
            };
            cfg.system.cores = 15; // + shared domain = 16
            let w = make_workload(&cfg).expect("workload");
            let mut last = None;
            let (m, lo, hi) = measure(5, || {
                last = Some(run_with_workload(&cfg, &w).unwrap());
            });
            let r = last.expect("measured at least once");
            bench_util::report(
                &format!("inbox-order[{mode_name}/{name}] 16-domain e2e"),
                m,
                lo,
                hi,
            );
            println!(
                "  {mode_name}/{name}: staged={} reordered={} \
                 merge={:.0} ns/window",
                r.pdes.inbox_staged,
                r.pdes.inbox_reordered,
                r.pdes.merge_ns_per_window()
            );
            pair = pair.obj(
                name,
                JsonObj::new()
                    .u64("median_ns", m as u64)
                    .u64("inbox_staged", r.pdes.inbox_staged)
                    .u64("inbox_reordered", r.pdes.inbox_reordered)
                    .f64("merge_ns_per_window", r.pdes.merge_ns_per_window()),
            );
        }
        inbox_rows = inbox_rows.obj(mode_name, pair);
    }
    json = json.obj("inbox_order_16_domain", inbox_rows);

    // Crossbar arbitration: the paper's mid-window try_lock (host) vs the
    // deterministic border-staged grants (border), on an IO-heavy sharing
    // app (one crossbar access per 20 ops). Virtual kernel: the pure
    // cost/benefit of staging + canonical border grants; threaded
    // 2-thread: the end-to-end price of unconditional IO determinism.
    let mut xbar_rows = JsonObj::new();
    for (mode_name, mode, threads) in [
        ("virtual", parti_sim::config::Mode::Virtual, 0usize),
        ("threaded_2t", parti_sim::config::Mode::Parallel, 2),
    ] {
        let mut pair = JsonObj::new();
        for (name, arb) in [("host", XbarArb::Host), ("border", XbarArb::Border)]
        {
            let mut cfg = RunConfig {
                app: "canneal".to_string(),
                ops_per_core: 2048,
                mode,
                threads,
                xbar_arb: arb,
                ..Default::default()
            };
            cfg.system.cores = 15; // + shared domain = 16
            cfg.system.io_milli = 50;
            let w = make_workload(&cfg).expect("workload");
            let mut last = None;
            let (m, lo, hi) = measure(5, || {
                last = Some(run_with_workload(&cfg, &w).unwrap());
            });
            let r = last.expect("measured at least once");
            bench_util::report(
                &format!("xbar-arb[{mode_name}/{name}] 16-domain io e2e"),
                m,
                lo,
                hi,
            );
            println!(
                "  {mode_name}/{name}: io_reqs={:.0} staged={} deferred={}",
                r.stats.sum_suffix(".io_reqs"),
                r.pdes.xbar_staged,
                r.pdes.xbar_deferred_grants
            );
            pair = pair.obj(
                name,
                JsonObj::new()
                    .u64("median_ns", m as u64)
                    .u64("io_reqs", r.stats.sum_suffix(".io_reqs") as u64)
                    .u64("xbar_staged", r.pdes.xbar_staged)
                    .u64("xbar_deferred_grants", r.pdes.xbar_deferred_grants),
            );
        }
        xbar_rows = xbar_rows.obj(mode_name, pair);
    }
    json = json.obj("xbar_arb_16_domain", xbar_rows);

    // Sweep orchestrator outer pool: the `quick` registry sweep (4 whole
    // simulations) at outer 1 vs 4. The journal bytes are pool-size
    // invariant (tests/sweep.rs gates that); this row tracks what the
    // outer pool buys in points/sec on this host (docs/SWEEP.md).
    let mut sweep_rows = JsonObj::new();
    {
        use parti_sim::harness::sweep::{run_sweep, SweepOptions};
        let spec =
            parti_sim::spec::sweep::sweep("quick").expect("quick preset");
        for outer in [1usize, 4] {
            let journal = std::env::temp_dir().join(format!(
                "parti_bench_sweep_{}_o{outer}.jsonl",
                std::process::id()
            ));
            let mut points = 0usize;
            let (m, lo, hi) = measure(5, || {
                let _ = std::fs::remove_file(&journal);
                let opts = SweepOptions {
                    journal: journal.clone(),
                    outer: Some(outer),
                    ..SweepOptions::default()
                };
                let out = run_sweep(&spec, &opts).unwrap();
                points = out.ran;
            });
            let _ = std::fs::remove_file(&journal);
            bench_util::report(
                &format!("sweep_outer_pool[quick/outer{outer}]"),
                m,
                lo,
                hi,
            );
            let m_ns = m as f64;
            let pps =
                if m_ns > 0.0 { points as f64 / (m_ns / 1e9) } else { 0.0 };
            println!("  outer{outer}: {points} points, {pps:.2} points/s");
            sweep_rows = sweep_rows.obj(
                &format!("outer{outer}"),
                JsonObj::new()
                    .u64("median_ns", m as u64)
                    .f64("points_per_sec", pps),
            );
        }
    }
    json = json.obj("sweep_outer_pool", sweep_rows);

    // Border-quiescent checkpoint round trip on fig4-8
    // (docs/CHECKPOINT.md): what a snapshot costs to serialize, what a
    // restore costs to parse + re-elaborate + load, and the file size.
    // The snapshot is produced at the half-way border through the real
    // snap rule; bit-identity of the resumed run is gated by
    // rust/tests/checkpoint.rs — this row tracks only the cost.
    {
        use parti_sim::ckpt::{read_snapshot, snapshot_machine};
        use parti_sim::harness::{rebuild_from_snapshot, run_to_checkpoint};
        let spec = platforms::preset("fig4-8").expect("fig4-8 preset");
        let mut cfg = RunConfig::for_spec(&spec);
        cfg.app = "blackscholes".to_string();
        cfg.ops_per_core = 1024;
        cfg.mode = parti_sim::config::Mode::Virtual;
        let w = make_workload(&cfg).expect("workload");
        let full = run_with_workload(&cfg, &w).unwrap();
        let path = std::env::temp_dir().join(format!(
            "parti_bench_ckpt_{}.ckpt",
            std::process::id()
        ));
        let (_partial, border) =
            run_to_checkpoint(&cfg, full.sim_ticks / 2, &path).unwrap();
        let border = border.expect("half-way border reached");
        let bytes = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let file_bytes = bytes.len() as u64;

        let (restore_m, lo, hi) = measure(11, || {
            let snap = read_snapshot(&bytes).unwrap();
            let (machine, _eff, resumed) =
                rebuild_from_snapshot(&snap, &cfg).unwrap();
            std::hint::black_box((&machine, resumed));
        });
        bench_util::report(
            "ckpt restore (parse+elaborate+load) fig4-8",
            restore_m,
            lo,
            hi,
        );

        let snap = read_snapshot(&bytes).unwrap();
        let (machine, eff, _resumed) =
            rebuild_from_snapshot(&snap, &cfg).unwrap();
        let (snap_m, lo, hi) = measure(11, || {
            let again = snapshot_machine(&machine, &eff, border).unwrap();
            std::hint::black_box(again.len());
        });
        bench_util::report("ckpt snapshot fig4-8", snap_m, lo, hi);
        println!(
            "  border={border} file={file_bytes} bytes \
             snapshot={:.0}us restore={:.0}us",
            snap_m as f64 / 1e3,
            restore_m as f64 / 1e3
        );
        json = json.obj(
            "checkpoint_roundtrip",
            JsonObj::new().obj(
                "fig4_8",
                JsonObj::new()
                    .u64("snapshot_ns", snap_m as u64)
                    .u64("restore_ns", restore_m as u64)
                    .u64("file_bytes", file_bytes),
            ),
        );
    }

    // End-to-end serial kernel throughput (the L3 §Perf headline).
    let mut cfg = RunConfig {
        app: "blackscholes".to_string(),
        ops_per_core: 4096,
        ..Default::default()
    };
    cfg.system.cores = 4;
    let w = make_workload(&cfg).expect("workload");
    let mut events_per_sec = 0.0;
    bench("serial end-to-end 4c x 4096 ops", 5, || {
        let r = run_with_workload(&cfg, &w).unwrap();
        events_per_sec = r.events_per_sec();
    });
    println!("serial kernel throughput: {events_per_sec:.0} events/s");
    json = json.f64("serial_events_per_sec", events_per_sec);

    // Default to the tracked repo-root file regardless of cargo's CWD.
    let path = std::env::var("BENCH_SCHED_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sched.json").to_string()
    });
    let body = json.str("status", "measured").build();
    if let Err(e) = std::fs::write(&path, format!("{body}\n")) {
        eprintln!("could not write {path}: {e}");
    } else {
        println!("wrote {path}");
    }
}
