//! Shared helpers for the hand-rolled bench harness (offline environment —
//! criterion is unavailable; these benches measure with `std::time::Instant`
//! and print median-of-N results in a criterion-like format).

// Included per-bench via #[path]; not every bench uses every helper.
#![allow(dead_code)]

use std::time::Instant;

/// Measure `f` `runs` times; returns (median_ns, min_ns, max_ns).
pub fn measure<F: FnMut()>(runs: usize, mut f: F) -> (u128, u128, u128) {
    let mut samples: Vec<u128> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos());
    }
    samples.sort_unstable();
    (
        samples[samples.len() / 2],
        samples[0],
        *samples.last().unwrap(),
    )
}

pub fn report(name: &str, median_ns: u128, min_ns: u128, max_ns: u128) {
    println!(
        "{name:<48} median {:>12.3} ms   [{:.3} .. {:.3}]",
        median_ns as f64 / 1e6,
        min_ns as f64 / 1e6,
        max_ns as f64 / 1e6
    );
}

/// Run-and-report in one call.
pub fn bench<F: FnMut()>(name: &str, runs: usize, f: F) {
    let (m, lo, hi) = measure(runs, f);
    report(name, m, lo, hi);
}
