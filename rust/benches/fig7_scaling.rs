//! Bench: regenerate Fig. 7 — speedup & simulated-time error vs core count
//! × quantum, for the synthetic bare-metal benchmark and blackscholes.
//!
//! Scale via env: FIG7_OPS (default 2048), FIG7_MAX_CORES (default 32 —
//! pass 120 for the paper's full sweep), FIG7_HOST_CORES (default 64).

#[path = "bench_util.rs"]
mod bench_util;

use parti_sim::harness::figures::{fig7, render_rows, FigureOpts};

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let opts = FigureOpts {
        ops_per_core: env_usize("FIG7_OPS", 2048),
        max_cores: env_usize("FIG7_MAX_CORES", 32),
        host_cores: env_usize("FIG7_HOST_CORES", 64),
        ..Default::default()
    };
    let t = std::time::Instant::now();
    let rows = fig7(&opts).expect("fig7");
    println!("== Fig. 7 (paper: speedup up to 42.7x @120 cores; terr <3% synthetic, <=6% blackscholes) ==\n");
    println!("{}", render_rows(&rows));
    // Headline numbers in the paper's terms:
    let best = rows
        .iter()
        .max_by(|a, b| a.1.speedup.partial_cmp(&b.1.speedup).unwrap())
        .unwrap();
    println!(
        "max speedup: {:.2}x ({} @ {} cores, q={}ns)",
        best.1.speedup, best.0, best.1.cores, best.1.quantum_ns
    );
    println!("bench wall time: {:.1}s", t.elapsed().as_secs_f64());
}
