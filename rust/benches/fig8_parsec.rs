//! Bench: regenerate Fig. 8 — speedup & simulated-time error for the
//! PARSEC subset + STREAM on a 32-core target, per quantum.
//!
//! Scale via env: FIG8_OPS (default 2048), FIG8_CORES (default 32),
//! FIG8_HOST_CORES (default 64).

#[path = "bench_util.rs"]
mod bench_util;

use parti_sim::harness::figures::{fig8, render_rows, FigureOpts};

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let opts = FigureOpts {
        ops_per_core: env_usize("FIG8_OPS", 2048),
        max_cores: env_usize("FIG8_CORES", 32),
        host_cores: env_usize("FIG8_HOST_CORES", 64),
        ..Default::default()
    };
    let t = std::time::Instant::now();
    let rows = fig8(&opts).expect("fig8");
    println!("== Fig. 8 (paper @32 cores: swaptions 12.6x best, dedup 3.6x worst, avg 10.7x; terr <15% for q<=12ns) ==\n");
    println!("{}", render_rows(&rows));

    // Per-app best speedup + the paper's ordering observation.
    let mut by_app: std::collections::BTreeMap<String, f64> = Default::default();
    for (app, r) in &rows {
        let e = by_app.entry(app.clone()).or_insert(0.0);
        *e = e.max(r.speedup);
    }
    println!("best speedup per app (ordering should put low-sharing apps on top):");
    let mut v: Vec<_> = by_app.into_iter().collect();
    v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (app, s) in &v {
        println!("  {app:<14} {s:>6.2}x");
    }
    let avg: f64 = v.iter().map(|(_, s)| s).sum::<f64>() / v.len() as f64;
    println!("average best speedup: {avg:.2}x (paper: 10.7x on a real 64-core host)");
    println!("bench wall time: {:.1}s", t.elapsed().as_secs_f64());
}
