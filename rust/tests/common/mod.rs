//! Shared bit-identity harness for the determinism suites
//! (docs/DETERMINISM.md). Every suite that gates "threaded == virtual"
//! used to carry its own copy of the assert; this module is the single
//! superset definition, so a newly added deterministic counter lands in
//! every suite at once.
//!
//! Compiled per test binary (`mod common;`), so helpers a given suite
//! does not use are expected dead code.
#![allow(dead_code)]

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::run_with_workload;
use parti_sim::pdes::RunResult;
use parti_sim::workload::Workload;

/// The standard adversarial thread matrix: undersubscribed, matched and
/// oversubscribed host threads, each with and without window stealing.
pub const FULL_MATRIX: &[(usize, bool)] = &[
    (1, false),
    (1, true),
    (2, false),
    (2, true),
    (8, false),
    (8, true),
];

/// Bit-identity: everything deterministic must match exactly —
/// `sim_ticks`, event counts, every deterministic PDES counter
/// (including the border-staging and traffic counters) and every
/// per-component statistic, in order. Host-side counters (`steals`,
/// `stolen_events`, `inbox_reordered`, `inbox_merge_ns`, the `prof_*`
/// wall-time buckets, wall-clock) are excluded by design — they describe
/// the host execution, not the simulation.
pub fn assert_bit_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.sim_ticks, b.sim_ticks, "{what}: sim_ticks");
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.pdes.barriers, b.pdes.barriers, "{what}: barriers");
    assert_eq!(
        a.pdes.quanta_skipped, b.pdes.quanta_skipped,
        "{what}: quanta_skipped"
    );
    assert_eq!(
        a.pdes.inbox_staged, b.pdes.inbox_staged,
        "{what}: inbox_staged"
    );
    assert_eq!(a.pdes.xbar_staged, b.pdes.xbar_staged, "{what}: xbar_staged");
    assert_eq!(
        a.pdes.xbar_deferred_grants, b.pdes.xbar_deferred_grants,
        "{what}: xbar_deferred_grants"
    );
    assert_identical_modulo_schedule(a, b, what);
}

/// The weaker identity used when the *window schedule itself* is the
/// independent variable (e.g. `fixed` vs `horizon` quantum policies):
/// simulated results and all schedule-independent deterministic counters
/// must match, while `barriers` / `quanta_skipped` / the staging counts
/// are allowed to differ (that difference is the point of the policy).
pub fn assert_identical_modulo_schedule(
    a: &RunResult,
    b: &RunResult,
    what: &str,
) {
    assert_eq!(a.sim_ticks, b.sim_ticks, "{what}: sim_ticks");
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.pdes.cross_events, b.pdes.cross_events, "{what}: cross");
    assert_eq!(a.pdes.postponed, b.pdes.postponed, "{what}: postponed");
    assert_eq!(a.pdes.tpp_sum, b.pdes.tpp_sum, "{what}: tpp_sum");
    assert_eq!(
        a.pdes.traffic_offered, b.pdes.traffic_offered,
        "{what}: traffic_offered"
    );
    assert_eq!(
        a.pdes.traffic_accepted, b.pdes.traffic_accepted,
        "{what}: traffic_accepted"
    );
    assert_eq!(
        a.pdes.traffic_retries, b.pdes.traffic_retries,
        "{what}: traffic_retries"
    );
    assert_eq!(
        a.pdes.traffic_phases, b.pdes.traffic_phases,
        "{what}: traffic_phases"
    );
    assert_eq!(a.pdes.issued, b.pdes.issued, "{what}: issued");
    assert_eq!(a.pdes.squashed, b.pdes.squashed, "{what}: squashed");
    assert_eq!(
        a.pdes.rob_full_stalls, b.pdes.rob_full_stalls,
        "{what}: rob_full_stalls"
    );
    assert_eq!(
        a.pdes.iq_full_stalls, b.pdes.iq_full_stalls,
        "{what}: iq_full_stalls"
    );
    assert_eq!(
        a.pdes.rob_occupancy_sum, b.pdes.rob_occupancy_sum,
        "{what}: rob_occupancy_sum"
    );
    assert_eq!(
        a.stats.entries.len(),
        b.stats.entries.len(),
        "{what}: stat cardinality"
    );
    for ((an, av), (bn, bv)) in a.stats.entries.iter().zip(&b.stats.entries) {
        assert_eq!(an, bn, "{what}: stat name order");
        assert_eq!(av, bv, "{what}: per-component stat {an}");
    }
}

/// Canonical form of a sweep journal (index-sorted records re-emitted
/// without the `host_*` wall-clock fields), panicking on any damaged
/// line — the strict read the sweep gates build on (docs/SWEEP.md).
pub fn canonical_journal(path: &std::path::Path) -> Vec<String> {
    parti_sim::harness::sweep::canonical_journal(path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

/// Gate: two journals hold bit-identical canonical records. Everything
/// deterministic must match line for line; only `host_*` fields (which
/// the canonical form strips) may differ between the underlying files.
pub fn assert_journals_equivalent(
    a: &std::path::Path,
    b: &std::path::Path,
    what: &str,
) {
    let (ca, cb) = (canonical_journal(a), canonical_journal(b));
    assert_eq!(
        ca.len(),
        cb.len(),
        "{what}: record counts differ ({} vs {})",
        ca.len(),
        cb.len()
    );
    for (i, (la, lb)) in ca.iter().zip(&cb).enumerate() {
        assert_eq!(la, lb, "{what}: canonical record {i} differs");
    }
}

/// The standard matrix gate: for each `(threads, steal)` point, run
/// `vcfg` on the threaded kernel against the pre-computed deterministic
/// `reference` (normally a virtual-kernel run of the same `vcfg` and
/// workload) and require full bit-identity. `what_prefix` labels
/// failures (the point's knobs are appended).
pub fn assert_threaded_matches(
    reference: &RunResult,
    vcfg: &RunConfig,
    w: &Workload,
    matrix: &[(usize, bool)],
    what_prefix: &str,
) {
    for &(threads, steal) in matrix {
        let mut cfg = vcfg.clone();
        cfg.mode = Mode::Parallel;
        cfg.steal = steal;
        cfg.threads = threads;
        let r = run_with_workload(&cfg, w).unwrap();
        let what = format!("{what_prefix}/steal={steal}/threads={threads}");
        assert_bit_identical(reference, &r, &what);
    }
}
