//! Coherence correctness: data written by one core must be visible to
//! others through the CHI-lite protocol, across barriers, in all kernels.
//!
//! These tests construct hand-written traces with `expected` load values,
//! so any stale data served by the hierarchy shows up as a
//! `value_mismatches` stat.

use std::sync::Arc;

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::run_with_workload;
use parti_sim::sim::time::NS;
use parti_sim::workload::trace::NO_EXPECT;
use parti_sim::workload::{CoreTrace, Workload};

const SHARED: u64 = 0x8000_0000;

fn trace(ops: Vec<(u64, bool, u64, u64)>) -> CoreTrace {
    // (addr, is_store, value, expected)
    CoreTrace {
        addr: ops.iter().map(|o| o.0).collect(),
        is_store: ops.iter().map(|o| o.1).collect(),
        gap: vec![2; ops.len()],
        value: ops.iter().map(|o| o.2).collect(),
        expected: ops.iter().map(|o| o.3).collect(),
    }
}

fn cfg(cores: usize, mode: Mode) -> RunConfig {
    let mut c = RunConfig { mode, quantum: 8 * NS, ..Default::default() };
    c.system.cores = cores;
    c
}

fn run(workload: Workload, mode: Mode) -> parti_sim::pdes::RunResult {
    let c = cfg(workload.n_cores(), mode);
    run_with_workload(&c, &workload).unwrap()
}

fn assert_no_mismatch(r: &parti_sim::pdes::RunResult, what: &str) {
    assert_eq!(
        r.stats.sum_suffix(".value_mismatches"),
        0.0,
        "{what}: wrong data returned by the coherent hierarchy"
    );
}

/// Producer stores N lines before the barrier; consumer loads them after.
fn producer_consumer_workload(n_lines: u64) -> Workload {
    let mut prod = Vec::new();
    for i in 0..n_lines {
        prod.push((SHARED + i * 64, true, 1000 + i, NO_EXPECT));
    }
    let mut cons = Vec::new();
    // consumer: private warm-up ops so both sides reach the barrier
    for i in 0..n_lines {
        cons.push((0x1000_0000 + i * 64, false, 0, NO_EXPECT));
    }
    // after barrier: loads must observe the producer's values
    let mut prod2 = Vec::new();
    let mut cons2 = Vec::new();
    for i in 0..n_lines {
        prod2.push((0x2000_0000 + i * 64, false, 0, NO_EXPECT));
        cons2.push((SHARED + i * 64, false, 0, 1000 + i));
    }
    prod.extend(prod2);
    cons.extend(cons2);
    Workload {
        cores: vec![Arc::new(trace(prod)), Arc::new(trace(cons))],
        barrier_every: n_lines as usize,
        name: "producer-consumer".into(),
        phase_ops: 0,
    }
}

#[test]
fn producer_consumer_serial() {
    let r = run(producer_consumer_workload(32), Mode::Serial);
    assert_no_mismatch(&r, "serial");
    assert_eq!(r.stats.sum_suffix(".committed_ops") as u64, 4 * 32);
}

#[test]
fn producer_consumer_virtual_pdes() {
    let r = run(producer_consumer_workload(32), Mode::Virtual);
    assert_no_mismatch(&r, "virtual");
}

#[test]
fn producer_consumer_threaded_pdes() {
    let r = run(producer_consumer_workload(32), Mode::Parallel);
    assert_no_mismatch(&r, "parallel");
}

/// Read-own-write: a core must observe its own stores (same line, repeated).
#[test]
fn read_own_write() {
    let line = SHARED;
    let mut ops = Vec::new();
    for v in 0..64u64 {
        ops.push((line, true, v, NO_EXPECT));
        ops.push((line, false, 0, v));
    }
    let w = Workload {
        cores: vec![Arc::new(trace(ops))],
        barrier_every: 0,
        name: "row".into(),
        phase_ops: 0,
    };
    let r = run(w, Mode::Serial);
    assert_no_mismatch(&r, "read-own-write");
}

/// Migratory sharing: the same line is written by core0, read+written by
/// core1, read by core0 — with barriers between the phases. Exercises
/// SnpUnique / ownership migration.
#[test]
fn migratory_ownership() {
    let line = SHARED;
    let pad = |v: &mut Vec<(u64, bool, u64, u64)>, base: u64| {
        for i in 0..8 {
            v.push((base + i * 64, false, 0, NO_EXPECT));
        }
    };
    // phase length 9 ops (8 pad + 1 line op), barrier_every = 9
    let mut c0 = Vec::new();
    let mut c1 = Vec::new();
    // phase 1: c0 writes 7 ; c1 pads
    pad(&mut c0, 0x1000_0000);
    c0.push((line, true, 7, NO_EXPECT));
    pad(&mut c1, 0x1100_0000);
    c1.push((0x1100_1000, false, 0, NO_EXPECT));
    // phase 2: c1 reads 7 then... (read must be its own phase)
    pad(&mut c0, 0x1200_0000);
    c0.push((0x1200_1000, false, 0, NO_EXPECT));
    pad(&mut c1, 0x1300_0000);
    c1.push((line, false, 0, 7));
    // phase 3: c1 writes 9
    pad(&mut c0, 0x1400_0000);
    c0.push((0x1400_1000, false, 0, NO_EXPECT));
    pad(&mut c1, 0x1500_0000);
    c1.push((line, true, 9, NO_EXPECT));
    // phase 4: c0 reads 9 (ownership migrated back via snoop)
    pad(&mut c0, 0x1600_0000);
    c0.push((line, false, 0, 9));
    pad(&mut c1, 0x1700_0000);
    c1.push((0x1700_1000, false, 0, NO_EXPECT));

    let w = Workload {
        cores: vec![Arc::new(trace(c0)), Arc::new(trace(c1))],
        barrier_every: 9,
        name: "migratory".into(),
        phase_ops: 0,
    };
    for mode in [Mode::Serial, Mode::Virtual, Mode::Parallel] {
        let r = run(w.clone(), mode);
        assert_no_mismatch(&r, &format!("{mode:?}"));
    }
}

/// Heavy shared-line contention: all cores hammer a small set of shared
/// lines with stores and loads. No expected values (racy), but the run must
/// terminate (no protocol deadlock) and commit everything.
#[test]
fn contention_torture_completes() {
    let n_cores = 4;
    let mut cores = Vec::new();
    for c in 0..n_cores as u64 {
        let mut ops = Vec::new();
        for i in 0..256u64 {
            let line = SHARED + (i % 8) * 64;
            let store = (i + c) % 3 == 0;
            ops.push((line, store, c * 10_000 + i, NO_EXPECT));
        }
        cores.push(Arc::new(trace(ops)));
    }
    let w = Workload { cores, barrier_every: 0, name: "torture".into(), phase_ops: 0 };
    for mode in [Mode::Serial, Mode::Virtual, Mode::Parallel] {
        let r = run(w.clone(), mode);
        assert_eq!(
            r.stats.sum_suffix(".committed_ops") as u64,
            n_cores as u64 * 256,
            "{mode:?}: contention must not deadlock"
        );
        assert_no_mismatch(&r, &format!("{mode:?}"));
        // snoops must actually have happened
        let snoops = r.stats.get("hnf.snoops_sent").unwrap_or(0.0);
        assert!(snoops > 0.0, "{mode:?}: contention must trigger snoops");
    }
}

/// Same-line load after store from the SAME core with no barrier — store
/// buffer forwarding through L1 write-through-update.
#[test]
fn same_core_store_load_ordering() {
    let mut ops = Vec::new();
    for i in 0..32u64 {
        let line = SHARED + i * 64;
        ops.push((line, true, 0xAB00 + i, NO_EXPECT));
        ops.push((line, false, 0, 0xAB00 + i));
    }
    let w = Workload {
        cores: vec![Arc::new(trace(ops.clone())), Arc::new(trace(vec![
            (0x1000_0000, false, 0, NO_EXPECT);
            4
        ]))],
        barrier_every: 0,
        name: "st-ld".into(),
        phase_ops: 0,
    };
    for mode in [Mode::Serial, Mode::Virtual] {
        let r = run(w.clone(), mode);
        assert_no_mismatch(&r, &format!("{mode:?}"));
    }
}

/// Capacity evictions: working set far beyond L2 forces write-backs; data
/// must survive the round trip through L3/DRAM.
#[test]
fn writeback_roundtrip_preserves_data() {
    // 8 MiB working set >> 2 MiB L2: write everything, barrier, read back.
    let lines = 4096u64; // 256 KiB... enough to overflow L1D (64 KiB)
    let mut ops = Vec::new();
    for i in 0..lines {
        ops.push((SHARED + i * 64, true, 0xC0DE_0000 + i, NO_EXPECT));
    }
    for i in 0..lines {
        ops.push((SHARED + i * 64, false, 0, 0xC0DE_0000 + i));
    }
    let w = Workload {
        cores: vec![Arc::new(trace(ops))],
        barrier_every: 0,
        name: "wb".into(),
        phase_ops: 0,
    };
    let r = run(w, Mode::Serial);
    assert_no_mismatch(&r, "writeback roundtrip");
}
