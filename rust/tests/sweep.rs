//! Acceptance gates for the sweep orchestrator (docs/SWEEP.md):
//!
//! * the journal's canonical form is bit-identical whatever the outer
//!   pool width (`--outer 1` ≡ `--outer 8`);
//! * `--shard i/N` decomposes exactly — the sorted union of the shard
//!   journals equals the unsharded journal for N ∈ {2, 3};
//! * a killed sweep plus `--resume` equals the uninterrupted run;
//! * a damaged journal line (truncation, trailing garbage) is reported
//!   with its line number and its point re-run, never silently skipped;
//! * wall-clock data lives only in `host_*` fields, which the canonical
//!   form strips.

mod common;

use std::path::{Path, PathBuf};

use parti_sim::config::Mode;
use parti_sim::harness::sweep::{
    canonical_journal_union, expand, run_sweep, SweepOptions, SweepOutcome,
};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::spec::sweep::SweepSpec;
use parti_sim::stats::SweepRecord;

use common::{assert_journals_equivalent, canonical_journal};

/// A unique temp path per test (tests run concurrently in one binary).
fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("parti_sweep_{}_{name}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

fn cleanup(paths: &[&PathBuf]) {
    for p in paths {
        let _ = std::fs::remove_file(p);
    }
}

/// 8 cheap points: 2 workloads × 2 kernels × 2 quanta on the 2-core
/// platform, threaded kernel at 2 inner threads.
fn small_spec() -> SweepSpec {
    SweepSpec {
        name: "gate".to_string(),
        workloads: vec!["app:synthetic".into(), "traffic:hotspot".into()],
        kernels: vec![Mode::Virtual, Mode::Parallel],
        quantum_ns: vec![8, 16],
        inner_threads: 2,
        ops_per_core: 64,
        ..SweepSpec::default()
    }
}

fn run(
    spec: &SweepSpec,
    journal: &Path,
    tweak: impl FnOnce(&mut SweepOptions),
) -> SweepOutcome {
    let mut opts = SweepOptions {
        journal: journal.to_path_buf(),
        ..SweepOptions::default()
    };
    tweak(&mut opts);
    run_sweep(spec, &opts).expect("sweep runs")
}

#[test]
fn outer_pool_size_does_not_change_the_journal() {
    let spec = small_spec();
    let (j1, j8) = (tmp("outer1"), tmp("outer8"));
    let a = run(&spec, &j1, |o| o.outer = Some(1));
    let b = run(&spec, &j8, |o| o.outer = Some(8));
    assert_eq!(a.ran, 8);
    assert_eq!(b.ran, 8);
    assert_eq!(b.outer, 8);
    assert_journals_equivalent(&j1, &j8, "outer 1 vs outer 8");

    // Wall-clock segregation: raw records carry `host_*`, canonical
    // records do not — so the gate above really did compare bytes.
    let raw = std::fs::read_to_string(&j1).unwrap();
    assert!(raw.contains("\"host_ns\""), "raw journal keeps wall-clock");
    for line in canonical_journal(&j1) {
        assert!(!line.contains("host_"), "canonical strips host_*: {line}");
    }
    cleanup(&[&j1, &j8]);
}

#[test]
fn shard_union_matches_unsharded() {
    let spec = small_spec();
    let whole = tmp("unsharded");
    run(&spec, &whole, |_| {});
    for n in [2usize, 3] {
        let shards: Vec<PathBuf> =
            (0..n).map(|i| tmp(&format!("shard{i}of{n}"))).collect();
        let mut total = 0;
        for (i, j) in shards.iter().enumerate() {
            let out = run(&spec, j, |o| o.shard = Some((i, n)));
            total += out.ran;
        }
        assert_eq!(total, 8, "shards cover every point exactly once");
        let union = canonical_journal_union(&shards).unwrap();
        assert_eq!(
            union,
            canonical_journal(&whole),
            "union of {n} shard journals == unsharded journal"
        );
        cleanup(&shards.iter().collect::<Vec<_>>());
    }
    cleanup(&[&whole]);
}

#[test]
fn resume_after_partial_run_matches_uninterrupted() {
    let spec = small_spec();
    let (full, part) = (tmp("full"), tmp("partial"));
    run(&spec, &full, |_| {});
    // "Kill after k": the in-order committer means stopping after 3
    // points leaves the same clean prefix a real kill would.
    let a = run(&spec, &part, |o| o.max_points = Some(3));
    assert_eq!((a.ran, a.skipped), (3, 0));
    let b = run(&spec, &part, |o| o.resume = true);
    assert_eq!((b.ran, b.skipped), (5, 3), "resume skips the prefix");
    assert_journals_equivalent(&part, &full, "kill+resume vs uninterrupted");
    cleanup(&[&full, &part]);
}

#[test]
fn truncated_journal_line_is_reported_and_rerun() {
    let spec = small_spec();
    let (full, hurt) = (tmp("full2"), tmp("truncated"));
    run(&spec, &full, |_| {});
    run(&spec, &hurt, |_| {});
    // Chop line 4 mid-record, as a kill mid-write would.
    let text = std::fs::read_to_string(&hurt).unwrap();
    let mut lines: Vec<String> = text.lines().map(String::from).collect();
    lines[3].truncate(lines[3].len() / 2);
    std::fs::write(&hurt, lines.join("\n") + "\n").unwrap();

    let out = run(&spec, &hurt, |o| o.resume = true);
    assert_eq!(out.repaired.len(), 1, "one damaged line");
    assert_eq!(out.repaired[0].line, 4, "reported with its line number");
    assert_eq!((out.ran, out.skipped), (1, 7), "damaged point re-run");
    assert_journals_equivalent(&hurt, &full, "repaired vs uninterrupted");
    cleanup(&[&full, &hurt]);
}

#[test]
fn trailing_garbage_is_reported_and_ignored() {
    let spec = small_spec();
    let j = tmp("garbage");
    run(&spec, &j, |_| {});
    let mut text = std::fs::read_to_string(&j).unwrap();
    text.push_str("not json at all\n");
    std::fs::write(&j, text).unwrap();

    let out = run(&spec, &j, |o| o.resume = true);
    assert_eq!(out.repaired.len(), 1);
    assert_eq!(out.repaired[0].line, 9, "the appended garbage line");
    assert_eq!((out.ran, out.skipped), (0, 8), "all real points intact");
    for line in std::fs::read_to_string(&j).unwrap().lines() {
        SweepRecord::from_json_line(line).expect("journal repaired clean");
    }
    cleanup(&[&j]);
}

#[test]
fn journaled_records_match_direct_runs() {
    let spec = small_spec();
    let j = tmp("direct");
    run(&spec, &j, |_| {});
    let canon = canonical_journal(&j);
    for (k, point) in expand(&spec).unwrap().iter().enumerate().take(3) {
        let w = make_workload(&point.cfg).unwrap();
        let r = run_with_workload(&point.cfg, &w).unwrap();
        let rec = SweepRecord::from_run(point.index as u64, &point.id, &r);
        assert_eq!(
            canon[k],
            rec.to_canonical_line(),
            "orchestrated point {k} == direct run"
        );
    }
    cleanup(&[&j]);
}

#[test]
fn existing_journal_without_resume_is_refused() {
    let spec = small_spec();
    let j = tmp("norerun");
    run(&spec, &j, |o| o.max_points = Some(1));
    let opts = SweepOptions { journal: j.clone(), ..SweepOptions::default() };
    let err = run_sweep(&spec, &opts).unwrap_err().to_string();
    assert!(err.contains("--resume"), "error points at --resume: {err}");
    cleanup(&[&j]);
}
