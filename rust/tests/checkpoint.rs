//! Acceptance gates for border-quiescent checkpoint/restore
//! (docs/CHECKPOINT.md):
//!
//! * checkpoint + restore ≡ the uninterrupted run, bit-identically, over
//!   platforms × kernels × threads × stealing × IO traffic;
//! * checkpoint bytes are invariant to the producing kernel (virtual vs
//!   threaded, any thread count, stealing on or off);
//! * `--checkpoint-at` mid-window snaps forward to the next border
//!   (never backward) per the documented snap rule;
//! * a version bump, a tampered pinned config (spec-hash mismatch) and a
//!   truncated file are all rejected with typed, offset-carrying errors;
//! * `ckpt diff` names the first diverging component of a perturbed
//!   snapshot;
//! * `sweep run --from-checkpoint` journals bit-identically to cold runs
//!   of the same points.

mod common;

use std::path::PathBuf;

use parti_sim::ckpt::{self, snap_to_border, CkptError};
use parti_sim::config::{Mode, RunConfig};
use parti_sim::cpu::CpuModel;
use parti_sim::harness::sweep::{expand, run_sweep, SweepOptions};
use parti_sim::harness::{restore_and_run, run_once, run_to_checkpoint};
use parti_sim::sched::QuantumPolicy;
use parti_sim::sim::time::NS;
use parti_sim::spec::{platforms, sweep};

use common::{assert_bit_identical, assert_journals_equivalent};

/// A unique temp path per test (tests run concurrently in one binary).
fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("parti_ckpt_{}_{name}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

fn cleanup(paths: &[&PathBuf]) {
    for p in paths {
        let _ = std::fs::remove_file(p);
    }
}

/// A windowed-kernel config on a named platform preset.
fn cfg_for(platform: &str, io_milli: u64, ops: usize) -> RunConfig {
    let spec = platforms::resolve(platform).unwrap();
    let mut cfg = RunConfig::for_spec(&spec);
    cfg.mode = Mode::Virtual;
    cfg.app = "synthetic".into();
    cfg.ops_per_core = ops;
    cfg.quantum = 16 * NS;
    cfg.system.io_milli = io_milli;
    cfg
}

/// Checkpoint `cfg` halfway through its own run; returns the snapshot
/// file, the frozen border and the uninterrupted reference result.
fn checkpoint_halfway(
    cfg: &RunConfig,
    name: &str,
) -> (PathBuf, u64, parti_sim::pdes::RunResult) {
    let reference = run_once(cfg).unwrap();
    let at = reference.sim_ticks / 2;
    assert!(at > 0, "{name}: degenerate run");
    let file = tmp(name);
    let (_, border) = run_to_checkpoint(cfg, at, &file).unwrap();
    let border = border
        .unwrap_or_else(|| panic!("{name}: run ended before tick {at}"));
    assert!(border >= at, "{name}: snap rule never goes backward");
    (file, border, reference)
}

#[test]
fn restore_matches_uninterrupted_across_matrix() {
    for (platform, ops) in
        [("fig4-2", 192usize), ("ring-16", 96), ("mesh-64", 16)]
    {
        for io_milli in [0u64, 5] {
            let base = cfg_for(platform, io_milli, ops);
            let name = format!("matrix_{platform}_{io_milli}");
            let (file, _, reference) = checkpoint_halfway(&base, &name);
            let bytes = std::fs::read(&file).unwrap();
            let snap = ckpt::read_snapshot(&bytes).unwrap();

            // Virtual restore.
            let (outcome, _) = restore_and_run(&snap, &base, None).unwrap();
            assert_bit_identical(
                &reference,
                &outcome.into_finished(),
                &format!("{platform}/io={io_milli}/virtual"),
            );

            // Threaded restores across the adversarial matrix — the
            // producing kernel was virtual, so this also crosses kernels.
            for &(threads, steal) in common::FULL_MATRIX {
                let mut free = base.clone();
                free.mode = Mode::Parallel;
                free.threads = threads;
                free.steal = steal;
                let (outcome, _) =
                    restore_and_run(&snap, &free, None).unwrap();
                assert_bit_identical(
                    &reference,
                    &outcome.into_finished(),
                    &format!(
                        "{platform}/io={io_milli}/threads={threads}\
                         /steal={steal}"
                    ),
                );
            }
            cleanup(&[&file]);
        }
    }
}

#[test]
fn checkpoint_bytes_are_producer_kernel_invariant() {
    let base = cfg_for("fig4-2", 5, 256);
    let reference = run_once(&base).unwrap();
    let at = reference.sim_ticks / 2;
    let fv = tmp("producer_virtual");
    let (_, bv) = run_to_checkpoint(&base, at, &fv).unwrap();
    let bv = bv.expect("checkpoint taken");
    let golden = std::fs::read(&fv).unwrap();

    for &(threads, steal) in common::FULL_MATRIX {
        let mut cfg = base.clone();
        cfg.mode = Mode::Parallel;
        cfg.threads = threads;
        cfg.steal = steal;
        let f = tmp(&format!("producer_t{threads}_s{steal}"));
        let (_, b) = run_to_checkpoint(&cfg, at, &f).unwrap();
        assert_eq!(b, Some(bv), "threads={threads}/steal={steal}: border");
        assert_eq!(
            std::fs::read(&f).unwrap(),
            golden,
            "threads={threads}/steal={steal}: checkpoint bytes must not \
             fingerprint the producing kernel"
        );
        cleanup(&[&f]);
    }
    cleanup(&[&fv]);
}

#[test]
fn checkpoint_at_snaps_forward_to_next_border() {
    let base = cfg_for("fig4-2", 0, 128);
    let q = base.quantum;

    // Mid-window request: forward to the *next* border, never backward.
    let f1 = tmp("snap_mid");
    let (_, border) = run_to_checkpoint(&base, q + 1, &f1).unwrap();
    assert_eq!(border, Some(snap_to_border(q + 1, q)));
    assert_eq!(border, Some(2 * q));

    // An exact border is its own snap target.
    let f2 = tmp("snap_exact");
    let (_, border) = run_to_checkpoint(&base, q, &f2).unwrap();
    assert_eq!(border, Some(q));

    // Tick 0 still executes one window (a snapshot of a never-run
    // machine would just be elaboration).
    let f3 = tmp("snap_zero");
    let (_, border) = run_to_checkpoint(&base, 0, &f3).unwrap();
    assert_eq!(border, Some(q));
    cleanup(&[&f1, &f2, &f3]);
}

#[test]
fn adaptive_policy_checkpoint_roundtrips() {
    for policy in
        [QuantumPolicy::Horizon, QuantumPolicy::Hybrid { max_leap: 4 }]
    {
        let mut base = cfg_for("fig4-2", 0, 128);
        base.quantum_policy = policy;
        let name = format!("policy_{policy:?}");
        let (file, _, reference) = checkpoint_halfway(&base, &name);
        let bytes = std::fs::read(&file).unwrap();
        let snap = ckpt::read_snapshot(&bytes).unwrap();
        let (outcome, _) = restore_and_run(&snap, &base, None).unwrap();
        assert_bit_identical(&reference, &outcome.into_finished(), &name);
        cleanup(&[&file]);
    }
}

#[test]
fn restored_run_can_checkpoint_again() {
    // Re-freezing a restored run at T2 must produce the same bytes as
    // freezing a cold run at T2 — checkpoints compose.
    let base = cfg_for("fig4-2", 0, 192);
    let reference = run_once(&base).unwrap();
    let (t1, t2) = (reference.sim_ticks / 3, 2 * reference.sim_ticks / 3);

    let cold2 = tmp("rechkpt_cold");
    let (_, b2) = run_to_checkpoint(&base, t2, &cold2).unwrap();
    assert!(b2.is_some());

    let first = tmp("rechkpt_first");
    let (_, b1) = run_to_checkpoint(&base, t1, &first).unwrap();
    assert!(b1.is_some());
    let snap = ckpt::read_snapshot(&std::fs::read(&first).unwrap()).unwrap();
    let (outcome, eff) = restore_and_run(&snap, &base, Some(t2)).unwrap();
    match outcome {
        parti_sim::pdes::RunOutcome::Checkpointed {
            machine, border, ..
        } => {
            assert_eq!(Some(border), b2, "same snap target");
            let again =
                ckpt::snapshot_machine(&machine, &eff, border).unwrap();
            assert_eq!(
                again,
                std::fs::read(&cold2).unwrap(),
                "re-checkpoint == cold checkpoint at the same border"
            );
        }
        parti_sim::pdes::RunOutcome::Finished(_) => {
            panic!("resumed run finished before its re-checkpoint tick")
        }
    }
    cleanup(&[&cold2, &first]);
}

#[test]
fn run_finishing_first_writes_no_checkpoint() {
    let base = cfg_for("fig4-2", 0, 32);
    let file = tmp("never_reached");
    let (result, border) =
        run_to_checkpoint(&base, u64::MAX / 2, &file).unwrap();
    assert!(border.is_none(), "run terminates before the requested tick");
    assert!(!file.exists(), "no partial file left behind");
    let reference = run_once(&base).unwrap();
    assert_bit_identical(&reference, &result, "finished-first run");
}

#[test]
fn serial_and_atomic_checkpoints_are_rejected() {
    let mut serial = cfg_for("fig4-2", 0, 32);
    serial.mode = Mode::Serial;
    let err = match run_to_checkpoint(&serial, 1, &tmp("reject_serial")) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("serial checkpoint must be rejected"),
    };
    assert!(err.contains("windowed"), "points at the kernel: {err}");

    let mut atomic = cfg_for("fig4-2", 0, 32);
    atomic.cpu_model = CpuModel::Atomic;
    atomic.mode = Mode::Serial;
    let err = match run_to_checkpoint(&atomic, 1, &tmp("reject_atomic")) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("atomic checkpoint must be rejected"),
    };
    assert!(err.contains("timing"), "points at the CPU model: {err}");
}

#[test]
fn version_hash_and_truncation_are_rejected() {
    let base = cfg_for("fig4-2", 0, 64);
    let (file, _, _) = checkpoint_halfway(&base, "reject_matrix");
    let golden = std::fs::read(&file).unwrap();
    assert!(ckpt::read_snapshot(&golden).is_ok());

    // Version bump: byte 8 is the little-endian low byte of `version`.
    let mut bumped = golden.clone();
    bumped[8] += 1;
    match ckpt::read_snapshot(&bumped) {
        Err(CkptError::Mismatch { what, .. }) => {
            assert!(what.contains("version"), "{what}")
        }
        other => panic!("expected version mismatch, got {other:?}"),
    }

    // Tampered pinned config: the header hash covers spec + config, so
    // flipping one digit of `seed = 42` must trip the spec-hash check.
    let mut tampered = golden.clone();
    let needle = b"seed = ";
    let pos = tampered
        .windows(needle.len())
        .position(|w| w == needle)
        .expect("pinned config embeds the seed");
    tampered[pos + needle.len()] ^= 1;
    match ckpt::read_snapshot(&tampered) {
        Err(CkptError::Mismatch { what, .. }) => {
            assert!(what.contains("spec hash"), "{what}")
        }
        other => panic!("expected spec-hash mismatch, got {other:?}"),
    }

    // Truncation anywhere fails with the absolute byte offset.
    for cut in [golden.len() - 5, golden.len() / 2, 20] {
        match ckpt::read_snapshot(&golden[..cut]) {
            Err(CkptError::Truncated { offset, wanted }) => {
                assert!(offset <= cut, "offset {offset} inside the file");
                assert!(wanted > 0);
            }
            other => panic!("cut at {cut}: expected truncation, got {other:?}"),
        }
    }
    cleanup(&[&file]);
}

#[test]
fn diff_names_first_diverging_component() {
    let base = cfg_for("fig4-2", 0, 64);
    let (file, _, _) = checkpoint_halfway(&base, "diff_perturb");
    let golden = std::fs::read(&file).unwrap();

    assert!(
        ckpt::diff_snapshots(&golden, &golden).unwrap().is_none(),
        "identical files diff clean"
    );

    // Flip the first byte of some component's state record; the report
    // must name that component and the in-record byte offset.
    let snap = ckpt::read_snapshot(&golden).unwrap();
    let victim = snap
        .comps
        .iter()
        .find(|c| !c.state.is_empty())
        .expect("some component carries state");
    let mut bad = golden.clone();
    bad[victim.state_off] ^= 0xff;
    let report = ckpt::diff_snapshots(&golden, &bad)
        .unwrap()
        .expect("perturbed snapshot diverges");
    assert!(
        report.contains(&victim.name),
        "report names `{}`: {report}",
        victim.name
    );
    assert!(
        report.contains("state differs at byte 0 of"),
        "report carries the byte offset: {report}"
    );
    cleanup(&[&file]);
}

// ---------------------------------------------------------------------
// O3 pipeline checkpoints (the `flags` header word, docs/CHECKPOINT.md
// §3): an O3 snapshot freezes the pipeline mid-flight (non-empty
// ROB/LSQ, outstanding sequencer requests) and restores bit-identically
// on both windowed kernels; Minor snapshots keep flags = 0 and the
// original "V1" layout; a reader without O3 support rejects an O3
// snapshot at the flags word instead of misparsing it.
// ---------------------------------------------------------------------

use parti_sim::ckpt::format::FLAG_O3;
use parti_sim::ckpt::{Header, StateReader};
use parti_sim::spec::CpuSpec;

/// A cramped O3 traffic config: narrow structures and few MSHRs keep
/// ops in flight essentially all the time, so a mid-run border freezes
/// a genuinely busy pipeline.
fn o3_ckpt_cfg() -> RunConfig {
    let mut cfg = cfg_for("ring-16", 5, 96);
    cfg.traffic = Some("uniform-random".to_string());
    cfg.system.cpu_spec = CpuSpec {
        width: 2,
        rob_size: 12,
        iq_size: 6,
        lsq_size: 4,
        fetch_buf: 4,
        mshrs: 3,
    };
    cfg
}

#[test]
fn o3_checkpoint_freezes_mid_flight_and_restores_bit_identically() {
    let base = o3_ckpt_cfg();
    assert_eq!(base.cpu_model, CpuModel::O3, "presets default to o3");
    let reference = run_once(&base).unwrap();

    // Find a border where the frozen pipeline is demonstrably
    // mid-flight: ops past issue but not yet committed live in the ROB
    // (and their requests in the LSQ / sequencer outstanding set).
    let mut chosen = None;
    for (num, den) in [(1u64, 4u64), (1, 2), (3, 4)] {
        let at = reference.sim_ticks * num / den;
        let file = tmp(&format!("o3_midflight_{num}_{den}"));
        let (partial, border) = run_to_checkpoint(&base, at, &file).unwrap();
        assert!(border.is_some(), "run ended before tick {at}");
        let issued = partial.stats.sum_suffix(".issued");
        let committed = partial.stats.sum_suffix(".committed_ops");
        if issued > committed {
            chosen = Some((file, partial));
            break;
        }
        cleanup(&[&file]);
    }
    let (file, partial) = chosen.expect(
        "a cramped O3 pipeline must be mid-flight at some border \
         (issued > committed nowhere?)",
    );
    assert!(
        partial.stats.sum_suffix(".issued")
            > partial.stats.sum_suffix(".committed_ops"),
        "frozen state carries in-flight (issued, uncommitted) ops"
    );

    let bytes = std::fs::read(&file).unwrap();
    let snap = ckpt::read_snapshot(&bytes).unwrap();
    assert_eq!(snap.header.flags, FLAG_O3, "o3 snapshots set the flag");

    // Bit-identical completion on the virtual kernel and across the
    // threaded matrix.
    let (outcome, _) = restore_and_run(&snap, &base, None).unwrap();
    assert_bit_identical(
        &reference,
        &outcome.into_finished(),
        "o3-midflight/virtual",
    );
    for &(threads, steal) in common::FULL_MATRIX {
        let mut free = base.clone();
        free.mode = Mode::Parallel;
        free.threads = threads;
        free.steal = steal;
        let (outcome, _) = restore_and_run(&snap, &free, None).unwrap();
        assert_bit_identical(
            &reference,
            &outcome.into_finished(),
            &format!("o3-midflight/threads={threads}/steal={steal}"),
        );
    }
    cleanup(&[&file]);
}

#[test]
fn minor_checkpoints_keep_flags_zero_and_still_load() {
    // The pre-O3 layout: a Minor run writes flags = 0 and none of the
    // O3 extensions, and the current reader loads it exactly as before.
    let mut base = cfg_for("fig4-2", 5, 128);
    base.cpu_model = CpuModel::Minor;
    let (file, _, reference) = checkpoint_halfway(&base, "minor_v1");
    let bytes = std::fs::read(&file).unwrap();
    let snap = ckpt::read_snapshot(&bytes).unwrap();
    assert_eq!(snap.header.flags, 0, "minor snapshots stay V1 (flags 0)");
    let (outcome, _) = restore_and_run(&snap, &base, None).unwrap();
    assert_bit_identical(&reference, &outcome.into_finished(), "minor_v1");
    cleanup(&[&file]);
}

#[test]
fn o3_snapshot_is_rejected_by_a_reader_without_o3_support() {
    let base = o3_ckpt_cfg();
    let (file, _, _) = checkpoint_halfway(&base, "o3_flags_reject");
    let golden = std::fs::read(&file).unwrap();
    assert!(ckpt::read_snapshot(&golden).is_ok());

    // A flags=0-era reader (modelled by the narrow supported mask) must
    // refuse at the flags word — byte 12 — naming the missing feature.
    let mut r = StateReader::new(&golden);
    match Header::read_with_supported(&mut r, 0) {
        Err(CkptError::Corrupt { offset, what }) => {
            assert_eq!(offset, 12, "flags word offset");
            assert!(what.contains("O3"), "hint names the feature: {what}");
            assert!(what.contains("CHECKPOINT.md"), "{what}");
        }
        other => panic!("expected flags rejection, got {other:?}"),
    }

    // And the current reader symmetrically refuses bits *it* does not
    // know (a future format extension), at the same offset.
    let mut future = golden.clone();
    future[15] |= 0x80; // high byte of the little-endian flags u32
    match ckpt::read_snapshot(&future) {
        Err(CkptError::Corrupt { offset, .. }) => assert_eq!(offset, 12),
        other => panic!("expected unknown-flag rejection, got {other:?}"),
    }
    cleanup(&[&file]);
}

#[test]
fn sweep_forks_from_checkpoint_identically() {
    let spec = sweep::resolve("quick").unwrap();
    let points = expand(&spec).unwrap();
    let donor = points
        .iter()
        .find(|p| p.cfg.mode != Mode::Serial)
        .expect("quick has a windowed point");
    let reference = run_once(&donor.cfg).unwrap();
    let ck = tmp("sweep_donor");
    let (_, border) =
        run_to_checkpoint(&donor.cfg, reference.sim_ticks / 2, &ck).unwrap();
    assert!(border.is_some(), "donor checkpoint taken");

    let (cold_j, fork_j) = (tmp("sweep_cold"), tmp("sweep_forked"));
    let cold = run_sweep(
        &spec,
        &SweepOptions { journal: cold_j.clone(), ..Default::default() },
    )
    .unwrap();
    let forked = run_sweep(
        &spec,
        &SweepOptions {
            journal: fork_j.clone(),
            from_checkpoint: Some(ck.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(cold.ran, forked.ran, "same point coverage");
    assert_journals_equivalent(&cold_j, &fork_j, "forked sweep vs cold");
    cleanup(&[&ck, &cold_j, &fork_j]);
}
