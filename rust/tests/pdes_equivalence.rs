//! PDES vs serial equivalence: the paper's core accuracy claims.
//!
//! * Functional equivalence: load checksums identical (no data corruption
//!   from parallelisation) for race-free workloads.
//! * Bounded timing deviation: simulated-time error stays in a sane band
//!   for quanta at/below the L3-hit latency (paper: <15% for q <= 12 ns).
//! * Virtual mode is deterministic (bit-identical across repetitions).
//! * The threaded and virtual kernels implement the same postponement
//!   semantics.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::{HostModel, RunResult};
use parti_sim::sim::time::NS;
use parti_sim::stats::compare;
use parti_sim::workload::Workload;

fn cfg(app: &str, cores: usize, ops: usize, mode: Mode, q_ns: u64) -> RunConfig {
    let mut c = RunConfig {
        app: app.into(),
        ops_per_core: ops,
        mode,
        quantum: q_ns * NS,
        ..Default::default()
    };
    c.system.cores = cores;
    c
}

fn run(app: &str, cores: usize, ops: usize, mode: Mode, q: u64, w: &Workload) -> RunResult {
    run_with_workload(&cfg(app, cores, ops, mode, q), w).unwrap()
}

#[test]
fn virtual_matches_serial_functionally() {
    // Race-free apps only (share_milli == 0): for apps with shared stores,
    // racing loads have no single correct value and checksums legitimately
    // differ between interleavings (the paper makes the same argument
    // about non-determinism in §6).
    for app in ["synthetic", "stream"] {
        let base = cfg(app, 4, 1024, Mode::Serial, 16);
        let w = make_workload(&base).unwrap();
        let serial = run_with_workload(&base, &w).unwrap();
        for q in [2u64, 8, 16] {
            let v = run(app, 4, 1024, Mode::Virtual, q, &w);
            let acc = compare(&serial, &v);
            assert_eq!(
                serial.stats.sum_suffix(".committed_ops"),
                v.stats.sum_suffix(".committed_ops"),
                "{app} q={q}"
            );
            assert!(
                acc.checksum_match,
                "{app} q={q}: load checksums must match (race-free app)"
            );
            assert_eq!(
                v.stats.sum_suffix(".value_mismatches"),
                0.0,
                "{app} q={q}"
            );
        }
    }
}

#[test]
fn sim_time_error_bounded_at_paper_quanta() {
    // Paper (§5.2): quantum <= 12 ns keeps total-simulated-time error
    // below 15%. Allow 2x slack for our smaller traces.
    for app in ["synthetic", "blackscholes"] {
        let base = cfg(app, 4, 2048, Mode::Serial, 16);
        let w = make_workload(&base).unwrap();
        let serial = run_with_workload(&base, &w).unwrap();
        for q in [2u64, 8] {
            let v = run(app, 4, 2048, Mode::Virtual, q, &w);
            let err = compare(&serial, &v).sim_time_error.abs();
            assert!(
                err < 0.30,
                "{app} q={q}: sim-time error {:.1}% out of band",
                err * 100.0
            );
        }
    }
}

#[test]
fn smaller_quantum_not_much_worse() {
    // Error should broadly shrink (or at least not explode) as the quantum
    // shrinks — the paper's central accuracy knob.
    let base = cfg("blackscholes", 4, 2048, Mode::Serial, 16);
    let w = make_workload(&base).unwrap();
    let serial = run_with_workload(&base, &w).unwrap();
    let err_small = compare(&serial, &run("blackscholes", 4, 2048, Mode::Virtual, 2, &w))
        .sim_time_error
        .abs();
    let err_big = compare(&serial, &run("blackscholes", 4, 2048, Mode::Virtual, 16, &w))
        .sim_time_error
        .abs();
    assert!(
        err_small <= err_big + 0.05,
        "q=2 error {err_small} should not exceed q=16 error {err_big} by >5pp"
    );
}

#[test]
fn virtual_is_deterministic() {
    let base = cfg("canneal", 4, 512, Mode::Virtual, 8);
    let w = make_workload(&base).unwrap();
    let a = run_with_workload(&base, &w).unwrap();
    let b = run_with_workload(&base, &w).unwrap();
    assert_eq!(a.sim_ticks, b.sim_ticks, "virtual PDES must be deterministic");
    assert_eq!(a.events, b.events);
    assert_eq!(a.pdes.postponed, b.pdes.postponed);
    assert_eq!(a.pdes.tpp_sum, b.pdes.tpp_sum);
}

#[test]
fn threaded_matches_serial_functionally() {
    let base = cfg("synthetic", 4, 512, Mode::Serial, 16);
    let w = make_workload(&base).unwrap();
    let serial = run_with_workload(&base, &w).unwrap();
    let p = run("synthetic", 4, 512, Mode::Parallel, 8, &w);
    let acc = compare(&serial, &p);
    assert!(acc.checksum_match, "threaded kernel must preserve data");
    assert_eq!(p.stats.sum_suffix(".value_mismatches"), 0.0);
}

#[test]
fn threaded_and_virtual_agree_on_functional_results() {
    // Both implement the same postpone-to-border rule; private-only
    // workloads should produce identical checksums (timing may differ
    // slightly due to host-time xbar races — none here).
    let base = cfg("synthetic", 4, 512, Mode::Virtual, 8);
    let w = make_workload(&base).unwrap();
    let v = run_with_workload(&base, &w).unwrap();
    let p = run("synthetic", 4, 512, Mode::Parallel, 8, &w);
    assert_eq!(
        v.stats.sum_suffix(".load_checksum"),
        p.stats.sum_suffix(".load_checksum")
    );
}

#[test]
fn postponements_happen_and_are_bounded_by_quantum() {
    let base = cfg("canneal", 4, 1024, Mode::Virtual, 8);
    let w = make_workload(&base).unwrap();
    let r = run_with_workload(&base, &w).unwrap();
    assert!(r.pdes.cross_events > 0, "sharing app must cross domains");
    assert!(r.pdes.postponed > 0, "cross events inside windows get postponed");
    let mean = r.pdes.tpp_mean();
    assert!(
        mean > 0.0 && mean <= (8 * NS) as f64,
        "t_pp mean {mean} must lie in (0, quantum]"
    );
}

#[test]
fn sharing_apps_have_more_cross_traffic_than_private_apps() {
    let mk = |app: &str| {
        let base = cfg(app, 4, 1024, Mode::Virtual, 8);
        let w = make_workload(&base).unwrap();
        run_with_workload(&base, &w).unwrap()
    };
    let canneal = mk("canneal");
    let synthetic = mk("synthetic");
    assert!(
        canneal.pdes.cross_events > synthetic.pdes.cross_events,
        "canneal (high sharing) must generate more cross-domain events"
    );
}

#[test]
fn host_model_speedup_scales_with_sharing() {
    // The paper's headline shape: low-sharing apps speed up more.
    let speedup = |app: &str| {
        let sbase = cfg(app, 8, 1024, Mode::Serial, 16);
        let w = make_workload(&sbase).unwrap();
        let serial = run_with_workload(&sbase, &w).unwrap();
        let v = run(app, 8, 1024, Mode::Virtual, 8, &w);
        let mut host = HostModel::default();
        host.calibrate_cost(&serial);
        host.speedup(serial.events, v.work.as_ref().unwrap())
    };
    let s_synth = speedup("synthetic");
    let s_canneal = speedup("canneal");
    assert!(
        s_synth > s_canneal,
        "synthetic ({s_synth:.2}x) must outscale canneal ({s_canneal:.2}x)"
    );
}

#[test]
fn speedup_grows_with_core_count() {
    let speedup_at = |cores: usize| {
        let sbase = cfg("synthetic", cores, 512, Mode::Serial, 16);
        let w = make_workload(&sbase).unwrap();
        let serial = run_with_workload(&sbase, &w).unwrap();
        let v = run("synthetic", cores, 512, Mode::Virtual, 8, &w);
        let mut host = HostModel::default();
        host.calibrate_cost(&serial);
        host.speedup(serial.events, v.work.as_ref().unwrap())
    };
    let s2 = speedup_at(2);
    let s8 = speedup_at(8);
    assert!(
        s8 > s2,
        "speedup must grow with cores: 2-core {s2:.2}x vs 8-core {s8:.2}x"
    );
}
