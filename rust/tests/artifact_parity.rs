//! Cross-language parity: the AOT artifact (JAX/Pallas via PJRT) and the
//! Rust procedural generator must produce bit-identical traces, and the
//! payload artifacts must match independent Rust math.
//!
//! Tests that need `artifacts/` skip gracefully when it is missing (run
//! `make artifacts` first); the golden-vector tests always run.

use parti_sim::runtime::{artifact_trace, Runtime, PAYLOAD_B};
use parti_sim::workload::{addrgen, squares32, AddrGenParams};
use parti_sim::workload::gen::SQUARES_KEY;

/// Golden vectors pinned from the Python reference implementation
/// (python/compile/kernels/ref.py) — keep in sync with
/// python/tests/test_kernel.py::test_known_vector_stability.
#[test]
fn squares32_matches_python_goldens() {
    let cases: [(u64, u32); 5] = [
        (0, 0x8352d815),
        (1, 0x4d645c71),
        (2, 0x5f664b34),
        (12345678901234, 0x837df4da),
        (1 << 63, 0x0bb1ab45),
    ];
    for (ctr, want) in cases {
        assert_eq!(
            squares32(ctr, SQUARES_KEY),
            want,
            "squares32({ctr:#x}) diverged from the Python reference"
        );
    }
}

#[test]
fn addrgen_matches_python_goldens() {
    let p = AddrGenParams {
        seed: 42,
        core_id: 3,
        offset: 0,
        private_base: 0x1000_0000,
        private_size: 65536,
        shared_base: 0x8000_0000,
        shared_size: 8 * 1024 * 1024,
        stride: 1,
        share_milli: 100,
        random_milli: 200,
        line_bytes: 64,
        compute_base: 2,
        compute_spread: 8,
        store_milli: 300,
    };
    let ops = addrgen(&p, 8);
    let want_addr: [u64; 8] = [
        0x1000_0000,
        0x1000_0000,
        0x1000_8800,
        0x8058_c480,
        0x1000_0000,
        0x1000_0000,
        0x1000_0000,
        0x1000_0000,
    ];
    let want_gap: [u32; 8] = [2, 4, 5, 5, 4, 6, 7, 9];
    for i in 0..8 {
        assert_eq!(ops[i].addr, want_addr[i], "addr[{i}]");
        assert_eq!(ops[i].gap, want_gap[i], "gap[{i}]");
        assert!(!ops[i].is_store, "store[{i}] (python golden: all loads)");
    }
}

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("PJRT client"))
}

#[test]
fn artifact_trace_is_bit_identical_to_rust_port() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("workload").expect("workload artifact");
    for (core, share, stride, store) in
        [(0u64, 0u64, 1u64, 300u64), (5, 400, 7, 500), (119, 1000, 3, 0)]
    {
        let p = AddrGenParams {
            core_id: core,
            share_milli: share,
            stride,
            store_milli: store,
            ..Default::default()
        };
        let a = artifact_trace(&exe, &p, 2048).expect("artifact exec");
        let b = addrgen(&p, 2048);
        for i in 0..2048 {
            assert_eq!(a.addr[i], b[i].addr, "core {core} addr[{i}]");
            assert_eq!(a.is_store[i], b[i].is_store, "core {core} store[{i}]");
            assert_eq!(a.gap[i], b[i].gap, "core {core} gap[{i}]");
        }
    }
}

#[test]
fn stream_artifact_matches_rust_triad() {
    let Some(rt) = runtime_or_skip() else { return };
    let b: Vec<f32> = (0..PAYLOAD_B).map(|i| i as f32 * 0.5 - 100.0).collect();
    let c: Vec<f32> = (0..PAYLOAD_B).map(|i| (i % 97) as f32).collect();
    let scalar = 3.0f32;
    let a = parti_sim::runtime::stream_payload(&rt, &b, &c, scalar).unwrap();
    for i in 0..PAYLOAD_B {
        let want = b[i] + scalar * c[i];
        assert!(
            (a[i] - want).abs() <= 1e-4 * want.abs().max(1.0),
            "triad[{i}]: {} vs {}",
            a[i],
            want
        );
    }
}

#[test]
fn blackscholes_artifact_satisfies_parity_and_bounds() {
    let Some(rt) = runtime_or_skip() else { return };
    let n = PAYLOAD_B;
    // Deterministic in-range inputs (independent of Python's streams).
    let u = |i: usize, k: u64| {
        squares32(i as u64 * 5 + k, SQUARES_KEY) as f32 / u32::MAX as f32
    };
    let spot: Vec<f32> = (0..n).map(|i| 5.0 + 95.0 * u(i, 0)).collect();
    let strike: Vec<f32> = (0..n).map(|i| 5.0 + 95.0 * u(i, 1)).collect();
    let rate: Vec<f32> = (0..n).map(|i| 0.01 + 0.09 * u(i, 2)).collect();
    let vol: Vec<f32> = (0..n).map(|i| 0.05 + 0.55 * u(i, 3)).collect();
    let time: Vec<f32> = (0..n).map(|i| 0.1 + 2.9 * u(i, 4)).collect();
    let (call, put) = parti_sim::runtime::blackscholes_payload(
        &rt, &spot, &strike, &rate, &vol, &time,
    )
    .unwrap();
    for i in 0..n {
        // Model-independent put-call parity: C - P = S - K e^{-rT}.
        let lhs = call[i] - put[i];
        let rhs = spot[i] - strike[i] * (-rate[i] * time[i]).exp();
        assert!(
            (lhs - rhs).abs() < 5e-3 * rhs.abs().max(1.0),
            "parity[{i}]: {lhs} vs {rhs}"
        );
        assert!(call[i] >= -1e-3 && put[i] >= -1e-3, "prices nonneg [{i}]");
        // C <= S and P <= K e^{-rT} (no-arbitrage bounds).
        assert!(call[i] <= spot[i] + 1e-3);
        assert!(put[i] <= strike[i] + 1e-3);
    }
}
