//! Scheduler-layer determinism gates on a small MPSoC config.
//!
//! The `sched/` refactor must be invisible to simulation results:
//!
//! * Swapping the event-queue implementation (heap ↔ bucket) must produce
//!   bit-identical runs — same `sim_ticks`, same event count, same
//!   per-component statistics — on both deterministic kernels.
//! * The deterministic kernels themselves stay bit-reproducible across
//!   repetitions with the lock-free mailboxes in place.
//! * The threaded kernel (whose intra-window inbox interleaving is
//!   host-timing dependent by design, like parti-gem5 — paper §6) must
//!   stay functionally identical to the serial reference: same committed
//!   ops and same load checksums.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::RunResult;
use parti_sim::sched::QueueKind;
use parti_sim::sim::time::NS;
use parti_sim::stats::compare;

fn cfg(mode: Mode, queue: QueueKind) -> RunConfig {
    let mut c = RunConfig {
        app: "canneal".into(), // sharing app: exercises cross-domain paths
        ops_per_core: 768,
        mode,
        quantum: 8 * NS,
        queue,
        ..Default::default()
    };
    c.system.cores = 4;
    c
}

fn run(mode: Mode, queue: QueueKind) -> RunResult {
    let c = cfg(mode, queue);
    let w = make_workload(&c).unwrap();
    run_with_workload(&c, &w).unwrap()
}

fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.sim_ticks, b.sim_ticks, "{what}: sim_ticks");
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.pdes.cross_events, b.pdes.cross_events, "{what}: cross");
    assert_eq!(a.pdes.postponed, b.pdes.postponed, "{what}: postponed");
    assert_eq!(a.pdes.tpp_sum, b.pdes.tpp_sum, "{what}: tpp_sum");
    assert_eq!(
        a.stats.entries.len(),
        b.stats.entries.len(),
        "{what}: stat cardinality"
    );
    for ((an, av), (bn, bv)) in a.stats.entries.iter().zip(&b.stats.entries) {
        assert_eq!(an, bn, "{what}: stat name order");
        assert_eq!(av, bv, "{what}: per-component stat {an}");
    }
}

#[test]
fn serial_is_identical_across_queue_kinds() {
    let heap = run(Mode::Serial, QueueKind::Heap);
    let bucket = run(Mode::Serial, QueueKind::Bucket);
    assert!(heap.events > 0);
    assert_identical(&heap, &bucket, "serial heap-vs-bucket");
}

#[test]
fn virtual_is_identical_across_queue_kinds() {
    let heap = run(Mode::Virtual, QueueKind::Heap);
    let bucket = run(Mode::Virtual, QueueKind::Bucket);
    assert!(heap.pdes.cross_events > 0, "must exercise the mailboxes");
    assert_identical(&heap, &bucket, "virtual heap-vs-bucket");
}

#[test]
fn deterministic_kernels_reproduce_bit_identically() {
    for mode in [Mode::Serial, Mode::Virtual] {
        let a = run(mode, QueueKind::Bucket);
        let b = run(mode, QueueKind::Bucket);
        assert_identical(&a, &b, "repeat run");
    }
}

#[test]
fn threaded_kernel_matches_serial_functionally() {
    // Race-free app for the functional comparison (see pdes_equivalence.rs
    // for why sharing apps legitimately diverge on racing loads).
    let mut serial_cfg = cfg(Mode::Serial, QueueKind::Bucket);
    serial_cfg.app = "synthetic".into();
    let w = make_workload(&serial_cfg).unwrap();
    let serial = run_with_workload(&serial_cfg, &w).unwrap();
    for queue in [QueueKind::Heap, QueueKind::Bucket] {
        let mut par_cfg = cfg(Mode::Parallel, queue);
        par_cfg.app = "synthetic".into();
        let par = run_with_workload(&par_cfg, &w).unwrap();
        let acc = compare(&serial, &par);
        assert!(acc.checksum_match, "{queue:?}: checksums must match");
        assert_eq!(
            serial.stats.sum_suffix(".committed_ops"),
            par.stats.sum_suffix(".committed_ops"),
            "{queue:?}: all ops must commit"
        );
        assert_eq!(
            par.stats.sum_suffix(".value_mismatches"),
            0.0,
            "{queue:?}"
        );
    }
}
