//! Adversarial gates for the staged O3 pipeline model (docs/O3.md).
//!
//! Three legs, mirroring the traffic suite:
//!
//! * **Determinism** — the pipeline is event-driven state machinery, so
//!   on every preset topology a threaded `--cpu o3` run must stay
//!   bit-identical to the virtual reference across `--threads {1,2,8}`
//!   × `--steal` × `--io-milli {0,5}` × two traffic patterns, including
//!   the new pipeline counters (issued, squashed, rob/iq stalls,
//!   time-integrated ROB occupancy).
//! * **Shape** — the stages must actually buy what they advertise:
//!   multiple outstanding misses make O3 finish a miss-heavy workload
//!   in less simulated time than Minor at width >= 2, and a
//!   deliberately tiny ROB/IQ reports structural stalls.
//! * **Degeneracy** — with every structure sized 1 the pipeline
//!   collapses to an in-order, one-outstanding machine, and the run
//!   must be tick-for-tick equivalent to Minor (same sim time, same
//!   memory-system behaviour, same checksums).

use std::collections::BTreeMap;

use parti_sim::config::{Mode, RunConfig};
use parti_sim::cpu::CpuModel;
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::RunResult;
use parti_sim::sched::QuantumPolicy;
use parti_sim::sim::time::NS;
use parti_sim::spec::platforms;
use parti_sim::spec::CpuSpec;
use parti_sim::stats::Summary;

mod common;
use common::{assert_threaded_matches, FULL_MATRIX};

/// The two traffic patterns of the determinism matrix: the hotspot
/// (shared-line contention, store-heavy) and uniform-random (miss-heavy,
/// scattered) scenarios stress the LSQ forwarding path and the multiple-
/// outstanding-miss path respectively.
const PATTERNS: &[&str] = &["hotspot", "uniform-random"];

/// An O3 traffic run on `preset`, with a deliberately cramped pipeline
/// (narrow, small ROB/IQ/LSQ, few MSHRs) so every structural-stall and
/// backpressure path fires inside a test-suite-fast run.
fn o3_cfg(preset: &str, scenario: &str, io_milli: u64) -> RunConfig {
    let spec = platforms::preset(preset).unwrap();
    let mut cfg = RunConfig::for_spec(&spec);
    cfg.cpu_model = CpuModel::O3;
    cfg.system.cpu_spec = CpuSpec {
        width: 2,
        rob_size: 12,
        iq_size: 6,
        lsq_size: 4,
        fetch_buf: 4,
        mshrs: 3,
    };
    cfg.traffic = Some(scenario.to_string());
    cfg.ops_per_core = match preset {
        "fig4-2" => 640,
        "ring-16" => 256,
        _ => 160,
    };
    cfg.mode = Mode::Virtual;
    cfg.quantum = 8 * NS;
    cfg.quantum_policy = QuantumPolicy::Hybrid { max_leap: 4 };
    cfg.system.io_milli = io_milli;
    cfg
}

/// The tentpole matrix for one preset: both patterns × `--io-milli
/// {0,5}` × the full `--threads`/`--steal` grid, gated on full
/// bit-identity (including the five pipeline counters, via the shared
/// superset assert) against the virtual reference.
fn preset_matrix(preset: &str) {
    for pattern in PATTERNS {
        for io_milli in [0u64, 5] {
            let vcfg = o3_cfg(preset, pattern, io_milli);
            let w = make_workload(&vcfg).unwrap();
            let reference = run_with_workload(&vcfg, &w).unwrap();
            let what = format!("{preset}/{pattern}/io={io_milli}");
            assert!(reference.events > 0, "{what}: empty run");
            assert_eq!(
                reference.pdes.traffic_accepted,
                reference.pdes.traffic_offered,
                "{what}: a completed run accepts every offered op"
            );
            assert_eq!(
                reference.pdes.traffic_retries as f64,
                reference.stats.sum_suffix(".lsq_stalls"),
                "{what}: retries must mirror the per-core LSQ stalls"
            );
            assert!(
                reference.pdes.issued >= reference.pdes.traffic_offered,
                "{what}: every data op (plus ifetches) passes issue"
            );
            assert_eq!(
                reference.pdes.rob_occupancy_sum as f64,
                reference.stats.sum_suffix(".rob_occupancy_sum"),
                "{what}: global ROB occupancy mirrors the per-core stat"
            );
            assert_eq!(
                reference.stats.sum_suffix(".value_mismatches"),
                0.0,
                "{what}: forwarding/replies must return the right data"
            );
            assert_threaded_matches(&reference, &vcfg, &w, FULL_MATRIX, &what);
        }
    }
}

#[test]
fn fig4_2_o3_threaded_matches_virtual() {
    preset_matrix("fig4-2");
}

#[test]
fn ring_16_o3_threaded_matches_virtual() {
    preset_matrix("ring-16");
}

#[test]
fn mesh_64_o3_threaded_matches_virtual() {
    preset_matrix("mesh-64");
}

/// Pipeline shape: at width >= 2 with multiple outstanding misses, O3
/// must finish the miss-heavy uniform-random pattern in less simulated
/// time than the one-outstanding in-order Minor on the same trace.
#[test]
fn o3_overlaps_misses_and_beats_minor_sim_time() {
    let mut o3 = o3_cfg("ring-16", "uniform-random", 0);
    // Default (uncramped) geometry: this gate is about overlap, not
    // structural stalls.
    o3.system.cpu_spec = CpuSpec::default();
    let w = make_workload(&o3).unwrap();
    let mut minor = o3.clone();
    minor.cpu_model = CpuModel::Minor;
    let r_o3 = run_with_workload(&o3, &w).unwrap();
    let r_minor = run_with_workload(&minor, &w).unwrap();
    assert!(
        r_o3.sim_ticks < r_minor.sim_ticks,
        "O3 ({}) must finish miss-heavy traffic before Minor ({})",
        r_o3.sim_ticks,
        r_minor.sim_ticks
    );
    assert_eq!(
        r_o3.stats.sum_suffix(".committed_ops"),
        r_minor.stats.sum_suffix(".committed_ops"),
        "both models must retire the whole trace"
    );
}

/// Structural-stall shape: a deliberately tiny ROB must report dispatch
/// stalls, and a tiny IQ must report issue-queue stalls; both global
/// counters mirror the per-core stats and survive into the summary JSON.
#[test]
fn tiny_structures_report_their_stalls() {
    let mut cfg = o3_cfg("fig4-2", "hotspot", 0);
    cfg.system.cpu_spec = CpuSpec {
        width: 4,
        rob_size: 2,
        iq_size: 2,
        lsq_size: 2,
        fetch_buf: 8,
        mshrs: 8,
    };
    let w = make_workload(&cfg).unwrap();
    let r = run_with_workload(&cfg, &w).unwrap();
    assert!(
        r.pdes.rob_full_stalls > 0,
        "a 2-entry ROB under width 4 must stall dispatch"
    );
    assert_eq!(
        r.pdes.rob_full_stalls as f64,
        r.stats.sum_suffix(".rob_full_stalls"),
        "global counter mirrors per-core stat"
    );
    assert_eq!(
        r.pdes.iq_full_stalls as f64,
        r.stats.sum_suffix(".iq_full_stalls"),
        "global counter mirrors per-core stat"
    );
    assert!(
        r.pdes.rob_occupancy_sum > 0,
        "a run that dispatched anything accrues ROB occupancy"
    );
    let s = Summary::from_result(&r);
    assert_eq!(s.rob_full_stalls, r.pdes.rob_full_stalls);
    let json = s.to_json();
    for key in [
        "issued",
        "squashed",
        "rob_full_stalls",
        "iq_full_stalls",
        "rob_occupancy_sum",
    ] {
        assert!(json.contains(key), "summary JSON must carry {key}");
    }
}

/// The curated stat subset of the degeneracy gate: every per-component
/// stat except the pipeline-implementation counters whose *counting
/// semantics* differ between the two models even when their timing is
/// identical (Minor counts LSQ retries per blocked attempt, O3 per
/// blocked dispatch; issued/squashed/occupancy/stl do not exist on
/// Minor at all — O3 simply emits a superset of stat names).
fn degeneracy_stats(r: &RunResult) -> BTreeMap<String, u64> {
    const EXCLUDE: &[&str] = &[
        ".lsq_stalls",
        ".issued",
        ".squashed",
        ".rob_full_stalls",
        ".iq_full_stalls",
        ".rob_occupancy_sum",
        ".stl_forwards",
    ];
    r.stats
        .entries
        .iter()
        .filter(|(n, _)| !EXCLUDE.iter().any(|s| n.ends_with(s)))
        .map(|(n, v)| (n.clone(), v.to_bits()))
        .collect()
}

/// Degeneracy: with width/rob/iq/lsq/fetch-buf all 1, the O3 pipeline
/// is an in-order machine with one instruction in flight — the Minor
/// model by construction. The two must agree tick for tick: same sim
/// time, same per-core finish ticks and checksums, and an identical
/// memory system (every cache/sequencer/fabric stat).
#[test]
fn degenerate_o3_is_tick_for_tick_minor() {
    for (preset, pattern, io_milli) in [
        ("fig4-2", "hotspot", 5u64),
        ("ring-16", "uniform-random", 0u64),
    ] {
        let mut o3 = o3_cfg(preset, pattern, io_milli);
        o3.mode = Mode::Serial;
        o3.system.cpu_spec = CpuSpec {
            width: 1,
            rob_size: 1,
            iq_size: 1,
            lsq_size: 1,
            fetch_buf: 1,
            // Keep the sequencer cap at its default: the degeneracy is
            // in the pipeline, not the memory system.
            ..CpuSpec::default()
        };
        let w = make_workload(&o3).unwrap();
        let mut minor = o3.clone();
        minor.cpu_model = CpuModel::Minor;
        let r_o3 = run_with_workload(&o3, &w).unwrap();
        let r_minor = run_with_workload(&minor, &w).unwrap();
        let what = format!("{preset}/{pattern}/io={io_milli}");
        assert_eq!(
            r_o3.sim_ticks, r_minor.sim_ticks,
            "{what}: degenerate O3 must match Minor tick for tick"
        );
        assert_eq!(
            degeneracy_stats(&r_o3),
            degeneracy_stats(&r_minor),
            "{what}: memory system and per-core results must be identical"
        );
        assert_eq!(
            r_o3.pdes.traffic_accepted, r_minor.pdes.traffic_accepted,
            "{what}: same accepted load"
        );
        // The pipeline never finds room to ever hold two ops, so the
        // out-of-order-only counters stay silent.
        assert_eq!(r_o3.pdes.squashed, 0, "{what}: nothing to squash");
        assert_eq!(
            r_o3.stats.sum_suffix(".stl_forwards"),
            0.0,
            "{what}: a 1-entry ROB cannot forward store-to-load"
        );
    }
}

/// Repeatability of the pipeline state machine: re-elaborating and
/// re-running the same cramped O3 scenario is bit-identical.
#[test]
fn o3_rerun_is_bit_identical() {
    let cfg = o3_cfg("fig4-2", "hotspot", 5);
    let w1 = make_workload(&cfg).unwrap();
    let a = run_with_workload(&cfg, &w1).unwrap();
    let w2 = make_workload(&cfg).unwrap();
    let b = run_with_workload(&cfg, &w2).unwrap();
    common::assert_bit_identical(&a, &b, "re-elaborated o3 run");
}
