//! End-to-end integration: every app × every CPU model runs to completion
//! on the serial kernel with sane statistics.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::cpu::CpuModel;
use parti_sim::harness::figures::atomic_vs_timing;
use parti_sim::harness::{make_workload, run_once, run_with_workload};
use parti_sim::sim::time::NS;
use parti_sim::stats::Summary;
use parti_sim::workload::APPS;

fn cfg(app: &str, cores: usize, ops: usize) -> RunConfig {
    let mut c = RunConfig {
        app: app.into(),
        ops_per_core: ops,
        ..Default::default()
    };
    c.system.cores = cores;
    c
}

#[test]
fn every_app_completes_serially() {
    for app in APPS {
        let c = cfg(app.traits_.name, 2, 256);
        let r = run_once(&c).expect(app.traits_.name);
        let committed = r.stats.sum_suffix(".committed_ops");
        assert_eq!(
            committed as u64,
            2 * 256,
            "{}: all trace ops must commit",
            app.traits_.name
        );
        assert!(r.sim_ticks > 0);
        assert!(r.events > 0);
    }
}

#[test]
fn timing_mips_in_paper_ballpark() {
    // §1: timing mode achieves 0.01-0.1 MIPS on a workstation. Allow a
    // generous envelope (different host, small run).
    let r = run_once(&cfg("synthetic", 2, 2048)).unwrap();
    let mips = r.mips();
    assert!(mips > 0.001 && mips < 10.0, "MIPS {mips} out of envelope");
}

#[test]
fn barrier_apps_hit_barriers() {
    let c = cfg("blackscholes", 4, 2048); // harness: ops < barrier_every -> 0
    let r = run_once(&c).unwrap();
    let _ = r;
    // dedup has barrier_every=512 -> 2048 ops hit 3 boundaries per core.
    let c = cfg("dedup", 4, 2048);
    let r = run_once(&c).unwrap();
    let barriers = r.stats.sum_suffix(".barriers");
    assert!(barriers > 0.0, "dedup must synchronise at barriers");
    assert_eq!(
        r.stats.sum_suffix(".committed_ops") as u64,
        4 * 2048,
        "barriers must not deadlock"
    );
}

#[test]
fn io_traffic_goes_through_crossbar() {
    let mut c = cfg("synthetic", 2, 512);
    c.system.io_milli = 20; // one IO access per 50 ops
    let r = run_once(&c).unwrap();
    let io = r.stats.sum_suffix(".io_reqs");
    assert!(io > 0.0, "io_milli must generate crossbar traffic");
    let uart = r.stats.get("uart.reads").unwrap_or(0.0)
        + r.stats.get("uart.writes").unwrap_or(0.0);
    let timer = r.stats.get("timer.reads").unwrap_or(0.0)
        + r.stats.get("timer.writes").unwrap_or(0.0);
    assert!(uart + timer > 0.0, "peripherals must see requests");
}

#[test]
fn atomic_mode_runs_and_is_faster_per_op() {
    let p = atomic_vs_timing(2, 2048).unwrap();
    assert!(p.atomic_mips > 0.0 && p.timing_mips > 0.0);
    assert!(
        p.ratio < 0.8,
        "timing mode must be substantially slower than atomic (got ratio {})",
        p.ratio
    );
}

#[test]
fn kvm_fast_forward_completes_instantly() {
    let mut c = cfg("synthetic", 2, 2048);
    c.cpu_model = CpuModel::Kvm;
    let r = run_once(&c).unwrap();
    assert_eq!(r.stats.sum_suffix(".committed_ops") as u64, 2 * 2048);
    // Fast-forward advances virtually no simulated time.
    assert!(r.sim_ticks < 100 * NS * 2048);
}

#[test]
fn minor_is_slower_than_o3_in_sim_time() {
    let workload = make_workload(&cfg("blackscholes", 2, 1024)).unwrap();
    let mut c_o3 = cfg("blackscholes", 2, 1024);
    c_o3.cpu_model = CpuModel::O3;
    let mut c_minor = c_o3.clone();
    c_minor.cpu_model = CpuModel::Minor;
    let r_o3 = run_with_workload(&c_o3, &workload).unwrap();
    let r_minor = run_with_workload(&c_minor, &workload).unwrap();
    assert!(
        r_minor.sim_ticks > r_o3.sim_ticks,
        "in-order Minor ({}) must take longer than O3 ({})",
        r_minor.sim_ticks,
        r_o3.sim_ticks
    );
}

#[test]
fn summary_serialises() {
    let r = run_once(&cfg("synthetic", 2, 256)).unwrap();
    let s = Summary::from_result(&r);
    let j = s.to_json();
    assert!(j.contains("\"sim_ticks\""));
    assert!(j.contains("\"l1d_miss_rate\""));
}

#[test]
fn serial_runs_are_deterministic() {
    let c = cfg("canneal", 3, 512);
    let w = make_workload(&c).unwrap();
    let a = run_with_workload(&c, &w).unwrap();
    let b = run_with_workload(&c, &w).unwrap();
    assert_eq!(a.sim_ticks, b.sim_ticks);
    assert_eq!(a.events, b.events);
    let ca = a.stats.sum_suffix(".load_checksum");
    let cb = b.stats.sum_suffix(".load_checksum");
    assert_eq!(ca, cb);
}

#[test]
fn no_value_mismatches_in_normal_runs() {
    for app in ["synthetic", "canneal", "stream"] {
        let r = run_once(&cfg(app, 2, 512)).unwrap();
        assert_eq!(
            r.stats.sum_suffix(".value_mismatches"),
            0.0,
            "{app}: coherent memory must never return wrong data"
        );
    }
}

#[test]
fn virtual_mode_rejects_single_domain_configs() {
    // guard: virtual/parallel need >= 2 domains, i.e. >= 1 core + shared.
    let mut c = cfg("synthetic", 1, 128);
    c.mode = Mode::Virtual;
    c.quantum = 8 * NS;
    // 1 core => 2 domains; this must still work.
    let r = run_once(&c).unwrap();
    assert_eq!(r.n_domains, 2);
}
