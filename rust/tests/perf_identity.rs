//! Gates for the raw-speed campaign (ISSUE 6): every hot-path
//! optimisation — cache-line padding of the shared per-domain arrays, the
//! k-way border inbox merge, the mailbox drain-into scratch, the IO-free
//! crossbar border skip, the bucket-queue live bitmap and the tunable
//! calendar geometry — must be invisible to the simulation. The matrix
//! runs {fig4-2, mesh-64} × {heap, bucket} × `--threads {1,8}` with
//! `--profile` enabled and asserts the threaded kernel stays bit-identical
//! to the virtual reference: `sim_ticks`, every deterministic PDES
//! counter, and every per-component statistic.
//!
//! The `--profile` instrumentation itself is also gated: it must record
//! wall-time without perturbing any simulated result, and a non-default
//! `--bucket-width`/`--bucket-slots` geometry must only change host speed,
//! never outcomes.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::sched::{BucketShape, QuantumPolicy, QueueKind};
use parti_sim::sim::time::NS;
use parti_sim::spec::{platforms, SystemSpec};

mod common;
use common::assert_bit_identical;

/// PDES config on `spec` with a sharing workload plus IO traffic, so the
/// matrix exercises the inbox merge, the crossbar arbitration *and* its
/// IO-free skip (at 5 accesses per 1000 ops = 1 per 200, most borders
/// still carry no IO; ops_per_core must exceed 200 so every core issues
/// at least one — same geometry as tests/xbar_arb.rs).
fn matrix_cfg(spec: &SystemSpec, queue: QueueKind) -> RunConfig {
    let mut cfg = RunConfig::for_spec(spec);
    cfg.app = "canneal".into();
    cfg.ops_per_core = if spec.cores <= 2 { 768 } else { 224 };
    cfg.system.io_milli = 5;
    cfg.mode = Mode::Virtual;
    cfg.quantum = 8 * NS;
    cfg.quantum_policy = QuantumPolicy::Fixed;
    cfg.queue = queue;
    cfg
}

#[test]
fn optimised_matrix_is_bit_identical_with_profile_enabled() {
    for name in ["fig4-2", "mesh-64"] {
        let spec = platforms::preset(name).unwrap();
        for queue in [QueueKind::Heap, QueueKind::Bucket] {
            let vcfg = matrix_cfg(&spec, queue);
            let w = make_workload(&vcfg).unwrap();
            let reference = run_with_workload(&vcfg, &w).unwrap();
            assert!(reference.events > 0, "{name}: empty run");
            assert!(
                reference.pdes.inbox_staged > 0,
                "{name}: sharing app must exercise the inbox handoff"
            );
            assert!(
                reference.pdes.xbar_staged > 0,
                "{name}: io_milli must exercise the crossbar arbitration"
            );
            for threads in [1usize, 8] {
                let mut cfg = vcfg.clone();
                cfg.mode = Mode::Parallel;
                cfg.threads = threads;
                cfg.profile = true;
                let r = run_with_workload(&cfg, &w).unwrap();
                let what = format!("{name}/{queue:?}/threads={threads}");
                assert_bit_identical(&reference, &r, &what);
                assert!(
                    r.pdes.profiled(),
                    "{what}: --profile recorded no wall time"
                );
                assert!(
                    r.pdes.prof_window_ns > 0,
                    "{what}: window execution must show up in the profile"
                );
            }
        }
    }
}

#[test]
fn profile_flag_does_not_perturb_the_virtual_kernel() {
    let spec = platforms::preset("fig4-2").unwrap();
    let cfg = matrix_cfg(&spec, QueueKind::Bucket);
    let w = make_workload(&cfg).unwrap();
    let plain = run_with_workload(&cfg, &w).unwrap();
    assert!(!plain.pdes.profiled(), "profile off must record nothing");
    let mut pcfg = cfg.clone();
    pcfg.profile = true;
    let profiled = run_with_workload(&pcfg, &w).unwrap();
    assert_bit_identical(&plain, &profiled, "virtual/profile");
    assert_eq!(
        plain.pdes.inbox_reordered, profiled.pdes.inbox_reordered,
        "same kernel, same workload: even the host-order divergence matches"
    );
    assert!(profiled.pdes.prof_window_ns > 0, "virtual fills the window bucket");
}

#[test]
fn bucket_geometry_changes_speed_never_outcomes() {
    let spec = platforms::preset("fig4-2").unwrap();
    let cfg = matrix_cfg(&spec, QueueKind::Bucket);
    let w = make_workload(&cfg).unwrap();
    let reference = run_with_workload(&cfg, &w).unwrap();
    for (width, nbuckets) in [(256u64, 16usize), (64, 4), (1 << 16, 128)] {
        let mut scfg = cfg.clone();
        scfg.bucket_shape =
            BucketShape { width, nbuckets }.validate().unwrap();
        let r = run_with_workload(&scfg, &w).unwrap();
        assert_bit_identical(
            &reference,
            &r,
            &format!("shape {width}x{nbuckets}"),
        );
    }
}
