//! Determinism gates for the adaptive quantum and window work stealing.
//!
//! The two mechanisms are host-side optimisations and must be invisible to
//! simulation results (DESIGN.md §4.4/§4.5):
//!
//! * Every `--quantum-policy` value produces bit-identical `sim_ticks`,
//!   event counts and per-component statistics on the deterministic
//!   kernel; only the barrier count shrinks. The windows that actually
//!   execute events are identical border-for-border.
//! * `horizon` executes at most as many barriers as `fixed`, and on a
//!   sparse/skewed 16-domain machine strictly fewer, with
//!   `barriers + quanta_skipped` exactly equal to the fixed barrier count.
//! * The threaded kernel stays functionally identical to the serial
//!   reference across policies, steal on/off and thread counts. Its
//!   intra-window Ruby timing is host-dependent by design (paper §6) —
//!   with or without stealing — so the functional gate (checksums +
//!   committed ops) is the strongest one available for it; the
//!   bit-identity gates run on the deterministic kernel, where the
//!   quantum policy is the only knob with any effect.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::{run_virtual, MachineBuilder, RunResult};
use parti_sim::sched::{QuantumPolicy, RunPolicy};
use parti_sim::sim::component::{Component, Ctx};
use parti_sim::sim::event::EventKind;
use parti_sim::sim::ids::DomainId;
use parti_sim::sim::stats::StatSink;
use parti_sim::sim::time::{Tick, NS};
use parti_sim::stats::compare;

mod common;
use common::assert_identical_modulo_schedule as assert_identical;

const POLICIES: [QuantumPolicy; 3] = [
    QuantumPolicy::Fixed,
    QuantumPolicy::Horizon,
    QuantumPolicy::Hybrid { max_leap: 4 },
];

/// The windows that executed at least one event, as (window_end, work).
fn busy_windows(r: &RunResult) -> Vec<(Tick, Vec<u32>)> {
    let w = r.work.as_ref().expect("virtual runs record work");
    w.window_ends
        .iter()
        .zip(&w.per_quantum)
        .filter(|(_, q)| q.iter().any(|&x| x > 0))
        .map(|(&e, q)| (e, q.clone()))
        .collect()
}

fn virtual_run(policy: QuantumPolicy) -> RunResult {
    let mut c = RunConfig {
        app: "canneal".into(), // sharing app: exercises cross-domain paths
        ops_per_core: 768,
        mode: Mode::Virtual,
        quantum: 8 * NS,
        quantum_policy: policy,
        ..Default::default()
    };
    c.system.cores = 4;
    let w = make_workload(&c).unwrap();
    run_with_workload(&c, &w).unwrap()
}

// (`RunPolicy::steal` has no effect in `Mode::Virtual` — the kernel is
// single-threaded — so a virtual steal-on/off matrix would be vacuous.
// Steal coverage lives in the threaded-kernel tests below, where the flag
// actually changes the domain→thread binding.)
#[test]
fn virtual_is_identical_across_quantum_policies() {
    let reference = virtual_run(QuantumPolicy::Fixed);
    assert!(reference.events > 0);
    let ref_busy = busy_windows(&reference);
    assert!(!ref_busy.is_empty());
    for policy in POLICIES {
        let r = virtual_run(policy);
        assert_identical(&reference, &r, &format!("{policy:?}"));
        assert_eq!(
            ref_busy,
            busy_windows(&r),
            "{policy:?}: busy windows must align border-for-border"
        );
        assert!(
            r.pdes.barriers <= reference.pdes.barriers,
            "{policy:?}: adaptive policies must not add barriers"
        );
    }
}

// ---------------------------------------------------------------------
// Sparse/skewed 16-domain machine: each domain pulses on its own long
// period, so most fixed windows are provably dead and `horizon` must
// leap them.
// ---------------------------------------------------------------------

struct Pulse {
    name: String,
    period: Tick,
    remaining: u32,
    fired: u64,
}

impl Component for Pulse {
    fn handle(&mut self, _kind: EventKind, ctx: &mut Ctx) {
        self.fired += 1;
        if self.remaining > 0 {
            self.remaining -= 1;
            ctx.schedule_self(
                self.period,
                EventKind::Generic { code: 0, arg: 0 },
            );
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        ctx.schedule_self(self.period, EventKind::Generic { code: 0, arg: 0 });
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("fired", self.fired);
    }
}

/// 16 domains, quantum 10 ticks, domain `d` pulses every `50 + 25*d`
/// ticks, 30 times: dense enough to overlap, sparse enough that most grid
/// windows are globally empty.
fn sparse_machine(policy: QuantumPolicy) -> RunResult {
    const QUANTUM: Tick = 10;
    let mut b = MachineBuilder::new(16, QUANTUM);
    b.set_policy(RunPolicy { quantum_policy: policy, ..RunPolicy::default() });
    for d in 0..16u32 {
        b.add(
            DomainId(d),
            Box::new(Pulse {
                name: format!("pulse{d}"),
                period: 50 + 25 * d as Tick,
                remaining: 30,
                fired: 0,
            }),
        );
    }
    run_virtual(b.finish(), 1_000_000)
}

#[test]
fn horizon_skips_dead_windows_on_skewed_16_domains() {
    let fixed = sparse_machine(QuantumPolicy::Fixed);
    let horizon = sparse_machine(QuantumPolicy::Horizon);
    let hybrid = sparse_machine(QuantumPolicy::Hybrid { max_leap: 4 });

    assert_identical(&fixed, &horizon, "horizon vs fixed");
    assert_identical(&fixed, &hybrid, "hybrid vs fixed");
    assert_eq!(fixed.events, 16 * 31, "31 pulses per domain");

    // The acceptance gate: horizon executes <= (here: strictly fewer)
    // barriers than fixed on the skewed 16-domain config.
    assert!(
        horizon.pdes.barriers < fixed.pdes.barriers,
        "horizon ({}) must beat fixed ({}) on a sparse machine",
        horizon.pdes.barriers,
        fixed.pdes.barriers
    );
    assert!(horizon.pdes.quanta_skipped > 0);
    assert_eq!(fixed.pdes.quanta_skipped, 0, "fixed never leaps");

    // Every window is either executed or skipped — nothing else: the grid
    // walk is exact.
    assert_eq!(
        horizon.pdes.barriers + horizon.pdes.quanta_skipped,
        fixed.pdes.barriers,
        "windows executed + windows leapt must equal the fixed window count"
    );
    // Hybrid sits between the two.
    assert!(horizon.pdes.barriers <= hybrid.pdes.barriers);
    assert!(hybrid.pdes.barriers < fixed.pdes.barriers);
    assert_eq!(
        hybrid.pdes.barriers + hybrid.pdes.quanta_skipped,
        fixed.pdes.barriers
    );
}

// ---------------------------------------------------------------------
// Threaded kernel: functional identity across every policy knob.
// ---------------------------------------------------------------------

#[test]
fn threaded_kernel_functionally_identical_across_policy_knobs() {
    let mut serial_cfg = RunConfig {
        app: "synthetic".into(), // race-free app: checksums must match
        ops_per_core: 512,
        mode: Mode::Serial,
        quantum: 8 * NS,
        ..Default::default()
    };
    serial_cfg.system.cores = 4;
    let w = make_workload(&serial_cfg).unwrap();
    let serial = run_with_workload(&serial_cfg, &w).unwrap();

    for policy in POLICIES {
        for steal in [false, true] {
            for threads in [0usize, 2] {
                let mut cfg = serial_cfg.clone();
                cfg.mode = Mode::Parallel;
                cfg.quantum_policy = policy;
                cfg.steal = steal;
                cfg.threads = threads;
                let par = run_with_workload(&cfg, &w).unwrap();
                let what =
                    format!("{policy:?}/steal={steal}/threads={threads}");
                let acc = compare(&serial, &par);
                assert!(acc.checksum_match, "{what}: checksums must match");
                assert_eq!(
                    serial.stats.sum_suffix(".committed_ops"),
                    par.stats.sum_suffix(".committed_ops"),
                    "{what}: all ops must commit"
                );
                assert_eq!(
                    par.stats.sum_suffix(".value_mismatches"),
                    0.0,
                    "{what}: no coherence violations"
                );
            }
        }
    }
}

#[test]
fn oversubscribed_threaded_kernel_steals_windows() {
    // 16 domains on 2 host threads with stealing: the claim list must
    // actually migrate work between threads at least once.
    let mut cfg = RunConfig {
        app: "canneal".into(),
        ops_per_core: 512,
        mode: Mode::Parallel,
        quantum: 8 * NS,
        steal: true,
        threads: 2,
        ..Default::default()
    };
    cfg.system.cores = 15; // + shared domain = 16
    let w = make_workload(&cfg).unwrap();
    let r = run_with_workload(&cfg, &w).unwrap();
    assert!(r.events > 0);
    assert!(
        r.pdes.steals > 0,
        "2 threads x 16 domains must steal at least once"
    );
}
