//! Determinism gates for the border-ordered Ruby inbox handoff
//! (`--inbox-order border`, DESIGN.md §6, docs/DETERMINISM.md).
//!
//! The paper concedes (§6) that the threaded kernel consumes Ruby messages
//! in host-timing-dependent order. The border-ordered handoff removes that
//! last freedom, so the acceptance gate here is strictly stronger than the
//! functional gate in `tests/adaptive_quantum.rs`:
//!
//! * Under `border`, the threaded kernel is **bit-identical** to the
//!   deterministic virtual kernel — `sim_ticks`, event counts and every
//!   per-component statistic — across `--threads {1,2,8}` ×
//!   `--quantum-policy {fixed,horizon,hybrid}` × `--steal {on,off}`, on a
//!   sharing workload with software barriers (the worst case).
//! * The reordered-message counter proves the handoff actually changed an
//!   order: on a skewed "host" (the virtual kernel's round-robin, which
//!   stages each domain's whole window back-to-back) it must be nonzero.
//! * Under `host`, nothing is staged and the paper's behaviour (functional
//!   identity only) still holds.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::sched::{InboxOrder, QuantumPolicy, XbarArb};
use parti_sim::sim::time::NS;
use parti_sim::stats::compare;

mod common;
use common::{assert_bit_identical, assert_threaded_matches, FULL_MATRIX};

const POLICIES: [QuantumPolicy; 3] = [
    QuantumPolicy::Fixed,
    QuantumPolicy::Horizon,
    QuantumPolicy::Hybrid { max_leap: 4 },
];

/// Sharing app with software barriers (canneal: `barrier_every = 512`,
/// exceeded by 768 ops/core) — both the Ruby handoff and the
/// workload-barrier release path must be deterministic for this to pass.
fn base_cfg(order: InboxOrder, policy: QuantumPolicy) -> RunConfig {
    let mut c = RunConfig {
        app: "canneal".into(),
        ops_per_core: 768,
        mode: Mode::Virtual,
        quantum: 8 * NS,
        quantum_policy: policy,
        inbox_order: order,
        ..Default::default()
    };
    c.system.cores = 4;
    c
}

#[test]
fn border_threaded_is_bit_identical_to_virtual_across_all_knobs() {
    for policy in POLICIES {
        let vcfg = base_cfg(InboxOrder::Border, policy);
        let w = make_workload(&vcfg).unwrap();
        let reference = run_with_workload(&vcfg, &w).unwrap();
        assert!(reference.events > 0);
        assert!(
            reference.pdes.inbox_staged > 0,
            "sharing app must exercise the handoff"
        );
        assert_threaded_matches(
            &reference,
            &vcfg,
            &w,
            FULL_MATRIX,
            &format!("{policy:?}"),
        );
    }
}

#[test]
fn border_threaded_is_repeat_deterministic() {
    // The property host order lacks: two runs of the same threaded
    // configuration agree bit-for-bit, even oversubscribed and stealing.
    let mut cfg =
        base_cfg(InboxOrder::Border, QuantumPolicy::Hybrid { max_leap: 4 });
    cfg.mode = Mode::Parallel;
    cfg.steal = true;
    cfg.threads = 2;
    let w = make_workload(&cfg).unwrap();
    let a = run_with_workload(&cfg, &w).unwrap();
    let b = run_with_workload(&cfg, &w).unwrap();
    assert_bit_identical(&a, &b, "repeat");
}

#[test]
fn skewed_host_order_shows_nonzero_reordered_counter() {
    // The virtual kernel is a deterministic stand-in for a maximally
    // skewed host: it executes domains round-robin, so domain d's whole
    // window of cross-domain sends is staged before domain d+1's. The
    // canonical merge must interleave them back by arrival tick — the
    // reordered counter is exactly the number of deliveries whose host
    // staging position was wrong, and on a sharing app it cannot be zero.
    let cfg = base_cfg(InboxOrder::Border, QuantumPolicy::Fixed);
    let w = make_workload(&cfg).unwrap();
    let r = run_with_workload(&cfg, &w).unwrap();
    assert!(r.pdes.inbox_staged > 0, "cross traffic must be staged");
    assert!(
        r.pdes.inbox_reordered > 0,
        "round-robin staging of {} deliveries produced no reorders — \
         the merge would be a no-op and host order already canonical",
        r.pdes.inbox_staged
    );
    assert!(r.pdes.inbox_reordered <= r.pdes.inbox_staged);
}

#[test]
fn io_crossbar_runs_are_bit_identical_on_deterministic_executors() {
    // Regression for the `--io-milli > 0` crossbar path (ROADMAP item):
    // distinct same-tick cross-domain `MemReq`/`MemResp` deliveries to
    // the same consumer used to tie in the mailbox drain (every injected
    // event carried seq 0, so the stable drain-sort fell back to host
    // push order). With the canonical `(sender_domain, send order)` key
    // the drain is total, extending bit-exactness to IO-heavy runs on
    // every deterministic executor order: the virtual kernel and the
    // threaded kernel with a single statically-bound thread. (The former
    // §4.3 concession — the crossbar layer mutex racing under *true*
    // thread concurrency — is closed by the border-staged arbitration,
    // `--xbar-arb border`; the full threads × steal × preset matrix is
    // gated in tests/xbar_arb.rs and docs/XBAR.md tells the story.)
    for policy in POLICIES {
        let mut vcfg = base_cfg(InboxOrder::Border, policy);
        vcfg.system.io_milli = 50;
        let w = make_workload(&vcfg).unwrap();
        let reference = run_with_workload(&vcfg, &w).unwrap();
        assert!(
            reference.stats.sum_suffix(".io_reqs") > 0.0,
            "io_milli must generate crossbar traffic"
        );
        // Repeat determinism of the reference itself.
        let again = run_with_workload(&vcfg, &w).unwrap();
        assert_bit_identical(&reference, &again, "io virtual repeat");
        // Threaded, one thread, static binding: same executor order as
        // the virtual kernel, so everything must match bit-for-bit.
        let mut cfg = vcfg.clone();
        cfg.mode = Mode::Parallel;
        cfg.steal = false;
        cfg.threads = 1;
        let r = run_with_workload(&cfg, &w).unwrap();
        let what = format!("io/{policy:?}/threads=1");
        assert_bit_identical(&reference, &r, &what);
        let r2 = run_with_workload(&cfg, &w).unwrap();
        assert_bit_identical(&r, &r2, "io threaded repeat");
    }
}

#[test]
fn host_order_stays_functional_and_stages_nothing() {
    // `--inbox-order host --xbar-arb host` is the paper's original
    // contract: still functionally correct (checksums, committed ops),
    // with both border-staging machineries completely inert — no stages,
    // no border-merge hooks, no merge time.
    let mut scfg = base_cfg(InboxOrder::Host, QuantumPolicy::Fixed);
    scfg.xbar_arb = XbarArb::Host;
    scfg.app = "synthetic".into(); // race-free: checksums must match
    scfg.ops_per_core = 512;
    scfg.mode = Mode::Serial;
    let w = make_workload(&scfg).unwrap();
    let serial = run_with_workload(&scfg, &w).unwrap();
    let mut pcfg = scfg.clone();
    pcfg.mode = Mode::Parallel;
    let par = run_with_workload(&pcfg, &w).unwrap();
    let acc = compare(&serial, &par);
    assert!(acc.checksum_match, "host order must stay functional");
    assert_eq!(
        serial.stats.sum_suffix(".committed_ops"),
        par.stats.sum_suffix(".committed_ops")
    );
    assert_eq!(par.pdes.inbox_staged, 0, "host order must not stage");
    assert_eq!(par.pdes.inbox_reordered, 0);
    assert_eq!(par.pdes.inbox_merge_ns, 0);
    assert_eq!(par.pdes.xbar_staged, 0, "host arb must not stage");
    assert_eq!(par.pdes.xbar_deferred_grants, 0);
}

#[test]
fn border_and_host_agree_functionally_on_race_free_apps() {
    // The handoff changes *when* messages become visible (timing), never
    // *what* they carry: on a race-free app the two orders commit the
    // same data.
    let mut host_cfg = base_cfg(InboxOrder::Host, QuantumPolicy::Fixed);
    host_cfg.app = "stream".into();
    host_cfg.ops_per_core = 512;
    let w = make_workload(&host_cfg).unwrap();
    let host = run_with_workload(&host_cfg, &w).unwrap();
    let mut border_cfg = host_cfg.clone();
    border_cfg.inbox_order = InboxOrder::Border;
    let border = run_with_workload(&border_cfg, &w).unwrap();
    assert_eq!(
        host.stats.sum_suffix(".load_checksum"),
        border.stats.sum_suffix(".load_checksum"),
        "handoff must be timing-only"
    );
    assert_eq!(
        host.stats.sum_suffix(".committed_ops"),
        border.stats.sum_suffix(".committed_ops")
    );
}
