//! Gates for the declarative `SystemSpec` platform API (ISSUE 4):
//!
//! * TOML round-trip property: `SystemSpec -> TOML -> SystemSpec` is the
//!   identity over a seeded random walk of the spec space.
//! * Validation rejects broken specs with actionable errors.
//! * Every preset elaborates and runs on every kernel, and the threaded
//!   kernel is **bit-identical** to the virtual kernel across
//!   `{star, ring, mesh}` × `--quantum-policy` × `--steal` ×
//!   `--threads {1,2,8}` — extending `tests/inbox_order.rs`'s guarantee
//!   from the Fig. 4 star to the whole topology design space.
//! * Legacy flag-built star runs match the spec-built `fig4-8` platform
//!   bit-for-bit (the old `RunConfig` surface is a thin spec conversion).

use parti_sim::config::{Mode, RunConfig};
use parti_sim::cpu::CpuModel;
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::sched::QuantumPolicy;
use parti_sim::sim::time::NS;
use parti_sim::spec::{platforms, Interconnect, SystemSpec};
use parti_sim::stats::compare;

mod common;
use common::{assert_bit_identical, assert_threaded_matches, FULL_MATRIX};

// ---- helpers ----------------------------------------------------------

/// A PDES run config on `spec` with a sharing workload sized so the whole
/// preset matrix stays test-suite-fast (total core-ops roughly constant).
fn matrix_cfg(spec: &SystemSpec, policy: QuantumPolicy) -> RunConfig {
    let mut cfg = RunConfig::for_spec(spec);
    cfg.app = "canneal".into(); // sharing + software barriers: worst case
    cfg.ops_per_core = (4096 / spec.cores).max(48);
    cfg.mode = Mode::Virtual;
    cfg.quantum = 8 * NS;
    cfg.quantum_policy = policy;
    cfg
}

// ---- TOML round-trip property -----------------------------------------

/// Deterministic xorshift so the walk is reproducible without a rand dep.
struct Rng(u64);
impl Rng {
    fn step(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    fn pick(&mut self, n: u64) -> u64 {
        self.step() % n
    }
}

#[test]
fn toml_roundtrip_property_over_random_specs() {
    let mut rng = Rng(0x5eed_cafe_f00d_0001);
    let mut checked = 0;
    while checked < 64 {
        let cores = (rng.pick(12) + 1) as usize;
        let ic = match rng.pick(3) {
            0 => Interconnect::Star,
            1 => Interconnect::Ring,
            _ => {
                // Any divisor keeps the mesh full-rowed.
                let divisors: Vec<usize> =
                    (1..=cores).filter(|d| cores % d == 0).collect();
                let cols =
                    divisors[rng.pick(divisors.len() as u64) as usize];
                Interconnect::Mesh { cols }
            }
        };
        let mut spec = SystemSpec {
            cores,
            cpu: if rng.pick(2) == 0 {
                CpuModel::O3
            } else {
                CpuModel::Minor
            },
            cpu_mhz: 500 * (rng.pick(8) + 1),
            line_bytes: 1 << (5 + rng.pick(3)), // 32/64/128
            interconnect: ic,
            noc_latency_ns_x10: rng.pick(50) + 1,
            router_buffer: (rng.pick(8) + 1) as usize,
            data_flits: rng.pick(8) + 1,
            dram_mhz: 250 * (rng.pick(8) + 1),
            mem_channels: (rng.pick(4) + 1) as usize,
            io_milli: rng.pick(100),
            ..SystemSpec::default()
        }
        .named(
            format!("prop-{checked}"),
            format!("property walk point {checked}"),
        );
        for c in
            [&mut spec.l1i, &mut spec.l1d, &mut spec.l2, &mut spec.l3]
        {
            c.assoc = 1 << rng.pick(4);
            c.size_bytes =
                spec.line_bytes * c.assoc as u64 * (1 << rng.pick(6));
            c.latency_ns = rng.pick(16) + 1;
        }
        if spec.validate().is_err() {
            // The walk occasionally produces an invalid point (e.g. a
            // 1-core ring); the property is about valid specs.
            continue;
        }
        let toml = spec.to_toml();
        let back = SystemSpec::from_toml(&toml)
            .unwrap_or_else(|e| panic!("roundtrip parse failed: {e}\n{toml}"));
        assert_eq!(spec, back, "TOML roundtrip must be the identity");
        checked += 1;
    }
}

#[test]
fn from_toml_rejects_broken_specs_with_actionable_errors() {
    // Unknown key (typo).
    let err = SystemSpec::from_toml("corez = 8\n").unwrap_err();
    assert!(err.to_string().contains("unknown key `corez`"), "{err}");
    // Invalid value type.
    let err = SystemSpec::from_toml("cores = \"eight\"\n").unwrap_err();
    assert!(err.to_string().contains("cores"), "{err}");
    // Structurally valid TOML, semantically broken spec: the validation
    // layer runs too and explains the fix.
    let err =
        SystemSpec::from_toml("cores = 5\ninterconnect = \"mesh\"\nmesh_cols = 4\n")
            .unwrap_err();
    assert!(err.to_string().contains("multiple of mesh_cols"), "{err}");
    // Several problems are all reported at once.
    let err = SystemSpec::from_toml("cores = 0\nrouter_buffer = 0\n")
        .unwrap_err();
    assert!(err.errors.len() >= 2, "{err}");
}

#[test]
fn spec_file_loads_from_disk() {
    let spec = platforms::preset("ring-16").unwrap();
    let dir = std::env::temp_dir();
    let path = dir.join("parti_sim_platform_test.toml");
    std::fs::write(&path, spec.to_toml()).unwrap();
    let loaded = SystemSpec::load(&path).unwrap();
    assert_eq!(loaded, spec);
    // The CLI resolver takes the same path.
    let resolved = platforms::resolve(path.to_str().unwrap()).unwrap();
    assert_eq!(resolved, spec);
    let _ = std::fs::remove_file(&path);
}

// ---- functional gates per topology ------------------------------------

#[test]
fn every_topology_is_coherent_serial_vs_virtual() {
    // The new fabrics must carry the CHI-lite protocol correctly: the
    // serial reference and the virtual PDES kernel agree on checksums
    // and committed ops on a sharing workload, per topology.
    for ic in [
        Interconnect::Star,
        Interconnect::Ring,
        Interconnect::Mesh { cols: 2 },
    ] {
        let spec = SystemSpec {
            cores: 4,
            interconnect: ic,
            mem_channels: 2,
            ..SystemSpec::default()
        }
        .named("gate", "coherence gate");
        let mut serial_cfg = matrix_cfg(&spec, QuantumPolicy::Fixed);
        serial_cfg.mode = Mode::Serial;
        let w = make_workload(&serial_cfg).unwrap();
        let serial = run_with_workload(&serial_cfg, &w).unwrap();
        let mut vcfg = serial_cfg.clone();
        vcfg.mode = Mode::Virtual;
        let virt = run_with_workload(&vcfg, &w).unwrap();
        let acc = compare(&serial, &virt);
        assert!(
            acc.checksum_match,
            "{ic:?}: virtual kernel corrupted data on the new fabric"
        );
        assert_eq!(
            serial.stats.sum_suffix(".committed_ops"),
            virt.stats.sum_suffix(".committed_ops"),
            "{ic:?}: committed ops"
        );
        // The fabric actually carried traffic.
        assert!(
            serial.stats.sum_suffix(".routed") > 0.0,
            "{ic:?}: no routed messages?"
        );
    }
}

#[test]
fn longer_fabrics_cost_more_simulated_time() {
    // Sanity of the hop-latency model: the same workload on the same
    // cores takes at least as long on a ring (multi-hop) as on the star
    // (single central hop).
    let mut times = Vec::new();
    for ic in [Interconnect::Star, Interconnect::Ring] {
        let spec = SystemSpec {
            cores: 4,
            interconnect: ic,
            ..SystemSpec::default()
        }
        .named("hop", "hop cost gate");
        let mut cfg = matrix_cfg(&spec, QuantumPolicy::Fixed);
        cfg.mode = Mode::Serial;
        let w = make_workload(&cfg).unwrap();
        times.push(run_with_workload(&cfg, &w).unwrap().sim_ticks);
    }
    assert!(
        times[1] > times[0],
        "ring ({}) must be slower than star ({}) — hop latency not \
         routed through the fabric?",
        times[1],
        times[0]
    );
}

// ---- the preset bit-identity matrix -----------------------------------

#[test]
fn preset_matrix_threaded_is_bit_identical_to_virtual() {
    // Acceptance gate: `run --platform ring-16` / `mesh-64` (and the
    // star) produce bit-identical stats between the threaded and virtual
    // kernels across thread counts, policies and stealing, under the
    // default border-ordered handoff.
    let presets = ["fig4-2", "ring-16", "mesh-64"];
    for name in presets {
        let spec = platforms::preset(name).unwrap();
        for policy in
            [QuantumPolicy::Fixed, QuantumPolicy::Hybrid { max_leap: 4 }]
        {
            let vcfg = matrix_cfg(&spec, policy);
            let w = make_workload(&vcfg).unwrap();
            let reference = run_with_workload(&vcfg, &w).unwrap();
            assert!(reference.events > 0, "{name}: empty run");
            assert!(
                reference.pdes.inbox_staged > 0,
                "{name}: sharing app must exercise the handoff"
            );
            assert_threaded_matches(
                &reference,
                &vcfg,
                &w,
                FULL_MATRIX,
                &format!("{name}/{policy:?}"),
            );
        }
    }
}

#[test]
fn legacy_flags_and_spec_path_build_identical_star_runs() {
    // `run` with legacy flags (no --platform) must reproduce the
    // spec-built star bit-for-bit: the flag surface is a thin conversion
    // into SystemSpec, and the star elaboration preserves the historic
    // component order and ids.
    let spec = platforms::preset("fig4-8").unwrap();
    let mut legacy = RunConfig {
        app: "canneal".into(),
        ops_per_core: 512,
        mode: Mode::Virtual,
        quantum: 8 * NS,
        ..RunConfig::default()
    };
    legacy.system.cores = 8; // the legacy flag path

    let mut via_spec = RunConfig::for_spec(&spec);
    via_spec.app = legacy.app.clone();
    via_spec.ops_per_core = legacy.ops_per_core;
    via_spec.mode = legacy.mode;
    via_spec.quantum = legacy.quantum;

    assert_eq!(legacy.system, via_spec.system, "thin conversion drifted");
    let w = make_workload(&legacy).unwrap();
    let a = run_with_workload(&legacy, &w).unwrap();
    let b = run_with_workload(&via_spec, &w).unwrap();
    assert_bit_identical(&a, &b, "legacy flags vs fig4-8 spec");
}

#[test]
fn invalid_platform_surfaces_as_error_not_panic() {
    // Poke a broken platform (ragged mesh) straight into the legacy
    // config surface, bypassing spec validation-by-construction; the
    // harness must still refuse with the actionable message.
    let mut cfg = RunConfig {
        app: "synthetic".into(),
        ops_per_core: 16,
        ..RunConfig::default()
    };
    cfg.system.cores = 5;
    cfg.system.interconnect = Interconnect::Mesh { cols: 4 };
    let w = make_workload(&cfg).unwrap();
    let err = run_with_workload(&cfg, &w).unwrap_err();
    assert!(
        err.to_string().contains("multiple of mesh_cols"),
        "expected the actionable validation error, got: {err}"
    );
}
