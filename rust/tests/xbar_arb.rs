//! Determinism gates for the border-staged IO-crossbar layer arbitration
//! (`--xbar-arb border`, docs/XBAR.md, docs/DETERMINISM.md).
//!
//! The paper's §4.3 crossbar resolves layer occupancy with `try_lock` +
//! occupy/busy on live shared state — the last documented source of
//! nondeterminism under true thread concurrency after the PR-3 inbox
//! handoff. The border-staged protocol removes it, upgrading the
//! determinism guarantee to *unconditional*: with the default
//! `--inbox-order border --xbar-arb border`, the threaded kernel is
//! bit-identical to the virtual kernel on IO-heavy runs across thread
//! counts, stealing and platform presets.
//!
//! Acceptance gate (ISSUE 5): threaded runs with `--io-milli 5` are
//! bit-identical to the virtual reference across `--threads {1,2,8}` ×
//! `--steal` × `{fig4-2, ring-16, mesh-64}` under `--xbar-arb border`.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::pdes::RunResult;
use parti_sim::sched::{QuantumPolicy, XbarArb};
use parti_sim::sim::time::NS;
use parti_sim::spec::platforms;

mod common;
use common::{assert_bit_identical, assert_threaded_matches, FULL_MATRIX};

/// A sharing workload on `preset`, sized so the whole matrix stays
/// test-suite-fast while every core still issues IO at `--io-milli 5`
/// (one access per 200 ops — ops_per_core must exceed 200).
fn preset_cfg(preset: &str, io_milli: u64) -> RunConfig {
    let spec = platforms::preset(preset).unwrap();
    let mut cfg = RunConfig::for_spec(&spec);
    cfg.app = "canneal".into();
    cfg.ops_per_core = match preset {
        "fig4-2" => 768,
        "ring-16" => 320,
        _ => 224,
    };
    cfg.mode = Mode::Virtual;
    cfg.quantum = 8 * NS;
    cfg.quantum_policy = QuantumPolicy::Hybrid { max_leap: 4 };
    cfg.system.io_milli = io_milli;
    cfg
}

#[test]
fn border_arb_threaded_is_bit_identical_to_virtual_across_the_matrix() {
    // The ISSUE 5 acceptance matrix. `--io-milli 0` gets a single smoke
    // point per preset (the full io-free matrix is already gated by
    // tests/platforms.rs); `--io-milli 5` runs the full
    // threads × steal product, which is exactly the configuration the
    // old §4.3 try_lock arbitration could not keep deterministic.
    for preset in ["fig4-2", "ring-16", "mesh-64"] {
        for io_milli in [0u64, 5] {
            let vcfg = preset_cfg(preset, io_milli);
            let w = make_workload(&vcfg).unwrap();
            let reference = run_with_workload(&vcfg, &w).unwrap();
            assert!(reference.events > 0, "{preset}: empty run");
            if io_milli > 0 {
                assert!(
                    reference.stats.sum_suffix(".io_reqs") > 0.0,
                    "{preset}: io_milli must generate crossbar traffic"
                );
                assert!(
                    reference.pdes.xbar_staged > 0,
                    "{preset}: border arb must stage the IO requests"
                );
            } else {
                assert_eq!(reference.pdes.xbar_staged, 0, "{preset}: inert");
            }
            let matrix: &[(usize, bool)] =
                if io_milli > 0 { FULL_MATRIX } else { &[(2, true)] };
            assert_threaded_matches(
                &reference,
                &vcfg,
                &w,
                matrix,
                &format!("{preset}/io={io_milli}"),
            );
        }
    }
}

#[test]
fn io_workloads_complete_under_every_kernel() {
    // Regression for the IO response routing (devices answer to the
    // *sequencer*, which releases the layer before completing to the
    // CPU): every IO transaction must finish, so the full workload
    // commits on the serial reference, the virtual kernel and the
    // threaded kernel alike. Before the fix, leaked layer occupancies
    // deadlocked every core after its first few IO accesses and the run
    // quiesced with most ops uncommitted.
    let mut cfg = preset_cfg("fig4-2", 50);
    cfg.mode = Mode::Serial;
    let w = make_workload(&cfg).unwrap();
    let expected = (2 * cfg.ops_per_core) as f64;
    let serial = run_with_workload(&cfg, &w).unwrap();
    assert_eq!(
        serial.stats.sum_suffix(".committed_ops"),
        expected,
        "serial: every op (incl. IO) must commit"
    );
    for mode in [Mode::Virtual, Mode::Parallel] {
        let mut c = cfg.clone();
        c.mode = mode;
        let r = run_with_workload(&c, &w).unwrap();
        assert_eq!(
            r.stats.sum_suffix(".committed_ops"),
            expected,
            "{mode:?}: every op (incl. IO) must commit"
        );
        // Device-side request counts must agree with the serial
        // reference (`io_reqs` counts *attempts*, which differ between
        // arbitration styles — host-mode busy retries re-issue).
        assert_eq!(
            device_requests(&r),
            device_requests(&serial),
            "{mode:?}: devices must see the same request set as serial"
        );
    }
}

/// Total requests the crossbar targets actually served.
fn device_requests(r: &RunResult) -> f64 {
    r.stats.get("uart.reads").unwrap_or(0.0)
        + r.stats.get("uart.writes").unwrap_or(0.0)
        + r.stats.get("timer.reads").unwrap_or(0.0)
        + r.stats.get("timer.writes").unwrap_or(0.0)
}

#[test]
fn contended_layers_defer_and_replay_deterministically() {
    // 4 cores hammering 2 device layers: grants must be deferred across
    // borders (the busy/retry path of the protocol) and the whole run
    // must stay repeat-deterministic, including the deferral counter.
    let mut cfg = preset_cfg("fig4-2", 0);
    cfg.system.cores = 4;
    cfg.system.io_milli = 100; // one IO access per 10 ops
    cfg.ops_per_core = 512;
    let w = make_workload(&cfg).unwrap();
    let a = run_with_workload(&cfg, &w).unwrap();
    assert!(a.pdes.xbar_staged > 0, "IO must be staged");
    assert!(
        a.pdes.xbar_deferred_grants > 0,
        "4 initiators on 2 layers must contend ({} staged)",
        a.pdes.xbar_staged
    );
    let b = run_with_workload(&cfg, &w).unwrap();
    assert_bit_identical(&a, &b, "virtual repeat");
    // Threaded, oversubscribed and stealing: same bits.
    let mut pcfg = cfg.clone();
    pcfg.mode = Mode::Parallel;
    pcfg.threads = 2;
    pcfg.steal = true;
    let p = run_with_workload(&pcfg, &w).unwrap();
    assert_bit_identical(&a, &p, "threaded 2t steal");
}

#[test]
fn host_arb_is_the_ab_lever_and_stays_deterministic_when_sequential() {
    // `--xbar-arb host` restores the paper's mid-window try_lock path —
    // the A/B lever for bisecting a divergence (docs/DETERMINISM.md §4).
    // On deterministic executor orders (virtual kernel; threaded with one
    // statically-bound thread) it is still bit-exact, which is precisely
    // the pre-PR-5 guarantee.
    let mut vcfg = preset_cfg("fig4-2", 50);
    vcfg.xbar_arb = XbarArb::Host;
    let w = make_workload(&vcfg).unwrap();
    let reference = run_with_workload(&vcfg, &w).unwrap();
    assert_eq!(reference.pdes.xbar_staged, 0, "host arb must not stage");
    assert_eq!(reference.pdes.xbar_deferred_grants, 0);
    let again = run_with_workload(&vcfg, &w).unwrap();
    assert_bit_identical(&reference, &again, "host-arb virtual repeat");
    let mut cfg = vcfg.clone();
    cfg.mode = Mode::Parallel;
    cfg.threads = 1;
    cfg.steal = false;
    let r = run_with_workload(&cfg, &w).unwrap();
    assert_bit_identical(&reference, &r, "host-arb threads=1");
}

#[test]
fn border_and_host_arb_agree_functionally() {
    // The arbitration contract changes *when* layers are granted
    // (timing), never what the devices compute: on the deterministic
    // virtual kernel both arbs commit the same ops and see the same IO
    // request mix.
    let border_cfg = preset_cfg("fig4-2", 50);
    let w = make_workload(&border_cfg).unwrap();
    let border = run_with_workload(&border_cfg, &w).unwrap();
    let mut host_cfg = border_cfg.clone();
    host_cfg.xbar_arb = XbarArb::Host;
    let host = run_with_workload(&host_cfg, &w).unwrap();
    assert_eq!(
        border.stats.sum_suffix(".committed_ops"),
        host.stats.sum_suffix(".committed_ops"),
        "arbitration must be timing-only"
    );
    // Every issued request reaches its device exactly once under both
    // contracts (`io_reqs` itself counts attempts and differs: host-mode
    // busy retries re-issue, border-mode requests stage once).
    assert_eq!(
        device_requests(&border),
        device_requests(&host),
        "devices see every request"
    );
    assert!(device_requests(&border) > 0.0);
}
