//! Property-based tests on the coordinator invariants (event ordering,
//! cache replacement, message-buffer ordering, crossbar layer exclusivity,
//! host-model monotonicity, traffic-spec TOML round-trip), driven by the
//! in-tree deterministic property-test harness
//! ([`parti_sim::util::prop`]).

use std::collections::BTreeMap;

use parti_sim::mem::{CacheArray, LineState};
use parti_sim::pdes::{HostModel, WorkProfile};
use parti_sim::ruby::new_inbox;
use parti_sim::ruby::{MsgKind, RubyMsg};
use parti_sim::sched::{QueueKind, SchedQueue, Scheduler};
use parti_sim::sim::event::{prio, EventKind};
use parti_sim::sim::ids::CompId;
use parti_sim::spec::traffic::{
    TrafficSpec, ALL_PATTERNS, MAX_SHARED_LINES, MAX_WORKING_LINES,
};
use parti_sim::util::prop::check;
use parti_sim::workload::{addrgen, AddrGenParams};
use parti_sim::xbar::{default_xbar, Occupy};

// ---------------------------------------------------------------------
// Event queue (both implementations): pops are totally ordered by
// (tick, prio, seq); deschedule removes exactly the chosen events; the
// bucketed queue's pop sequence is identical to the heap's.
// ---------------------------------------------------------------------

const KINDS: [QueueKind; 2] = [QueueKind::Heap, QueueKind::Bucket];

#[test]
fn prop_event_queue_total_order() {
    check("eq-total-order", 50, |g, _| {
        let n = g.range_usize(1, 200);
        // Mix of near ticks (intra-bucket) and far ticks (ring/overflow).
        let ticks: Vec<u64> = (0..n)
            .map(|_| {
                if g.bool() {
                    g.range_u64(0, 50)
                } else {
                    g.range_u64(0, 2_000_000)
                }
            })
            .collect();
        for kind in KINDS {
            let mut q = SchedQueue::new(kind);
            for &tick in &ticks {
                let p = *g.pick(&[prio::BARRIER, prio::DEFAULT, prio::CPU]);
                q.schedule(tick, p, CompId(0), EventKind::CpuTick);
            }
            let mut last = (0u64, 0u8, 0u64);
            let mut popped = 0;
            while let Some(e) = q.pop() {
                let key = (e.tick, e.prio, e.seq);
                assert!(
                    key >= last,
                    "{kind:?}: events out of order: {key:?} < {last:?}"
                );
                last = key;
                popped += 1;
            }
            assert_eq!(popped, n, "{kind:?}");
        }
    });
}

#[test]
fn prop_event_queue_deschedule_is_precise() {
    check("eq-deschedule", 50, |g, _| {
        for kind in KINDS {
            let mut q = SchedQueue::new(kind);
            let n = g.range_usize(1, 100);
            let mut keep = 0usize;
            let mut handles = Vec::new();
            for i in 0..n {
                let h = q.schedule(
                    g.range_u64(0, 200_000),
                    prio::DEFAULT,
                    CompId(i as u32),
                    EventKind::CpuTick,
                );
                handles.push(h);
            }
            let mut cancelled = Vec::new();
            for h in handles {
                if g.bool() {
                    q.deschedule(h);
                    cancelled.push(h.0);
                } else {
                    keep += 1;
                }
            }
            assert_eq!(q.len(), keep, "{kind:?}: len after deschedules");
            let mut seen = 0;
            while let Some(e) = q.pop() {
                assert!(
                    !cancelled.contains(&e.seq),
                    "{kind:?}: cancelled event popped"
                );
                seen += 1;
            }
            assert_eq!(seen, keep, "{kind:?}");
        }
    });
}

/// The tentpole equivalence property: drive the heap queue and the
/// bucketed queue with the same random schedule / deschedule / reschedule
/// / insert / pop interleaving and require bit-identical pop sequences
/// (including handles, i.e. sequence numbers).
#[test]
fn prop_heap_and_bucket_pop_identically() {
    use parti_sim::sim::event::Event;

    check("eq-heap-vs-bucket", 60, |g, case| {
        let mut heap = SchedQueue::new(QueueKind::Heap);
        let mut bucket = SchedQueue::new(QueueKind::Bucket);
        let mut live_handles = Vec::new();
        let steps = g.range_usize(20, 400);
        for _ in 0..steps {
            match g.range_usize(0, 9) {
                // schedule (weighted heaviest)
                0..=4 => {
                    let tick = match g.range_usize(0, 2) {
                        0 => g.range_u64(0, 4000),       // current bucket
                        1 => g.range_u64(0, 200_000),    // ring range
                        _ => g.range_u64(0, 50_000_000), // overflow range
                    };
                    let p = *g.pick(&[prio::BARRIER, prio::DEFAULT, prio::CPU]);
                    let t = CompId(g.range_u64(0, 30) as u32);
                    let h1 = heap.schedule(tick, p, t, EventKind::CpuTick);
                    let h2 = bucket.schedule(tick, p, t, EventKind::CpuTick);
                    assert_eq!(h1, h2, "case {case}: handle divergence");
                    live_handles.push(h1);
                }
                // insert (mailbox-drain path)
                5 => {
                    let ev = Event {
                        tick: g.range_u64(0, 1_000_000),
                        prio: prio::DEFAULT,
                        seq: 0,
                        target: CompId(g.range_u64(0, 30) as u32),
                        kind: EventKind::CpuTick,
                    };
                    let h1 = heap.insert(ev.clone());
                    let h2 = bucket.insert(ev);
                    assert_eq!(h1, h2, "case {case}: insert handle divergence");
                    live_handles.push(h1);
                }
                // deschedule a random (possibly stale) handle
                6 => {
                    if !live_handles.is_empty() {
                        let i = g.range_usize(0, live_handles.len() - 1);
                        let h = live_handles[i];
                        heap.deschedule(h);
                        bucket.deschedule(h);
                    }
                }
                // reschedule
                7 => {
                    if !live_handles.is_empty() {
                        let i = g.range_usize(0, live_handles.len() - 1);
                        let h = live_handles[i];
                        let tick = g.range_u64(0, 300_000);
                        let t = CompId(g.range_u64(0, 30) as u32);
                        let h1 = heap.reschedule(
                            h,
                            tick,
                            prio::DEFAULT,
                            t,
                            EventKind::CpuTick,
                        );
                        let h2 = bucket.reschedule(
                            h,
                            tick,
                            prio::DEFAULT,
                            t,
                            EventKind::CpuTick,
                        );
                        assert_eq!(h1, h2, "case {case}");
                        live_handles.push(h1);
                    }
                }
                // pop
                _ => {
                    let a = heap.pop();
                    let b = bucket.pop();
                    match (&a, &b) {
                        (None, None) => {}
                        (Some(x), Some(y)) => {
                            assert_eq!(
                                (x.tick, x.prio, x.seq, x.target),
                                (y.tick, y.prio, y.seq, y.target),
                                "case {case}: pop divergence"
                            );
                        }
                        _ => panic!("case {case}: pop presence divergence"),
                    }
                }
            }
            assert_eq!(heap.len(), bucket.len(), "case {case}: len divergence");
        }
        // Drain both to the end: the tails must match too.
        loop {
            let a = heap.pop();
            let b = bucket.pop();
            match (a, b) {
                (None, None) => break,
                (Some(x), Some(y)) => assert_eq!(
                    (x.tick, x.prio, x.seq, x.target),
                    (y.tick, y.prio, y.seq, y.target),
                    "case {case}: tail divergence"
                ),
                _ => panic!("case {case}: tail presence divergence"),
            }
        }
        assert_eq!(heap.executed(), bucket.executed(), "case {case}");
    });
}

// ---------------------------------------------------------------------
// Cache array vs a naive model: same hit/miss classification and same
// final content for random access/allocate/invalidate sequences.
// ---------------------------------------------------------------------

#[test]
fn prop_cache_array_matches_naive_lru_model() {
    check("cache-lru-model", 30, |g, _| {
        let assoc = g.range_usize(1, 4);
        let sets = 1usize << g.range_usize(0, 3);
        let mut c = CacheArray::new((sets * assoc * 64) as u64, assoc, 64);
        // naive model: per set, Vec<(addr)> in LRU order (front = LRU)
        let mut model: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
        let addr_pool: Vec<u64> =
            (0..32).map(|i| i * 64).collect();
        for _ in 0..g.range_usize(10, 300) {
            let addr = *g.pick(&addr_pool);
            let set = ((addr / 64) as usize) % sets;
            let ways = model.entry(set).or_default();
            match g.range_usize(0, 2) {
                0 => {
                    // access
                    let want_hit = ways.contains(&addr);
                    let got = c.access(addr).is_some();
                    assert_eq!(got, want_hit, "access({addr:#x})");
                    if want_hit {
                        ways.retain(|&a| a != addr);
                        ways.push(addr);
                    }
                }
                1 => {
                    // allocate
                    c.allocate(addr, LineState::Shared, addr);
                    if ways.contains(&addr) {
                        ways.retain(|&a| a != addr);
                    } else if ways.len() == assoc {
                        ways.remove(0); // evict LRU
                    }
                    ways.push(addr);
                }
                _ => {
                    // invalidate
                    let had = ways.contains(&addr);
                    let got = c.invalidate(addr).is_some();
                    assert_eq!(got, had, "invalidate({addr:#x})");
                    ways.retain(|&a| a != addr);
                }
            }
        }
        // final content agrees
        for (set, ways) in &model {
            for &a in ways {
                assert!(
                    c.peek(a).is_some(),
                    "model has {a:#x} (set {set}), cache does not"
                );
            }
        }
    });
}

// ---------------------------------------------------------------------
// MessageBuffer/Inbox: drained messages come out in global arrival order;
// capacity is never exceeded.
// ---------------------------------------------------------------------

#[test]
fn prop_inbox_global_arrival_order() {
    check("inbox-order", 50, |g, _| {
        let nbufs = g.range_usize(1, 4);
        let caps: Vec<usize> = (0..nbufs).map(|_| usize::MAX).collect();
        let inbox = new_inbox(&caps);
        let mut ib = inbox.lock().unwrap();
        let n = g.range_usize(1, 100);
        // Feed via the public force-less path: bufs are pub within Inbox.
        for _ in 0..n {
            let b = g.range_usize(0, nbufs - 1);
            let arrival = g.range_u64(0, 50);
            let msg = RubyMsg {
                kind: MsgKind::ReadShared,
                addr: arrival, // encode arrival in addr for checking
                value: 0,
                src: CompId(0),
                dst: CompId(1),
                txn: 0,
                core: 0,
                issued: 0,
            };
            ib.bufs[b].push_for_test(arrival, msg);
        }
        let drained = ib.drain_ready(25);
        let mut last = 0u64;
        for m in &drained {
            assert!(m.addr >= last, "arrival order violated");
            assert!(m.addr <= 25, "not-ready message drained");
            last = m.addr;
        }
        assert_eq!(ib.total_pending() + drained.len(), n);
    });
}

// ---------------------------------------------------------------------
// Crossbar: at most one holder per layer at any time; every waiter
// eventually gets the layer.
// ---------------------------------------------------------------------

#[test]
fn prop_xbar_layer_exclusive_and_fair() {
    check("xbar-exclusive", 40, |g, _| {
        use parti_sim::xbar::IO_BASE;
        let x = default_xbar(&[CompId(100), CompId(101)]);
        let initiators: Vec<CompId> = (0..6).map(CompId).collect();
        let mut holder: Option<CompId> = None;
        let mut granted_total = 0usize;
        for _ in 0..g.range_usize(10, 200) {
            let who = *g.pick(&initiators);
            if holder == Some(who) {
                // holder releases
                let next = x.release(IO_BASE, who);
                holder = None;
                if let Some(w) = next {
                    // the woken waiter must be able to take the layer
                    match x.try_occupy(IO_BASE, w) {
                        Occupy::Granted { .. } => {
                            holder = Some(w);
                            granted_total += 1;
                        }
                        other => panic!("woken waiter rejected: {other:?}"),
                    }
                }
            } else {
                match x.try_occupy(IO_BASE, who) {
                    Occupy::Granted { .. } => {
                        assert!(holder.is_none(), "two holders at once");
                        holder = Some(who);
                        granted_total += 1;
                    }
                    Occupy::Busy => assert!(holder.is_some()),
                    Occupy::Contended => {} // single-threaded: cannot happen
                    Occupy::NoTarget => panic!("mapped address"),
                }
            }
        }
        assert!(granted_total > 0);
    });
}

// ---------------------------------------------------------------------
// Host model: speedup is monotone in host cores; makespan >= max work and
// >= total/H (standard scheduling lower bounds).
// ---------------------------------------------------------------------

#[test]
fn prop_host_model_bounds_and_monotonicity() {
    check("host-model", 50, |g, _| {
        let quanta = g.range_usize(1, 20);
        let domains = g.range_usize(1, 16);
        let work = WorkProfile {
            per_quantum: (0..quanta)
                .map(|_| {
                    (0..domains).map(|_| g.range_u64(0, 500) as u32).collect()
                })
                .collect(),
            ..Default::default()
        };
        let cost = 10.0;
        let mk = |h: usize| HostModel {
            h_cores: h,
            event_cost_ns: cost,
            barrier_cost_ns: 0.0,
            steal: true,
        };
        for q in &work.per_quantum {
            let h = g.range_usize(1, 8);
            let m = mk(h).quantum_makespan(q);
            let total: f64 = q.iter().map(|&w| w as f64 * cost).sum();
            let maxw = q.iter().map(|&w| w as f64 * cost).fold(0.0, f64::max);
            assert!(m >= maxw - 1e-9, "makespan below max work");
            assert!(m >= total / h as f64 - 1e-9, "makespan below total/H");
            assert!(m <= total + 1e-9, "makespan above serial total");
        }
        let serial_events: u64 = work.total();
        let s2 = mk(2).speedup(serial_events, &work);
        let s8 = mk(8).speedup(serial_events, &work);
        assert!(s8 >= s2 - 1e-9, "more host cores must not hurt");
    });
}

// ---------------------------------------------------------------------
// TrafficSpec: `spec -> TOML -> spec` is the identity over a seeded walk
// of the valid spec space, and every single-knob excursion outside the
// documented ranges is rejected — by `validate()` directly and by the
// `from_toml` path (so a hand-edited scenario file cannot smuggle a
// broken spec past the CLI).
// ---------------------------------------------------------------------

/// One random point in the *valid* TrafficSpec space.
fn random_traffic_spec(
    g: &mut parti_sim::util::prop::Gen,
    i: usize,
) -> TrafficSpec {
    TrafficSpec {
        name: format!("prop-{i}"),
        description: format!("traffic property walk point {i}"),
        pattern: *g.pick(ALL_PATTERNS),
        seed: g.u64(),
        intensity_milli: g.range_u64(1, 1000),
        burst_intensity_milli: g.range_u64(1, 1000),
        phase_ops: g.range_usize(1, 4096),
        store_milli: g.range_u64(0, 1000),
        sharing_milli: g.range_u64(0, 1000),
        working_lines: g.range_u64(1, MAX_WORKING_LINES),
        shared_lines: g.range_u64(1, MAX_SHARED_LINES),
    }
}

#[test]
fn prop_traffic_spec_toml_roundtrip_is_identity() {
    check("traffic-toml-roundtrip", 64, |g, i| {
        let spec = random_traffic_spec(g, i);
        spec.validate()
            .unwrap_or_else(|e| panic!("walk left the valid region: {e}"));
        let toml = spec.to_toml();
        let back = TrafficSpec::from_toml(&toml)
            .unwrap_or_else(|e| panic!("roundtrip parse failed: {e}\n{toml}"));
        assert_eq!(spec, back, "TOML roundtrip must be the identity");
    });
}

#[test]
fn prop_traffic_spec_out_of_range_knobs_are_rejected() {
    // Each case takes a valid spec and pushes exactly one knob outside
    // its range; both validate() and the serialise-then-parse path must
    // refuse, and the error must name the offending knob.
    let break_one: &[(&str, fn(&mut TrafficSpec))] = &[
        ("intensity_milli", |s| s.intensity_milli = 0),
        ("intensity_milli", |s| s.intensity_milli = 1001),
        ("burst_intensity_milli", |s| s.burst_intensity_milli = 0),
        ("phase_ops", |s| s.phase_ops = 0),
        ("store_milli", |s| s.store_milli = 2000),
        ("sharing_milli", |s| s.sharing_milli = 1001),
        ("working_lines", |s| s.working_lines = 0),
        ("working_lines", |s| s.working_lines = MAX_WORKING_LINES + 1),
        ("shared_lines", |s| s.shared_lines = MAX_SHARED_LINES + 1),
    ];
    check("traffic-rejection", 40, |g, i| {
        let mut spec = random_traffic_spec(g, i);
        let (knob, breaker) = *g.pick(break_one);
        breaker(&mut spec);
        let err = spec
            .validate()
            .expect_err("an out-of-range knob must fail validation");
        assert!(
            err.errors.iter().any(|e| e.contains(knob)),
            "{knob}: error must name the knob, got {err}"
        );
        let err = TrafficSpec::from_toml(&spec.to_toml())
            .expect_err("from_toml must re-validate");
        assert!(err.errors.iter().any(|e| e.contains(knob)), "{err}");
    });
}

#[test]
fn traffic_toml_rejects_unknown_keys_and_collects_all_errors() {
    // A typo must not silently fall back to a default...
    let err = TrafficSpec::from_toml("sharring_milli = 500\n").unwrap_err();
    assert!(
        err.errors[0].contains("unknown key `sharring_milli`"),
        "{err}"
    );
    // ...and the hint points at the schema doc.
    assert!(err.to_string().contains("docs/TRAFFIC.md"), "{err}");
    // Zero intensity and out-of-range sharing are refused together with
    // the unknown key: one parse reports every problem at once.
    let err = TrafficSpec::from_toml(
        "intensity_milli = 0\nsharing_milli = 1500\nhotness = 3\n",
    )
    .unwrap_err();
    assert!(err.errors.iter().any(|e| e.contains("hotness")), "{err}");
    // Parse-layer errors (the unknown key) are reported first; the
    // value-range problems surface once the schema is fixed.
    let err =
        TrafficSpec::from_toml("intensity_milli = 0\nsharing_milli = 1500\n")
            .unwrap_err();
    assert!(
        err.errors.iter().any(|e| e.contains("intensity_milli")),
        "{err}"
    );
    assert!(
        err.errors.iter().any(|e| e.contains("sharing_milli")),
        "{err}"
    );
}

// ---------------------------------------------------------------------
// SweepSpec: shard partitioning is total and disjoint over the expanded
// point set; `spec -> TOML -> spec` is the identity over a seeded walk
// of the valid spec space; every single-knob excursion is rejected by
// `validate()` and by the `from_toml` path (docs/SWEEP.md).
// ---------------------------------------------------------------------

use parti_sim::config::Mode;
use parti_sim::harness::sweep::{expand, shard_points};
use parti_sim::sched::QuantumPolicy;
use parti_sim::spec::sweep::{Sampling, SweepSpec};
use parti_sim::spec::Interconnect;

/// A non-empty, duplicate-free random subset of `pool` (SweepSpec
/// rejects duplicate axis values).
fn subset<T: Clone>(g: &mut parti_sim::util::prop::Gen, pool: &[T]) -> Vec<T> {
    let mut out = Vec::new();
    for t in pool {
        if g.bool() {
            out.push(t.clone());
        }
    }
    if out.is_empty() {
        out.push(pool[g.range_usize(0, pool.len() - 1)].clone());
    }
    out
}

/// One random point in the *valid* SweepSpec space. Axis pools stay
/// inside every preset's constraints (ring fabrics need >= 2 cores;
/// meshes are excluded because they also constrain divisibility).
fn random_sweep_spec(
    g: &mut parti_sim::util::prop::Gen,
    i: usize,
) -> SweepSpec {
    let opt = |g: &mut parti_sim::util::prop::Gen, v: Vec<u64>| {
        if g.bool() {
            Vec::new()
        } else {
            v
        }
    };
    let cores =
        if g.bool() { Vec::new() } else { subset(g, &[2usize, 4, 6, 8]) };
    let cpu_widths =
        if g.bool() { Vec::new() } else { subset(g, &[1usize, 2, 4, 8]) };
    let rob_sizes =
        if g.bool() { Vec::new() } else { subset(g, &[8usize, 32, 64, 128]) };
    let fabrics = if g.bool() {
        Vec::new()
    } else {
        subset(g, &[Interconnect::Star, Interconnect::Ring])
    };
    let l2 = subset(g, &[128u64, 256, 512]);
    let q = subset(g, &[4u64, 8, 16, 32]);
    SweepSpec {
        name: format!("prop-{i}"),
        description: format!("sweep property walk point {i}"),
        platforms: subset(
            g,
            &["fig4-2".to_string(), "fig4-8".to_string(), "ring-16".to_string()],
        ),
        cores,
        l2_kib: opt(g, l2),
        fabrics,
        workloads: subset(
            g,
            &[
                "app:synthetic".to_string(),
                "app:canneal".to_string(),
                "traffic:hotspot".to_string(),
                "traffic:transpose".to_string(),
            ],
        ),
        kernels: subset(g, &[Mode::Serial, Mode::Parallel, Mode::Virtual]),
        cpu_widths,
        rob_sizes,
        quantum_ns: q,
        quantum_policies: subset(
            g,
            &[
                QuantumPolicy::Fixed,
                QuantumPolicy::Horizon,
                QuantumPolicy::Hybrid { max_leap: 8 },
            ],
        ),
        sampling: if g.bool() { Sampling::Grid } else { Sampling::Random },
        samples: g.range_usize(1, 64),
        sample_seed: g.u64(),
        ops_per_core: g.range_usize(1, 4096),
        seed: g.u64(),
        inner_threads: g.range_usize(1, 8),
    }
}

#[test]
fn prop_sweep_shard_partition_is_total_and_disjoint() {
    check("sweep-shard-partition", 16, |g, i| {
        let spec = random_sweep_spec(g, i);
        spec.validate()
            .unwrap_or_else(|e| panic!("walk left the valid region: {e}"));
        let points = expand(&spec).unwrap();
        for n in 1..=4usize {
            let mut seen = Vec::new();
            for s in 0..n {
                let shard = shard_points(&points, (s, n));
                for p in &shard {
                    assert_eq!(
                        p.index % n,
                        s,
                        "point {} landed in the wrong shard",
                        p.index
                    );
                }
                seen.extend(shard.iter().map(|p| p.index));
            }
            seen.sort_unstable();
            let want: Vec<usize> = (0..points.len()).collect();
            // Equality of the sorted union with 0..len is totality and
            // disjointness at once (a duplicate would make it too long).
            assert_eq!(seen, want, "shards {n}: not a partition");
        }
    });
}

#[test]
fn prop_sweep_spec_toml_roundtrip_is_identity() {
    check("sweep-toml-roundtrip", 64, |g, i| {
        let spec = random_sweep_spec(g, i);
        let toml = spec.to_toml();
        let back = SweepSpec::from_toml(&toml)
            .unwrap_or_else(|e| panic!("roundtrip parse failed: {e}\n{toml}"));
        assert_eq!(spec, back, "TOML roundtrip must be the identity");
    });
}

#[test]
fn prop_sweep_spec_out_of_range_knobs_are_rejected() {
    // Each case pushes exactly one knob outside its documented range;
    // both validate() and the serialise-then-parse path must refuse,
    // naming the offending knob.
    let break_one: &[(&str, fn(&mut SweepSpec))] = &[
        ("platforms", |s| s.platforms.clear()),
        ("platforms", |s| s.platforms = vec!["atlantis".into()]),
        ("cores", |s| s.cores = vec![0]),
        ("l2_kib", |s| s.l2_kib = vec![0]),
        ("workloads", |s| s.workloads.clear()),
        ("workloads", |s| s.workloads = vec!["app:nosuch".into()]),
        ("workloads", |s| s.workloads = vec!["hotspot".into()]),
        ("kernels", |s| s.kernels.clear()),
        ("quantum_ns", |s| s.quantum_ns.clear()),
        ("quantum_ns", |s| s.quantum_ns = vec![0]),
        ("quantum_ns", |s| s.quantum_ns = vec![8, 8]),
        ("quantum_policies", |s| s.quantum_policies.clear()),
        ("cpu_widths", |s| s.cpu_widths = vec![0]),
        ("cpu_widths", |s| s.cpu_widths = vec![17]),
        ("cpu_widths", |s| s.cpu_widths = vec![2, 2]),
        ("rob_sizes", |s| s.rob_sizes = vec![0]),
        ("rob_sizes", |s| s.rob_sizes = vec![4096]),
        ("samples", |s| {
            s.sampling = Sampling::Random;
            s.samples = 0;
        }),
        ("ops_per_core", |s| s.ops_per_core = 0),
        ("inner_threads", |s| s.inner_threads = 0),
    ];
    check("sweep-rejection", 40, |g, i| {
        let mut spec = random_sweep_spec(g, i);
        let (knob, breaker) = *g.pick(break_one);
        breaker(&mut spec);
        let err = spec
            .validate()
            .expect_err("an out-of-range knob must fail validation");
        assert!(
            err.errors.iter().any(|e| e.contains(knob)),
            "{knob}: error must name the knob, got {err}"
        );
        let err = SweepSpec::from_toml(&spec.to_toml())
            .expect_err("from_toml must re-validate");
        assert!(err.errors.iter().any(|e| e.contains(knob)), "{err}");
    });
}

#[test]
fn sweep_toml_rejects_unknown_keys() {
    // A typo must not silently fall back to a default, and the hint
    // points at the schema doc.
    let err = SweepSpec::from_toml("kernles = \"virtual\"\n").unwrap_err();
    assert!(err.errors[0].contains("unknown key `kernles`"), "{err}");
    assert!(err.to_string().contains("docs/SWEEP.md"), "{err}");
}

// ---------------------------------------------------------------------
// CpuSpec: the O3 pipeline-geometry knobs survive the platform TOML
// round-trip over a seeded walk of the valid knob space; every
// single-knob excursion outside the documented ranges is rejected —
// by `validate()` directly and by the `from_toml` path — naming the
// offending TOML key (docs/O3.md).
// ---------------------------------------------------------------------

use parti_sim::spec::{CpuSpec, SystemSpec};

/// One random point in the *valid* CpuSpec space (docs/O3.md ranges).
fn random_cpu_spec(g: &mut parti_sim::util::prop::Gen) -> CpuSpec {
    CpuSpec {
        width: g.range_usize(1, 16),
        rob_size: g.range_usize(1, 512),
        iq_size: g.range_usize(1, 512),
        lsq_size: g.range_usize(1, 256),
        fetch_buf: g.range_usize(1, 256),
        mshrs: g.range_usize(1, 64),
    }
}

#[test]
fn prop_cpu_spec_toml_roundtrip_is_identity() {
    check("cpu-toml-roundtrip", 64, |g, i| {
        let spec = SystemSpec {
            cpu_spec: random_cpu_spec(g),
            ..SystemSpec::default()
        }
        .named(format!("prop-{i}"), format!("cpu knob walk point {i}"));
        spec.validate()
            .unwrap_or_else(|e| panic!("walk left the valid region: {e}"));
        let toml = spec.to_toml();
        let back = SystemSpec::from_toml(&toml)
            .unwrap_or_else(|e| panic!("roundtrip parse failed: {e}\n{toml}"));
        assert_eq!(spec, back, "TOML roundtrip must be the identity");
        assert_eq!(spec.cpu_spec, back.cpu_spec);
    });
}

#[test]
fn prop_cpu_spec_out_of_range_knobs_are_rejected() {
    // Each case pushes exactly one knob outside its documented range
    // (both below and above); validate() and the serialise-then-parse
    // path must refuse, and the error must name the TOML key.
    let break_one: &[(&str, fn(&mut CpuSpec))] = &[
        ("cpu_width", |c| c.width = 0),
        ("cpu_width", |c| c.width = 17),
        ("cpu_rob_size", |c| c.rob_size = 0),
        ("cpu_rob_size", |c| c.rob_size = 513),
        ("cpu_iq_size", |c| c.iq_size = 0),
        ("cpu_iq_size", |c| c.iq_size = 513),
        ("cpu_lsq_size", |c| c.lsq_size = 0),
        ("cpu_lsq_size", |c| c.lsq_size = 257),
        ("cpu_fetch_buf", |c| c.fetch_buf = 0),
        ("cpu_fetch_buf", |c| c.fetch_buf = 257),
        ("cpu_mshrs", |c| c.mshrs = 0),
        ("cpu_mshrs", |c| c.mshrs = 65),
    ];
    check("cpu-rejection", 48, |g, i| {
        let mut cpu = random_cpu_spec(g);
        let (knob, breaker) = *g.pick(break_one);
        breaker(&mut cpu);
        let spec = SystemSpec { cpu_spec: cpu, ..SystemSpec::default() }
            .named(format!("prop-{i}"), "broken cpu knob");
        let err = spec
            .validate()
            .expect_err("an out-of-range knob must fail validation");
        assert!(
            err.errors.iter().any(|e| e.contains(knob)),
            "{knob}: error must name the knob, got {err}"
        );
        let err = SystemSpec::from_toml(&spec.to_toml())
            .expect_err("from_toml must re-validate");
        assert!(err.errors.iter().any(|e| e.contains(knob)), "{err}");
    });
}

#[test]
fn cpu_knob_typo_is_rejected_with_hint() {
    // A misspelt cpu knob must not silently fall back to the default
    // pipeline geometry, and the hint points at the schema doc.
    let err = SystemSpec::from_toml("cpu_widht = 4\n").unwrap_err();
    assert!(err.errors[0].contains("unknown key `cpu_widht`"), "{err}");
    assert!(err.to_string().contains("PLATFORMS.md"), "{err}");
}

// ---------------------------------------------------------------------
// addrgen: structural invariants for arbitrary parameters.
// ---------------------------------------------------------------------

#[test]
fn prop_addrgen_structural_invariants() {
    check("addrgen-invariants", 40, |g, _| {
        let p = AddrGenParams {
            seed: g.u64(),
            core_id: g.range_u64(0, 127),
            offset: g.range_u64(0, 1 << 20),
            private_size: 1 << g.range_usize(10, 22),
            shared_size: 1 << g.range_usize(16, 25),
            stride: g.range_u64(1, 64),
            share_milli: g.range_u64(0, 1000),
            random_milli: g.range_u64(0, 1000),
            store_milli: g.range_u64(0, 1000),
            compute_base: g.range_u64(0, 16),
            compute_spread: g.range_u64(1, 16),
            ..Default::default()
        };
        let ops = addrgen(&p, 512);
        for o in &ops {
            assert_eq!(o.addr % 64, 0, "line alignment");
            let in_priv = o.addr >= p.private_base
                && o.addr < p.private_base + p.private_size;
            let in_shared = o.addr >= p.shared_base
                && o.addr < p.shared_base + p.shared_size;
            assert!(in_priv || in_shared, "address outside both regions");
            assert!(o.gap as u64 >= p.compute_base);
            assert!((o.gap as u64) < p.compute_base + p.compute_spread.max(1));
        }
        if p.share_milli == 0 {
            assert!(ops.iter().all(|o| o.addr < p.shared_base));
        }
        if p.share_milli == 1000 {
            assert!(ops.iter().all(|o| o.addr >= p.shared_base));
        }
    });
}
