//! Gates for the synthetic-traffic engine (docs/TRAFFIC.md).
//!
//! The engine's contract has three legs, and each gets an adversarial
//! gate here:
//!
//! * **Determinism** — every pattern's elaboration is a pure function of
//!   its `TrafficSpec`, so on every preset topology the threaded kernel
//!   must stay bit-identical to the virtual reference across
//!   `--threads {1,2,8}` × `--steal` × `--io-milli {0,5}`, including the
//!   inbox/crossbar staging counters and the new
//!   offered/accepted/retries traffic counters.
//! * **Shape** — the patterns must actually produce their advertised
//!   contention structure: the hotspot scenario concentrates per-line
//!   serialisation (`hnf.requeued`) and snoop traffic at the HN-F well
//!   beyond uniform-random, and the transpose exchange covers far more
//!   mesh station hops than the neighbor halo exchange.
//! * **Repeatability** — re-elaborating and re-running the same scenario
//!   is bit-identical; changing only the spec's seed moves the traces.

use parti_sim::config::{Mode, RunConfig};
use parti_sim::harness::{make_workload, run_with_workload};
use parti_sim::sched::QuantumPolicy;
use parti_sim::sim::time::NS;
use parti_sim::spec::platforms;
use parti_sim::spec::traffic::{scenario, scenarios};
use parti_sim::stats::Summary;
use parti_sim::workload::apps::{PRIVATE_BASE, PRIVATE_SPAN};
use parti_sim::workload::traffic::mesh_hops;

mod common;
use common::{assert_bit_identical, assert_threaded_matches, FULL_MATRIX};

/// A traffic run on `preset`, sized so the whole pattern × preset matrix
/// stays test-suite-fast while `--io-milli 5` (one IO access per 200
/// ops) still fires on every core — the same geometry as
/// tests/xbar_arb.rs.
fn traffic_cfg(preset: &str, scenario_name: &str, io_milli: u64) -> RunConfig {
    let spec = platforms::preset(preset).unwrap();
    let mut cfg = RunConfig::for_spec(&spec);
    cfg.traffic = Some(scenario_name.to_string());
    cfg.ops_per_core = match preset {
        "fig4-2" => 768,
        "ring-16" => 320,
        _ => 224,
    };
    cfg.mode = Mode::Virtual;
    cfg.quantum = 8 * NS;
    cfg.quantum_policy = QuantumPolicy::Hybrid { max_leap: 4 };
    cfg.system.io_milli = io_milli;
    cfg
}

/// The tentpole matrix for one preset: every pattern × `--io-milli
/// {0,5}` × the full `--threads`/`--steal` grid, gated on full
/// bit-identity against the virtual reference. Split per preset so the
/// three presets run on separate test threads.
fn preset_matrix(preset: &str) {
    for t in scenarios() {
        for io_milli in [0u64, 5] {
            let vcfg = traffic_cfg(preset, &t.name, io_milli);
            let w = make_workload(&vcfg).unwrap();
            let reference = run_with_workload(&vcfg, &w).unwrap();
            let what = format!("{preset}/{}/io={io_milli}", t.name);
            assert!(reference.events > 0, "{what}: empty run");
            assert_eq!(
                reference.pdes.traffic_offered,
                (vcfg.system.cores * vcfg.ops_per_core) as u64,
                "{what}: offered load must be the full trace"
            );
            assert_eq!(
                reference.pdes.traffic_accepted,
                reference.pdes.traffic_offered,
                "{what}: a completed run accepts every offered op"
            );
            assert_eq!(
                reference.pdes.traffic_retries as f64,
                reference.stats.sum_suffix(".lsq_stalls"),
                "{what}: retries must mirror the per-core LSQ stalls"
            );
            assert!(
                reference.pdes.inbox_staged > 0,
                "{what}: sharing traffic must exercise the inbox handoff"
            );
            if io_milli > 0 {
                assert!(
                    reference.pdes.xbar_staged > 0,
                    "{what}: io_milli must exercise the crossbar"
                );
            } else {
                assert_eq!(reference.pdes.xbar_staged, 0, "{what}: inert");
            }
            assert_threaded_matches(&reference, &vcfg, &w, FULL_MATRIX, &what);
        }
    }
}

#[test]
fn fig4_2_every_pattern_threaded_matches_virtual() {
    preset_matrix("fig4-2");
}

#[test]
fn ring_16_every_pattern_threaded_matches_virtual() {
    preset_matrix("ring-16");
}

#[test]
fn mesh_64_every_pattern_threaded_matches_virtual() {
    preset_matrix("mesh-64");
}

#[test]
fn hotspot_concentrates_contention_at_the_hnf() {
    // ring-16 (cores < 28, so no private/shared address aliasing): the
    // hotspot scenario hammers 8 shared lines from 16 cores, which must
    // show up as per-line transaction serialisation (`requeued`) and
    // multi-sharer snoop traffic at the HN-F, both well beyond what the
    // uniform-random scenario's scattered remote accesses produce.
    let mut results = Vec::new();
    for name in ["uniform-random", "hotspot"] {
        let cfg = traffic_cfg("ring-16", name, 0);
        let w = make_workload(&cfg).unwrap();
        results.push(run_with_workload(&cfg, &w).unwrap());
    }
    let (uni, hot) = (&results[0], &results[1]);
    let stat = |r: &parti_sim::pdes::RunResult, n: &str| {
        r.stats.get(n).unwrap_or(0.0)
    };
    assert!(
        stat(hot, "hnf.requeued") > stat(uni, "hnf.requeued"),
        "hotspot must serialise on the hot lines: requeued {} vs {}",
        stat(hot, "hnf.requeued"),
        stat(uni, "hnf.requeued")
    );
    assert!(
        stat(hot, "hnf.snoops_sent") > stat(uni, "hnf.snoops_sent"),
        "hot-line stores must out-snoop uniform remote traffic: {} vs {}",
        stat(hot, "hnf.snoops_sent"),
        stat(uni, "hnf.snoops_sent")
    );
}

#[test]
fn transpose_on_mesh_crosses_more_hops_than_neighbor() {
    // All coherence traffic is HN-F-mediated (no direct core-to-core
    // messages), so the fabric cannot distinguish *which* core owns a
    // remote line — the hop structure the two patterns advertise lives
    // in the requester→owner geometry of the elaborated traces. On the
    // 8-wide mesh-64, the transpose exchange must cover far more
    // station hops than the one-step halo exchange. The two scenarios
    // share seed and sharing degree, so op k of core c is remote in
    // both or neither and the comparison is op-for-op.
    let cols = 8;
    let mut sums = Vec::new();
    for name in ["transpose", "neighbor"] {
        let cfg = traffic_cfg("mesh-64", name, 0);
        let w = make_workload(&cfg).unwrap();
        let mut hops = 0usize;
        for (c, trace) in w.cores.iter().enumerate() {
            for &a in &trace.addr {
                let owner = ((a - PRIVATE_BASE) / PRIVATE_SPAN) as usize;
                if owner != c && owner < w.n_cores() {
                    hops += mesh_hops(cols, c, owner);
                }
            }
        }
        sums.push(hops);
    }
    assert!(
        sums[0] > 2 * sums[1],
        "transpose ({}) must cross well over twice the mesh hops of \
         neighbor ({})",
        sums[0],
        sums[1]
    );
}

#[test]
fn same_scenario_is_repeat_deterministic_and_seed_moves_it() {
    let cfg = traffic_cfg("ring-16", "hotspot", 0);
    let w1 = make_workload(&cfg).unwrap();
    let a = run_with_workload(&cfg, &w1).unwrap();
    // Independent re-elaboration + re-run: bit-identical.
    let w2 = make_workload(&cfg).unwrap();
    let b = run_with_workload(&cfg, &w2).unwrap();
    assert_bit_identical(&a, &b, "re-elaborated scenario");
    // Only the seed changes, via the TOML file path (the other half of
    // `--traffic`): the traces must move.
    let mut spec = scenario("hotspot").unwrap();
    spec.seed += 1;
    let path = std::env::temp_dir().join("parti_sim_traffic_seed_test.toml");
    std::fs::write(&path, spec.to_toml()).unwrap();
    let mut fcfg = cfg.clone();
    fcfg.traffic = Some(path.to_str().unwrap().to_string());
    let w3 = make_workload(&fcfg).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_ne!(
        w1.cores[0].addr, w3.cores[0].addr,
        "reseeding must change the traces"
    );
}

#[test]
fn bursty_phase_reports_its_phase_structure() {
    let cfg = traffic_cfg("fig4-2", "bursty-phase", 0);
    let w = make_workload(&cfg).unwrap();
    assert_eq!(w.phases(), 3, "768 ops / 256-op phases");
    let r = run_with_workload(&cfg, &w).unwrap();
    assert_eq!(r.pdes.traffic_phases, 3);
    // The counters survive into the summary and its JSON export.
    let s = Summary::from_result(&r);
    assert_eq!(s.traffic_phases, 3);
    assert_eq!(s.traffic_offered, r.pdes.traffic_offered);
    let json = s.to_json();
    for key in [
        "traffic_offered",
        "traffic_accepted",
        "traffic_retries",
        "traffic_phases",
    ] {
        assert!(json.contains(key), "summary JSON must carry {key}");
    }
}

#[test]
fn unphased_patterns_report_zero_phases() {
    let cfg = traffic_cfg("fig4-2", "uniform-random", 0);
    let w = make_workload(&cfg).unwrap();
    assert_eq!(w.phases(), 0);
    let r = run_with_workload(&cfg, &w).unwrap();
    assert_eq!(r.pdes.traffic_phases, 0);
}
