//! Offline stand-in for the `anyhow` crate, exposing exactly the subset
//! parti-sim uses: [`Error`], [`Result`], the [`Context`] extension trait
//! and the `anyhow!` / `ensure!` / `bail!` macros.
//!
//! The build environment has no registry access, so this path dependency
//! keeps the crate buildable; swapping in the real `anyhow` is a one-line
//! Cargo.toml change and requires no source edits.

use std::fmt;

/// A string-backed error value. Like the real `anyhow::Error`, it
/// deliberately does **not** implement `std::error::Error`, which is what
/// makes the blanket `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context line.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension adding `.context(...)` to results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a formatted message, or any
/// `Display` value — same arm structure as the real `anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::other("boom")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("x = {x}");
        assert_eq!(b.to_string(), "x = 7");
        let c = anyhow!("x = {}", x);
        assert_eq!(c.to_string(), "x = 7");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading artifact").unwrap_err();
        assert_eq!(e.to_string(), "loading artifact: boom");
        let n: Option<u32> = None;
        assert_eq!(
            n.context("missing").unwrap_err().to_string(),
            "missing"
        );
    }

    #[test]
    fn ensure_and_bail() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {}", 42);
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "wanted 42");
        fn g() -> Result<()> {
            bail!("nope")
        }
        assert!(g().is_err());
    }
}
