//! Offline stand-in for the `rustc-hash` crate: `FxHashMap` / `FxHashSet`
//! over a fast non-cryptographic multiply-rotate hasher in the Fx style.
//!
//! The build environment has no registry access, so this path dependency
//! keeps the crate buildable; swapping in the real `rustc-hash` is a
//! one-line Cargo.toml change and requires no source edits.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;
const ROTATE: u32 = 5;

/// Multiply-rotate hasher in the Fx style: one rotate, one xor and one
/// multiply per word. Not DoS-resistant — keys here are dense internal
/// ids (seqs, addresses, component ids), never attacker-controlled.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(ROTATE) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, i as u32 * 2);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&37), Some(&74));
        let mut s: FxHashSet<u64> = FxHashSet::default();
        assert!(s.insert(5));
        assert!(!s.insert(5));
        assert!(s.remove(&5));
        assert!(!s.remove(&5));
    }

    #[test]
    fn hashing_is_deterministic_and_spreads() {
        let h = |x: u64| {
            let mut f = FxHasher::default();
            f.write_u64(x);
            f.finish()
        };
        assert_eq!(h(42), h(42));
        assert_ne!(h(1), h(2));
        // Nearby keys should not collide in the low bits (bucket index).
        let low: FxHashSet<u64> = (0..64).map(|i| h(i) & 0x3f).collect();
        assert!(low.len() > 16, "low bits too clustered: {}", low.len());
    }

    #[test]
    fn write_bytes_covers_remainder() {
        let mut a = FxHasher::default();
        a.write(b"hello world, 13");
        let mut b = FxHasher::default();
        b.write(b"hello world, 14");
        assert_ne!(a.finish(), b.finish());
    }
}
