//! The SimObject abstraction and the scheduling context handed to event
//! handlers.
//!
//! Every event targets exactly one [`Component`]; intra-tick interactions
//! between components are expressed as same-tick events with a later
//! sub-priority — semantically identical to gem5's synchronous call chains,
//! but free of aliased mutable borrows.

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::sched::{EventHandle, SchedQueue, Scheduler};
use crate::sim::event::{prio, EventKind};
use crate::sim::ids::{CompId, DomainId};
use crate::sim::shared::SharedState;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

/// A hardware model living in exactly one time domain.
pub trait Component: Send {
    /// Handle one event. `ctx.now()` is the event's tick.
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx);

    /// Hierarchical instance name (e.g. `"cpu3.l1d"`).
    fn name(&self) -> &str;

    /// Schedule initial events. Called once before the simulation starts.
    fn init(&mut self, _ctx: &mut Ctx) {}

    /// Quantum-border hook of the border-staged protocols: under the
    /// border-ordered inbox handoff (`--inbox-order border`, DESIGN.md
    /// §6) Ruby consumers merge the cross-domain deliveries staged for
    /// them during the closed window into their message buffers — in
    /// canonical `(arrival, sender_domain, seq)` order — and arm the
    /// consumer wakeup; under the border-staged crossbar arbitration
    /// (`--xbar-arb border`, docs/XBAR.md) the
    /// [`crate::xbar::XbarArbiter`] grants the window's staged layer
    /// requests in canonical `(request_tick, sender_domain, seq)` order.
    ///
    /// Called by the windowed kernels inside the quiescent span of the
    /// border protocol: after the freeze barrier (no producer is running)
    /// and before the domain publishes its post-drain `next_tick`, so
    /// merged wakeups count towards the horizon and staged traffic can
    /// never be dropped by a quiescent verdict. `ctx.now()` is the border
    /// tick. Components without message buffers keep the no-op default.
    fn border_merge(&mut self, _ctx: &mut Ctx) {}

    /// Checkpoint hook (the producer half, mirroring [`Self::border_merge`]
    /// in placement): serialize every field that can differ from the
    /// freshly-elaborated state — in-flight transactions, cache arrays,
    /// message buffers, trace cursors, deterministic counters. Called by
    /// the checkpoint writer at a quantum border inside the quiescent span
    /// (after `border_merge`, before the window plan), so no producer is
    /// running and staged cross-domain traffic has already been merged.
    /// Map-like state must be written sorted by key so the bytes are
    /// invariant to the producing kernel (docs/CHECKPOINT.md).
    ///
    /// Stateless components keep the no-op default; restore then verifies
    /// the payload is empty, so a model that grows state without updating
    /// both hooks fails loudly instead of resuming skewed.
    fn save_state(&self, _out: &mut StateWriter) {}

    /// Checkpoint hook (the restore half): overwrite this freshly-built
    /// component's state from bytes produced by [`Self::save_state`]. The
    /// restored machine skips `init` — pending events come back through
    /// the domain queues — so restore must leave the component exactly as
    /// the producer's quiescent border left it.
    fn restore_state(
        &mut self,
        _src: &mut StateReader,
    ) -> Result<(), CkptError> {
        Ok(())
    }

    /// Dump statistics.
    fn stats(&self, _out: &mut StatSink) {}
}

/// Scheduling context for one event execution.
///
/// Routing rule (paper §3.1): events for the local domain go straight into
/// the local scheduler queue; events for a foreign domain are pushed into
/// that domain's mailbox, postponed to the next quantum border when their
/// target time falls inside the current window (accounted as `t_pp`).
pub struct Ctx<'a> {
    now: Tick,
    domain: DomainId,
    /// End of the current quantum window (`Tick::MAX` when not windowed).
    window_end: Tick,
    eq: &'a mut SchedQueue,
    shared: &'a SharedState,
    self_id: CompId,
}

impl<'a> Ctx<'a> {
    pub fn new(
        now: Tick,
        domain: DomainId,
        window_end: Tick,
        eq: &'a mut SchedQueue,
        shared: &'a SharedState,
        self_id: CompId,
    ) -> Self {
        Ctx { now, domain, window_end, eq, shared, self_id }
    }

    #[inline]
    pub fn now(&self) -> Tick {
        self.now
    }

    #[inline]
    pub fn self_id(&self) -> CompId {
        self.self_id
    }

    #[inline]
    pub fn domain(&self) -> DomainId {
        self.domain
    }

    #[inline]
    pub fn shared(&self) -> &SharedState {
        self.shared
    }

    /// Schedule at an absolute tick with an explicit priority.
    pub fn schedule_abs_prio(
        &mut self,
        tick: Tick,
        target: CompId,
        kind: EventKind,
        prio: u8,
    ) -> Option<EventHandle> {
        let tick = tick.max(self.now);
        let tdom = self.shared.domain_of(target);
        if tdom == self.domain {
            return Some(self.eq.schedule(tick, prio, target, kind));
        }
        // Inter-domain scheduling (§3.1): exact target time is unknown to
        // us; times inside the current window are postponed to the border.
        use std::sync::atomic::Ordering::Relaxed;
        self.shared.pdes.cross_events.fetch_add(1, Relaxed);
        let eff = if tick < self.window_end {
            self.shared.pdes.postponed.fetch_add(1, Relaxed);
            self.shared
                .pdes
                .tpp_sum
                .fetch_add(self.window_end - tick, Relaxed);
            self.window_end
        } else {
            tick
        };
        self.shared.injectors[tdom.index()].push(crate::sim::event::Event {
            tick: eff,
            prio,
            // Canonical (sender domain, send order) merge key: makes the
            // border drain-sort total, so same-(tick, prio, target)
            // deliveries (e.g. the IO crossbar's packets) merge in
            // simulation order, not host push order. The queue re-assigns
            // its own seq on insert.
            seq: self.shared.next_injector_seq(self.domain),
            target,
            kind,
        });
        None
    }

    /// Schedule at an absolute tick (default priority).
    pub fn schedule_abs(
        &mut self,
        tick: Tick,
        target: CompId,
        kind: EventKind,
    ) -> Option<EventHandle> {
        self.schedule_abs_prio(tick, target, kind, prio::DEFAULT)
    }

    /// Schedule after a relative delay (default priority).
    pub fn schedule(
        &mut self,
        delay: Tick,
        target: CompId,
        kind: EventKind,
    ) -> Option<EventHandle> {
        self.schedule_abs(self.now + delay, target, kind)
    }

    /// Schedule on self after a delay.
    pub fn schedule_self(
        &mut self,
        delay: Tick,
        kind: EventKind,
    ) -> Option<EventHandle> {
        self.schedule(delay, self.self_id, kind)
    }

    /// Cancel a previously scheduled local event.
    pub fn deschedule(&mut self, h: EventHandle) {
        self.eq.deschedule(h);
    }

    /// True when this run uses the deterministic border-ordered handoff
    /// (`--inbox-order border`) on a *windowed* kernel. The serial kernel
    /// has no quantum (`SharedState::quantum == Tick::MAX`) and is
    /// inherently deterministic, so it always reports `false`.
    pub fn border_ordered(&self) -> bool {
        self.shared.policy.inbox_order
            == crate::sched::InboxOrder::Border
            && self.shared.quantum < Tick::MAX
    }

    /// True when this run arbitrates IO-crossbar layers at quantum borders
    /// (`--xbar-arb border`, docs/XBAR.md) on a *windowed* kernel. Like
    /// [`Ctx::border_ordered`], the serial kernel has no quantum and its
    /// single-threaded `try_lock` path is already deterministic, so it
    /// always reports `false`.
    pub fn xbar_border(&self) -> bool {
        self.shared.policy.xbar_arb == crate::sched::XbarArb::Border
            && self.shared.quantum < Tick::MAX
    }

    /// Schedule on self applying the full cross-domain scheduling rule
    /// even though the target is local: under the border-ordered handoff
    /// the event goes through this domain's *own injector* — a tick
    /// inside the current window lands on the border, and the event is
    /// re-sequenced by the border drain-sort like every foreign-domain
    /// observer's.
    ///
    /// Used where one simulated rendezvous has both local and foreign
    /// observers and determinism requires them to resume symmetrically —
    /// today the workload-barrier release (`cpu/timing.rs`): the waiters
    /// are released through border-postponed cross-domain events, so the
    /// last arriver must resume at the same effective tick *and* with the
    /// same same-`(tick, prio)` ordering relative to border-merged
    /// events, whichever core the host happened to run last. A direct
    /// local schedule would assign the queue sequence mid-window — before
    /// the border merges — while the waiters' events are sequenced after
    /// them, so tie-breaking would depend on which core completed the
    /// rendezvous (docs/DETERMINISM.md). Outside border mode (or on the
    /// serial kernel) this is an exact local schedule.
    pub fn schedule_self_postponed(&mut self, tick: Tick, kind: EventKind) {
        let tick = tick.max(self.now);
        if self.border_ordered() {
            let eff =
                if tick < self.window_end { self.window_end } else { tick };
            self.shared.injectors[self.domain.index()].push(
                crate::sim::event::Event {
                    tick: eff,
                    prio: prio::DEFAULT,
                    // Same canonical merge key as a cross-domain push, so
                    // the release is ordered like every foreign
                    // observer's event at the border drain.
                    seq: self.shared.next_injector_seq(self.domain),
                    target: self.self_id,
                    kind,
                },
            );
        } else {
            self.eq.schedule(tick, prio::DEFAULT, self.self_id, kind);
        }
    }

    /// Report this core's workload as finished.
    pub fn core_done(&self) {
        self.shared.core_done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::QueueKind;
    use crate::sim::ids::DomainId;

    fn shared_two_domains() -> SharedState {
        // comp0 -> domain0, comp1 -> domain1
        SharedState::new(
            vec![(DomainId(0), 0), (DomainId(1), 0)],
            2,
            16_000,
            1,
        )
    }

    fn kinds() -> [QueueKind; 2] {
        [QueueKind::Heap, QueueKind::Bucket]
    }

    #[test]
    fn local_schedule_goes_to_eq() {
        for kind in kinds() {
            let shared = shared_two_domains();
            let mut eq = SchedQueue::new(kind);
            let mut ctx =
                Ctx::new(100, DomainId(0), 16_000, &mut eq, &shared, CompId(0));
            let h = ctx.schedule(50, CompId(0), EventKind::CpuTick);
            assert!(h.is_some());
            assert_eq!(eq.pop().unwrap().tick, 150);
        }
    }

    #[test]
    fn cross_domain_postpones_to_border() {
        let shared = shared_two_domains();
        let mut eq = SchedQueue::default();
        let mut ctx =
            Ctx::new(100, DomainId(0), 16_000, &mut eq, &shared, CompId(0));
        ctx.schedule(50, CompId(1), EventKind::CpuTick);
        assert!(eq.pop().is_none(), "must not land in local queue");
        let drained = shared.injectors[1].drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].tick, 16_000, "postponed to quantum border");
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(shared.pdes.postponed.load(Relaxed), 1);
        assert_eq!(shared.pdes.tpp_sum.load(Relaxed), 16_000 - 150);
    }

    #[test]
    fn cross_domain_beyond_border_keeps_time() {
        let shared = shared_two_domains();
        let mut eq = SchedQueue::default();
        let mut ctx =
            Ctx::new(100, DomainId(0), 16_000, &mut eq, &shared, CompId(0));
        ctx.schedule(20_000, CompId(1), EventKind::CpuTick);
        let drained = shared.injectors[1].drain();
        assert_eq!(drained[0].tick, 20_100, "beyond border: exact time kept");
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(shared.pdes.postponed.load(Relaxed), 0);
    }

    #[test]
    fn self_postponed_goes_through_own_injector_when_windowed() {
        // Windowed + border order (the defaults): the event takes the
        // injector channel — inside-window ticks land on the border,
        // beyond-window ticks keep their time, and nothing reaches the
        // local queue until the border drain re-sequences it.
        let shared = shared_two_domains();
        let mut eq = SchedQueue::default();
        let mut ctx =
            Ctx::new(100, DomainId(0), 16_000, &mut eq, &shared, CompId(0));
        assert!(ctx.border_ordered());
        ctx.schedule_self_postponed(150, EventKind::WlBarrierRelease);
        ctx.schedule_self_postponed(20_000, EventKind::WlBarrierRelease);
        assert!(eq.pop().is_none(), "must not land in the local queue");
        let drained = shared.injectors[0].drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].tick, 16_000, "postponed to the border");
        assert_eq!(drained[1].tick, 20_000, "beyond border: exact time");
        assert_eq!(drained[0].target, CompId(0), "self-targeted");

        // Serial (quantum == Tick::MAX): exact local schedule.
        let serial = SharedState::new(
            vec![(DomainId(0), 0), (DomainId(0), 1)],
            1,
            Tick::MAX,
            1,
        );
        let mut eq = SchedQueue::default();
        let mut ctx =
            Ctx::new(100, DomainId(0), Tick::MAX, &mut eq, &serial, CompId(0));
        assert!(!ctx.border_ordered());
        ctx.schedule_self_postponed(150, EventKind::WlBarrierRelease);
        assert_eq!(eq.pop().unwrap().tick, 150);
    }

    #[test]
    fn past_schedule_clamps_to_now() {
        for kind in kinds() {
            let shared = shared_two_domains();
            let mut eq = SchedQueue::new(kind);
            let mut ctx =
                Ctx::new(100, DomainId(0), 16_000, &mut eq, &shared, CompId(0));
            ctx.schedule_abs(10, CompId(0), EventKind::CpuTick);
            assert_eq!(eq.pop().unwrap().tick, 100);
        }
    }
}
