//! Simulation time base.
//!
//! Like gem5, the simulator counts *ticks*; one tick is one picosecond.
//! All model latencies (Table 2 of the paper) are expressed in ns and
//! converted with the constants below.

/// Simulated time in picoseconds.
pub type Tick = u64;

/// One picosecond.
pub const PS: Tick = 1;
/// One nanosecond.
pub const NS: Tick = 1_000;
/// One microsecond.
pub const US: Tick = 1_000_000;
/// One millisecond.
pub const MS: Tick = 1_000_000_000;

/// A clock with a fixed period, converting cycles to ticks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Clock {
    period: Tick,
}

impl Clock {
    /// Clock from a frequency in MHz (2 GHz CPU -> `Clock::from_mhz(2000)`).
    pub fn from_mhz(mhz: u64) -> Self {
        assert!(mhz > 0, "clock frequency must be positive");
        Clock { period: 1_000_000 / mhz }
    }

    /// Clock period in ticks.
    #[inline]
    pub fn period(&self) -> Tick {
        self.period
    }

    /// Convert a cycle count to ticks.
    #[inline]
    pub fn cycles(&self, n: u64) -> Tick {
        n * self.period
    }

    /// Cycles elapsed at time `t` (rounded down).
    #[inline]
    pub fn ticks_to_cycles(&self, t: Tick) -> u64 {
        t / self.period
    }

    /// Next edge at or after `t`.
    #[inline]
    pub fn next_edge(&self, t: Tick) -> Tick {
        t.div_ceil(self.period) * self.period
    }
}

/// Convert ticks to (fractional) nanoseconds for reporting.
pub fn ticks_to_ns(t: Tick) -> f64 {
    t as f64 / NS as f64
}

/// Convert ticks to seconds for reporting.
pub fn ticks_to_seconds(t: Tick) -> f64 {
    t as f64 / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_2ghz_period_is_500ps() {
        let c = Clock::from_mhz(2000);
        assert_eq!(c.period(), 500);
        assert_eq!(c.cycles(4), 2 * NS);
    }

    #[test]
    fn next_edge_rounds_up() {
        let c = Clock::from_mhz(1000); // 1ns period
        assert_eq!(c.next_edge(0), 0);
        assert_eq!(c.next_edge(1), NS);
        assert_eq!(c.next_edge(NS), NS);
        assert_eq!(c.next_edge(NS + 1), 2 * NS);
    }

    #[test]
    fn unit_ratios() {
        assert_eq!(NS, 1000 * PS);
        assert_eq!(US, 1000 * NS);
        assert_eq!(MS, 1000 * US);
    }

    #[test]
    fn ticks_to_cycles_floor() {
        let c = Clock::from_mhz(2000);
        assert_eq!(c.ticks_to_cycles(499), 0);
        assert_eq!(c.ticks_to_cycles(500), 1);
        assert_eq!(c.ticks_to_cycles(1999), 3);
    }
}
