//! Events and their total order.
//!
//! An event is executed at `(tick, prio, seq)` order against a single target
//! component. `seq` is a per-queue monotonic counter, so the serial kernel is
//! fully deterministic; `prio` mirrors gem5's event priorities (lower runs
//! first at equal tick).

use crate::proto::Packet;
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

/// gem5-like priorities (subset). Lower value runs first within a tick.
pub mod prio {
    /// Quantum-barrier bookkeeping (must run before models at the border).
    pub const BARRIER: u8 = 0;
    /// Default model priority.
    pub const DEFAULT: u8 = 50;
    /// CPU ticks run after message deliveries at the same tick.
    pub const CPU: u8 = 60;
    /// Statistic/teardown events run last.
    pub const STAT: u8 = 200;
}

/// What the target component should do.
///
/// Ruby messages do NOT travel in events: they sit in
/// [`crate::ruby::inbox::Inbox`]es and only the `ConsumerWakeup` is
/// scheduled, exactly like gem5's Consumer model (§3.4).
#[derive(Clone, Debug)]
pub enum EventKind {
    /// Advance a CPU model's state machine.
    CpuTick,
    /// Timing-protocol request delivery (classic protocol, §3.3).
    MemReq { pkt: Packet },
    /// Timing-protocol response delivery.
    MemResp { pkt: Packet },
    /// A responder that previously rejected a request signals readiness.
    RetryReq,
    /// Ruby consumer wakeup: drain ready messages from the inbox.
    ConsumerWakeup,
    /// IO-crossbar layer release (paper §4.3).
    XbarRelease { layer: usize },
    /// DRAM controller internal tick (queue service).
    DramTick,
    /// Workload barrier released: all cores arrived, resume execution.
    WlBarrierRelease,
    /// Component-private event with a small payload.
    Generic { code: u32, arg: u64 },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Event {
    pub tick: Tick,
    pub prio: u8,
    /// Per-queue monotonic sequence number; tie-breaker making execution
    /// order total and deterministic. While an event is in flight through
    /// a cross-domain [`crate::sched::Mailbox`] this field instead holds
    /// the canonical `(sender_domain, send order)` merge key
    /// ([`crate::sim::shared::SharedState::next_injector_seq`]); the
    /// border drain sorts by it, then the queue re-sequences on insert.
    pub seq: u64,
    pub target: CompId,
    pub kind: EventKind,
}

impl Event {
    #[inline]
    pub fn key(&self) -> (Tick, u8, u64) {
        (self.tick, self.prio, self.seq)
    }
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(tick: Tick, prio: u8, seq: u64) -> Event {
        Event { tick, prio, seq, target: CompId(0), kind: EventKind::CpuTick }
    }

    #[test]
    fn order_by_tick_then_prio_then_seq() {
        assert!(ev(1, 0, 9) < ev(2, 0, 0));
        assert!(ev(5, prio::BARRIER, 9) < ev(5, prio::DEFAULT, 0));
        assert!(ev(5, 10, 1) < ev(5, 10, 2));
    }

    #[test]
    fn eq_is_key_based() {
        assert_eq!(ev(3, 1, 7), ev(3, 1, 7));
        assert_ne!(ev(3, 1, 7), ev(3, 1, 8));
    }
}
