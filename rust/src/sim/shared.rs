//! State shared between time domains.
//!
//! Everything a model may touch from *any* domain thread lives here:
//! the component→domain map, the per-domain event mailboxes (the
//! inter-domain scheduling mechanism of §3.1, lock-free — see
//! [`crate::sched::Mailbox`]), parallelisation-artefact counters (t_pp),
//! the workload barrier device and the global stop flag.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::sched::{Mailbox, RunPolicy};
use crate::sim::ids::{CompId, DomainId};
use crate::sim::time::Tick;

/// Software barrier executed by the simulated cores (`Op::Barrier`).
///
/// The last arriving core releases all waiters; releases scheduled into
/// foreign domains are postponed to the next quantum border like any other
/// cross-domain event.
#[derive(Default)]
pub struct WlBarrier {
    pub state: Mutex<WlBarrierState>,
}

#[derive(Default)]
pub struct WlBarrierState {
    pub participants: u32,
    pub waiting: Vec<CompId>,
    /// Latest local arrival tick in the current generation.
    pub max_arrival: Tick,
    pub generation: u64,
}

pub enum BarrierOutcome {
    /// Caller must wait for a `WlBarrierRelease` event.
    Wait,
    /// Caller was last: release these waiters at `release_at`.
    Release { waiters: Vec<CompId>, release_at: Tick },
}

impl WlBarrier {
    pub fn arrive(&self, who: CompId, now: Tick) -> BarrierOutcome {
        let mut st = self.state.lock().unwrap();
        st.max_arrival = st.max_arrival.max(now);
        if st.waiting.len() as u32 + 1 == st.participants {
            let waiters = std::mem::take(&mut st.waiting);
            let at = st.max_arrival;
            st.max_arrival = 0;
            st.generation += 1;
            BarrierOutcome::Release { waiters, release_at: at }
        } else {
            st.waiting.push(who);
            BarrierOutcome::Wait
        }
    }
}

/// Counters for the parallelisation timing artefacts.
#[derive(Default)]
pub struct PdesStats {
    /// Number of cross-domain scheduled events.
    pub cross_events: AtomicU64,
    /// Number of cross-domain events postponed to the quantum border.
    pub postponed: AtomicU64,
    /// Sum of postponement delays t_pp (ticks).
    pub tpp_sum: AtomicU64,
    /// Quantum barriers executed.
    pub barriers: AtomicU64,
    /// Dead quantum windows skipped by the adaptive window policy
    /// (deterministic: a pure function of the simulation content).
    pub quanta_skipped: AtomicU64,
    /// Window claims executed by a thread other than the domain's home
    /// thread (threaded kernel, `--steal`; host-timing dependent).
    pub steals: AtomicU64,
    /// Events executed inside stolen window claims (host-timing dependent).
    pub stolen_events: AtomicU64,
    /// Cross-domain Ruby deliveries staged by the border-ordered handoff
    /// (`--inbox-order border`; deterministic — one per cross send).
    pub inbox_staged: AtomicU64,
    /// Staged deliveries whose canonical merge position differed from
    /// their host staging order — the reordering the handoff neutralised
    /// (host-timing dependent on the threaded kernel, like `steals`).
    pub inbox_reordered: AtomicU64,
    /// Host nanoseconds spent in the border-staged merge hooks — the
    /// inbox merges plus, when `--xbar-arb border`, the crossbar grant
    /// pass (host-timing dependent; divide by `barriers` for the
    /// per-window cost). Zero only when both staging protocols are
    /// `host`.
    pub inbox_merge_ns: AtomicU64,
    /// IO-crossbar layer requests staged by the border-staged arbitration
    /// (`--xbar-arb border`; deterministic — one per IO request).
    pub xbar_staged: AtomicU64,
    /// Border grant decisions deferred because the layer was still
    /// occupied (`--xbar-arb border`; deterministic — a request that
    /// waits k borders counts k times).
    pub xbar_deferred_grants: AtomicU64,
    /// Memory ops the workload offers: total trace ops elaborated,
    /// seeded by the system builder (deterministic — a pure function of
    /// the workload).
    pub traffic_offered: AtomicU64,
    /// Offered ops the memory system accepted to completion (committed
    /// data ops, summed over timing cores; deterministic). Falls short
    /// of `traffic_offered` exactly when a saturating traffic pattern
    /// is truncated (e.g. by `max_ticks`) — the backpressure signal.
    pub traffic_accepted: AtomicU64,
    /// Issue attempts a core retried because its LSQ was full — offered
    /// load the memory system pushed back on (deterministic).
    pub traffic_retries: AtomicU64,
    /// Traffic phases of the longest core trace (`bursty-phase`
    /// workloads; 0 = unphased; deterministic).
    pub traffic_phases: AtomicU64,
    /// Ops the O3 pipelines issued to the memory system or forwarded
    /// in-LSQ (deterministic; zero under Minor).
    pub issued: AtomicU64,
    /// Fetched-but-undispatched ops the O3 pipelines squashed at
    /// workload-barrier boundaries (deterministic; zero under Minor).
    pub squashed: AtomicU64,
    /// O3 dispatch stalls on a full reorder buffer (deterministic).
    pub rob_full_stalls: AtomicU64,
    /// O3 dispatch stalls on a full issue queue (deterministic).
    pub iq_full_stalls: AtomicU64,
    /// Time-integrated ROB occupancy, summed over O3 cores: Σ entries ×
    /// ticks (deterministic; divide by `sim_ticks × cores` for the mean).
    pub rob_occupancy_sum: AtomicU64,
    /// `--profile`: host ns spent executing window claims, summed over
    /// threads (host-timing dependent; zero when profiling is off).
    pub prof_window_ns: AtomicU64,
    /// `--profile`: host ns waiting at the freeze barrier (phase 1),
    /// summed over threads — the load-imbalance signal.
    pub prof_freeze_wait_ns: AtomicU64,
    /// `--profile`: host ns in the border sync (inbox merge + xbar grants
    /// + mailbox drain + horizon publish), summed over threads.
    pub prof_border_sync_ns: AtomicU64,
    /// `--profile`: host ns from entering the publish barrier to leaving
    /// the verdict barrier (phases 2+3, including the leader's planning),
    /// summed over threads.
    pub prof_publish_wait_ns: AtomicU64,
}

/// Bits of the canonical injector key reserved for the per-domain send
/// counter (low bits); the sender domain occupies the bits above. See
/// [`SharedState::next_injector_seq`].
pub const XSEQ_BITS: u32 = 40;

/// State shared by all domains of one simulation run.
pub struct SharedState {
    /// Component -> (owning domain, dense local index).
    pub locate: Vec<(DomainId, u32)>,
    /// Per-domain cross-scheduling mailboxes (drained at quantum borders).
    pub injectors: Vec<Mailbox>,
    /// Per-*sender*-domain injection counters backing the canonical
    /// `(sender_domain, send order)` merge key every mailbox-injected
    /// event carries in its `seq` field (see
    /// [`SharedState::next_injector_seq`]).
    xseq: Vec<AtomicU64>,
    /// Quantum length in ticks; `Tick::MAX` disables windowing (serial).
    pub quantum: Tick,
    /// Border policy knobs (adaptive quantum, stealing, thread count);
    /// set once by the machine builder before the run starts.
    pub policy: RunPolicy,
    pub pdes: PdesStats,
    pub stop: AtomicBool,
    pub cores_total: u32,
    pub cores_done: AtomicU32,
    pub wl_barrier: WlBarrier,
}

impl SharedState {
    pub fn new(
        locate: Vec<(DomainId, u32)>,
        n_domains: usize,
        quantum: Tick,
        cores_total: u32,
    ) -> Self {
        let injectors = (0..n_domains).map(|_| Mailbox::default()).collect();
        let xseq = (0..n_domains).map(|_| AtomicU64::new(0)).collect();
        SharedState {
            locate,
            injectors,
            xseq,
            quantum,
            policy: RunPolicy::default(),
            pdes: PdesStats::default(),
            stop: AtomicBool::new(false),
            cores_total,
            cores_done: AtomicU32::new(0),
            wl_barrier: WlBarrier::default(),
        }
    }

    pub fn domain_of(&self, c: CompId) -> DomainId {
        self.locate[c.index()].0
    }

    /// The canonical merge key for the next event `dom` pushes into a
    /// cross-domain [`Mailbox`]: `(dom << XSEQ_BITS) | send_counter`.
    ///
    /// The mailbox drain sorts by `(tick, prio, target, seq)`; with this
    /// key the sort is *total* — two distinct same-tick deliveries to the
    /// same consumer (e.g. the `--io-milli` crossbar's `MemReq`/`MemResp`
    /// packets racing onto one device) can no longer tie, so their merge
    /// order is a pure function of the simulation (sender domain, then
    /// the sender's program order) instead of host push interleaving.
    /// Only the owning thread of `dom`'s window ever advances `dom`'s
    /// counter (the claim list hands a window to exactly one thread), so
    /// the sequence each event receives is deterministic; `Relaxed`
    /// suffices because the value is data, not synchronisation.
    pub fn next_injector_seq(&self, dom: DomainId) -> u64 {
        let cnt = self.xseq[dom.index()].fetch_add(1, Ordering::Relaxed);
        debug_assert!(cnt < 1 << XSEQ_BITS, "injector counter overflow");
        ((dom.0 as u64) << XSEQ_BITS) | cnt
    }

    /// Called by a CPU model when its workload is exhausted.
    ///
    /// The count itself only needs atomicity (Relaxed); the stop flag is a
    /// Release store so the thread that observes it (Acquire) also sees the
    /// completed workload state.
    pub fn core_done(&self) {
        let done = self.cores_done.fetch_add(1, Ordering::Relaxed) + 1;
        if done >= self.cores_total {
            self.stop.store(true, Ordering::Release);
        }
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Checkpoint producer: serialize the cross-domain shared state — the
    /// injector send cursors, the workload-barrier rendezvous, the core
    /// completion count, and the *deterministic* PDES counters only.
    ///
    /// Host-timing-dependent counters (`steals`, `stolen_events`,
    /// `inbox_reordered`, `inbox_merge_ns`, every `prof_*` field) are
    /// deliberately excluded: they differ between producing kernels, and a
    /// checkpoint's bytes must be a pure function of the simulation
    /// content (docs/CHECKPOINT.md). Precondition: taken inside a quantum
    /// border's quiescent span, so every mailbox is empty (asserted by the
    /// checkpoint writer) and `stop` is false.
    ///
    /// `o3` is the snapshot's `FLAG_O3` bit: when set, the five O3
    /// pipeline counters are appended after the base array. A flags = 0
    /// (Minor) snapshot keeps the original byte layout exactly.
    pub fn save_ckpt(&self, w: &mut StateWriter, o3: bool) {
        w.usize(self.xseq.len());
        for x in &self.xseq {
            w.u64(x.load(Ordering::Relaxed));
        }
        w.u32(self.cores_done.load(Ordering::Relaxed));
        let wl = self.wl_barrier.state.lock().unwrap();
        w.u32(wl.participants);
        w.usize(wl.waiting.len());
        for c in &wl.waiting {
            w.comp_id(*c);
        }
        w.u64(wl.max_arrival);
        w.u64(wl.generation);
        drop(wl);
        let p = &self.pdes;
        for ctr in [
            &p.cross_events,
            &p.postponed,
            &p.tpp_sum,
            &p.barriers,
            &p.quanta_skipped,
            &p.inbox_staged,
            &p.xbar_staged,
            &p.xbar_deferred_grants,
            &p.traffic_offered,
            &p.traffic_accepted,
            &p.traffic_retries,
            &p.traffic_phases,
        ] {
            w.u64(ctr.load(Ordering::Relaxed));
        }
        if o3 {
            for ctr in [
                &p.issued,
                &p.squashed,
                &p.rob_full_stalls,
                &p.iq_full_stalls,
                &p.rob_occupancy_sum,
            ] {
                w.u64(ctr.load(Ordering::Relaxed));
            }
        }
    }

    /// Checkpoint restore: overwrite the fields written by
    /// [`Self::save_ckpt`] on a freshly built `SharedState`. The builder
    /// already seeded `traffic_offered`/`traffic_phases` from the
    /// regenerated workload; the snapshot values overwrite them with the
    /// identical numbers (the workload is a pure function of the pinned
    /// config).
    pub fn restore_ckpt(
        &self,
        r: &mut StateReader,
        o3: bool,
    ) -> Result<(), CkptError> {
        let n = r.usize()?;
        if n != self.xseq.len() {
            return Err(CkptError::Mismatch {
                what: "injector cursor count".to_string(),
                expected: self.xseq.len().to_string(),
                found: n.to_string(),
            });
        }
        for x in &self.xseq {
            x.store(r.u64()?, Ordering::Relaxed);
        }
        self.cores_done.store(r.u32()?, Ordering::Relaxed);
        {
            let mut wl = self.wl_barrier.state.lock().unwrap();
            let participants = r.u32()?;
            if participants != wl.participants {
                return Err(CkptError::Mismatch {
                    what: "workload barrier participants".to_string(),
                    expected: wl.participants.to_string(),
                    found: participants.to_string(),
                });
            }
            let waiting = r.usize()?;
            wl.waiting.clear();
            for _ in 0..waiting {
                let c = r.comp_id()?;
                wl.waiting.push(c);
            }
            wl.max_arrival = r.u64()?;
            wl.generation = r.u64()?;
        }
        let p = &self.pdes;
        for ctr in [
            &p.cross_events,
            &p.postponed,
            &p.tpp_sum,
            &p.barriers,
            &p.quanta_skipped,
            &p.inbox_staged,
            &p.xbar_staged,
            &p.xbar_deferred_grants,
            &p.traffic_offered,
            &p.traffic_accepted,
            &p.traffic_retries,
            &p.traffic_phases,
        ] {
            ctr.store(r.u64()?, Ordering::Relaxed);
        }
        if o3 {
            for ctr in [
                &p.issued,
                &p.squashed,
                &p.rob_full_stalls,
                &p.iq_full_stalls,
                &p.rob_occupancy_sum,
            ] {
                ctr.store(r.u64()?, Ordering::Relaxed);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wl_barrier_releases_on_last() {
        let b = WlBarrier::default();
        b.state.lock().unwrap().participants = 3;
        assert!(matches!(b.arrive(CompId(0), 100), BarrierOutcome::Wait));
        assert!(matches!(b.arrive(CompId(1), 200), BarrierOutcome::Wait));
        match b.arrive(CompId(2), 150) {
            BarrierOutcome::Release { waiters, release_at } => {
                assert_eq!(waiters.len(), 2);
                assert_eq!(release_at, 200);
            }
            _ => panic!("expected release"),
        }
    }

    #[test]
    fn core_done_sets_stop_at_total() {
        let s = SharedState::new(vec![], 1, Tick::MAX, 2);
        s.core_done();
        assert!(!s.should_stop());
        s.core_done();
        assert!(s.should_stop());
    }
}
