//! The event queue: a min-heap over `(tick, prio, seq)` with gem5's
//! schedule / deschedule / reschedule interface.
//!
//! Descheduling is implemented with lazy tombstones (`cancelled` set), which
//! keeps `schedule` O(log n) and avoids heap surgery; cancelled entries are
//! dropped when they surface.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use rustc_hash::FxHashSet;

use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

/// Handle identifying a scheduled event (its sequence number).
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub struct EventHandle(pub u64);

#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    cancelled: FxHashSet<u64>,
    next_seq: u64,
    /// Number of events popped (executed) from this queue.
    pub executed: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live (non-cancelled) events.
    pub fn len(&self) -> usize {
        self.heap.len() - self.cancelled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Schedule `kind` on `target` at absolute `tick`.
    pub fn schedule(
        &mut self,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Event { tick, prio, seq, target, kind }));
        EventHandle(seq)
    }

    /// Insert a fully formed event (used when draining cross-domain
    /// injectors); re-sequences it into this queue's order.
    pub fn insert(&mut self, mut ev: Event) -> EventHandle {
        ev.seq = self.next_seq;
        self.next_seq += 1;
        let h = EventHandle(ev.seq);
        self.heap.push(Reverse(ev));
        h
    }

    /// Cancel a scheduled event. Cancelling an already-executed or unknown
    /// handle is a no-op (mirrors gem5's squash semantics).
    pub fn deschedule(&mut self, h: EventHandle) {
        self.cancelled.insert(h.0);
    }

    /// gem5 reschedule = deschedule + schedule.
    pub fn reschedule(
        &mut self,
        h: EventHandle,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        self.deschedule(h);
        self.schedule(tick, prio, target, kind)
    }

    /// Tick of the next live event.
    pub fn next_tick(&mut self) -> Option<Tick> {
        self.skim();
        self.heap.peek().map(|Reverse(e)| e.tick)
    }

    /// Pop the next live event.
    pub fn pop(&mut self) -> Option<Event> {
        self.skim();
        let ev = self.heap.pop().map(|Reverse(e)| e);
        if ev.is_some() {
            self.executed += 1;
        }
        ev
    }

    /// Pop the next live event only if it is strictly before `limit`.
    pub fn pop_before(&mut self, limit: Tick) -> Option<Event> {
        match self.next_tick() {
            Some(t) if t < limit => self.pop(),
            _ => None,
        }
    }

    /// Drop cancelled events sitting at the head.
    #[inline]
    fn skim(&mut self) {
        // Fast path: descheduling is rare (§Perf L3.3) — skip the per-pop
        // tombstone lookup entirely when no event is cancelled.
        if self.cancelled.is_empty() {
            return;
        }
        while let Some(Reverse(e)) = self.heap.peek() {
            if self.cancelled.remove(&e.seq) {
                self.heap.pop();
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k() -> EventKind {
        EventKind::CpuTick
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, 50, CompId(0), k());
        q.schedule(10, 50, CompId(1), k());
        q.schedule(20, 50, CompId(2), k());
        let order: Vec<Tick> = std::iter::from_fn(|| q.pop().map(|e| e.tick)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn same_tick_fifo_by_seq() {
        let mut q = EventQueue::new();
        q.schedule(5, 50, CompId(0), k());
        q.schedule(5, 50, CompId(1), k());
        q.schedule(5, 50, CompId(2), k());
        let order: Vec<u32> =
            std::iter::from_fn(|| q.pop().map(|e| e.target.0)).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn priority_beats_seq() {
        let mut q = EventQueue::new();
        q.schedule(5, 60, CompId(0), k());
        q.schedule(5, 0, CompId(1), k());
        assert_eq!(q.pop().unwrap().target, CompId(1));
    }

    #[test]
    fn deschedule_skips_event() {
        let mut q = EventQueue::new();
        let h = q.schedule(1, 50, CompId(0), k());
        q.schedule(2, 50, CompId(1), k());
        q.deschedule(h);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().target, CompId(1));
        assert!(q.pop().is_none());
    }

    #[test]
    fn reschedule_moves_event() {
        let mut q = EventQueue::new();
        let h = q.schedule(10, 50, CompId(0), k());
        q.reschedule(h, 1, 50, CompId(0), k());
        assert_eq!(q.pop().unwrap().tick, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn pop_before_respects_limit() {
        let mut q = EventQueue::new();
        q.schedule(10, 50, CompId(0), k());
        assert!(q.pop_before(10).is_none());
        assert!(q.pop_before(11).is_some());
    }

    #[test]
    fn insert_resequences() {
        let mut q = EventQueue::new();
        q.schedule(5, 50, CompId(0), k());
        let ev = Event { tick: 5, prio: 50, seq: 0, target: CompId(9), kind: k() };
        q.insert(ev);
        // inserted event got a later seq -> pops second
        assert_eq!(q.pop().unwrap().target, CompId(0));
        assert_eq!(q.pop().unwrap().target, CompId(9));
    }
}
