//! The discrete-event simulation core (gem5's kernel, §3.1 of the paper).
//!
//! * [`time`] — tick base and clocks.
//! * [`ids`] — component / domain identifiers.
//! * [`event`] — events and their `(tick, prio, seq)` total order.
//! * [`component`] — the SimObject trait and the scheduling [`component::Ctx`].
//! * [`shared`] — cross-domain shared state (mailboxes, t_pp accounting,
//!   workload barrier, stop flag).
//! * [`stats`] — per-component statistic collection.
//!
//! The event queue itself (schedule / deschedule / reschedule), the
//! cross-domain mailboxes and the quantum barrier live in [`crate::sched`].

pub mod component;
pub mod event;
pub mod ids;
pub mod shared;
pub mod stats;
pub mod time;

pub use component::{Component, Ctx};
pub use event::{Event, EventKind};
pub use ids::{CompId, DomainId};
pub use shared::SharedState;
pub use stats::StatSink;
pub use time::{Clock, Tick, NS, PS, US};
