//! Identifiers for simulation objects and time domains.

use std::fmt;

/// Dense id of a component (SimObject) in the machine arena.
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CompId(pub u32);

/// Dense id of a time domain (event queue + thread).
///
/// Following the paper's partitioning (§4.1): domain `i` of an N-core system
/// holds core `i` plus its private resources for `i < N`; domain `N` is the
/// shared domain (L3/HNF, central router, DRAM, IO crossbar, peripherals).
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DomainId(pub u32);

impl CompId {
    pub const NONE: CompId = CompId(u32::MAX);

    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl DomainId {
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Debug for CompId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "c{}", self.0)
    }
}

impl fmt::Display for CompId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "c{}", self.0)
    }
}

impl fmt::Debug for DomainId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "d{}", self.0)
    }
}

impl fmt::Display for DomainId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "d{}", self.0)
    }
}
