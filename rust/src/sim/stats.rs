//! Per-component statistic collection.
//!
//! Components expose their counters through [`StatSink`]; the harness
//! aggregates them into a [`crate::stats::Summary`] at the end of a run.

/// Collects `(name, value)` pairs, prefixed with the owning component name.
#[derive(Default, Debug, Clone)]
pub struct StatSink {
    prefix: String,
    pub entries: Vec<(String, f64)>,
}

impl StatSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the prefix used for subsequent `add` calls.
    pub fn with_prefix(&mut self, prefix: &str) {
        self.prefix = prefix.to_string();
    }

    pub fn add(&mut self, name: &str, value: f64) {
        let full = if self.prefix.is_empty() {
            name.to_string()
        } else {
            format!("{}.{}", self.prefix, name)
        };
        self.entries.push((full, value));
    }

    pub fn add_u64(&mut self, name: &str, value: u64) {
        self.add(name, value as f64);
    }

    /// Sum of all entries whose full name ends with `suffix`.
    pub fn sum_suffix(&self, suffix: &str) -> f64 {
        self.entries
            .iter()
            .filter(|(n, _)| n.ends_with(suffix))
            .map(|(_, v)| v)
            .sum()
    }

    /// First entry with exactly this name.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefixing_and_lookup() {
        let mut s = StatSink::new();
        s.with_prefix("cpu0");
        s.add_u64("insts", 10);
        s.with_prefix("cpu1");
        s.add_u64("insts", 32);
        assert_eq!(s.get("cpu0.insts"), Some(10.0));
        assert_eq!(s.sum_suffix(".insts"), 42.0);
    }
}
