//! Communication protocols (§3.3 of the paper).
//!
//! The *timing* protocol splits a transaction into request and response
//! events ([`packet::Packet`] delivered via
//! [`crate::sim::event::EventKind::MemReq`] /
//! [`crate::sim::event::EventKind::MemResp`]); rejection and retry are
//! modelled with explicit retry events. The *atomic* protocol completes a
//! transaction in a single synchronous call chain — see
//! [`crate::cpu::atomic`].

pub mod packet;

pub use packet::{Cmd, Packet};
