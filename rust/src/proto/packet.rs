//! gem5-style packets for the (classic) timing protocol.
//!
//! Packets carry a command, target address, a functional payload value and
//! the delays accumulated in flight (`header_delay`, `payload_delay` — the
//! Δt_h and Δt_p of §3.3 in the paper). The Ruby side converts packets to
//! [`crate::ruby::RubyMsg`]s at the sequencer, exactly like gem5 (§3.4).

use crate::sim::ids::CompId;
use crate::sim::time::Tick;

/// Packet command. Request commands expect a matching response.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Cmd {
    ReadReq,
    WriteReq,
    ReadResp,
    WriteResp,
}

impl Cmd {
    #[inline]
    pub fn is_request(self) -> bool {
        matches!(self, Cmd::ReadReq | Cmd::WriteReq)
    }

    #[inline]
    pub fn is_read(self) -> bool {
        matches!(self, Cmd::ReadReq | Cmd::ReadResp)
    }

    /// The response command matching a request.
    pub fn response(self) -> Cmd {
        match self {
            Cmd::ReadReq => Cmd::ReadResp,
            Cmd::WriteReq => Cmd::WriteResp,
            other => panic!("{other:?} is not a request"),
        }
    }
}

/// A memory transaction packet.
#[derive(Copy, Clone, Debug)]
pub struct Packet {
    /// Unique transaction id (allocated by the issuing CPU/sequencer).
    pub id: u64,
    pub cmd: Cmd,
    /// Byte address of the access.
    pub addr: u64,
    /// Access size in bytes.
    pub size: u32,
    /// Functional payload (line-granular value; writes carry the new value,
    /// read responses carry the observed value).
    pub value: u64,
    /// Component to which the response must be routed.
    pub requester: CompId,
    /// Simulated core that issued the transaction (for stats).
    pub core: u16,
    /// Tick at which the original request was issued (latency stats).
    pub issued: Tick,
    /// Accumulated header delay (Δt_h).
    pub header_delay: Tick,
    /// Accumulated payload delay (Δt_p).
    pub payload_delay: Tick,
}

impl Packet {
    pub fn request(
        id: u64,
        cmd: Cmd,
        addr: u64,
        size: u32,
        value: u64,
        requester: CompId,
        core: u16,
        issued: Tick,
    ) -> Self {
        debug_assert!(cmd.is_request());
        Packet {
            id,
            cmd,
            addr,
            size,
            value,
            requester,
            core,
            issued,
            header_delay: 0,
            payload_delay: 0,
        }
    }

    /// Turn this packet into its response in place (gem5's `makeResponse`).
    pub fn make_response(mut self, value: u64) -> Self {
        self.cmd = self.cmd.response();
        self.value = value;
        self
    }

    /// Total accumulated in-flight delay.
    #[inline]
    pub fn flight_delay(&self) -> Tick {
        self.header_delay + self.payload_delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_roundtrip() {
        let p = Packet::request(1, Cmd::ReadReq, 0x40, 64, 0, CompId(3), 0, 100);
        let r = p.make_response(0xdead);
        assert_eq!(r.cmd, Cmd::ReadResp);
        assert_eq!(r.value, 0xdead);
        assert_eq!(r.requester, CompId(3));
        assert!(!r.cmd.is_request());
    }

    #[test]
    #[should_panic]
    fn response_of_response_panics() {
        Cmd::ReadResp.response();
    }

    #[test]
    fn flight_delay_sums() {
        let mut p = Packet::request(1, Cmd::WriteReq, 0, 8, 7, CompId(0), 1, 0);
        p.header_delay = 500;
        p.payload_delay = 1500;
        assert_eq!(p.flight_delay(), 2000);
    }
}
