//! PJRT runtime: load the AOT-compiled HLO artifacts (built once by
//! `make artifacts` from the JAX/Pallas layer) and execute them from Rust.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and DESIGN.md): the
//! text parser reassigns instruction ids, avoiding the 64-bit-id proto
//! incompatibility between jax ≥ 0.5 and xla_extension 0.5.1.
//!
//! Python never runs here — this module only loads and executes the
//! artifacts. The procedural generator in [`crate::workload::gen`] is the
//! bit-exact fallback when no artifacts directory is available.
//!
//! The `xla` crate cannot be fetched in the offline build environment, so
//! the real implementation is gated behind the `pjrt` feature; without it
//! this module compiles as a stub with the same API that reports artifacts
//! as unavailable, and every consumer falls back to the procedural
//! generator (their artifact paths skip gracefully by design).

use std::path::PathBuf;

/// Trace length produced per `workload.hlo.txt` execution (must match
/// python/compile/model.py TRACE_N).
pub const TRACE_N: usize = 16384;
/// Payload batch size (model.py PAYLOAD_B).
pub const PAYLOAD_B: usize = 4096;

/// Default artifacts location: `$PARTI_ARTIFACTS` or `./artifacts`.
fn default_artifact_dir() -> PathBuf {
    std::env::var("PARTI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::{Path, PathBuf};
    use std::sync::Arc;

    use anyhow::{anyhow, Context, Result};

    use crate::workload::{AddrGenParams, CoreTrace, Workload};

    use super::{PAYLOAD_B, TRACE_N};

    /// A compiled artifact ready to execute.
    pub struct LoadedExe {
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT client plus the compiled artifacts of this repo.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at `artifacts_dir`.
        pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(Runtime { client, dir: artifacts_dir.into() })
        }

        pub fn default_dir() -> PathBuf {
            super::default_artifact_dir()
        }

        pub fn artifacts_available(dir: &Path) -> bool {
            dir.join("workload.hlo.txt").exists()
        }

        /// Load + compile one HLO-text artifact.
        pub fn load(&self, name: &str) -> Result<LoadedExe> {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            Ok(LoadedExe { exe })
        }
    }

    impl LoadedExe {
        /// Execute with literal inputs; returns the flattened tuple elements.
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let mut lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            // aot.py lowers with return_tuple=True.
            let parts =
                lit.decompose_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
            Ok(parts)
        }
    }

    /// Generate one core's trace via the `workload.hlo.txt` artifact.
    pub fn artifact_trace(
        exe: &LoadedExe,
        params: &AddrGenParams,
        n: usize,
    ) -> Result<CoreTrace> {
        assert!(n <= TRACE_N, "artifact emits TRACE_N ops per call");
        let vec = params.to_vec();
        let input = xla::Literal::vec1(&vec);
        let parts = exe.run(&[input])?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs, got {}", parts.len());
        let addr: Vec<u64> =
            parts[0].to_vec().map_err(|e| anyhow!("addr: {e:?}"))?;
        let is_store: Vec<u32> =
            parts[1].to_vec().map_err(|e| anyhow!("store: {e:?}"))?;
        let gap: Vec<u32> =
            parts[2].to_vec().map_err(|e| anyhow!("gap: {e:?}"))?;
        Ok(CoreTrace::from_arrays(
            params.core_id as u16,
            addr[..n].to_vec(),
            is_store[..n].to_vec(),
            gap[..n].to_vec(),
        ))
    }

    /// Build a whole workload from the AOT artifact (the production path).
    pub fn artifact_workload(
        rt: &Runtime,
        app: &crate::workload::App,
        n_cores: usize,
        ops_per_core: usize,
        seed: u64,
    ) -> Result<Workload> {
        anyhow::ensure!(
            ops_per_core <= TRACE_N,
            "ops_per_core {ops_per_core} exceeds artifact TRACE_N {TRACE_N}"
        );
        let exe = rt.load("workload").context("loading workload artifact")?;
        let cores = (0..n_cores as u64)
            .map(|c| {
                let p = app.params_for_core(c, seed);
                artifact_trace(&exe, &p, ops_per_core).map(Arc::new)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Workload {
            cores,
            barrier_every: app.barrier_every,
            name: app.traits_.name.to_string(),
            phase_ops: 0,
        })
    }

    /// Execute the Black-Scholes payload artifact (example/functional checks).
    pub fn blackscholes_payload(
        rt: &Runtime,
        spot: &[f32],
        strike: &[f32],
        rate: &[f32],
        vol: &[f32],
        time: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            spot.len() == PAYLOAD_B,
            "payload batch must be {PAYLOAD_B}"
        );
        let exe = rt.load("blackscholes")?;
        let lits: Vec<xla::Literal> = [spot, strike, rate, vol, time]
            .iter()
            .map(|v| xla::Literal::vec1(v))
            .collect();
        let parts = exe.run(&lits)?;
        anyhow::ensure!(parts.len() == 2, "expected (call, put)");
        Ok((
            parts[0].to_vec().map_err(|e| anyhow!("call: {e:?}"))?,
            parts[1].to_vec().map_err(|e| anyhow!("put: {e:?}"))?,
        ))
    }

    /// Execute the STREAM triad payload artifact.
    pub fn stream_payload(
        rt: &Runtime,
        b: &[f32],
        c: &[f32],
        scalar: f32,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            b.len() == PAYLOAD_B,
            "payload batch must be {PAYLOAD_B}"
        );
        let exe = rt.load("stream")?;
        let lits = vec![
            xla::Literal::vec1(b),
            xla::Literal::vec1(c),
            xla::Literal::vec1(&[scalar]),
        ];
        let parts = exe.run(&lits)?;
        Ok(parts[0].to_vec().map_err(|e| anyhow!("a: {e:?}"))?)
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Result};

    use crate::workload::{AddrGenParams, CoreTrace, Workload};

    const DISABLED: &str =
        "built without the `pjrt` feature: PJRT/XLA runtime unavailable \
         (the procedural workload generator is the bit-exact fallback)";

    /// Stub artifact handle; never constructible without `pjrt`.
    pub struct LoadedExe {
        _private: (),
    }

    /// Stub runtime: reports artifacts as unavailable so every consumer
    /// takes its procedural-fallback path.
    pub struct Runtime {
        _dir: PathBuf,
    }

    impl Runtime {
        pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let _dir: PathBuf = artifacts_dir.into();
            bail!(DISABLED)
        }

        pub fn default_dir() -> PathBuf {
            super::default_artifact_dir()
        }

        /// Always false without `pjrt`: artifacts cannot be executed, so
        /// callers must use the procedural generator.
        pub fn artifacts_available(_dir: &Path) -> bool {
            false
        }

        pub fn load(&self, _name: &str) -> Result<LoadedExe> {
            bail!(DISABLED)
        }
    }

    pub fn artifact_trace(
        _exe: &LoadedExe,
        _params: &AddrGenParams,
        _n: usize,
    ) -> Result<CoreTrace> {
        bail!(DISABLED)
    }

    pub fn artifact_workload(
        _rt: &Runtime,
        _app: &crate::workload::App,
        _n_cores: usize,
        _ops_per_core: usize,
        _seed: u64,
    ) -> Result<Workload> {
        bail!(DISABLED)
    }

    pub fn blackscholes_payload(
        _rt: &Runtime,
        _spot: &[f32],
        _strike: &[f32],
        _rate: &[f32],
        _vol: &[f32],
        _time: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!(DISABLED)
    }

    pub fn stream_payload(
        _rt: &Runtime,
        _b: &[f32],
        _c: &[f32],
        _scalar: f32,
    ) -> Result<Vec<f32>> {
        bail!(DISABLED)
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::*;
