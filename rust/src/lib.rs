//! # parti-sim
//!
//! A reproduction of *parti-gem5: gem5's Timing Mode Parallelised*
//! (Cubero-Cascante et al., SAMOS 2023) as a three-layer Rust + JAX/Pallas
//! system:
//!
//! * **L3 (this crate)** — a full MPSoC timing simulator: gem5-style DES
//!   kernel, detailed CPU models (Atomic/Minor/O3), a Ruby-like coherent
//!   memory subsystem (CHI-lite protocol, message buffers, routers,
//!   throttles), an IO crossbar, a DRAM model — plus the paper's
//!   contribution: quantum-based PDES with per-core time domains,
//!   thread-safe Ruby message passing and thread-safe crossbar layers —
//!   both made deterministic by border-staged protocols (the inbox
//!   handoff and the crossbar layer arbitration, docs/XBAR.md).
//! * **L2/L1 (python/, build-time only)** — JAX workload-trace synthesis
//!   with Pallas kernels, AOT-lowered to HLO and executed from Rust via
//!   PJRT ([`runtime`]).
//!
//! Start with a platform — a preset from [`spec::platforms`], a spec TOML
//! file, or a hand-built [`spec::SystemSpec`] (star / ring / mesh
//! interconnects) — put it in a [`config::RunConfig`]
//! ([`config::RunConfig::for_spec`]), elaborate it with
//! [`ruby::topology::build_system`], then run one of the kernels in
//! [`pdes`]. The legacy [`config::SystemConfig`] flag surface still works
//! as a thin conversion into the spec.

pub mod ckpt;
pub mod config;
pub mod cpu;
pub mod harness;
pub mod mem;
pub mod pdes;
pub mod proto;
pub mod ruby;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod spec;
pub mod stats;
pub mod util;
pub mod workload;
pub mod xbar;
