//! System configuration (Table 2 of the paper) and run configuration.
//!
//! [`SystemConfig`] is the legacy flat platform description driven by
//! individual CLI flags; the typed, serializable platform API is
//! [`crate::spec::SystemSpec`], and [`RunConfig::spec`] /
//! [`RunConfig::apply_spec`] are the thin conversions between the two.
//! New code (and anything naming a topology) should go through the spec.

use crate::cpu::CpuModel;
use crate::sched::{
    BucketShape, InboxOrder, QuantumPolicy, QueueKind, RunPolicy, XbarArb,
};
use crate::sim::time::{Tick, NS};
use crate::spec::{CpuSpec, Interconnect, SystemSpec};

/// Cache geometry + latency.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheConfig {
    pub size_bytes: u64,
    pub assoc: usize,
    pub latency_ns: u64,
}

/// The simulated platform (defaults = Table 2).
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// Simulated cores.
    pub cores: usize,
    /// CPU clock in MHz (Table 2: 2 GHz).
    pub cpu_mhz: u64,
    pub l1i: CacheConfig,
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    pub l3: CacheConfig,
    pub line_bytes: u64,
    /// NoC link + router latency (Table 2: 0.5 ns).
    pub noc_latency_ns_x10: u64,
    /// Router buffer size in messages (Table 2: 4).
    pub router_buffer: usize,
    /// Link flits charged for a data message (32-bit links, Table 2).
    pub data_flits: u64,
    /// DRAM clock in MHz (Table 2: 1 GHz).
    pub dram_mhz: u64,
    /// Fraction of ops that touch IO devices (milli); exercises the
    /// crossbar path of §4.3. The paper's workloads do this via the OS.
    pub io_milli: u64,
    /// Interconnect fabric between the private L2s and the shared HN-F
    /// (Fig. 4's star by default; see [`crate::spec::Interconnect`]).
    pub interconnect: Interconnect,
    /// Line-interleaved DRAM channels behind the HN-F.
    pub mem_channels: usize,
    /// O3 pipeline geometry (see [`crate::spec::CpuSpec`]; ignored by
    /// non-O3 models).
    pub cpu_spec: CpuSpec,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            cores: 2,
            cpu_mhz: 2000,
            l1i: CacheConfig { size_bytes: 32 * 1024, assoc: 2, latency_ns: 1 },
            l1d: CacheConfig { size_bytes: 64 * 1024, assoc: 2, latency_ns: 1 },
            l2: CacheConfig {
                size_bytes: 2 * 1024 * 1024,
                assoc: 8,
                latency_ns: 4,
            },
            l3: CacheConfig {
                size_bytes: 16 * 1024 * 1024,
                assoc: 8,
                latency_ns: 6,
            },
            line_bytes: 64,
            noc_latency_ns_x10: 5, // 0.5 ns
            router_buffer: 4,
            data_flits: 4,
            dram_mhz: 1000,
            io_milli: 0,
            interconnect: Interconnect::Star,
            mem_channels: 1,
            cpu_spec: CpuSpec::default(),
        }
    }
}

impl SystemConfig {
    pub fn with_cores(cores: usize) -> Self {
        SystemConfig { cores, ..Default::default() }
    }

    pub fn noc_latency(&self) -> Tick {
        self.noc_latency_ns_x10 * NS / 10
    }

    /// L3-hit round-trip latency — the paper's recipe for the max quantum
    /// (§5.1: links + cache access latencies ≈ 16 ns).
    pub fn l3_hit_latency(&self) -> Tick {
        // 8 link crossings + L1 + L2 + L3 access latencies.
        8 * self.noc_latency()
            + (self.l1d.latency_ns + self.l2.latency_ns + self.l3.latency_ns)
                * NS
    }
}

/// Which kernel executes the machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Reference single-thread DES.
    Serial,
    /// Threaded PDES (one thread per domain).
    Parallel,
    /// Sequentialized PDES + host model (deterministic; DESIGN.md §3).
    Virtual,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "serial" => Mode::Serial,
            "parallel" => Mode::Parallel,
            "virtual" => Mode::Virtual,
            _ => return None,
        })
    }
}

/// A full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub system: SystemConfig,
    pub cpu_model: CpuModel,
    pub mode: Mode,
    /// Quantum t_qΔ in ticks (ignored in serial mode).
    pub quantum: Tick,
    pub app: String,
    /// Synthetic-traffic selector (`--traffic <name|file.toml>`): a named
    /// scenario from [`crate::spec::traffic::scenarios`] or a TOML
    /// [`crate::spec::traffic::TrafficSpec`] file. `None` runs `app`;
    /// `Some` replaces the app workload with the elaborated traffic
    /// (docs/TRAFFIC.md). A traffic spec carries its own `seed`, so a
    /// scenario file is a self-contained, repeatable experiment; the
    /// run-level `seed` below drives app workloads only.
    pub traffic: Option<String>,
    pub ops_per_core: usize,
    pub seed: u64,
    /// Hard simulated-time limit.
    pub max_ticks: Tick,
    /// Modeled host cores for virtual mode.
    pub host_cores: usize,
    /// Event-queue implementation (see [`QueueKind`]).
    pub queue: QueueKind,
    /// Window-advance policy at quantum borders (see [`QuantumPolicy`]).
    pub quantum_policy: QuantumPolicy,
    /// Claim-based window work stealing in the threaded kernel (opt-in).
    pub steal: bool,
    /// Host threads for the threaded kernel; `0` = one per domain.
    pub threads: usize,
    /// Cross-domain Ruby message visibility (`--inbox-order`): the
    /// deterministic border-ordered handoff (default) or the paper's
    /// host-order consumption (see [`InboxOrder`]).
    pub inbox_order: InboxOrder,
    /// IO-crossbar layer arbitration (`--xbar-arb`): deterministic
    /// border-staged grants (default) or the paper's mid-window
    /// `try_lock` occupancy (see [`XbarArb`] and docs/XBAR.md).
    pub xbar_arb: XbarArb,
    /// Calendar geometry for [`QueueKind::Bucket`] (`--bucket-width` /
    /// `--bucket-slots`); a pure performance lever — the pop order is
    /// shape-independent (docs/PERF.md).
    pub bucket_shape: BucketShape,
    /// `--profile`: record per-thread, per-phase wall breakdowns into the
    /// run's `PdesStats` (host-side observation only).
    pub profile: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            system: SystemConfig::default(),
            cpu_model: CpuModel::O3,
            mode: Mode::Serial,
            quantum: 16 * NS,
            app: "synthetic".to_string(),
            traffic: None,
            ops_per_core: 4096,
            seed: 42,
            max_ticks: 10_000_000_000_000, // 10 s simulated
            host_cores: 64,
            queue: QueueKind::default(),
            quantum_policy: QuantumPolicy::default(),
            steal: false,
            threads: 0,
            inbox_order: InboxOrder::default(),
            xbar_arb: XbarArb::default(),
            bucket_shape: BucketShape::default(),
            profile: false,
        }
    }
}

impl RunConfig {
    /// The border-policy bundle handed to the machine builder.
    pub fn run_policy(&self) -> RunPolicy {
        RunPolicy {
            quantum_policy: self.quantum_policy,
            steal: self.steal,
            threads: self.threads,
            inbox_order: self.inbox_order,
            xbar_arb: self.xbar_arb,
            profile: self.profile,
        }
    }

    /// The platform half of this run as a [`SystemSpec`] — the thin
    /// conversion that makes the legacy flag surface a front-end of the
    /// declarative platform API (elaboration only ever sees the spec).
    pub fn spec(&self) -> SystemSpec {
        SystemSpec::from_parts(&self.system, self.cpu_model)
    }

    /// Replace the platform half of this run with `spec` (run knobs —
    /// mode, quantum, workload, scheduler policy — are untouched).
    pub fn apply_spec(&mut self, spec: &SystemSpec) {
        spec.apply_to(self);
    }

    /// A default run configuration on a named/loaded platform.
    pub fn for_spec(spec: &SystemSpec) -> Self {
        let mut cfg = RunConfig::default();
        cfg.apply_spec(spec);
        cfg
    }
}

impl SystemConfig {
    /// Serialise to a flat numeric `key = value` config file (legacy
    /// TOML-compatible subset; hand-rolled because the build environment
    /// is offline). The interconnect travels as a numeric code —
    /// [`crate::spec::SystemSpec::to_toml`] is the human-facing format.
    pub fn to_toml(&self) -> String {
        let c = self;
        let mut s = String::new();
        let mut kv = |k: &str, v: u64| s.push_str(&format!("{k} = {v}\n"));
        kv("cores", c.cores as u64);
        kv("cpu_mhz", c.cpu_mhz);
        for (p, cc) in [("l1i", &c.l1i), ("l1d", &c.l1d), ("l2", &c.l2), ("l3", &c.l3)] {
            kv(&format!("{p}_size_bytes"), cc.size_bytes);
            kv(&format!("{p}_assoc"), cc.assoc as u64);
            kv(&format!("{p}_latency_ns"), cc.latency_ns);
        }
        kv("line_bytes", c.line_bytes);
        kv("noc_latency_ns_x10", c.noc_latency_ns_x10);
        kv("router_buffer", c.router_buffer as u64);
        kv("data_flits", c.data_flits);
        kv("dram_mhz", c.dram_mhz);
        kv("io_milli", c.io_milli);
        // 0 = star, 1 = ring, 2 = mesh (mesh_cols carries the width).
        let (ic, cols) = match c.interconnect {
            Interconnect::Star => (0, 0),
            Interconnect::Ring => (1, 0),
            Interconnect::Mesh { cols } => (2, cols as u64),
        };
        kv("interconnect", ic);
        kv("mesh_cols", cols);
        kv("mem_channels", c.mem_channels as u64);
        kv("cpu_width", c.cpu_spec.width as u64);
        kv("cpu_rob_size", c.cpu_spec.rob_size as u64);
        kv("cpu_iq_size", c.cpu_spec.iq_size as u64);
        kv("cpu_lsq_size", c.cpu_spec.lsq_size as u64);
        kv("cpu_fetch_buf", c.cpu_spec.fetch_buf as u64);
        kv("cpu_mshrs", c.cpu_spec.mshrs as u64);
        s
    }

    /// Parse the `key = value` format emitted by [`Self::to_toml`].
    /// Unknown keys are rejected; missing keys keep their defaults.
    pub fn from_toml(s: &str) -> Result<Self, String> {
        let mut c = SystemConfig::default();
        let mut ic_code = 0u64;
        let mut mesh_cols = 0usize;
        for (lineno, line) in s.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let k = k.trim();
            let v: u64 = v
                .trim()
                .parse()
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let cache = |c: &mut CacheConfig, field: &str, v: u64| match field {
                "size_bytes" => c.size_bytes = v,
                "assoc" => c.assoc = v as usize,
                "latency_ns" => c.latency_ns = v,
                _ => unreachable!(),
            };
            match k {
                "cores" => c.cores = v as usize,
                "cpu_mhz" => c.cpu_mhz = v,
                "line_bytes" => c.line_bytes = v,
                "noc_latency_ns_x10" => c.noc_latency_ns_x10 = v,
                "router_buffer" => c.router_buffer = v as usize,
                "data_flits" => c.data_flits = v,
                "dram_mhz" => c.dram_mhz = v,
                "io_milli" => c.io_milli = v,
                "interconnect" => ic_code = v,
                "mesh_cols" => mesh_cols = v as usize,
                "mem_channels" => c.mem_channels = v as usize,
                "cpu_width" => c.cpu_spec.width = v as usize,
                "cpu_rob_size" => c.cpu_spec.rob_size = v as usize,
                "cpu_iq_size" => c.cpu_spec.iq_size = v as usize,
                "cpu_lsq_size" => c.cpu_spec.lsq_size = v as usize,
                "cpu_fetch_buf" => c.cpu_spec.fetch_buf = v as usize,
                "cpu_mshrs" => c.cpu_spec.mshrs = v as usize,
                _ => {
                    let (p, field) = k
                        .split_once('_')
                        .ok_or_else(|| format!("unknown key {k}"))?;
                    let target = match p {
                        "l1i" => &mut c.l1i,
                        "l1d" => &mut c.l1d,
                        "l2" => &mut c.l2,
                        "l3" => &mut c.l3,
                        _ => return Err(format!("unknown key {k}")),
                    };
                    match field {
                        "size_bytes" | "assoc" | "latency_ns" => {
                            cache(target, field, v)
                        }
                        _ => return Err(format!("unknown key {k}")),
                    }
                }
            }
        }
        c.interconnect = match ic_code {
            0 => Interconnect::Star,
            1 => Interconnect::Ring,
            2 => Interconnect::Mesh { cols: mesh_cols },
            other => {
                return Err(format!(
                    "interconnect = {other}: use 0 (star), 1 (ring) or 2 \
                     (mesh, with mesh_cols)"
                ))
            }
        };
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table2() {
        let c = SystemConfig::default();
        assert_eq!(c.cpu_mhz, 2000);
        assert_eq!(c.l1i.size_bytes, 32 * 1024);
        assert_eq!(c.l1d.size_bytes, 64 * 1024);
        assert_eq!(c.l1d.assoc, 2);
        assert_eq!(c.l2.size_bytes, 2 * 1024 * 1024);
        assert_eq!(c.l2.assoc, 8);
        assert_eq!(c.l3.size_bytes, 16 * 1024 * 1024);
        assert_eq!(c.router_buffer, 4);
        assert_eq!(c.noc_latency(), 500);
    }

    #[test]
    fn l3_hit_latency_matches_paper_quantum() {
        // §5.1: ~16 ns L3 hit -> the max quantum used in the sweeps.
        let c = SystemConfig::default();
        assert_eq!(c.l3_hit_latency(), 15 * NS);
    }

    #[test]
    fn toml_roundtrip() {
        let c = SystemConfig::with_cores(8);
        let s = c.to_toml();
        let back = SystemConfig::from_toml(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn toml_roundtrip_keeps_interconnect_and_channels() {
        let mut c = SystemConfig::with_cores(12);
        c.interconnect = Interconnect::Mesh { cols: 4 };
        c.mem_channels = 2;
        assert_eq!(SystemConfig::from_toml(&c.to_toml()).unwrap(), c);
        c.interconnect = Interconnect::Ring;
        assert_eq!(SystemConfig::from_toml(&c.to_toml()).unwrap(), c);
    }

    #[test]
    fn run_config_spec_roundtrip() {
        let mut cfg =
            RunConfig { cpu_model: CpuModel::Minor, ..RunConfig::default() };
        cfg.system.cores = 6;
        cfg.system.interconnect = Interconnect::Ring;
        let spec = cfg.spec();
        let mut cfg2 = RunConfig::default();
        cfg2.apply_spec(&spec);
        assert_eq!(cfg2.system, cfg.system);
        assert_eq!(cfg2.cpu_model, cfg.cpu_model);
    }
}
