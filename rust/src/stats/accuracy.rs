//! Accuracy metrics: the paper's two error indicators (§5).
//!
//! * **Simulated-time error** — percentual deviation of total simulated
//!   time between the parallel run and the serial reference ("a good
//!   indicator of the overall accuracy since individual timing deviations
//!   ... will ultimately be reflected there").
//! * **Cache miss-rate error** — absolute (percentage-point) deviation of
//!   the miss rate per cache level, averaged over cores for private levels
//!   (Fig. 9).

use crate::pdes::RunResult;

use super::avg_miss_rate;

/// Accuracy of a parallel/virtual run vs the serial reference.
#[derive(Debug, Clone, Copy)]
pub struct Accuracy {
    /// Signed relative error of total simulated time (fraction; ×100 = %).
    pub sim_time_error: f64,
    /// Absolute miss-rate errors in percentage points per level.
    pub l1i_pp: f64,
    pub l1d_pp: f64,
    pub l2_pp: f64,
    pub l3_pp: f64,
    /// Functional check: do the load checksums match (XOR over cores)?
    pub checksum_match: bool,
}

/// Per-level absolute miss-rate deviations (percentage points).
pub fn cache_miss_rate_errors(reference: &RunResult, run: &RunResult) -> [f64; 4] {
    let lvls = [".l1i.miss_rate", ".l1d.miss_rate", ".l2.miss_rate", "hnf.miss_rate"];
    let mut out = [0.0; 4];
    for (k, lvl) in lvls.iter().enumerate() {
        let a = avg_miss_rate(reference, lvl);
        let b = avg_miss_rate(run, lvl);
        out[k] = (b - a).abs() * 100.0;
    }
    out
}

/// Commutative fold of all per-core load checksums.
fn checksum(result: &RunResult) -> u64 {
    result
        .stats
        .entries
        .iter()
        .filter(|(n, _)| n.ends_with(".load_checksum"))
        .map(|(_, v)| *v as u64)
        .fold(0u64, |acc, v| acc.wrapping_add(v))
}

/// Compare a run against the serial reference.
pub fn compare(reference: &RunResult, run: &RunResult) -> Accuracy {
    let sim_time_error = if reference.sim_ticks == 0 {
        0.0
    } else {
        (run.sim_ticks as f64 - reference.sim_ticks as f64)
            / reference.sim_ticks as f64
    };
    let [l1i_pp, l1d_pp, l2_pp, l3_pp] = cache_miss_rate_errors(reference, run);
    Accuracy {
        sim_time_error,
        l1i_pp,
        l1d_pp,
        l2_pp,
        l3_pp,
        checksum_match: checksum(reference) == checksum(run),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdes::result::PdesSnapshot;
    use crate::sim::stats::StatSink;

    fn result(ticks: u64, l1d: f64, csum: u64) -> RunResult {
        let mut stats = StatSink::new();
        stats.with_prefix("cpu0.l1d");
        stats.add("miss_rate", l1d);
        stats.with_prefix("cpu0");
        stats.add_u64("load_checksum", csum);
        RunResult {
            sim_ticks: ticks,
            events: 0,
            host_ns: 1,
            stats,
            pdes: PdesSnapshot::default(),
            work: None,
            n_domains: 1,
        }
    }

    #[test]
    fn sim_time_error_signed() {
        let a = result(1000, 0.1, 7);
        let b = result(1100, 0.1, 7);
        let acc = compare(&a, &b);
        assert!((acc.sim_time_error - 0.1).abs() < 1e-12);
        assert!(acc.checksum_match);
    }

    #[test]
    fn miss_rate_error_absolute_pp() {
        let a = result(1000, 0.10, 7);
        let b = result(1000, 0.12, 7);
        let acc = compare(&a, &b);
        assert!((acc.l1d_pp - 2.0).abs() < 1e-9);
    }

    #[test]
    fn checksum_mismatch_detected() {
        let a = result(1000, 0.1, 7);
        let b = result(1000, 0.1, 8);
        assert!(!compare(&a, &b).checksum_match);
    }
}
