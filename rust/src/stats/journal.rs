//! The sweep journal record: one flat JSON object per completed sweep
//! point, appended to a `.jsonl` journal by
//! [`crate::harness::sweep::run_sweep`].
//!
//! The schema segregates determinism classes by *prefix*: every field
//! is a pure function of the point's `RunConfig` (bit-identical across
//! outer pool sizes, shards and resumes — `tests/sweep.rs` gates this)
//! **except** the `host_*` fields, which depend on host wall-clock
//! timing and are emitted last. Stripping the `host_*` keys yields the
//! *canonical* form ([`SweepRecord::to_canonical_line`]) that the
//! determinism gates and the CI shard-merge diff compare.
//!
//! Parsing ([`SweepRecord::from_json_line`]) exists for `--resume`: the
//! journal is re-read to learn which point ids are already done. The
//! parser is strict — a truncated or garbled line is an error carrying
//! a reason, which the harness reports with its line number and repairs
//! by re-running the point (never silently skipping it). Integers are
//! parsed from their decimal tokens directly (not through `f64`), so a
//! 64-bit checksum survives the round-trip exactly.

use std::collections::BTreeMap;

use crate::pdes::RunResult;
use crate::stats::avg_miss_rate;
use crate::util::json::JsonObj;

/// One journaled sweep point. Field order here is emission order; the
/// `host_*` fields stay last so the canonical prefix is contiguous.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepRecord {
    /// Position in the expanded point list (the journal sort key).
    pub index: u64,
    /// Canonical point id (the resume key; docs/SWEEP.md).
    pub id: String,
    // -- deterministic results (the canonical section) ------------------
    pub sim_ticks: u64,
    pub sim_seconds: f64,
    pub events: u64,
    pub committed_ops: u64,
    pub barriers: u64,
    pub quanta_skipped: u64,
    pub cross_events: u64,
    pub postponed: u64,
    pub inbox_staged: u64,
    pub xbar_staged: u64,
    pub xbar_deferred_grants: u64,
    pub traffic_offered: u64,
    pub traffic_accepted: u64,
    pub traffic_retries: u64,
    pub traffic_phases: u64,
    /// O3 pipeline counters (docs/O3.md): zero under `--cpu minor`.
    /// Parse-optional so pre-O3 journals still resume cleanly.
    pub issued: u64,
    pub squashed: u64,
    pub rob_full_stalls: u64,
    pub iq_full_stalls: u64,
    pub rob_occupancy_sum: u64,
    /// Sum of the fabric `.routed` counters.
    pub routed: u64,
    /// HN-F per-line serialisation requeues.
    pub hnf_requeued: u64,
    /// XOR fold of the per-core `.load_checksum` stats (the functional
    /// fingerprint; deterministic per kernel).
    pub load_checksum: u64,
    pub l1d_miss_rate: f64,
    pub l2_miss_rate: f64,
    pub l3_miss_rate: f64,
    // -- host-timing-dependent (stripped from the canonical form) -------
    pub host_ns: u64,
    pub host_events_per_sec: f64,
}

impl SweepRecord {
    /// Build the record for one finished run.
    pub fn from_run(index: u64, id: &str, r: &RunResult) -> Self {
        let load_checksum = r
            .stats
            .entries
            .iter()
            .filter(|(n, _)| n.ends_with(".load_checksum"))
            .fold(0u64, |a, (_, v)| a ^ (*v as u64));
        SweepRecord {
            index,
            id: id.to_string(),
            sim_ticks: r.sim_ticks,
            sim_seconds: r.sim_seconds(),
            events: r.events,
            committed_ops: r.stats.sum_suffix(".committed_ops") as u64,
            barriers: r.pdes.barriers,
            quanta_skipped: r.pdes.quanta_skipped,
            cross_events: r.pdes.cross_events,
            postponed: r.pdes.postponed,
            inbox_staged: r.pdes.inbox_staged,
            xbar_staged: r.pdes.xbar_staged,
            xbar_deferred_grants: r.pdes.xbar_deferred_grants,
            traffic_offered: r.pdes.traffic_offered,
            traffic_accepted: r.pdes.traffic_accepted,
            traffic_retries: r.pdes.traffic_retries,
            traffic_phases: r.pdes.traffic_phases,
            issued: r.pdes.issued,
            squashed: r.pdes.squashed,
            rob_full_stalls: r.pdes.rob_full_stalls,
            iq_full_stalls: r.pdes.iq_full_stalls,
            rob_occupancy_sum: r.pdes.rob_occupancy_sum,
            routed: r.stats.sum_suffix(".routed") as u64,
            hnf_requeued: r.stats.get("hnf.requeued").unwrap_or(0.0) as u64,
            load_checksum,
            l1d_miss_rate: avg_miss_rate(r, ".l1d.miss_rate"),
            l2_miss_rate: avg_miss_rate(r, ".l2.miss_rate"),
            l3_miss_rate: avg_miss_rate(r, "hnf.miss_rate"),
            host_ns: r.host_ns,
            host_events_per_sec: r.events_per_sec(),
        }
    }

    fn json_obj(&self, with_host: bool) -> JsonObj {
        let mut j = JsonObj::new()
            .u64("index", self.index)
            .str("id", &self.id)
            .u64("sim_ticks", self.sim_ticks)
            .f64("sim_seconds", self.sim_seconds)
            .u64("events", self.events)
            .u64("committed_ops", self.committed_ops)
            .u64("barriers", self.barriers)
            .u64("quanta_skipped", self.quanta_skipped)
            .u64("cross_events", self.cross_events)
            .u64("postponed", self.postponed)
            .u64("inbox_staged", self.inbox_staged)
            .u64("xbar_staged", self.xbar_staged)
            .u64("xbar_deferred_grants", self.xbar_deferred_grants)
            .u64("traffic_offered", self.traffic_offered)
            .u64("traffic_accepted", self.traffic_accepted)
            .u64("traffic_retries", self.traffic_retries)
            .u64("traffic_phases", self.traffic_phases)
            .u64("issued", self.issued)
            .u64("squashed", self.squashed)
            .u64("rob_full_stalls", self.rob_full_stalls)
            .u64("iq_full_stalls", self.iq_full_stalls)
            .u64("rob_occupancy_sum", self.rob_occupancy_sum)
            .u64("routed", self.routed)
            .u64("hnf_requeued", self.hnf_requeued)
            .u64("load_checksum", self.load_checksum)
            .f64("l1d_miss_rate", self.l1d_miss_rate)
            .f64("l2_miss_rate", self.l2_miss_rate)
            .f64("l3_miss_rate", self.l3_miss_rate);
        if with_host {
            j = j
                .u64("host_ns", self.host_ns)
                .f64("host_events_per_sec", self.host_events_per_sec);
        }
        j
    }

    /// The full journal line (canonical fields first, `host_*` last).
    pub fn to_json_line(&self) -> String {
        self.json_obj(true).build()
    }

    /// The record with every `host_*` field stripped — the form the
    /// determinism gates compare byte-for-byte.
    pub fn to_canonical_line(&self) -> String {
        self.json_obj(false).build()
    }

    /// Strict parse of one journal line (full or canonical — the
    /// `host_*` fields are optional and default to zero). Any malformed
    /// syntax, missing canonical field or unknown field is an error.
    pub fn from_json_line(line: &str) -> Result<Self, String> {
        let mut map = parse_flat_object(line)?;
        let m = &mut map;
        let rec = SweepRecord {
            index: take_u64(m, "index", true)?,
            id: take_str(m, "id")?,
            sim_ticks: take_u64(m, "sim_ticks", true)?,
            sim_seconds: take_f64(m, "sim_seconds", true)?,
            events: take_u64(m, "events", true)?,
            committed_ops: take_u64(m, "committed_ops", true)?,
            barriers: take_u64(m, "barriers", true)?,
            quanta_skipped: take_u64(m, "quanta_skipped", true)?,
            cross_events: take_u64(m, "cross_events", true)?,
            postponed: take_u64(m, "postponed", true)?,
            inbox_staged: take_u64(m, "inbox_staged", true)?,
            xbar_staged: take_u64(m, "xbar_staged", true)?,
            xbar_deferred_grants: take_u64(m, "xbar_deferred_grants", true)?,
            traffic_offered: take_u64(m, "traffic_offered", true)?,
            traffic_accepted: take_u64(m, "traffic_accepted", true)?,
            traffic_retries: take_u64(m, "traffic_retries", true)?,
            traffic_phases: take_u64(m, "traffic_phases", true)?,
            issued: take_u64(m, "issued", false)?,
            squashed: take_u64(m, "squashed", false)?,
            rob_full_stalls: take_u64(m, "rob_full_stalls", false)?,
            iq_full_stalls: take_u64(m, "iq_full_stalls", false)?,
            rob_occupancy_sum: take_u64(m, "rob_occupancy_sum", false)?,
            routed: take_u64(m, "routed", true)?,
            hnf_requeued: take_u64(m, "hnf_requeued", true)?,
            load_checksum: take_u64(m, "load_checksum", true)?,
            l1d_miss_rate: take_f64(m, "l1d_miss_rate", true)?,
            l2_miss_rate: take_f64(m, "l2_miss_rate", true)?,
            l3_miss_rate: take_f64(m, "l3_miss_rate", true)?,
            host_ns: take_u64(m, "host_ns", false)?,
            host_events_per_sec: take_f64(m, "host_events_per_sec", false)?,
        };
        if let Some(k) = map.keys().next() {
            return Err(format!("unknown field `{k}`"));
        }
        Ok(rec)
    }
}

/// A parsed flat JSON value: a string, or the raw token of a number.
/// Numbers stay tokens so `u64` fields round-trip without an `f64`
/// detour (a 64-bit checksum does not fit in 53 mantissa bits).
enum JsonVal {
    Str(String),
    Raw(String),
}

fn take_u64(
    map: &mut BTreeMap<String, JsonVal>,
    k: &str,
    required: bool,
) -> Result<u64, String> {
    match map.remove(k) {
        Some(JsonVal::Raw(t)) => {
            t.parse::<u64>().map_err(|e| format!("field `{k}` = {t}: {e}"))
        }
        Some(JsonVal::Str(_)) => Err(format!("field `{k}` must be a number")),
        None if required => Err(format!("missing field `{k}`")),
        None => Ok(0),
    }
}

fn take_f64(
    map: &mut BTreeMap<String, JsonVal>,
    k: &str,
    required: bool,
) -> Result<f64, String> {
    match map.remove(k) {
        Some(JsonVal::Raw(t)) => {
            t.parse::<f64>().map_err(|e| format!("field `{k}` = {t}: {e}"))
        }
        Some(JsonVal::Str(_)) => Err(format!("field `{k}` must be a number")),
        None if required => Err(format!("missing field `{k}`")),
        None => Ok(0.0),
    }
}

fn take_str(
    map: &mut BTreeMap<String, JsonVal>,
    k: &str,
) -> Result<String, String> {
    match map.remove(k) {
        Some(JsonVal::Str(s)) => Ok(s),
        Some(JsonVal::Raw(_)) => Err(format!("field `{k}` must be a string")),
        None => Err(format!("missing field `{k}`")),
    }
}

/// Parse one flat JSON object (`{"k": v, ...}`; string or numeric
/// values, no nesting) into a key → value map. Duplicate keys, nested
/// containers and any trailing bytes are errors.
fn parse_flat_object(line: &str) -> Result<BTreeMap<String, JsonVal>, String> {
    let b = line.trim().as_bytes();
    let mut i = 0usize;
    let mut map = BTreeMap::new();

    fn skip_ws(b: &[u8], i: &mut usize) {
        while *i < b.len() && b[*i].is_ascii_whitespace() {
            *i += 1;
        }
    }
    fn expect(b: &[u8], i: &mut usize, c: u8) -> Result<(), String> {
        skip_ws(b, i);
        if *i < b.len() && b[*i] == c {
            *i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected `{}` at byte {} (truncated line?)",
                c as char, *i
            ))
        }
    }
    fn string(b: &[u8], i: &mut usize) -> Result<String, String> {
        expect(b, i, b'"')?;
        let mut out = String::new();
        while *i < b.len() {
            match b[*i] {
                b'"' => {
                    *i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    *i += 1;
                    let esc = *b.get(*i).ok_or_else(|| {
                        "string escape at end of line".to_string()
                    })?;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'n' => out.push('\n'),
                        b'u' => {
                            let hex =
                                b.get(*i + 1..*i + 5).ok_or_else(|| {
                                    "truncated \\u escape".to_string()
                                })?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("\\u{hex}: {e}"))?;
                            out.push(char::from_u32(cp).ok_or_else(|| {
                                format!("\\u{hex}: bad codepoint")
                            })?);
                            *i += 4;
                        }
                        other => {
                            return Err(format!(
                                "unsupported escape \\{}",
                                other as char
                            ))
                        }
                    }
                    *i += 1;
                }
                _ => {
                    // Multi-byte UTF-8 sequences pass through untouched.
                    let s = std::str::from_utf8(&b[*i..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    *i += c.len_utf8();
                }
            }
        }
        Err("unterminated string (truncated line?)".to_string())
    }

    expect(b, &mut i, b'{')?;
    skip_ws(b, &mut i);
    if i < b.len() && b[i] == b'}' {
        i += 1;
    } else {
        loop {
            let key = string(b, &mut i)?;
            expect(b, &mut i, b':')?;
            skip_ws(b, &mut i);
            let val = if i < b.len() && b[i] == b'"' {
                JsonVal::Str(string(b, &mut i)?)
            } else {
                let start = i;
                while i < b.len() && !matches!(b[i], b',' | b'}') {
                    i += 1;
                }
                let tok = std::str::from_utf8(&b[start..i])
                    .map_err(|e| e.to_string())?
                    .trim()
                    .to_string();
                if tok.is_empty() {
                    return Err(format!("empty value for key `{key}`"));
                }
                if matches!(tok.as_bytes()[0], b'{' | b'[') {
                    return Err(format!("nested value for key `{key}`"));
                }
                JsonVal::Raw(tok)
            };
            if map.insert(key.clone(), val).is_some() {
                return Err(format!("duplicate key `{key}`"));
            }
            skip_ws(b, &mut i);
            match b.get(i) {
                Some(b',') => i += 1,
                Some(b'}') => {
                    i += 1;
                    break;
                }
                _ => {
                    return Err(format!(
                        "expected `,` or `}}` at byte {i} (truncated line?)"
                    ))
                }
            }
        }
    }
    skip_ws(b, &mut i);
    if i != b.len() {
        return Err(format!("trailing bytes after object at byte {i}"));
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SweepRecord {
        SweepRecord {
            index: 3,
            id: "fig4-2+c4+l2:512k+star+app:canneal+virtual+q8+fixed"
                .to_string(),
            sim_ticks: 123_456,
            sim_seconds: 0.000123456,
            events: 999,
            committed_ops: 512,
            barriers: 17,
            quanta_skipped: 2,
            cross_events: 40,
            postponed: 4,
            inbox_staged: 11,
            xbar_staged: 0,
            xbar_deferred_grants: 0,
            traffic_offered: 0,
            traffic_accepted: 0,
            traffic_retries: 0,
            traffic_phases: 0,
            issued: 530,
            squashed: 0,
            rob_full_stalls: 9,
            iq_full_stalls: 3,
            rob_occupancy_sum: 4096,
            routed: 77,
            hnf_requeued: 1,
            // Not representable in f64 — the parser must keep it exact.
            load_checksum: 0x8000_0000_0000_0401,
            l1d_miss_rate: 0.125,
            l2_miss_rate: 0.5,
            l3_miss_rate: 0.25,
            host_ns: 31_337,
            host_events_per_sec: 1.5e6,
        }
    }

    #[test]
    fn full_line_roundtrips_exactly() {
        let r = sample();
        let back = SweepRecord::from_json_line(&r.to_json_line()).unwrap();
        assert_eq!(r, back);
        assert_eq!(back.load_checksum, 0x8000_0000_0000_0401);
    }

    #[test]
    fn canonical_strips_host_fields_only() {
        let r = sample();
        let canon = r.to_canonical_line();
        assert!(!canon.contains("host_"), "{canon}");
        assert!(canon.contains("\"load_checksum\""));
        let back = SweepRecord::from_json_line(&canon).unwrap();
        assert_eq!(back.host_ns, 0);
        assert_eq!(back.host_events_per_sec, 0.0);
        assert_eq!(back.to_canonical_line(), canon, "canonical is stable");
    }

    #[test]
    fn host_fields_differ_canonical_equal() {
        let a = sample();
        let b = SweepRecord { host_ns: 1, host_events_per_sec: 9.9, ..a.clone() };
        assert_ne!(a.to_json_line(), b.to_json_line());
        assert_eq!(a.to_canonical_line(), b.to_canonical_line());
    }

    #[test]
    fn pre_o3_journal_lines_still_parse() {
        // A journal written before the O3 pipeline counters existed has
        // no `issued`/`squashed`/stall fields; `--resume` must still
        // read it (the counters default to zero, like the host fields).
        let mut line = sample().to_json_line();
        for f in [
            "issued",
            "squashed",
            "rob_full_stalls",
            "iq_full_stalls",
            "rob_occupancy_sum",
        ] {
            let needle = format!("\"{f}\": ");
            let start = line.find(&needle).expect(f);
            let end = start + line[start..].find(", ").unwrap() + 2;
            line.replace_range(start..end, "");
        }
        assert!(!line.contains("rob_"), "{line}");
        let back = SweepRecord::from_json_line(&line).unwrap();
        assert_eq!(back.issued, 0);
        assert_eq!(back.rob_occupancy_sum, 0);
        assert_eq!(back.sim_ticks, sample().sim_ticks);
    }

    #[test]
    fn truncated_line_is_an_error() {
        let line = sample().to_json_line();
        for cut in [line.len() / 2, line.len() - 1] {
            let err = SweepRecord::from_json_line(&line[..cut]).unwrap_err();
            assert!(!err.is_empty());
        }
    }

    #[test]
    fn garbage_and_unknown_fields_are_errors() {
        assert!(SweepRecord::from_json_line("not json").is_err());
        assert!(SweepRecord::from_json_line("{}").unwrap_err().contains("index"));
        let with_extra =
            sample().to_json_line().replace("\"host_ns\"", "\"hots_ns\"");
        let err = SweepRecord::from_json_line(&with_extra).unwrap_err();
        assert!(err.contains("hots_ns"), "{err}");
    }

    #[test]
    fn id_escapes_survive() {
        let r = SweepRecord { id: "odd \"quoted\" id".to_string(), ..sample() };
        let back = SweepRecord::from_json_line(&r.to_json_line()).unwrap();
        assert_eq!(back.id, "odd \"quoted\" id");
    }
}
