//! Run-level statistics: aggregation over component stats, accuracy
//! comparison between runs (the paper's error metrics), and JSON export.

pub mod accuracy;
pub mod journal;

use crate::pdes::RunResult;
use crate::util::json::JsonObj;

pub use accuracy::{cache_miss_rate_errors, compare, Accuracy};
pub use journal::SweepRecord;

/// Flat, serialisable summary of one run.
#[derive(Debug, Clone)]
pub struct Summary {
    pub sim_seconds: f64,
    pub sim_ticks: u64,
    pub events: u64,
    pub host_ns: u64,
    pub mips: f64,
    pub events_per_sec: f64,
    pub n_domains: usize,
    pub committed_ops: f64,
    pub cross_events: u64,
    pub postponed: u64,
    pub tpp_mean_ns: f64,
    pub barriers: u64,
    pub quanta_skipped: u64,
    pub steals: u64,
    pub stolen_events: u64,
    pub inbox_staged: u64,
    pub inbox_reordered: u64,
    /// Mean cost of the border-staged merge hooks (inbox merges +
    /// crossbar grants), ns per window (host-timing dependent).
    pub inbox_merge_ns_per_window: f64,
    /// IO-crossbar layer requests staged at borders (deterministic).
    pub xbar_staged: u64,
    /// Crossbar grant decisions deferred at borders (deterministic).
    pub xbar_deferred_grants: u64,
    /// Memory ops the workload offered (deterministic; docs/TRAFFIC.md).
    pub traffic_offered: u64,
    /// Offered ops accepted to completion (deterministic; shortfall
    /// against `traffic_offered` is the backpressure signal).
    pub traffic_accepted: u64,
    /// LSQ-full issue retries (deterministic).
    pub traffic_retries: u64,
    /// Traffic phases of the longest trace (0 = unphased; deterministic).
    pub traffic_phases: u64,
    /// O3 pipeline counters (deterministic; all zero under Minor —
    /// docs/O3.md).
    pub issued: u64,
    pub squashed: u64,
    pub rob_full_stalls: u64,
    pub iq_full_stalls: u64,
    pub rob_occupancy_sum: u64,
    /// `--profile` phase breakdowns, host ns summed over threads (all zero
    /// when profiling is off; host-timing dependent like `host_ns`).
    pub prof_window_ns: u64,
    pub prof_freeze_wait_ns: u64,
    pub prof_border_sync_ns: u64,
    pub prof_publish_wait_ns: u64,
    pub l1i_miss_rate: f64,
    pub l1d_miss_rate: f64,
    pub l2_miss_rate: f64,
    pub l3_miss_rate: f64,
}

/// Average of the per-component `miss_rate` stats whose names end with
/// `suffix` (e.g. ".l1d.miss_rate"), weighted equally per cache (the paper
/// averages private caches over all cores).
pub fn avg_miss_rate(result: &RunResult, suffix: &str) -> f64 {
    let vals: Vec<f64> = result
        .stats
        .entries
        .iter()
        .filter(|(n, _)| n.ends_with(suffix))
        .map(|(_, v)| *v)
        .collect();
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

impl Summary {
    pub fn from_result(r: &RunResult) -> Self {
        Summary {
            sim_seconds: r.sim_seconds(),
            sim_ticks: r.sim_ticks,
            events: r.events,
            host_ns: r.host_ns,
            mips: r.mips(),
            events_per_sec: r.events_per_sec(),
            n_domains: r.n_domains,
            committed_ops: r.stats.sum_suffix(".committed_ops"),
            cross_events: r.pdes.cross_events,
            postponed: r.pdes.postponed,
            tpp_mean_ns: r.pdes.tpp_mean() / 1000.0,
            barriers: r.pdes.barriers,
            quanta_skipped: r.pdes.quanta_skipped,
            steals: r.pdes.steals,
            stolen_events: r.pdes.stolen_events,
            inbox_staged: r.pdes.inbox_staged,
            inbox_reordered: r.pdes.inbox_reordered,
            inbox_merge_ns_per_window: r.pdes.merge_ns_per_window(),
            xbar_staged: r.pdes.xbar_staged,
            xbar_deferred_grants: r.pdes.xbar_deferred_grants,
            traffic_offered: r.pdes.traffic_offered,
            traffic_accepted: r.pdes.traffic_accepted,
            traffic_retries: r.pdes.traffic_retries,
            traffic_phases: r.pdes.traffic_phases,
            issued: r.pdes.issued,
            squashed: r.pdes.squashed,
            rob_full_stalls: r.pdes.rob_full_stalls,
            iq_full_stalls: r.pdes.iq_full_stalls,
            rob_occupancy_sum: r.pdes.rob_occupancy_sum,
            prof_window_ns: r.pdes.prof_window_ns,
            prof_freeze_wait_ns: r.pdes.prof_freeze_wait_ns,
            prof_border_sync_ns: r.pdes.prof_border_sync_ns,
            prof_publish_wait_ns: r.pdes.prof_publish_wait_ns,
            l1i_miss_rate: avg_miss_rate(r, ".l1i.miss_rate"),
            l1d_miss_rate: avg_miss_rate(r, ".l1d.miss_rate"),
            l2_miss_rate: avg_miss_rate(r, ".l2.miss_rate"),
            l3_miss_rate: avg_miss_rate(r, "hnf.miss_rate"),
        }
    }

    pub fn to_json(&self) -> String {
        JsonObj::new()
            .f64("sim_seconds", self.sim_seconds)
            .u64("sim_ticks", self.sim_ticks)
            .u64("events", self.events)
            .u64("host_ns", self.host_ns)
            .f64("mips", self.mips)
            .f64("events_per_sec", self.events_per_sec)
            .u64("n_domains", self.n_domains as u64)
            .f64("committed_ops", self.committed_ops)
            .u64("cross_events", self.cross_events)
            .u64("postponed", self.postponed)
            .f64("tpp_mean_ns", self.tpp_mean_ns)
            .u64("barriers", self.barriers)
            .u64("quanta_skipped", self.quanta_skipped)
            .u64("steals", self.steals)
            .u64("stolen_events", self.stolen_events)
            .u64("inbox_staged", self.inbox_staged)
            .u64("inbox_reordered", self.inbox_reordered)
            .f64("inbox_merge_ns_per_window", self.inbox_merge_ns_per_window)
            .u64("xbar_staged", self.xbar_staged)
            .u64("xbar_deferred_grants", self.xbar_deferred_grants)
            .u64("traffic_offered", self.traffic_offered)
            .u64("traffic_accepted", self.traffic_accepted)
            .u64("traffic_retries", self.traffic_retries)
            .u64("traffic_phases", self.traffic_phases)
            .u64("issued", self.issued)
            .u64("squashed", self.squashed)
            .u64("rob_full_stalls", self.rob_full_stalls)
            .u64("iq_full_stalls", self.iq_full_stalls)
            .u64("rob_occupancy_sum", self.rob_occupancy_sum)
            .u64("prof_window_ns", self.prof_window_ns)
            .u64("prof_freeze_wait_ns", self.prof_freeze_wait_ns)
            .u64("prof_border_sync_ns", self.prof_border_sync_ns)
            .u64("prof_publish_wait_ns", self.prof_publish_wait_ns)
            .f64("l1i_miss_rate", self.l1i_miss_rate)
            .f64("l1d_miss_rate", self.l1d_miss_rate)
            .f64("l2_miss_rate", self.l2_miss_rate)
            .f64("l3_miss_rate", self.l3_miss_rate)
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdes::result::PdesSnapshot;
    use crate::sim::stats::StatSink;

    #[test]
    fn summary_json_is_parsable_shape() {
        let mut stats = StatSink::new();
        stats.with_prefix("cpu0");
        stats.add_u64("committed_ops", 10);
        let r = RunResult {
            sim_ticks: 1000,
            events: 50,
            host_ns: 2000,
            stats,
            pdes: PdesSnapshot::default(),
            work: None,
            n_domains: 1,
        };
        let s = Summary::from_result(&r);
        let j = s.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"committed_ops\": 10"));
    }
}
