//! The named platform registry: curated [`SystemSpec`] presets covering
//! the paper's machines and the new topology design space, resolvable by
//! name from the CLI (`run --platform fig4-8`) or programmatically
//! ([`preset`], [`resolve`]).
//!
//! Every preset is gated by the bit-identity matrix in
//! `tests/platforms.rs` (threaded ≡ virtual kernel under the
//! border-ordered handoff) and smoke-run by the CI platform matrix.

use std::path::Path;

use super::{Interconnect, SpecError, SystemSpec};

/// All built-in platforms, in listing order.
pub fn presets() -> Vec<SystemSpec> {
    let base = SystemSpec::default();
    vec![
        SystemSpec {
            cores: 2,
            ..base.clone()
        }
        .named(
            "fig4-2",
            "smallest Fig. 4 star: 2 cores, Table 2 geometry (CI smoke)",
        ),
        SystemSpec {
            cores: 8,
            ..base.clone()
        }
        .named(
            "fig4-8",
            "the paper's Fig. 4 hierarchical star at 8 cores, Table 2 \
             geometry",
        ),
        SystemSpec {
            cores: 16,
            interconnect: Interconnect::Ring,
            ..base.clone()
        }
        .named(
            "ring-16",
            "16 cores on a uni-directional ring, HN-F at station 0 — the \
             cheap-to-wire, high-hop-count corner",
        ),
        SystemSpec {
            cores: 64,
            interconnect: Interconnect::Mesh { cols: 8 },
            mem_channels: 4,
            ..base.clone()
        }
        .named(
            "mesh-64",
            "64 cores on an 8x8 mesh (X-then-Y routing), 4 DRAM channels",
        ),
        SystemSpec {
            cores: 120,
            mem_channels: 4,
            ..base.clone()
        }
        .named(
            "mpsoc-120",
            "the paper's largest swept MPSoC: 120-core star (Fig. 7's \
             right edge), 4 DRAM channels",
        ),
    ]
}

/// Look up a preset by name.
pub fn preset(name: &str) -> Option<SystemSpec> {
    presets().into_iter().find(|p| p.name == name)
}

/// Resolve a CLI `--platform` argument: a preset name, or a path to a
/// spec TOML file (anything containing a path separator or ending in
/// `.toml`). The error lists the available presets.
pub fn resolve(arg: &str) -> Result<SystemSpec, SpecError> {
    if arg.ends_with(".toml") || arg.contains('/') {
        return SystemSpec::load(Path::new(arg));
    }
    preset(arg).ok_or_else(|| {
        let names: Vec<String> =
            presets().iter().map(|p| p.name.clone()).collect();
        SpecError {
            errors: vec![format!(
                "unknown platform `{arg}` — available presets: {}; or pass \
                 a spec file path ending in .toml",
                names.join(", ")
            )],
        }
    })
}

/// One-line-per-preset listing for the `platforms` subcommand.
pub fn render_list() -> String {
    let mut s = format!(
        "{:<12} {:>6} {:>6} {:<12} {:>8} description\n",
        "name", "cores", "cpu", "fabric", "mem-ch"
    );
    for p in presets() {
        s.push_str(&format!(
            "{:<12} {:>6} {:>6} {:<12} {:>8} {}\n",
            p.name,
            p.cores,
            format!("{:?}", p.cpu).to_lowercase(),
            p.interconnect.describe(p.cores),
            p.mem_channels,
            p.description,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate_and_roundtrip() {
        let all = presets();
        assert!(all.len() >= 4);
        for p in all {
            p.validate()
                .unwrap_or_else(|e| panic!("preset {}: {e}", p.name));
            let back = SystemSpec::from_toml(&p.to_toml())
                .unwrap_or_else(|e| panic!("preset {} toml: {e}", p.name));
            assert_eq!(p, back, "preset {} must round-trip", p.name);
        }
    }

    #[test]
    fn names_are_unique_and_resolvable() {
        let all = presets();
        let mut names: Vec<&str> =
            all.iter().map(|p| p.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate preset name");
        for p in &all {
            assert_eq!(resolve(&p.name).unwrap(), *p);
        }
    }

    #[test]
    fn issue_presets_exist() {
        for name in ["fig4-8", "ring-16", "mesh-64", "mpsoc-120"] {
            let p = preset(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(p.name, name);
        }
        assert_eq!(
            preset("mesh-64").unwrap().interconnect,
            Interconnect::Mesh { cols: 8 }
        );
        assert_eq!(preset("ring-16").unwrap().interconnect, Interconnect::Ring);
    }

    #[test]
    fn unknown_platform_error_lists_presets() {
        let err = resolve("nope").unwrap_err();
        assert!(err.errors[0].contains("fig4-8"), "{err}");
        assert!(err.errors[0].contains("ring-16"), "{err}");
    }

    #[test]
    fn listing_mentions_every_preset() {
        let s = render_list();
        for p in presets() {
            assert!(s.contains(&p.name), "listing misses {}", p.name);
        }
    }
}
