//! The declarative sweep API: [`SweepSpec`] describes a whole *design
//! space* — platform axes ([`super::SystemSpec`] presets plus cache /
//! core / fabric overrides) × workloads (Table 3 apps and
//! [`super::traffic`] scenarios) × run-policy levers (kernel, quantum,
//! `--quantum-policy`) — independently of how the points are executed
//! ([`crate::harness::sweep`] owns the outer pool, the journal and the
//! shard arithmetic).
//!
//! This is the paper's actual use case: the 42.7× speedup only matters
//! because architects run thousands of configurations, not one. A
//! `SweepSpec` can be
//!
//! * built in code (the tests and examples do this),
//! * loaded from / saved to TOML ([`SweepSpec::from_toml`],
//!   [`SweepSpec::to_toml`] — the same hand-rolled flat subset the
//!   platform and traffic specs use; axis lists are comma-separated
//!   inside one quoted string),
//! * taken from the named registry ([`sweeps`],
//!   `parti-sim sweep run --spec quick`),
//! * validated with actionable errors ([`SweepSpec::validate`]),
//!
//! and then *expanded* into a deterministic point list by
//! [`crate::harness::sweep::expand`]: grid sampling enumerates the full
//! cartesian product in field order, random sampling draws a
//! deterministic distinct subset keyed by `sample_seed` — either way the
//! point list (ids, order, indices) is a pure function of the spec, which
//! is what makes `--shard i/N` partitions and journal resume exact
//! (`tests/sweep.rs` gates this).
//!
//! See `docs/SWEEP.md` for the schema, the budget rule and the journal
//! format.

use std::path::Path;

use super::{platforms, traffic, Interconnect, MAX_CORES};
use crate::config::Mode;
use crate::sched::QuantumPolicy;

/// How the point set is drawn from the axis grid.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum Sampling {
    /// Every combination, in field order (platforms outermost,
    /// quantum_policies innermost).
    #[default]
    Grid,
    /// `samples` distinct grid points, drawn by the deterministic
    /// counter-based RNG keyed by `sample_seed`.
    Random,
}

impl Sampling {
    /// Parse the spec-TOML / CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "grid" => Sampling::Grid,
            "random" => Sampling::Random,
            _ => return None,
        })
    }

    /// The TOML / CLI keyword.
    pub fn keyword(&self) -> &'static str {
        match self {
            Sampling::Grid => "grid",
            Sampling::Random => "random",
        }
    }
}

/// The sweep spelling of a kernel [`Mode`] (round-trips with
/// [`Mode::parse`]).
pub fn mode_keyword(m: Mode) -> &'static str {
    match m {
        Mode::Serial => "serial",
        Mode::Parallel => "parallel",
        Mode::Virtual => "virtual",
    }
}

/// The sweep spelling of a [`QuantumPolicy`]: `fixed`, `horizon`,
/// `hybrid:<max_leap>` (the bare `hybrid` keyword loses the leap cap, so
/// sweeps always spell it out).
pub fn policy_keyword(p: QuantumPolicy) -> String {
    match p {
        QuantumPolicy::Fixed => "fixed".to_string(),
        QuantumPolicy::Horizon => "horizon".to_string(),
        QuantumPolicy::Hybrid { max_leap } => format!("hybrid:{max_leap}"),
    }
}

/// Parse [`policy_keyword`] spellings (also accepts the CLI's bare
/// `hybrid`, which carries the default leap cap).
pub fn parse_policy(s: &str) -> Option<QuantumPolicy> {
    if let Some(n) = s.strip_prefix("hybrid:") {
        return n.parse().ok().map(|max_leap| QuantumPolicy::Hybrid { max_leap });
    }
    QuantumPolicy::parse(s)
}

/// The sweep spelling of an [`Interconnect`]: `star`, `ring`,
/// `mesh:<cols>` (the platform-TOML splits the width into `mesh_cols`;
/// a one-token axis value keeps sweep lists flat).
pub fn fabric_keyword(ic: Interconnect) -> String {
    match ic {
        Interconnect::Star => "star".to_string(),
        Interconnect::Ring => "ring".to_string(),
        Interconnect::Mesh { cols } => format!("mesh:{cols}"),
    }
}

/// Parse [`fabric_keyword`] spellings.
pub fn parse_fabric(s: &str) -> Option<Interconnect> {
    if let Some(n) = s.strip_prefix("mesh:") {
        return n.parse().ok().map(|cols| Interconnect::Mesh { cols });
    }
    match s.to_ascii_lowercase().as_str() {
        "star" => Some(Interconnect::Star),
        "ring" => Some(Interconnect::Ring),
        _ => None,
    }
}

/// Validation failure: every problem found, each with a fix hint
/// (mirrors [`super::SpecError`] / [`traffic::TrafficError`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SweepError {
    pub errors: Vec<String>,
}

impl SweepError {
    fn one(msg: impl Into<String>) -> Self {
        SweepError { errors: vec![msg.into()] }
    }
}

impl std::fmt::Display for SweepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid SweepSpec:")?;
        for e in &self.errors {
            write!(f, "\n  - {e}")?;
        }
        Ok(())
    }
}

impl std::error::Error for SweepError {}

/// Hard cap on the expanded point count — "millions of configurations"
/// is the design target, an accidental billion-point grid is a typo.
pub const MAX_SWEEP_POINTS: usize = 1 << 24;

/// Upper bound on a quantum axis value in ns (1 ms of simulated time per
/// window is far past any useful accuracy/speed trade).
pub const MAX_QUANTUM_NS: u64 = 1_000_000;

/// A complete, serializable description of one design-space sweep.
///
/// The first eight fields are *axes* (every combination is a point);
/// `cores`, `l2_kib` and `fabrics` may be empty, meaning "keep each
/// platform's own value" (one implicit entry). The remaining fields are
/// per-sweep scalars shared by every point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepSpec {
    /// Registry / file identity (informational; `sweep list` shows it).
    pub name: String,
    /// One-line description for `sweep --describe`.
    pub description: String,
    /// Platform axis: preset names or spec `.toml` paths.
    pub platforms: Vec<String>,
    /// Core-count overrides applied to each platform (empty = keep).
    pub cores: Vec<usize>,
    /// Private-L2 capacity overrides in KiB (empty = keep).
    pub l2_kib: Vec<u64>,
    /// Interconnect overrides, spelled `star`/`ring`/`mesh:<cols>`
    /// (empty = keep).
    pub fabrics: Vec<Interconnect>,
    /// Workload axis: `app:<name>` or `traffic:<scenario|file.toml>`.
    pub workloads: Vec<String>,
    /// Kernel axis: `serial`/`parallel`/`virtual`.
    pub kernels: Vec<Mode>,
    /// Quantum axis in ns.
    pub quantum_ns: Vec<u64>,
    /// Window-advance policy axis (`fixed`/`horizon`/`hybrid:<n>`).
    pub quantum_policies: Vec<QuantumPolicy>,
    /// O3 per-stage width overrides (empty = keep each platform's;
    /// only meaningful for `cpu = o3` platforms — docs/O3.md).
    pub cpu_widths: Vec<usize>,
    /// O3 reorder-buffer size overrides (empty = keep).
    pub rob_sizes: Vec<usize>,
    /// Grid or random point selection.
    pub sampling: Sampling,
    /// Points drawn when `sampling = "random"` (clamped to the grid).
    pub samples: usize,
    /// Seed for the random draw (grid ignores it).
    pub sample_seed: u64,
    /// Ops per core for every point.
    pub ops_per_core: usize,
    /// Workload seed for `app:` points (traffic specs carry their own).
    pub seed: u64,
    /// Host threads per `parallel`-kernel point — the *inner* width the
    /// outer×inner ≤ budget rule divides by (docs/SWEEP.md).
    pub inner_threads: usize,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            name: "custom".to_string(),
            description: String::new(),
            platforms: vec!["fig4-2".to_string()],
            cores: Vec::new(),
            l2_kib: Vec::new(),
            fabrics: Vec::new(),
            workloads: vec!["app:synthetic".to_string()],
            kernels: vec![Mode::Virtual],
            quantum_ns: vec![8],
            quantum_policies: vec![QuantumPolicy::Fixed],
            cpu_widths: Vec::new(),
            rob_sizes: Vec::new(),
            sampling: Sampling::Grid,
            samples: 16,
            sample_seed: 7,
            ops_per_core: 256,
            seed: 42,
            inner_threads: 1,
        }
    }
}

fn first_dup<T: PartialEq + std::fmt::Debug>(v: &[T]) -> Option<String> {
    for (i, a) in v.iter().enumerate() {
        if v[..i].contains(a) {
            return Some(format!("{a:?}"));
        }
    }
    None
}

impl SweepSpec {
    /// Rename in place (builder-style, used by the sweep registry).
    pub fn named(
        mut self,
        name: impl Into<String>,
        description: impl Into<String>,
    ) -> Self {
        self.name = name.into();
        self.description = description.into();
        self
    }

    /// Per-axis grid lengths, in expansion order (platforms outermost).
    /// Optional axes count one implicit "keep the platform's value"
    /// entry when empty.
    pub fn axis_lens(&self) -> [usize; 10] {
        [
            self.platforms.len().max(1),
            self.cores.len().max(1),
            self.l2_kib.len().max(1),
            self.fabrics.len().max(1),
            self.workloads.len().max(1),
            self.kernels.len().max(1),
            self.quantum_ns.len().max(1),
            self.quantum_policies.len().max(1),
            self.cpu_widths.len().max(1),
            self.rob_sizes.len().max(1),
        ]
    }

    /// Full cartesian-grid size (`None` on usize overflow).
    pub fn grid_len(&self) -> Option<usize> {
        self.axis_lens().iter().try_fold(1usize, |a, &l| a.checked_mul(l))
    }

    /// Points a run would execute: the grid, or the (clamped) random
    /// sample count.
    pub fn point_count(&self) -> usize {
        let grid = self.grid_len().unwrap_or(usize::MAX);
        match self.sampling {
            Sampling::Grid => grid,
            Sampling::Random => self.samples.min(grid),
        }
    }

    /// Check every invariant expansion relies on. Collects *all*
    /// problems, each with an actionable hint, instead of stopping at
    /// the first.
    pub fn validate(&self) -> Result<(), SweepError> {
        let mut errors = Vec::new();
        let mut err = |m: String| errors.push(m);

        if self.platforms.is_empty() {
            err("platforms is empty — list at least one preset name or \
                 platform spec .toml path (`parti-sim platforms` lists the \
                 presets)"
                .to_string());
        }
        for p in &self.platforms {
            let is_path = p.ends_with(".toml") || p.contains('/');
            if !is_path && platforms::preset(p).is_none() {
                let names: Vec<String> =
                    platforms::presets().iter().map(|s| s.name.clone()).collect();
                err(format!(
                    "platforms entry `{p}` is not a preset — available: {}; \
                     or use a platform spec file path ending in .toml",
                    names.join(", ")
                ));
            }
        }
        for &c in &self.cores {
            if c == 0 || c > MAX_CORES {
                err(format!(
                    "cores entry {c} is out of range — overrides must be \
                     1..={MAX_CORES}"
                ));
            }
        }
        for &k in &self.l2_kib {
            if k == 0 || k > 1 << 20 {
                err(format!(
                    "l2_kib entry {k} is out of range — use 1..={} KiB",
                    1u64 << 20
                ));
            }
        }
        for f in &self.fabrics {
            if let Interconnect::Mesh { cols } = f {
                if *cols == 0 {
                    err("fabrics entry mesh:0 — a mesh needs >= 1 column"
                        .to_string());
                }
            }
        }
        if self.workloads.is_empty() {
            err("workloads is empty — list at least one `app:<name>` or \
                 `traffic:<scenario>` entry"
                .to_string());
        }
        for w in &self.workloads {
            match w.split_once(':') {
                Some(("app", name)) => {
                    if crate::workload::app_by_name(name).is_none() {
                        err(format!(
                            "workloads entry `{w}`: unknown app `{name}` — \
                             the Table 3 names are synthetic, blackscholes, \
                             canneal, dedup, ferret, fluidanimate, \
                             swaptions, stream"
                        ));
                    }
                }
                Some(("traffic", name)) => {
                    let is_path = name.ends_with(".toml") || name.contains('/');
                    if !is_path && traffic::scenario(name).is_none() {
                        err(format!(
                            "workloads entry `{w}`: unknown traffic scenario \
                             `{name}` — `parti-sim traffic` lists them"
                        ));
                    }
                }
                _ => err(format!(
                    "workloads entry `{w}` — use `app:<name>` or \
                     `traffic:<scenario|file.toml>`"
                )),
            }
        }
        if self.kernels.is_empty() {
            err("kernels is empty — list serial, parallel and/or virtual"
                .to_string());
        }
        if self.quantum_ns.is_empty() {
            err("quantum_ns is empty — list at least one quantum in ns"
                .to_string());
        }
        for &q in &self.quantum_ns {
            if q == 0 || q > MAX_QUANTUM_NS {
                err(format!(
                    "quantum_ns entry {q} is out of range — use \
                     1..={MAX_QUANTUM_NS} ns"
                ));
            }
        }
        if self.quantum_policies.is_empty() {
            err("quantum_policies is empty — list fixed, horizon and/or \
                 hybrid:<max_leap>"
                .to_string());
        }
        for &p in &self.quantum_policies {
            if p == (QuantumPolicy::Hybrid { max_leap: 0 }) {
                err("quantum_policies entry hybrid:0 — the leap cap must \
                     be >= 1"
                    .to_string());
            }
        }
        for &w in &self.cpu_widths {
            if w == 0 || w > 16 {
                err(format!(
                    "cpu_widths entry {w} is out of range — the O3 stage \
                     width must be 1..=16 (docs/O3.md)"
                ));
            }
        }
        for &r in &self.rob_sizes {
            if r == 0 || r > 512 {
                err(format!(
                    "rob_sizes entry {r} is out of range — the reorder \
                     buffer must be 1..=512 entries (docs/O3.md)"
                ));
            }
        }
        if self.ops_per_core == 0 || self.ops_per_core > 1 << 22 {
            err(format!(
                "ops_per_core = {} is out of range — use 1..={}",
                self.ops_per_core,
                1usize << 22
            ));
        }
        if self.sampling == Sampling::Random && self.samples == 0 {
            err("samples = 0 with sampling = \"random\" — draw at least one \
                 point (or use sampling = \"grid\")"
                .to_string());
        }
        if self.samples > MAX_SWEEP_POINTS {
            err(format!(
                "samples = {} is out of range — the point cap is \
                 {MAX_SWEEP_POINTS}",
                self.samples
            ));
        }
        if self.inner_threads == 0 || self.inner_threads > 1024 {
            err(format!(
                "inner_threads = {} is out of range — use 1..=1024 host \
                 threads per parallel-kernel point",
                self.inner_threads
            ));
        }
        match self.grid_len() {
            Some(n) if n <= MAX_SWEEP_POINTS => {}
            _ => err(format!(
                "the axes multiply to more than {MAX_SWEEP_POINTS} grid \
                 points — shrink an axis or use sampling = \"random\""
            )),
        }
        for (axis, dup) in [
            ("platforms", first_dup(&self.platforms)),
            ("cores", first_dup(&self.cores)),
            ("l2_kib", first_dup(&self.l2_kib)),
            ("fabrics", first_dup(&self.fabrics)),
            ("workloads", first_dup(&self.workloads)),
            ("kernels", first_dup(&self.kernels)),
            ("quantum_ns", first_dup(&self.quantum_ns)),
            ("quantum_policies", first_dup(&self.quantum_policies)),
            ("cpu_widths", first_dup(&self.cpu_widths)),
            ("rob_sizes", first_dup(&self.rob_sizes)),
        ] {
            if let Some(d) = dup {
                err(format!(
                    "{axis} lists {d} twice — duplicate axis values would \
                     collide on the canonical point id"
                ));
            }
        }
        if errors.is_empty() {
            Ok(())
        } else {
            Err(SweepError { errors })
        }
    }

    // ---- TOML ----------------------------------------------------------

    /// Serialise to the flat TOML subset (`key = value`, `#` comments,
    /// double-quoted strings; axis lists are comma-separated inside one
    /// quoted string). [`SweepSpec::from_toml`] round-trips this exactly;
    /// `tests/properties.rs` holds the property test.
    pub fn to_toml(&self) -> String {
        fn join<T, F: Fn(&T) -> String>(items: &[T], f: F) -> String {
            items.iter().map(f).collect::<Vec<_>>().join(", ")
        }
        let mut s = String::new();
        s.push_str("# parti-sim sweep spec (docs/SWEEP.md)\n");
        s.push_str(&format!("name = \"{}\"\n", self.name));
        s.push_str(&format!("description = \"{}\"\n", self.description));
        s.push_str(&format!(
            "platforms = \"{}\"\n",
            join(&self.platforms, |p| p.clone())
        ));
        s.push_str(&format!(
            "cores = \"{}\"\n",
            join(&self.cores, |c| c.to_string())
        ));
        s.push_str(&format!(
            "l2_kib = \"{}\"\n",
            join(&self.l2_kib, |k| k.to_string())
        ));
        s.push_str(&format!(
            "fabrics = \"{}\"\n",
            join(&self.fabrics, |f| fabric_keyword(*f))
        ));
        s.push_str(&format!(
            "workloads = \"{}\"\n",
            join(&self.workloads, |w| w.clone())
        ));
        s.push_str(&format!(
            "kernels = \"{}\"\n",
            join(&self.kernels, |m| mode_keyword(*m).to_string())
        ));
        s.push_str(&format!(
            "quantum_ns = \"{}\"\n",
            join(&self.quantum_ns, |q| q.to_string())
        ));
        s.push_str(&format!(
            "quantum_policies = \"{}\"\n",
            join(&self.quantum_policies, |p| policy_keyword(*p))
        ));
        s.push_str(&format!(
            "cpu_widths = \"{}\"\n",
            join(&self.cpu_widths, |w| w.to_string())
        ));
        s.push_str(&format!(
            "rob_sizes = \"{}\"\n",
            join(&self.rob_sizes, |r| r.to_string())
        ));
        s.push_str(&format!("sampling = \"{}\"\n", self.sampling.keyword()));
        s.push_str(&format!("samples = {}\n", self.samples));
        s.push_str(&format!("sample_seed = {}\n", self.sample_seed));
        s.push_str(&format!("ops_per_core = {}\n", self.ops_per_core));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str(&format!("inner_threads = {}\n", self.inner_threads));
        s
    }

    /// Parse the format emitted by [`SweepSpec::to_toml`]. Unknown keys
    /// are rejected (typos must not silently fall back to defaults);
    /// missing keys keep the defaults. The parsed spec is validated
    /// before being returned.
    pub fn from_toml(text: &str) -> Result<Self, SweepError> {
        let mut spec = SweepSpec::default();
        let mut errors = Vec::new();

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let lineno = lineno + 1;
            let Some((k, v)) = line.split_once('=') else {
                errors.push(format!(
                    "line {lineno}: expected `key = value`, got `{line}`"
                ));
                continue;
            };
            let (k, v) = (k.trim(), v.trim());
            // String values are double-quoted; numbers are bare.
            let as_str = v.strip_prefix('"').and_then(|s| s.strip_suffix('"'));
            let mut as_num = || -> Option<u64> {
                match v.parse::<u64>() {
                    Ok(n) => Some(n),
                    Err(e) => {
                        errors.push(format!(
                            "line {lineno}: {k} = {v}: {e} (expected an \
                             unsigned integer)"
                        ));
                        None
                    }
                }
            };
            match k {
                "samples" => {
                    if let Some(n) = as_num() {
                        spec.samples = n as usize;
                    }
                }
                "sample_seed" => {
                    if let Some(n) = as_num() {
                        spec.sample_seed = n;
                    }
                }
                "ops_per_core" => {
                    if let Some(n) = as_num() {
                        spec.ops_per_core = n as usize;
                    }
                }
                "seed" => {
                    if let Some(n) = as_num() {
                        spec.seed = n;
                    }
                }
                "inner_threads" => {
                    if let Some(n) = as_num() {
                        spec.inner_threads = n as usize;
                    }
                }
                _ => {
                    let Some(sv) = as_str else {
                        errors.push(format!(
                            "line {lineno}: {k} must be a double-quoted \
                             string, e.g. {k} = \"...\""
                        ));
                        continue;
                    };
                    let items: Vec<&str> = sv
                        .split(',')
                        .map(str::trim)
                        .filter(|x| !x.is_empty())
                        .collect();
                    match k {
                        "name" => spec.name = sv.to_string(),
                        "description" => spec.description = sv.to_string(),
                        "sampling" => match Sampling::parse(sv) {
                            Some(m) => spec.sampling = m,
                            None => errors.push(format!(
                                "line {lineno}: sampling = \"{sv}\" — use \
                                 grid or random"
                            )),
                        },
                        "platforms" => {
                            spec.platforms =
                                items.iter().map(|x| x.to_string()).collect();
                        }
                        "workloads" => {
                            spec.workloads =
                                items.iter().map(|x| x.to_string()).collect();
                        }
                        "cores" => {
                            spec.cores.clear();
                            for x in &items {
                                match x.parse::<usize>() {
                                    Ok(n) => spec.cores.push(n),
                                    Err(e) => errors.push(format!(
                                        "line {lineno}: cores entry `{x}`: \
                                         {e} (expected an unsigned integer)"
                                    )),
                                }
                            }
                        }
                        "l2_kib" => {
                            spec.l2_kib.clear();
                            for x in &items {
                                match x.parse::<u64>() {
                                    Ok(n) => spec.l2_kib.push(n),
                                    Err(e) => errors.push(format!(
                                        "line {lineno}: l2_kib entry `{x}`: \
                                         {e} (expected an unsigned integer)"
                                    )),
                                }
                            }
                        }
                        "quantum_ns" => {
                            spec.quantum_ns.clear();
                            for x in &items {
                                match x.parse::<u64>() {
                                    Ok(n) => spec.quantum_ns.push(n),
                                    Err(e) => errors.push(format!(
                                        "line {lineno}: quantum_ns entry \
                                         `{x}`: {e} (expected an unsigned \
                                         integer)"
                                    )),
                                }
                            }
                        }
                        "fabrics" => {
                            spec.fabrics.clear();
                            for x in &items {
                                match parse_fabric(x) {
                                    Some(f) => spec.fabrics.push(f),
                                    None => errors.push(format!(
                                        "line {lineno}: fabrics entry `{x}` \
                                         — use star, ring or mesh:<cols>"
                                    )),
                                }
                            }
                        }
                        "kernels" => {
                            spec.kernels.clear();
                            for x in &items {
                                match Mode::parse(x) {
                                    Some(m) => spec.kernels.push(m),
                                    None => errors.push(format!(
                                        "line {lineno}: kernels entry `{x}` \
                                         — use serial, parallel or virtual"
                                    )),
                                }
                            }
                        }
                        "quantum_policies" => {
                            spec.quantum_policies.clear();
                            for x in &items {
                                match parse_policy(x) {
                                    Some(p) => spec.quantum_policies.push(p),
                                    None => errors.push(format!(
                                        "line {lineno}: quantum_policies \
                                         entry `{x}` — use fixed, horizon \
                                         or hybrid:<max_leap>"
                                    )),
                                }
                            }
                        }
                        "cpu_widths" => {
                            spec.cpu_widths.clear();
                            for x in &items {
                                match x.parse::<usize>() {
                                    Ok(n) => spec.cpu_widths.push(n),
                                    Err(e) => errors.push(format!(
                                        "line {lineno}: cpu_widths entry \
                                         `{x}`: {e} (expected an unsigned \
                                         integer)"
                                    )),
                                }
                            }
                        }
                        "rob_sizes" => {
                            spec.rob_sizes.clear();
                            for x in &items {
                                match x.parse::<usize>() {
                                    Ok(n) => spec.rob_sizes.push(n),
                                    Err(e) => errors.push(format!(
                                        "line {lineno}: rob_sizes entry \
                                         `{x}`: {e} (expected an unsigned \
                                         integer)"
                                    )),
                                }
                            }
                        }
                        _ => errors.push(format!(
                            "line {lineno}: unknown key `{k}` — see \
                             docs/SWEEP.md for the schema"
                        )),
                    }
                }
            }
        }

        if !errors.is_empty() {
            return Err(SweepError { errors });
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load a spec from a `.toml` file on disk.
    pub fn load(path: &Path) -> Result<Self, SweepError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            SweepError::one(format!("cannot read {}: {e}", path.display()))
        })?;
        Self::from_toml(&text)
    }

    /// Multi-line human description for `sweep --describe`.
    pub fn describe(&self) -> String {
        fn axis<T, F: Fn(&T) -> String>(v: &[T], f: F) -> String {
            if v.is_empty() {
                "(keep platform's)".to_string()
            } else {
                v.iter().map(f).collect::<Vec<_>>().join(", ")
            }
        }
        format!(
            "{name}: {desc}\n\
             sampling       {samp} -> {pts} point(s)\n\
             platforms      {plat}\n\
             cores          {cores}\n\
             l2_kib         {l2}\n\
             fabrics        {fab}\n\
             workloads      {wl}\n\
             kernels        {kern}\n\
             quantum_ns     {q}\n\
             policies       {pol}\n\
             cpu_widths     {cw}\n\
             rob_sizes      {rs}\n\
             scalars        ops_per_core {ops}, seed {seed}, \
             inner_threads {inner}",
            name = self.name,
            desc = self.description,
            samp = self.sampling.keyword(),
            pts = self.point_count(),
            plat = axis(&self.platforms, |p| p.clone()),
            cores = axis(&self.cores, |c| c.to_string()),
            l2 = axis(&self.l2_kib, |k| format!("{k}k")),
            fab = axis(&self.fabrics, |f| fabric_keyword(*f)),
            wl = axis(&self.workloads, |w| w.clone()),
            kern = axis(&self.kernels, |m| mode_keyword(*m).to_string()),
            q = axis(&self.quantum_ns, |q| q.to_string()),
            pol = axis(&self.quantum_policies, |p| policy_keyword(*p)),
            cw = axis(&self.cpu_widths, |w| w.to_string()),
            rs = axis(&self.rob_sizes, |r| r.to_string()),
            ops = self.ops_per_core,
            seed = self.seed,
            inner = self.inner_threads,
        )
    }
}

// ---- Sweep registry ----------------------------------------------------

/// All built-in sweeps, in listing order. `quick` is the CI / bench
/// workhorse; the next three are the classic DSE axes the example walks;
/// `random-dse` shows the sampled mode.
pub fn sweeps() -> Vec<SweepSpec> {
    let base = SweepSpec::default();
    vec![
        SweepSpec {
            workloads: vec![
                "app:synthetic".to_string(),
                "traffic:hotspot".to_string(),
            ],
            quantum_ns: vec![8, 16],
            ops_per_core: 128,
            ..base.clone()
        }
        .named(
            "quick",
            "4-point smoke grid — the CI shard/merge demo and the bench \
             workload",
        ),
        SweepSpec {
            cores: vec![4],
            l2_kib: vec![256, 512, 1024, 2048],
            workloads: vec!["app:canneal".to_string()],
            ops_per_core: 4096,
            ..base.clone()
        }
        .named(
            "l2-capacity",
            "private L2 capacity axis on the 4-core Fig. 4 star (canneal)",
        ),
        SweepSpec {
            cores: vec![4],
            fabrics: vec![
                Interconnect::Star,
                Interconnect::Ring,
                Interconnect::Mesh { cols: 2 },
            ],
            workloads: vec!["app:canneal".to_string()],
            ops_per_core: 4096,
            ..base.clone()
        }
        .named(
            "fabric-4core",
            "star vs ring vs 2-wide mesh at Table 2 caches (canneal)",
        ),
        SweepSpec {
            platforms: vec!["ring-16".to_string()],
            workloads: traffic::scenarios()
                .iter()
                .map(|t| format!("traffic:{}", t.name))
                .collect(),
            ops_per_core: 512,
            ..base.clone()
        }
        .named(
            "ring-traffic",
            "all six TrafficSpec patterns on the ring-16 fabric",
        ),
        SweepSpec {
            cores: vec![4],
            cpu_widths: vec![1, 2, 4],
            rob_sizes: vec![8, 64],
            workloads: vec!["traffic:hotspot".to_string()],
            ops_per_core: 512,
            ..base.clone()
        }
        .named(
            "o3-capacity",
            "O3 width x ROB capacity grid on the 4-core star (hotspot \
             traffic; docs/O3.md)",
        ),
        SweepSpec {
            sampling: Sampling::Random,
            samples: 24,
            platforms: vec![
                "fig4-2".to_string(),
                "fig4-8".to_string(),
                "ring-16".to_string(),
            ],
            workloads: vec![
                "app:blackscholes".to_string(),
                "traffic:hotspot".to_string(),
                "traffic:transpose".to_string(),
            ],
            quantum_ns: vec![4, 8, 16, 32],
            quantum_policies: vec![
                QuantumPolicy::Fixed,
                QuantumPolicy::Horizon,
            ],
            ..base.clone()
        }
        .named(
            "random-dse",
            "24 random points over platform x workload x quantum x policy",
        ),
    ]
}

/// Look up a sweep by name.
pub fn sweep(name: &str) -> Option<SweepSpec> {
    sweeps().into_iter().find(|s| s.name == name)
}

/// Resolve a CLI `--spec` argument: a sweep name, or a path to a sweep
/// TOML file (anything containing a path separator or ending in
/// `.toml`). The error lists the available sweeps.
pub fn resolve(arg: &str) -> Result<SweepSpec, SweepError> {
    if arg.ends_with(".toml") || arg.contains('/') {
        return SweepSpec::load(Path::new(arg));
    }
    sweep(arg).ok_or_else(|| {
        let names: Vec<String> =
            sweeps().iter().map(|s| s.name.clone()).collect();
        SweepError {
            errors: vec![format!(
                "unknown sweep `{arg}` — available sweeps: {}; or pass a \
                 sweep spec file path ending in .toml",
                names.join(", ")
            )],
        }
    })
}

/// One-line-per-sweep listing for the `sweep` subcommand.
pub fn render_list() -> String {
    let mut s = format!(
        "{:<14} {:>8} {:>7} description\n",
        "name", "sampling", "points"
    );
    for t in sweeps() {
        s.push_str(&format!(
            "{:<14} {:>8} {:>7} {}\n",
            t.name,
            t.sampling.keyword(),
            t.point_count(),
            t.description,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_validates() {
        SweepSpec::default().validate().unwrap();
    }

    #[test]
    fn keywords_roundtrip() {
        for m in [Mode::Serial, Mode::Parallel, Mode::Virtual] {
            assert_eq!(Mode::parse(mode_keyword(m)), Some(m));
        }
        for p in [
            QuantumPolicy::Fixed,
            QuantumPolicy::Horizon,
            QuantumPolicy::Hybrid { max_leap: 3 },
        ] {
            assert_eq!(parse_policy(&policy_keyword(p)), Some(p));
        }
        for f in [
            Interconnect::Star,
            Interconnect::Ring,
            Interconnect::Mesh { cols: 4 },
        ] {
            assert_eq!(parse_fabric(&fabric_keyword(f)), Some(f));
        }
        assert_eq!(parse_fabric("torus"), None);
        assert_eq!(parse_policy("sometimes"), None);
    }

    #[test]
    fn all_sweeps_validate_and_roundtrip() {
        let all = sweeps();
        assert!(all.len() >= 5);
        for t in all {
            t.validate()
                .unwrap_or_else(|e| panic!("sweep {}: {e}", t.name));
            let back = SweepSpec::from_toml(&t.to_toml())
                .unwrap_or_else(|e| panic!("sweep {} toml: {e}", t.name));
            assert_eq!(t, back, "sweep {} must round-trip", t.name);
        }
    }

    #[test]
    fn grid_count_is_axis_product() {
        let spec = SweepSpec {
            workloads: vec!["app:synthetic".into(), "app:stream".into()],
            kernels: vec![Mode::Serial, Mode::Virtual],
            quantum_ns: vec![4, 8, 16],
            ..SweepSpec::default()
        };
        assert_eq!(spec.grid_len(), Some(12));
        assert_eq!(spec.point_count(), 12);
        let sampled = SweepSpec {
            sampling: Sampling::Random,
            samples: 5,
            ..spec.clone()
        };
        assert_eq!(sampled.point_count(), 5);
        let clamped = SweepSpec {
            sampling: Sampling::Random,
            samples: 500,
            ..spec
        };
        assert_eq!(clamped.point_count(), 12, "samples clamp to the grid");
    }

    #[test]
    fn unknown_sweep_error_lists_sweeps() {
        let err = resolve("nope").unwrap_err();
        assert!(err.errors[0].contains("quick"), "{err}");
        assert!(err.errors[0].contains("random-dse"), "{err}");
    }

    #[test]
    fn unknown_key_is_rejected_with_hint() {
        let err = SweepSpec::from_toml("kernles = \"virtual\"\n").unwrap_err();
        assert!(err.errors[0].contains("unknown key `kernles`"), "{err}");
        assert!(err.to_string().contains("SWEEP.md"));
    }

    #[test]
    fn bad_axis_entries_are_rejected_with_choices() {
        let err =
            SweepSpec::from_toml("kernels = \"serial, warp\"\n").unwrap_err();
        assert!(err.errors[0].contains("warp"), "{err}");
        let err = SweepSpec::from_toml("fabrics = \"torus\"\n").unwrap_err();
        assert!(err.errors[0].contains("mesh:<cols>"), "{err}");
        let err = SweepSpec::from_toml("quantum_policies = \"soon\"\n")
            .unwrap_err();
        assert!(err.errors[0].contains("hybrid:<max_leap>"), "{err}");
    }

    #[test]
    fn empty_list_means_keep_platform_value() {
        let spec = SweepSpec::from_toml("cores = \"\"\n").unwrap();
        assert!(spec.cores.is_empty());
        assert_eq!(spec.axis_lens()[1], 1);
    }

    #[test]
    fn unknown_workload_prefix_is_rejected() {
        let spec = SweepSpec {
            workloads: vec!["synthetic".to_string()],
            ..SweepSpec::default()
        };
        let err = spec.validate().unwrap_err();
        assert!(err.errors[0].contains("app:<name>"), "{err}");
    }

    #[test]
    fn validation_collects_all_errors() {
        let spec = SweepSpec {
            platforms: vec!["atlantis".to_string()],
            kernels: Vec::new(),
            quantum_ns: vec![0],
            ops_per_core: 0,
            ..SweepSpec::default()
        };
        let err = spec.validate().unwrap_err();
        assert!(err.errors.len() >= 4, "{err}");
        assert!(err.errors.iter().any(|e| e.contains("atlantis")));
        assert!(err.errors.iter().any(|e| e.contains("kernels")));
        assert!(err.errors.iter().any(|e| e.contains("quantum_ns")));
        assert!(err.errors.iter().any(|e| e.contains("ops_per_core")));
    }

    #[test]
    fn cpu_axes_expand_and_reject_bad_entries() {
        let spec = SweepSpec {
            cpu_widths: vec![1, 2, 4],
            rob_sizes: vec![8, 64],
            ..SweepSpec::default()
        };
        spec.validate().unwrap();
        assert_eq!(spec.grid_len(), Some(6));
        let back = SweepSpec::from_toml(&spec.to_toml()).unwrap();
        assert_eq!(spec, back);
        let bad = SweepSpec {
            cpu_widths: vec![0],
            rob_sizes: vec![4096],
            ..SweepSpec::default()
        };
        let err = bad.validate().unwrap_err();
        assert!(err.errors.iter().any(|e| e.contains("cpu_widths")), "{err}");
        assert!(err.errors.iter().any(|e| e.contains("rob_sizes")), "{err}");
    }

    #[test]
    fn duplicate_axis_values_are_rejected() {
        let spec = SweepSpec {
            quantum_ns: vec![8, 8],
            ..SweepSpec::default()
        };
        let err = spec.validate().unwrap_err();
        assert!(err.errors[0].contains("quantum_ns"), "{err}");
        assert!(err.errors[0].contains("twice"), "{err}");
    }

    #[test]
    fn listing_mentions_every_sweep() {
        let s = render_list();
        for t in sweeps() {
            assert!(s.contains(&t.name), "listing misses {}", t.name);
        }
    }
}
