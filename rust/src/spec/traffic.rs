//! The declarative traffic API: [`TrafficSpec`] describes synthetic
//! memory traffic — the pattern, its intensity, read/write mix, sharing
//! degree and seed — independently of the platform it runs on
//! ([`super::SystemSpec`]) and of how the run is executed
//! ([`crate::config::RunConfig`]).
//!
//! The paper's evaluation drives every platform with the same CPU-bound
//! Table 3 apps, which barely exercise the interconnect: the ring and
//! mesh presets never see adversarial fabric load, so the border inbox
//! merge, the `XbarArbiter` and the stealing policies are gated only on
//! friendly inputs. A `TrafficSpec` closes that gap. It can be
//!
//! * built in code (the examples do this),
//! * loaded from / saved to TOML ([`TrafficSpec::from_toml`],
//!   [`TrafficSpec::to_toml`] — the same hand-rolled flat subset
//!   `SystemSpec` uses; the build environment is offline),
//! * taken from the named scenario registry ([`scenarios`],
//!   `parti-sim run --traffic hotspot`),
//! * validated with actionable errors ([`TrafficSpec::validate`]),
//!
//! and then *elaborated* into per-core op traces by
//! [`crate::workload::traffic::traffic_workload`]: deterministic
//! counter-based RNG streams keyed by `(seed, core)`, so the generated
//! traffic — and therefore the simulation — is independent of thread
//! count, steal decisions and host timing (`tests/traffic.rs` gates
//! bit-identity for every pattern on every topology).
//!
//! See `docs/TRAFFIC.md` for the schema, the pattern catalog and the
//! determinism argument.

use std::path::Path;

/// The six synthetic access patterns (`docs/TRAFFIC.md` has ASCII
/// sketches of each). A pattern only shapes the *remote* share of a
/// core's accesses — the `sharing_milli` knob says how many ops leave
/// the core's own private region.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum TrafficPattern {
    /// Every core targets a uniformly random core's private region —
    /// the baseline all-to-all load.
    #[default]
    UniformRandom,
    /// All remote traffic hammers a tiny shared window homed at the
    /// HN-F: per-line transaction serialisation and snoop stress.
    Hotspot,
    /// On an `s x s` grid of cores, core `(r, c)` targets core
    /// `(c, r)`'s region — the classic matrix-transpose exchange with
    /// long mesh paths (falls back to the antidiagonal partner
    /// `n-1-c` when the core count is not a perfect square).
    Transpose,
    /// Core `c` targets core `c+1`'s region (wrapping): the
    /// nearest-neighbour halo exchange, the shortest-path contrast to
    /// [`TrafficPattern::Transpose`].
    Neighbor,
    /// Cores pair up `(0,1), (2,3), ...`: the even core *stores* into
    /// the pair's shared buffer, the odd core *loads* from it —
    /// one-way data flow through the home node.
    ProducerConsumer,
    /// Alternates calm and saturating phases every
    /// [`TrafficSpec::phase_ops`] ops (remote targets as
    /// [`TrafficPattern::UniformRandom`]): exercises backpressure and
    /// per-window load swings.
    BurstyPhase,
}

/// Every pattern, in listing / documentation order.
pub const ALL_PATTERNS: &[TrafficPattern] = &[
    TrafficPattern::UniformRandom,
    TrafficPattern::Hotspot,
    TrafficPattern::Transpose,
    TrafficPattern::Neighbor,
    TrafficPattern::ProducerConsumer,
    TrafficPattern::BurstyPhase,
];

impl TrafficPattern {
    /// Parse the spec-TOML / CLI spelling (the kebab-case keyword).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "uniform-random" => TrafficPattern::UniformRandom,
            "hotspot" => TrafficPattern::Hotspot,
            "transpose" => TrafficPattern::Transpose,
            "neighbor" => TrafficPattern::Neighbor,
            "producer-consumer" => TrafficPattern::ProducerConsumer,
            "bursty-phase" => TrafficPattern::BurstyPhase,
            _ => return None,
        })
    }

    /// The TOML / CLI keyword.
    pub fn keyword(&self) -> &'static str {
        match self {
            TrafficPattern::UniformRandom => "uniform-random",
            TrafficPattern::Hotspot => "hotspot",
            TrafficPattern::Transpose => "transpose",
            TrafficPattern::Neighbor => "neighbor",
            TrafficPattern::ProducerConsumer => "producer-consumer",
            TrafficPattern::BurstyPhase => "bursty-phase",
        }
    }

    /// One-line characterisation for listings.
    pub fn describe(&self) -> &'static str {
        match self {
            TrafficPattern::UniformRandom => {
                "uniform spray over every core's private region"
            }
            TrafficPattern::Hotspot => {
                "all remote ops hammer one small HN-F-homed window"
            }
            TrafficPattern::Transpose => {
                "core (r,c) targets core (c,r) — long mesh paths"
            }
            TrafficPattern::Neighbor => {
                "core c targets core c+1 — nearest-neighbour halo"
            }
            TrafficPattern::ProducerConsumer => {
                "even cores store, odd cores load a per-pair buffer"
            }
            TrafficPattern::BurstyPhase => {
                "alternating calm and saturating phases"
            }
        }
    }
}

/// Validation failure: every problem found, each with a fix hint
/// (mirrors [`super::SpecError`] for the platform spec).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrafficError {
    pub errors: Vec<String>,
}

impl TrafficError {
    fn one(msg: impl Into<String>) -> Self {
        TrafficError { errors: vec![msg.into()] }
    }
}

impl std::fmt::Display for TrafficError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid TrafficSpec:")?;
        for e in &self.errors {
            write!(f, "\n  - {e}")?;
        }
        Ok(())
    }
}

impl std::error::Error for TrafficError {}

/// Upper bound on `working_lines`: a private region is
/// [`crate::workload::apps::PRIVATE_SPAN`] = 64 MiB of 64-byte lines.
pub const MAX_WORKING_LINES: u64 = 64 * 1024 * 1024 / 64;

/// Upper bound on `shared_lines` (a 64 MiB shared window).
pub const MAX_SHARED_LINES: u64 = 64 * 1024 * 1024 / 64;

/// A complete, serializable description of one synthetic traffic
/// scenario. All `_milli` knobs are per-1000 fractions, like the
/// existing `--io-milli` / `store_milli` conventions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TrafficSpec {
    /// Registry / file identity (informational; `traffic` lists it).
    pub name: String,
    /// One-line description for `traffic --describe`.
    pub description: String,
    /// Which access pattern shapes the remote ops.
    pub pattern: TrafficPattern,
    /// Generator seed; each core derives its own counter stream from
    /// `(seed, core)`, so elaboration never depends on host state.
    pub seed: u64,
    /// Offered intensity per 1000 issue slots (1..=1000): 1000 issues
    /// back-to-back, lower values insert compute gaps between ops.
    pub intensity_milli: u64,
    /// Intensity of the *burst* phases of `bursty-phase` (1..=1000);
    /// ignored by every other pattern.
    pub burst_intensity_milli: u64,
    /// Ops per phase for `bursty-phase` (even phases are calm, odd
    /// phases burst); ignored by every other pattern.
    pub phase_ops: usize,
    /// Store fraction per 1000 ops (0..=1000); `producer-consumer`
    /// overrides it on remote ops (producers store, consumers load).
    pub store_milli: u64,
    /// Sharing degree per 1000 ops (0..=1000): the fraction of ops
    /// that leave the core's own region for the pattern's target.
    pub sharing_milli: u64,
    /// Lines in each core's private working set (64-byte lines).
    pub working_lines: u64,
    /// Lines in the pattern's shared window: the hotspot window, or
    /// the per-pair producer-consumer buffer.
    pub shared_lines: u64,
}

impl Default for TrafficSpec {
    fn default() -> Self {
        TrafficSpec {
            name: "custom".to_string(),
            description: String::new(),
            pattern: TrafficPattern::UniformRandom,
            seed: 42,
            intensity_milli: 800,
            burst_intensity_milli: 1000,
            phase_ops: 256,
            store_milli: 300,
            sharing_milli: 500,
            working_lines: 4096,
            shared_lines: 64,
        }
    }
}

impl TrafficSpec {
    /// Rename in place (builder-style, used by the scenario registry).
    pub fn named(
        mut self,
        name: impl Into<String>,
        description: impl Into<String>,
    ) -> Self {
        self.name = name.into();
        self.description = description.into();
        self
    }

    /// Check every invariant elaboration relies on. Collects *all*
    /// problems, each with an actionable hint, instead of stopping at
    /// the first.
    pub fn validate(&self) -> Result<(), TrafficError> {
        let mut errors = Vec::new();
        let mut err = |m: String| errors.push(m);

        if self.intensity_milli == 0 || self.intensity_milli > 1000 {
            err(format!(
                "intensity_milli = {} is out of range — use 1..=1000 ops \
                 per 1000 issue slots (0 would generate no traffic at all)",
                self.intensity_milli
            ));
        }
        if self.burst_intensity_milli == 0 || self.burst_intensity_milli > 1000
        {
            err(format!(
                "burst_intensity_milli = {} is out of range — use 1..=1000 \
                 for the bursty-phase burst phases",
                self.burst_intensity_milli
            ));
        }
        if self.phase_ops == 0 {
            err("phase_ops = 0 — bursty-phase needs >= 1 op per phase"
                .to_string());
        }
        if self.store_milli > 1000 {
            err(format!(
                "store_milli = {} is out of range — use 0..=1000 \
                 (stores per 1000 ops)",
                self.store_milli
            ));
        }
        if self.sharing_milli > 1000 {
            err(format!(
                "sharing_milli = {} is out of range — use 0..=1000 \
                 (remote ops per 1000)",
                self.sharing_milli
            ));
        }
        if self.working_lines == 0 || self.working_lines > MAX_WORKING_LINES {
            err(format!(
                "working_lines = {} is out of range — use \
                 1..={MAX_WORKING_LINES} 64-byte lines (one private \
                 region is 64 MiB)",
                self.working_lines
            ));
        }
        if self.shared_lines == 0 || self.shared_lines > MAX_SHARED_LINES {
            err(format!(
                "shared_lines = {} is out of range — use \
                 1..={MAX_SHARED_LINES} 64-byte lines",
                self.shared_lines
            ));
        }
        if errors.is_empty() {
            Ok(())
        } else {
            Err(TrafficError { errors })
        }
    }

    // ---- TOML ----------------------------------------------------------

    /// Serialise to the flat TOML subset (`key = value`, `#` comments,
    /// double-quoted strings). [`TrafficSpec::from_toml`] round-trips
    /// this exactly; `tests/properties.rs` holds the property test.
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str("# parti-sim traffic spec (docs/TRAFFIC.md)\n");
        s.push_str(&format!("name = \"{}\"\n", self.name));
        s.push_str(&format!("description = \"{}\"\n", self.description));
        s.push_str(&format!("pattern = \"{}\"\n", self.pattern.keyword()));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str(&format!("intensity_milli = {}\n", self.intensity_milli));
        s.push_str(&format!(
            "burst_intensity_milli = {}\n",
            self.burst_intensity_milli
        ));
        s.push_str(&format!("phase_ops = {}\n", self.phase_ops));
        s.push_str(&format!("store_milli = {}\n", self.store_milli));
        s.push_str(&format!("sharing_milli = {}\n", self.sharing_milli));
        s.push_str(&format!("working_lines = {}\n", self.working_lines));
        s.push_str(&format!("shared_lines = {}\n", self.shared_lines));
        s
    }

    /// Parse the format emitted by [`TrafficSpec::to_toml`]. Unknown
    /// keys are rejected (typos must not silently fall back to
    /// defaults); missing keys keep the defaults. The parsed spec is
    /// validated before being returned.
    pub fn from_toml(text: &str) -> Result<Self, TrafficError> {
        let mut spec = TrafficSpec::default();
        let mut errors = Vec::new();

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let lineno = lineno + 1;
            let Some((k, v)) = line.split_once('=') else {
                errors.push(format!(
                    "line {lineno}: expected `key = value`, got `{line}`"
                ));
                continue;
            };
            let (k, v) = (k.trim(), v.trim());
            // String values are double-quoted; numbers are bare.
            let as_str = v.strip_prefix('"').and_then(|s| s.strip_suffix('"'));
            let mut as_num = || -> Option<u64> {
                match v.parse::<u64>() {
                    Ok(n) => Some(n),
                    Err(e) => {
                        errors.push(format!(
                            "line {lineno}: {k} = {v}: {e} (expected an \
                             unsigned integer)"
                        ));
                        None
                    }
                }
            };
            match k {
                "name" | "description" | "pattern" => {
                    let Some(sv) = as_str else {
                        errors.push(format!(
                            "line {lineno}: {k} must be a double-quoted \
                             string, e.g. {k} = \"...\""
                        ));
                        continue;
                    };
                    match k {
                        "name" => spec.name = sv.to_string(),
                        "description" => spec.description = sv.to_string(),
                        "pattern" => match TrafficPattern::parse(sv) {
                            Some(p) => spec.pattern = p,
                            None => errors.push(format!(
                                "line {lineno}: pattern = \"{sv}\" — use one \
                                 of uniform-random, hotspot, transpose, \
                                 neighbor, producer-consumer, bursty-phase"
                            )),
                        },
                        _ => unreachable!(),
                    }
                }
                "seed" => {
                    if let Some(n) = as_num() {
                        spec.seed = n;
                    }
                }
                "intensity_milli" => {
                    if let Some(n) = as_num() {
                        spec.intensity_milli = n;
                    }
                }
                "burst_intensity_milli" => {
                    if let Some(n) = as_num() {
                        spec.burst_intensity_milli = n;
                    }
                }
                "phase_ops" => {
                    if let Some(n) = as_num() {
                        spec.phase_ops = n as usize;
                    }
                }
                "store_milli" => {
                    if let Some(n) = as_num() {
                        spec.store_milli = n;
                    }
                }
                "sharing_milli" => {
                    if let Some(n) = as_num() {
                        spec.sharing_milli = n;
                    }
                }
                "working_lines" => {
                    if let Some(n) = as_num() {
                        spec.working_lines = n;
                    }
                }
                "shared_lines" => {
                    if let Some(n) = as_num() {
                        spec.shared_lines = n;
                    }
                }
                _ => errors.push(format!(
                    "line {lineno}: unknown key `{k}` — see docs/TRAFFIC.md \
                     for the schema"
                )),
            }
        }

        if !errors.is_empty() {
            return Err(TrafficError { errors });
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load a spec from a `.toml` file on disk.
    pub fn load(path: &Path) -> Result<Self, TrafficError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            TrafficError::one(format!("cannot read {}: {e}", path.display()))
        })?;
        Self::from_toml(&text)
    }

    /// Multi-line human description for `traffic --describe`.
    pub fn describe(&self) -> String {
        format!(
            "{name}: {desc}\n\
             pattern        {pat} — {pdesc}\n\
             intensity      {int}/1000 (burst {burst}/1000 every \
             {phase} ops for bursty-phase)\n\
             mix            {st}/1000 stores, {sh}/1000 remote\n\
             footprint      {wl} working lines/core, {sl} shared lines\n\
             seed           {seed}",
            name = self.name,
            desc = self.description,
            pat = self.pattern.keyword(),
            pdesc = self.pattern.describe(),
            int = self.intensity_milli,
            burst = self.burst_intensity_milli,
            phase = self.phase_ops,
            st = self.store_milli,
            sh = self.sharing_milli,
            wl = self.working_lines,
            sl = self.shared_lines,
            seed = self.seed,
        )
    }
}

// ---- Scenario registry -------------------------------------------------

/// All built-in scenarios, one per pattern, in listing order. Each is
/// named by its pattern keyword and tuned so the pattern's signature
/// behaviour is visible (`tests/traffic.rs` gates the shapes).
pub fn scenarios() -> Vec<TrafficSpec> {
    let base = TrafficSpec::default();
    vec![
        base.clone().named(
            "uniform-random",
            "all-to-all spray over every private region — the baseline \
             interconnect load",
        ),
        TrafficSpec {
            pattern: TrafficPattern::Hotspot,
            sharing_milli: 700,
            store_milli: 400,
            shared_lines: 8,
            ..base.clone()
        }
        .named(
            "hotspot",
            "every remote op hammers an 8-line HN-F window — per-line \
             serialisation and snoop stress",
        ),
        TrafficSpec {
            pattern: TrafficPattern::Transpose,
            sharing_milli: 600,
            ..base.clone()
        }
        .named(
            "transpose",
            "matrix-transpose partner exchange — the long-path corner \
             of a mesh",
        ),
        TrafficSpec {
            pattern: TrafficPattern::Neighbor,
            sharing_milli: 600,
            ..base.clone()
        }
        .named(
            "neighbor",
            "nearest-neighbour halo exchange — the short-path contrast \
             to transpose",
        ),
        TrafficSpec {
            pattern: TrafficPattern::ProducerConsumer,
            shared_lines: 256,
            ..base.clone()
        }
        .named(
            "producer-consumer",
            "even cores fill a per-pair shared buffer, odd cores drain \
             it — one-way flow through the home node",
        ),
        TrafficSpec {
            pattern: TrafficPattern::BurstyPhase,
            intensity_milli: 150,
            burst_intensity_milli: 1000,
            phase_ops: 256,
            ..base.clone()
        }
        .named(
            "bursty-phase",
            "calm/saturating phases alternating every 256 ops — \
             backpressure and window-load swings",
        ),
    ]
}

/// Look up a scenario by name.
pub fn scenario(name: &str) -> Option<TrafficSpec> {
    scenarios().into_iter().find(|s| s.name == name)
}

/// Resolve a CLI `--traffic` argument: a scenario name, or a path to a
/// traffic TOML file (anything containing a path separator or ending
/// in `.toml`). The error lists the available scenarios.
pub fn resolve(arg: &str) -> Result<TrafficSpec, TrafficError> {
    if arg.ends_with(".toml") || arg.contains('/') {
        return TrafficSpec::load(Path::new(arg));
    }
    scenario(arg).ok_or_else(|| {
        let names: Vec<String> =
            scenarios().iter().map(|s| s.name.clone()).collect();
        TrafficError {
            errors: vec![format!(
                "unknown traffic scenario `{arg}` — available scenarios: \
                 {}; or pass a traffic spec file path ending in .toml",
                names.join(", ")
            )],
        }
    })
}

/// One-line-per-scenario listing for the `traffic` subcommand.
pub fn render_list() -> String {
    let mut s = format!(
        "{:<18} {:>9} {:>6} {:>6} description\n",
        "name", "intensity", "store", "remote"
    );
    for t in scenarios() {
        s.push_str(&format!(
            "{:<18} {:>9} {:>6} {:>6} {}\n",
            t.name,
            t.intensity_milli,
            t.store_milli,
            t.sharing_milli,
            t.description,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_validates() {
        TrafficSpec::default().validate().unwrap();
    }

    #[test]
    fn pattern_keywords_roundtrip() {
        for &p in ALL_PATTERNS {
            assert_eq!(TrafficPattern::parse(p.keyword()), Some(p));
        }
        assert_eq!(TrafficPattern::parse("zipf"), None);
    }

    #[test]
    fn all_scenarios_validate_and_roundtrip() {
        let all = scenarios();
        assert_eq!(all.len(), ALL_PATTERNS.len(), "one scenario per pattern");
        for t in all {
            t.validate()
                .unwrap_or_else(|e| panic!("scenario {}: {e}", t.name));
            let back = TrafficSpec::from_toml(&t.to_toml())
                .unwrap_or_else(|e| panic!("scenario {} toml: {e}", t.name));
            assert_eq!(t, back, "scenario {} must round-trip", t.name);
        }
    }

    #[test]
    fn scenario_names_match_pattern_keywords() {
        for (t, &p) in scenarios().iter().zip(ALL_PATTERNS) {
            assert_eq!(t.name, p.keyword());
            assert_eq!(t.pattern, p);
            assert_eq!(resolve(&t.name).unwrap(), *t);
        }
    }

    #[test]
    fn unknown_scenario_error_lists_scenarios() {
        let err = resolve("nope").unwrap_err();
        assert!(err.errors[0].contains("hotspot"), "{err}");
        assert!(err.errors[0].contains("bursty-phase"), "{err}");
    }

    #[test]
    fn unknown_key_is_rejected_with_hint() {
        let err = TrafficSpec::from_toml("patern = \"hotspot\"\n").unwrap_err();
        assert!(err.errors[0].contains("unknown key `patern`"), "{err}");
        assert!(err.to_string().contains("TRAFFIC.md"));
    }

    #[test]
    fn unknown_pattern_is_rejected_with_choices() {
        let err =
            TrafficSpec::from_toml("pattern = \"zipf\"\n").unwrap_err();
        assert!(err.errors[0].contains("producer-consumer"), "{err}");
    }

    #[test]
    fn zero_intensity_is_rejected() {
        let spec =
            TrafficSpec { intensity_milli: 0, ..TrafficSpec::default() };
        let err = spec.validate().unwrap_err();
        assert!(err.errors[0].contains("intensity_milli"), "{err}");
        assert!(
            TrafficSpec::from_toml("intensity_milli = 0\n").is_err(),
            "parse must validate"
        );
    }

    #[test]
    fn out_of_range_sharing_is_rejected() {
        let spec =
            TrafficSpec { sharing_milli: 1001, ..TrafficSpec::default() };
        let err = spec.validate().unwrap_err();
        assert!(err.errors[0].contains("sharing_milli"), "{err}");
    }

    #[test]
    fn validation_collects_all_errors() {
        let spec = TrafficSpec {
            intensity_milli: 0,
            phase_ops: 0,
            working_lines: 0,
            ..TrafficSpec::default()
        };
        let err = spec.validate().unwrap_err();
        assert!(err.errors.len() >= 3, "{err}");
        assert!(err.errors.iter().any(|e| e.contains("phase_ops")));
        assert!(err.errors.iter().any(|e| e.contains("working_lines")));
    }

    #[test]
    fn listing_mentions_every_scenario() {
        let s = render_list();
        for t in scenarios() {
            assert!(s.contains(&t.name), "listing misses {}", t.name);
        }
    }
}
