//! The declarative platform API: [`SystemSpec`] describes a complete
//! simulated MPSoC — core count, CPU model, per-level cache geometry,
//! memory channels and the interconnect topology — independently of how a
//! run is executed (kernel, quantum, workload all stay in
//! [`crate::config::RunConfig`]).
//!
//! This is the design-space-exploration surface the paper motivates:
//! parti-gem5 inherits gem5's custom cache and interconnect models, so a
//! reproduction that can only build the Fig. 4 hierarchical star is not
//! exploring anything. A `SystemSpec` can instead be
//!
//! * built in code (the examples do this),
//! * loaded from / saved to TOML ([`SystemSpec::from_toml`],
//!   [`SystemSpec::to_toml`] — hand-rolled flat subset, the build
//!   environment is offline),
//! * taken from the named preset registry
//!   ([`platforms::presets`], `parti-sim run --platform fig4-8`),
//! * validated with actionable errors ([`SystemSpec::validate`]),
//!
//! and then *elaborated* into components and time domains by
//! [`crate::ruby::topology::build_system`]. Domain partitioning (one
//! domain per core plus one shared domain) is computed from the spec, so
//! every topology runs unchanged on all three PDES kernels, under every
//! `--quantum-policy`, with `--steal`, and under the deterministic
//! border-ordered inbox handoff (`tests/platforms.rs` gates bit-identity
//! on every preset).
//!
//! See `docs/PLATFORMS.md` for the schema, the preset table and a guide to
//! adding a topology.

pub mod platforms;
pub mod sweep;
pub mod traffic;

use crate::config::{CacheConfig, RunConfig, SystemConfig};
use crate::cpu::CpuModel;

/// The interconnect fabric between the per-core L2s and the shared HN-F.
///
/// All three topologies keep the paper's domain discipline: per-core
/// resources (including the core's local router and throttle) live in the
/// core's own time domain, the fabric *stations* live in the shared
/// domain, and every domain-crossing link is a uni-directional
/// [`crate::ruby::throttle::Throttle`] (Fig. 5c). Hop latency is the
/// spec's NoC latency, charged per link by the existing
/// [`crate::ruby::router::Router`] components.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum Interconnect {
    /// Fig. 4's hierarchical star: one central station (`rc`) every core
    /// hangs off. One fabric hop between any L2 and the HN-F.
    #[default]
    Star,
    /// A uni-directional ring of one station per core; the HN-F attaches
    /// at station 0. Average hop count grows with the core count — the
    /// cheap-to-wire, high-latency end of the design space.
    Ring,
    /// A `cols`-wide 2D mesh with deterministic X-then-Y routing; the
    /// HN-F attaches at station 0 (the north-west corner). Requires
    /// `cores % cols == 0` (full rows).
    Mesh { cols: usize },
}

impl Interconnect {
    /// Parse the spec-TOML / CLI spelling: `star`, `ring`, `mesh`
    /// (`mesh_cols` carries the width separately in TOML).
    pub fn parse(s: &str, mesh_cols: usize) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "star" => Interconnect::Star,
            "ring" => Interconnect::Ring,
            "mesh" => Interconnect::Mesh { cols: mesh_cols },
            _ => return None,
        })
    }

    /// The TOML / CLI keyword (the mesh width travels separately).
    pub fn keyword(&self) -> &'static str {
        match self {
            Interconnect::Star => "star",
            Interconnect::Ring => "ring",
            Interconnect::Mesh { .. } => "mesh",
        }
    }

    /// Human-readable form (`mesh(8x4)` needs the core count for rows).
    pub fn describe(&self, cores: usize) -> String {
        match self {
            Interconnect::Star => "star".to_string(),
            Interconnect::Ring => format!("ring({cores})"),
            Interconnect::Mesh { cols } => {
                format!("mesh({}x{})", cols, cores.div_ceil(*cols))
            }
        }
    }
}

/// Micro-architecture knobs for the staged O3 pipeline
/// ([`crate::cpu::O3Cpu`], docs/O3.md). The Minor model ignores them —
/// its geometry is fixed (one outstanding access, width 1). Every knob
/// is a sweepable axis ([`sweep::SweepSpec`]) and round-trips through
/// the platform TOML as `cpu_width`, `cpu_rob_size`, `cpu_iq_size`,
/// `cpu_lsq_size`, `cpu_fetch_buf` and `cpu_mshrs`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct CpuSpec {
    /// Ops per stage per cycle (dispatch/issue/commit budgets).
    pub width: usize,
    /// Reorder-buffer entries.
    pub rob_size: usize,
    /// Issue-queue entries (dispatched, waiting to issue).
    pub iq_size: usize,
    /// Split LSQ capacity: loads and stores each get this many in-flight
    /// slots.
    pub lsq_size: usize,
    /// Fetch-buffer entries (ops buffered ahead of dispatch).
    pub fetch_buf: usize,
    /// Sequencer MSHR cap: coherent requests in flight per core before
    /// the sequencer queues ([`crate::ruby::sequencer::Sequencer`]).
    pub mshrs: usize,
}

impl Default for CpuSpec {
    fn default() -> Self {
        CpuSpec {
            width: 4,
            rob_size: 64,
            iq_size: 32,
            lsq_size: 16,
            fetch_buf: 8,
            mshrs: 8,
        }
    }
}

/// A complete, serializable description of one simulated platform.
///
/// Field defaults are the paper's Table 2 machine with the Fig. 4 star —
/// [`SystemSpec::default`] elaborates to exactly the system the legacy
/// `RunConfig` flags built before this API existed.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemSpec {
    /// Registry / file identity (informational; `platforms` lists it).
    pub name: String,
    /// One-line description for `platforms --describe`.
    pub description: String,
    /// Simulated cores (= per-core time domains).
    pub cores: usize,
    /// CPU model driving every core (`atomic`/`kvm` are serial-only).
    pub cpu: CpuModel,
    /// CPU clock in MHz.
    pub cpu_mhz: u64,
    pub l1i: CacheConfig,
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    /// Shared L3 (the HN-F's array).
    pub l3: CacheConfig,
    pub line_bytes: u64,
    pub interconnect: Interconnect,
    /// NoC link + router latency in tenths of a ns (Table 2: 0.5 ns).
    pub noc_latency_ns_x10: u64,
    /// Router buffer size in messages on finite (domain-crossing) links.
    pub router_buffer: usize,
    /// Link flits charged for a data message.
    pub data_flits: u64,
    /// DRAM clock in MHz.
    pub dram_mhz: u64,
    /// Independent DRAM channels behind the HN-F, line-interleaved.
    pub mem_channels: usize,
    /// IO accesses per 1000 ops (exercises the §4.3 crossbar path).
    pub io_milli: u64,
    /// O3 pipeline geometry (ignored by non-O3 models).
    pub cpu_spec: CpuSpec,
}

impl Default for SystemSpec {
    fn default() -> Self {
        SystemSpec::from_parts(&SystemConfig::default(), CpuModel::O3)
            .named("table2", "Table 2 defaults (Fig. 4 star)")
    }
}

/// Validation failure: every problem found, each with a fix hint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecError {
    pub errors: Vec<String>,
}

impl SpecError {
    fn one(msg: impl Into<String>) -> Self {
        SpecError { errors: vec![msg.into()] }
    }
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid SystemSpec:")?;
        for e in &self.errors {
            write!(f, "\n  - {e}")?;
        }
        Ok(())
    }
}

impl std::error::Error for SpecError {}

/// Hard cap on simulated cores (one time domain each; the paper's largest
/// MPSoC is 120).
pub const MAX_CORES: usize = 1024;

impl SystemSpec {
    /// Build a spec from the legacy configuration pair — the thin
    /// conversion that keeps every old `RunConfig` flag working.
    pub fn from_parts(sys: &SystemConfig, cpu: CpuModel) -> Self {
        SystemSpec {
            name: "custom".to_string(),
            description: String::new(),
            cores: sys.cores,
            cpu,
            cpu_mhz: sys.cpu_mhz,
            l1i: sys.l1i,
            l1d: sys.l1d,
            l2: sys.l2,
            l3: sys.l3,
            line_bytes: sys.line_bytes,
            interconnect: sys.interconnect,
            noc_latency_ns_x10: sys.noc_latency_ns_x10,
            router_buffer: sys.router_buffer,
            data_flits: sys.data_flits,
            dram_mhz: sys.dram_mhz,
            mem_channels: sys.mem_channels,
            io_milli: sys.io_milli,
            cpu_spec: sys.cpu_spec,
        }
    }

    /// Rename in place (builder-style, used by the preset registry).
    pub fn named(
        mut self,
        name: impl Into<String>,
        description: impl Into<String>,
    ) -> Self {
        self.name = name.into();
        self.description = description.into();
        self
    }

    /// The legacy configuration pair this spec describes (inverse of
    /// [`SystemSpec::from_parts`]).
    pub fn to_parts(&self) -> (SystemConfig, CpuModel) {
        let sys = SystemConfig {
            cores: self.cores,
            cpu_mhz: self.cpu_mhz,
            l1i: self.l1i,
            l1d: self.l1d,
            l2: self.l2,
            l3: self.l3,
            line_bytes: self.line_bytes,
            interconnect: self.interconnect,
            noc_latency_ns_x10: self.noc_latency_ns_x10,
            router_buffer: self.router_buffer,
            data_flits: self.data_flits,
            dram_mhz: self.dram_mhz,
            mem_channels: self.mem_channels,
            io_milli: self.io_milli,
            cpu_spec: self.cpu_spec,
        };
        (sys, self.cpu)
    }

    /// Overwrite the platform half of a [`RunConfig`] (cores, CPU model,
    /// caches, interconnect); run knobs (mode, quantum, workload, policy
    /// flags) are untouched. CLI flag overrides are applied *after* this.
    pub fn apply_to(&self, cfg: &mut RunConfig) {
        let (sys, cpu) = self.to_parts();
        cfg.system = sys;
        cfg.cpu_model = cpu;
    }

    /// Per-hop NoC latency in ticks (mirrors
    /// [`crate::config::SystemConfig::noc_latency`] — same x10 encoding,
    /// one conversion for both the legacy and the spec path).
    pub fn noc_latency(&self) -> crate::sim::time::Tick {
        self.noc_latency_ns_x10 * crate::sim::time::NS / 10
    }

    /// Number of fabric stations the interconnect elaborates to (the
    /// star's single central router, or one per core).
    pub fn n_stations(&self) -> usize {
        match self.interconnect {
            Interconnect::Star => 1,
            Interconnect::Ring | Interconnect::Mesh { .. } => self.cores,
        }
    }

    /// Check every invariant elaboration relies on. Collects *all*
    /// problems, each with an actionable hint, instead of stopping at the
    /// first.
    pub fn validate(&self) -> Result<(), SpecError> {
        let mut errors = Vec::new();
        let mut err = |m: String| errors.push(m);

        if self.cores == 0 || self.cores > MAX_CORES {
            err(format!(
                "cores = {} is out of range — set cores between 1 and {MAX_CORES}",
                self.cores
            ));
        }
        if !self.line_bytes.is_power_of_two() || self.line_bytes < 8 {
            err(format!(
                "line_bytes = {} must be a power of two >= 8 (gem5 uses 64)",
                self.line_bytes
            ));
        }
        for (what, c) in [
            ("l1i", &self.l1i),
            ("l1d", &self.l1d),
            ("l2", &self.l2),
            ("l3", &self.l3),
        ] {
            if c.assoc == 0 {
                err(format!("{what}_assoc = 0 — associativity must be >= 1"));
            }
            let way_bytes = self.line_bytes * c.assoc.max(1) as u64;
            if c.size_bytes == 0 || c.size_bytes % way_bytes != 0 {
                err(format!(
                    "{what}_size_bytes = {} must be a nonzero multiple of \
                     line_bytes * {what}_assoc = {} (whole cache sets)",
                    c.size_bytes, way_bytes
                ));
            }
            if c.latency_ns == 0 {
                err(format!(
                    "{what}_latency_ns = 0 — every cache level needs >= 1 ns \
                     (Table 2 uses 1/1/4/6)"
                ));
            }
        }
        if self.cpu_mhz == 0 {
            err("cpu_mhz = 0 — set a nonzero CPU clock (Table 2: 2000)".into());
        }
        if self.dram_mhz == 0 {
            err("dram_mhz = 0 — set a nonzero DRAM clock (Table 2: 1000)".into());
        }
        if self.router_buffer == 0 {
            err(
                "router_buffer = 0 would deadlock every finite link — \
                 set it to >= 1 message (Table 2: 4)"
                    .into(),
            );
        }
        if self.mem_channels == 0 || self.mem_channels > 16 {
            err(format!(
                "mem_channels = {} is out of range — use 1..=16 \
                 line-interleaved DRAM channels",
                self.mem_channels
            ));
        }
        for (what, v, max) in [
            ("cpu_width", self.cpu_spec.width, 16),
            ("cpu_rob_size", self.cpu_spec.rob_size, 512),
            ("cpu_iq_size", self.cpu_spec.iq_size, 512),
            ("cpu_lsq_size", self.cpu_spec.lsq_size, 256),
            ("cpu_fetch_buf", self.cpu_spec.fetch_buf, 256),
            ("cpu_mshrs", self.cpu_spec.mshrs, 64),
        ] {
            if v == 0 || v > max {
                err(format!(
                    "{what} = {v} is out of range — the O3 pipeline needs \
                     1..={max} (docs/O3.md lists the defaults)"
                ));
            }
        }
        match self.interconnect {
            Interconnect::Star => {}
            Interconnect::Ring => {
                if self.cores < 2 {
                    err(format!(
                        "interconnect = ring needs cores >= 2 (got {}) — \
                         a 1-station ring has no links; use star",
                        self.cores
                    ));
                }
            }
            Interconnect::Mesh { cols } => {
                if cols == 0 || cols > self.cores.max(1) {
                    err(format!(
                        "mesh_cols = {cols} is out of range — choose \
                         1..={} (one station per core)",
                        self.cores.max(1)
                    ));
                } else if self.cores % cols != 0 {
                    err(format!(
                        "mesh: cores = {} is not a multiple of mesh_cols = \
                         {cols} — X-then-Y routing needs full rows; choose \
                         a divisor of the core count",
                        self.cores
                    ));
                }
            }
        }
        if errors.is_empty() {
            Ok(())
        } else {
            Err(SpecError { errors })
        }
    }

    // ---- TOML ----------------------------------------------------------

    /// Serialise to the flat TOML subset (`key = value`, `#` comments,
    /// double-quoted strings). [`SystemSpec::from_toml`] round-trips this
    /// exactly; `tests/platforms.rs` holds the property test.
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str("# parti-sim platform spec (docs/PLATFORMS.md)\n");
        s.push_str(&format!("name = \"{}\"\n", self.name));
        s.push_str(&format!("description = \"{}\"\n", self.description));
        s.push_str(&format!("cores = {}\n", self.cores));
        s.push_str(&format!(
            "cpu = \"{}\"\n",
            match self.cpu {
                CpuModel::Kvm => "kvm",
                CpuModel::Atomic => "atomic",
                CpuModel::Minor => "minor",
                CpuModel::O3 => "o3",
            }
        ));
        s.push_str(&format!("cpu_mhz = {}\n", self.cpu_mhz));
        s.push_str(&format!("cpu_width = {}\n", self.cpu_spec.width));
        s.push_str(&format!("cpu_rob_size = {}\n", self.cpu_spec.rob_size));
        s.push_str(&format!("cpu_iq_size = {}\n", self.cpu_spec.iq_size));
        s.push_str(&format!("cpu_lsq_size = {}\n", self.cpu_spec.lsq_size));
        s.push_str(&format!("cpu_fetch_buf = {}\n", self.cpu_spec.fetch_buf));
        s.push_str(&format!("cpu_mshrs = {}\n", self.cpu_spec.mshrs));
        for (p, c) in [
            ("l1i", &self.l1i),
            ("l1d", &self.l1d),
            ("l2", &self.l2),
            ("l3", &self.l3),
        ] {
            s.push_str(&format!("{p}_size_bytes = {}\n", c.size_bytes));
            s.push_str(&format!("{p}_assoc = {}\n", c.assoc));
            s.push_str(&format!("{p}_latency_ns = {}\n", c.latency_ns));
        }
        s.push_str(&format!("line_bytes = {}\n", self.line_bytes));
        s.push_str(&format!(
            "interconnect = \"{}\"\n",
            self.interconnect.keyword()
        ));
        if let Interconnect::Mesh { cols } = self.interconnect {
            s.push_str(&format!("mesh_cols = {cols}\n"));
        }
        s.push_str(&format!(
            "noc_latency_ns_x10 = {}\n",
            self.noc_latency_ns_x10
        ));
        s.push_str(&format!("router_buffer = {}\n", self.router_buffer));
        s.push_str(&format!("data_flits = {}\n", self.data_flits));
        s.push_str(&format!("dram_mhz = {}\n", self.dram_mhz));
        s.push_str(&format!("mem_channels = {}\n", self.mem_channels));
        s.push_str(&format!("io_milli = {}\n", self.io_milli));
        s
    }

    /// Parse the format emitted by [`SystemSpec::to_toml`]. Unknown keys
    /// are rejected (typos must not silently fall back to defaults);
    /// missing keys keep the Table 2 defaults. The parsed spec is
    /// validated before being returned.
    pub fn from_toml(text: &str) -> Result<Self, SpecError> {
        let mut spec = SystemSpec::default().named("custom", "");
        let mut interconnect_kw: Option<String> = None;
        let mut mesh_cols: Option<usize> = None;
        let mut errors = Vec::new();

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let lineno = lineno + 1;
            let Some((k, v)) = line.split_once('=') else {
                errors.push(format!(
                    "line {lineno}: expected `key = value`, got `{line}`"
                ));
                continue;
            };
            let (k, v) = (k.trim(), v.trim());
            // String values are double-quoted; numbers are bare.
            let as_str = v.strip_prefix('"').and_then(|s| s.strip_suffix('"'));
            let mut as_num = || -> Option<u64> {
                match v.parse::<u64>() {
                    Ok(n) => Some(n),
                    Err(e) => {
                        errors.push(format!(
                            "line {lineno}: {k} = {v}: {e} (expected an \
                             unsigned integer)"
                        ));
                        None
                    }
                }
            };
            match k {
                "name" | "description" | "cpu" | "interconnect" => {
                    let Some(sv) = as_str else {
                        errors.push(format!(
                            "line {lineno}: {k} must be a double-quoted \
                             string, e.g. {k} = \"...\""
                        ));
                        continue;
                    };
                    match k {
                        "name" => spec.name = sv.to_string(),
                        "description" => spec.description = sv.to_string(),
                        "cpu" => match CpuModel::parse(sv) {
                            Some(m) => spec.cpu = m,
                            None => errors.push(format!(
                                "line {lineno}: cpu = \"{sv}\" — use one of \
                                 o3, minor, atomic, kvm"
                            )),
                        },
                        "interconnect" => {
                            interconnect_kw = Some(sv.to_string())
                        }
                        _ => unreachable!(),
                    }
                }
                "cores" => {
                    if let Some(n) = as_num() {
                        spec.cores = n as usize;
                    }
                }
                "cpu_mhz" => {
                    if let Some(n) = as_num() {
                        spec.cpu_mhz = n;
                    }
                }
                "cpu_width" => {
                    if let Some(n) = as_num() {
                        spec.cpu_spec.width = n as usize;
                    }
                }
                "cpu_rob_size" => {
                    if let Some(n) = as_num() {
                        spec.cpu_spec.rob_size = n as usize;
                    }
                }
                "cpu_iq_size" => {
                    if let Some(n) = as_num() {
                        spec.cpu_spec.iq_size = n as usize;
                    }
                }
                "cpu_lsq_size" => {
                    if let Some(n) = as_num() {
                        spec.cpu_spec.lsq_size = n as usize;
                    }
                }
                "cpu_fetch_buf" => {
                    if let Some(n) = as_num() {
                        spec.cpu_spec.fetch_buf = n as usize;
                    }
                }
                "cpu_mshrs" => {
                    if let Some(n) = as_num() {
                        spec.cpu_spec.mshrs = n as usize;
                    }
                }
                "line_bytes" => {
                    if let Some(n) = as_num() {
                        spec.line_bytes = n;
                    }
                }
                "noc_latency_ns_x10" => {
                    if let Some(n) = as_num() {
                        spec.noc_latency_ns_x10 = n;
                    }
                }
                "router_buffer" => {
                    if let Some(n) = as_num() {
                        spec.router_buffer = n as usize;
                    }
                }
                "data_flits" => {
                    if let Some(n) = as_num() {
                        spec.data_flits = n;
                    }
                }
                "dram_mhz" => {
                    if let Some(n) = as_num() {
                        spec.dram_mhz = n;
                    }
                }
                "mem_channels" => {
                    if let Some(n) = as_num() {
                        spec.mem_channels = n as usize;
                    }
                }
                "io_milli" => {
                    if let Some(n) = as_num() {
                        spec.io_milli = n;
                    }
                }
                "mesh_cols" => {
                    if let Some(n) = as_num() {
                        mesh_cols = Some(n as usize);
                    }
                }
                _ => {
                    let target = if k.starts_with("l1i_") {
                        Some(&mut spec.l1i)
                    } else if k.starts_with("l1d_") {
                        Some(&mut spec.l1d)
                    } else if k.starts_with("l2_") {
                        Some(&mut spec.l2)
                    } else if k.starts_with("l3_") {
                        Some(&mut spec.l3)
                    } else {
                        None
                    };
                    let field =
                        k.split_once('_').map(|(_, f)| f).unwrap_or("");
                    match (target, field) {
                        (Some(c), "size_bytes") => {
                            if let Some(n) = as_num() {
                                c.size_bytes = n;
                            }
                        }
                        (Some(c), "assoc") => {
                            if let Some(n) = as_num() {
                                c.assoc = n as usize;
                            }
                        }
                        (Some(c), "latency_ns") => {
                            if let Some(n) = as_num() {
                                c.latency_ns = n;
                            }
                        }
                        _ => errors.push(format!(
                            "line {lineno}: unknown key `{k}` — see \
                             docs/PLATFORMS.md for the schema"
                        )),
                    }
                }
            }
        }

        if let Some(kw) = interconnect_kw {
            match Interconnect::parse(&kw, mesh_cols.unwrap_or(0)) {
                Some(Interconnect::Mesh { cols }) if mesh_cols.is_none() => {
                    let _ = cols;
                    errors.push(
                        "interconnect = \"mesh\" needs a `mesh_cols = N` \
                         line (the mesh width)"
                            .to_string(),
                    );
                }
                Some(ic) => spec.interconnect = ic,
                None => errors.push(format!(
                    "interconnect = \"{kw}\" — use one of star, ring, mesh"
                )),
            }
        } else if let Some(cols) = mesh_cols {
            errors.push(format!(
                "mesh_cols = {cols} without `interconnect = \"mesh\"` — \
                 add the interconnect line or drop mesh_cols"
            ));
        }

        if !errors.is_empty() {
            return Err(SpecError { errors });
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load a spec from a `.toml` file on disk.
    pub fn load(path: &std::path::Path) -> Result<Self, SpecError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            SpecError::one(format!("cannot read {}: {e}", path.display()))
        })?;
        Self::from_toml(&text)
    }

    /// Multi-line human description for `platforms --describe`.
    pub fn describe(&self) -> String {
        let kib = |b: u64| format!("{} KiB", b / 1024);
        format!(
            "{name}: {desc}\n\
             cores          {cores} x {cpu:?} @ {mhz} MHz\n\
             interconnect   {ic}\n\
             caches         L1I {l1i}/{l1ia}w  L1D {l1d}/{l1da}w  \
             L2 {l2}/{l2a}w  L3 {l3}/{l3a}w  ({lb} B lines)\n\
             memory         {ch} channel(s) @ {dram} MHz\n\
             noc            {noc_ns:.1} ns/hop, {rb}-msg buffers, \
             {df} data flits\n\
             io             {io} accesses per 1000 ops\n\
             o3 pipeline    width {w}, rob {rob}, iq {iq}, lsq {lsq}x2, \
             fetch-buf {fb}, {mshrs} mshrs",
            name = self.name,
            desc = self.description,
            cores = self.cores,
            cpu = self.cpu,
            mhz = self.cpu_mhz,
            ic = self.interconnect.describe(self.cores),
            l1i = kib(self.l1i.size_bytes),
            l1ia = self.l1i.assoc,
            l1d = kib(self.l1d.size_bytes),
            l1da = self.l1d.assoc,
            l2 = kib(self.l2.size_bytes),
            l2a = self.l2.assoc,
            l3 = kib(self.l3.size_bytes),
            l3a = self.l3.assoc,
            lb = self.line_bytes,
            ch = self.mem_channels,
            dram = self.dram_mhz,
            noc_ns = self.noc_latency_ns_x10 as f64 / 10.0,
            rb = self.router_buffer,
            df = self.data_flits,
            io = self.io_milli,
            w = self.cpu_spec.width,
            rob = self.cpu_spec.rob_size,
            iq = self.cpu_spec.iq_size,
            lsq = self.cpu_spec.lsq_size,
            fb = self.cpu_spec.fetch_buf,
            mshrs = self.cpu_spec.mshrs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_matches_legacy_default_config() {
        let spec = SystemSpec::default();
        let (sys, cpu) = spec.to_parts();
        assert_eq!(sys, SystemConfig::default());
        assert_eq!(cpu, CpuModel::O3);
        assert_eq!(spec.noc_latency(), sys.noc_latency(), "x10 mirrors");
        spec.validate().unwrap();
    }

    #[test]
    fn parts_roundtrip() {
        let sys = SystemConfig {
            interconnect: Interconnect::Mesh { cols: 4 },
            mem_channels: 2,
            ..SystemConfig::with_cores(16)
        };
        let spec = SystemSpec::from_parts(&sys, CpuModel::Minor);
        let (back, cpu) = spec.to_parts();
        assert_eq!(back, sys);
        assert_eq!(cpu, CpuModel::Minor);
    }

    #[test]
    fn toml_roundtrip_ring() {
        let spec = SystemSpec {
            cores: 8,
            interconnect: Interconnect::Ring,
            mem_channels: 2,
            ..SystemSpec::default()
        }
        .named("r", "a ring");
        let back = SystemSpec::from_toml(&spec.to_toml()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn toml_roundtrip_mesh_keeps_cols() {
        let spec = SystemSpec {
            cores: 12,
            interconnect: Interconnect::Mesh { cols: 4 },
            ..SystemSpec::default()
        }
        .named("m", "a mesh");
        let back = SystemSpec::from_toml(&spec.to_toml()).unwrap();
        assert_eq!(back.interconnect, Interconnect::Mesh { cols: 4 });
        assert_eq!(spec, back);
    }

    #[test]
    fn toml_roundtrip_cpu_knobs() {
        let spec = SystemSpec {
            cpu_spec: CpuSpec {
                width: 2,
                rob_size: 8,
                iq_size: 4,
                lsq_size: 2,
                fetch_buf: 3,
                mshrs: 1,
            },
            ..SystemSpec::default()
        }
        .named("k", "tiny o3");
        let toml = spec.to_toml();
        assert!(toml.contains("cpu_rob_size = 8"), "{toml}");
        let back = SystemSpec::from_toml(&toml).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn cpu_knobs_out_of_range_rejected() {
        let mut spec = SystemSpec::default();
        spec.cpu_spec.width = 0;
        spec.cpu_spec.rob_size = 100_000;
        let err = spec.validate().unwrap_err();
        assert!(err.errors.iter().any(|e| e.contains("cpu_width")), "{err}");
        assert!(
            err.errors.iter().any(|e| e.contains("cpu_rob_size")),
            "{err}"
        );
    }

    #[test]
    fn unknown_key_is_rejected_with_hint() {
        let err = SystemSpec::from_toml("coers = 4\n").unwrap_err();
        assert!(err.errors[0].contains("unknown key `coers`"), "{err}");
        assert!(err.to_string().contains("PLATFORMS.md"));
    }

    #[test]
    fn mesh_without_cols_is_rejected() {
        let err =
            SystemSpec::from_toml("interconnect = \"mesh\"\n").unwrap_err();
        assert!(err.errors[0].contains("mesh_cols"), "{err}");
    }

    #[test]
    fn validation_collects_all_errors() {
        let mut spec = SystemSpec {
            cores: 0,
            router_buffer: 0,
            ..SystemSpec::default()
        };
        spec.l2.assoc = 0;
        let err = spec.validate().unwrap_err();
        assert!(err.errors.len() >= 3, "{err}");
        assert!(err.errors.iter().any(|e| e.contains("cores")));
        assert!(err.errors.iter().any(|e| e.contains("router_buffer")));
    }

    #[test]
    fn mesh_ragged_rows_rejected() {
        let mut spec = SystemSpec {
            cores: 5,
            interconnect: Interconnect::Mesh { cols: 4 },
            ..SystemSpec::default()
        };
        let err = spec.validate().unwrap_err();
        assert!(err.errors[0].contains("multiple of mesh_cols"), "{err}");
        spec.cores = 8;
        spec.validate().unwrap();
    }

    #[test]
    fn ring_of_one_rejected() {
        let mut spec = SystemSpec {
            cores: 1,
            interconnect: Interconnect::Ring,
            ..SystemSpec::default()
        };
        assert!(spec.validate().is_err());
        spec.interconnect = Interconnect::Star;
        spec.validate().unwrap();
    }

    #[test]
    fn n_stations_per_topology() {
        let mut spec = SystemSpec { cores: 8, ..SystemSpec::default() };
        assert_eq!(spec.n_stations(), 1);
        spec.interconnect = Interconnect::Ring;
        assert_eq!(spec.n_stations(), 8);
        spec.interconnect = Interconnect::Mesh { cols: 4 };
        assert_eq!(spec.n_stations(), 8);
    }
}
