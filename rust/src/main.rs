//! parti-sim — CLI launcher for the parti-gem5 reproduction.
//!
//! ```text
//! parti-sim run      --app blackscholes --cores 8 --mode virtual --quantum-ns 8
//! parti-sim run      --platform ring-16 --mode parallel  # named platform
//! parti-sim run      --platform my_soc.toml              # spec from disk
//! parti-sim run      --traffic hotspot --threads 8       # synthetic traffic
//! parti-sim compare  --app canneal --cores 32           # serial vs PDES
//! parti-sim sweep run --spec quick --shard 0/2          # journaled DSE
//! parti-sim run      --checkpoint-at 64000              # freeze at a border
//! parti-sim run      --restore parti.ckpt --mode parallel --threads 8
//! parti-sim ckpt     info|validate|diff ...             # snapshot tools
//! parti-sim platforms                                   # preset registry
//! parti-sim traffic                                     # traffic scenarios
//! parti-sim fig7|fig8|fig9|tables|protocols             # paper artefacts
//! parti-sim ffwd     --app dedup --cores 4              # KVM fast-forward
//! parti-sim help
//! ```

use anyhow::Result;

use parti_sim::config::{Mode, RunConfig};
use parti_sim::cpu::CpuModel;
use parti_sim::harness::figures::{
    atomic_vs_timing, fig7, fig8, fig9, fig_quantum_policy, fig_traffic,
    render_quantum_rows, render_rows, render_traffic_rows, FigureOpts,
};
use parti_sim::harness::{
    compare_modes, restore_and_run, run_once, run_to_checkpoint, tables,
};
use parti_sim::pdes::{HostModel, RunOutcome};
use parti_sim::sched::{
    BucketShape, InboxOrder, QuantumPolicy, QueueKind, XbarArb,
};
use parti_sim::sim::time::NS;
use parti_sim::spec::{platforms, SystemSpec};
use parti_sim::stats::Summary;
use parti_sim::util::cli::Args;

const HELP: &str = "\
parti-sim — parti-gem5 reproduction: parallelised timing-mode MPSoC simulation

USAGE: parti-sim <command> [--flag value]...

COMMANDS
  run        one simulation run
  compare    serial reference vs PDES: speedup + accuracy
  platforms  list platform presets (--describe NAME, --dump NAME,
             --validate FILE.toml)
  traffic    list synthetic-traffic scenarios (--describe NAME,
             --dump NAME, --validate FILE.toml; docs/TRAFFIC.md)
  sweep      journaled DSE sweeps: `sweep run --spec S`, `sweep list`
             (--describe, --dump, --validate as above; docs/SWEEP.md)
  ckpt       snapshot tools: `ckpt info F`, `ckpt validate F`,
             `ckpt diff A B` (exit 1 on divergence; docs/CHECKPOINT.md)
  fig7       core & quantum sweep (synthetic + blackscholes)
  fig8       PARSEC subset + STREAM @ 32 cores
  fig9       cache miss-rate accuracy (same runs as fig8)
  figq       adaptive-quantum sweep: fixed vs horizon barrier savings
  figt       traffic sweep: topology presets × traffic patterns
  tables     paper tables 1-3 (--which 0|1|2|3)
  protocols  §3.3 atomic-vs-timing throughput comparison
  ffwd       KVM fast-forward (functional warm-up)
  help       this text

RUN/COMPARE/FFWD FLAGS
  --platform P      named preset (see `platforms`) or a spec
                    .toml file: core count, CPU model, caches,
                    memory channels and interconnect topology
                    (star|ring|mesh) come from the spec; other
                    flags still override it    [legacy Table 2 star]
  --app NAME        synthetic|blackscholes|canneal|dedup|ferret|
                    fluidanimate|swaptions|stream     [synthetic]
  --traffic T       named traffic scenario (see `traffic`) or
                    a TrafficSpec .toml file; replaces --app
                    with elaborated synthetic traffic
                    (docs/TRAFFIC.md)                 [off]
  --cores N         simulated cores          [4, or the platform's]
  --cpu MODEL       o3|minor|atomic|kvm               [o3]
  --cpu-width N     O3 per-stage width (docs/O3.md)   [4]
  --rob-size N      O3 reorder-buffer entries         [64]
  --iq-size N       O3 issue-queue entries            [32]
  --lsq-size N      O3 load/store queue entries each  [16]
  --fetch-buf N     O3 fetch-buffer entries           [8]
  --mshrs N         sequencer MSHRs (coherent reqs
                    in flight per core)               [8]
  --mode MODE       serial|parallel|virtual           [serial]
  --queue KIND      bucket|heap event queue           [bucket]
  --bucket-width N  bucket-queue slot width in ticks
                    (power of two; docs/PERF.md)      [2048]
  --bucket-slots N  bucket-queue ring slots
                    (power of two >= 2)               [64]
  --quantum-ns N    quantum t_qΔ in ns                [16]
  --quantum-policy P  fixed|horizon|hybrid window advance
                    (horizon leaps dead windows)      [fixed]
  --max-leap N      hybrid policy: max quanta leapt
                    per border                        [64]
  --steal           claim-based window work stealing
                    (parallel mode; adds no nondeterminism)
  --threads N       host threads for parallel mode
                    (0 = one per domain)              [0]
  --inbox-order O   border|host Ruby message handoff:
                    border = deterministic border-ordered
                    merge, host = paper's racy order   [border]
  --xbar-arb A      border|host IO-crossbar layer
                    arbitration: border = deterministic
                    border-staged grants, host = paper's
                    mid-window try_lock (§4.3)         [border]
  --ops N           trace ops per core                [4096]
  --seed N                                            [42]
  --host-cores N    modeled host cores (virtual mode) [64]
  --io-milli N      IO accesses per 1000 ops (§4.3)   [0]
  --profile         record per-phase border wall time
                    (window/freeze/border-sync/publish;
                    docs/PERF.md) — host-side only,
                    simulation results are unchanged
  --checkpoint-at T freeze at the first quantum border >= T
                    ticks (snap rule, docs/CHECKPOINT.md) and
                    write a snapshot; needs a windowed kernel
                    (defaults --mode to virtual)      [off]
  --checkpoint-out F  snapshot file for --checkpoint-at
                                                [parti.ckpt]
  --restore F       resume a snapshot bit-identically: pinned
                    axes come from the file, free axes (mode,
                    threads, steal, queue, ...) from the flags
  --json            emit the summary as JSON

  Flags are documented in detail in docs/CLI.md.

SWEEP FLAGS (sweep run; docs/SWEEP.md)
  --spec S          named sweep (see `sweep list`) or a
                    SweepSpec .toml file              [required]
  --journal PATH    append-only JSONL results file
                    (one record per point)  [sweep_journal.jsonl]
  --outer N         outer pool width (whole simulations);
                    default follows the budget rule
                    outer x inner <= --budget-cores
  --budget-cores N  host-core budget for the rule   [host cores]
  --shard i/N       run only points with index = i (mod N)
  --resume          skip journaled points; damaged lines are
                    reported with line numbers and re-run
  --max-points K    stop after K new points (smoke tests)
  --from-checkpoint F  fork every point that shares the
                    snapshot's pinned axes from this file
                    instead of cold-starting it
                    (docs/CHECKPOINT.md)              [off]

FIGURE FLAGS
  --ops N           trace ops per core                [2048]
  --max-cores N     cap swept core counts             [120 / 32]
  --host-cores N    modeled host cores                [64]
  --threaded        use the threaded kernel (needs a many-core host)
  --platform P      sweep on this platform's topology/geometry
                    (core counts the spec cannot scale to are skipped)
";

/// Resolve `--platform` (preset name or spec file), if given.
fn platform_arg(a: &Args) -> Result<Option<SystemSpec>> {
    match a.get("platform") {
        None => Ok(None),
        Some(p) => platforms::resolve(p)
            .map(Some)
            .map_err(|e| anyhow::anyhow!("{e}")),
    }
}

fn run_config(a: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig {
        app: a.get_str("app", "synthetic"),
        ops_per_core: a.get_usize("ops", 4096),
        seed: a.get_u64("seed", 42),
        ..Default::default()
    };
    cfg.system.cores = 4; // legacy CLI default
    if let Some(spec) = platform_arg(a)? {
        cfg.apply_spec(&spec);
    }
    // Explicit flags override the platform; their defaults are whatever
    // the platform (or the legacy baseline) already set.
    cfg.system.cores = a.get_usize("cores", cfg.system.cores);
    cfg.system.io_milli = a.get_u64("io-milli", cfg.system.io_milli);
    cfg.traffic = a.get("traffic").map(String::from);
    if let Some(cpu) = a.get("cpu") {
        cfg.cpu_model = CpuModel::parse(cpu)
            .ok_or_else(|| anyhow::anyhow!("bad --cpu {cpu}"))?;
    }
    let cs = &mut cfg.system.cpu_spec;
    cs.width = a.get_usize("cpu-width", cs.width);
    cs.rob_size = a.get_usize("rob-size", cs.rob_size);
    cs.iq_size = a.get_usize("iq-size", cs.iq_size);
    cs.lsq_size = a.get_usize("lsq-size", cs.lsq_size);
    cs.fetch_buf = a.get_usize("fetch-buf", cs.fetch_buf);
    cs.mshrs = a.get_usize("mshrs", cs.mshrs);
    let mode = a.get_str("mode", "serial");
    cfg.mode = Mode::parse(&mode)
        .ok_or_else(|| anyhow::anyhow!("bad --mode {mode}"))?;
    let queue = a.get_str("queue", "bucket");
    cfg.queue = QueueKind::parse(&queue)
        .ok_or_else(|| anyhow::anyhow!("bad --queue {queue}"))?;
    cfg.bucket_shape = BucketShape {
        width: a.get_u64("bucket-width", cfg.bucket_shape.width),
        nbuckets: a.get_usize("bucket-slots", cfg.bucket_shape.nbuckets),
    }
    .validate()
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    cfg.quantum = a.get_u64("quantum-ns", 16) * NS;
    let qp = a.get_str("quantum-policy", "fixed");
    cfg.quantum_policy = QuantumPolicy::parse(&qp)
        .ok_or_else(|| anyhow::anyhow!("bad --quantum-policy {qp}"))?;
    if let QuantumPolicy::Hybrid { max_leap } = &mut cfg.quantum_policy {
        *max_leap = a.get_u64("max-leap", *max_leap as u64).max(1) as u32;
    }
    cfg.steal = a.has("steal");
    cfg.threads = a.get_usize("threads", 0);
    let order = a.get_str("inbox-order", "border");
    cfg.inbox_order = InboxOrder::parse(&order)
        .ok_or_else(|| anyhow::anyhow!("bad --inbox-order {order}"))?;
    let arb = a.get_str("xbar-arb", "border");
    cfg.xbar_arb = XbarArb::parse(&arb)
        .ok_or_else(|| anyhow::anyhow!("bad --xbar-arb {arb}"))?;
    cfg.host_cores = a.get_usize("host-cores", 64);
    cfg.profile = a.has("profile");
    Ok(cfg)
}

fn figure_opts(a: &Args, default_max_cores: usize) -> Result<FigureOpts> {
    let qp = a.get_str("quantum-policy", "fixed");
    Ok(FigureOpts {
        ops_per_core: a.get_usize("ops", 2048),
        seed: a.get_u64("seed", 42),
        host_cores: a.get_usize("host-cores", 64),
        threaded: a.has("threaded"),
        max_cores: a.get_usize("max-cores", default_max_cores),
        quantum_policy: QuantumPolicy::parse(&qp)
            .ok_or_else(|| anyhow::anyhow!("bad --quantum-policy {qp}"))?,
        platform: platform_arg(a)?,
    })
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    match args.command.as_deref() {
        Some("run") => {
            let mut cfg = run_config(&args)?;
            let ckpt_at = match args.get("checkpoint-at") {
                Some(t) => Some(t.parse::<u64>().map_err(|e| {
                    anyhow::anyhow!("bad --checkpoint-at {t}: {e}")
                })?),
                None => None,
            };
            let ckpt_out = std::path::PathBuf::from(
                args.get_str("checkpoint-out", "parti.ckpt"),
            );
            let restore = args.get("restore");
            if (ckpt_at.is_some() || restore.is_some())
                && args.get("mode").is_none()
            {
                // Checkpointing needs a windowed kernel; keep `run`'s
                // serial default for plain runs only.
                cfg.mode = Mode::Virtual;
            }
            let (cfg, result) = if let Some(path) = restore {
                let bytes = std::fs::read(path).map_err(|e| {
                    anyhow::anyhow!("cannot read checkpoint {path}: {e}")
                })?;
                let snap = parti_sim::ckpt::read_snapshot(&bytes)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let (outcome, eff) = restore_and_run(&snap, &cfg, ckpt_at)?;
                eprintln!(
                    "restored {path}: resuming at border {}",
                    snap.header.tick
                );
                let result = match outcome {
                    RunOutcome::Finished(result) => result,
                    RunOutcome::Checkpointed { machine, border, result } => {
                        let bytes = parti_sim::ckpt::snapshot_machine(
                            &machine, &eff, border,
                        )?;
                        std::fs::write(&ckpt_out, &bytes).map_err(|e| {
                            anyhow::anyhow!(
                                "cannot write checkpoint {}: {e}",
                                ckpt_out.display()
                            )
                        })?;
                        eprintln!(
                            "checkpoint: border {border} -> {} ({} bytes)",
                            ckpt_out.display(),
                            bytes.len()
                        );
                        result
                    }
                };
                (eff, result)
            } else if let Some(at) = ckpt_at {
                let (result, border) =
                    run_to_checkpoint(&cfg, at, &ckpt_out)?;
                match border {
                    Some(b) => eprintln!(
                        "checkpoint: border {b} -> {}",
                        ckpt_out.display()
                    ),
                    None => eprintln!(
                        "run finished before tick {at}; no checkpoint \
                         written"
                    ),
                }
                (cfg, result)
            } else {
                let result = run_once(&cfg)?;
                (cfg, result)
            };
            let s = Summary::from_result(&result);
            if args.has("json") {
                println!("{}", s.to_json());
            } else {
                print_summary(&cfg, &s);
            }
        }
        Some("ckpt") => {
            use parti_sim::ckpt;
            let path_arg = |i: usize, what: &str| -> Result<&String> {
                args.rest.get(i).ok_or_else(|| {
                    anyhow::anyhow!(
                        "ckpt: missing {what} (see `parti-sim help`)"
                    )
                })
            };
            let read_file = |p: &str| -> Result<Vec<u8>> {
                std::fs::read(p).map_err(|e| {
                    anyhow::anyhow!("cannot read checkpoint {p}: {e}")
                })
            };
            match args.rest.first().map(|s| s.as_str()) {
                Some("info") => {
                    let path = path_arg(1, "snapshot file")?;
                    let bytes = read_file(path)?;
                    let mut r = ckpt::StateReader::new(&bytes);
                    let h = ckpt::Header::read(&mut r)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    println!("file: {path} ({} bytes)", bytes.len());
                    println!(
                        "format: v{} (flags {:#06x})",
                        h.version, h.flags
                    );
                    println!("spec hash: {:#018x}", h.spec_hash);
                    println!(
                        "border tick: {}  quantum: {}",
                        h.tick, h.quantum
                    );
                    println!(
                        "domains: {}  components: {}",
                        h.n_domains, h.n_components
                    );
                    let mut seen = std::collections::BTreeMap::new();
                    while !r.is_done() {
                        let (tag, payload, _) =
                            ckpt::format::read_record(&mut r)
                                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                        let e = seen.entry(tag).or_insert((0usize, 0usize));
                        e.0 += 1;
                        e.1 += payload.len();
                    }
                    println!("records:");
                    for (tag, (count, bytes)) in &seen {
                        println!(
                            "  {:<10} {:>4} record(s) {:>10} payload byte(s)",
                            ckpt::format::tag_name(*tag),
                            count,
                            bytes
                        );
                    }
                }
                Some("validate") => {
                    let path = path_arg(1, "snapshot file")?;
                    let bytes = read_file(path)?;
                    let snap = ckpt::read_snapshot(&bytes)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    let spec = snap
                        .spec()
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    let cfg = snap
                        .config()
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    let pending: usize =
                        snap.domains.iter().map(|d| d.events.len()).sum();
                    println!(
                        "ok: {path} is a valid v{} snapshot",
                        snap.header.version
                    );
                    println!(
                        "  platform `{}` ({} cores), app {}, border {}",
                        spec.name,
                        cfg.system.cores,
                        cfg.traffic.as_deref().unwrap_or(&cfg.app),
                        snap.header.tick
                    );
                    println!(
                        "  {} domain(s), {} component(s), {} pending \
                         event(s)",
                        snap.header.n_domains,
                        snap.header.n_components,
                        pending
                    );
                }
                Some("diff") => {
                    let pa = path_arg(1, "first snapshot file")?;
                    let pb = path_arg(2, "second snapshot file")?;
                    let a = read_file(pa)?;
                    let b = read_file(pb)?;
                    match ckpt::diff_snapshots(&a, &b)
                        .map_err(|e| anyhow::anyhow!("{e}"))?
                    {
                        None => println!(
                            "identical: {pa} == {pb} ({} bytes)",
                            a.len()
                        ),
                        Some(report) => {
                            println!("{pa} vs {pb}:\n  {report}");
                            std::process::exit(1);
                        }
                    }
                }
                other => {
                    return Err(anyhow::anyhow!(
                        "unknown ckpt verb `{}` — use `ckpt info F`, \
                         `ckpt validate F` or `ckpt diff A B`",
                        other.unwrap_or("")
                    ));
                }
            }
        }
        Some("compare") => {
            let mut serial_cfg = run_config(&args)?;
            serial_cfg.mode = Mode::Serial;
            let mut par_cfg = run_config(&args)?;
            if par_cfg.mode == Mode::Serial {
                par_cfg.mode = Mode::Virtual;
            }
            let mut host = HostModel {
                h_cores: par_cfg.host_cores,
                ..Default::default()
            };
            let row = compare_modes(&serial_cfg, &par_cfg, &mut host)?;
            println!(
                "app={} cores={} quantum={}ns\n  speedup(H={}): {:.2}x\n  sim-time error: {:.2}%\n  miss-rate err (pp) l1i/l1d/l2/l3: {:.3}/{:.3}/{:.3}/{:.3}\n  checksums: {}",
                par_cfg.app,
                row.cores,
                row.quantum_ns,
                par_cfg.host_cores,
                row.speedup,
                row.sim_time_error * 100.0,
                row.miss_rate_err_pp[0],
                row.miss_rate_err_pp[1],
                row.miss_rate_err_pp[2],
                row.miss_rate_err_pp[3],
                if row.checksum_match { "match" } else { "MISMATCH" }
            );
        }
        Some("platforms") => {
            if let Some(name) = args.get("describe") {
                let spec = platforms::resolve(name)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                println!("{}", spec.describe());
            } else if let Some(name) = args.get("dump") {
                let spec = platforms::resolve(name)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                print!("{}", spec.to_toml());
            } else if let Some(path) = args.get("validate") {
                let spec = platforms::resolve(path)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                println!(
                    "ok: platform `{}` is valid ({} cores, {})",
                    spec.name,
                    spec.cores,
                    spec.interconnect.describe(spec.cores)
                );
            } else {
                print!("{}", platforms::render_list());
                println!(
                    "\nUse `run --platform <name|file.toml>`; `--describe`, \
                     `--dump`, `--validate` inspect a spec."
                );
            }
        }
        Some("traffic") => {
            use parti_sim::spec::traffic;
            if let Some(name) = args.get("describe") {
                let spec =
                    traffic::resolve(name).map_err(|e| anyhow::anyhow!("{e}"))?;
                println!("{}", spec.describe());
            } else if let Some(name) = args.get("dump") {
                let spec =
                    traffic::resolve(name).map_err(|e| anyhow::anyhow!("{e}"))?;
                print!("{}", spec.to_toml());
            } else if let Some(path) = args.get("validate") {
                let spec =
                    traffic::resolve(path).map_err(|e| anyhow::anyhow!("{e}"))?;
                spec.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
                println!(
                    "ok: traffic spec `{}` is valid ({}, seed {})",
                    spec.name,
                    spec.pattern.describe(),
                    spec.seed
                );
            } else {
                print!("{}", traffic::render_list());
                println!(
                    "\nUse `run --traffic <name|file.toml>`; `--describe`, \
                     `--dump`, `--validate` inspect a spec (docs/TRAFFIC.md)."
                );
            }
        }
        Some("sweep") => {
            use parti_sim::harness::sweep as orch;
            use parti_sim::spec::sweep;
            if let Some(name) = args.get("describe") {
                let spec =
                    sweep::resolve(name).map_err(|e| anyhow::anyhow!("{e}"))?;
                println!("{}", spec.describe());
            } else if let Some(name) = args.get("dump") {
                let spec =
                    sweep::resolve(name).map_err(|e| anyhow::anyhow!("{e}"))?;
                print!("{}", spec.to_toml());
            } else if let Some(path) = args.get("validate") {
                let spec =
                    sweep::resolve(path).map_err(|e| anyhow::anyhow!("{e}"))?;
                let points = orch::expand(&spec)?;
                println!(
                    "ok: sweep `{}` is valid ({} point(s))",
                    spec.name,
                    points.len()
                );
            } else {
                match args.rest.first().map(|s| s.as_str()) {
                    Some("run") => {
                        let arg = args.get("spec").ok_or_else(|| {
                            anyhow::anyhow!(
                                "sweep run needs --spec <name|file.toml> \
                                 (see `sweep list`)"
                            )
                        })?;
                        let spec = sweep::resolve(arg)
                            .map_err(|e| anyhow::anyhow!("{e}"))?;
                        let mut opts = orch::SweepOptions {
                            journal: args
                                .get_str("journal", "sweep_journal.jsonl")
                                .into(),
                            resume: args.has("resume"),
                            ..Default::default()
                        };
                        if let Some(o) = args.get("outer") {
                            opts.outer = Some(o.parse().map_err(|e| {
                                anyhow::anyhow!("bad --outer {o}: {e}")
                            })?);
                        }
                        opts.budget_cores =
                            args.get_usize("budget-cores", opts.budget_cores);
                        if let Some(s) = args.get("shard") {
                            opts.shard = Some(orch::parse_shard(s)?);
                        }
                        if let Some(k) = args.get("max-points") {
                            opts.max_points = Some(k.parse().map_err(|e| {
                                anyhow::anyhow!("bad --max-points {k}: {e}")
                            })?);
                        }
                        opts.from_checkpoint = args
                            .get("from-checkpoint")
                            .map(std::path::PathBuf::from);
                        let out = orch::run_sweep(&spec, &opts)?;
                        for i in &out.repaired {
                            eprintln!(
                                "journal: repaired damaged line {} ({}); \
                                 its point was re-run",
                                i.line, i.error
                            );
                        }
                        println!(
                            "sweep `{}`: {} point(s), {} skipped \
                             (journaled), {} ran on outer pool of {}",
                            spec.name, out.points, out.skipped, out.ran,
                            out.outer
                        );
                        println!("journal: {}\n", opts.journal.display());
                        print!("{}", tables::sweep_table(&out.records));
                    }
                    None | Some("list") => {
                        print!("{}", sweep::render_list());
                        println!(
                            "\nUse `sweep run --spec <name|file.toml>` \
                             (--journal, --outer, --shard i/N, --resume); \
                             `--describe`, `--dump`, `--validate` inspect \
                             a spec (docs/SWEEP.md)."
                        );
                    }
                    Some(other) => {
                        return Err(anyhow::anyhow!(
                            "unknown sweep verb `{other}` — use `sweep \
                             run` or `sweep list`"
                        ));
                    }
                }
            }
        }
        Some("fig7") => {
            let opts = figure_opts(&args, 120)?;
            println!("Fig. 7 — speedup & simulated-time error vs cores × quantum\n");
            println!("{}", render_rows(&fig7(&opts)?));
        }
        Some("fig8") => {
            let opts = figure_opts(&args, 32)?;
            println!("Fig. 8 — PARSEC + STREAM @ {} cores\n", 32.min(opts.max_cores));
            println!("{}", render_rows(&fig8(&opts)?));
        }
        Some("fig9") => {
            let opts = figure_opts(&args, 32)?;
            println!("Fig. 9 — cache miss-rate absolute errors (pp)\n");
            println!("{}", render_rows(&fig9(&opts)?));
        }
        Some("figq") => {
            let opts = figure_opts(&args, 16)?;
            println!(
                "Adaptive quantum — fixed vs horizon: modeled speedup and \
                 barrier savings\n(results are bit-identical across \
                 policies; only border count and wall-clock change)\n"
            );
            println!("{}", render_quantum_rows(&fig_quantum_policy(&opts)?));
        }
        Some("figt") => {
            let opts = figure_opts(&args, 64)?;
            println!(
                "Traffic sweep — topology presets × traffic patterns on the \
                 measurement kernel\n(all reported counters are \
                 deterministic; docs/TRAFFIC.md)\n"
            );
            println!("{}", render_traffic_rows(&fig_traffic(&opts)?));
        }
        Some("tables") => {
            let which = args.get_usize("which", 0);
            let cfg = parti_sim::config::SystemConfig::default();
            if which == 0 || which == 1 {
                println!("{}", tables::table1());
            }
            if which == 0 || which == 2 {
                println!("{}", tables::table2(&cfg));
            }
            if which == 0 || which == 3 {
                println!("{}", tables::table3());
            }
        }
        Some("protocols") => {
            let p = atomic_vs_timing(
                args.get_usize("cores", 4),
                args.get_usize("ops", 8192),
            )?;
            println!(
                "atomic: {:.3} MIPS\ntiming(O3+Ruby): {:.3} MIPS\nratio: {:.1}% (paper §3.3: ~20%)",
                p.atomic_mips,
                p.timing_mips,
                p.ratio * 100.0
            );
        }
        Some("ffwd") => {
            let mut cfg = run_config(&args)?;
            cfg.cpu_model = CpuModel::Kvm;
            cfg.mode = Mode::Serial;
            let result = run_once(&cfg)?;
            println!(
                "fast-forwarded {} ops in {:.1} ms host time (functional warm-up)",
                result.stats.sum_suffix(".committed_ops"),
                result.host_ns as f64 / 1e6
            );
        }
        _ => print!("{HELP}"),
    }
    Ok(())
}

fn print_summary(cfg: &RunConfig, s: &Summary) {
    println!(
        "app={} cores={} cpu={:?} mode={:?} fabric={} mem-ch={}",
        cfg.app,
        cfg.system.cores,
        cfg.cpu_model,
        cfg.mode,
        cfg.system.interconnect.describe(cfg.system.cores),
        cfg.system.mem_channels
    );
    println!(
        "  simulated: {:.6} ms  ({} ticks)",
        s.sim_seconds * 1e3,
        s.sim_ticks
    );
    println!(
        "  host: {:.1} ms   {:.0} events/s   {:.4} MIPS",
        s.host_ns as f64 / 1e6,
        s.events_per_sec,
        s.mips
    );
    println!(
        "  ops={}  events={}  domains={}",
        s.committed_ops, s.events, s.n_domains
    );
    println!(
        "  pdes: cross={} postponed={} tpp_mean={:.2}ns barriers={}",
        s.cross_events, s.postponed, s.tpp_mean_ns, s.barriers
    );
    println!(
        "  sched: policy={:?} skipped_quanta={} steals={} stolen_events={}",
        cfg.quantum_policy, s.quanta_skipped, s.steals, s.stolen_events
    );
    println!(
        "  inbox: order={:?} staged={} reordered={} merge={:.0}ns/window",
        cfg.inbox_order,
        s.inbox_staged,
        s.inbox_reordered,
        s.inbox_merge_ns_per_window
    );
    println!(
        "  xbar: arb={:?} staged={} deferred_grants={}",
        cfg.xbar_arb, s.xbar_staged, s.xbar_deferred_grants
    );
    println!(
        "  traffic: {} offered={} accepted={} retries={} phases={}",
        cfg.traffic.as_deref().unwrap_or("app-trace"),
        s.traffic_offered,
        s.traffic_accepted,
        s.traffic_retries,
        s.traffic_phases
    );
    if cfg.cpu_model == CpuModel::O3 {
        let mean_occ = if s.sim_ticks > 0 {
            s.rob_occupancy_sum as f64
                / (s.sim_ticks as f64 * cfg.system.cores as f64)
        } else {
            0.0
        };
        println!(
            "  o3: issued={} squashed={} rob_full={} iq_full={} \
             rob_occ_mean={:.2}",
            s.issued,
            s.squashed,
            s.rob_full_stalls,
            s.iq_full_stalls,
            mean_occ
        );
    }
    if cfg.profile {
        println!(
            "  profile (summed over threads): window={:.2}ms \
             freeze-wait={:.2}ms border-sync={:.2}ms publish-wait={:.2}ms",
            s.prof_window_ns as f64 / 1e6,
            s.prof_freeze_wait_ns as f64 / 1e6,
            s.prof_border_sync_ns as f64 / 1e6,
            s.prof_publish_wait_ns as f64 / 1e6
        );
    }
    println!(
        "  miss rates: l1i={:.4} l1d={:.4} l2={:.4} l3={:.4}",
        s.l1i_miss_rate, s.l1d_miss_rate, s.l2_miss_rate, s.l3_miss_rate
    );
}
