//! Non-coherent peripherals behind the IO crossbar (Fig. 4: UART, timer).
//!
//! These answer classic timing-protocol packets with a fixed device latency.
//! They are deliberately simple — their role in the paper (and here) is to
//! generate *non-coherent* cross-domain traffic through the thread-safe
//! IO-XBAR layers of §4.3.

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::stats::StatSink;
use crate::sim::time::{Tick, NS};

/// A UART-like device: writes append to an internal buffer, reads return the
/// running status word (bytes written so far).
pub struct Uart {
    name: String,
    latency: Tick,
    bytes_written: u64,
    reads: u64,
    writes: u64,
}

impl Uart {
    pub fn new(name: String) -> Self {
        Uart { name, latency: 100 * NS, bytes_written: 0, reads: 0, writes: 0 }
    }
}

impl Component for Uart {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::MemReq { pkt } => {
                let value = if pkt.cmd.is_read() {
                    self.reads += 1;
                    self.bytes_written
                } else {
                    self.writes += 1;
                    self.bytes_written += pkt.size as u64;
                    0
                };
                let resp = pkt.make_response(value);
                ctx.schedule(
                    self.latency,
                    resp.requester,
                    EventKind::MemResp { pkt: resp },
                );
            }
            other => panic!("uart: unexpected event {other:?}"),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("reads", self.reads);
        out.add_u64("writes", self.writes);
        out.add_u64("bytes_written", self.bytes_written);
    }

    fn save_state(&self, w: &mut StateWriter) {
        w.u64(self.bytes_written);
        w.u64(self.reads);
        w.u64(self.writes);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.bytes_written = r.u64()?;
        self.reads = r.u64()?;
        self.writes = r.u64()?;
        Ok(())
    }
}

/// A timer device: reads return the current simulated time in ns; writes are
/// acknowledged and ignored.
pub struct Timer {
    name: String,
    latency: Tick,
    reads: u64,
    writes: u64,
}

impl Timer {
    pub fn new(name: String) -> Self {
        Timer { name, latency: 50 * NS, reads: 0, writes: 0 }
    }
}

impl Component for Timer {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::MemReq { pkt } => {
                let value = if pkt.cmd.is_read() {
                    self.reads += 1;
                    ctx.now() / NS
                } else {
                    self.writes += 1;
                    0
                };
                let resp = pkt.make_response(value);
                ctx.schedule(
                    self.latency,
                    resp.requester,
                    EventKind::MemResp { pkt: resp },
                );
            }
            other => panic!("timer: unexpected event {other:?}"),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("reads", self.reads);
        out.add_u64("writes", self.writes);
    }

    fn save_state(&self, w: &mut StateWriter) {
        w.u64(self.reads);
        w.u64(self.writes);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.reads = r.u64()?;
        self.writes = r.u64()?;
        Ok(())
    }
}
