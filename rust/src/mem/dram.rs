//! DRAM controller timing model (the SN-F / main-memory node of Fig. 4).
//!
//! Open-page policy over banks: requests queue per controller, are serviced
//! FCFS at the controller clock, and pay row-activation (tRCD+tRP) on a row
//! miss, plus CAS and burst time. The functional backing store is a sparse
//! line→value map, which also serves as the ground truth for end-to-end
//! functional comparison between serial and parallel runs.
//!
//! The controller lives in the shared domain and speaks the classic timing
//! protocol (`MemReq`/`MemResp` events); the HNF (its only requester in the
//! CHI system) and the atomic-mode CPUs both use it.

use std::collections::VecDeque;

use rustc_hash::FxHashMap;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::proto::Packet;
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::stats::StatSink;
use crate::sim::time::{Tick, NS};

#[derive(Clone, Copy, Debug)]
pub struct DramTiming {
    /// Controller clock period.
    pub clk_period: Tick,
    /// Row activate + precharge penalty on row miss.
    pub t_row: Tick,
    /// Column access latency.
    pub t_cas: Tick,
    /// Data burst duration per access.
    pub t_burst: Tick,
    pub n_banks: usize,
    /// Bytes per row (per bank).
    pub row_bytes: u64,
}

impl Default for DramTiming {
    /// ~DDR4-like figures at the paper's 1 GHz DRAM clock (Table 2).
    fn default() -> Self {
        DramTiming {
            clk_period: NS,
            t_row: 28 * NS,
            t_cas: 14 * NS,
            t_burst: 4 * NS,
            n_banks: 16,
            row_bytes: 2048,
        }
    }
}

struct Bank {
    open_row: Option<u64>,
    busy_until: Tick,
}

pub struct DramCtrl {
    name: String,
    timing: DramTiming,
    banks: Vec<Bank>,
    queue: VecDeque<Packet>,
    /// Functional backing store, line-granular.
    pub store: FxHashMap<u64, u64>,
    line_bytes: u64,
    ticking: bool,
    // stats
    reads: u64,
    writes: u64,
    row_hits: u64,
    row_misses: u64,
    queue_delay_sum: Tick,
    max_queue: usize,
}

impl DramCtrl {
    pub fn new(name: String, timing: DramTiming, line_bytes: u64) -> Self {
        let banks = (0..timing.n_banks)
            .map(|_| Bank { open_row: None, busy_until: 0 })
            .collect();
        DramCtrl {
            name,
            timing,
            banks,
            queue: VecDeque::new(),
            store: FxHashMap::default(),
            line_bytes,
            ticking: false,
            reads: 0,
            writes: 0,
            row_hits: 0,
            row_misses: 0,
            queue_delay_sum: 0,
            max_queue: 0,
        }
    }

    #[inline]
    fn bank_of(&self, addr: u64) -> usize {
        // line-interleaved banks
        ((addr / self.line_bytes) as usize) % self.timing.n_banks
    }

    #[inline]
    fn row_of(&self, addr: u64) -> u64 {
        addr / (self.timing.row_bytes * self.timing.n_banks as u64)
    }

    /// Functional + timing service of one packet; returns completion tick.
    fn service(&mut self, pkt: &mut Packet, now: Tick) -> Tick {
        let bank_idx = self.bank_of(pkt.addr);
        let row = self.row_of(pkt.addr);
        let t = self.timing;
        let bank = &mut self.banks[bank_idx];

        let start = now.max(bank.busy_until);
        let row_lat = if bank.open_row == Some(row) {
            self.row_hits += 1;
            0
        } else {
            self.row_misses += 1;
            bank.open_row = Some(row);
            t.t_row
        };
        let done = start + row_lat + t.t_cas + t.t_burst;
        bank.busy_until = done;

        let line = pkt.addr & !(self.line_bytes - 1);
        if pkt.cmd.is_read() {
            self.reads += 1;
            pkt.value = *self.store.get(&line).unwrap_or(&0);
        } else {
            self.writes += 1;
            self.store.insert(line, pkt.value);
        }
        self.queue_delay_sum += start - now.min(start);
        done
    }

    /// Atomic-protocol access: functional effect + latency estimate in one
    /// synchronous call (used by the Atomic/KVM CPU models, §3.3).
    pub fn atomic_access(&mut self, pkt: &mut Packet, now: Tick) -> Tick {
        let done = self.service(pkt, now);
        done - now
    }
}

impl Component for DramCtrl {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::MemReq { pkt } => {
                self.queue.push_back(pkt);
                self.max_queue = self.max_queue.max(self.queue.len());
                if !self.ticking {
                    self.ticking = true;
                    ctx.schedule_self(0, EventKind::DramTick);
                }
            }
            EventKind::DramTick => {
                // Service one request per tick event; respond when data is
                // back on the bus.
                if let Some(mut pkt) = self.queue.pop_front() {
                    let done = self.service(&mut pkt, ctx.now());
                    let resp = pkt.make_response(pkt.value);
                    ctx.schedule_abs(
                        done,
                        resp.requester,
                        EventKind::MemResp { pkt: resp },
                    );
                }
                if self.queue.is_empty() {
                    self.ticking = false;
                } else {
                    ctx.schedule_self(
                        self.timing.clk_period,
                        EventKind::DramTick,
                    );
                }
            }
            other => panic!("dram: unexpected event {other:?}"),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("reads", self.reads);
        out.add_u64("writes", self.writes);
        out.add_u64("row_hits", self.row_hits);
        out.add_u64("row_misses", self.row_misses);
        out.add_u64("queue_delay_ticks", self.queue_delay_sum);
        out.add_u64("max_queue", self.max_queue as u64);
    }

    fn save_state(&self, w: &mut StateWriter) {
        w.usize(self.banks.len());
        for b in &self.banks {
            w.opt_u64(b.open_row);
            w.u64(b.busy_until);
        }
        w.usize(self.queue.len());
        for pkt in &self.queue {
            w.packet(pkt);
        }
        // Sparse backing store: sorted by line address for byte-stable output
        // regardless of hash-map iteration order.
        let mut lines: Vec<(u64, u64)> =
            self.store.iter().map(|(&k, &v)| (k, v)).collect();
        lines.sort_unstable_by_key(|&(k, _)| k);
        w.usize(lines.len());
        for (addr, val) in lines {
            w.u64(addr);
            w.u64(val);
        }
        w.bool(self.ticking);
        w.u64(self.reads);
        w.u64(self.writes);
        w.u64(self.row_hits);
        w.u64(self.row_misses);
        w.u64(self.queue_delay_sum);
        w.usize(self.max_queue);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        let n_banks = r.usize()?;
        if n_banks != self.banks.len() {
            return Err(CkptError::Mismatch {
                what: format!("{}: bank count", self.name),
                expected: self.banks.len().to_string(),
                found: n_banks.to_string(),
            });
        }
        for b in &mut self.banks {
            b.open_row = r.opt_u64()?;
            b.busy_until = r.u64()?;
        }
        self.queue.clear();
        for _ in 0..r.usize()? {
            self.queue.push_back(r.packet()?);
        }
        self.store.clear();
        for _ in 0..r.usize()? {
            let addr = r.u64()?;
            let val = r.u64()?;
            self.store.insert(addr, val);
        }
        self.ticking = r.bool()?;
        self.reads = r.u64()?;
        self.writes = r.u64()?;
        self.row_hits = r.u64()?;
        self.row_misses = r.u64()?;
        self.queue_delay_sum = r.u64()?;
        self.max_queue = r.usize()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::Cmd;
    use crate::sim::ids::CompId;

    fn pkt(addr: u64, cmd: Cmd, value: u64) -> Packet {
        Packet::request(0, cmd, addr, 64, value, CompId(0), 0, 0)
    }

    #[test]
    fn write_then_read_roundtrip() {
        let mut d = DramCtrl::new("dram".into(), DramTiming::default(), 64);
        let mut w = pkt(0x1000, Cmd::WriteReq, 0xabc);
        d.service(&mut w, 0);
        let mut r = pkt(0x1000, Cmd::ReadReq, 0);
        d.service(&mut r, 100 * NS);
        assert_eq!(r.value, 0xabc);
    }

    #[test]
    fn row_hit_is_faster() {
        let mut d = DramCtrl::new("dram".into(), DramTiming::default(), 64);
        let mut a = pkt(0x0, Cmd::ReadReq, 0);
        let t0 = d.atomic_access(&mut a, 0);
        // same row, bank free again
        let mut b = pkt(0x40 * 16, Cmd::ReadReq, 0); // next line in bank 0
        let t1 = d.atomic_access(&mut b, 1_000 * NS);
        assert!(t1 < t0, "row hit {t1} must beat row miss {t0}");
        assert_eq!(d.row_hits, 1);
        assert_eq!(d.row_misses, 1);
    }

    #[test]
    fn bank_conflict_serialises() {
        let mut d = DramCtrl::new("dram".into(), DramTiming::default(), 64);
        let mut a = pkt(0x0, Cmd::ReadReq, 0);
        let mut b = pkt(0x0, Cmd::ReadReq, 0);
        let done_a = d.service(&mut a, 0);
        let done_b = d.service(&mut b, 0);
        assert!(done_b > done_a, "same-bank requests must serialise");
    }

    #[test]
    fn unwritten_reads_zero() {
        let mut d = DramCtrl::new("dram".into(), DramTiming::default(), 64);
        let mut r = pkt(0xdead00, Cmd::ReadReq, 5);
        d.service(&mut r, 0);
        assert_eq!(r.value, 0);
    }
}
