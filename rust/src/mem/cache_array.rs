//! Set-associative cache array with LRU replacement.
//!
//! This is the *storage* half of a cache: tag lookup, allocation, LRU
//! victimisation and per-line coherence state + functional data. The
//! *protocol* half lives in the Ruby controllers ([`crate::ruby`]).

use crate::ckpt::io::{CkptError, StateReader, StateWriter};

/// Per-line coherence state (CHI-lite MESI; see `ruby::msg`).
#[derive(Copy, Clone, PartialEq, Eq, Debug, Default)]
pub enum LineState {
    #[default]
    Invalid,
    Shared,
    Exclusive,
    Modified,
}

impl LineState {
    #[inline]
    pub fn is_valid(self) -> bool {
        self != LineState::Invalid
    }

    /// May this copy be written without upgrading?
    #[inline]
    pub fn is_writable(self) -> bool {
        matches!(self, LineState::Exclusive | LineState::Modified)
    }
}

#[derive(Copy, Clone, Debug)]
pub struct Line {
    pub tag: u64,
    pub state: LineState,
    /// Functional payload (line-granular value).
    pub data: u64,
    /// LRU timestamp (monotonic counter).
    lru: u64,
}

/// A victim evicted to make room for an allocation.
#[derive(Copy, Clone, Debug)]
pub struct Victim {
    pub addr: u64,
    pub state: LineState,
    pub data: u64,
}

pub struct CacheArray {
    sets: Vec<Vec<Line>>,
    assoc: usize,
    line_bytes: u64,
    set_shift: u32,
    set_mask: u64,
    tick: u64,
    // stats
    pub hits: u64,
    pub misses: u64,
}

impl CacheArray {
    /// `size_bytes` / `assoc` / `line_bytes` must give a power-of-two set
    /// count (Table 2 configs all do).
    pub fn new(size_bytes: u64, assoc: usize, line_bytes: u64) -> Self {
        assert!(line_bytes.is_power_of_two(), "line size must be 2^k");
        let n_sets = (size_bytes / (assoc as u64 * line_bytes)).max(1);
        assert!(
            n_sets.is_power_of_two(),
            "set count must be a power of two (size={size_bytes}, assoc={assoc})"
        );
        CacheArray {
            sets: vec![Vec::with_capacity(assoc); n_sets as usize],
            assoc,
            line_bytes,
            set_shift: line_bytes.trailing_zeros(),
            set_mask: n_sets - 1,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    pub fn line_addr(&self, addr: u64) -> u64 {
        addr & !(self.line_bytes - 1)
    }

    #[inline]
    fn set_of(&self, addr: u64) -> usize {
        ((addr >> self.set_shift) & self.set_mask) as usize
    }

    #[inline]
    fn tag_of(&self, addr: u64) -> u64 {
        addr >> self.set_shift >> self.set_mask.count_ones()
    }

    /// Look up a line; bumps LRU and the hit/miss counters.
    pub fn access(&mut self, addr: u64) -> Option<&mut Line> {
        self.tick += 1;
        let tick = self.tick;
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        match self.sets[set].iter_mut().find(|l| l.tag == tag) {
            Some(line) => {
                line.lru = tick;
                self.hits += 1;
                Some(line)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Look up without touching LRU or stats (snoops, probes).
    pub fn peek(&self, addr: u64) -> Option<&Line> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.sets[set].iter().find(|l| l.tag == tag)
    }

    pub fn peek_mut(&mut self, addr: u64) -> Option<&mut Line> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        self.sets[set].iter_mut().find(|l| l.tag == tag)
    }

    /// Allocate `addr` with `state`/`data`; returns the evicted victim (only
    /// valid victims are reported — Invalid ways are reused silently).
    pub fn allocate(
        &mut self,
        addr: u64,
        state: LineState,
        data: u64,
    ) -> Option<Victim> {
        self.tick += 1;
        let tick = self.tick;
        let (set_idx, tag) = (self.set_of(addr), self.tag_of(addr));
        let assoc = self.assoc;
        let set = &mut self.sets[set_idx];

        if let Some(line) = set.iter_mut().find(|l| l.tag == tag) {
            line.state = state;
            line.data = data;
            line.lru = tick;
            return None;
        }
        if set.len() < assoc {
            set.push(Line { tag, state, data, lru: tick });
            return None;
        }
        // evict LRU way
        let (vi, _) = set
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.lru)
            .expect("nonempty set");
        let victim = set[vi];
        set[vi] = Line { tag, state, data, lru: tick };
        let victim_addr = self.addr_of(set_idx, victim.tag);
        victim.state.is_valid().then_some(Victim {
            addr: victim_addr,
            state: victim.state,
            data: victim.data,
        })
    }

    /// Remove a line (invalidation); returns its previous content.
    pub fn invalidate(&mut self, addr: u64) -> Option<Line> {
        let (set, tag) = (self.set_of(addr), self.tag_of(addr));
        let s = &mut self.sets[set];
        let idx = s.iter().position(|l| l.tag == tag)?;
        Some(s.swap_remove(idx))
    }

    fn addr_of(&self, set: usize, tag: u64) -> u64 {
        ((tag << self.set_mask.count_ones()) | set as u64) << self.set_shift
    }

    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses() as f64
        }
    }

    /// All valid lines (checkpointing / functional comparison).
    pub fn valid_lines(&self) -> impl Iterator<Item = (u64, &Line)> + '_ {
        self.sets.iter().enumerate().flat_map(move |(si, set)| {
            set.iter()
                .filter(|l| l.state.is_valid())
                .map(move |l| (self.addr_of(si, l.tag), l))
        })
    }

    /// Checkpoint producer half: every way of every set, *in way order*,
    /// plus the LRU clock and the hit/miss counters. Way order matters:
    /// `find` scans ways linearly and `invalidate` uses `swap_remove`, so
    /// the physical ordering is architectural state that a bit-identical
    /// resume must reproduce. Geometry (set count, associativity, line
    /// size) is rebuilt from the spec, not serialized.
    pub fn save_ckpt(&self, w: &mut StateWriter) {
        w.usize(self.sets.len());
        for set in &self.sets {
            w.usize(set.len());
            for l in set {
                w.u64(l.tag);
                w.line_state(l.state);
                w.u64(l.data);
                w.u64(l.lru);
            }
        }
        w.u64(self.tick);
        w.u64(self.hits);
        w.u64(self.misses);
    }

    /// Checkpoint restore half for a freshly built array of the same
    /// geometry.
    pub fn restore_ckpt(
        &mut self,
        r: &mut StateReader,
    ) -> Result<(), CkptError> {
        let n = r.usize()?;
        if n != self.sets.len() {
            return Err(CkptError::Mismatch {
                what: "cache set count".to_string(),
                expected: self.sets.len().to_string(),
                found: n.to_string(),
            });
        }
        for set in &mut self.sets {
            set.clear();
            let ways = r.usize()?;
            for _ in 0..ways {
                let tag = r.u64()?;
                let state = r.line_state()?;
                let data = r.u64()?;
                let lru = r.u64()?;
                set.push(Line { tag, state, data, lru });
            }
        }
        self.tick = r.u64()?;
        self.hits = r.u64()?;
        self.misses = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CacheArray {
        // 2 sets x 2 ways x 64B = 256B
        CacheArray::new(256, 2, 64)
    }

    #[test]
    fn miss_then_hit() {
        let mut c = small();
        assert!(c.access(0x1000).is_none());
        c.allocate(0x1000, LineState::Shared, 7);
        let l = c.access(0x1000).expect("hit");
        assert_eq!(l.data, 7);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn same_set_eviction_is_lru() {
        let mut c = small();
        // set 0 lines: addresses with bit6 clear
        c.allocate(0x0000, LineState::Shared, 1);
        c.allocate(0x0080, LineState::Shared, 2);
        c.access(0x0000); // make 0x0080 LRU
        let v = c.allocate(0x0100, LineState::Shared, 3).expect("evict");
        assert_eq!(v.addr, 0x0080);
        assert!(c.peek(0x0000).is_some());
        assert!(c.peek(0x0080).is_none());
    }

    #[test]
    fn invalidate_removes() {
        let mut c = small();
        c.allocate(0x40, LineState::Modified, 9);
        let l = c.invalidate(0x40).expect("line");
        assert_eq!(l.state, LineState::Modified);
        assert!(c.peek(0x40).is_none());
    }

    #[test]
    fn line_addr_masks_offset() {
        let c = small();
        assert_eq!(c.line_addr(0x1234), 0x1200);
    }

    #[test]
    fn victim_addr_roundtrip() {
        let mut c = small();
        for i in 0..3u64 {
            c.allocate(0x1000 + i * 128, LineState::Shared, i);
        }
        // third allocation in set 0 evicts the first
        assert!(c.peek(0x1000).is_none() || c.peek(0x1100).is_some());
    }

    #[test]
    fn peek_does_not_count() {
        let mut c = small();
        c.allocate(0x0, LineState::Shared, 0);
        let (h, m) = (c.hits, c.misses);
        c.peek(0x0);
        c.peek(0x40);
        assert_eq!((c.hits, c.misses), (h, m));
    }

    #[test]
    fn invalid_allocation_reuses_way_without_victim() {
        let mut c = small();
        assert!(c.allocate(0x0, LineState::Shared, 0).is_none());
        assert!(c.allocate(0x80, LineState::Shared, 0).is_none());
    }
}
