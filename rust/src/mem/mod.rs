//! Memory-system substrates: cache storage arrays, the DRAM controller and
//! the non-coherent peripherals.

pub mod cache_array;
pub mod dram;
pub mod peripherals;

pub use cache_array::{CacheArray, Line, LineState, Victim};
pub use dram::{DramCtrl, DramTiming};
pub use peripherals::{Timer, Uart};
