//! Two-level bucketed (calendar-style) event queue.
//!
//! The near future is split into `shape.nbuckets` fixed-width buckets
//! arranged as a ring; the bucket currently containing the horizon is kept
//! as a small binary heap (`cur`), the rest as unsorted vectors, and
//! everything beyond the ring lives in an overflow heap. Scheduling into
//! the current window is O(log b) for a bucket of size b (vs O(log n) of
//! the whole-queue heap), and the common DES pattern — schedule a few ns
//! ahead, pop, repeat — touches only the small `cur` heap.
//!
//! The geometry is a run knob ([`BucketShape`], `--bucket-width` /
//! `--bucket-slots`): workloads whose latencies cluster tightly want
//! narrow buckets (less sorting inside `cur`), sparse ones want a wider
//! ring (fewer overflow migrations). Both axes are powers of two so the
//! level arithmetic stays shift/mask. The pop order is shape-independent,
//! so the shape is a pure performance lever (docs/PERF.md).
//!
//! Invariants (checked in debug builds):
//! * `horizon` is width-aligned and never decreases.
//! * `cur` holds exactly the events with `tick < horizon + width` (late
//!   cross-domain inserts below `horizon` also land here; the heap order
//!   absorbs them).
//! * ring slot `(tick / width) % nbuckets` holds events with
//!   `horizon + width <= tick < horizon + width * nbuckets`; at any moment
//!   a slot holds events of exactly one width-aligned range.
//! * `overflow` holds everything at or beyond the ring.
//! * `live` has bit `s` set iff ring slot `s` is non-empty, and
//!   `ring_count` is the total event count across slots — so an `advance`
//!   finds the earliest non-empty bucket with a couple of word scans
//!   instead of touching up to `nbuckets` scattered `Vec` headers.
//!
//! Pop order is identical to [`crate::sched::HeapQueue`]: the global
//! minimum by `(tick, prio, seq)` is always in `cur` when `cur` is
//! non-empty, because `advance` jumps the horizon to the earliest non-empty
//! bucket before refilling `cur`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use rustc_hash::FxHashSet;

use crate::sched::api::{EventHandle, Scheduler};
use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

/// Default bucket width in ticks (2 ns at the 1 tick = 1 ps base). Most
/// model latencies (NoC hops, cache accesses) fall within a few buckets.
const WIDTH: Tick = 2048;
/// Default ring size; the ring spans `WIDTH * NBUCKETS` = 128 ns of near
/// future.
const NBUCKETS: usize = 64;

/// Calendar geometry: bucket width (ticks) × ring slots. Both must be
/// powers of two (the hot-path level arithmetic is shift/mask). Selected
/// per run via `RunConfig` / `--bucket-width` / `--bucket-slots`; the
/// default `(2048, 64)` is the geometry every earlier PR measured.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BucketShape {
    /// Bucket width in ticks (power of two).
    pub width: Tick,
    /// Ring slots (power of two, ≥ 2).
    pub nbuckets: usize,
}

impl Default for BucketShape {
    fn default() -> Self {
        BucketShape { width: WIDTH, nbuckets: NBUCKETS }
    }
}

impl BucketShape {
    /// Check the power-of-two constraints, returning an actionable error.
    pub fn validate(self) -> Result<Self, String> {
        if !self.width.is_power_of_two() {
            return Err(format!(
                "bucket width must be a power of two, got {}",
                self.width
            ));
        }
        if self.nbuckets < 2 || !self.nbuckets.is_power_of_two() {
            return Err(format!(
                "bucket slots must be a power of two >= 2, got {}",
                self.nbuckets
            ));
        }
        Ok(self)
    }
}

pub struct BucketQueue {
    /// Sorted current bucket: all events with `tick < horizon + width`.
    cur: BinaryHeap<Reverse<Event>>,
    /// Unsorted near-future buckets, indexed by `(tick / width) % nbuckets`.
    ring: Vec<Vec<Event>>,
    /// Bit `s` set iff `ring[s]` is non-empty (see module invariants).
    live: Vec<u64>,
    /// Total events stored across all ring buckets.
    ring_count: usize,
    /// Far future: events at or beyond `horizon + width * nbuckets`.
    overflow: BinaryHeap<Reverse<Event>>,
    /// Width-aligned start of `cur`'s range.
    horizon: Tick,
    /// Seqs scheduled and not yet popped or cancelled (the live set).
    pending: FxHashSet<u64>,
    /// Tombstones still physically present in one of the levels.
    cancelled: FxHashSet<u64>,
    /// Reused drain buffer: `advance` swaps it with the slot being
    /// emptied so the slot's `Vec` keeps its capacity across ring
    /// revolutions — steady state allocates no `Vec` growth per window.
    scratch: Vec<Event>,
    /// log2 of the bucket width (shape.width = 1 << width_log2).
    width_log2: u32,
    /// `nbuckets - 1` (slot index mask).
    slot_mask: usize,
    /// `width * nbuckets`, saturated.
    span: Tick,
    next_seq: u64,
    executed: u64,
}

impl Default for BucketQueue {
    fn default() -> Self {
        Self::with_shape(BucketShape::default())
    }
}

impl BucketQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a queue with an explicit calendar geometry. Panics on an
    /// invalid shape — validate at the configuration boundary
    /// ([`BucketShape::validate`]) for a recoverable error.
    pub fn with_shape(shape: BucketShape) -> Self {
        let shape = shape.validate().expect("invalid bucket shape");
        BucketQueue {
            cur: BinaryHeap::new(),
            ring: (0..shape.nbuckets).map(|_| Vec::new()).collect(),
            live: vec![0; shape.nbuckets.div_ceil(64)],
            ring_count: 0,
            overflow: BinaryHeap::new(),
            horizon: 0,
            pending: FxHashSet::default(),
            cancelled: FxHashSet::default(),
            scratch: Vec::new(),
            width_log2: shape.width.trailing_zeros(),
            slot_mask: shape.nbuckets - 1,
            span: shape.width.saturating_mul(shape.nbuckets as Tick),
            next_seq: 0,
            executed: 0,
        }
    }

    #[inline]
    fn width(&self) -> Tick {
        1 << self.width_log2
    }

    #[inline]
    fn ring_end(&self) -> Tick {
        self.horizon.saturating_add(self.span)
    }

    #[inline]
    fn slot_of(&self, t: Tick) -> usize {
        ((t >> self.width_log2) as usize) & self.slot_mask
    }

    #[inline]
    fn bucket_start(&self, t: Tick) -> Tick {
        (t >> self.width_log2) << self.width_log2
    }

    #[inline]
    fn set_live(&mut self, slot: usize) {
        self.live[slot >> 6] |= 1 << (slot & 63);
    }

    #[inline]
    fn clear_live(&mut self, slot: usize) {
        self.live[slot >> 6] &= !(1 << (slot & 63));
    }

    /// Place an event into the level its tick belongs to.
    #[inline]
    fn place(&mut self, ev: Event) {
        let t = ev.tick;
        if t < self.horizon.saturating_add(self.width()) {
            self.cur.push(Reverse(ev));
        } else if t < self.ring_end() {
            let slot = self.slot_of(t);
            self.ring[slot].push(ev);
            self.set_live(slot);
            self.ring_count += 1;
        } else {
            self.overflow.push(Reverse(ev));
        }
    }

    /// Drop cancelled events sitting at the head of `cur`.
    #[inline]
    fn skim_cur(&mut self) {
        if self.cancelled.is_empty() {
            return;
        }
        while let Some(Reverse(e)) = self.cur.peek() {
            if self.cancelled.remove(&e.seq) {
                self.cur.pop();
            } else {
                break;
            }
        }
    }

    /// First live ring slot cyclically after `base` — `base` itself is
    /// never live at an `advance` (its residue maps to the overflow
    /// range). Word scans over the `live` bitmap: O(nbuckets / 64) words
    /// instead of up to `nbuckets` scattered `Vec` header reads, which is
    /// what made sparse far-future schedules crawl.
    fn next_live_slot(&self, base: usize) -> Option<usize> {
        debug_assert_eq!(
            self.live[base >> 6] >> (base & 63) & 1,
            0,
            "horizon residue slot must be empty at advance"
        );
        let start = (base + 1) & self.slot_mask;
        let (w0, b0) = (start >> 6, start & 63);
        let high = self.live[w0] & (!0u64 << b0);
        if high != 0 {
            return Some((w0 << 6) + high.trailing_zeros() as usize);
        }
        let words = self.live.len();
        for i in 1..words {
            let w = (w0 + i) % words;
            if self.live[w] != 0 {
                return Some(
                    (w << 6) + self.live[w].trailing_zeros() as usize,
                );
            }
        }
        let low = self.live[w0] & !(!0u64 << b0);
        if low != 0 {
            return Some((w0 << 6) + low.trailing_zeros() as usize);
        }
        None
    }

    /// Jump the horizon to the earliest non-empty bucket and refill `cur`.
    ///
    /// Precondition: `cur` is empty and `ring_count + overflow.len() > 0`.
    /// Guaranteed to move at least one stored event out of ring/overflow
    /// (possibly dropping it as cancelled), so caller loops terminate.
    fn advance(&mut self) {
        // Ring slots at residues cyclically after the horizon's hold
        // strictly increasing bucket starts (one width-aligned range per
        // slot), so the first live bit after the horizon residue is the
        // ring minimum. Every ring bucket start is below the overflow's
        // (overflow holds ticks >= ring_end), so overflow is only
        // consulted when the ring is empty.
        let mut next_slot = usize::MAX;
        let mut next_start = Tick::MAX;
        if self.ring_count > 0 {
            let base = self.slot_of(self.horizon);
            let slot = self
                .next_live_slot(base)
                .expect("ring_count > 0 with an all-zero live bitmap");
            let head =
                self.ring[slot].first().expect("live bit on empty slot");
            next_start = self.bucket_start(head.tick);
            next_slot = slot;
        } else if let Some(Reverse(e)) = self.overflow.peek() {
            next_start = self.bucket_start(e.tick);
        }
        debug_assert_ne!(next_start, Tick::MAX, "advance on empty queue");
        debug_assert!(next_start >= self.horizon, "horizon must not retreat");
        self.horizon = next_start;

        if next_slot != usize::MAX {
            // Drain the slot through the scratch buffer so its Vec keeps
            // its capacity for the next ring revolution (the old
            // `mem::take` dropped the allocation every time).
            std::mem::swap(&mut self.scratch, &mut self.ring[next_slot]);
            self.ring_count -= self.scratch.len();
            self.clear_live(next_slot);
            for ev in self.scratch.drain(..) {
                if self.cancelled.remove(&ev.seq) {
                    continue;
                }
                debug_assert!(
                    ev.tick < self.horizon.saturating_add(self.width())
                );
                self.cur.push(Reverse(ev));
            }
            std::mem::swap(&mut self.scratch, &mut self.ring[next_slot]);
        }

        // The ring's span moved forward: migrate newly-near overflow events.
        let ring_end = self.ring_end();
        while let Some(Reverse(e)) = self.overflow.peek() {
            if e.tick >= ring_end {
                break;
            }
            let Reverse(ev) = self.overflow.pop().unwrap();
            if self.cancelled.remove(&ev.seq) {
                continue;
            }
            if ev.tick < self.horizon.saturating_add(self.width()) {
                self.cur.push(Reverse(ev));
            } else {
                let s = self.slot_of(ev.tick);
                self.ring[s].push(ev);
                self.set_live(s);
                self.ring_count += 1;
            }
        }

        // Saturation fallback (ticks near u64::MAX can make the range
        // arithmetic saturate): guarantee progress by draining overflow
        // straight into the sorted heap.
        if self.cur.is_empty() && self.ring_count == 0 {
            while let Some(Reverse(ev)) = self.overflow.pop() {
                if self.cancelled.remove(&ev.seq) {
                    continue;
                }
                self.cur.push(Reverse(ev));
            }
        }
    }

    /// Test hook: the `live` bitmap mirrors slot occupancy exactly.
    #[cfg(test)]
    fn check_live_invariant(&self) {
        let mut count = 0;
        for (s, slot) in self.ring.iter().enumerate() {
            let bit = self.live[s >> 6] >> (s & 63) & 1 == 1;
            assert_eq!(bit, !slot.is_empty(), "live bit {s} out of sync");
            count += slot.len();
        }
        assert_eq!(count, self.ring_count, "ring_count out of sync");
    }
}

impl Scheduler for BucketQueue {
    fn schedule(
        &mut self,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.insert(seq);
        self.place(Event { tick, prio, seq, target, kind });
        EventHandle(seq)
    }

    fn insert(&mut self, mut ev: Event) -> EventHandle {
        ev.seq = self.next_seq;
        self.next_seq += 1;
        let h = EventHandle(ev.seq);
        self.pending.insert(ev.seq);
        self.place(ev);
        h
    }

    fn deschedule(&mut self, h: EventHandle) {
        if self.pending.remove(&h.0) {
            self.cancelled.insert(h.0);
        }
    }

    fn next_tick(&mut self) -> Option<Tick> {
        loop {
            self.skim_cur();
            if let Some(Reverse(e)) = self.cur.peek() {
                return Some(e.tick);
            }
            if self.ring_count == 0 && self.overflow.is_empty() {
                return None;
            }
            self.advance();
        }
    }

    fn pop(&mut self) -> Option<Event> {
        loop {
            self.skim_cur();
            if let Some(Reverse(ev)) = self.cur.pop() {
                self.pending.remove(&ev.seq);
                self.executed += 1;
                return Some(ev);
            }
            if self.ring_count == 0 && self.overflow.is_empty() {
                return None;
            }
            self.advance();
        }
    }

    fn len(&self) -> usize {
        self.pending.len()
    }

    fn executed(&self) -> u64 {
        self.executed
    }

    fn pending_events(&self) -> Vec<Event> {
        let live = |e: &&Event| self.pending.contains(&e.seq);
        let mut evs: Vec<Event> = self
            .cur
            .iter()
            .chain(self.overflow.iter())
            .map(|Reverse(e)| e)
            .filter(live)
            .cloned()
            .collect();
        evs.extend(
            self.ring.iter().flat_map(|slot| slot.iter()).filter(live).cloned(),
        );
        evs.sort_unstable_by_key(|e| e.key());
        evs
    }

    fn set_executed(&mut self, n: u64) {
        self.executed = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k() -> EventKind {
        EventKind::CpuTick
    }

    #[test]
    fn pops_in_time_order_across_levels() {
        let mut q = BucketQueue::new();
        // cur, ring, and overflow ranges all populated, out of order.
        q.schedule(WIDTH * NBUCKETS as Tick * 3, 50, CompId(0), k());
        q.schedule(10, 50, CompId(1), k());
        q.schedule(WIDTH * 5 + 7, 50, CompId(2), k());
        q.schedule(WIDTH - 1, 50, CompId(3), k());
        q.schedule(WIDTH * NBUCKETS as Tick + 1, 50, CompId(4), k());
        let order: Vec<Tick> =
            std::iter::from_fn(|| q.pop().map(|e| e.tick)).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(order, sorted);
        assert_eq!(order.len(), 5);
    }

    #[test]
    fn same_tick_fifo_by_seq_and_prio() {
        let mut q = BucketQueue::new();
        q.schedule(5, 50, CompId(0), k());
        q.schedule(5, 50, CompId(1), k());
        q.schedule(5, 0, CompId(2), k());
        assert_eq!(q.pop().unwrap().target, CompId(2));
        assert_eq!(q.pop().unwrap().target, CompId(0));
        assert_eq!(q.pop().unwrap().target, CompId(1));
    }

    #[test]
    fn deschedule_works_in_every_level() {
        let mut q = BucketQueue::new();
        let far = WIDTH * NBUCKETS as Tick * 2;
        let h0 = q.schedule(1, 50, CompId(0), k());
        let h1 = q.schedule(WIDTH * 3, 50, CompId(1), k());
        let h2 = q.schedule(far, 50, CompId(2), k());
        q.schedule(far + 1, 50, CompId(3), k());
        q.deschedule(h0);
        q.deschedule(h1);
        q.deschedule(h2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().target, CompId(3));
        assert!(q.pop().is_none());
        assert_eq!(q.executed(), 1);
    }

    #[test]
    fn stale_deschedule_does_not_underflow_len() {
        let mut q = BucketQueue::new();
        let h = q.schedule(1, 50, CompId(0), k());
        assert!(q.pop().is_some());
        q.deschedule(h);
        assert!(q.is_empty());
        q.schedule(2, 50, CompId(1), k());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn insert_below_horizon_still_pops() {
        let mut q = BucketQueue::new();
        // Drive the horizon forward.
        q.schedule(WIDTH * 10, 50, CompId(0), k());
        assert_eq!(q.pop().unwrap().target, CompId(0));
        // A late cross-domain insert below the horizon must still surface
        // (and before anything later).
        q.insert(Event { tick: 3, prio: 50, seq: 0, target: CompId(1), kind: k() });
        q.schedule(WIDTH * 20, 50, CompId(2), k());
        assert_eq!(q.pop().unwrap().target, CompId(1));
        assert_eq!(q.pop().unwrap().target, CompId(2));
    }

    #[test]
    fn sparse_far_future_jumps() {
        let mut q = BucketQueue::new();
        // Events millions of ticks apart: advance must jump, not crawl.
        for i in 0..10u64 {
            q.schedule(i * 1_000_000_000, 50, CompId(i as u32), k());
        }
        for i in 0..10u64 {
            assert_eq!(q.pop().unwrap().target, CompId(i as u32));
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn sparse_far_future_keeps_live_bitmap_in_sync() {
        // A sparse schedule that walks every level: a handful of distant
        // ring slots (including the wrap-around residues), overflow events
        // that migrate in as the horizon jumps, and deschedules that leave
        // tombstones in live slots. The bitmap must mirror physical slot
        // occupancy after every mutation — it is what lets `advance`
        // short-circuit the old full-ring scan.
        let mut q = BucketQueue::new();
        let mut handles = Vec::new();
        for i in 0..40u64 {
            // Strides coprime to the ring size hit scattered residues.
            let t = i * (WIDTH * 13 + 5) + i * i * 977;
            handles.push(q.schedule(t, 50, CompId(i as u32), k()));
            q.check_live_invariant();
        }
        // Cancel every third event, including ones sitting in ring slots.
        for h in handles.iter().step_by(3) {
            q.deschedule(*h);
            q.check_live_invariant();
        }
        let mut last = 0;
        let mut popped = 0;
        while let Some(e) = q.pop() {
            assert!(e.tick >= last, "pop order violated");
            last = e.tick;
            popped += 1;
            q.check_live_invariant();
        }
        assert_eq!(popped, 40 - handles.iter().step_by(3).count());
        assert!(q.is_empty());
    }

    #[test]
    fn custom_shapes_pop_identically() {
        // The calendar geometry is a pure performance lever: every shape
        // must produce the exact pop sequence of the default.
        let shapes = [
            BucketShape::default(),
            BucketShape { width: 256, nbuckets: 16 },
            BucketShape { width: 64, nbuckets: 4 },
            BucketShape { width: 1 << 16, nbuckets: 128 },
        ];
        let mut seed = 0x9e3779b97f4a7c15u64;
        let mut ticks = Vec::new();
        for _ in 0..500 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ticks.push(seed >> 24); // up to ~2^40 ticks: all levels hit
        }
        let reference: Vec<(Tick, u64)> = {
            let mut q = BucketQueue::with_shape(shapes[0]);
            for &t in &ticks {
                q.schedule(t, 50, CompId(0), k());
            }
            std::iter::from_fn(|| q.pop().map(|e| (e.tick, e.seq))).collect()
        };
        assert_eq!(reference.len(), ticks.len());
        for shape in &shapes[1..] {
            let mut q = BucketQueue::with_shape(*shape);
            for &t in &ticks {
                q.schedule(t, 50, CompId(0), k());
            }
            let order: Vec<(Tick, u64)> =
                std::iter::from_fn(|| q.pop().map(|e| (e.tick, e.seq)))
                    .collect();
            assert_eq!(order, reference, "{shape:?} diverged");
        }
    }

    #[test]
    fn shape_validation_rejects_bad_geometry() {
        assert!(BucketShape { width: 2048, nbuckets: 64 }.validate().is_ok());
        assert!(BucketShape { width: 1000, nbuckets: 64 }.validate().is_err());
        assert!(BucketShape { width: 2048, nbuckets: 48 }.validate().is_err());
        assert!(BucketShape { width: 2048, nbuckets: 1 }.validate().is_err());
    }

    #[test]
    fn next_tick_matches_pop() {
        let mut q = BucketQueue::new();
        q.schedule(70_000, 50, CompId(0), k());
        q.schedule(7, 50, CompId(1), k());
        assert_eq!(q.next_tick(), Some(7));
        assert_eq!(q.pop().unwrap().tick, 7);
        assert_eq!(q.next_tick(), Some(70_000));
    }

    #[test]
    fn pop_before_respects_limit() {
        let mut q = BucketQueue::new();
        q.schedule(WIDTH * 4, 50, CompId(0), k());
        assert!(q.pop_before(WIDTH * 4).is_none());
        assert!(q.pop_before(WIDTH * 4 + 1).is_some());
    }
}
