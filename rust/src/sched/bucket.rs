//! Two-level bucketed (calendar-style) event queue.
//!
//! The near future is split into `NBUCKETS` fixed-width buckets arranged as
//! a ring; the bucket currently containing the horizon is kept as a small
//! binary heap (`cur`), the rest as unsorted vectors, and everything beyond
//! the ring lives in an overflow heap. Scheduling into the current window is
//! O(log b) for a bucket of size b (vs O(log n) of the whole-queue heap),
//! and the common DES pattern — schedule a few ns ahead, pop, repeat —
//! touches only the small `cur` heap.
//!
//! Invariants (checked in debug builds):
//! * `horizon` is `WIDTH`-aligned and never decreases.
//! * `cur` holds exactly the events with `tick < horizon + WIDTH` (late
//!   cross-domain inserts below `horizon` also land here; the heap order
//!   absorbs them).
//! * ring slot `(tick / WIDTH) % NBUCKETS` holds events with
//!   `horizon + WIDTH <= tick < horizon + WIDTH * NBUCKETS`; at any moment
//!   a slot holds events of exactly one `WIDTH`-aligned range.
//! * `overflow` holds everything at or beyond the ring.
//!
//! Pop order is identical to [`crate::sched::HeapQueue`]: the global
//! minimum by `(tick, prio, seq)` is always in `cur` when `cur` is
//! non-empty, because `advance` jumps the horizon to the earliest non-empty
//! bucket before refilling `cur`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use rustc_hash::FxHashSet;

use crate::sched::api::{EventHandle, Scheduler};
use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

/// Bucket width in ticks (2 ns at the 1 tick = 1 ps base). Most model
/// latencies (NoC hops, cache accesses) fall within a few buckets.
const WIDTH: Tick = 2048;
/// Ring size; the ring spans `WIDTH * NBUCKETS` = 128 ns of near future.
const NBUCKETS: usize = 64;

pub struct BucketQueue {
    /// Sorted current bucket: all events with `tick < horizon + WIDTH`.
    cur: BinaryHeap<Reverse<Event>>,
    /// Unsorted near-future buckets, indexed by `(tick / WIDTH) % NBUCKETS`.
    ring: Vec<Vec<Event>>,
    /// Total events stored across all ring buckets.
    ring_count: usize,
    /// Far future: events at or beyond `horizon + WIDTH * NBUCKETS`.
    overflow: BinaryHeap<Reverse<Event>>,
    /// `WIDTH`-aligned start of `cur`'s range.
    horizon: Tick,
    /// Seqs scheduled and not yet popped or cancelled (the live set).
    pending: FxHashSet<u64>,
    /// Tombstones still physically present in one of the levels.
    cancelled: FxHashSet<u64>,
    next_seq: u64,
    executed: u64,
}

impl Default for BucketQueue {
    fn default() -> Self {
        BucketQueue {
            cur: BinaryHeap::new(),
            ring: (0..NBUCKETS).map(|_| Vec::new()).collect(),
            ring_count: 0,
            overflow: BinaryHeap::new(),
            horizon: 0,
            pending: FxHashSet::default(),
            cancelled: FxHashSet::default(),
            next_seq: 0,
            executed: 0,
        }
    }
}

impl BucketQueue {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn ring_end(&self) -> Tick {
        self.horizon.saturating_add(WIDTH * NBUCKETS as Tick)
    }

    /// Place an event into the level its tick belongs to.
    #[inline]
    fn place(&mut self, ev: Event) {
        let t = ev.tick;
        if t < self.horizon.saturating_add(WIDTH) {
            self.cur.push(Reverse(ev));
        } else if t < self.ring_end() {
            let slot = ((t / WIDTH) as usize) % NBUCKETS;
            self.ring[slot].push(ev);
            self.ring_count += 1;
        } else {
            self.overflow.push(Reverse(ev));
        }
    }

    /// Drop cancelled events sitting at the head of `cur`.
    #[inline]
    fn skim_cur(&mut self) {
        if self.cancelled.is_empty() {
            return;
        }
        while let Some(Reverse(e)) = self.cur.peek() {
            if self.cancelled.remove(&e.seq) {
                self.cur.pop();
            } else {
                break;
            }
        }
    }

    /// Jump the horizon to the earliest non-empty bucket and refill `cur`.
    ///
    /// Precondition: `cur` is empty and `ring_count + overflow.len() > 0`.
    /// Guaranteed to move at least one stored event out of ring/overflow
    /// (possibly dropping it as cancelled), so caller loops terminate.
    fn advance(&mut self) {
        // Ring slots at residues (horizon/WIDTH + 1), (horizon/WIDTH + 2),
        // ... hold strictly increasing bucket starts (one WIDTH-aligned
        // range per slot), so walking forward from the horizon residue and
        // stopping at the first non-empty slot finds the ring minimum —
        // amortised O(1) per bucket over a ring revolution, instead of a
        // full 64-slot scan per advance. Every ring bucket start is below
        // the overflow's (overflow holds ticks >= ring_end), so overflow
        // is only consulted when the ring is empty.
        let mut next_start = Tick::MAX;
        if self.ring_count > 0 {
            let base = (self.horizon / WIDTH) as usize;
            for k in 1..NBUCKETS {
                let slot = &self.ring[(base + k) % NBUCKETS];
                if let Some(e) = slot.first() {
                    next_start = e.tick / WIDTH * WIDTH;
                    break;
                }
            }
        } else if let Some(Reverse(e)) = self.overflow.peek() {
            next_start = e.tick / WIDTH * WIDTH;
        }
        debug_assert_ne!(next_start, Tick::MAX, "advance on empty queue");
        debug_assert!(next_start >= self.horizon, "horizon must not retreat");
        self.horizon = next_start;

        let slot = ((next_start / WIDTH) as usize) % NBUCKETS;
        let moved = std::mem::take(&mut self.ring[slot]);
        self.ring_count -= moved.len();
        for ev in moved {
            if self.cancelled.remove(&ev.seq) {
                continue;
            }
            debug_assert!(ev.tick < self.horizon.saturating_add(WIDTH));
            self.cur.push(Reverse(ev));
        }

        // The ring's span moved forward: migrate newly-near overflow events.
        let ring_end = self.ring_end();
        while let Some(Reverse(e)) = self.overflow.peek() {
            if e.tick >= ring_end {
                break;
            }
            let Reverse(ev) = self.overflow.pop().unwrap();
            if self.cancelled.remove(&ev.seq) {
                continue;
            }
            if ev.tick < self.horizon.saturating_add(WIDTH) {
                self.cur.push(Reverse(ev));
            } else {
                let s = ((ev.tick / WIDTH) as usize) % NBUCKETS;
                self.ring[s].push(ev);
                self.ring_count += 1;
            }
        }

        // Saturation fallback (ticks near u64::MAX can make the range
        // arithmetic saturate): guarantee progress by draining overflow
        // straight into the sorted heap.
        if self.cur.is_empty() && self.ring_count == 0 {
            while let Some(Reverse(ev)) = self.overflow.pop() {
                if self.cancelled.remove(&ev.seq) {
                    continue;
                }
                self.cur.push(Reverse(ev));
            }
        }
    }
}

impl Scheduler for BucketQueue {
    fn schedule(
        &mut self,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.insert(seq);
        self.place(Event { tick, prio, seq, target, kind });
        EventHandle(seq)
    }

    fn insert(&mut self, mut ev: Event) -> EventHandle {
        ev.seq = self.next_seq;
        self.next_seq += 1;
        let h = EventHandle(ev.seq);
        self.pending.insert(ev.seq);
        self.place(ev);
        h
    }

    fn deschedule(&mut self, h: EventHandle) {
        if self.pending.remove(&h.0) {
            self.cancelled.insert(h.0);
        }
    }

    fn next_tick(&mut self) -> Option<Tick> {
        loop {
            self.skim_cur();
            if let Some(Reverse(e)) = self.cur.peek() {
                return Some(e.tick);
            }
            if self.ring_count == 0 && self.overflow.is_empty() {
                return None;
            }
            self.advance();
        }
    }

    fn pop(&mut self) -> Option<Event> {
        loop {
            self.skim_cur();
            if let Some(Reverse(ev)) = self.cur.pop() {
                self.pending.remove(&ev.seq);
                self.executed += 1;
                return Some(ev);
            }
            if self.ring_count == 0 && self.overflow.is_empty() {
                return None;
            }
            self.advance();
        }
    }

    fn len(&self) -> usize {
        self.pending.len()
    }

    fn executed(&self) -> u64 {
        self.executed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k() -> EventKind {
        EventKind::CpuTick
    }

    #[test]
    fn pops_in_time_order_across_levels() {
        let mut q = BucketQueue::new();
        // cur, ring, and overflow ranges all populated, out of order.
        q.schedule(WIDTH * NBUCKETS as Tick * 3, 50, CompId(0), k());
        q.schedule(10, 50, CompId(1), k());
        q.schedule(WIDTH * 5 + 7, 50, CompId(2), k());
        q.schedule(WIDTH - 1, 50, CompId(3), k());
        q.schedule(WIDTH * NBUCKETS as Tick + 1, 50, CompId(4), k());
        let order: Vec<Tick> =
            std::iter::from_fn(|| q.pop().map(|e| e.tick)).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(order, sorted);
        assert_eq!(order.len(), 5);
    }

    #[test]
    fn same_tick_fifo_by_seq_and_prio() {
        let mut q = BucketQueue::new();
        q.schedule(5, 50, CompId(0), k());
        q.schedule(5, 50, CompId(1), k());
        q.schedule(5, 0, CompId(2), k());
        assert_eq!(q.pop().unwrap().target, CompId(2));
        assert_eq!(q.pop().unwrap().target, CompId(0));
        assert_eq!(q.pop().unwrap().target, CompId(1));
    }

    #[test]
    fn deschedule_works_in_every_level() {
        let mut q = BucketQueue::new();
        let far = WIDTH * NBUCKETS as Tick * 2;
        let h0 = q.schedule(1, 50, CompId(0), k());
        let h1 = q.schedule(WIDTH * 3, 50, CompId(1), k());
        let h2 = q.schedule(far, 50, CompId(2), k());
        q.schedule(far + 1, 50, CompId(3), k());
        q.deschedule(h0);
        q.deschedule(h1);
        q.deschedule(h2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().target, CompId(3));
        assert!(q.pop().is_none());
        assert_eq!(q.executed(), 1);
    }

    #[test]
    fn stale_deschedule_does_not_underflow_len() {
        let mut q = BucketQueue::new();
        let h = q.schedule(1, 50, CompId(0), k());
        assert!(q.pop().is_some());
        q.deschedule(h);
        assert!(q.is_empty());
        q.schedule(2, 50, CompId(1), k());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn insert_below_horizon_still_pops() {
        let mut q = BucketQueue::new();
        // Drive the horizon forward.
        q.schedule(WIDTH * 10, 50, CompId(0), k());
        assert_eq!(q.pop().unwrap().target, CompId(0));
        // A late cross-domain insert below the horizon must still surface
        // (and before anything later).
        q.insert(Event { tick: 3, prio: 50, seq: 0, target: CompId(1), kind: k() });
        q.schedule(WIDTH * 20, 50, CompId(2), k());
        assert_eq!(q.pop().unwrap().target, CompId(1));
        assert_eq!(q.pop().unwrap().target, CompId(2));
    }

    #[test]
    fn sparse_far_future_jumps() {
        let mut q = BucketQueue::new();
        // Events millions of ticks apart: advance must jump, not crawl.
        for i in 0..10u64 {
            q.schedule(i * 1_000_000_000, 50, CompId(i as u32), k());
        }
        for i in 0..10u64 {
            assert_eq!(q.pop().unwrap().target, CompId(i as u32));
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn next_tick_matches_pop() {
        let mut q = BucketQueue::new();
        q.schedule(70_000, 50, CompId(0), k());
        q.schedule(7, 50, CompId(1), k());
        assert_eq!(q.next_tick(), Some(7));
        assert_eq!(q.pop().unwrap().tick, 7);
        assert_eq!(q.next_tick(), Some(70_000));
    }

    #[test]
    fn pop_before_respects_limit() {
        let mut q = BucketQueue::new();
        q.schedule(WIDTH * 4, 50, CompId(0), k());
        assert!(q.pop_before(WIDTH * 4).is_none());
        assert!(q.pop_before(WIDTH * 4 + 1).is_some());
    }
}
