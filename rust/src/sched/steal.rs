//! [`ClaimList`]: deterministic-victim work stealing for window execution.
//!
//! The threaded kernel binds *domains* to *host threads*. With the paper's
//! static 1:1 binding, a thread whose domain goes quiescent early idles at
//! the freeze barrier while loaded domains still grind — MGSim calls this
//! out as the main waste of host cores under skewed event density
//! (arXiv 1302.1390). The cure is to make the binding per-window: each
//! window, every runnable domain (its whole movable `SchedQueue` plus the
//! components it drives) is an indivisible work item, and threads *claim*
//! items from a shared list until it is exhausted. A thread that finishes
//! its first claim early adopts the next unclaimed — i.e. steals the window
//! of — the most-loaded remaining domain.
//!
//! **Determinism guard.** Stealing never splits a domain: a claim hands the
//! *entire* domain to exactly one thread for the window, so its events
//! still execute sequentially in `(tick, prio, seq)` order against its own
//! components, mailboxes keep their single consumer at the border, and the
//! component→domain map never changes (cross-domain classification — and
//! therefore postponement — is untouched). Stealing therefore introduces
//! **no new nondeterminism**: every simulation-visible effect of a window
//! (events executed, mailbox pushes, border drains) is the same whichever
//! thread runs it. Under `--inbox-order host`, what remains host-timing
//! dependent is exactly what was already host-timing dependent without
//! stealing — intra-window Ruby message arrival (paper §6) — so the gates
//! in `tests/adaptive_quantum.rs` assert functional identity (checksums,
//! committed ops) for the threaded kernel across steal/thread settings.
//! Under the default `--inbox-order border` even that is gone, and
//! `tests/inbox_order.rs` tightens the gate to full bit-identity across
//! steal/thread/policy settings. Host-side counters (steal counts,
//! wall-clock, merge cost) always vary.
//!
//! **Claim binding × the border-ordered handoff.** The handoff's staging
//! sequence (`StagedMsg::seq`, `ruby/msg.rs`) is "the sender domain's
//! program order within the window" — well-defined *only because* a claim
//! hands each domain to exactly one thread per window ([`ClaimList::claim`]
//! returns every index exactly once between two `replan`s), so a domain's
//! sends are never interleaved by two executors. The consumer side rides
//! the **static** `d % n_threads` border partition instead of the claim
//! binding: any quiesced thread may perform a merge (the canonical order is
//! a pure function of the stage content), but exactly one must, and the
//! static partition guarantees that one-merger-per-inbox-per-border
//! property no matter which thread executed — or stole — the window that
//! staged the messages.
//!
//! **Victim selection** is deterministic: at each border the leader sorts
//! the claim order by the events each domain executed in the closed window
//! (descending — an LPT list schedule), breaking ties by domain id. The
//! *claim order* is therefore a pure function of the simulation; only the
//! claim *assignment* (which thread pops which item) depends on host
//! timing, and that assignment cannot affect results per the argument
//! above.
//!
//! **Synchronisation contract.** `claim` may be called concurrently by any
//! worker between two barriers; `replan` may only be called while every
//! other participant is parked at a barrier (the quantum-border quiescent
//! span). All atomics are `Relaxed`: the surrounding
//! [`crate::sched::TreeBarrier`] provides the happens-before edges between
//! a `replan` and the next round of `claim`s.

use std::cmp::Reverse;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::atomic::{AtomicU32, AtomicUsize};

/// A shared, re-plannable list of domain indices claimed one at a time.
pub struct ClaimList {
    /// Claim order for the current window (domain indices).
    order: Vec<AtomicU32>,
    /// Next position in `order` to hand out.
    cursor: AtomicUsize,
}

impl ClaimList {
    /// A claim list over `n` domains in identity order (the first window
    /// runs before any load has been observed).
    pub fn identity(n: usize) -> Self {
        ClaimList {
            order: (0..n).map(|d| AtomicU32::new(d as u32)).collect(),
            cursor: AtomicUsize::new(0),
        }
    }

    /// Number of work items per window.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Claim the next domain, or `None` when this window's list is
    /// exhausted. Each index is handed out exactly once per window.
    pub fn claim(&self) -> Option<usize> {
        let i = self.cursor.fetch_add(1, Relaxed);
        if i < self.order.len() {
            Some(self.order[i].load(Relaxed) as usize)
        } else {
            None
        }
    }

    /// Re-sort the claim order by observed load (events executed in the
    /// closed window), heaviest first, ties by domain id, and reset the
    /// cursor for the next window.
    ///
    /// Leader-only, and only while all other participants are parked at a
    /// barrier (see the module-level contract).
    pub fn replan(&self, loads: &[u32]) {
        debug_assert_eq!(loads.len(), self.order.len());
        let mut ids: Vec<u32> = (0..self.order.len() as u32).collect();
        ids.sort_by_key(|&d| (Reverse(loads[d as usize]), d));
        for (slot, d) in self.order.iter().zip(ids) {
            slot.store(d, Relaxed);
        }
        self.cursor.store(0, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_hands_out_each_index_once() {
        let c = ClaimList::identity(4);
        assert_eq!(c.len(), 4);
        let got: Vec<usize> = std::iter::from_fn(|| c.claim()).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(c.claim(), None, "exhausted lists stay exhausted");
    }

    #[test]
    fn replan_orders_heaviest_first_with_id_tiebreak() {
        let c = ClaimList::identity(5);
        while c.claim().is_some() {}
        c.replan(&[3, 9, 3, 0, 9]);
        let got: Vec<usize> = std::iter::from_fn(|| c.claim()).collect();
        assert_eq!(got, vec![1, 4, 0, 2, 3]);
    }

    #[test]
    fn concurrent_claims_are_a_partition() {
        use std::sync::Mutex;
        let c = ClaimList::identity(64);
        let seen = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let mut mine = Vec::new();
                    while let Some(d) = c.claim() {
                        mine.push(d);
                    }
                    seen.lock().unwrap().extend(mine);
                });
            }
        });
        let mut all = seen.into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>(), "lost or double claim");
    }

    #[test]
    fn replan_resets_for_the_next_window() {
        let c = ClaimList::identity(3);
        while c.claim().is_some() {}
        c.replan(&[0, 0, 0]);
        assert_eq!(
            std::iter::from_fn(|| c.claim()).count(),
            3,
            "cursor must reset"
        );
    }
}
