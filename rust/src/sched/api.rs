//! The scheduling surface every kernel and model goes through.
//!
//! [`Scheduler`] mirrors gem5's `EventQueue` interface (schedule /
//! deschedule / reschedule) over the total event order `(tick, prio, seq)`.
//! Two implementations exist — [`crate::sched::HeapQueue`] and
//! [`crate::sched::BucketQueue`] — selected per run via [`QueueKind`] and
//! dispatched statically through [`crate::sched::SchedQueue`].

use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

/// Handle identifying a scheduled event (its sequence number).
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub struct EventHandle(pub u64);

/// Which event-queue implementation a run uses.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    /// Binary min-heap with lazy tombstones (the reference implementation).
    Heap,
    /// Two-level bucketed (calendar-style) queue.
    #[default]
    Bucket,
}

impl QueueKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "heap" => QueueKind::Heap,
            "bucket" | "calendar" => QueueKind::Bucket,
            _ => return None,
        })
    }
}

/// gem5's event-queue interface over the `(tick, prio, seq)` total order.
///
/// Implementations must pop events in strictly ascending key order and be
/// deterministic: the same sequence of calls yields the same sequence of
/// pops regardless of the implementation chosen.
pub trait Scheduler {
    /// Schedule `kind` on `target` at absolute `tick`.
    fn schedule(
        &mut self,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle;

    /// Insert a fully formed event (used when draining cross-domain
    /// mailboxes); re-sequences it into this queue's order.
    fn insert(&mut self, ev: Event) -> EventHandle;

    /// Cancel a scheduled event. Cancelling an already-executed or unknown
    /// handle is a no-op (mirrors gem5's squash semantics).
    fn deschedule(&mut self, h: EventHandle);

    /// Tick of the next live event.
    fn next_tick(&mut self) -> Option<Tick>;

    /// Pop the next live event.
    fn pop(&mut self) -> Option<Event>;

    /// Number of live (non-cancelled, non-executed) events.
    fn len(&self) -> usize;

    /// Number of events popped (executed) from this queue.
    fn executed(&self) -> u64;

    /// Every live event in canonical `(tick, prio, seq)` order, without
    /// consuming anything or touching the executed counter. This is the
    /// checkpoint producer's view of the queue: cancelled tombstones are
    /// filtered out, so the result is a pure function of the schedule
    /// history — identical across queue implementations and producing
    /// kernels (docs/CHECKPOINT.md).
    fn pending_events(&self) -> Vec<Event>;

    /// Overwrite the executed-pop counter. Checkpoint restore uses this to
    /// resume the producer's event accounting on a freshly built queue.
    fn set_executed(&mut self, n: u64);

    /// gem5 reschedule = deschedule + schedule.
    fn reschedule(
        &mut self,
        h: EventHandle,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        self.deschedule(h);
        self.schedule(tick, prio, target, kind)
    }

    /// Pop the next live event only if it is strictly before `limit`.
    fn pop_before(&mut self, limit: Tick) -> Option<Event> {
        match self.next_tick() {
            Some(t) if t < limit => self.pop(),
            _ => None,
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_kind_parses() {
        assert_eq!(QueueKind::parse("heap"), Some(QueueKind::Heap));
        assert_eq!(QueueKind::parse("Bucket"), Some(QueueKind::Bucket));
        assert_eq!(QueueKind::parse("calendar"), Some(QueueKind::Bucket));
        assert_eq!(QueueKind::parse("fifo"), None);
    }

    #[test]
    fn default_is_bucket() {
        assert_eq!(QueueKind::default(), QueueKind::Bucket);
    }
}
