//! [`SchedQueue`]: the concrete, object-free dispatch over the two
//! [`Scheduler`] implementations.
//!
//! Kernels and the scheduling context hold a `SchedQueue` by value — enum
//! dispatch compiles to a two-way branch, so there is no vtable on the
//! per-event hot path and the implementations stay swappable per run via
//! [`QueueKind`].

use crate::sched::api::{EventHandle, QueueKind, Scheduler};
use crate::sched::bucket::{BucketQueue, BucketShape};
use crate::sched::heap::HeapQueue;
use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

pub enum SchedQueue {
    Heap(HeapQueue),
    Bucket(BucketQueue),
}

impl SchedQueue {
    pub fn new(kind: QueueKind) -> Self {
        Self::with_shape(kind, BucketShape::default())
    }

    /// Construct with an explicit calendar geometry (`--bucket-width` /
    /// `--bucket-slots`); the shape only matters for [`QueueKind::Bucket`].
    pub fn with_shape(kind: QueueKind, shape: BucketShape) -> Self {
        match kind {
            QueueKind::Heap => SchedQueue::Heap(HeapQueue::new()),
            QueueKind::Bucket => {
                SchedQueue::Bucket(BucketQueue::with_shape(shape))
            }
        }
    }

    pub fn kind(&self) -> QueueKind {
        match self {
            SchedQueue::Heap(_) => QueueKind::Heap,
            SchedQueue::Bucket(_) => QueueKind::Bucket,
        }
    }
}

impl Default for SchedQueue {
    fn default() -> Self {
        SchedQueue::new(QueueKind::default())
    }
}

macro_rules! delegate {
    ($self:ident, $q:ident => $body:expr) => {
        match $self {
            SchedQueue::Heap($q) => $body,
            SchedQueue::Bucket($q) => $body,
        }
    };
}

impl Scheduler for SchedQueue {
    fn schedule(
        &mut self,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        delegate!(self, q => q.schedule(tick, prio, target, kind))
    }

    fn insert(&mut self, ev: Event) -> EventHandle {
        delegate!(self, q => q.insert(ev))
    }

    fn deschedule(&mut self, h: EventHandle) {
        delegate!(self, q => q.deschedule(h))
    }

    fn next_tick(&mut self) -> Option<Tick> {
        delegate!(self, q => q.next_tick())
    }

    fn pop(&mut self) -> Option<Event> {
        delegate!(self, q => q.pop())
    }

    fn len(&self) -> usize {
        delegate!(self, q => q.len())
    }

    fn executed(&self) -> u64 {
        delegate!(self, q => q.executed())
    }

    fn pending_events(&self) -> Vec<Event> {
        delegate!(self, q => q.pending_events())
    }

    fn set_executed(&mut self, n: u64) {
        delegate!(self, q => q.set_executed(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_kinds_construct_and_schedule() {
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = SchedQueue::new(kind);
            assert_eq!(q.kind(), kind);
            q.schedule(5, 50, CompId(0), EventKind::CpuTick);
            q.schedule(1, 50, CompId(1), EventKind::CpuTick);
            assert_eq!(q.len(), 2);
            assert_eq!(q.pop().unwrap().target, CompId(1));
            assert_eq!(q.pop().unwrap().target, CompId(0));
            assert!(q.pop().is_none());
            assert_eq!(q.executed(), 2);
        }
    }

    /// `pending_events` is the checkpoint view of a queue: identical across
    /// implementations, in canonical `(tick, prio, seq)` order, with
    /// cancelled events filtered and the executed counter untouched.
    #[test]
    fn pending_events_is_canonical_and_kind_invariant() {
        let views: Vec<Vec<(Tick, u8, u64, CompId)>> = [QueueKind::Heap, QueueKind::Bucket]
            .into_iter()
            .map(|kind| {
                let mut q = SchedQueue::new(kind);
                q.schedule(50_000, 50, CompId(0), EventKind::CpuTick);
                q.schedule(7, 60, CompId(1), EventKind::CpuTick);
                let h = q.schedule(7, 50, CompId(2), EventKind::CpuTick);
                q.schedule(7, 50, CompId(3), EventKind::DramTick);
                q.deschedule(h);
                let before = q.executed();
                let evs = q.pending_events();
                assert_eq!(q.executed(), before, "pending_events must not pop");
                assert_eq!(q.len(), 3);
                evs.iter().map(|e| (e.tick, e.prio, e.seq, e.target)).collect()
            })
            .collect();
        assert_eq!(views[0], views[1], "queue kinds disagree on pending view");
        let ticks: Vec<Tick> = views[0].iter().map(|v| v.0).collect();
        assert_eq!(ticks, vec![7, 7, 50_000]);
        // prio breaks the same-tick tie: prio 50 (CompId 3, the survivor of
        // the cancelled pair) sorts before prio 60 (CompId 1).
        assert_eq!(views[0][0].3, CompId(3));
        assert_eq!(views[0][1].3, CompId(1));
    }
}
