//! [`SchedQueue`]: the concrete, object-free dispatch over the two
//! [`Scheduler`] implementations.
//!
//! Kernels and the scheduling context hold a `SchedQueue` by value — enum
//! dispatch compiles to a two-way branch, so there is no vtable on the
//! per-event hot path and the implementations stay swappable per run via
//! [`QueueKind`].

use crate::sched::api::{EventHandle, QueueKind, Scheduler};
use crate::sched::bucket::{BucketQueue, BucketShape};
use crate::sched::heap::HeapQueue;
use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

pub enum SchedQueue {
    Heap(HeapQueue),
    Bucket(BucketQueue),
}

impl SchedQueue {
    pub fn new(kind: QueueKind) -> Self {
        Self::with_shape(kind, BucketShape::default())
    }

    /// Construct with an explicit calendar geometry (`--bucket-width` /
    /// `--bucket-slots`); the shape only matters for [`QueueKind::Bucket`].
    pub fn with_shape(kind: QueueKind, shape: BucketShape) -> Self {
        match kind {
            QueueKind::Heap => SchedQueue::Heap(HeapQueue::new()),
            QueueKind::Bucket => {
                SchedQueue::Bucket(BucketQueue::with_shape(shape))
            }
        }
    }

    pub fn kind(&self) -> QueueKind {
        match self {
            SchedQueue::Heap(_) => QueueKind::Heap,
            SchedQueue::Bucket(_) => QueueKind::Bucket,
        }
    }
}

impl Default for SchedQueue {
    fn default() -> Self {
        SchedQueue::new(QueueKind::default())
    }
}

macro_rules! delegate {
    ($self:ident, $q:ident => $body:expr) => {
        match $self {
            SchedQueue::Heap($q) => $body,
            SchedQueue::Bucket($q) => $body,
        }
    };
}

impl Scheduler for SchedQueue {
    fn schedule(
        &mut self,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        delegate!(self, q => q.schedule(tick, prio, target, kind))
    }

    fn insert(&mut self, ev: Event) -> EventHandle {
        delegate!(self, q => q.insert(ev))
    }

    fn deschedule(&mut self, h: EventHandle) {
        delegate!(self, q => q.deschedule(h))
    }

    fn next_tick(&mut self) -> Option<Tick> {
        delegate!(self, q => q.next_tick())
    }

    fn pop(&mut self) -> Option<Event> {
        delegate!(self, q => q.pop())
    }

    fn len(&self) -> usize {
        delegate!(self, q => q.len())
    }

    fn executed(&self) -> u64 {
        delegate!(self, q => q.executed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_kinds_construct_and_schedule() {
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = SchedQueue::new(kind);
            assert_eq!(q.kind(), kind);
            q.schedule(5, 50, CompId(0), EventKind::CpuTick);
            q.schedule(1, 50, CompId(1), EventKind::CpuTick);
            assert_eq!(q.len(), 2);
            assert_eq!(q.pop().unwrap().target, CompId(1));
            assert_eq!(q.pop().unwrap().target, CompId(0));
            assert!(q.pop().is_none());
            assert_eq!(q.executed(), 2);
        }
    }
}
