//! Lock-free MPSC mailbox for cross-domain event injection.
//!
//! Replaces the `Mutex<Vec<Event>>` injector of §3.1: any domain thread may
//! `push` concurrently (multi-producer); only the owning domain `drain`s,
//! and only at quantum borders (single consumer). The structure is a
//! segment list: producers reserve a slot with one `fetch_add`, write the
//! event, and publish it with one release store — no CAS on the fast path
//! and no lock, so a burst of cross-domain schedules from many domains
//! never serialises on a mutex.
//!
//! # Memory-ordering argument
//!
//! * A producer claims slot `i` with `reserve.fetch_add(1, Relaxed)` —
//!   claiming needs atomicity, not ordering. It then writes the event and
//!   publishes with `ready[i].store(true, Release)`.
//! * The consumer reads `ready[i]` with `Acquire`; the release/acquire pair
//!   makes the event write visible before the slot is consumed.
//! * Segment growth: the full segment's `next` pointer is installed with a
//!   `AcqRel` compare-exchange and read with `Acquire`, so a producer (or
//!   the consumer) that follows `next` sees a fully initialised segment.
//! * `pushed`/`drained` counters use Release/Acquire so `is_empty()` is
//!   exact at quantum borders, where the barrier protocol guarantees all
//!   producers have published (every count update happens-before the
//!   barrier's own acquire/release chain).
//!
//! # Reclamation
//!
//! The kernel protocol drains mailboxes only between the freeze and verdict
//! phases of the quantum barrier, when every producer thread is parked
//! inside the barrier. A producer's transient reference to a segment
//! therefore cannot outlive the window that created it, and any fully
//! consumed segment with a successor can be freed immediately during
//! `drain` — no epochs or hazard pointers needed. (`tail` cannot dangle
//! either: a successor is only ever installed together with a tail
//! advance, both completed before the producer reaches the barrier.)

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::Ordering::{AcqRel, Acquire, Relaxed, Release};
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize};

use crate::sim::event::Event;

/// Events per segment; one segment is ~4 KiB, amortising allocation over
/// bursts while keeping idle mailboxes small.
const SEG_CAP: usize = 64;

struct Slot {
    ready: AtomicBool,
    ev: UnsafeCell<MaybeUninit<Event>>,
}

struct Segment {
    /// Slots claimed so far; may overshoot `SEG_CAP` (claims that lose the
    /// race simply move to the next segment).
    reserve: AtomicUsize,
    next: AtomicPtr<Segment>,
    slots: [Slot; SEG_CAP],
}

impl Segment {
    fn new_boxed() -> *mut Segment {
        Box::into_raw(Box::new(Segment {
            reserve: AtomicUsize::new(0),
            next: AtomicPtr::new(ptr::null_mut()),
            slots: std::array::from_fn(|_| Slot {
                ready: AtomicBool::new(false),
                ev: UnsafeCell::new(MaybeUninit::uninit()),
            }),
        }))
    }
}

pub struct Mailbox {
    /// Producers append here.
    tail: AtomicPtr<Segment>,
    /// Consumer cursor: the oldest not-fully-consumed segment...
    head: AtomicPtr<Segment>,
    /// ...and the next slot to consume within it (consumer-only).
    head_idx: AtomicUsize,
    /// Events published (post-commit) / consumed, for `is_empty`.
    pushed: AtomicU64,
    drained: AtomicU64,
    /// Guards the single-consumer / no-push-during-drain contract in tests.
    #[cfg(debug_assertions)]
    draining: AtomicBool,
}

// SAFETY: `Event` is Send (it already crossed threads inside the old
// `Mutex<Vec<Event>>`); all shared mutation goes through atomics, and the
// raw slot accesses are ordered by the ready flags as argued above.
unsafe impl Send for Mailbox {}
unsafe impl Sync for Mailbox {}

impl Default for Mailbox {
    fn default() -> Self {
        let seg = Segment::new_boxed();
        Mailbox {
            tail: AtomicPtr::new(seg),
            head: AtomicPtr::new(seg),
            head_idx: AtomicUsize::new(0),
            pushed: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            #[cfg(debug_assertions)]
            draining: AtomicBool::new(false),
        }
    }
}

impl Mailbox {
    /// Push an event from any thread. Lock-free: one `fetch_add` plus one
    /// release store on the fast path.
    pub fn push(&self, ev: Event) {
        #[cfg(debug_assertions)]
        debug_assert!(
            !self.draining.load(Relaxed),
            "Mailbox::push during drain violates the border protocol"
        );
        let mut ev = Some(ev);
        loop {
            let seg = self.tail.load(Acquire);
            // SAFETY: segments are only freed while producers are parked at
            // the quantum barrier (see module docs), so `seg` is live.
            let s = unsafe { &*seg };
            let idx = s.reserve.fetch_add(1, Relaxed);
            if idx < SEG_CAP {
                // SAFETY: `fetch_add` hands out each index exactly once, so
                // this thread exclusively owns slot `idx` until `ready` is
                // published.
                unsafe {
                    (*s.slots[idx].ev.get()).write(ev.take().unwrap());
                }
                s.slots[idx].ready.store(true, Release);
                self.pushed.fetch_add(1, Release);
                return;
            }
            // Segment full: install (or discover) the successor, advance
            // the shared tail, and retry there.
            let next = s.next.load(Acquire);
            let next = if next.is_null() {
                let fresh = Segment::new_boxed();
                match s.next.compare_exchange(
                    ptr::null_mut(),
                    fresh,
                    AcqRel,
                    Acquire,
                ) {
                    Ok(_) => fresh,
                    Err(existing) => {
                        // SAFETY: `fresh` was never shared.
                        unsafe { drop(Box::from_raw(fresh)) };
                        existing
                    }
                }
            } else {
                next
            };
            let _ = self.tail.compare_exchange(seg, next, AcqRel, Acquire);
        }
    }

    /// Drain all published events, sorted by `(tick, prio, target, seq)`.
    ///
    /// Producers stamp `seq` with the canonical
    /// `(sender_domain << XSEQ_BITS) | send_counter` merge key
    /// ([`crate::sim::shared::SharedState::next_injector_seq`]), which
    /// makes this sort **total**: two distinct same-tick deliveries to
    /// the same target (e.g. the `--io-milli` crossbar's packets) order
    /// by sender domain and the sender's program order — a pure function
    /// of the simulation — never by host push interleaving. Insertion
    /// order into the domain queue (and therefore re-sequencing) is
    /// exactly reproducible across kernels and thread counts.
    ///
    /// Contract: single consumer (the owning domain), called only at
    /// quantum borders while producers are parked at the barrier.
    pub fn drain(&self) -> Vec<Event> {
        let mut out = Vec::new();
        self.drain_into(&mut out);
        out
    }

    /// [`Mailbox::drain`] into a caller-owned scratch Vec (cleared first).
    /// The border path reuses one scratch per domain, so a steady-state
    /// drain allocates nothing: the scratch keeps its capacity and the
    /// sort is unstable (in-place) — safe because the canonical seq key
    /// makes the sort key total, so stability buys nothing.
    pub fn drain_into(&self, out: &mut Vec<Event>) {
        #[cfg(debug_assertions)]
        assert!(
            !self.draining.swap(true, Acquire),
            "concurrent Mailbox::drain (single-consumer contract violated)"
        );
        out.clear();
        // SAFETY: single consumer; segments ahead of `head` are only freed
        // here; producers are quiescent per the border protocol.
        unsafe {
            let mut seg = self.head.load(Acquire);
            let mut idx = self.head_idx.load(Relaxed);
            loop {
                let s = &*seg;
                let committed = s.reserve.load(Acquire).min(SEG_CAP);
                while idx < committed {
                    if !s.slots[idx].ready.load(Acquire) {
                        // Claimed but unpublished: impossible at a border;
                        // stop defensively rather than spin.
                        break;
                    }
                    out.push((*s.slots[idx].ev.get()).assume_init_read());
                    s.slots[idx].ready.store(false, Relaxed);
                    idx += 1;
                }
                let next = s.next.load(Acquire);
                if idx >= SEG_CAP && !next.is_null() {
                    // Fully consumed and superseded: free it (safe per the
                    // reclamation argument in the module docs).
                    drop(Box::from_raw(seg));
                    seg = next;
                    idx = 0;
                } else {
                    break;
                }
            }
            self.head.store(seg, Release);
            self.head_idx.store(idx, Relaxed);
        }
        self.drained.fetch_add(out.len() as u64, Release);
        #[cfg(debug_assertions)]
        self.draining.store(false, Release);
        out.sort_unstable_by_key(|e| (e.tick, e.prio, e.target.0, e.seq));
    }

    /// Exact at quantum borders (producers quiescent); a racy estimate
    /// otherwise.
    pub fn is_empty(&self) -> bool {
        self.drained.load(Acquire) == self.pushed.load(Acquire)
    }
}

impl Drop for Mailbox {
    fn drop(&mut self) {
        unsafe {
            let mut seg = *self.head.get_mut();
            let mut idx = *self.head_idx.get_mut();
            while !seg.is_null() {
                let next;
                {
                    let s = &mut *seg;
                    let committed = (*s.reserve.get_mut()).min(SEG_CAP);
                    for i in idx..committed {
                        if *s.slots[i].ready.get_mut() {
                            (*s.slots[i].ev.get()).assume_init_drop();
                        }
                    }
                    next = *s.next.get_mut();
                }
                drop(Box::from_raw(seg));
                seg = next;
                idx = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::EventKind;
    use crate::sim::ids::CompId;
    use crate::sim::time::Tick;

    fn ev(tick: Tick, target: u32) -> Event {
        Event {
            tick,
            prio: 50,
            seq: 0,
            target: CompId(target),
            kind: EventKind::CpuTick,
        }
    }

    #[test]
    fn drain_is_sorted() {
        let m = Mailbox::default();
        for (t, c) in [(30u64, 1u32), (10, 2), (10, 0), (20, 3)] {
            m.push(ev(t, c));
        }
        let v = m.drain();
        let keys: Vec<(Tick, u32)> =
            v.iter().map(|e| (e.tick, e.target.0)).collect();
        assert_eq!(keys, vec![(10, 0), (10, 2), (20, 3), (30, 1)]);
        assert!(m.is_empty());
    }

    #[test]
    fn survives_segment_growth() {
        let m = Mailbox::default();
        let n = SEG_CAP as u64 * 5 + 3;
        for i in 0..n {
            m.push(ev(i, i as u32));
        }
        assert!(!m.is_empty());
        let v = m.drain();
        assert_eq!(v.len(), n as usize);
        for (i, e) in v.iter().enumerate() {
            assert_eq!(e.tick, i as u64);
        }
        assert!(m.is_empty());
        // Reuse after full drain.
        m.push(ev(7, 7));
        assert_eq!(m.drain().len(), 1);
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let m = Mailbox::default();
        let per = 10_000u64;
        let producers = 4u64;
        std::thread::scope(|s| {
            for p in 0..producers {
                let m = &m;
                s.spawn(move || {
                    for i in 0..per {
                        m.push(ev(p * per + i, p as u32));
                    }
                });
            }
        });
        let v = m.drain();
        assert_eq!(v.len(), (per * producers) as usize);
        // All distinct ticks present exactly once (drain sorts by tick).
        for (i, e) in v.iter().enumerate() {
            assert_eq!(e.tick, i as u64, "lost or duplicated event");
        }
        assert!(m.is_empty());
    }

    #[test]
    fn undrained_events_are_dropped_cleanly() {
        let m = Mailbox::default();
        for i in 0..(SEG_CAP as u64 * 2) {
            m.push(ev(i, 0));
        }
        drop(m); // must free all segments and the pending events
    }

    #[test]
    fn same_tick_same_target_orders_by_canonical_key_not_push_order() {
        // Regression for the `--io-milli` crossbar path: two distinct
        // same-tick deliveries to the same consumer used to tie (both
        // carried seq 0) and the stable sort fell back to host push
        // order. With the canonical (sender_domain << XSEQ_BITS) | count
        // key the drain order is total: a maximally skewed host that
        // appends domain 2's sends before domain 1's must still drain
        // domain 1 first, and each domain's own sends in program order.
        let key = |dom: u64, cnt: u64| {
            (dom << crate::sim::shared::XSEQ_BITS) | cnt
        };
        let m = Mailbox::default();
        for (dom, cnt) in [(2u64, 0u64), (2, 1), (1, 1), (1, 0)] {
            m.push(Event {
                tick: 100,
                prio: 50,
                seq: key(dom, cnt),
                target: CompId(7),
                kind: EventKind::CpuTick,
            });
        }
        let keys: Vec<u64> = m.drain().iter().map(|e| e.seq).collect();
        assert_eq!(
            keys,
            vec![key(1, 0), key(1, 1), key(2, 0), key(2, 1)],
            "ties must break by (sender domain, send order), not push order"
        );
    }

    #[test]
    fn alternating_push_drain_batches() {
        let m = Mailbox::default();
        let mut total = 0usize;
        for round in 0..10u64 {
            for i in 0..37u64 {
                m.push(ev(round * 1000 + i, i as u32));
            }
            total += m.drain().len();
            assert!(m.is_empty());
        }
        assert_eq!(total, 370);
    }
}
