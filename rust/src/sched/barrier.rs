//! Sense-reversing combining-tree quantum barrier with abort support.
//!
//! The threaded kernel synchronises all domain threads at every quantum
//! border (Fig. 1b). The old centralised barrier funnelled every arrival
//! through one mutex + condvar, an O(n) cache-line ping-pong per phase;
//! here arrivals combine up a fan-in-`FANIN` tree of cache-line-padded
//! counters, so contention per node is bounded by the fan-in, and release
//! is a single global sense flip that waiters observe with one acquire
//! load.
//!
//! Protocol per round:
//! 1. Thread `t` increments its leaf node (`fetch_add`, AcqRel). The last
//!    arriver at a node resets it for the next round and climbs to the
//!    parent; everyone else waits on the sense word.
//! 2. The thread that completes the root flips the global sense (Release)
//!    and returns [`Outcome::Leader`] — exactly one leader per round.
//! 3. Waiters spin (then yield, then sleep) until the sense matches their
//!    per-[`Waiter`] expectation and return [`Outcome::Follower`].
//!
//! The AcqRel increments chain every pre-barrier write into the root flip,
//! and the waiters' Acquire load extends the chain to them — so the
//! barrier is a full happens-before frontier without any `SeqCst`.
//!
//! Node resets are safe without double-buffering: a thread can only arrive
//! at a node for round `r+1` after observing the round-`r` sense flip,
//! which the resetting thread performed (transitively) *after* the reset.
//!
//! A panic inside a domain thread calls [`TreeBarrier::abort`]; every
//! current and future waiter then returns [`Outcome::Aborted`] instead of
//! deadlocking.

use std::sync::atomic::Ordering::{AcqRel, Acquire, Relaxed, Release};
use std::sync::atomic::{AtomicBool, AtomicUsize};

/// Tree fan-in: 4 keeps the tree shallow for realistic domain counts
/// (≤ 129 threads in the paper's sweeps → 4 levels) while bounding
/// per-node contention.
const FANIN: usize = 4;

const NO_PARENT: usize = usize::MAX;

/// One combining node, padded to a cache line so arrivals at different
/// nodes never false-share.
#[repr(align(64))]
struct Node {
    count: AtomicUsize,
    expected: usize,
    parent: usize,
}

impl Node {
    fn new(expected: usize) -> Self {
        Node { count: AtomicUsize::new(0), expected, parent: NO_PARENT }
    }
}

/// Per-thread barrier state: assigned leaf and local sense.
pub struct Waiter {
    leaf: usize,
    sense: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed the root in this round (exactly one per round).
    Leader,
    Follower,
    /// A peer aborted (panicked); stop immediately.
    Aborted,
}

pub struct TreeBarrier {
    nodes: Vec<Node>,
    /// Leaf node index for each participant.
    leaf_of: Vec<usize>,
    sense: AtomicBool,
    aborted: AtomicBool,
}

impl TreeBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "barrier needs at least one participant");
        let mut nodes = Vec::new();
        let mut leaf_of = vec![0usize; n];
        // Level 0: group threads FANIN at a time.
        let l0 = n.div_ceil(FANIN);
        for g in 0..l0 {
            let lo = g * FANIN;
            let hi = ((g + 1) * FANIN).min(n);
            for t in lo..hi {
                leaf_of[t] = g;
            }
            nodes.push(Node::new(hi - lo));
        }
        // Upper levels: group nodes until a single root remains.
        let mut level: Vec<usize> = (0..l0).collect();
        while level.len() > 1 {
            let mut next_level = Vec::new();
            for group in level.chunks(FANIN) {
                let parent = nodes.len();
                nodes.push(Node::new(group.len()));
                for &c in group {
                    nodes[c].parent = parent;
                }
                next_level.push(parent);
            }
            level = next_level;
        }
        TreeBarrier {
            nodes,
            leaf_of,
            sense: AtomicBool::new(false),
            aborted: AtomicBool::new(false),
        }
    }

    /// Per-thread state for participant `thread` (0-based, `< n`).
    pub fn waiter(&self, thread: usize) -> Waiter {
        Waiter { leaf: self.leaf_of[thread], sense: true }
    }

    pub fn wait(&self, w: &mut Waiter) -> Outcome {
        if self.aborted.load(Acquire) {
            return Outcome::Aborted;
        }
        let target = w.sense;
        w.sense = !w.sense;
        let mut node = w.leaf;
        loop {
            let nd = &self.nodes[node];
            if nd.count.fetch_add(1, AcqRel) + 1 < nd.expected {
                break; // not last here: wait for the sense flip below
            }
            // Last arrival at this node: reset it for the next round
            // (safe — see module docs) and climb.
            nd.count.store(0, Relaxed);
            if nd.parent == NO_PARENT {
                self.sense.store(target, Release);
                return Outcome::Leader;
            }
            node = nd.parent;
        }
        let mut spins = 0u32;
        while self.sense.load(Acquire) != target {
            if self.aborted.load(Acquire) {
                return Outcome::Aborted;
            }
            spins += 1;
            if spins < 64 {
                std::hint::spin_loop();
            } else if spins < 4096 {
                // Oversubscribed hosts (fewer cores than domains) must let
                // peers run; pure spinning would deadlock a timeslice.
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
        }
        Outcome::Follower
    }

    /// Release every waiter with `Aborted`; all future waits abort too.
    pub fn abort(&self) {
        self.aborted.store(true, Release);
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted.load(Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::SeqCst;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn all_threads_pass_each_generation() {
        for n in [2usize, 4, 5, 9, 17] {
            let b = TreeBarrier::new(n);
            let leaders = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for t in 0..n {
                    let b = &b;
                    let leaders = &leaders;
                    s.spawn(move || {
                        let mut w = b.waiter(t);
                        for _ in 0..100 {
                            if b.wait(&mut w) == Outcome::Leader {
                                leaders.fetch_add(1, SeqCst);
                            }
                        }
                    });
                }
            });
            assert_eq!(
                leaders.load(SeqCst),
                100,
                "exactly one leader per round (n={n})"
            );
        }
    }

    #[test]
    fn single_participant_is_always_leader() {
        let b = TreeBarrier::new(1);
        let mut w = b.waiter(0);
        for _ in 0..10 {
            assert_eq!(b.wait(&mut w), Outcome::Leader);
        }
    }

    #[test]
    fn barrier_orders_memory() {
        // Data written before round r must be visible after round r.
        let n = 4usize;
        let b = TreeBarrier::new(n);
        let slots: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|s| {
            for t in 0..n {
                let b = &b;
                let slots = &slots;
                s.spawn(move || {
                    let mut w = b.waiter(t);
                    for round in 1..50usize {
                        slots[t].store(round, Relaxed);
                        b.wait(&mut w);
                        for other in slots {
                            assert!(other.load(Relaxed) >= round);
                        }
                        b.wait(&mut w); // keep rounds aligned
                    }
                });
            }
        });
    }

    #[test]
    fn abort_releases_waiters() {
        let b = TreeBarrier::new(3);
        std::thread::scope(|s| {
            let h1 = s.spawn(|| b.wait(&mut b.waiter(0)));
            let h2 = s.spawn(|| b.wait(&mut b.waiter(1)));
            std::thread::sleep(std::time::Duration::from_millis(20));
            b.abort();
            assert_eq!(h1.join().unwrap(), Outcome::Aborted);
            assert_eq!(h2.join().unwrap(), Outcome::Aborted);
        });
        let mut w = b.waiter(2);
        assert_eq!(b.wait(&mut w), Outcome::Aborted, "future waits abort too");
    }
}
