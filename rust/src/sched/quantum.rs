//! Adaptive-quantum policy: how the border leader picks the next
//! `window_end`.
//!
//! With a fixed quantum the kernel executes a barrier every `t_q` of
//! simulated time even when no domain has an event for thousands of ticks
//! (DRAM stalls, devices idling, all cores blocked on a miss). The border
//! verdict of the three-phase protocol already sees every domain's
//! post-drain `next_tick`, so the leader can compute the **global event
//! horizon** — the minimum over all domains — and leap the window directly
//! to the first quantum border after it, skipping the dead windows
//! entirely.
//!
//! The leap is **exact**, not an approximation: events only execute in
//! windows that contain them, cross-domain postponement targets only depend
//! on the `window_end` of windows in which events execute, and the chosen
//! `window_end` stays on the fixed quantum grid — so every policy executes
//! the same events in the same windows and produces bit-identical
//! `sim_ticks` and per-component statistics. Only the number of barriers
//! (and therefore host wall-clock) changes. DESIGN.md §4.4 carries the full
//! argument.
//!
//! Policies ([`QuantumPolicy`], selected via `RunConfig::quantum_policy` /
//! `--quantum-policy`):
//!
//! * `Fixed` — the paper's behaviour: `window_end += quantum`, always.
//! * `Horizon` — leap to the first grid border strictly after the global
//!   horizon (unbounded leap).
//! * `Hybrid` — like `Horizon` but leap at most `max_leap` quanta per
//!   border, bounding the worst-case border-to-border latency for host-side
//!   observers (stats polling, stop-flag responsiveness).

use crate::sim::time::Tick;

/// Default `max_leap` for [`QuantumPolicy::Hybrid`] (quanta per border).
pub const DEFAULT_MAX_LEAP: u32 = 64;

/// How the border leader advances `window_end` (see module docs).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum QuantumPolicy {
    /// Fixed windows: `window_end += quantum` at every border.
    #[default]
    Fixed,
    /// Leap to the first quantum-grid border strictly after the global
    /// event horizon; dead windows cost no barrier at all.
    Horizon,
    /// Horizon leaping, clamped to at most `max_leap` quanta per border.
    Hybrid {
        /// Maximum quanta leapt in one border decision (≥ 1).
        max_leap: u32,
    },
}

impl QuantumPolicy {
    /// Parse a `--quantum-policy` value (`fixed`, `horizon`, `hybrid`).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fixed" => QuantumPolicy::Fixed,
            "horizon" => QuantumPolicy::Horizon,
            "hybrid" => QuantumPolicy::Hybrid { max_leap: DEFAULT_MAX_LEAP },
            _ => return None,
        })
    }
}

/// How cross-domain Ruby deliveries become visible to their consumer
/// (`--inbox-order`, DESIGN.md §6 and docs/DETERMINISM.md).
///
/// The paper concedes (§6) that the threaded kernel consumes Ruby messages
/// in host-timing-dependent order: a delivery pushed mid-window is seen by
/// any consumer wakeup that happens to drain after it lands, so two runs of
/// the same simulation can interleave message consumption differently.
/// `Border` removes exactly that freedom — and nothing else.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum InboxOrder {
    /// The paper's behaviour: cross-domain deliveries land in the
    /// consumer's message buffers immediately; drain order (and therefore
    /// timing) depends on host thread interleaving. Kept selectable as the
    /// reference for the paper's §6 nondeterminism discussion.
    Host,
    /// Deterministic border-ordered handoff: cross-domain deliveries are
    /// staged per sender domain during the window and merged into the
    /// consumer's buffers at the quantum border in canonical
    /// `(arrival_tick, sender_domain, seq)` order, so consumption never
    /// depends on host timing. The threaded kernel becomes bit-identical
    /// to the virtual kernel across thread counts, quantum policies and
    /// stealing.
    #[default]
    Border,
}

impl InboxOrder {
    /// Parse an `--inbox-order` value (`host`, `border`).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "host" => InboxOrder::Host,
            "border" => InboxOrder::Border,
            _ => return None,
        })
    }
}

/// How the IO crossbar arbitrates layer occupancy (`--xbar-arb`,
/// docs/XBAR.md and docs/DETERMINISM.md).
///
/// The paper's §4.3 crossbar guards each layer with a mutex and resolves
/// occupancy with `try_lock` *mid-window* — which initiator wins a layer
/// depends on host thread timing, the last documented source of
/// nondeterminism under true thread concurrency. `Border` extends the
/// border-handoff protocol from messages to *resources*: layer requests
/// are staged during the window and granted at the quantum border in
/// canonical `(request_tick, sender_domain, seq)` order.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum XbarArb {
    /// The paper's behaviour: occupancy is resolved mid-window with
    /// `try_lock` + occupy/busy on live layer state; which initiator wins
    /// can depend on host timing. Kept selectable as the A/B lever for
    /// divergence bisection (docs/DETERMINISM.md §4).
    Host,
    /// Deterministic border-staged arbitration: layer requests are staged
    /// per sender domain during the window and granted at the quantum
    /// border — inside the quiescent span — in canonical
    /// `(request_tick, sender_domain, seq)` order; busy outcomes stay
    /// queued and replay as postponed grants at later borders. Together
    /// with [`InboxOrder::Border`] this makes the threaded kernel
    /// bit-identical to the virtual kernel even on IO-heavy runs under
    /// true thread concurrency.
    #[default]
    Border,
}

impl XbarArb {
    /// Parse an `--xbar-arb` value (`host`, `border`).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "host" => XbarArb::Host,
            "border" => XbarArb::Border,
            _ => return None,
        })
    }
}

/// Per-run scheduling policy knobs, carried by the shared state so both
/// parallel kernels read the same configuration at the border.
#[derive(Copy, Clone, Debug, Default)]
pub struct RunPolicy {
    /// Window-advance policy (see [`QuantumPolicy`]).
    pub quantum_policy: QuantumPolicy,
    /// Claim-based window work stealing in the threaded kernel (opt-in;
    /// see [`crate::sched::ClaimList`]).
    pub steal: bool,
    /// Host threads for the threaded kernel; `0` means one per domain
    /// (the paper's configuration).
    pub threads: usize,
    /// Cross-domain Ruby message visibility (see [`InboxOrder`]; the
    /// default is the deterministic border-ordered handoff).
    pub inbox_order: InboxOrder,
    /// IO-crossbar layer arbitration (see [`XbarArb`]; the default is the
    /// deterministic border-staged grant protocol).
    pub xbar_arb: XbarArb,
    /// `--profile`: record per-thread, per-phase wall breakdowns
    /// (window-exec / freeze-wait / border-sync / publish-wait ns) into
    /// [`crate::sim::shared::PdesStats`]. Host-side observation only — no
    /// simulation decision reads the timers, so every deterministic
    /// guarantee is unchanged (gated by `tests/perf_identity.rs`).
    pub profile: bool,
}

impl RunPolicy {
    /// True when any border-staged protocol is active, i.e. the windowed
    /// kernels must run the [`crate::sim::component::Component::border_merge`]
    /// hooks inside the quiescent span of the border protocol.
    pub fn border_staging(&self) -> bool {
        self.inbox_order == InboxOrder::Border
            || self.xbar_arb == XbarArb::Border
    }
}

/// One border decision: the next `window_end` plus how many whole quanta
/// of dead simulated time the leap skipped (0 under [`QuantumPolicy::Fixed`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct WindowPlan {
    pub window_end: Tick,
    pub skipped_quanta: u64,
}

/// Compute the next `window_end` from the current border.
///
/// `cur_end` is the border being decided at (always on the quantum grid),
/// `horizon` the global minimum post-drain `next_tick` over all domains.
/// The result is always on the grid, always advances by at least one
/// quantum, and never leaps past an existing event: the returned window is
/// exactly the one in which the horizon event executes under the fixed
/// policy (or an earlier, provably empty one under `Hybrid`'s clamp).
pub fn plan_next_window(
    policy: QuantumPolicy,
    cur_end: Tick,
    quantum: Tick,
    horizon: Tick,
) -> WindowPlan {
    debug_assert!(quantum > 0, "windowed kernels require a positive quantum");
    let base = cur_end.saturating_add(quantum);
    let cap = match policy {
        QuantumPolicy::Fixed => {
            return WindowPlan { window_end: base, skipped_quanta: 0 };
        }
        QuantumPolicy::Horizon => Tick::MAX,
        QuantumPolicy::Hybrid { max_leap } => cur_end
            .saturating_add(quantum.saturating_mul(max_leap.max(1) as Tick)),
    };
    // First grid border strictly after the horizon: the window an event at
    // `horizon` executes in (events run strictly before `window_end`).
    let target = (horizon / quantum).saturating_add(1).saturating_mul(quantum);
    let window_end = target.clamp(base, cap.max(base));
    WindowPlan {
        window_end,
        skipped_quanta: (window_end - base) / quantum,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inbox_order_parses_and_defaults_to_border() {
        assert_eq!(InboxOrder::parse("host"), Some(InboxOrder::Host));
        assert_eq!(InboxOrder::parse("Border"), Some(InboxOrder::Border));
        assert_eq!(InboxOrder::parse("sorted"), None);
        assert_eq!(InboxOrder::default(), InboxOrder::Border);
        assert_eq!(RunPolicy::default().inbox_order, InboxOrder::Border);
    }

    #[test]
    fn xbar_arb_parses_and_defaults_to_border() {
        assert_eq!(XbarArb::parse("host"), Some(XbarArb::Host));
        assert_eq!(XbarArb::parse("Border"), Some(XbarArb::Border));
        assert_eq!(XbarArb::parse("staged"), None);
        assert_eq!(XbarArb::default(), XbarArb::Border);
        assert_eq!(RunPolicy::default().xbar_arb, XbarArb::Border);
    }

    #[test]
    fn border_staging_reflects_either_protocol() {
        let mut p = RunPolicy::default();
        assert!(p.border_staging(), "both default to border");
        p.inbox_order = InboxOrder::Host;
        assert!(p.border_staging(), "xbar border alone keeps the hooks on");
        p.xbar_arb = XbarArb::Host;
        assert!(!p.border_staging(), "both host: hooks off");
        p.inbox_order = InboxOrder::Border;
        assert!(p.border_staging(), "inbox border alone keeps the hooks on");
    }

    #[test]
    fn parses() {
        assert_eq!(QuantumPolicy::parse("fixed"), Some(QuantumPolicy::Fixed));
        assert_eq!(
            QuantumPolicy::parse("Horizon"),
            Some(QuantumPolicy::Horizon)
        );
        assert_eq!(
            QuantumPolicy::parse("hybrid"),
            Some(QuantumPolicy::Hybrid { max_leap: DEFAULT_MAX_LEAP })
        );
        assert_eq!(QuantumPolicy::parse("adaptive"), None);
    }

    #[test]
    fn fixed_always_steps_one_quantum() {
        for horizon in [0u64, 5, 100, 10_000, Tick::MAX] {
            let p = plan_next_window(QuantumPolicy::Fixed, 80, 10, horizon);
            assert_eq!(p, WindowPlan { window_end: 90, skipped_quanta: 0 });
        }
    }

    #[test]
    fn horizon_within_next_window_steps_one_quantum() {
        // Next event at tick 83: the next window (80, 90) contains it.
        let p = plan_next_window(QuantumPolicy::Horizon, 80, 10, 83);
        assert_eq!(p, WindowPlan { window_end: 90, skipped_quanta: 0 });
    }

    #[test]
    fn horizon_leaps_dead_windows() {
        // Next event at tick 137: windows ending 90..=130 are dead; the
        // event executes in (130, 140).
        let p = plan_next_window(QuantumPolicy::Horizon, 80, 10, 137);
        assert_eq!(p, WindowPlan { window_end: 140, skipped_quanta: 5 });
    }

    #[test]
    fn horizon_on_grid_border_lands_in_covering_window() {
        // An event exactly at a border tick executes in the window that
        // *ends after* it (windows are end-exclusive).
        let p = plan_next_window(QuantumPolicy::Horizon, 80, 10, 130);
        assert_eq!(p, WindowPlan { window_end: 140, skipped_quanta: 5 });
    }

    #[test]
    fn horizon_in_past_never_stalls() {
        // A late cross-domain insert below the border still advances the
        // window by one quantum (it executes in the very next window).
        let p = plan_next_window(QuantumPolicy::Horizon, 80, 10, 4);
        assert_eq!(p, WindowPlan { window_end: 90, skipped_quanta: 0 });
    }

    #[test]
    fn hybrid_clamps_the_leap() {
        let p = plan_next_window(
            QuantumPolicy::Hybrid { max_leap: 3 },
            80,
            10,
            1000,
        );
        assert_eq!(p, WindowPlan { window_end: 110, skipped_quanta: 2 });
        // Within the clamp it behaves like Horizon.
        let p = plan_next_window(
            QuantumPolicy::Hybrid { max_leap: 8 },
            80,
            10,
            137,
        );
        assert_eq!(p, WindowPlan { window_end: 140, skipped_quanta: 5 });
    }

    #[test]
    fn stays_on_the_quantum_grid() {
        for policy in [
            QuantumPolicy::Fixed,
            QuantumPolicy::Horizon,
            QuantumPolicy::Hybrid { max_leap: 4 },
        ] {
            let mut cur = 16u64;
            for horizon in [17u64, 40, 900, 3333, 100_000] {
                let p = plan_next_window(policy, cur, 16, horizon);
                assert_eq!(p.window_end % 16, 0, "{policy:?} left the grid");
                assert!(p.window_end > cur, "{policy:?} did not advance");
                if policy == QuantumPolicy::Horizon {
                    assert!(
                        p.window_end > horizon,
                        "Horizon must land past the next event"
                    );
                }
                cur = p.window_end;
            }
        }
    }

    #[test]
    fn saturates_instead_of_overflowing() {
        let p = plan_next_window(
            QuantumPolicy::Horizon,
            Tick::MAX - 10,
            1 << 40,
            Tick::MAX - 5,
        );
        assert_eq!(p.window_end, Tick::MAX);
    }
}
