//! The scheduler layer: everything between "a component schedules an event"
//! and "a domain thread executes it" (parti-gem5 §3.1, Fig. 1b).
//!
//! This subsystem owns the three hot-path mechanisms of the PDES kernel and
//! hides their internals behind a small, object-free API:
//!
//! * [`api`] — the [`Scheduler`] trait (gem5's schedule / deschedule /
//!   reschedule surface) plus [`EventHandle`] and the [`QueueKind`] selector.
//! * [`heap`] — [`HeapQueue`], the reference binary-heap implementation.
//! * [`bucket`] — [`BucketQueue`], a two-level bucketed (calendar-style)
//!   queue keyed by `(tick, prio, seq)`.
//! * [`queue`] — [`SchedQueue`], the enum that statically dispatches to one
//!   of the two implementations (no trait objects on the hot path).
//! * [`mailbox`] — [`Mailbox`], the lock-free MPSC segment-list injector
//!   for cross-domain event scheduling.
//! * [`barrier`] — [`TreeBarrier`], the sense-reversing combining-tree
//!   quantum barrier with abort support.
//! * [`quantum`] — [`QuantumPolicy`] and [`plan_next_window`], the
//!   adaptive-quantum border decision (leap over provably dead windows),
//!   plus [`RunPolicy`], the per-run policy knobs, [`InboxOrder`],
//!   the cross-domain Ruby message visibility contract (the deterministic
//!   border-ordered handoff vs the paper's host-order consumption), and
//!   [`XbarArb`], the IO-crossbar layer-arbitration contract (the
//!   deterministic border-staged grants vs the paper's mid-window
//!   `try_lock`, docs/XBAR.md).
//! * [`steal`] — [`ClaimList`], the per-window domain→thread claim list
//!   that lets idle host threads adopt the windows of loaded domains with
//!   a deterministic victim order.
//!
//! Nothing outside this module names a queue, injector, barrier or border
//! policy implementation directly: kernels and models go through
//! [`SchedQueue`], [`Mailbox`], [`TreeBarrier`], [`plan_next_window`] and
//! [`ClaimList`] only, so future scaling work (e.g. queue sharding) stays
//! local to `sched/`.

pub mod api;
pub mod barrier;
pub mod bucket;
pub mod heap;
pub mod mailbox;
pub mod quantum;
pub mod queue;
pub mod steal;

pub use api::{EventHandle, QueueKind, Scheduler};
pub use barrier::{Outcome, TreeBarrier, Waiter};
pub use bucket::{BucketQueue, BucketShape};
pub use heap::HeapQueue;
pub use mailbox::Mailbox;
pub use quantum::{
    plan_next_window, InboxOrder, QuantumPolicy, RunPolicy, WindowPlan,
    XbarArb, DEFAULT_MAX_LEAP,
};
pub use queue::SchedQueue;
pub use steal::ClaimList;
