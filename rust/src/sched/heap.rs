//! The reference event queue: a min-heap over `(tick, prio, seq)`.
//!
//! Descheduling is implemented with lazy tombstones (`cancelled` set), which
//! keeps `schedule` O(log n) and avoids heap surgery; cancelled entries are
//! dropped when they surface. A separate `pending` set tracks the live
//! events, which both makes `len()` exact and makes descheduling an
//! already-popped handle a true no-op (previously such a handle left a
//! permanent tombstone and `len()` underflowed).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use rustc_hash::FxHashSet;

use crate::sched::api::{EventHandle, Scheduler};
use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

#[derive(Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Reverse<Event>>,
    /// Seqs scheduled and not yet popped or cancelled (the live set).
    pending: FxHashSet<u64>,
    /// Tombstones still physically present in the heap.
    cancelled: FxHashSet<u64>,
    next_seq: u64,
    executed: u64,
}

impl HeapQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop cancelled events sitting at the head.
    #[inline]
    fn skim(&mut self) {
        // Fast path: descheduling is rare (§Perf L3.3) — skip the per-pop
        // tombstone lookup entirely when no event is cancelled.
        if self.cancelled.is_empty() {
            return;
        }
        while let Some(Reverse(e)) = self.heap.peek() {
            if self.cancelled.remove(&e.seq) {
                self.heap.pop();
            } else {
                break;
            }
        }
    }
}

impl Scheduler for HeapQueue {
    fn schedule(
        &mut self,
        tick: Tick,
        prio: u8,
        target: CompId,
        kind: EventKind,
    ) -> EventHandle {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.insert(seq);
        self.heap.push(Reverse(Event { tick, prio, seq, target, kind }));
        EventHandle(seq)
    }

    fn insert(&mut self, mut ev: Event) -> EventHandle {
        ev.seq = self.next_seq;
        self.next_seq += 1;
        let h = EventHandle(ev.seq);
        self.pending.insert(ev.seq);
        self.heap.push(Reverse(ev));
        h
    }

    fn deschedule(&mut self, h: EventHandle) {
        // Only a live handle becomes a tombstone; descheduling an executed
        // or unknown handle is a no-op (the len-underflow fix).
        if self.pending.remove(&h.0) {
            self.cancelled.insert(h.0);
        }
    }

    fn next_tick(&mut self) -> Option<Tick> {
        self.skim();
        self.heap.peek().map(|Reverse(e)| e.tick)
    }

    fn pop(&mut self) -> Option<Event> {
        self.skim();
        let ev = self.heap.pop().map(|Reverse(e)| e);
        if let Some(e) = &ev {
            self.pending.remove(&e.seq);
            self.executed += 1;
        }
        ev
    }

    fn len(&self) -> usize {
        self.pending.len()
    }

    fn executed(&self) -> u64 {
        self.executed
    }

    fn pending_events(&self) -> Vec<Event> {
        let mut evs: Vec<Event> = self
            .heap
            .iter()
            .filter(|Reverse(e)| self.pending.contains(&e.seq))
            .map(|Reverse(e)| e.clone())
            .collect();
        evs.sort_unstable_by_key(|e| e.key());
        evs
    }

    fn set_executed(&mut self, n: u64) {
        self.executed = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k() -> EventKind {
        EventKind::CpuTick
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = HeapQueue::new();
        q.schedule(30, 50, CompId(0), k());
        q.schedule(10, 50, CompId(1), k());
        q.schedule(20, 50, CompId(2), k());
        let order: Vec<Tick> =
            std::iter::from_fn(|| q.pop().map(|e| e.tick)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn same_tick_fifo_by_seq() {
        let mut q = HeapQueue::new();
        q.schedule(5, 50, CompId(0), k());
        q.schedule(5, 50, CompId(1), k());
        q.schedule(5, 50, CompId(2), k());
        let order: Vec<u32> =
            std::iter::from_fn(|| q.pop().map(|e| e.target.0)).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn priority_beats_seq() {
        let mut q = HeapQueue::new();
        q.schedule(5, 60, CompId(0), k());
        q.schedule(5, 0, CompId(1), k());
        assert_eq!(q.pop().unwrap().target, CompId(1));
    }

    #[test]
    fn deschedule_skips_event() {
        let mut q = HeapQueue::new();
        let h = q.schedule(1, 50, CompId(0), k());
        q.schedule(2, 50, CompId(1), k());
        q.deschedule(h);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().target, CompId(1));
        assert!(q.pop().is_none());
    }

    #[test]
    fn reschedule_moves_event() {
        let mut q = HeapQueue::new();
        let h = q.schedule(10, 50, CompId(0), k());
        q.reschedule(h, 1, 50, CompId(0), k());
        assert_eq!(q.pop().unwrap().tick, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn pop_before_respects_limit() {
        let mut q = HeapQueue::new();
        q.schedule(10, 50, CompId(0), k());
        assert!(q.pop_before(10).is_none());
        assert!(q.pop_before(11).is_some());
    }

    #[test]
    fn insert_resequences() {
        let mut q = HeapQueue::new();
        q.schedule(5, 50, CompId(0), k());
        let ev = Event { tick: 5, prio: 50, seq: 0, target: CompId(9), kind: k() };
        q.insert(ev);
        // inserted event got a later seq -> pops second
        assert_eq!(q.pop().unwrap().target, CompId(0));
        assert_eq!(q.pop().unwrap().target, CompId(9));
    }

    /// Regression: descheduling an already-popped handle must neither make
    /// `len()` wrap nor swallow a later event (the old tombstone-set
    /// implementation kept a permanent `cancelled` entry, so
    /// `heap.len() - cancelled.len()` underflowed).
    #[test]
    fn stale_deschedule_does_not_underflow_len() {
        let mut q = HeapQueue::new();
        let h = q.schedule(1, 50, CompId(0), k());
        assert_eq!(q.pop().unwrap().target, CompId(0));
        q.deschedule(h); // stale: already executed
        assert_eq!(q.len(), 0);
        assert!(q.is_empty());
        q.schedule(2, 50, CompId(1), k());
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().target, CompId(1));
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn double_deschedule_is_noop() {
        let mut q = HeapQueue::new();
        let h = q.schedule(1, 50, CompId(0), k());
        q.schedule(2, 50, CompId(1), k());
        q.deschedule(h);
        q.deschedule(h);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().target, CompId(1));
        assert!(q.pop().is_none());
    }

    #[test]
    fn executed_counts_only_live_pops() {
        let mut q = HeapQueue::new();
        let h = q.schedule(1, 50, CompId(0), k());
        q.schedule(2, 50, CompId(1), k());
        q.deschedule(h);
        while q.pop().is_some() {}
        assert_eq!(q.executed(), 1);
    }
}
