//! Border-quiescent checkpoint/restore (docs/CHECKPOINT.md).
//!
//! A checkpoint freezes a windowed run at a quantum border, *inside* the
//! border protocol's quiescent span: every thread is parked, every
//! cross-domain mailbox has been drained, every staged inbox delivery and
//! crossbar request has been merged, and every component sits between
//! events. At that instant the machine's complete state is exactly
//! \[per-domain clocks + pending event queues\] + \[per-component
//! architectural state\] + \[shared cross-domain cursors\] — no in-flight
//! protocol state exists anywhere else, so the snapshot is total by
//! construction rather than by enumeration.
//!
//! The file format ([`format`]) is versioned and self-describing: the
//! embedded [`SystemSpec`] TOML and pinned run-configuration let
//! `restore` rebuild the exact component arena with zero external inputs,
//! and the spec hash rejects a restore under different result-determining
//! knobs before any state is touched. Canonical ordering everywhere
//! (domains by id, components by [`CompId`], events by `(tick, prio,
//! seq)`, maps by key) makes the bytes a pure function of the simulation
//! content — the producing kernel, thread count and steal setting leave
//! no fingerprint, which is what lets `ckpt diff` attribute any
//! divergence to simulation state rather than host noise.
//!
//! The intended workflow (the "fork a thousand sweeps" recipe of
//! docs/CHECKPOINT.md): run the expensive warm-up once, checkpoint at a
//! border, then fan a sweep out from the snapshot — every point that
//! shares the pinned axes restores in milliseconds and diverges only in
//! its free axes (kernel mode, thread count, stealing, queue
//! implementation), which the determinism suites prove result-invariant.
//!
//! [`SystemSpec`]: crate::spec::SystemSpec
//! [`CompId`]: crate::sim::ids::CompId

pub mod diff;
pub mod format;
pub mod io;
pub mod restore;
pub mod save;

pub use diff::diff_snapshots;
pub use format::{Header, MAGIC, VERSION};
pub use io::{CkptError, StateReader, StateWriter};
pub use restore::{apply, read_snapshot, CompImage, DomainImage, Snapshot};
pub use save::snapshot_machine;

use crate::sim::time::Tick;

/// The snap rule under the fixed quantum policy, in closed form:
/// `--checkpoint-at T` freezes at the first border `k·quantum >= T`
/// (minimum one executed window — a snapshot of a never-run machine is
/// just elaboration). Adaptive policies (`horizon`, `hybrid`) have no
/// closed form — their borders depend on the event horizon — so the
/// kernels implement the same rule operationally: the first *executed*
/// border whose `window_end` reaches the requested tick, checked strictly
/// after the stop verdict (a run that terminates first finishes
/// normally).
pub fn snap_to_border(requested: Tick, quantum: Tick) -> Tick {
    requested.div_ceil(quantum).max(1) * quantum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snap_rule_fixed_policy() {
        let q = 16_000;
        // Tick 0 / anything inside the first window snaps to border 1.
        assert_eq!(snap_to_border(0, q), q);
        assert_eq!(snap_to_border(1, q), q);
        assert_eq!(snap_to_border(q - 1, q), q);
        // An exact border is its own snap target.
        assert_eq!(snap_to_border(q, q), q);
        assert_eq!(snap_to_border(7 * q, q), 7 * q);
        // One past a border snaps forward, never backward.
        assert_eq!(snap_to_border(q + 1, q), 2 * q);
        assert_eq!(snap_to_border(7 * q + 1, q), 8 * q);
    }
}
