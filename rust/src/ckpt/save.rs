//! Checkpoint producer: serialize a machine frozen at a quantum border.
//!
//! The writer runs inside the quiescent span of the border the kernel
//! stopped at (every mailbox drained, every staged inbox/xbar entry
//! merged, every component idle between events) — that is what makes a
//! complete architectural snapshot possible without any cooperation from
//! mid-flight protocol state. Quiescence is asserted, not assumed: a
//! non-empty mailbox or staging area panics rather than producing a
//! silently incomplete file.
//!
//! Canonical ordering contract (docs/CHECKPOINT.md): domains are written
//! in domain-id order, components in global [`CompId`] order, pending
//! events in the queue's `(tick, prio, seq)` order, and every component
//! serializes hash-map contents sorted by key. The resulting bytes are a
//! pure function of the simulation content — identical whichever windowed
//! kernel (threaded or virtual) produced the machine, at any thread
//! count, with or without work stealing.
//!
//! [`CompId`]: crate::sim::ids::CompId

use crate::ckpt::format::{
    pinned_text, spec_hash, write_record, Header, FLAG_O3, R_COMP, R_CONFIG,
    R_DOMAIN, R_END, R_SHARED, R_SPEC, VERSION,
};
use crate::ckpt::io::{CkptError, StateWriter};
use crate::config::RunConfig;
use crate::pdes::Machine;
use crate::sched::Scheduler;
use crate::sim::time::Tick;

/// Serialize `machine`, frozen at quantum border `border`, into a
/// self-describing snapshot. `cfg` must be the configuration the machine
/// was built from — its pinned half (docs/CHECKPOINT.md) is embedded and
/// hashed so a restore under different result-determining knobs is
/// rejected up front.
///
/// Only timing CPU models are checkpointable: atomic/kvm cores share one
/// functional memory image outside the component arena, so their machines
/// have no complete per-component state to snapshot (they also only run
/// on the serial kernel, which has no quantum borders to freeze at).
pub fn snapshot_machine(
    machine: &Machine,
    cfg: &RunConfig,
    border: Tick,
) -> Result<Vec<u8>, CkptError> {
    if !cfg.cpu_model.is_timing() {
        return Err(CkptError::Mismatch {
            what: "cpu model".to_string(),
            expected: "a timing model (minor/o3)".to_string(),
            found: format!("{:?}", cfg.cpu_model).to_ascii_lowercase(),
        });
    }
    let shared = &machine.shared;
    for (i, mbox) in shared.injectors.iter().enumerate() {
        assert!(
            mbox.is_empty(),
            "domain {i} mailbox not drained: checkpoint outside the \
             quiescent span"
        );
    }

    let spec_toml = cfg.spec().to_toml();
    let config_text = pinned_text(cfg);
    // O3 runs flag their larger frozen state (extended shared record,
    // ROB/LSQ-carrying component records) so old readers reject cleanly.
    let o3 = cfg.cpu_model == crate::cpu::CpuModel::O3;
    let header = Header {
        version: VERSION,
        flags: if o3 { FLAG_O3 } else { 0 },
        spec_hash: spec_hash(&spec_toml, &config_text),
        tick: border,
        quantum: shared.quantum,
        n_domains: machine.domains.len() as u32,
        n_components: shared.locate.len() as u32,
    };

    let mut w = StateWriter::new();
    header.write(&mut w);
    write_record(&mut w, R_CONFIG, config_text.as_bytes());
    write_record(&mut w, R_SPEC, spec_toml.as_bytes());

    let mut sw = StateWriter::new();
    shared.save_ckpt(&mut sw, o3);
    write_record(&mut w, R_SHARED, &sw.into_bytes());

    for d in &machine.domains {
        let mut dw = StateWriter::new();
        dw.u32(d.id.0);
        dw.u64(d.now);
        dw.u64(d.eq.executed());
        let events = d.eq.pending_events();
        dw.usize(events.len());
        for ev in &events {
            dw.event(ev);
        }
        write_record(&mut w, R_DOMAIN, &dw.into_bytes());
    }

    for (cid, &(dom, local)) in shared.locate.iter().enumerate() {
        let comp = &machine.domains[dom.index()].comps[local as usize];
        let mut cw = StateWriter::new();
        cw.u32(cid as u32);
        cw.str(comp.name());
        let mut state = StateWriter::new();
        comp.save_state(&mut state);
        cw.bytes(&state.into_bytes());
        write_record(&mut w, R_COMP, &cw.into_bytes());
    }

    write_record(&mut w, R_END, b"");
    Ok(w.into_bytes())
}
