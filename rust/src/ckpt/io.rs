//! Typed little-endian primitives for the checkpoint format.
//!
//! [`StateWriter`] and [`StateReader`] are the only (de)serialization
//! surface the checkpoint subsystem uses — no serde, mirroring the
//! repo's hand-rolled TOML/JSON plumbing. Every multi-byte integer is
//! little-endian; every variable-length field is length-prefixed, so a
//! reader can always report the exact byte offset where a truncated or
//! corrupt file stops making sense ([`CkptError::Truncated`]).
//!
//! Canonical-ordering contract (docs/CHECKPOINT.md): callers must emit
//! hash-map contents sorted by key and heap contents in `(tick, prio,
//! seq)` / `(arrival, seq)` order, so a snapshot's bytes are a pure
//! function of the simulation content — never of host iteration order.
//! That is what makes checkpoint bytes invariant to the producing
//! kernel.

use crate::mem::LineState;
use crate::proto::{Cmd, Packet};
use crate::ruby::msg::{MsgKind, RubyMsg};
use crate::sim::event::{Event, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

/// Everything that can go wrong producing or consuming a snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CkptError {
    /// Host I/O failure (open/read/write).
    Io(String),
    /// The file ends before a field does; `offset` is the absolute byte
    /// position of the incomplete read, `wanted` how many bytes it
    /// needed.
    Truncated { offset: usize, wanted: usize },
    /// A structurally invalid value (bad tag, bad magic, bad UTF-8) at
    /// an absolute byte offset.
    Corrupt { offset: usize, what: String },
    /// A well-formed snapshot that does not match this binary or run
    /// configuration (format version, spec hash, component identity).
    Mismatch { what: String, expected: String, found: String },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint io: {e}"),
            CkptError::Truncated { offset, wanted } => write!(
                f,
                "checkpoint truncated at byte {offset} ({wanted} more byte(s) needed)"
            ),
            CkptError::Corrupt { offset, what } => {
                write!(f, "checkpoint corrupt at byte {offset}: {what}")
            }
            CkptError::Mismatch { what, expected, found } => write!(
                f,
                "checkpoint mismatch: {what} — snapshot has {found}, expected {expected}"
            ),
        }
    }
}

impl std::error::Error for CkptError {}

/// Append-only little-endian byte sink.
#[derive(Default)]
pub struct StateWriter {
    buf: Vec<u8>,
}

impl StateWriter {
    pub fn new() -> Self {
        StateWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
        }
    }

    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn bytes(&mut self, bytes: &[u8]) {
        self.u64(bytes.len() as u64);
        self.raw(bytes);
    }

    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }

    pub fn comp_id(&mut self, c: CompId) {
        self.u32(c.0);
    }

    pub fn opt_comp_id(&mut self, c: Option<CompId>) {
        match c {
            None => self.u8(0),
            Some(c) => {
                self.u8(1);
                self.comp_id(c);
            }
        }
    }

    pub fn line_state(&mut self, s: LineState) {
        self.u8(match s {
            LineState::Invalid => 0,
            LineState::Shared => 1,
            LineState::Exclusive => 2,
            LineState::Modified => 3,
        });
    }

    pub fn packet(&mut self, p: &Packet) {
        self.u64(p.id);
        self.u8(match p.cmd {
            Cmd::ReadReq => 0,
            Cmd::WriteReq => 1,
            Cmd::ReadResp => 2,
            Cmd::WriteResp => 3,
        });
        self.u64(p.addr);
        self.u32(p.size);
        self.u64(p.value);
        self.comp_id(p.requester);
        self.u16(p.core);
        self.u64(p.issued);
        self.u64(p.header_delay);
        self.u64(p.payload_delay);
    }

    pub fn msg(&mut self, m: &RubyMsg) {
        match m.kind {
            MsgKind::SeqReq { is_store } => {
                self.u8(0);
                self.bool(is_store);
            }
            MsgKind::SeqResp => self.u8(1),
            MsgKind::ReadShared => self.u8(2),
            MsgKind::ReadUnique => self.u8(3),
            MsgKind::WriteBackFull => self.u8(4),
            MsgKind::Evict => self.u8(5),
            MsgKind::SnpShared => self.u8(6),
            MsgKind::SnpUnique => self.u8(7),
            MsgKind::CompData { state } => {
                self.u8(8);
                self.line_state(state);
            }
            MsgKind::SnpResp { dirty, had_copy } => {
                self.u8(9);
                self.bool(dirty);
                self.bool(had_copy);
            }
            MsgKind::Comp => self.u8(10),
        }
        self.u64(m.addr);
        self.u64(m.value);
        self.comp_id(m.src);
        self.comp_id(m.dst);
        self.u64(m.txn);
        self.u16(m.core);
        self.u64(m.issued);
    }

    pub fn event(&mut self, ev: &Event) {
        self.u64(ev.tick);
        self.u8(ev.prio);
        self.u64(ev.seq);
        self.comp_id(ev.target);
        match &ev.kind {
            EventKind::CpuTick => self.u8(0),
            EventKind::MemReq { pkt } => {
                self.u8(1);
                self.packet(pkt);
            }
            EventKind::MemResp { pkt } => {
                self.u8(2);
                self.packet(pkt);
            }
            EventKind::RetryReq => self.u8(3),
            EventKind::ConsumerWakeup => self.u8(4),
            EventKind::XbarRelease { layer } => {
                self.u8(5);
                self.usize(*layer);
            }
            EventKind::DramTick => self.u8(6),
            EventKind::WlBarrierRelease => self.u8(7),
            EventKind::Generic { code, arg } => {
                self.u8(8);
                self.u32(*code);
                self.u64(*arg);
            }
        }
    }
}

/// Cursor over a byte slice, tracking the absolute offset for error
/// reporting (`base` shifts reported offsets when reading a nested,
/// length-framed payload out of a larger file).
pub struct StateReader<'a> {
    buf: &'a [u8],
    pos: usize,
    base: usize,
}

impl<'a> StateReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        StateReader { buf, pos: 0, base: 0 }
    }

    /// Reader over a nested payload whose first byte sits at absolute
    /// file offset `base` — truncation errors stay file-absolute.
    pub fn with_base(buf: &'a [u8], base: usize) -> Self {
        StateReader { buf, pos: 0, base }
    }

    /// Absolute offset of the next unread byte.
    pub fn offset(&self) -> usize {
        self.base + self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CkptError> {
        if self.remaining() < n {
            return Err(CkptError::Truncated {
                offset: self.offset(),
                wanted: n - self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, CkptError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, CkptError> {
        let off = self.offset();
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(CkptError::Corrupt {
                offset: off,
                what: format!("bad bool byte {v}"),
            }),
        }
    }

    pub fn u16(&mut self) -> Result<u16, CkptError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, CkptError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, CkptError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize, CkptError> {
        Ok(self.u64()? as usize)
    }

    pub fn opt_u64(&mut self) -> Result<Option<u64>, CkptError> {
        if self.bool()? {
            Ok(Some(self.u64()?))
        } else {
            Ok(None)
        }
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], CkptError> {
        let off = self.offset();
        let n = self.u64()? as usize;
        if n > self.remaining() {
            return Err(CkptError::Truncated {
                offset: off,
                wanted: n - self.remaining(),
            });
        }
        self.take(n)
    }

    pub fn str(&mut self) -> Result<&'a str, CkptError> {
        let off = self.offset();
        std::str::from_utf8(self.bytes()?).map_err(|e| CkptError::Corrupt {
            offset: off,
            what: format!("bad utf-8 string: {e}"),
        })
    }

    pub fn comp_id(&mut self) -> Result<CompId, CkptError> {
        Ok(CompId(self.u32()?))
    }

    pub fn opt_comp_id(&mut self) -> Result<Option<CompId>, CkptError> {
        if self.bool()? {
            Ok(Some(self.comp_id()?))
        } else {
            Ok(None)
        }
    }

    pub fn line_state(&mut self) -> Result<LineState, CkptError> {
        let off = self.offset();
        Ok(match self.u8()? {
            0 => LineState::Invalid,
            1 => LineState::Shared,
            2 => LineState::Exclusive,
            3 => LineState::Modified,
            v => {
                return Err(CkptError::Corrupt {
                    offset: off,
                    what: format!("bad line-state tag {v}"),
                })
            }
        })
    }

    pub fn packet(&mut self) -> Result<Packet, CkptError> {
        let id = self.u64()?;
        let off = self.offset();
        let cmd = match self.u8()? {
            0 => Cmd::ReadReq,
            1 => Cmd::WriteReq,
            2 => Cmd::ReadResp,
            3 => Cmd::WriteResp,
            v => {
                return Err(CkptError::Corrupt {
                    offset: off,
                    what: format!("bad packet command tag {v}"),
                })
            }
        };
        Ok(Packet {
            id,
            cmd,
            addr: self.u64()?,
            size: self.u32()?,
            value: self.u64()?,
            requester: self.comp_id()?,
            core: self.u16()?,
            issued: self.u64()?,
            header_delay: self.u64()?,
            payload_delay: self.u64()?,
        })
    }

    pub fn msg(&mut self) -> Result<RubyMsg, CkptError> {
        let off = self.offset();
        let kind = match self.u8()? {
            0 => MsgKind::SeqReq { is_store: self.bool()? },
            1 => MsgKind::SeqResp,
            2 => MsgKind::ReadShared,
            3 => MsgKind::ReadUnique,
            4 => MsgKind::WriteBackFull,
            5 => MsgKind::Evict,
            6 => MsgKind::SnpShared,
            7 => MsgKind::SnpUnique,
            8 => MsgKind::CompData { state: self.line_state()? },
            9 => MsgKind::SnpResp {
                dirty: self.bool()?,
                had_copy: self.bool()?,
            },
            10 => MsgKind::Comp,
            v => {
                return Err(CkptError::Corrupt {
                    offset: off,
                    what: format!("bad message kind tag {v}"),
                })
            }
        };
        Ok(RubyMsg {
            kind,
            addr: self.u64()?,
            value: self.u64()?,
            src: self.comp_id()?,
            dst: self.comp_id()?,
            txn: self.u64()?,
            core: self.u16()?,
            issued: self.u64()?,
        })
    }

    pub fn event(&mut self) -> Result<Event, CkptError> {
        let tick: Tick = self.u64()?;
        let prio = self.u8()?;
        let seq = self.u64()?;
        let target = self.comp_id()?;
        let off = self.offset();
        let kind = match self.u8()? {
            0 => EventKind::CpuTick,
            1 => EventKind::MemReq { pkt: self.packet()? },
            2 => EventKind::MemResp { pkt: self.packet()? },
            3 => EventKind::RetryReq,
            4 => EventKind::ConsumerWakeup,
            5 => EventKind::XbarRelease { layer: self.usize()? },
            6 => EventKind::DramTick,
            7 => EventKind::WlBarrierRelease,
            8 => EventKind::Generic { code: self.u32()?, arg: self.u64()? },
            v => {
                return Err(CkptError::Corrupt {
                    offset: off,
                    what: format!("bad event kind tag {v}"),
                })
            }
        };
        Ok(Event { tick, prio, seq, target, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = StateWriter::new();
        w.u8(7);
        w.bool(true);
        w.u16(0xBEEF);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.opt_u64(None);
        w.opt_u64(Some(42));
        w.str("hnf");
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.opt_u64().unwrap(), None);
        assert_eq!(r.opt_u64().unwrap(), Some(42));
        assert_eq!(r.str().unwrap(), "hnf");
        assert!(r.is_done());
    }

    #[test]
    fn event_roundtrip_every_kind() {
        let pkt = Packet::request(9, Cmd::WriteReq, 0x40, 64, 5, CompId(3), 1, 77);
        let kinds = vec![
            EventKind::CpuTick,
            EventKind::MemReq { pkt },
            EventKind::MemResp { pkt: pkt.make_response(11) },
            EventKind::RetryReq,
            EventKind::ConsumerWakeup,
            EventKind::XbarRelease { layer: 2 },
            EventKind::DramTick,
            EventKind::WlBarrierRelease,
            EventKind::Generic { code: 5, arg: 99 },
        ];
        let mut w = StateWriter::new();
        for (i, k) in kinds.iter().enumerate() {
            w.event(&Event {
                tick: 1000 + i as u64,
                prio: 50,
                seq: i as u64,
                target: CompId(i as u32),
                kind: k.clone(),
            });
        }
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        for (i, _) in kinds.iter().enumerate() {
            let ev = r.event().unwrap();
            assert_eq!(ev.tick, 1000 + i as u64);
            assert_eq!(ev.target, CompId(i as u32));
        }
        assert!(r.is_done());
    }

    #[test]
    fn msg_roundtrip_every_kind() {
        let kinds = vec![
            MsgKind::SeqReq { is_store: true },
            MsgKind::SeqResp,
            MsgKind::ReadShared,
            MsgKind::ReadUnique,
            MsgKind::WriteBackFull,
            MsgKind::Evict,
            MsgKind::SnpShared,
            MsgKind::SnpUnique,
            MsgKind::CompData { state: LineState::Modified },
            MsgKind::SnpResp { dirty: true, had_copy: false },
            MsgKind::Comp,
        ];
        let mut w = StateWriter::new();
        for k in &kinds {
            w.msg(&RubyMsg {
                kind: *k,
                addr: 0x80,
                value: 3,
                src: CompId(1),
                dst: CompId(2),
                txn: 8,
                core: 0,
                issued: 12,
            });
        }
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        for k in &kinds {
            let m = r.msg().unwrap();
            assert_eq!(m.kind, *k);
            assert_eq!(m.addr, 0x80);
        }
        assert!(r.is_done());
    }

    #[test]
    fn truncation_reports_absolute_offset() {
        let mut w = StateWriter::new();
        w.u64(1);
        w.u64(2);
        let bytes = w.into_bytes();
        let mut r = StateReader::with_base(&bytes[..12], 100);
        r.u64().unwrap();
        match r.u64() {
            Err(CkptError::Truncated { offset, wanted }) => {
                assert_eq!(offset, 108);
                assert_eq!(wanted, 4);
            }
            other => panic!("expected truncation, got {other:?}"),
        }
    }

    #[test]
    fn bad_tag_is_corrupt_not_panic() {
        let mut r = StateReader::new(&[200]);
        assert!(matches!(r.line_state(), Err(CkptError::Corrupt { .. })));
    }
}
