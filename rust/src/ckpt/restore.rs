//! Checkpoint consumer: parse a snapshot and rebuild a machine from it.
//!
//! Restore is two independent halves. [`read_snapshot`] is pure parsing —
//! it validates the header (magic, version, flags), walks the record
//! sequence in its mandatory order, cross-checks the embedded spec hash
//! and counts, and hands back a structured [`Snapshot`] without touching
//! any simulator state. [`apply`] then overwrites a *freshly elaborated*
//! machine — built from the snapshot's own embedded [`SystemSpec`] and
//! pinned configuration, so the component arena is guaranteed congruent —
//! with the recorded clocks, event queues and per-component state. The
//! kernels resume it through `KernelCtl::resume_border` and continue
//! bit-identically to the uninterrupted run (docs/CHECKPOINT.md).
//!
//! [`SystemSpec`]: crate::spec::SystemSpec

use crate::ckpt::format::{
    config_from_snapshot, read_record, spec_hash, tag_name, Header, FLAG_O3,
    R_COMP, R_CONFIG, R_DOMAIN, R_END, R_SHARED, R_SPEC,
};
use crate::ckpt::io::{CkptError, StateReader};
use crate::config::RunConfig;
use crate::pdes::Machine;
use crate::sched::Scheduler;
use crate::sim::event::Event;
use crate::sim::time::Tick;
use crate::spec::SystemSpec;

/// One domain's recorded execution state.
#[derive(Clone, Debug)]
pub struct DomainImage {
    pub id: u32,
    /// Local clock: tick of the last executed event.
    pub now: Tick,
    /// The queue's executed-pop counter.
    pub executed: u64,
    /// Pending events in canonical `(tick, prio, seq)` order.
    pub events: Vec<Event>,
}

/// One component's recorded architectural state.
#[derive(Clone, Debug)]
pub struct CompImage {
    pub id: u32,
    /// Elaboration name; restore refuses a component whose name differs.
    pub name: String,
    /// Opaque [`Component::save_state`] bytes.
    ///
    /// [`Component::save_state`]: crate::sim::component::Component::save_state
    pub state: Vec<u8>,
    /// Absolute file offset of `state[0]` (error reporting stays
    /// file-absolute through the nested framing).
    pub state_off: usize,
}

/// A fully parsed snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub header: Header,
    /// The pinned run-configuration text (docs/CHECKPOINT.md table).
    pub config_text: String,
    /// The platform as [`SystemSpec`] TOML.
    pub spec_toml: String,
    /// Opaque shared-state record payload.
    pub shared: Vec<u8>,
    /// Absolute file offset of `shared[0]`.
    pub shared_off: usize,
    pub domains: Vec<DomainImage>,
    pub comps: Vec<CompImage>,
}

impl Snapshot {
    /// Parse the embedded platform spec.
    pub fn spec(&self) -> Result<SystemSpec, CkptError> {
        SystemSpec::from_toml(&self.spec_toml).map_err(|e| {
            CkptError::Corrupt {
                offset: 0,
                what: format!("embedded platform spec: {e}"),
            }
        })
    }

    /// Rebuild the producing run's configuration: platform from the
    /// embedded spec, pinned knobs from the config record, free axes at
    /// their defaults (callers override them before elaboration).
    pub fn config(&self) -> Result<RunConfig, CkptError> {
        config_from_snapshot(&self.spec()?, &self.config_text)
    }
}

fn expect_tag(
    found: u8,
    expected: u8,
    offset: usize,
) -> Result<(), CkptError> {
    if found == expected {
        Ok(())
    } else {
        Err(CkptError::Corrupt {
            offset,
            what: format!(
                "expected a {} record, found {}",
                tag_name(expected),
                tag_name(found)
            ),
        })
    }
}

fn record_utf8(payload: &[u8], offset: usize) -> Result<String, CkptError> {
    std::str::from_utf8(payload).map(str::to_string).map_err(|e| {
        CkptError::Corrupt {
            offset,
            what: format!("bad utf-8 record: {e}"),
        }
    })
}

/// Ensure a nested record reader consumed its whole payload.
fn expect_drained(
    r: &StateReader,
    what: &str,
) -> Result<(), CkptError> {
    if r.is_done() {
        Ok(())
    } else {
        Err(CkptError::Corrupt {
            offset: r.offset(),
            what: format!("{what}: {} trailing byte(s)", r.remaining()),
        })
    }
}

/// Parse and validate a whole snapshot file. Rejects (with the exact byte
/// offset where possible): truncation anywhere, out-of-order or unknown
/// records, a spec-hash that does not match the embedded spec + config
/// (i.e. a corrupted identity), domain/component counts that contradict
/// the header, and trailing garbage after the end record.
pub fn read_snapshot(bytes: &[u8]) -> Result<Snapshot, CkptError> {
    let mut r = StateReader::new(bytes);
    let header = Header::read(&mut r)?;

    let rec_off = r.offset();
    let (tag, payload, off) = read_record(&mut r)?;
    expect_tag(tag, R_CONFIG, rec_off)?;
    let config_text = record_utf8(payload, off)?;

    let rec_off = r.offset();
    let (tag, payload, off) = read_record(&mut r)?;
    expect_tag(tag, R_SPEC, rec_off)?;
    let spec_toml = record_utf8(payload, off)?;

    let computed = spec_hash(&spec_toml, &config_text);
    if computed != header.spec_hash {
        return Err(CkptError::Mismatch {
            what: "spec hash".to_string(),
            expected: format!("{computed:#018x} (over the embedded spec + config)"),
            found: format!("{:#018x}", header.spec_hash),
        });
    }

    let rec_off = r.offset();
    let (tag, payload, shared_off) = read_record(&mut r)?;
    expect_tag(tag, R_SHARED, rec_off)?;
    let shared = payload.to_vec();

    let mut domains = Vec::with_capacity(header.n_domains as usize);
    for i in 0..header.n_domains {
        let rec_off = r.offset();
        let (tag, payload, off) = read_record(&mut r)?;
        expect_tag(tag, R_DOMAIN, rec_off)?;
        let mut dr = StateReader::with_base(payload, off);
        let id = dr.u32()?;
        if id != i {
            return Err(CkptError::Corrupt {
                offset: off,
                what: format!("domain record {i} carries id {id}"),
            });
        }
        let now = dr.u64()?;
        let executed = dr.u64()?;
        let n_events = dr.usize()?;
        let mut events = Vec::with_capacity(n_events.min(payload.len()));
        for _ in 0..n_events {
            events.push(dr.event()?);
        }
        expect_drained(&dr, &format!("domain {id} record"))?;
        domains.push(DomainImage { id, now, executed, events });
    }

    let mut comps = Vec::with_capacity(header.n_components as usize);
    for i in 0..header.n_components {
        let rec_off = r.offset();
        let (tag, payload, off) = read_record(&mut r)?;
        expect_tag(tag, R_COMP, rec_off)?;
        let mut cr = StateReader::with_base(payload, off);
        let id = cr.u32()?;
        if id != i {
            return Err(CkptError::Corrupt {
                offset: off,
                what: format!("component record {i} carries id {id}"),
            });
        }
        let name = cr.str()?.to_string();
        let state_off = cr.offset() + 8;
        let state = cr.bytes()?.to_vec();
        expect_drained(&cr, &format!("component {name} record"))?;
        comps.push(CompImage { id, name, state, state_off });
    }

    let rec_off = r.offset();
    let (tag, payload, _) = read_record(&mut r)?;
    expect_tag(tag, R_END, rec_off)?;
    if !payload.is_empty() {
        return Err(CkptError::Corrupt {
            offset: rec_off,
            what: "end record with payload".to_string(),
        });
    }
    if !r.is_done() {
        return Err(CkptError::Corrupt {
            offset: r.offset(),
            what: format!("{} byte(s) after the end record", r.remaining()),
        });
    }

    Ok(Snapshot {
        header,
        config_text,
        spec_toml,
        shared,
        shared_off,
        domains,
        comps,
    })
}

/// Overwrite a freshly elaborated, never-initialised machine with the
/// snapshot's state: shared cross-domain state, per-domain clocks and
/// event queues (events re-sequence on insertion — canonical order in
/// means the relative `(tick, prio)` tie-break order is preserved and
/// post-restore events sort after every restored one, exactly as in the
/// uninterrupted run), then every component in [`CompId`] order.
///
/// The machine must come from the snapshot's own spec + pinned config
/// (`Snapshot::config`), so the structural checks here (domain count,
/// component count/names) can only fire on a corrupted or mislabelled
/// file — they are cheap insurance, not a compatibility layer.
///
/// [`CompId`]: crate::sim::ids::CompId
pub fn apply(snap: &Snapshot, machine: &mut Machine) -> Result<(), CkptError> {
    if machine.domains.len() != snap.header.n_domains as usize {
        return Err(CkptError::Mismatch {
            what: "domain count".to_string(),
            expected: machine.domains.len().to_string(),
            found: snap.header.n_domains.to_string(),
        });
    }
    let shared = machine.shared.clone();
    if shared.locate.len() != snap.header.n_components as usize {
        return Err(CkptError::Mismatch {
            what: "component count".to_string(),
            expected: shared.locate.len().to_string(),
            found: snap.header.n_components.to_string(),
        });
    }

    let mut sr = StateReader::with_base(&snap.shared, snap.shared_off);
    shared.restore_ckpt(&mut sr, snap.header.flags & FLAG_O3 != 0)?;
    expect_drained(&sr, "shared-state record")?;

    for img in &snap.domains {
        let d = &mut machine.domains[img.id as usize];
        assert!(
            d.eq.is_empty(),
            "restore target machine already initialised (domain {} queue \
             not empty)",
            img.id
        );
        d.now = img.now;
        for ev in &img.events {
            d.eq.insert(ev.clone());
        }
        d.eq.set_executed(img.executed);
    }

    for c in &snap.comps {
        let (dom, local) = shared.locate[c.id as usize];
        let comp = &mut machine.domains[dom.index()].comps[local as usize];
        if comp.name() != c.name {
            return Err(CkptError::Mismatch {
                what: format!("component {} identity", c.id),
                expected: comp.name().to_string(),
                found: c.name.clone(),
            });
        }
        let mut r = StateReader::with_base(&c.state, c.state_off);
        comp.restore_state(&mut r)?;
        expect_drained(&r, &format!("component {} state", c.name))?;
    }
    Ok(())
}
