//! The on-disk snapshot format: header, record framing, and the pinned
//! run-configuration text.
//!
//! A checkpoint file is:
//!
//! | section | contents |
//! |---|---|
//! | header | magic `PGEM5CKP`, format version, flags, spec hash, border tick, quantum, domain/component counts |
//! | `R_CONFIG` | the pinned run-configuration (`key = value` text) |
//! | `R_SPEC` | the full [`SystemSpec`] TOML the machine rebuilds from |
//! | `R_SHARED` | shared cross-domain state (injector cursors, workload barrier, deterministic PDES counters) |
//! | `R_DOMAIN` × n | per-domain clock, executed count and pending events in canonical order |
//! | `R_COMP` × n | per-component architectural state via [`Component::save_state`] |
//! | `R_END` | terminator (guards against silent truncation) |
//!
//! Every record is `tag: u8, len: u64, payload` — a reader can skip or
//! diff records without understanding their payloads, and a truncated file
//! fails with the exact byte offset. The `flags` header word carries
//! forward-compatible feature bits: [`FLAG_O3`] marks a snapshot whose
//! shared record and per-core component records include the O3 pipeline's
//! larger in-flight state (ROB/LSQ entries, outstanding sequencer
//! requests, the five O3 PDES counters). A reader that doesn't support a
//! set bit rejects the file cleanly at the flags word's byte offset
//! instead of misparsing it; flags = 0 snapshots (the original "V1"
//! layout) stay byte-identical and loadable forever.
//!
//! [`Component::save_state`]: crate::sim::component::Component::save_state
//! [`SystemSpec`]: crate::spec::SystemSpec

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::config::{Mode, RunConfig};
use crate::cpu::CpuModel;
use crate::sched::{InboxOrder, QuantumPolicy, XbarArb};
use crate::sim::time::Tick;
use crate::spec::SystemSpec;

/// File magic: identifies a parti-gem5 checkpoint.
pub const MAGIC: &[u8; 8] = b"PGEM5CKP";
/// Current format version; bumped on any layout change.
pub const VERSION: u32 = 1;

/// Header flag bit: the snapshot carries O3-pipeline state (an extended
/// shared record and larger per-core component records). Set iff the
/// producing run used `--cpu o3`.
pub const FLAG_O3: u32 = 1;
/// Every flag bit this build understands; unknown bits are rejected.
pub const SUPPORTED_FLAGS: u32 = FLAG_O3;

/// Record tags, in file order.
pub const R_CONFIG: u8 = 1;
pub const R_SPEC: u8 = 2;
pub const R_SHARED: u8 = 3;
pub const R_DOMAIN: u8 = 4;
pub const R_COMP: u8 = 5;
pub const R_END: u8 = 6;

pub fn tag_name(tag: u8) -> &'static str {
    match tag {
        R_CONFIG => "config",
        R_SPEC => "spec",
        R_SHARED => "shared",
        R_DOMAIN => "domain",
        R_COMP => "component",
        R_END => "end",
        _ => "unknown",
    }
}

/// The fixed-size snapshot header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Header {
    pub version: u32,
    /// Feature bits ([`FLAG_O3`]); unknown bits are rejected on read.
    pub flags: u32,
    /// FNV-1a over the spec TOML + pinned config text: a restore under a
    /// different platform or result-determining run knob is rejected
    /// before any state is touched.
    pub spec_hash: u64,
    /// The quantum border the snapshot was taken at.
    pub tick: Tick,
    /// The producer's quantum (result-determining; pinned).
    pub quantum: Tick,
    pub n_domains: u32,
    pub n_components: u32,
}

impl Header {
    pub fn write(&self, w: &mut StateWriter) {
        w.raw(MAGIC);
        w.u32(self.version);
        w.u32(self.flags);
        w.u64(self.spec_hash);
        w.u64(self.tick);
        w.u64(self.quantum);
        w.u32(self.n_domains);
        w.u32(self.n_components);
    }

    pub fn read(r: &mut StateReader) -> Result<Self, CkptError> {
        Self::read_with_supported(r, SUPPORTED_FLAGS)
    }

    /// Parse a header accepting only the flag bits in `supported`. The
    /// narrow mask exists for tests modelling an older reader; production
    /// code goes through [`Header::read`].
    pub fn read_with_supported(
        r: &mut StateReader,
        supported: u32,
    ) -> Result<Self, CkptError> {
        let off = r.offset();
        let mut magic = [0u8; 8];
        for b in &mut magic {
            *b = r.u8()?;
        }
        if &magic != MAGIC {
            return Err(CkptError::Corrupt {
                offset: off,
                what: "not a parti-gem5 checkpoint (bad magic)".to_string(),
            });
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(CkptError::Mismatch {
                what: "format version".to_string(),
                expected: VERSION.to_string(),
                found: version.to_string(),
            });
        }
        let flags_off = r.offset();
        let flags = r.u32()?;
        if flags & !supported != 0 {
            return Err(CkptError::Corrupt {
                offset: flags_off,
                what: format!(
                    "unsupported feature flags {:#x} (this reader \
                     understands {supported:#x}; the snapshot needs a \
                     build with O3-pipeline checkpoint support — \
                     docs/CHECKPOINT.md §3)",
                    flags & !supported
                ),
            });
        }
        Ok(Header {
            version,
            flags,
            spec_hash: r.u64()?,
            tick: r.u64()?,
            quantum: r.u64()?,
            n_domains: r.u32()?,
            n_components: r.u32()?,
        })
    }
}

/// 64-bit FNV-1a.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The snapshot's identity hash: spec TOML and pinned config text,
/// NUL-separated so neither can masquerade as the other.
pub fn spec_hash(spec_toml: &str, config_text: &str) -> u64 {
    let mut bytes = Vec::with_capacity(spec_toml.len() + config_text.len() + 1);
    bytes.extend_from_slice(spec_toml.as_bytes());
    bytes.push(0);
    bytes.extend_from_slice(config_text.as_bytes());
    fnv1a(&bytes)
}

fn cpu_keyword(m: CpuModel) -> &'static str {
    match m {
        CpuModel::Kvm => "kvm",
        CpuModel::Atomic => "atomic",
        CpuModel::Minor => "minor",
        CpuModel::O3 => "o3",
    }
}

fn policy_keyword(p: QuantumPolicy) -> String {
    match p {
        QuantumPolicy::Fixed => "fixed".to_string(),
        QuantumPolicy::Horizon => "horizon".to_string(),
        QuantumPolicy::Hybrid { max_leap } => format!("hybrid:{max_leap}"),
    }
}

fn parse_policy(s: &str) -> Option<QuantumPolicy> {
    if let Some(n) = s.strip_prefix("hybrid:") {
        return Some(QuantumPolicy::Hybrid { max_leap: n.parse().ok()? });
    }
    QuantumPolicy::parse(s)
}

/// Serialise the result-determining half of a [`RunConfig`] — the knobs a
/// restore MUST reproduce for bit-identity. Everything absent from this
/// text (kernel mode, thread count, stealing, queue implementation,
/// calendar geometry, profiling) is proven result-invariant by the
/// determinism suites and stays freely overridable at restore
/// (docs/CHECKPOINT.md has the table).
pub fn pinned_text(cfg: &RunConfig) -> String {
    let mut s = String::new();
    let mut kv = |k: &str, v: String| {
        s.push_str(k);
        s.push_str(" = ");
        s.push_str(&v);
        s.push('\n');
    };
    kv("cpu", cpu_keyword(cfg.cpu_model).to_string());
    kv("app", cfg.app.clone());
    kv("traffic", cfg.traffic.clone().unwrap_or_else(|| "-".to_string()));
    kv("ops_per_core", cfg.ops_per_core.to_string());
    kv("seed", cfg.seed.to_string());
    kv("quantum", cfg.quantum.to_string());
    kv("quantum_policy", policy_keyword(cfg.quantum_policy));
    kv("inbox_order", match cfg.inbox_order {
        InboxOrder::Host => "host".to_string(),
        InboxOrder::Border => "border".to_string(),
    });
    kv("xbar_arb", match cfg.xbar_arb {
        XbarArb::Host => "host".to_string(),
        XbarArb::Border => "border".to_string(),
    });
    s
}

/// Rebuild a [`RunConfig`] from an embedded spec TOML + pinned config
/// text. The platform half comes from the spec; the pinned knobs from the
/// text; everything else keeps defaults (the restore entry points then
/// apply the caller's free-axis overrides). `mode` defaults to
/// [`Mode::Virtual`] — a checkpoint can only resume on a windowed kernel.
pub fn config_from_snapshot(
    spec: &SystemSpec,
    config_text: &str,
) -> Result<RunConfig, CkptError> {
    let mut cfg = RunConfig::for_spec(spec);
    cfg.mode = Mode::Virtual;
    let bad = |k: &str, v: &str| CkptError::Mismatch {
        what: format!("pinned config key `{k}`"),
        expected: "a parseable value".to_string(),
        found: v.to_string(),
    };
    for line in config_text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| CkptError::Corrupt {
            offset: 0,
            what: format!("pinned config line without `=`: {line}"),
        })?;
        let (k, v) = (k.trim(), v.trim());
        match k {
            "cpu" => {
                cfg.cpu_model = CpuModel::parse(v).ok_or_else(|| bad(k, v))?
            }
            "app" => cfg.app = v.to_string(),
            "traffic" => {
                cfg.traffic =
                    if v == "-" { None } else { Some(v.to_string()) }
            }
            "ops_per_core" => {
                cfg.ops_per_core = v.parse().map_err(|_| bad(k, v))?
            }
            "seed" => cfg.seed = v.parse().map_err(|_| bad(k, v))?,
            "quantum" => cfg.quantum = v.parse().map_err(|_| bad(k, v))?,
            "quantum_policy" => {
                cfg.quantum_policy =
                    parse_policy(v).ok_or_else(|| bad(k, v))?
            }
            "inbox_order" => {
                cfg.inbox_order =
                    InboxOrder::parse(v).ok_or_else(|| bad(k, v))?
            }
            "xbar_arb" => {
                cfg.xbar_arb = XbarArb::parse(v).ok_or_else(|| bad(k, v))?
            }
            _ => {
                return Err(CkptError::Mismatch {
                    what: "pinned config key".to_string(),
                    expected: "a known key".to_string(),
                    found: k.to_string(),
                })
            }
        }
    }
    Ok(cfg)
}

/// Append one framed record.
pub fn write_record(w: &mut StateWriter, tag: u8, payload: &[u8]) {
    w.u8(tag);
    w.bytes(payload);
}

/// Read one framed record, returning `(tag, payload, payload_offset)`.
pub fn read_record<'a>(
    r: &mut StateReader<'a>,
) -> Result<(u8, &'a [u8], usize), CkptError> {
    let off = r.offset();
    let tag = r.u8()?;
    if !(R_CONFIG..=R_END).contains(&tag) {
        return Err(CkptError::Corrupt {
            offset: off,
            what: format!("bad record tag {tag}"),
        });
    }
    let payload_off = r.offset() + 8;
    let payload = r.bytes()?;
    Ok((tag, payload, payload_off))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header {
            version: VERSION,
            flags: 0,
            spec_hash: 0x1234_5678_9abc_def0,
            tick: 32_000,
            quantum: 16_000,
            n_domains: 3,
            n_components: 20,
        };
        let mut w = StateWriter::new();
        h.write(&mut w);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        assert_eq!(Header::read(&mut r).unwrap(), h);
        assert!(r.is_done());
    }

    #[test]
    fn header_rejects_version_bump() {
        let h = Header {
            version: VERSION,
            flags: 0,
            spec_hash: 1,
            tick: 1,
            quantum: 1,
            n_domains: 1,
            n_components: 1,
        };
        let mut w = StateWriter::new();
        h.write(&mut w);
        let mut bytes = w.into_bytes();
        bytes[8] = VERSION as u8 + 1; // little-endian low byte of version
        let mut r = StateReader::new(&bytes);
        match Header::read(&mut r) {
            Err(CkptError::Mismatch { what, .. }) => {
                assert!(what.contains("version"))
            }
            other => panic!("expected version mismatch, got {other:?}"),
        }
    }

    #[test]
    fn o3_flag_roundtrips_and_old_reader_rejects_it() {
        let h = Header {
            version: VERSION,
            flags: FLAG_O3,
            spec_hash: 2,
            tick: 16_000,
            quantum: 8_000,
            n_domains: 2,
            n_components: 9,
        };
        let mut w = StateWriter::new();
        h.write(&mut w);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        assert_eq!(Header::read(&mut r).unwrap(), h);
        // A reader without O3 support rejects at the flags word (byte 12
        // = 8 magic + 4 version), with a hint naming the missing feature.
        let mut r = StateReader::new(&bytes);
        match Header::read_with_supported(&mut r, 0) {
            Err(CkptError::Corrupt { offset, what }) => {
                assert_eq!(offset, 12, "flags word offset");
                assert!(what.contains("O3"), "{what}");
            }
            other => panic!("expected flags rejection, got {other:?}"),
        }
    }

    #[test]
    fn unknown_flag_bits_rejected_by_current_reader() {
        let h = Header {
            version: VERSION,
            flags: 0x8000_0000,
            spec_hash: 2,
            tick: 1,
            quantum: 1,
            n_domains: 1,
            n_components: 1,
        };
        let mut w = StateWriter::new();
        h.write(&mut w);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        assert!(matches!(
            Header::read(&mut r),
            Err(CkptError::Corrupt { offset: 12, .. })
        ));
    }

    #[test]
    fn header_rejects_bad_magic() {
        let bytes = b"NOTACKPT_____________________".to_vec();
        let mut r = StateReader::new(&bytes);
        assert!(matches!(
            Header::read(&mut r),
            Err(CkptError::Corrupt { offset: 0, .. })
        ));
    }

    #[test]
    fn pinned_text_roundtrips_through_config() {
        let cfg = RunConfig {
            app: "stream".to_string(),
            traffic: Some("hotspot".to_string()),
            ops_per_core: 128,
            seed: 7,
            quantum: 8_000,
            quantum_policy: QuantumPolicy::Hybrid { max_leap: 9 },
            ..RunConfig::default()
        };
        let text = pinned_text(&cfg);
        let spec = cfg.spec();
        let back = config_from_snapshot(&spec, &text).unwrap();
        assert_eq!(pinned_text(&back), text);
        assert_eq!(back.quantum, 8_000);
        assert_eq!(back.quantum_policy, QuantumPolicy::Hybrid { max_leap: 9 });
        assert_eq!(back.traffic.as_deref(), Some("hotspot"));
        assert_eq!(back.mode, Mode::Virtual);
    }

    #[test]
    fn spec_hash_separates_halves() {
        // The NUL separator stops `spec+config` content from sliding
        // between the two halves unnoticed.
        assert_ne!(spec_hash("ab", "c"), spec_hash("a", "bc"));
        assert_ne!(spec_hash("x", "y"), spec_hash("y", "x"));
    }

    #[test]
    fn record_frame_roundtrip() {
        let mut w = StateWriter::new();
        write_record(&mut w, R_CONFIG, b"hello");
        write_record(&mut w, R_END, b"");
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        let (tag, payload, off) = read_record(&mut r).unwrap();
        assert_eq!((tag, payload, off), (R_CONFIG, &b"hello"[..], 9));
        let (tag, payload, _) = read_record(&mut r).unwrap();
        assert_eq!((tag, payload), (R_END, &b""[..]));
        assert!(r.is_done());
    }
}
