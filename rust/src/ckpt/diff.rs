//! Structural snapshot comparison — the divergence-debugging tool of
//! docs/DETERMINISM.md and docs/CHECKPOINT.md.
//!
//! `ckpt diff a b` answers "*where* do two runs first disagree", not just
//! "do they". Because the format is framed and canonically ordered, the
//! comparison can walk the sections in file order (identity → shared
//! state → domains → components) and name the first diverging unit — the
//! component name plus the byte offset inside its state record — which
//! turns a failed bit-identity gate into a ~one-component bisection
//! instead of a two-gigabyte hexdump session.

use crate::ckpt::io::CkptError;
use crate::ckpt::restore::{read_snapshot, Snapshot};

/// First index where two byte strings disagree (or the shorter length).
fn first_byte_diff(a: &[u8], b: &[u8]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

/// First line where two texts disagree, 1-based.
fn first_line_diff(a: &str, b: &str) -> (usize, String, String) {
    for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
        if la != lb {
            return (i + 1, la.to_string(), lb.to_string());
        }
    }
    let n = a.lines().count().min(b.lines().count());
    (
        n + 1,
        a.lines().nth(n).unwrap_or("<end>").to_string(),
        b.lines().nth(n).unwrap_or("<end>").to_string(),
    )
}

fn diff_parsed(a: &Snapshot, b: &Snapshot) -> Option<String> {
    let ha = &a.header;
    let hb = &b.header;
    for (what, va, vb) in [
        ("spec hash", ha.spec_hash, hb.spec_hash),
        ("border tick", ha.tick, hb.tick),
        ("quantum", ha.quantum, hb.quantum),
        ("domain count", ha.n_domains as u64, hb.n_domains as u64),
        ("component count", ha.n_components as u64, hb.n_components as u64),
    ] {
        if va != vb {
            return Some(format!("header: {what} differs ({va} vs {vb})"));
        }
    }
    if a.config_text != b.config_text {
        let (line, la, lb) = first_line_diff(&a.config_text, &b.config_text);
        return Some(format!(
            "pinned config: line {line} differs (`{la}` vs `{lb}`)"
        ));
    }
    if a.spec_toml != b.spec_toml {
        let (line, la, lb) = first_line_diff(&a.spec_toml, &b.spec_toml);
        return Some(format!(
            "platform spec: line {line} differs (`{la}` vs `{lb}`)"
        ));
    }
    if a.shared != b.shared {
        return Some(format!(
            "shared state: first differing byte at record offset {}",
            first_byte_diff(&a.shared, &b.shared)
        ));
    }
    for (da, db) in a.domains.iter().zip(b.domains.iter()) {
        if da.now != db.now {
            return Some(format!(
                "domain {}: clock differs ({} vs {})",
                da.id, da.now, db.now
            ));
        }
        if da.executed != db.executed {
            return Some(format!(
                "domain {}: executed count differs ({} vs {})",
                da.id, da.executed, db.executed
            ));
        }
        if da.events.len() != db.events.len() {
            return Some(format!(
                "domain {}: pending event count differs ({} vs {})",
                da.id,
                da.events.len(),
                db.events.len()
            ));
        }
        for (i, (ea, eb)) in da.events.iter().zip(db.events.iter()).enumerate()
        {
            let (sa, sb) = (format!("{ea:?}"), format!("{eb:?}"));
            if sa != sb {
                return Some(format!(
                    "domain {}: pending event {i} differs\n  a: {sa}\n  b: {sb}",
                    da.id
                ));
            }
        }
    }
    for (ca, cb) in a.comps.iter().zip(b.comps.iter()) {
        if ca.name != cb.name {
            return Some(format!(
                "component {}: name differs ({} vs {})",
                ca.id, ca.name, cb.name
            ));
        }
        if ca.state != cb.state {
            let off = first_byte_diff(&ca.state, &cb.state);
            return Some(format!(
                "component {} ({}): state differs at byte {} of {} \
                 (file offsets {} vs {})",
                ca.id,
                ca.name,
                off,
                ca.state.len().max(cb.state.len()),
                ca.state_off + off,
                cb.state_off + off,
            ));
        }
    }
    None
}

/// Compare two snapshot files. `Ok(None)` means bit-identical;
/// `Ok(Some(report))` names the first diverging section in file order —
/// header identity, pinned config, platform spec, shared state, the
/// first diverging domain (clock / executed count / first differing
/// pending event), or the first diverging component (name + byte offset
/// into its state record). Either file failing to parse is an error.
pub fn diff_snapshots(
    a_bytes: &[u8],
    b_bytes: &[u8],
) -> Result<Option<String>, CkptError> {
    if a_bytes == b_bytes {
        return Ok(None);
    }
    let a = read_snapshot(a_bytes)?;
    let b = read_snapshot(b_bytes)?;
    Ok(Some(diff_parsed(&a, &b).unwrap_or_else(|| {
        // Same parsed content, different bytes: only the framing can
        // differ, which read_snapshot's strict validation rules out —
        // keep a truthful fallback anyway.
        "files differ but every parsed section is identical".to_string()
    })))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_and_line_diffs() {
        assert_eq!(first_byte_diff(b"abcd", b"abXd"), 2);
        assert_eq!(first_byte_diff(b"ab", b"ab"), 2);
        let (line, la, lb) = first_line_diff("a\nb\nc", "a\nB\nc");
        assert_eq!((line, la.as_str(), lb.as_str()), (2, "b", "B"));
    }
}
