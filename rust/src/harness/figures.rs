//! Figure regeneration: Fig. 7 (core & quantum sweep), Fig. 8 (PARSEC +
//! STREAM @ 32 cores), Fig. 9 (cache miss-rate errors), plus the §3.3
//! atomic-vs-timing comparison.

use anyhow::Result;

use crate::config::{Mode, RunConfig};
use crate::cpu::CpuModel;
use crate::pdes::HostModel;
use crate::sim::time::NS;
use crate::workload::FIG8_APPS;

use super::{compare_modes, run_once, ComparisonRow};

/// Default quantum sweep (ns). The paper's max quantum is the L3-hit
/// latency (~16 ns, §5.1).
pub const QUANTA_NS: &[u64] = &[2, 4, 8, 16];

pub struct FigureOpts {
    pub ops_per_core: usize,
    pub seed: u64,
    /// Modeled host cores for the virtual speedup (paper: 64).
    pub host_cores: usize,
    /// Use the threaded kernel instead of the virtual one (meaningful only
    /// on a many-core host).
    pub threaded: bool,
    /// Scale factor for core counts (keeps CI fast).
    pub max_cores: usize,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            ops_per_core: 2048,
            seed: 42,
            host_cores: 64,
            threaded: false,
            max_cores: 120,
        }
    }
}

fn cfg_pair(
    app: &str,
    cores: usize,
    quantum_ns: u64,
    opts: &FigureOpts,
) -> (RunConfig, RunConfig) {
    let mut serial = RunConfig {
        app: app.to_string(),
        ops_per_core: opts.ops_per_core,
        seed: opts.seed,
        cpu_model: CpuModel::O3,
        mode: Mode::Serial,
        host_cores: opts.host_cores,
        ..Default::default()
    };
    serial.system.cores = cores;
    let mut par = serial.clone();
    par.mode = if opts.threaded { Mode::Parallel } else { Mode::Virtual };
    par.quantum = quantum_ns * NS;
    (serial, par)
}

fn run_pair(
    app: &str,
    cores: usize,
    quantum_ns: u64,
    opts: &FigureOpts,
) -> Result<ComparisonRow> {
    let (serial, par) = cfg_pair(app, cores, quantum_ns, opts);
    let mut host = HostModel { h_cores: opts.host_cores, ..Default::default() };
    compare_modes(&serial, &par, &mut host)
}

/// Fig. 7: speedup + simulated-time error as a function of core count and
/// quantum, for the synthetic benchmark and blackscholes.
pub fn fig7(opts: &FigureOpts) -> Result<Vec<(String, ComparisonRow)>> {
    let mut rows = Vec::new();
    // Paper: cores in multiples of two, stopping at 120.
    let mut core_counts = vec![2usize, 4, 8, 16, 32, 64, 120];
    core_counts.retain(|&c| c <= opts.max_cores);
    for app in ["synthetic", "blackscholes"] {
        for &cores in &core_counts {
            for &q in QUANTA_NS {
                let row = run_pair(app, cores, q, opts)?;
                rows.push((app.to_string(), row));
            }
        }
    }
    Ok(rows)
}

/// Fig. 8: speedup + simulated-time error for the PARSEC subset + STREAM on
/// a 32-core target, per quantum.
pub fn fig8(opts: &FigureOpts) -> Result<Vec<(String, ComparisonRow)>> {
    let cores = 32.min(opts.max_cores);
    let mut rows = Vec::new();
    for app in FIG8_APPS {
        for &q in QUANTA_NS {
            let row = run_pair(app, cores, q, opts)?;
            rows.push((app.to_string(), row));
        }
    }
    Ok(rows)
}

/// Fig. 9 uses the same runs as Fig. 8 but reports the per-level absolute
/// cache-miss-rate errors.
pub fn fig9(opts: &FigureOpts) -> Result<Vec<(String, ComparisonRow)>> {
    fig8(opts)
}

/// §3.3: "simulations using the timing protocol and the detailed O3CPU
/// yield only 20% of the performance obtained with the atomic protocol".
pub struct ProtocolComparison {
    pub atomic_mips: f64,
    pub timing_mips: f64,
    pub ratio: f64,
}

pub fn atomic_vs_timing(cores: usize, ops: usize) -> Result<ProtocolComparison> {
    let mut atomic_cfg = RunConfig {
        cpu_model: CpuModel::Atomic,
        app: "synthetic".to_string(),
        ops_per_core: ops,
        ..Default::default()
    };
    atomic_cfg.system.cores = cores;
    let mut timing_cfg = atomic_cfg.clone();
    timing_cfg.cpu_model = CpuModel::O3;

    let a = run_once(&atomic_cfg)?;
    let t = run_once(&timing_cfg)?;
    let (am, tm) = (a.mips(), t.mips());
    Ok(ProtocolComparison {
        atomic_mips: am,
        timing_mips: tm,
        ratio: if am > 0.0 { tm / am } else { 0.0 },
    })
}

/// Render comparison rows as an aligned text table.
pub fn render_rows(rows: &[(String, ComparisonRow)]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<14} {:>6} {:>8} {:>9} {:>10} {:>8} {:>8} {:>8} {:>8} {:>6}\n",
        "app", "cores", "q(ns)", "speedup", "terr(%)", "l1i(pp)", "l1d(pp)", "l2(pp)", "l3(pp)", "csum"
    ));
    for (app, r) in rows {
        s.push_str(&format!(
            "{:<14} {:>6} {:>8} {:>9.2} {:>10.2} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>6}\n",
            app,
            r.cores,
            r.quantum_ns,
            r.speedup,
            r.sim_time_error * 100.0,
            r.miss_rate_err_pp[0],
            r.miss_rate_err_pp[1],
            r.miss_rate_err_pp[2],
            r.miss_rate_err_pp[3],
            if r.checksum_match { "ok" } else { "DIFF" },
        ));
    }
    s
}
