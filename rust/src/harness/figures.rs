//! Figure regeneration: Fig. 7 (core & quantum sweep), Fig. 8 (PARSEC +
//! STREAM @ 32 cores), Fig. 9 (cache miss-rate errors), plus the §3.3
//! atomic-vs-timing comparison.

use anyhow::Result;

use crate::config::{Mode, RunConfig};
use crate::cpu::CpuModel;
use crate::pdes::HostModel;
use crate::sched::QuantumPolicy;
use crate::sim::time::NS;
use crate::spec::SystemSpec;
use crate::workload::FIG8_APPS;

use super::{compare_modes, make_workload, run_once, run_with_workload, ComparisonRow};

/// Default quantum sweep (ns). The paper's max quantum is the L3-hit
/// latency (~16 ns, §5.1).
pub const QUANTA_NS: &[u64] = &[2, 4, 8, 16];

#[derive(Clone)]
pub struct FigureOpts {
    pub ops_per_core: usize,
    pub seed: u64,
    /// Modeled host cores for the virtual speedup (paper: 64).
    pub host_cores: usize,
    /// Use the threaded kernel instead of the virtual one (meaningful only
    /// on a many-core host).
    pub threaded: bool,
    /// Scale factor for core counts (keeps CI fast).
    pub max_cores: usize,
    /// Window-advance policy for the PDES runs (`--quantum-policy`):
    /// results are bit-identical across policies (DESIGN.md §4.4), so the
    /// sweeps stay accuracy-comparable while the barrier counters expose
    /// the border savings.
    pub quantum_policy: QuantumPolicy,
    /// Platform template for every swept point (`--platform`): cache
    /// geometry, memory channels and interconnect topology come from the
    /// spec, while the sweep still varies the core count. `None` keeps
    /// the legacy Table 2 star. Core counts the spec cannot scale to
    /// (e.g. a mesh whose width does not divide the count) are skipped —
    /// see [`FigureOpts::sweepable`].
    pub platform: Option<SystemSpec>,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            ops_per_core: 2048,
            seed: 42,
            host_cores: 64,
            threaded: false,
            max_cores: 120,
            quantum_policy: QuantumPolicy::Fixed,
            platform: None,
        }
    }
}

impl FigureOpts {
    /// Can this sweep point run on the selected platform? Without
    /// `--platform` every core count is sweepable; with one, the spec
    /// re-validated at `cores` must hold (a `mesh` needs full rows, a
    /// `ring` at least two stations).
    pub fn sweepable(&self, cores: usize) -> bool {
        match &self.platform {
            None => true,
            Some(spec) => {
                let mut s = spec.clone();
                s.cores = cores;
                s.validate().is_ok()
            }
        }
    }
}

/// The largest core count `<= target` the selected platform can scale to
/// (`target` itself without `--platform`). Never exceeds the caller's cap:
/// a mesh whose width does not divide the target steps *down* to the next
/// full-rows count, and an unsatisfiable cap is an error, not a silent
/// upgrade to a bigger machine.
fn largest_sweepable(opts: &FigureOpts, target: usize) -> Result<usize> {
    (1..=target)
        .rev()
        .find(|&c| opts.sweepable(c))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "platform {} cannot scale to any core count <= {target} \
                 (try a larger --max-cores or a different platform)",
                opts.platform
                    .as_ref()
                    .map_or("<none>", |s| s.name.as_str())
            )
        })
}

fn cfg_pair(
    app: &str,
    cores: usize,
    quantum_ns: u64,
    opts: &FigureOpts,
) -> (RunConfig, RunConfig) {
    let mut serial = RunConfig {
        app: app.to_string(),
        ops_per_core: opts.ops_per_core,
        seed: opts.seed,
        cpu_model: CpuModel::O3,
        mode: Mode::Serial,
        host_cores: opts.host_cores,
        ..Default::default()
    };
    serial.system.cores = cores;
    if let Some(spec) = &opts.platform {
        serial.apply_spec(spec);
        serial.system.cores = cores; // the sweep's core count wins
    }
    let mut par = serial.clone();
    par.mode = if opts.threaded { Mode::Parallel } else { Mode::Virtual };
    par.quantum = quantum_ns * NS;
    par.quantum_policy = opts.quantum_policy;
    (serial, par)
}

fn run_pair(
    app: &str,
    cores: usize,
    quantum_ns: u64,
    opts: &FigureOpts,
) -> Result<ComparisonRow> {
    let (serial, par) = cfg_pair(app, cores, quantum_ns, opts);
    let mut host = HostModel { h_cores: opts.host_cores, ..Default::default() };
    compare_modes(&serial, &par, &mut host)
}

/// Fig. 7: speedup + simulated-time error as a function of core count and
/// quantum, for the synthetic benchmark and blackscholes.
pub fn fig7(opts: &FigureOpts) -> Result<Vec<(String, ComparisonRow)>> {
    let mut rows = Vec::new();
    // Paper: cores in multiples of two, stopping at 120.
    let mut core_counts = vec![2usize, 4, 8, 16, 32, 64, 120];
    core_counts.retain(|&c| c <= opts.max_cores && opts.sweepable(c));
    if core_counts.is_empty() {
        // An actionable failure like fig8/figq, not a silent empty figure.
        anyhow::bail!(
            "platform {} has no sweepable core count <= {} in the Fig. 7 \
             grid (2,4,8,16,32,64,120) — raise --max-cores or pick \
             another platform",
            opts.platform
                .as_ref()
                .map_or("<none>", |s| s.name.as_str()),
            opts.max_cores
        );
    }
    for app in ["synthetic", "blackscholes"] {
        for &cores in &core_counts {
            for &q in QUANTA_NS {
                let row = run_pair(app, cores, q, opts)?;
                rows.push((app.to_string(), row));
            }
        }
    }
    Ok(rows)
}

/// Fig. 8: speedup + simulated-time error for the PARSEC subset + STREAM on
/// a 32-core target, per quantum.
pub fn fig8(opts: &FigureOpts) -> Result<Vec<(String, ComparisonRow)>> {
    let cores = largest_sweepable(opts, 32.min(opts.max_cores))?;
    let mut rows = Vec::new();
    for app in FIG8_APPS {
        for &q in QUANTA_NS {
            let row = run_pair(app, cores, q, opts)?;
            rows.push((app.to_string(), row));
        }
    }
    Ok(rows)
}

/// Fig. 9 uses the same runs as Fig. 8 but reports the per-level absolute
/// cache-miss-rate errors.
pub fn fig9(opts: &FigureOpts) -> Result<Vec<(String, ComparisonRow)>> {
    fig8(opts)
}

/// One row of the adaptive-quantum sweep (`figq`): the same app × quantum
/// point under `fixed` and `horizon`, with the barrier-count reduction
/// reported next to the modeled speedups. Results are bit-identical across
/// the two policies (DESIGN.md §4.4, gated by
/// `rust/tests/adaptive_quantum.rs`) — only the border count, and
/// therefore the modeled wall-clock, changes.
pub struct QuantumPolicyRow {
    pub app: String,
    pub cores: usize,
    pub quantum_ns: u64,
    pub speedup_fixed: f64,
    pub speedup_horizon: f64,
    pub barriers_fixed: u64,
    pub barriers_horizon: u64,
    /// Dead windows `horizon` leapt (`barriers_horizon + quanta_skipped
    /// == barriers_fixed`, the §4.4 invariant).
    pub quanta_skipped: u64,
}

impl QuantumPolicyRow {
    /// Fraction of fixed-policy borders the horizon policy eliminated.
    pub fn barrier_reduction(&self) -> f64 {
        if self.barriers_fixed == 0 {
            0.0
        } else {
            1.0 - self.barriers_horizon as f64 / self.barriers_fixed as f64
        }
    }
}

/// The adaptive-quantum figure sweep (ROADMAP item): exercise
/// `--quantum-policy horizon` across the Fig. 7 app × quantum grid and
/// report barrier-count reductions alongside the modeled speedup. The
/// speedup model charges every border its barrier cost, so leapt windows
/// translate directly into modeled wall-clock savings.
pub fn fig_quantum_policy(opts: &FigureOpts) -> Result<Vec<QuantumPolicyRow>> {
    let cores = largest_sweepable(opts, 16.min(opts.max_cores.max(2)))?;
    let mut rows = Vec::new();
    for app in ["synthetic", "blackscholes"] {
        // One serial reference and one workload per app; both policies
        // replay the identical traces.
        let (serial_cfg, _) = cfg_pair(app, cores, QUANTA_NS[0], opts);
        let w = make_workload(&serial_cfg)?;
        let serial = run_with_workload(&serial_cfg, &w)?;
        for &q in QUANTA_NS {
            let mut per_policy = Vec::new();
            for policy in [QuantumPolicy::Fixed, QuantumPolicy::Horizon] {
                let sub =
                    FigureOpts { quantum_policy: policy, ..opts.clone() };
                let (_, mut par) = cfg_pair(app, cores, q, &sub);
                par.mode = Mode::Virtual; // the measurement kernel
                let run = run_with_workload(&par, &w)?;
                let mut host = HostModel::for_threads(
                    opts.host_cores,
                    cores + 1,
                );
                host.calibrate_cost(&serial);
                let speedup = host.speedup(
                    serial.events,
                    run.work.as_ref().expect("virtual records work"),
                );
                per_policy.push((speedup, run.pdes));
            }
            let (speedup_fixed, pdes_fixed) = per_policy[0];
            let (speedup_horizon, pdes_horizon) = per_policy[1];
            rows.push(QuantumPolicyRow {
                app: app.to_string(),
                cores,
                quantum_ns: q,
                speedup_fixed,
                speedup_horizon,
                barriers_fixed: pdes_fixed.barriers,
                barriers_horizon: pdes_horizon.barriers,
                quanta_skipped: pdes_horizon.quanta_skipped,
            });
        }
    }
    Ok(rows)
}

/// Render the adaptive-quantum sweep as an aligned text table.
pub fn render_quantum_rows(rows: &[QuantumPolicyRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<14} {:>6} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9} {:>8}\n",
        "app",
        "cores",
        "q(ns)",
        "spd-fix",
        "spd-hor",
        "bar-fix",
        "bar-hor",
        "skipped",
        "saved"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<14} {:>6} {:>6} {:>9.2} {:>9.2} {:>9} {:>9} {:>9} {:>7.1}%\n",
            r.app,
            r.cores,
            r.quantum_ns,
            r.speedup_fixed,
            r.speedup_horizon,
            r.barriers_fixed,
            r.barriers_horizon,
            r.quanta_skipped,
            r.barrier_reduction() * 100.0,
        ));
    }
    s
}

/// One row of the traffic sweep (`figt`): a platform preset × traffic
/// scenario point on the measurement kernel, reporting the
/// offered/accepted/retries backpressure triple (docs/TRAFFIC.md) next
/// to the HN-F contention stats that separate the patterns (hotspot
/// concentrates `requeued`/`snoops_sent`; neighbor barely touches them).
pub struct TrafficRow {
    pub platform: String,
    pub pattern: String,
    pub cores: usize,
    pub sim_ms: f64,
    pub offered: u64,
    pub accepted: u64,
    pub retries: u64,
    /// HN-F per-line serialisation requeues, summed over HN-Fs.
    pub hnf_requeued: u64,
    /// Coherence snoops the HN-Fs sent, summed.
    pub snoops_sent: u64,
}

/// Platform presets the traffic sweep crosses with the scenario registry
/// (one per interconnect topology, smallest first).
pub const TRAFFIC_SWEEP_PLATFORMS: &[&str] = &["fig4-2", "ring-16", "mesh-64"];

/// The topology × pattern traffic sweep: every scenario in
/// [`crate::spec::traffic::scenarios`] on every preset of
/// [`TRAFFIC_SWEEP_PLATFORMS`] that fits `--max-cores`, on the virtual
/// measurement kernel (threaded with `--threaded`). Every reported
/// counter is deterministic, so the table is a regression artefact, not
/// just an illustration.
pub fn fig_traffic(opts: &FigureOpts) -> Result<Vec<TrafficRow>> {
    let mut rows = Vec::new();
    for name in TRAFFIC_SWEEP_PLATFORMS {
        let spec = crate::spec::platforms::resolve(name)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if spec.cores > opts.max_cores {
            continue;
        }
        for t in crate::spec::traffic::scenarios() {
            let mut cfg = RunConfig::for_spec(&spec);
            cfg.mode =
                if opts.threaded { Mode::Parallel } else { Mode::Virtual };
            cfg.quantum = *QUANTA_NS.last().unwrap() * NS;
            cfg.quantum_policy = opts.quantum_policy;
            cfg.ops_per_core = opts.ops_per_core;
            cfg.host_cores = opts.host_cores;
            cfg.traffic = Some(t.name.clone());
            let r = run_once(&cfg)?;
            rows.push(TrafficRow {
                platform: spec.name.clone(),
                pattern: t.name.clone(),
                cores: spec.cores,
                sim_ms: r.sim_seconds() * 1e3,
                offered: r.pdes.traffic_offered,
                accepted: r.pdes.traffic_accepted,
                retries: r.pdes.traffic_retries,
                hnf_requeued: r.stats.sum_suffix(".requeued") as u64,
                snoops_sent: r.stats.sum_suffix(".snoops_sent") as u64,
            });
        }
    }
    if rows.is_empty() {
        anyhow::bail!(
            "no traffic sweep platform fits --max-cores {} (presets: {})",
            opts.max_cores,
            TRAFFIC_SWEEP_PLATFORMS.join(", ")
        );
    }
    Ok(rows)
}

/// Render the traffic sweep as an aligned text table.
pub fn render_traffic_rows(rows: &[TrafficRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<10} {:<18} {:>6} {:>10} {:>9} {:>9} {:>8} {:>9} {:>8}\n",
        "platform",
        "pattern",
        "cores",
        "sim(ms)",
        "offered",
        "accepted",
        "retries",
        "requeued",
        "snoops"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<10} {:<18} {:>6} {:>10.4} {:>9} {:>9} {:>8} {:>9} {:>8}\n",
            r.platform,
            r.pattern,
            r.cores,
            r.sim_ms,
            r.offered,
            r.accepted,
            r.retries,
            r.hnf_requeued,
            r.snoops_sent,
        ));
    }
    s
}

/// §3.3: "simulations using the timing protocol and the detailed O3CPU
/// yield only 20% of the performance obtained with the atomic protocol".
pub struct ProtocolComparison {
    pub atomic_mips: f64,
    pub timing_mips: f64,
    pub ratio: f64,
}

pub fn atomic_vs_timing(cores: usize, ops: usize) -> Result<ProtocolComparison> {
    let mut atomic_cfg = RunConfig {
        cpu_model: CpuModel::Atomic,
        app: "synthetic".to_string(),
        ops_per_core: ops,
        ..Default::default()
    };
    atomic_cfg.system.cores = cores;
    let mut timing_cfg = atomic_cfg.clone();
    timing_cfg.cpu_model = CpuModel::O3;

    let a = run_once(&atomic_cfg)?;
    let t = run_once(&timing_cfg)?;
    let (am, tm) = (a.mips(), t.mips());
    Ok(ProtocolComparison {
        atomic_mips: am,
        timing_mips: tm,
        ratio: if am > 0.0 { tm / am } else { 0.0 },
    })
}

/// Render comparison rows as an aligned text table.
pub fn render_rows(rows: &[(String, ComparisonRow)]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<14} {:>6} {:>8} {:>9} {:>10} {:>8} {:>8} {:>8} {:>8} {:>6}\n",
        "app", "cores", "q(ns)", "speedup", "terr(%)", "l1i(pp)", "l1d(pp)", "l2(pp)", "l3(pp)", "csum"
    ));
    for (app, r) in rows {
        s.push_str(&format!(
            "{:<14} {:>6} {:>8} {:>9.2} {:>10.2} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>6}\n",
            app,
            r.cores,
            r.quantum_ns,
            r.speedup,
            r.sim_time_error * 100.0,
            r.miss_rate_err_pp[0],
            r.miss_rate_err_pp[1],
            r.miss_rate_err_pp[2],
            r.miss_rate_err_pp[3],
            if r.checksum_match { "ok" } else { "DIFF" },
        ));
    }
    s
}
