//! Regenerate the paper's tables 1-3 from the implementation itself.

use crate::config::SystemConfig;
use crate::workload::APPS;

/// Table 1: CPU models and their timing features.
pub fn table1() -> String {
    let mut s = String::new();
    s.push_str("Table 1. Main CPU Models and their Timing Features\n");
    s.push_str(
        "| CPU model | KVM | Atomic | Minor | O3 |\n\
         |---|---|---|---|---|\n\
         | Pipeline | N/A | none | in-order | out-of-order |\n\
         | Communication protocol | N/A | atomic | timing | timing |\n\
         | Custom cache protocols (Ruby) | no | no | yes | yes |\n\
         | Custom interconnect (Ruby) | no | no | yes | yes |\n\
         | Parallel simulation | gem5 | par-gem5 | this work | this work |\n",
    );
    s
}

/// Table 2: the simulated system (rendered from the live defaults, so the
/// table is honest about what the code actually runs).
pub fn table2(cfg: &SystemConfig) -> String {
    let mut s = String::new();
    s.push_str("Table 2. Main Characteristics of the Simulated System\n");
    s.push_str("| Component | Property | Value |\n|---|---|---|\n");
    s.push_str(&format!(
        "| CPU | Architecture | trace-driven O3/Minor (ARMv8-A stand-in) |\n\
         | CPU | Clock | {} GHz |\n",
        cfg.cpu_mhz / 1000
    ));
    for (name, c) in [("L1 I-Cache", &cfg.l1i), ("L1 D-Cache", &cfg.l1d), ("L2 Cache", &cfg.l2), ("L3 Cache", &cfg.l3)] {
        s.push_str(&format!(
            "| {name} | Capacity | {} KiB |\n| {name} | Associativity | {} |\n| {name} | Access latency | {} ns |\n",
            c.size_bytes / 1024,
            c.assoc,
            c.latency_ns
        ));
    }
    s.push_str(&format!(
        "| DRAM | Clock | {} GHz |\n| NoC | Link and router latency | {} ns |\n| NoC | Router buffer size | {} messages |\n",
        cfg.dram_mhz / 1000,
        cfg.noc_latency_ns_x10 as f64 / 10.0,
        cfg.router_buffer
    ));
    s
}

/// Table 3: PARSEC application characteristics (from the registry).
pub fn table3() -> String {
    let mut s = String::new();
    s.push_str("Table 3. Application Characteristics (workload registry)\n");
    s.push_str(
        "| Program | Model | Granularity | Sharing | Exchange | share_milli | barrier_every |\n|---|---|---|---|---|---|---|\n",
    );
    for app in APPS {
        let t = app.traits_;
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} |\n",
            t.name,
            t.model,
            t.granularity,
            t.sharing,
            t.exchange,
            app.share_milli,
            app.barrier_every
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        assert!(table1().contains("par-gem5"));
        let t2 = table2(&SystemConfig::default());
        assert!(t2.contains("| CPU | Clock | 2 GHz |"));
        assert!(t2.contains("| L2 Cache | Capacity | 2048 KiB |"));
        let t3 = table3();
        assert!(t3.contains("blackscholes"));
        assert!(t3.contains("stream"));
    }
}
