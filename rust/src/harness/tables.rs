//! Regenerate the paper's tables 1-3 from the implementation itself,
//! plus the sweep-journal summary table (docs/SWEEP.md).

use crate::config::SystemConfig;
use crate::stats::SweepRecord;
use crate::workload::APPS;

/// Table 1: CPU models and their timing features.
pub fn table1() -> String {
    let mut s = String::new();
    s.push_str("Table 1. Main CPU Models and their Timing Features\n");
    s.push_str(
        "| CPU model | KVM | Atomic | Minor | O3 |\n\
         |---|---|---|---|---|\n\
         | Pipeline | N/A | none | in-order | out-of-order |\n\
         | Communication protocol | N/A | atomic | timing | timing |\n\
         | Custom cache protocols (Ruby) | no | no | yes | yes |\n\
         | Custom interconnect (Ruby) | no | no | yes | yes |\n\
         | Parallel simulation | gem5 | par-gem5 | this work | this work |\n",
    );
    s
}

/// Table 2: the simulated system (rendered from the live defaults, so the
/// table is honest about what the code actually runs).
pub fn table2(cfg: &SystemConfig) -> String {
    let mut s = String::new();
    s.push_str("Table 2. Main Characteristics of the Simulated System\n");
    s.push_str("| Component | Property | Value |\n|---|---|---|\n");
    s.push_str(&format!(
        "| CPU | Architecture | trace-driven O3/Minor (ARMv8-A stand-in) |\n\
         | CPU | Clock | {} GHz |\n",
        cfg.cpu_mhz / 1000
    ));
    for (name, c) in [("L1 I-Cache", &cfg.l1i), ("L1 D-Cache", &cfg.l1d), ("L2 Cache", &cfg.l2), ("L3 Cache", &cfg.l3)] {
        s.push_str(&format!(
            "| {name} | Capacity | {} KiB |\n| {name} | Associativity | {} |\n| {name} | Access latency | {} ns |\n",
            c.size_bytes / 1024,
            c.assoc,
            c.latency_ns
        ));
    }
    s.push_str(&format!(
        "| DRAM | Clock | {} GHz |\n| NoC | Link and router latency | {} ns |\n| NoC | Router buffer size | {} messages |\n",
        cfg.dram_mhz / 1000,
        cfg.noc_latency_ns_x10 as f64 / 10.0,
        cfg.router_buffer
    ));
    s
}

/// Table 3: PARSEC application characteristics (from the registry).
pub fn table3() -> String {
    let mut s = String::new();
    s.push_str("Table 3. Application Characteristics (workload registry)\n");
    s.push_str(
        "| Program | Model | Granularity | Sharing | Exchange | share_milli | barrier_every |\n|---|---|---|---|---|---|---|\n",
    );
    for app in APPS {
        let t = app.traits_;
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} |\n",
            t.name,
            t.model,
            t.granularity,
            t.sharing,
            t.exchange,
            app.share_milli,
            app.barrier_every
        ));
    }
    s
}

/// Render sweep-journal records as a summary table, index-sorted. Only
/// deterministic fields appear — the table, like the canonical journal,
/// is reproducible across hosts and pool sizes.
pub fn sweep_table(records: &[SweepRecord]) -> String {
    let idw = records
        .iter()
        .map(|r| r.id.len())
        .max()
        .unwrap_or(0)
        .max("point id".len());
    let mut s = String::new();
    s.push_str(&format!(
        "| {:>5} | {:<idw$} | {:>12} | {:>10} | {:>8} | {:>10} | {:>8} |\n",
        "point", "point id", "sim_time_us", "events", "l2_miss", "offered", "retries",
    ));
    s.push_str(&format!(
        "|{:->7}|{:->w$}|{:->14}|{:->12}|{:->10}|{:->12}|{:->10}|\n",
        "", "", "", "", "", "", "",
        w = idw + 2,
    ));
    for r in records {
        s.push_str(&format!(
            "| {:>5} | {:<idw$} | {:>12.3} | {:>10} | {:>8.4} | {:>10} | {:>8} |\n",
            r.index,
            r.id,
            r.sim_seconds * 1e6,
            r.events,
            r.l2_miss_rate,
            r.traffic_offered,
            r.traffic_retries,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_table_lists_every_record() {
        let line = concat!(
            "{\"index\": 3, ",
            "\"id\": \"fig4-2+c4+l2:512k+star+app:canneal+virtual+q8+fixed\", ",
            "\"sim_ticks\": 123000, \"sim_seconds\": 0.000123, ",
            "\"events\": 42, \"committed_ops\": 10, \"barriers\": 2, ",
            "\"quanta_skipped\": 0, \"cross_events\": 5, \"postponed\": 1, ",
            "\"inbox_staged\": 4, \"xbar_staged\": 3, ",
            "\"xbar_deferred_grants\": 0, \"traffic_offered\": 64, ",
            "\"traffic_accepted\": 64, \"traffic_retries\": 7, ",
            "\"traffic_phases\": 0, \"routed\": 9, \"hnf_requeued\": 0, ",
            "\"load_checksum\": 17, \"l1d_miss_rate\": 0.25, ",
            "\"l2_miss_rate\": 0.125, \"l3_miss_rate\": 0.0625}",
        );
        let rec = SweepRecord::from_json_line(line).unwrap();
        let t = sweep_table(&[rec]);
        assert!(t.contains("point id"), "{t}");
        assert!(t.contains("fig4-2+c4+l2:512k+star+app:canneal+virtual+q8+fixed"));
        assert!(t.contains(" 0.1250 |"), "{t}");
        assert!(t.contains(" 7 |"), "{t}");
        assert_eq!(t.lines().count(), 3, "header + rule + one row");
    }

    #[test]
    fn tables_render() {
        assert!(table1().contains("par-gem5"));
        let t2 = table2(&SystemConfig::default());
        assert!(t2.contains("| CPU | Clock | 2 GHz |"));
        assert!(t2.contains("| L2 Cache | Capacity | 2048 KiB |"));
        let t3 = table3();
        assert!(t3.contains("blackscholes"));
        assert!(t3.contains("stream"));
    }
}
