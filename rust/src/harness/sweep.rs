//! The sweep orchestrator: expands a [`SweepSpec`] into a deterministic
//! point list, runs the points on a claim-based *outer* pool of whole
//! simulations, and journals one [`SweepRecord`] per point (append-only
//! JSONL, docs/SWEEP.md).
//!
//! Three invariants make sweeps composable, and `tests/sweep.rs` gates
//! each one on journal bytes (modulo the `host_*` wall-clock fields):
//!
//! * **Pool-size invariance.** Workers *claim* points dynamically (an
//!   atomic cursor — idle workers steal whatever is next), but records
//!   pass through an in-order committer: a record is written only when
//!   every earlier point's record is already written. The journal is a
//!   pure function of the point list, whatever `--outer` is, and a
//!   killed sweep always leaves a clean point-order prefix.
//! * **Shard decomposition.** `--shard i/N` keeps the points whose
//!   expansion index is `i (mod N)` — a partition by construction, so
//!   the sorted union of N shard journals equals the unsharded journal
//!   (`tests/properties.rs` holds the partition property).
//! * **Resume.** On `--resume` the journal is re-read and completed
//!   point ids are skipped; intact lines are kept byte-for-byte, and a
//!   truncated or garbled line (a killed writer, a bad merge) is
//!   reported with its line number and its point re-run.
//!
//! The outer pool multiplies with the threaded kernel's *inner* threads,
//! so the default width follows the budget rule `outer × inner ≤
//! budget_cores` ([`budget_outer`]; `--outer`/`--budget-cores`
//! override).

use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::ckpt;
use crate::config::{Mode, RunConfig};
use crate::sim::time::NS;
use crate::spec::sweep::{
    fabric_keyword, mode_keyword, policy_keyword, Sampling, SweepSpec,
};
use crate::spec::{platforms, SystemSpec};
use crate::stats::journal::SweepRecord;
use crate::util::prop::Gen;

use super::{make_workload, restore_and_run, run_with_workload};

/// One expanded sweep point: a canonical id and a ready-to-run config.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Position in the expanded point list (`SweepRecord::index`).
    pub index: usize,
    /// Canonical id built from the *resolved* axis values — the resume
    /// key, stable across shards and pool sizes.
    pub id: String,
    pub cfg: RunConfig,
}

/// Expand a spec into its deterministic point list: the full grid in
/// field order, or the `sample_seed`-keyed random subset. Point ids,
/// order and indices are a pure function of the spec.
pub fn expand(spec: &SweepSpec) -> Result<Vec<SweepPoint>> {
    spec.validate().map_err(|e| anyhow!("{e}"))?;
    let plats: Vec<SystemSpec> = spec
        .platforms
        .iter()
        .map(|p| platforms::resolve(p).map_err(|e| anyhow!("{e}")))
        .collect::<Result<_>>()?;
    let dims = spec.axis_lens();
    let total: usize = dims.iter().product();
    let chosen: Vec<usize> = match spec.sampling {
        Sampling::Grid => (0..total).collect(),
        Sampling::Random => sample_indices(spec, total),
    };
    let mut points = Vec::with_capacity(chosen.len());
    for (index, &gi) in chosen.iter().enumerate() {
        let mut rest = gi;
        let mut coord = [0usize; 10];
        for d in (0..10).rev() {
            coord[d] = rest % dims[d];
            rest /= dims[d];
        }
        points.push(make_point(spec, &plats, coord, index)?);
    }
    Ok(points)
}

/// Distinct grid indices for `sampling = "random"`: rejection-sample
/// from the deterministic CBRNG stream, then fill any collision-starved
/// remainder in ascending order (still deterministic).
fn sample_indices(spec: &SweepSpec, total: usize) -> Vec<usize> {
    let want = spec.samples.min(total);
    let mut g = Gen::new(spec.sample_seed);
    let mut seen = std::collections::BTreeSet::new();
    let mut chosen = Vec::with_capacity(want);
    let mut attempts = 0usize;
    let cap = want.saturating_mul(64).saturating_add(1024);
    while chosen.len() < want && attempts < cap {
        attempts += 1;
        let gi = g.range_usize(0, total - 1);
        if seen.insert(gi) {
            chosen.push(gi);
        }
    }
    for gi in 0..total {
        if chosen.len() >= want {
            break;
        }
        if seen.insert(gi) {
            chosen.push(gi);
        }
    }
    chosen
}

fn make_point(
    spec: &SweepSpec,
    plats: &[SystemSpec],
    coord: [usize; 10],
    index: usize,
) -> Result<SweepPoint> {
    let mut plat = plats[coord[0]].clone();
    if let Some(&c) = spec.cores.get(coord[1]) {
        plat.cores = c;
    }
    if let Some(&k) = spec.l2_kib.get(coord[2]) {
        plat.l2.size_bytes = k * 1024;
    }
    if let Some(&f) = spec.fabrics.get(coord[3]) {
        plat.interconnect = f;
    }
    if let Some(&w) = spec.cpu_widths.get(coord[8]) {
        plat.cpu_spec.width = w;
    }
    if let Some(&r) = spec.rob_sizes.get(coord[9]) {
        plat.cpu_spec.rob_size = r;
    }
    let workload = &spec.workloads[coord[4]];
    let kernel = spec.kernels[coord[5]];
    let q_ns = spec.quantum_ns[coord[6]];
    let policy = spec.quantum_policies[coord[7]];
    let mut id = format!(
        "{}+c{}+l2:{}k+{}+{}+{}+q{}+{}",
        plat.name,
        plat.cores,
        plat.l2.size_bytes / 1024,
        fabric_keyword(plat.interconnect),
        workload,
        mode_keyword(kernel),
        q_ns,
        policy_keyword(policy),
    );
    // CPU-geometry tokens appear only when the axis is swept, keeping
    // existing point ids (the resume keys of old journals) unchanged.
    if !spec.cpu_widths.is_empty() {
        id.push_str(&format!("+w{}", plat.cpu_spec.width));
    }
    if !spec.rob_sizes.is_empty() {
        id.push_str(&format!("+rob{}", plat.cpu_spec.rob_size));
    }
    // Overrides can break a platform (e.g. ragged mesh rows) — surface
    // the spec's actionable hints with the point named.
    plat.validate().map_err(|e| anyhow!("sweep point {id}: {e}"))?;
    let mut cfg = RunConfig::for_spec(&plat);
    match workload.split_once(':') {
        Some(("app", name)) => cfg.app = name.to_string(),
        Some(("traffic", name)) => cfg.traffic = Some(name.to_string()),
        _ => bail!("sweep point {id}: bad workload entry `{workload}`"),
    }
    cfg.ops_per_core = spec.ops_per_core;
    cfg.seed = spec.seed;
    cfg.mode = kernel;
    cfg.quantum = q_ns * NS;
    cfg.quantum_policy = policy;
    if kernel == Mode::Parallel {
        cfg.threads = spec.inner_threads;
    }
    Ok(SweepPoint { index, id, cfg })
}

/// Parse a `--shard i/N` argument.
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let (i, n) = s
        .split_once('/')
        .ok_or_else(|| anyhow!("--shard wants i/N, e.g. 0/2 (got `{s}`)"))?;
    let i: usize = i
        .trim()
        .parse()
        .map_err(|e| anyhow!("--shard index `{}`: {e}", i.trim()))?;
    let n: usize = n
        .trim()
        .parse()
        .map_err(|e| anyhow!("--shard count `{}`: {e}", n.trim()))?;
    if n == 0 {
        bail!("--shard i/N needs N >= 1");
    }
    if i >= n {
        bail!("--shard {i}/{n} is out of range — the index runs 0..{n}");
    }
    Ok((i, n))
}

/// The points shard `i` of `N` owns: expansion index ≡ i (mod N). Every
/// point lands in exactly one shard (total + disjoint by construction).
pub fn shard_points(
    points: &[SweepPoint],
    shard: (usize, usize),
) -> Vec<SweepPoint> {
    points
        .iter()
        .filter(|p| p.index % shard.1 == shard.0)
        .cloned()
        .collect()
}

/// Host hardware threads (the default `budget_cores`).
pub fn host_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The budget rule: outer × inner ≤ budget_cores, i.e. the outer pool
/// defaults to `budget_cores / inner` (at least 1). An explicit
/// `--outer` overrides the rule — oversubscribing is allowed, it just
/// stops being the default.
pub fn budget_outer(
    requested: Option<usize>,
    inner: usize,
    budget_cores: usize,
) -> usize {
    match requested {
        Some(n) => n.max(1),
        None => (budget_cores / inner.max(1)).max(1),
    }
}

/// One unparsable journal line, reported with its 1-based line number.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JournalIssue {
    pub line: usize,
    pub error: String,
}

/// Tolerant journal read: intact records plus per-line issues.
pub struct JournalScan {
    pub records: Vec<SweepRecord>,
    pub issues: Vec<JournalIssue>,
}

/// Read a journal, keeping intact records and collecting issues for
/// truncated / garbled lines instead of failing.
pub fn scan_journal(path: &Path) -> Result<JournalScan> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read journal {}: {e}", path.display()))?;
    let mut out = JournalScan { records: Vec::new(), issues: Vec::new() };
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match SweepRecord::from_json_line(line) {
            Ok(r) => out.records.push(r),
            Err(e) => out.issues.push(JournalIssue { line: i + 1, error: e }),
        }
    }
    Ok(out)
}

fn strict_records(path: &Path) -> Result<Vec<SweepRecord>> {
    let scan = scan_journal(path)?;
    if let Some(i) = scan.issues.first() {
        bail!("{}:{}: {}", path.display(), i.line, i.error);
    }
    Ok(scan.records)
}

/// The journal's canonical form: every record re-emitted without the
/// `host_*` wall-clock fields, sorted by point index. Two runs of the
/// same point set must agree on this byte-for-byte.
pub fn canonical_journal(path: &Path) -> Result<Vec<String>> {
    let mut rs = strict_records(path)?;
    rs.sort_by_key(|r| r.index);
    Ok(rs.iter().map(|r| r.to_canonical_line()).collect())
}

/// Canonical form of several journals merged — the shard-union gate
/// compares this against the unsharded run.
pub fn canonical_journal_union<P: AsRef<Path>>(
    paths: &[P],
) -> Result<Vec<String>> {
    let mut rs = Vec::new();
    for p in paths {
        rs.extend(strict_records(p.as_ref())?);
    }
    rs.sort_by_key(|r| r.index);
    Ok(rs.iter().map(|r| r.to_canonical_line()).collect())
}

/// How to execute a sweep (the `sweep run` flag surface).
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Append-only JSONL results file.
    pub journal: PathBuf,
    /// Outer pool width; `None` applies the budget rule.
    pub outer: Option<usize>,
    /// Deterministic `(i, N)` partition of the point set.
    pub shard: Option<(usize, usize)>,
    /// Skip points already journaled (and repair damaged lines).
    pub resume: bool,
    /// Host-core budget the outer × inner product must fit in.
    pub budget_cores: usize,
    /// Stop after this many *new* points (CI smoke, kill-testing).
    pub max_points: Option<usize>,
    /// Fork points from this snapshot instead of cold-starting them:
    /// every point whose pinned axes (platform spec + workload + quantum
    /// policy knobs, docs/CHECKPOINT.md) match the snapshot's restores at
    /// the recorded border and runs only the remainder; non-matching
    /// points fall back to a cold run with a notice. Journal records are
    /// identical either way — that is the whole point, and
    /// `tests/checkpoint.rs` gates it.
    pub from_checkpoint: Option<PathBuf>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            journal: PathBuf::from("sweep_journal.jsonl"),
            outer: None,
            shard: None,
            resume: false,
            budget_cores: host_parallelism(),
            max_points: None,
            from_checkpoint: None,
        }
    }
}

/// What a sweep run did, with the journal's full record set (old + new,
/// index-sorted) ready for rendering.
pub struct SweepOutcome {
    /// Points in this run's (post-shard) point set.
    pub points: usize,
    /// Points skipped because the journal already had them.
    pub skipped: usize,
    /// Points executed (and appended) by this run.
    pub ran: usize,
    /// Outer pool width actually used.
    pub outer: usize,
    /// Damaged journal lines that were dropped and re-run.
    pub repaired: Vec<JournalIssue>,
    pub records: Vec<SweepRecord>,
}

struct Commit {
    file: std::fs::File,
    /// Next pending-list slot the journal is waiting on.
    next: usize,
    /// Finished records not yet writable (a predecessor is still
    /// running).
    ready: BTreeMap<usize, SweepRecord>,
    written: Vec<SweepRecord>,
    failed: Option<String>,
}

/// True when `point` can fork from `snap`: every pinned axis matches
/// (compared as the exact texts the spec hash is computed over) and the
/// point runs on a windowed kernel.
fn point_matches_snapshot(point: &SweepPoint, snap: &ckpt::Snapshot) -> bool {
    point.cfg.mode != Mode::Serial
        && ckpt::format::pinned_text(&point.cfg) == snap.config_text
        && point.cfg.spec().to_toml() == snap.spec_toml
}

fn run_point(
    point: &SweepPoint,
    fork: Option<&ckpt::Snapshot>,
) -> Result<SweepRecord> {
    if let Some(snap) = fork {
        if point_matches_snapshot(point, snap) {
            let (outcome, _) = restore_and_run(snap, &point.cfg, None)?;
            let r = outcome.into_finished();
            return Ok(SweepRecord::from_run(
                point.index as u64,
                &point.id,
                &r,
            ));
        }
        eprintln!(
            "sweep: point {} does not share the checkpoint's pinned axes \
             — cold run",
            point.id
        );
    }
    let w = make_workload(&point.cfg)?;
    let r = run_with_workload(&point.cfg, &w)?;
    Ok(SweepRecord::from_run(point.index as u64, &point.id, &r))
}

/// Run a sweep end to end: expand, shard, skip journaled points, drain
/// the rest on the outer pool, appending records in point order.
pub fn run_sweep(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepOutcome> {
    let all = expand(spec)?;
    let points = match opts.shard {
        Some(s) => shard_points(&all, s),
        None => all,
    };

    let mut done: BTreeMap<String, SweepRecord> = BTreeMap::new();
    let mut repaired = Vec::new();
    if opts.journal.exists() {
        let scan = scan_journal(&opts.journal)?;
        if !opts.resume && !(scan.records.is_empty() && scan.issues.is_empty())
        {
            bail!(
                "journal {} already holds {} record(s) — pass --resume to \
                 skip completed points, or point --journal at a fresh file",
                opts.journal.display(),
                scan.records.len()
            );
        }
        if !scan.issues.is_empty() {
            // Rewrite with only the intact lines: the damaged points are
            // re-run below, never silently skipped.
            let mut body = String::new();
            for r in &scan.records {
                body.push_str(&r.to_json_line());
                body.push('\n');
            }
            std::fs::write(&opts.journal, body).map_err(|e| {
                anyhow!(
                    "cannot rewrite journal {}: {e}",
                    opts.journal.display()
                )
            })?;
        }
        for r in scan.records {
            done.insert(r.id.clone(), r);
        }
        repaired = scan.issues;
    }

    let fork = match &opts.from_checkpoint {
        None => None,
        Some(path) => {
            let bytes = std::fs::read(path).map_err(|e| {
                anyhow!("cannot read checkpoint {}: {e}", path.display())
            })?;
            Some(ckpt::read_snapshot(&bytes)?)
        }
    };
    let fork = fork.as_ref();

    let skipped = points.iter().filter(|p| done.contains_key(&p.id)).count();
    let mut pending: Vec<&SweepPoint> =
        points.iter().filter(|p| !done.contains_key(&p.id)).collect();
    if let Some(k) = opts.max_points {
        pending.truncate(k);
    }

    let inner = if spec.kernels.contains(&Mode::Parallel) {
        spec.inner_threads.max(1)
    } else {
        1
    };
    let outer = budget_outer(opts.outer, inner, opts.budget_cores)
        .min(pending.len().max(1));

    let file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&opts.journal)
        .map_err(|e| {
            anyhow!("cannot open journal {}: {e}", opts.journal.display())
        })?;
    let commit = Mutex::new(Commit {
        file,
        next: 0,
        ready: BTreeMap::new(),
        written: Vec::new(),
        failed: None,
    });
    let claim = AtomicUsize::new(0);
    let pending = &pending;

    std::thread::scope(|s| {
        for _ in 0..outer {
            s.spawn(|| loop {
                let k = claim.fetch_add(1, Ordering::Relaxed);
                if k >= pending.len() {
                    break;
                }
                if commit.lock().unwrap().failed.is_some() {
                    break;
                }
                let point = pending[k];
                let res = run_point(point, fork);
                let mut guard = commit.lock().unwrap();
                let c = &mut *guard;
                match res {
                    Ok(rec) => {
                        c.ready.insert(k, rec);
                        // In-order commit: write only the contiguous
                        // prefix, so journal bytes are independent of
                        // which worker finished first.
                        while let Some(r) = c.ready.remove(&c.next) {
                            let line = r.to_json_line();
                            if let Err(e) = writeln!(c.file, "{line}") {
                                c.failed =
                                    Some(format!("journal write: {e}"));
                                break;
                            }
                            c.written.push(r);
                            c.next += 1;
                        }
                        if c.failed.is_none() {
                            if let Err(e) = c.file.flush() {
                                c.failed =
                                    Some(format!("journal flush: {e}"));
                            }
                        }
                    }
                    Err(e) => {
                        if c.failed.is_none() {
                            c.failed = Some(format!(
                                "point {} ({}): {e}",
                                point.index, point.id
                            ));
                        }
                    }
                }
            });
        }
    });

    let commit = commit.into_inner().unwrap();
    if let Some(msg) = commit.failed {
        bail!("sweep aborted: {msg}");
    }
    let ran = commit.written.len();
    let mut records: Vec<SweepRecord> =
        points.iter().filter_map(|p| done.get(&p.id).cloned()).collect();
    records.extend(commit.written);
    records.sort_by_key(|r| r.index);
    Ok(SweepOutcome {
        points: points.len(),
        skipped,
        ran,
        outer,
        repaired,
        records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::sweep;

    #[test]
    fn expand_is_deterministic_and_ids_unique() {
        let spec = sweep::sweep("quick").unwrap();
        let a = expand(&spec).unwrap();
        let b = expand(&spec).unwrap();
        assert_eq!(a.len(), spec.point_count());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.id, y.id);
        }
        let mut ids: Vec<&str> = a.iter().map(|p| p.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), a.len(), "ids must be unique");
    }

    #[test]
    fn random_sampling_is_seeded_and_distinct() {
        let spec = sweep::sweep("random-dse").unwrap();
        let a = expand(&spec).unwrap();
        assert_eq!(a.len(), 24);
        let b = expand(&spec).unwrap();
        assert_eq!(
            a.iter().map(|p| p.id.clone()).collect::<Vec<_>>(),
            b.iter().map(|p| p.id.clone()).collect::<Vec<_>>(),
        );
        let reseeded =
            SweepSpec { sample_seed: spec.sample_seed + 1, ..spec };
        let c = expand(&reseeded).unwrap();
        assert_ne!(
            a.iter().map(|p| p.id.clone()).collect::<Vec<_>>(),
            c.iter().map(|p| p.id.clone()).collect::<Vec<_>>(),
            "a different sample_seed draws a different subset"
        );
    }

    #[test]
    fn shards_partition_the_point_set() {
        let spec = sweep::sweep("ring-traffic").unwrap();
        let all = expand(&spec).unwrap();
        for n in 1..=4 {
            let mut seen = Vec::new();
            for i in 0..n {
                for p in shard_points(&all, (i, n)) {
                    seen.push(p.index);
                }
            }
            seen.sort_unstable();
            let want: Vec<usize> = (0..all.len()).collect();
            assert_eq!(seen, want, "shards {n} must partition");
        }
    }

    #[test]
    fn shard_parse_rejects_bad_input() {
        assert_eq!(parse_shard("0/2").unwrap(), (0, 2));
        assert_eq!(parse_shard("2/3").unwrap(), (2, 3));
        assert!(parse_shard("3/3").is_err());
        assert!(parse_shard("1of2").is_err());
        assert!(parse_shard("1/0").is_err());
        assert!(parse_shard("x/2").is_err());
    }

    #[test]
    fn budget_rule_divides_and_clamps() {
        assert_eq!(budget_outer(None, 1, 8), 8);
        assert_eq!(budget_outer(None, 4, 8), 2);
        assert_eq!(budget_outer(None, 16, 8), 1, "never below 1");
        assert_eq!(budget_outer(Some(5), 16, 8), 5, "explicit wins");
        assert_eq!(budget_outer(Some(0), 1, 8), 1);
    }

    #[test]
    fn point_ids_name_resolved_values() {
        let spec = SweepSpec {
            cores: vec![4],
            l2_kib: vec![512],
            ..sweep::SweepSpec::default()
        };
        let pts = expand(&spec).unwrap();
        assert_eq!(pts.len(), 1);
        assert_eq!(
            pts[0].id,
            "fig4-2+c4+l2:512k+star+app:synthetic+virtual+q8+fixed"
        );
        assert_eq!(pts[0].cfg.system.cores, 4);
        assert_eq!(pts[0].cfg.system.l2.size_bytes, 512 * 1024);
    }

    #[test]
    fn bad_override_is_reported_with_point_id() {
        let spec = SweepSpec {
            cores: vec![5],
            fabrics: vec![crate::spec::Interconnect::Mesh { cols: 4 }],
            ..sweep::SweepSpec::default()
        };
        let err = expand(&spec).unwrap_err().to_string();
        assert!(err.contains("mesh"), "{err}");
        assert!(err.contains("+c5+"), "error names the point: {err}");
    }
}
