//! The experiment harness: run driver, sweeps and figure/table
//! regeneration (one entry per paper table/figure, DESIGN.md §5).

pub mod figures;
pub mod sweep;
pub mod tables;

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::ckpt;
use crate::config::{Mode, RunConfig};
use crate::cpu::CpuModel;
use crate::pdes::{
    run_parallel, run_parallel_ctl, run_serial, run_virtual, run_virtual_ctl,
    HostModel, KernelCtl, Machine, RunOutcome, RunResult,
};
use crate::ruby::{build_atomic_system, build_system};
use crate::runtime::Runtime;
use crate::sim::time::Tick;
use crate::workload::{app_by_name, Workload};

/// Produce the workload for a run: synthetic traffic when `--traffic`
/// selects a spec (docs/TRAFFIC.md), else the app's artifact path when
/// available, bit-exact procedural fallback otherwise.
pub fn make_workload(cfg: &RunConfig) -> Result<Workload> {
    if let Some(arg) = &cfg.traffic {
        let spec = crate::spec::traffic::resolve(arg)
            .map_err(|e| anyhow!("{e}"))?;
        spec.validate().map_err(|e| anyhow!("{e}"))?;
        return Ok(crate::workload::traffic_workload(
            &spec,
            cfg.system.cores,
            cfg.ops_per_core,
        ));
    }
    let app = app_by_name(&cfg.app)
        .ok_or_else(|| anyhow!("unknown app '{}'", cfg.app))?;
    let dir = Runtime::default_dir();
    if Runtime::artifacts_available(&dir)
        && cfg.ops_per_core <= crate::runtime::TRACE_N
    {
        let rt = Runtime::new(dir)?;
        return crate::runtime::artifact_workload(
            &rt,
            app,
            cfg.system.cores,
            cfg.ops_per_core,
            cfg.seed,
        );
    }
    Ok(app.generate(cfg.system.cores, cfg.ops_per_core, cfg.seed))
}

/// Execute one run end to end.
pub fn run_once(cfg: &RunConfig) -> Result<RunResult> {
    let workload = make_workload(cfg)?;
    run_with_workload(cfg, &workload)
}

/// Execute one run with a pre-built workload (so sweeps reuse traces).
pub fn run_with_workload(cfg: &RunConfig, workload: &Workload) -> Result<RunResult> {
    // Surface platform mistakes as errors (with the spec's actionable
    // hints) before elaboration would panic on them.
    cfg.spec()
        .validate()
        .map_err(|e| anyhow!("{e}"))?;
    if !cfg.cpu_model.is_timing() {
        anyhow::ensure!(
            cfg.mode == Mode::Serial,
            "atomic/kvm CPU models run on the serial kernel only (Table 1)"
        );
        let (machine, _mem) = build_atomic_system(
            cfg,
            workload,
            cfg.cpu_model == CpuModel::Kvm,
        );
        return Ok(run_serial(machine, cfg.max_ticks));
    }
    let built = build_system(cfg, workload);
    Ok(match cfg.mode {
        Mode::Serial => run_serial(built.machine, cfg.max_ticks),
        Mode::Parallel => run_parallel(built.machine, cfg.max_ticks),
        Mode::Virtual => run_virtual(built.machine, cfg.max_ticks),
    })
}

/// Copy the free (non-pinned) axes of `from` onto `cfg`: the knobs a
/// restored run may change without affecting results — kernel mode,
/// thread count, stealing, queue implementation, calendar geometry,
/// profiling, modeled host cores — plus the run cutoff, which is a
/// stop condition rather than state (docs/CHECKPOINT.md has the table).
pub fn apply_free_axes(cfg: &mut RunConfig, from: &RunConfig) {
    cfg.mode = from.mode;
    cfg.threads = from.threads;
    cfg.steal = from.steal;
    cfg.queue = from.queue;
    cfg.bucket_shape = from.bucket_shape;
    cfg.profile = from.profile;
    cfg.host_cores = from.host_cores;
    cfg.max_ticks = from.max_ticks;
}

/// Execute `cfg` until the first quantum border at/after `at` (the snap
/// rule, docs/CHECKPOINT.md), write the snapshot to `out`, and return the
/// partial-run result plus the border actually frozen at. A run that
/// terminates before reaching `at` finishes normally and returns
/// `(result, None)` — no file is written.
pub fn run_to_checkpoint(
    cfg: &RunConfig,
    at: Tick,
    out: &Path,
) -> Result<(RunResult, Option<Tick>)> {
    anyhow::ensure!(
        cfg.cpu_model.is_timing(),
        "checkpointing supports timing CPU models only (minor/o3): \
         atomic/kvm cores share one functional memory image outside the \
         component arena"
    );
    anyhow::ensure!(
        cfg.mode != Mode::Serial,
        "checkpoint needs a windowed kernel (--mode virtual|parallel): \
         the serial reference has no quantum borders to freeze at"
    );
    cfg.spec().validate().map_err(|e| anyhow!("{e}"))?;
    let workload = make_workload(cfg)?;
    let built = build_system(cfg, &workload);
    let ctl = KernelCtl { resume_border: None, checkpoint_at: Some(at) };
    let outcome = match cfg.mode {
        Mode::Parallel => run_parallel_ctl(built.machine, cfg.max_ticks, ctl),
        _ => run_virtual_ctl(built.machine, cfg.max_ticks, ctl),
    };
    match outcome {
        RunOutcome::Finished(result) => Ok((result, None)),
        RunOutcome::Checkpointed { machine, border, result } => {
            let bytes = ckpt::snapshot_machine(&machine, cfg, border)?;
            std::fs::write(out, &bytes).map_err(|e| {
                anyhow!("cannot write checkpoint {}: {e}", out.display())
            })?;
            Ok((result, Some(border)))
        }
    }
}

/// Elaborate the machine a snapshot describes and load its state — the
/// shared rebuild step behind `run --restore` and `sweep run
/// --from-checkpoint`. Pinned axes come from the snapshot; `free`
/// contributes only its free axes ([`apply_free_axes`]). Returns the
/// loaded machine, the effective configuration, and the border to resume
/// from.
pub fn rebuild_from_snapshot(
    snap: &ckpt::Snapshot,
    free: &RunConfig,
) -> Result<(Machine, RunConfig, Tick)> {
    let mut cfg = snap.config()?;
    apply_free_axes(&mut cfg, free);
    anyhow::ensure!(
        cfg.mode != Mode::Serial,
        "a checkpoint resumes on a windowed kernel (--mode \
         virtual|parallel)"
    );
    cfg.spec().validate().map_err(|e| anyhow!("{e}"))?;
    let workload = make_workload(&cfg)?;
    let built = build_system(&cfg, &workload);
    let mut machine = built.machine;
    ckpt::apply(snap, &mut machine)?;
    Ok((machine, cfg, snap.header.tick))
}

/// Restore a snapshot and run it to completion — bit-identical to the
/// uninterrupted producing run past the border (gated by
/// `tests/checkpoint.rs`). `re_checkpoint` optionally freezes the resumed
/// run again at a later tick (snap rule as usual); the machine is
/// discarded in the `Finished` arm of that case.
pub fn restore_and_run(
    snap: &ckpt::Snapshot,
    free: &RunConfig,
    re_checkpoint: Option<Tick>,
) -> Result<(RunOutcome, RunConfig)> {
    let (machine, cfg, border) = rebuild_from_snapshot(snap, free)?;
    let ctl = KernelCtl {
        resume_border: Some(border),
        checkpoint_at: re_checkpoint,
    };
    let outcome = match cfg.mode {
        Mode::Parallel => run_parallel_ctl(machine, cfg.max_ticks, ctl),
        _ => run_virtual_ctl(machine, cfg.max_ticks, ctl),
    };
    Ok((outcome, cfg))
}

/// Serial reference + virtual-parallel run + host-model speedup — the
/// measurement kernel behind every figure (DESIGN.md §3 substitution).
pub struct ComparisonRow {
    pub cores: usize,
    pub quantum_ns: u64,
    pub speedup: f64,
    pub sim_time_error: f64,
    pub miss_rate_err_pp: [f64; 4],
    pub checksum_match: bool,
    pub serial: RunResult,
    pub run: RunResult,
}

/// Run serial reference vs PDES (virtual by default; threaded if asked)
/// and compute speedup + accuracy.
pub fn compare_modes(
    cfg_serial: &RunConfig,
    cfg_par: &RunConfig,
    host: &mut HostModel,
) -> Result<ComparisonRow> {
    let workload = make_workload(cfg_serial)?;
    let serial = run_with_workload(cfg_serial, &workload)?;
    let run = run_with_workload(cfg_par, &workload)?;

    host.calibrate_cost(&serial);
    // Barrier cost scales with participating threads (N cores + 1).
    host.barrier_cost_ns = 500.0 + 25.0 * (cfg_par.system.cores + 1) as f64;
    let speedup = match cfg_par.mode {
        Mode::Parallel => {
            serial.host_ns as f64 / run.host_ns.max(1) as f64
        }
        _ => {
            let work = run.work.as_ref().expect("virtual run records work");
            host.speedup(serial.events, work)
        }
    };
    let acc = crate::stats::compare(&serial, &run);
    Ok(ComparisonRow {
        cores: cfg_par.system.cores,
        quantum_ns: cfg_par.quantum / crate::sim::time::NS,
        speedup,
        sim_time_error: acc.sim_time_error,
        miss_rate_err_pp: [acc.l1i_pp, acc.l1d_pp, acc.l2_pp, acc.l3_pp],
        checksum_match: acc.checksum_match,
        serial,
        run,
    })
}
