//! The experiment harness: run driver, sweeps and figure/table
//! regeneration (one entry per paper table/figure, DESIGN.md §5).

pub mod figures;
pub mod sweep;
pub mod tables;

use anyhow::{anyhow, Result};

use crate::config::{Mode, RunConfig};
use crate::cpu::CpuModel;
use crate::pdes::{run_parallel, run_serial, run_virtual, HostModel, RunResult};
use crate::ruby::{build_atomic_system, build_system};
use crate::runtime::Runtime;
use crate::workload::{app_by_name, Workload};

/// Produce the workload for a run: synthetic traffic when `--traffic`
/// selects a spec (docs/TRAFFIC.md), else the app's artifact path when
/// available, bit-exact procedural fallback otherwise.
pub fn make_workload(cfg: &RunConfig) -> Result<Workload> {
    if let Some(arg) = &cfg.traffic {
        let spec = crate::spec::traffic::resolve(arg)
            .map_err(|e| anyhow!("{e}"))?;
        spec.validate().map_err(|e| anyhow!("{e}"))?;
        return Ok(crate::workload::traffic_workload(
            &spec,
            cfg.system.cores,
            cfg.ops_per_core,
        ));
    }
    let app = app_by_name(&cfg.app)
        .ok_or_else(|| anyhow!("unknown app '{}'", cfg.app))?;
    let dir = Runtime::default_dir();
    if Runtime::artifacts_available(&dir)
        && cfg.ops_per_core <= crate::runtime::TRACE_N
    {
        let rt = Runtime::new(dir)?;
        return crate::runtime::artifact_workload(
            &rt,
            app,
            cfg.system.cores,
            cfg.ops_per_core,
            cfg.seed,
        );
    }
    Ok(app.generate(cfg.system.cores, cfg.ops_per_core, cfg.seed))
}

/// Execute one run end to end.
pub fn run_once(cfg: &RunConfig) -> Result<RunResult> {
    let workload = make_workload(cfg)?;
    run_with_workload(cfg, &workload)
}

/// Execute one run with a pre-built workload (so sweeps reuse traces).
pub fn run_with_workload(cfg: &RunConfig, workload: &Workload) -> Result<RunResult> {
    // Surface platform mistakes as errors (with the spec's actionable
    // hints) before elaboration would panic on them.
    cfg.spec()
        .validate()
        .map_err(|e| anyhow!("{e}"))?;
    if !cfg.cpu_model.is_timing() {
        anyhow::ensure!(
            cfg.mode == Mode::Serial,
            "atomic/kvm CPU models run on the serial kernel only (Table 1)"
        );
        let (machine, _mem) = build_atomic_system(
            cfg,
            workload,
            cfg.cpu_model == CpuModel::Kvm,
        );
        return Ok(run_serial(machine, cfg.max_ticks));
    }
    let built = build_system(cfg, workload);
    Ok(match cfg.mode {
        Mode::Serial => run_serial(built.machine, cfg.max_ticks),
        Mode::Parallel => run_parallel(built.machine, cfg.max_ticks),
        Mode::Virtual => run_virtual(built.machine, cfg.max_ticks),
    })
}

/// Serial reference + virtual-parallel run + host-model speedup — the
/// measurement kernel behind every figure (DESIGN.md §3 substitution).
pub struct ComparisonRow {
    pub cores: usize,
    pub quantum_ns: u64,
    pub speedup: f64,
    pub sim_time_error: f64,
    pub miss_rate_err_pp: [f64; 4],
    pub checksum_match: bool,
    pub serial: RunResult,
    pub run: RunResult,
}

/// Run serial reference vs PDES (virtual by default; threaded if asked)
/// and compute speedup + accuracy.
pub fn compare_modes(
    cfg_serial: &RunConfig,
    cfg_par: &RunConfig,
    host: &mut HostModel,
) -> Result<ComparisonRow> {
    let workload = make_workload(cfg_serial)?;
    let serial = run_with_workload(cfg_serial, &workload)?;
    let run = run_with_workload(cfg_par, &workload)?;

    host.calibrate_cost(&serial);
    // Barrier cost scales with participating threads (N cores + 1).
    host.barrier_cost_ns = 500.0 + 25.0 * (cfg_par.system.cores + 1) as f64;
    let speedup = match cfg_par.mode {
        Mode::Parallel => {
            serial.host_ns as f64 / run.host_ns.max(1) as f64
        }
        _ => {
            let work = run.work.as_ref().expect("virtual run records work");
            host.speedup(serial.events, work)
        }
    };
    let acc = crate::stats::compare(&serial, &run);
    Ok(ComparisonRow {
        cores: cfg_par.system.cores,
        quantum_ns: cfg_par.quantum / crate::sim::time::NS,
        speedup,
        sim_time_error: acc.sim_time_error,
        miss_rate_err_pp: [acc.l1i_pp, acc.l1d_pp, acc.l2_pp, acc.l3_pp],
        checksum_match: acc.checksum_match,
        serial,
        run,
    })
}
