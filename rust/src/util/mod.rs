//! Small self-contained utilities (the build environment is offline, so
//! CLI parsing, JSON emission and the property-test driver are in-tree).

pub mod cli;
pub mod json;
pub mod padded;
pub mod prop;

pub use padded::CachePadded;
