//! Cache-line padding for per-domain hot words.
//!
//! The threaded kernel keeps per-domain scalars (`next_ticks`, `loads`)
//! in dense `Vec`s — eight `AtomicU64`s share one 64-byte line, so eight
//! threads publishing their horizons at a border ping-pong the same line
//! (false sharing). [`CachePadded`] gives each element its own line(s):
//! 128-byte alignment covers the adjacent-line prefetcher on modern x86
//! (pairs of lines move together) and is what crossbeam settled on for
//! the same reason.
//!
//! The wrapper is deliberately tiny: `Deref`/`DerefMut` make
//! `padded[i].store(..)` read exactly like the unpadded code it replaces.

use std::ops::{Deref, DerefMut};

/// Pads and aligns `T` to 128 bytes so two instances never share a cache
/// line (or an adjacent-line prefetch pair).
#[derive(Default, Debug)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub fn new(value: T) -> Self {
        CachePadded { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

    #[test]
    fn elements_live_on_distinct_lines() {
        assert_eq!(std::mem::align_of::<CachePadded<AtomicU64>>(), 128);
        assert!(std::mem::size_of::<CachePadded<AtomicU64>>() >= 128);
        let v: Vec<CachePadded<AtomicU64>> =
            (0..4).map(|_| CachePadded::new(AtomicU64::new(0))).collect();
        let a = &*v[0] as *const AtomicU64 as usize;
        let b = &*v[1] as *const AtomicU64 as usize;
        assert!(b - a >= 128, "adjacent elements {a:#x}/{b:#x} share a line");
    }

    #[test]
    fn deref_reads_like_the_inner_type() {
        let p = CachePadded::new(AtomicU64::new(7));
        p.store(9, Relaxed);
        assert_eq!(p.load(Relaxed), 9);
        assert_eq!(p.into_inner().into_inner(), 9);
    }
}
