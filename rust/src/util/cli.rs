//! Minimal `--flag value` / `--flag` argument parser.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: Option<String>,
    /// Non-flag tokens after the subcommand (e.g. `sweep run`'s verb).
    pub rest: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags present without a value.
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let name = name.to_string();
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name, v);
                    }
                    _ => out.switches.push(name),
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.rest.push(tok);
            }
        }
        out
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = args("run --app stream --cores 8 --json");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get_str("app", "x"), "stream");
        assert_eq!(a.get_usize("cores", 1), 8);
        assert!(a.has("json"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn trailing_positionals_land_in_rest() {
        let a = args("sweep run --spec quick --shard 0/2");
        assert_eq!(a.command.as_deref(), Some("sweep"));
        assert_eq!(a.rest, vec!["run".to_string()]);
        assert_eq!(a.get_str("spec", "x"), "quick");
        assert_eq!(a.get_str("shard", "x"), "0/2");
        assert!(args("run").rest.is_empty());
    }

    #[test]
    fn defaults_apply() {
        let a = args("run");
        assert_eq!(a.get_u64("quantum-ns", 16), 16);
    }
}
