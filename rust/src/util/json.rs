//! Tiny JSON object emitter (flat and nested objects of numbers/strings).

/// Incremental JSON object builder.
#[derive(Default)]
pub struct JsonObj {
    fields: Vec<(String, String)>,
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format a float the way JSON expects (no NaN/inf).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.fields.push((k.to_string(), format!("\"{}\"", escape(v))));
        self
    }

    pub fn u64(mut self, k: &str, v: u64) -> Self {
        self.fields.push((k.to_string(), v.to_string()));
        self
    }

    pub fn f64(mut self, k: &str, v: f64) -> Self {
        self.fields.push((k.to_string(), fmt_f64(v)));
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.fields.push((k.to_string(), v.to_string()));
        self
    }

    pub fn raw(mut self, k: &str, v: String) -> Self {
        self.fields.push((k.to_string(), v));
        self
    }

    pub fn obj(self, k: &str, v: JsonObj) -> Self {
        let s = v.build();
        self.raw(k, s)
    }

    pub fn build(&self) -> String {
        let inner: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("\"{}\": {}", escape(k), v))
            .collect();
        format!("{{{}}}", inner.join(", "))
    }
}

/// Render a list of raw JSON values.
pub fn json_array<I: IntoIterator<Item = String>>(items: I) -> String {
    let v: Vec<String> = items.into_iter().collect();
    format!("[{}]", v.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_flat_object() {
        let j = JsonObj::new().str("a", "x\"y").u64("b", 7).f64("c", 1.5).build();
        assert_eq!(j, "{\"a\": \"x\\\"y\", \"b\": 7, \"c\": 1.5}");
    }

    #[test]
    fn nested_and_array() {
        let j = JsonObj::new().obj("o", JsonObj::new().bool("k", true)).build();
        assert_eq!(j, "{\"o\": {\"k\": true}}");
        assert_eq!(json_array(["1".into(), "2".into()]), "[1, 2]");
    }

    #[test]
    fn non_finite_is_null() {
        let j = JsonObj::new().f64("x", f64::NAN).build();
        assert_eq!(j, "{\"x\": null}");
    }
}
