//! Minimal property-test driver (offline stand-in for proptest).
//!
//! Deterministic pseudo-random case generation from the same squares32
//! CBRNG the workload generator uses; failures report the case index so
//! they reproduce exactly.

use crate::workload::gen::{squares32, SQUARES_KEY};

/// Deterministic case generator.
pub struct Gen {
    ctr: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { ctr: seed.wrapping_mul(0x9E3779B97F4A7C15) }
    }

    pub fn u32(&mut self) -> u32 {
        self.ctr = self.ctr.wrapping_add(1);
        squares32(self.ctr, SQUARES_KEY)
    }

    pub fn u64(&mut self) -> u64 {
        ((self.u32() as u64) << 32) | self.u32() as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.u64() % (hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.u32() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range_usize(0, items.len() - 1)]
    }
}

/// Run `cases` deterministic property cases; panics with the case index on
/// the first failure.
pub fn check<F: FnMut(&mut Gen, usize)>(name: &str, cases: usize, mut f: F) {
    for i in 0..cases {
        let mut g = Gen::new(0xC0FFEE ^ (i as u64));
        // A panic inside f is the failure signal; annotate with the index.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut g, i),
        ));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {i}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..16 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.range_u64(10, 20);
            assert!((10..=20).contains(&v));
        }
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        check("always-fails", 3, |_, _| panic!("boom"));
    }
}
