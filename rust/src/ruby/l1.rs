//! Private L1 cache controller (RN-F leaf; one instance each for I and D).
//!
//! Policy (DESIGN.md §6 simplifications, kept identical in serial and
//! parallel runs so accuracy comparisons are apples-to-apples):
//!
//! * Loads allocate; fills install in `Shared` state — the L2 below is the
//!   per-core coherence point, so L1 lines are never dirty.
//! * Stores are write-through-invalidate: the local copy is invalidated and
//!   the store forwarded to the L2, which obtains write permission. This
//!   removes all L1 transient states while preserving per-core program
//!   order (later loads miss to the L2, which has the new data).
//! * Back-invalidations/downgrades from the L2 (`SnpUnique`/`SnpShared`)
//!   are fire-and-forget: nothing here is ever dirty.

use rustc_hash::FxHashMap;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::mem::{CacheArray, LineState};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

use super::inbox::{OutLink, SharedInbox};
use super::msg::{MsgKind, RubyMsg};

/// An outstanding fill request for one line.
struct LineMshr {
    /// Transaction id of the ReadShared sent to the L2.
    req_txn: u64,
    /// Loads waiting for the fill.
    waiters: Vec<RubyMsg>,
}

/// Inbox buffer indices (fixed by the topology builder).
pub const L1_BUF_FROM_SEQ: usize = 0;
pub const L1_BUF_FROM_L2: usize = 1;

pub struct L1Ctrl {
    name: String,
    array: CacheArray,
    inbox: SharedInbox,
    to_l2: OutLink,
    to_seq: OutLink,
    /// Tag/data access latency charged on hit responses.
    latency: Tick,
    /// Pending load misses: line -> active fill request.
    mshr: FxHashMap<u64, LineMshr>,
    /// Requests superseded by a later store to the same line, keyed by the
    /// fill's transaction id: their waiters are answered with the fill data
    /// but the line is NOT installed (the store made it stale), and later
    /// loads issue a fresh request ordered after the store at the L2.
    stale: FxHashMap<u64, Vec<RubyMsg>>,
    // stats
    load_hits: u64,
    load_misses: u64,
    store_lookups: u64,
    mshr_merges: u64,
    /// Reusable wakeup drain buffer (perf: no alloc per wakeup).
    scratch: Vec<RubyMsg>,
}

impl L1Ctrl {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        size_bytes: u64,
        assoc: usize,
        line_bytes: u64,
        latency: Tick,
        inbox: SharedInbox,
        to_l2: OutLink,
        to_seq: OutLink,
    ) -> Self {
        L1Ctrl {
            name,
            array: CacheArray::new(size_bytes, assoc, line_bytes),
            inbox,
            to_l2,
            to_seq,
            latency,
            mshr: FxHashMap::default(),
            stale: FxHashMap::default(),
            load_hits: 0,
            load_misses: 0,
            store_lookups: 0,
            mshr_merges: 0,
            scratch: Vec::new(),
        }
    }

    fn on_seq_req(&mut self, msg: RubyMsg, is_store: bool, ctx: &mut Ctx) {
        let line = self.array.line_addr(msg.addr);
        if is_store {
            // Write-through-update: refresh a present copy in place
            // (no-write-allocate on miss), always defer ordering to the L2.
            // A pending fill for the same line must not install stale data
            // over the store -> squash it.
            self.store_lookups += 1;
            if let Some(l) = self.array.access(line) {
                l.data = msg.value;
            }
            // A pending fill is now stale: retire it to the stale table so
            // its waiters (issued before this store) still complete, while
            // loads issued after the store request fresh data.
            if let Some(m) = self.mshr.remove(&line) {
                self.stale.insert(m.req_txn, m.waiters);
            }
            let fwd = RubyMsg {
                src: ctx.self_id(),
                dst: self.to_l2.consumer,
                ..msg
            };
            let ok = self.to_l2.send(ctx, fwd, 0);
            debug_assert!(ok, "L1->L2 buffers are unbounded");
            return;
        }
        // Load path.
        if let Some(l) = self.array.access(line) {
            self.load_hits += 1;
            let value = l.data;
            let resp = msg.respond(MsgKind::SeqResp, ctx.self_id(), value);
            let ok = self.to_seq.send(ctx, resp, self.latency);
            debug_assert!(ok);
            return;
        }
        self.load_misses += 1;
        if let Some(m) = self.mshr.get_mut(&line) {
            self.mshr_merges += 1;
            m.waiters.push(msg);
            return;
        }
        self.mshr
            .insert(line, LineMshr { req_txn: msg.txn, waiters: vec![msg] });
        let req = RubyMsg {
            kind: MsgKind::ReadShared,
            addr: line,
            value: 0,
            src: ctx.self_id(),
            dst: self.to_l2.consumer,
            txn: msg.txn,
            core: msg.core,
            issued: msg.issued,
        };
        let ok = self.to_l2.send(ctx, req, 0);
        debug_assert!(ok);
    }

    fn on_comp_data(&mut self, msg: RubyMsg, ctx: &mut Ctx) {
        let line = msg.addr;
        // Fill for a store-superseded request: answer its waiters, but do
        // not install the (stale) line.
        if let Some(waiters) = self.stale.remove(&msg.txn) {
            for w in waiters {
                let resp = w.respond(MsgKind::SeqResp, ctx.self_id(), msg.value);
                let ok = self.to_seq.send(ctx, resp, self.latency);
                debug_assert!(ok);
            }
            return;
        }
        // L1 copies are always Shared (never writable) — the L2 holds the
        // real coherence state.
        self.array.allocate(line, LineState::Shared, msg.value);
        if let Some(m) = self.mshr.remove(&line) {
            debug_assert_eq!(m.req_txn, msg.txn, "fill/request mismatch");
            for w in m.waiters {
                let resp = w.respond(MsgKind::SeqResp, ctx.self_id(), msg.value);
                let ok = self.to_seq.send(ctx, resp, self.latency);
                debug_assert!(ok);
            }
        }
    }

    fn on_snoop(&mut self, msg: RubyMsg, invalidate: bool) {
        let line = self.array.line_addr(msg.addr);
        if invalidate {
            self.array.invalidate(line);
        } else if let Some(l) = self.array.peek_mut(line) {
            l.state = LineState::Shared;
        }
    }
}

impl Component for L1Ctrl {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::ConsumerWakeup => {
                let mut ready = std::mem::take(&mut self.scratch);
                super::inbox::drain_for_wakeup_into(&self.inbox, ctx, &mut ready);
                for msg in ready.drain(..) {
                    match msg.kind {
                        MsgKind::SeqReq { is_store } => {
                            self.on_seq_req(msg, is_store, ctx)
                        }
                        MsgKind::CompData { .. } => self.on_comp_data(msg, ctx),
                        // Store ack from L2 -> forward to sequencer.
                        MsgKind::Comp => {
                            let resp = RubyMsg {
                                src: ctx.self_id(),
                                dst: self.to_seq.consumer,
                                ..msg
                            };
                            let ok = self.to_seq.send(ctx, resp, 0);
                            debug_assert!(ok);
                        }
                        MsgKind::SnpUnique => self.on_snoop(msg, true),
                        MsgKind::SnpShared => self.on_snoop(msg, false),
                        other => panic!("{}: unexpected msg {other:?}", self.name),
                    }
                }
                self.scratch = ready;
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    /// Border-ordered handoff (`--inbox-order border`): merge the
    /// cross-domain deliveries staged for this inbox during the closed
    /// window, in canonical order (DESIGN.md §6).
    fn border_merge(&mut self, ctx: &mut Ctx) {
        super::inbox::merge_staged_for_border(&self.inbox, ctx);
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("hits", self.array.hits);
        out.add_u64("misses", self.array.misses);
        out.add("miss_rate", self.array.miss_rate());
        out.add_u64("load_hits", self.load_hits);
        out.add_u64("load_misses", self.load_misses);
        out.add_u64("store_lookups", self.store_lookups);
        out.add_u64("mshr_merges", self.mshr_merges);
    }

    fn save_state(&self, w: &mut StateWriter) {
        self.array.save_ckpt(w);
        self.inbox.lock().unwrap().save_ckpt(w);
        let mut mshr: Vec<(&u64, &LineMshr)> = self.mshr.iter().collect();
        mshr.sort_unstable_by_key(|&(&line, _)| line);
        w.usize(mshr.len());
        for (&line, m) in mshr {
            w.u64(line);
            w.u64(m.req_txn);
            w.usize(m.waiters.len());
            for msg in &m.waiters {
                w.msg(msg);
            }
        }
        let mut stale: Vec<(&u64, &Vec<RubyMsg>)> = self.stale.iter().collect();
        stale.sort_unstable_by_key(|&(&txn, _)| txn);
        w.usize(stale.len());
        for (&txn, waiters) in stale {
            w.u64(txn);
            w.usize(waiters.len());
            for msg in waiters {
                w.msg(msg);
            }
        }
        w.u64(self.load_hits);
        w.u64(self.load_misses);
        w.u64(self.store_lookups);
        w.u64(self.mshr_merges);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.array.restore_ckpt(r)?;
        self.inbox.lock().unwrap().restore_ckpt(r)?;
        self.mshr.clear();
        for _ in 0..r.usize()? {
            let line = r.u64()?;
            let req_txn = r.u64()?;
            let mut waiters = Vec::new();
            for _ in 0..r.usize()? {
                waiters.push(r.msg()?);
            }
            self.mshr.insert(line, LineMshr { req_txn, waiters });
        }
        self.stale.clear();
        for _ in 0..r.usize()? {
            let txn = r.u64()?;
            let mut waiters = Vec::new();
            for _ in 0..r.usize()? {
                waiters.push(r.msg()?);
            }
            self.stale.insert(txn, waiters);
        }
        self.load_hits = r.u64()?;
        self.load_misses = r.u64()?;
        self.store_lookups = r.u64()?;
        self.mshr_merges = r.u64()?;
        Ok(())
    }
}
