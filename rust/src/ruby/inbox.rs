//! Thread-safe Ruby message passing — the heart of the paper's §4.2.
//!
//! Every Consumer owns ONE [`SharedInbox`]: a single mutex protecting *all*
//! of its input [`MessageBuffer`]s. This is exactly the paper's *shared
//! wakeup mutex* (Fig. 5a): senders from any domain serialise against each
//! other and against the consumer's wakeup drain on the same lock.
//!
//! Two deliberate refinements over gem5's C++ structure (documented in
//! DESIGN.md §6):
//!
//! * The consumer holds the lock only while draining ready messages, never
//!   while *processing* them — so no lock is ever held while acquiring
//!   another consumer's inbox, and the cross-thread lock graph has no
//!   cycles by construction.
//! * Bi-directional router links still go through [`super::throttle`]
//!   objects (Fig. 5c): the throttle is the bandwidth model, and it keeps
//!   every domain-crossing link uni-directional exactly as in the paper.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{Arc, Mutex};

use crate::sim::component::Ctx;
use crate::sim::event::{prio, EventKind};
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

use super::msg::RubyMsg;

/// Heap entry ordered by (arrival, seq).
struct Entry {
    arrival: Tick,
    seq: u64,
    msg: RubyMsg,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        (self.arrival, self.seq) == (other.arrival, other.seq)
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.arrival, self.seq).cmp(&(other.arrival, other.seq))
    }
}

/// One buffered link end: a priority queue of in-transit messages ordered by
/// arrival time (gem5 Ruby's MessageBuffer, §3.4).
pub struct MessageBuffer {
    heap: BinaryHeap<Reverse<Entry>>,
    /// Slot limit; `usize::MAX` = unbounded (gem5 default).
    capacity: usize,
    next_seq: u64,
    // stats (read via Inbox::stats_sum)
    pub enqueued: u64,
    pub peak: usize,
}

impl MessageBuffer {
    pub fn new(capacity: usize) -> Self {
        MessageBuffer {
            heap: BinaryHeap::new(),
            capacity,
            next_seq: 0,
            enqueued: 0,
            peak: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn has_slot(&self) -> bool {
        self.heap.len() < self.capacity
    }

    fn push(&mut self, arrival: Tick, msg: RubyMsg) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Entry { arrival, seq, msg }));
        self.enqueued += 1;
        self.peak = self.peak.max(self.heap.len());
    }

    /// Direct enqueue at an absolute arrival time. Test/inspection hook —
    /// production senders go through [`OutLink::send`], which also handles
    /// capacity and consumer wakeup.
    pub fn push_for_test(&mut self, arrival: Tick, msg: RubyMsg) {
        self.push(arrival, msg);
    }

    fn pop_ready(&mut self, now: Tick) -> Option<RubyMsg> {
        match self.heap.peek() {
            Some(Reverse(e)) if e.arrival <= now => {
                Some(self.heap.pop().unwrap().0.msg)
            }
            _ => None,
        }
    }

    fn next_arrival(&self) -> Option<Tick> {
        self.heap.peek().map(|Reverse(e)| e.arrival)
    }
}

/// All input buffers of one consumer, behind its shared wakeup mutex.
pub struct Inbox {
    pub bufs: Vec<MessageBuffer>,
    /// Earliest tick a ConsumerWakeup event is already scheduled for
    /// (`Tick::MAX` = none). Senders skip scheduling when an
    /// earlier-or-equal wakeup is pending — a large event-count reduction
    /// on bursty consumers (§Perf L3.1).
    pending_wakeup: Tick,
}

impl Inbox {
    /// Sender-side dedup: record a message arriving at `arrival`; returns
    /// true iff the caller must schedule a wakeup event.
    pub fn note_send(&mut self, arrival: Tick) -> bool {
        if arrival < self.pending_wakeup {
            self.pending_wakeup = arrival;
            true
        } else {
            false
        }
    }

    /// Consumer-side: call at the start of a wakeup event firing at `now`.
    /// Consumes the pending slot this event occupied (later-scheduled
    /// wakeups stay tracked).
    pub fn begin_wakeup(&mut self, now: Tick) {
        if self.pending_wakeup <= now {
            self.pending_wakeup = Tick::MAX;
        }
    }

    /// Consumer-side: call after processing; if messages remain whose
    /// arrival precedes any tracked wakeup, returns the tick the consumer
    /// must self-schedule a wakeup for (and tracks it).
    pub fn arm(&mut self) -> Option<Tick> {
        match self.next_arrival() {
            Some(t) if t < self.pending_wakeup => {
                self.pending_wakeup = t;
                Some(t)
            }
            _ => None,
        }
    }
    /// Earliest ready message across all buffers.
    pub fn pop_ready(&mut self, now: Tick) -> Option<RubyMsg> {
        let (bi, _) = self
            .bufs
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.next_arrival().map(|a| (i, a)))
            .min_by_key(|&(_, a)| a)?;
        self.bufs[bi].pop_ready(now)
    }

    /// Drain every message with `arrival <= now`, in global arrival order.
    pub fn drain_ready(&mut self, now: Tick) -> Vec<RubyMsg> {
        let mut out = Vec::new();
        while let Some(m) = self.pop_ready(now) {
            out.push(m);
        }
        out
    }

    /// Earliest pending arrival (ready or not).
    pub fn next_arrival(&self) -> Option<Tick> {
        self.bufs.iter().filter_map(|b| b.next_arrival()).min()
    }

    pub fn total_pending(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum()
    }
}

/// The consumer's inbox handle: ONE mutex for all input buffers = the
/// paper's shared wakeup mutex.
pub type SharedInbox = Arc<Mutex<Inbox>>;

pub fn new_inbox(buffer_capacities: &[usize]) -> SharedInbox {
    Arc::new(Mutex::new(Inbox {
        bufs: buffer_capacities
            .iter()
            .map(|&c| MessageBuffer::new(c))
            .collect(),
        pending_wakeup: Tick::MAX,
    }))
}

/// Standard consumer wakeup bracket: drain all ready messages into the
/// caller's reusable scratch buffer (§Perf L3.2 — no per-wakeup
/// allocation), re-arm for the next future arrival, and schedule that
/// wakeup via `ctx`.
pub fn drain_for_wakeup_into(
    inbox: &SharedInbox,
    ctx: &mut Ctx,
    scratch: &mut Vec<RubyMsg>,
) {
    scratch.clear();
    let rearm = {
        let mut ib = inbox.lock().unwrap();
        ib.begin_wakeup(ctx.now());
        while let Some(m) = ib.pop_ready(ctx.now()) {
            scratch.push(m);
        }
        ib.arm()
    };
    if let Some(t) = rearm {
        ctx.schedule_abs_prio(
            t,
            ctx.self_id(),
            EventKind::ConsumerWakeup,
            prio::DEFAULT,
        );
    }
}

/// Allocating variant of [`drain_for_wakeup_into`].
pub fn drain_for_wakeup(inbox: &SharedInbox, ctx: &mut Ctx) -> Vec<RubyMsg> {
    let mut v = Vec::new();
    drain_for_wakeup_into(inbox, ctx, &mut v);
    v
}

/// Sender-side handle to one input buffer of a (possibly foreign-domain)
/// consumer.
#[derive(Clone)]
pub struct OutLink {
    pub inbox: SharedInbox,
    /// Index of our buffer within the consumer's inbox.
    pub buf: usize,
    /// The consumer to wake.
    pub consumer: CompId,
    /// Link latency added to every message (`delta` in Fig. 3).
    pub latency: Tick,
}

impl OutLink {
    /// Enqueue `msg` arriving at `now + latency + extra_delay` and schedule
    /// the consumer's wakeup (postponed at domain borders by `ctx`).
    ///
    /// Returns `false` without enqueueing when the target buffer is full —
    /// the caller must retry later (router stall).
    #[must_use]
    pub fn send(&self, ctx: &mut Ctx, msg: RubyMsg, extra_delay: Tick) -> bool {
        let arrival = ctx.now() + self.latency + extra_delay;
        let need_wakeup = {
            let mut inbox = self.inbox.lock().unwrap();
            let buf = &mut inbox.bufs[self.buf];
            if !buf.has_slot() {
                return false;
            }
            buf.push(arrival, msg);
            inbox.note_send(arrival)
        }; // lock released before scheduling
        if need_wakeup {
            ctx.schedule_abs_prio(
                arrival,
                self.consumer,
                EventKind::ConsumerWakeup,
                prio::DEFAULT,
            );
        }
        true
    }

    /// Slots currently free in the target buffer.
    pub fn free_slots(&self) -> usize {
        let inbox = self.inbox.lock().unwrap();
        let b = &inbox.bufs[self.buf];
        if b.capacity == usize::MAX {
            usize::MAX
        } else {
            b.capacity - b.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ruby::msg::MsgKind;

    fn msg(addr: u64) -> RubyMsg {
        RubyMsg {
            kind: MsgKind::ReadShared,
            addr,
            value: 0,
            src: CompId(0),
            dst: CompId(1),
            txn: addr,
            core: 0,
            issued: 0,
        }
    }

    #[test]
    fn arrival_order_across_buffers() {
        let inbox = new_inbox(&[usize::MAX, usize::MAX]);
        {
            let mut ib = inbox.lock().unwrap();
            ib.bufs[0].push(30, msg(0xa));
            ib.bufs[1].push(10, msg(0xb));
            ib.bufs[0].push(20, msg(0xc));
        }
        let mut ib = inbox.lock().unwrap();
        let order: Vec<u64> =
            ib.drain_ready(100).iter().map(|m| m.addr).collect();
        assert_eq!(order, vec![0xb, 0xc, 0xa]);
    }

    #[test]
    fn not_ready_messages_stay() {
        let inbox = new_inbox(&[usize::MAX]);
        {
            let mut ib = inbox.lock().unwrap();
            ib.bufs[0].push(50, msg(1));
            ib.bufs[0].push(150, msg(2));
        }
        let mut ib = inbox.lock().unwrap();
        assert_eq!(ib.drain_ready(100).len(), 1);
        assert_eq!(ib.next_arrival(), Some(150));
        assert_eq!(ib.total_pending(), 1);
    }

    #[test]
    fn capacity_blocks() {
        let inbox = new_inbox(&[2]);
        {
            let mut ib = inbox.lock().unwrap();
            ib.bufs[0].push(1, msg(1));
            ib.bufs[0].push(2, msg(2));
            assert!(!ib.bufs[0].has_slot());
        }
    }

    #[test]
    fn same_arrival_fifo() {
        let inbox = new_inbox(&[usize::MAX]);
        {
            let mut ib = inbox.lock().unwrap();
            for i in 0..5 {
                ib.bufs[0].push(10, msg(i));
            }
        }
        let mut ib = inbox.lock().unwrap();
        let order: Vec<u64> =
            ib.drain_ready(10).iter().map(|m| m.addr).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }
}
