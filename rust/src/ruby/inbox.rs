//! Thread-safe Ruby message passing — the heart of the paper's §4.2 —
//! plus the deterministic border-ordered handoff (DESIGN.md §6,
//! docs/DETERMINISM.md).
//!
//! Every Consumer owns ONE [`SharedInbox`]: a single mutex protecting *all*
//! of its input [`MessageBuffer`]s. This is exactly the paper's *shared
//! wakeup mutex* (Fig. 5a): senders from any domain serialise against each
//! other and against the consumer's wakeup drain on the same lock.
//!
//! Two deliberate refinements over gem5's C++ structure (documented in
//! DESIGN.md §6):
//!
//! * The consumer holds the lock only while draining ready messages, never
//!   while *processing* them — so no lock is ever held while acquiring
//!   another consumer's inbox, and the cross-thread lock graph has no
//!   cycles by construction.
//! * Bi-directional router links still go through [`super::throttle`]
//!   objects (Fig. 5c): the throttle is the bandwidth model, and it keeps
//!   every domain-crossing link uni-directional exactly as in the paper.
//!
//! # The border-ordered handoff (`--inbox-order border`)
//!
//! Under [`InboxOrder::Host`] (the paper's behaviour) a cross-domain
//! [`OutLink::send`] pushes straight into the consumer's buffer, so whether
//! a concurrent consumer wakeup sees the message depends on host thread
//! interleaving — the §6 nondeterminism and the source of the paper's
//! ≤15 % timing deviation. Under [`InboxOrder::Border`] (the default)
//! cross-domain deliveries are instead *staged* inside the inbox
//! ([`Inbox::stage`]) and only become visible at the quantum border, when
//! [`Inbox::merge_staged`] inserts them in canonical
//! `(arrival, sender_domain, seq)` order and arms the consumer wakeup.
//! Three invariants make this deterministic (argued in
//! docs/DETERMINISM.md):
//!
//! 1. **Mid-window isolation** — a foreign-domain send mutates only the
//!    stage, never the buffers or the wakeup-dedup state, so everything a
//!    consumer can observe during a window is written exclusively by the
//!    thread executing its own domain.
//! 2. **Canonical merge** — the merge key is a pure function of the
//!    simulation (`arrival` and `sender_domain` from the model, `seq` from
//!    the sender's program order, which the claim list keeps single-threaded
//!    per window); the host order in which senders appended is sorted away.
//! 3. **Snapshot back-pressure** — capacity checks compare against the
//!    buffer length frozen at the last border plus the sender's *own*
//!    staged messages ([`Inbox::stage_has_slot`]), never against live state
//!    another thread is mutating.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, Mutex};

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::sched::InboxOrder;
use crate::sim::component::Ctx;
use crate::sim::event::{prio, EventKind};
use crate::sim::ids::CompId;
use crate::sim::shared::PdesStats;
use crate::sim::time::Tick;

use super::msg::{RubyMsg, StagedMsg};

/// Heap entry ordered by (arrival, seq).
struct Entry {
    arrival: Tick,
    seq: u64,
    msg: RubyMsg,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        (self.arrival, self.seq) == (other.arrival, other.seq)
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.arrival, self.seq).cmp(&(other.arrival, other.seq))
    }
}

/// One buffered link end: a priority queue of in-transit messages ordered by
/// arrival time (gem5 Ruby's MessageBuffer, §3.4).
pub struct MessageBuffer {
    heap: BinaryHeap<Reverse<Entry>>,
    /// Slot limit; `usize::MAX` = unbounded (gem5 default).
    capacity: usize,
    next_seq: u64,
    /// Occupancy snapshot taken at the last quantum border by
    /// [`Inbox::merge_staged`]. Border-mode cross-domain capacity checks
    /// read this instead of the live `heap.len()`, which the consumer's
    /// thread may be mutating concurrently (determinism invariant 3).
    border_len: usize,
    /// Per-sender-domain count of deliveries staged for this buffer in
    /// the current window (`domain → count`; maintained only for finite
    /// buffers, so [`Inbox::stage_has_slot`] is O(senders), not a scan of
    /// the whole stage). Cleared by the border merge.
    staged_by: Vec<(u32, usize)>,
    // stats (read via Inbox::stats_sum)
    pub enqueued: u64,
    pub peak: usize,
}

impl MessageBuffer {
    pub fn new(capacity: usize) -> Self {
        MessageBuffer {
            heap: BinaryHeap::new(),
            capacity,
            next_seq: 0,
            border_len: 0,
            staged_by: Vec::new(),
            enqueued: 0,
            peak: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn has_slot(&self) -> bool {
        self.heap.len() < self.capacity
    }

    fn push(&mut self, arrival: Tick, msg: RubyMsg) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Entry { arrival, seq, msg }));
        self.enqueued += 1;
        self.peak = self.peak.max(self.heap.len());
    }

    /// Direct enqueue at an absolute arrival time. Test/inspection hook —
    /// production senders go through [`OutLink::send`], which also handles
    /// capacity and consumer wakeup.
    pub fn push_for_test(&mut self, arrival: Tick, msg: RubyMsg) {
        self.push(arrival, msg);
    }

    fn pop_ready(&mut self, now: Tick) -> Option<RubyMsg> {
        match self.heap.peek() {
            Some(Reverse(e)) if e.arrival <= now => {
                Some(self.heap.pop().unwrap().0.msg)
            }
            _ => None,
        }
    }

    fn next_arrival(&self) -> Option<Tick> {
        self.heap.peek().map(|Reverse(e)| e.arrival)
    }
}

/// All input buffers of one consumer, behind its shared wakeup mutex.
pub struct Inbox {
    pub bufs: Vec<MessageBuffer>,
    /// Earliest tick a ConsumerWakeup event is already scheduled for
    /// (`Tick::MAX` = none). Senders skip scheduling when an
    /// earlier-or-equal wakeup is pending — a large event-count reduction
    /// on bursty consumers (§Perf L3.1).
    pending_wakeup: Tick,
    /// Border-mode staging area: one *run* of cross-domain deliveries per
    /// sending domain, each in that sender's program order (canonicalised
    /// by the k-way merge in [`Inbox::merge_staged`]). At most a handful
    /// of foreign domains ever feed one inbox, so the run map is a tiny
    /// linear-scan Vec; the run Vecs are cleared (capacity kept) by the
    /// merge, so steady state stages without allocating. Empty under
    /// [`InboxOrder::Host`].
    stage_runs: Vec<(u32, Vec<StagedMsg>)>,
    /// Total deliveries across all runs (avoids summing on every
    /// [`Inbox::staged_len`] / merge-emptiness check).
    stage_total: usize,
    /// Next global host-append index for the current window (feeds
    /// [`StagedMsg::host_idx`]); reset by the merge.
    stage_host_idx: u32,
    /// Reusable per-run cursor scratch for the k-way merge.
    merge_cursors: Vec<usize>,
}

impl Inbox {
    /// Border-mode capacity check for a cross-domain send from
    /// `sender_dom` into buffer `buf`: the border occupancy snapshot plus
    /// this sender's *own* staged deliveries must leave a slot. Other
    /// domains' in-window stagings are deliberately invisible — the
    /// verdict must not depend on host interleaving — so a buffer fed by
    /// several foreign domains can transiently exceed its capacity at the
    /// merge (none exists in any built-in topology — star, ring or mesh:
    /// every finite domain-crossing buffer has exactly one sender, see
    /// `ruby/topology.rs`).
    pub fn stage_has_slot(&self, buf: usize, sender_dom: u32) -> bool {
        let b = &self.bufs[buf];
        if b.capacity == usize::MAX {
            return true;
        }
        let own = b
            .staged_by
            .iter()
            .find(|(d, _)| *d == sender_dom)
            .map_or(0, |&(_, c)| c);
        b.border_len + own < b.capacity
    }

    /// Stage a cross-domain delivery for the next border merge
    /// (border-ordered handoff). The caller must have checked
    /// [`Inbox::stage_has_slot`].
    pub fn stage(&mut self, sender_dom: u32, buf: usize, arrival: Tick, msg: RubyMsg) {
        let b = &mut self.bufs[buf];
        if b.capacity != usize::MAX {
            match b.staged_by.iter_mut().find(|(d, _)| *d == sender_dom) {
                Some((_, c)) => *c += 1,
                None => b.staged_by.push((sender_dom, 1)),
            }
        }
        let host_idx = self.stage_host_idx;
        self.stage_host_idx += 1;
        self.stage_total += 1;
        let run = match self
            .stage_runs
            .iter_mut()
            .position(|(d, _)| *d == sender_dom)
        {
            Some(i) => &mut self.stage_runs[i].1,
            None => {
                self.stage_runs.push((sender_dom, Vec::new()));
                &mut self.stage_runs.last_mut().unwrap().1
            }
        };
        let seq = run.len() as u64;
        run.push(StagedMsg { arrival, seq, host_idx, buf, msg });
    }

    /// Deliveries currently staged for the next border merge.
    pub fn staged_len(&self) -> usize {
        self.stage_total
    }

    /// Border merge (the heart of `--inbox-order border`): insert every
    /// staged delivery into its buffer in canonical
    /// `(arrival, sender_domain, seq)` order, refresh the capacity
    /// snapshots, and return the wakeup tick the consumer must be
    /// scheduled for (if any; `border` is the tick of the closed window's
    /// end, so postponed wakeups land exactly where the host-order path's
    /// injector postponement would put them).
    ///
    /// Canonical order is produced by a k-way merge of the per-sender runs
    /// rather than a flat sort of the whole stage: each run is already in
    /// the sender's program order, so it only needs a (usually skipped)
    /// per-run sort by `(arrival, seq)` before its head competes in the
    /// merge. With k = foreign domains feeding this inbox (1 for every
    /// buffer in the built-in topologies) the border cost is O(total)
    /// instead of the old O(total log total) gather-and-sort.
    ///
    /// Must only be called while every producer is parked at the freeze
    /// barrier (the quiescent span of the border protocol) and before the
    /// owning domain publishes its post-drain `next_tick`.
    pub fn merge_staged(&mut self, border: Tick, stats: &PdesStats) -> Option<Tick> {
        let mut min_arrival = None;
        if self.stage_total > 0 {
            let total = self.stage_total as u64;
            self.stage_total = 0;
            self.stage_host_idx = 0;
            // A run leaves program order only when a later send overtakes
            // an earlier one in arrival time (shorter latency path); the
            // is-sorted scan makes the common monotonic window free.
            // Unstable sort is deterministic here: the key is unique
            // (seq never repeats within a run).
            for (_, run) in &mut self.stage_runs {
                if run
                    .windows(2)
                    .any(|w| (w[0].arrival, w[0].seq) > (w[1].arrival, w[1].seq))
                {
                    run.sort_unstable_by_key(|e| (e.arrival, e.seq));
                }
            }
            self.merge_cursors.clear();
            self.merge_cursors.resize(self.stage_runs.len(), 0);
            let (mut postponed, mut tpp, mut reordered) = (0u64, 0u64, 0u64);
            let mut pos = 0u32;
            loop {
                // Scan the run heads for the minimal canonical key. Keys
                // are globally unique (the sender domain is part of the
                // key), so the winner is independent of scan order.
                let mut best: Option<((Tick, u32, u64), usize)> = None;
                for (ri, (dom, run)) in self.stage_runs.iter().enumerate() {
                    if let Some(e) = run.get(self.merge_cursors[ri]) {
                        let key = (e.arrival, *dom, e.seq);
                        if best.is_none_or(|(k, _)| key < k) {
                            best = Some((key, ri));
                        }
                    }
                }
                let Some((_, ri)) = best else { break };
                let e = &self.stage_runs[ri].1[self.merge_cursors[ri]];
                self.merge_cursors[ri] += 1;
                if e.arrival < border {
                    // Visibility was deferred to the border: the same
                    // t_pp artefact the injector path counts (§3.1).
                    postponed += 1;
                    tpp += border - e.arrival;
                }
                // How many deliveries the host append order got wrong —
                // the nondeterminism the handoff neutralised this window.
                if e.host_idx != pos {
                    reordered += 1;
                }
                if min_arrival.is_none() {
                    min_arrival = Some(e.arrival);
                }
                self.bufs[e.buf].push(e.arrival, e.msg);
                pos += 1;
            }
            // Keep the run Vecs (and their capacity) for the next window.
            for (_, run) in &mut self.stage_runs {
                run.clear();
            }
            stats.inbox_staged.fetch_add(total, Relaxed);
            stats.inbox_reordered.fetch_add(reordered, Relaxed);
            stats.postponed.fetch_add(postponed, Relaxed);
            stats.tpp_sum.fetch_add(tpp, Relaxed);
        }
        // Refresh the snapshot even when nothing was staged: the consumer
        // drained buffers during the window, and senders judge capacity
        // against the border state.
        for b in &mut self.bufs {
            b.border_len = b.heap.len();
            b.staged_by.clear();
        }
        // Same convention as the host-order sender path: track the
        // arrival, schedule at the postponed effective tick.
        if let Some(a) = min_arrival {
            if self.note_send(a) {
                return Some(a.max(border));
            }
        }
        None
    }
    /// Sender-side dedup: record a message arriving at `arrival`; returns
    /// true iff the caller must schedule a wakeup event.
    pub fn note_send(&mut self, arrival: Tick) -> bool {
        if arrival < self.pending_wakeup {
            self.pending_wakeup = arrival;
            true
        } else {
            false
        }
    }

    /// Consumer-side: call at the start of a wakeup event firing at `now`.
    /// Consumes the pending slot this event occupied (later-scheduled
    /// wakeups stay tracked).
    pub fn begin_wakeup(&mut self, now: Tick) {
        if self.pending_wakeup <= now {
            self.pending_wakeup = Tick::MAX;
        }
    }

    /// Consumer-side: call after processing; if messages remain whose
    /// arrival precedes any tracked wakeup, returns the tick the consumer
    /// must self-schedule a wakeup for (and tracks it).
    pub fn arm(&mut self) -> Option<Tick> {
        match self.next_arrival() {
            Some(t) if t < self.pending_wakeup => {
                self.pending_wakeup = t;
                Some(t)
            }
            _ => None,
        }
    }
    /// Earliest ready message across all buffers.
    pub fn pop_ready(&mut self, now: Tick) -> Option<RubyMsg> {
        let (bi, _) = self
            .bufs
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.next_arrival().map(|a| (i, a)))
            .min_by_key(|&(_, a)| a)?;
        self.bufs[bi].pop_ready(now)
    }

    /// Drain every message with `arrival <= now`, in global arrival order.
    pub fn drain_ready(&mut self, now: Tick) -> Vec<RubyMsg> {
        let mut out = Vec::new();
        while let Some(m) = self.pop_ready(now) {
            out.push(m);
        }
        out
    }

    /// Earliest pending arrival (ready or not).
    pub fn next_arrival(&self) -> Option<Tick> {
        self.bufs.iter().filter_map(|b| b.next_arrival()).min()
    }

    pub fn total_pending(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum()
    }

    /// Checkpoint producer half for one consumer's inbox. Must run inside
    /// the quiescent span of a quantum border, *after* the border merge:
    /// the staging area is empty (asserted — a non-empty stage means the
    /// caller is snapshotting non-quiescent state) and every per-buffer
    /// capacity snapshot is fresh. In-transit messages are written in
    /// canonical `(arrival, seq)` order, so the bytes are invariant to the
    /// producing kernel. Buffer capacities are rebuilt from the topology,
    /// not serialized.
    pub fn save_ckpt(&self, w: &mut StateWriter) {
        assert_eq!(
            self.stage_total, 0,
            "inbox checkpoint outside the quiescent span: staged deliveries present"
        );
        w.usize(self.bufs.len());
        for b in &self.bufs {
            debug_assert!(
                b.staged_by.is_empty(),
                "stale staging counts at a border checkpoint"
            );
            let mut entries: Vec<&Entry> =
                b.heap.iter().map(|Reverse(e)| e).collect();
            entries.sort_unstable_by_key(|e| (e.arrival, e.seq));
            w.usize(entries.len());
            for e in entries {
                w.u64(e.arrival);
                w.u64(e.seq);
                w.msg(&e.msg);
            }
            w.u64(b.next_seq);
            w.usize(b.border_len);
            w.u64(b.enqueued);
            w.usize(b.peak);
        }
        w.u64(self.pending_wakeup);
    }

    /// Checkpoint restore half: overwrite a freshly built inbox (same
    /// topology, hence same buffer count and capacities) with the state
    /// written by [`Self::save_ckpt`].
    pub fn restore_ckpt(
        &mut self,
        r: &mut StateReader,
    ) -> Result<(), CkptError> {
        let n = r.usize()?;
        if n != self.bufs.len() {
            return Err(CkptError::Mismatch {
                what: "inbox buffer count".to_string(),
                expected: self.bufs.len().to_string(),
                found: n.to_string(),
            });
        }
        for b in &mut self.bufs {
            b.heap.clear();
            let k = r.usize()?;
            for _ in 0..k {
                let arrival = r.u64()?;
                let seq = r.u64()?;
                let msg = r.msg()?;
                b.heap.push(Reverse(Entry { arrival, seq, msg }));
            }
            b.next_seq = r.u64()?;
            b.border_len = r.usize()?;
            b.staged_by.clear();
            b.enqueued = r.u64()?;
            b.peak = r.usize()?;
        }
        self.stage_runs.clear();
        self.stage_total = 0;
        self.stage_host_idx = 0;
        self.pending_wakeup = r.u64()?;
        Ok(())
    }
}

/// The consumer's inbox handle: ONE mutex for all input buffers = the
/// paper's shared wakeup mutex.
pub type SharedInbox = Arc<Mutex<Inbox>>;

pub fn new_inbox(buffer_capacities: &[usize]) -> SharedInbox {
    Arc::new(Mutex::new(Inbox {
        bufs: buffer_capacities
            .iter()
            .map(|&c| MessageBuffer::new(c))
            .collect(),
        pending_wakeup: Tick::MAX,
        stage_runs: Vec::new(),
        stage_total: 0,
        stage_host_idx: 0,
        merge_cursors: Vec::new(),
    }))
}

/// Border hook shared by every Ruby consumer's
/// [`crate::sim::component::Component::border_merge`]: merge this inbox's
/// staged cross-domain deliveries in canonical order and schedule the
/// consumer wakeup the merge calls for. `ctx.now()` must be the border
/// tick (the closed window's end).
///
/// No-op under `--inbox-order host`: the border hooks also run when only
/// the crossbar's border-staged arbitration is active (`--xbar-arb
/// border`), and in that combination the host-order inbox path must stay
/// untouched — nothing is staged and the capacity snapshots are unused.
pub fn merge_staged_for_border(inbox: &SharedInbox, ctx: &mut Ctx) {
    if ctx.shared().policy.inbox_order != InboxOrder::Border {
        return;
    }
    let wake = {
        let mut ib = inbox.lock().unwrap();
        ib.merge_staged(ctx.now(), &ctx.shared().pdes)
    };
    if let Some(t) = wake {
        ctx.schedule_abs_prio(
            t,
            ctx.self_id(),
            EventKind::ConsumerWakeup,
            prio::DEFAULT,
        );
    }
}

/// Standard consumer wakeup bracket: drain all ready messages into the
/// caller's reusable scratch buffer (§Perf L3.2 — no per-wakeup
/// allocation), re-arm for the next future arrival, and schedule that
/// wakeup via `ctx`.
pub fn drain_for_wakeup_into(
    inbox: &SharedInbox,
    ctx: &mut Ctx,
    scratch: &mut Vec<RubyMsg>,
) {
    scratch.clear();
    let rearm = {
        let mut ib = inbox.lock().unwrap();
        ib.begin_wakeup(ctx.now());
        while let Some(m) = ib.pop_ready(ctx.now()) {
            scratch.push(m);
        }
        ib.arm()
    };
    if let Some(t) = rearm {
        ctx.schedule_abs_prio(
            t,
            ctx.self_id(),
            EventKind::ConsumerWakeup,
            prio::DEFAULT,
        );
    }
}

/// Allocating variant of [`drain_for_wakeup_into`].
pub fn drain_for_wakeup(inbox: &SharedInbox, ctx: &mut Ctx) -> Vec<RubyMsg> {
    let mut v = Vec::new();
    drain_for_wakeup_into(inbox, ctx, &mut v);
    v
}

/// Sender-side handle to one input buffer of a (possibly foreign-domain)
/// consumer.
#[derive(Clone)]
pub struct OutLink {
    pub inbox: SharedInbox,
    /// Index of our buffer within the consumer's inbox.
    pub buf: usize,
    /// The consumer to wake.
    pub consumer: CompId,
    /// Link latency added to every message (`delta` in Fig. 3).
    pub latency: Tick,
}

impl OutLink {
    /// Enqueue `msg` arriving at `now + latency + extra_delay` and schedule
    /// the consumer's wakeup (postponed at domain borders by `ctx`).
    ///
    /// Under the border-ordered handoff (`--inbox-order border`, the
    /// default), a *cross-domain* send stages the message instead: it
    /// becomes visible to the consumer only at the quantum border, merged
    /// in canonical `(arrival, sender_domain, seq)` order, and the wakeup
    /// is armed by the merge — so neither the buffers nor the wakeup-dedup
    /// state are touched from a foreign thread mid-window. Same-domain
    /// sends (and every send under `--inbox-order host`) take the paper's
    /// direct path.
    ///
    /// Returns `false` without enqueueing when the target buffer is full —
    /// the caller must retry later (router stall). In border mode the
    /// capacity verdict is judged against the border snapshot plus this
    /// sender's own staged messages (see [`Inbox::stage_has_slot`]), so it
    /// too is independent of host timing.
    #[must_use]
    pub fn send(&self, ctx: &mut Ctx, msg: RubyMsg, extra_delay: Tick) -> bool {
        let arrival = ctx.now() + self.latency + extra_delay;
        if ctx.shared().policy.inbox_order == InboxOrder::Border
            && ctx.shared().domain_of(self.consumer) != ctx.domain()
        {
            let sender_dom = ctx.domain().0;
            let staged = {
                let mut inbox = self.inbox.lock().unwrap();
                if inbox.stage_has_slot(self.buf, sender_dom) {
                    inbox.stage(sender_dom, self.buf, arrival, msg);
                    true
                } else {
                    false
                }
            };
            if staged {
                // One cross-domain delivery; postponement (t_pp) is
                // accounted at the merge, where the deferral is known.
                ctx.shared().pdes.cross_events.fetch_add(1, Relaxed);
            }
            return staged;
        }
        let need_wakeup = {
            let mut inbox = self.inbox.lock().unwrap();
            let buf = &mut inbox.bufs[self.buf];
            if !buf.has_slot() {
                return false;
            }
            buf.push(arrival, msg);
            inbox.note_send(arrival)
        }; // lock released before scheduling
        if need_wakeup {
            ctx.schedule_abs_prio(
                arrival,
                self.consumer,
                EventKind::ConsumerWakeup,
                prio::DEFAULT,
            );
        }
        true
    }

    /// Slots currently free in the target buffer, judged against the live
    /// occupancy (an inspection/debug hook — border-mode senders must not
    /// base decisions on it; [`OutLink::send`] applies the snapshot rule).
    pub fn free_slots(&self) -> usize {
        let inbox = self.inbox.lock().unwrap();
        let b = &inbox.bufs[self.buf];
        if b.capacity == usize::MAX {
            usize::MAX
        } else {
            b.capacity - b.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ruby::msg::MsgKind;

    fn msg(addr: u64) -> RubyMsg {
        RubyMsg {
            kind: MsgKind::ReadShared,
            addr,
            value: 0,
            src: CompId(0),
            dst: CompId(1),
            txn: addr,
            core: 0,
            issued: 0,
        }
    }

    #[test]
    fn arrival_order_across_buffers() {
        let inbox = new_inbox(&[usize::MAX, usize::MAX]);
        {
            let mut ib = inbox.lock().unwrap();
            ib.bufs[0].push(30, msg(0xa));
            ib.bufs[1].push(10, msg(0xb));
            ib.bufs[0].push(20, msg(0xc));
        }
        let mut ib = inbox.lock().unwrap();
        let order: Vec<u64> =
            ib.drain_ready(100).iter().map(|m| m.addr).collect();
        assert_eq!(order, vec![0xb, 0xc, 0xa]);
    }

    #[test]
    fn not_ready_messages_stay() {
        let inbox = new_inbox(&[usize::MAX]);
        {
            let mut ib = inbox.lock().unwrap();
            ib.bufs[0].push(50, msg(1));
            ib.bufs[0].push(150, msg(2));
        }
        let mut ib = inbox.lock().unwrap();
        assert_eq!(ib.drain_ready(100).len(), 1);
        assert_eq!(ib.next_arrival(), Some(150));
        assert_eq!(ib.total_pending(), 1);
    }

    #[test]
    fn capacity_blocks() {
        let inbox = new_inbox(&[2]);
        {
            let mut ib = inbox.lock().unwrap();
            ib.bufs[0].push(1, msg(1));
            ib.bufs[0].push(2, msg(2));
            assert!(!ib.bufs[0].has_slot());
        }
    }

    #[test]
    fn same_arrival_fifo() {
        let inbox = new_inbox(&[usize::MAX]);
        {
            let mut ib = inbox.lock().unwrap();
            for i in 0..5 {
                ib.bufs[0].push(10, msg(i));
            }
        }
        let mut ib = inbox.lock().unwrap();
        let order: Vec<u64> =
            ib.drain_ready(10).iter().map(|m| m.addr).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    // ---- border-ordered handoff -------------------------------------

    #[test]
    fn staged_messages_invisible_until_merge() {
        let stats = PdesStats::default();
        let inbox = new_inbox(&[usize::MAX]);
        let mut ib = inbox.lock().unwrap();
        ib.stage(1, 0, 10, msg(0xa));
        assert_eq!(ib.staged_len(), 1);
        assert!(ib.drain_ready(100).is_empty(), "stage must stay hidden");
        assert_eq!(ib.next_arrival(), None);
        let wake = ib.merge_staged(50, &stats);
        assert_eq!(wake, Some(50), "arrival 10 postponed to border 50");
        assert_eq!(ib.staged_len(), 0);
        let order: Vec<u64> =
            ib.drain_ready(100).iter().map(|m| m.addr).collect();
        assert_eq!(order, vec![0xa]);
        assert_eq!(stats.inbox_staged.load(Relaxed), 1);
        assert_eq!(stats.postponed.load(Relaxed), 1);
        assert_eq!(stats.tpp_sum.load(Relaxed), 40);
    }

    #[test]
    fn merge_is_canonical_not_host_order() {
        // A maximally skewed host: domain 2's whole window of sends is
        // appended before domain 1's, and domain 2's own sends arrive
        // out of tick order. The merge must sort it all back into
        // (arrival, sender_domain, seq) order.
        let stats = PdesStats::default();
        let inbox = new_inbox(&[usize::MAX]);
        let mut ib = inbox.lock().unwrap();
        ib.stage(2, 0, 30, msg(0xa));
        ib.stage(2, 0, 10, msg(0xb));
        ib.stage(1, 0, 10, msg(0xc));
        ib.stage(1, 0, 30, msg(0xd));
        ib.merge_staged(40, &stats);
        let order: Vec<u64> =
            ib.drain_ready(100).iter().map(|m| m.addr).collect();
        assert_eq!(
            order,
            vec![0xc, 0xb, 0xd, 0xa],
            "(10,d1) < (10,d2) < (30,d1) < (30,d2)"
        );
        assert_eq!(stats.inbox_staged.load(Relaxed), 4);
        assert!(
            stats.inbox_reordered.load(Relaxed) > 0,
            "the skewed host order must be counted as reordered"
        );
    }

    #[test]
    fn same_domain_staging_keeps_program_order() {
        let stats = PdesStats::default();
        let inbox = new_inbox(&[usize::MAX]);
        let mut ib = inbox.lock().unwrap();
        for i in 0..5 {
            ib.stage(3, 0, 20, msg(i));
        }
        ib.merge_staged(40, &stats);
        let order: Vec<u64> =
            ib.drain_ready(100).iter().map(|m| m.addr).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4], "seq preserves program order");
        assert_eq!(stats.inbox_reordered.load(Relaxed), 0);
    }

    #[test]
    fn kway_merge_interleaves_runs_and_resets_between_windows() {
        let stats = PdesStats::default();
        let inbox = new_inbox(&[usize::MAX]);
        let mut ib = inbox.lock().unwrap();
        // Three senders; domain 1's run needs its per-run sort first.
        ib.stage(3, 0, 15, msg(0x1));
        ib.stage(1, 0, 20, msg(0x2));
        ib.stage(2, 0, 15, msg(0x3));
        ib.stage(1, 0, 10, msg(0x4));
        ib.merge_staged(30, &stats);
        let order: Vec<u64> =
            ib.drain_ready(100).iter().map(|m| m.addr).collect();
        assert_eq!(
            order,
            vec![0x4, 0x3, 0x1, 0x2],
            "(10,d1) < (15,d2) < (15,d3) < (20,d1)"
        );
        assert_eq!(stats.inbox_reordered.load(Relaxed), 4);
        // The next window starts from clean run state: fresh seqs, fresh
        // host indices, and an empty stage.
        assert_eq!(ib.staged_len(), 0);
        ib.stage(2, 0, 205, msg(0xb));
        ib.stage(1, 0, 205, msg(0xa));
        ib.merge_staged(210, &stats);
        let order: Vec<u64> =
            ib.drain_ready(300).iter().map(|m| m.addr).collect();
        assert_eq!(order, vec![0xa, 0xb], "domain breaks the arrival tie");
        assert_eq!(stats.inbox_reordered.load(Relaxed), 4 + 2);
        assert_eq!(stats.inbox_staged.load(Relaxed), 6);
    }

    #[test]
    fn stage_capacity_is_border_snapshot_plus_own_stagings() {
        let stats = PdesStats::default();
        let inbox = new_inbox(&[2]);
        let mut ib = inbox.lock().unwrap();
        // Border snapshot starts at 0: two stagings fit, the third not.
        assert!(ib.stage_has_slot(0, 1));
        ib.stage(1, 0, 10, msg(1));
        assert!(ib.stage_has_slot(0, 1));
        ib.stage(1, 0, 11, msg(2));
        assert!(!ib.stage_has_slot(0, 1), "own stagings count");
        ib.merge_staged(16, &stats);
        // Snapshot now 2 (= capacity): nothing fits until a drain AND a
        // fresh border refresh the snapshot.
        assert!(!ib.stage_has_slot(0, 1));
        let _ = ib.drain_ready(100);
        assert!(!ib.stage_has_slot(0, 1), "live drain is invisible");
        ib.merge_staged(32, &stats);
        assert!(ib.stage_has_slot(0, 1), "border refresh frees the slots");
    }

    #[test]
    fn merge_arms_wakeup_only_when_needed() {
        let stats = PdesStats::default();
        let inbox = new_inbox(&[usize::MAX]);
        let mut ib = inbox.lock().unwrap();
        // Future arrival beyond the border keeps its exact tick.
        ib.stage(1, 0, 120, msg(1));
        assert_eq!(ib.merge_staged(50, &stats), Some(120));
        // A pending earlier-or-equal wakeup dedups the next merge.
        ib.stage(1, 0, 130, msg(2));
        assert_eq!(ib.merge_staged(60, &stats), None, "wakeup 120 covers it");
        // An empty merge is a pure snapshot refresh.
        assert_eq!(ib.merge_staged(70, &stats), None);
    }
}
