//! NoC router: routes Ruby messages to output links by final destination.
//!
//! Head-of-line semantics: one message is routed per wakeup pass; if the
//! output buffer is full the message stalls in the router and a retry
//! wakeup is scheduled one router cycle later (gem5 Garnet-like behaviour,
//! coarse-grained).
//!
//! Routers never link directly to a foreign-domain router: every
//! domain-crossing output goes through a [`super::throttle::Throttle`],
//! keeping cross-domain links uni-directional and the inbox lock graph
//! acyclic (paper Fig. 5b/5c).

use std::collections::VecDeque;

use rustc_hash::FxHashMap;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::ids::CompId;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

use super::inbox::{OutLink, SharedInbox};
use super::msg::RubyMsg;

pub struct Router {
    name: String,
    inbox: SharedInbox,
    outs: Vec<OutLink>,
    /// Final-destination component -> output link index.
    routes: FxHashMap<CompId, usize>,
    /// Fallback output (e.g. "towards the central router") when the
    /// destination is not in `routes`.
    default_out: Option<usize>,
    cycle: Tick,
    /// Messages that could not be forwarded (full output buffer).
    stalled: VecDeque<RubyMsg>,
    // stats
    routed: u64,
    stalls: u64,
}

impl Router {
    pub fn new(
        name: String,
        inbox: SharedInbox,
        outs: Vec<OutLink>,
        routes: FxHashMap<CompId, usize>,
        default_out: Option<usize>,
        cycle: Tick,
    ) -> Self {
        Router {
            name,
            inbox,
            outs,
            routes,
            default_out,
            cycle,
            stalled: VecDeque::new(),
            routed: 0,
            stalls: 0,
        }
    }

    fn out_for(&self, dst: CompId) -> usize {
        match self.routes.get(&dst) {
            Some(&i) => i,
            None => self
                .default_out
                .unwrap_or_else(|| panic!("{}: no route to {dst}", self.name)),
        }
    }

    /// Try to forward one message; true on success.
    fn forward(&mut self, msg: RubyMsg, ctx: &mut Ctx) -> bool {
        let out = self.out_for(msg.dst);
        if self.outs[out].send(ctx, msg, 0) {
            self.routed += 1;
            true
        } else {
            false
        }
    }
}

impl Component for Router {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::ConsumerWakeup => {
                {
                    let mut ib = self.inbox.lock().unwrap();
                    ib.begin_wakeup(ctx.now());
                }
                // First retry stalled messages (in order), then new ones.
                while let Some(msg) = self.stalled.pop_front() {
                    if !self.forward(msg, ctx) {
                        self.stalled.push_front(msg);
                        self.stalls += 1;
                        ctx.schedule_self(self.cycle, EventKind::ConsumerWakeup);
                        return;
                    }
                }
                loop {
                    let msg = {
                        let mut ib = self.inbox.lock().unwrap();
                        ib.pop_ready(ctx.now())
                    };
                    let Some(msg) = msg else { break };
                    if !self.forward(msg, ctx) {
                        self.stalled.push_back(msg);
                        self.stalls += 1;
                        ctx.schedule_self(self.cycle, EventKind::ConsumerWakeup);
                        return;
                    }
                }
                // Wakeup-dedup: re-arm for messages still in transit.
                let rearm = {
                    let mut ib = self.inbox.lock().unwrap();
                    ib.arm()
                };
                if let Some(t) = rearm {
                    ctx.schedule_abs(t, ctx.self_id(), EventKind::ConsumerWakeup);
                }
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    /// Border-ordered handoff (`--inbox-order border`): merge the
    /// cross-domain deliveries staged for this inbox during the closed
    /// window, in canonical order (DESIGN.md §6).
    fn border_merge(&mut self, ctx: &mut Ctx) {
        super::inbox::merge_staged_for_border(&self.inbox, ctx);
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("routed", self.routed);
        out.add_u64("stalls", self.stalls);
    }

    fn save_state(&self, w: &mut StateWriter) {
        self.inbox.lock().unwrap().save_ckpt(w);
        w.usize(self.stalled.len());
        for msg in &self.stalled {
            w.msg(msg);
        }
        w.u64(self.routed);
        w.u64(self.stalls);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.inbox.lock().unwrap().restore_ckpt(r)?;
        self.stalled.clear();
        for _ in 0..r.usize()? {
            self.stalled.push_back(r.msg()?);
        }
        self.routed = r.u64()?;
        self.stalls = r.u64()?;
        Ok(())
    }
}
