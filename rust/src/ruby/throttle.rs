//! Throttle: the link-bandwidth model — and the key to deadlock-free
//! bi-directional router links (paper Fig. 5c).
//!
//! Placed at each router output that crosses a domain border, the throttle
//! (a) rate-limits the link (control messages take one link cycle, data
//! messages one cycle per flit) and (b) splits every bi-directional
//! router↔router connection into two independent uni-directional links, so
//! the circular wait of Fig. 5b cannot form: a consumer's inbox mutex is
//! only ever taken while holding *no* other inbox mutex (see
//! [`super::inbox`]).

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

use super::inbox::{OutLink, SharedInbox};
use super::msg::RubyMsg;

pub struct Throttle {
    name: String,
    inbox: SharedInbox,
    out: OutLink,
    /// One link cycle (0.5 ns in Table 2).
    cycle: Tick,
    /// Link cycles charged for a data-carrying message (flits).
    data_flits: u64,
    /// The link is busy until this tick (bandwidth accounting).
    busy_until: Tick,
    /// Head-of-line message that found the target buffer full.
    stalled_msg: Option<RubyMsg>,
    // stats
    forwarded: u64,
    data_msgs: u64,
    stalls: u64,
}

impl Throttle {
    pub fn new(
        name: String,
        inbox: SharedInbox,
        out: OutLink,
        cycle: Tick,
        data_flits: u64,
    ) -> Self {
        Throttle {
            name,
            inbox,
            out,
            cycle,
            data_flits,
            busy_until: 0,
            stalled_msg: None,
            forwarded: 0,
            data_msgs: 0,
            stalls: 0,
        }
    }

    fn occupancy(&self, msg: &RubyMsg) -> Tick {
        if msg.kind.carries_data() {
            self.cycle * self.data_flits
        } else {
            self.cycle
        }
    }
}

impl Component for Throttle {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::ConsumerWakeup => {
                let now = ctx.now();
                {
                    let mut ib = self.inbox.lock().unwrap();
                    ib.begin_wakeup(now);
                }
                if now < self.busy_until {
                    // Link busy: look again when it frees up.
                    ctx.schedule_abs(
                        self.busy_until,
                        ctx.self_id(),
                        EventKind::ConsumerWakeup,
                    );
                    return;
                }
                // Head-of-line stalled message retries first.
                let msg = match self.stalled_msg.take() {
                    Some(m) => m,
                    None => {
                        let m = {
                            let mut ib = self.inbox.lock().unwrap();
                            ib.pop_ready(now)
                        };
                        let Some(m) = m else { return };
                        m
                    }
                };
                let occ = self.occupancy(&msg);
                if !self.out.send(ctx, msg, occ) {
                    // Target buffer full: keep the message, retry shortly.
                    self.stalls += 1;
                    self.stalled_msg = Some(msg);
                    ctx.schedule_self(self.cycle, EventKind::ConsumerWakeup);
                    return;
                }
                self.forwarded += 1;
                if msg.kind.carries_data() {
                    self.data_msgs += 1;
                }
                self.busy_until = now + occ;
                // More traffic pending? Come back when the link frees.
                // (Always re-schedule here: the busy window, not the
                // message arrival, gates the next forward.)
                let next = {
                    let mut ib = self.inbox.lock().unwrap();
                    ib.arm()
                };
                if let Some(next) = next {
                    ctx.schedule_abs(
                        self.busy_until.max(next),
                        ctx.self_id(),
                        EventKind::ConsumerWakeup,
                    );
                }
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    /// Border-ordered handoff (`--inbox-order border`): merge the
    /// cross-domain deliveries staged for this inbox during the closed
    /// window, in canonical order (DESIGN.md §6).
    fn border_merge(&mut self, ctx: &mut Ctx) {
        super::inbox::merge_staged_for_border(&self.inbox, ctx);
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("forwarded", self.forwarded);
        out.add_u64("data_msgs", self.data_msgs);
        out.add_u64("stalls", self.stalls);
    }

    fn save_state(&self, w: &mut StateWriter) {
        self.inbox.lock().unwrap().save_ckpt(w);
        w.u64(self.busy_until);
        match &self.stalled_msg {
            Some(msg) => {
                w.bool(true);
                w.msg(msg);
            }
            None => w.bool(false),
        }
        w.u64(self.forwarded);
        w.u64(self.data_msgs);
        w.u64(self.stalls);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.inbox.lock().unwrap().restore_ckpt(r)?;
        self.busy_until = r.u64()?;
        self.stalled_msg = if r.bool()? { Some(r.msg()?) } else { None };
        self.forwarded = r.u64()?;
        self.data_msgs = r.u64()?;
        self.stalls = r.u64()?;
        Ok(())
    }
}
