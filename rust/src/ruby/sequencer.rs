//! Sequencer: converts classic timing-protocol packets from the CPU into
//! Ruby messages for the L1s, and routes IO-range packets to the crossbar
//! (§3.4, Fig. 4 — the black↔blue protocol boundary).

use std::collections::VecDeque;

use rustc_hash::FxHashMap;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::proto::{Cmd, Packet};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::ids::CompId;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;
use crate::xbar::{Occupy, XbarState};

use super::inbox::{OutLink, SharedInbox};
use super::msg::{MsgKind, RubyMsg};

pub const SEQ_BUF_FROM_L1D: usize = 0;
pub const SEQ_BUF_FROM_L1I: usize = 1;

/// Marks instruction-fetch packets (routed to the L1I instead of the L1D):
/// the CPU sets `Packet::size` to this sentinel on ifetches.
pub const IFETCH_SIZE: u32 = 0xFFFF_FFFF;

pub struct Sequencer {
    name: String,
    inbox: SharedInbox,
    to_l1d: OutLink,
    to_l1i: OutLink,
    cpu: CompId,
    xbar: std::sync::Arc<XbarState>,
    io_base: u64,
    /// MSHR-style cap on coherent transactions in flight at once. The
    /// Minor CPU keeps at most one access outstanding so never hits it;
    /// the O3 pipeline fills it (`CpuSpec::mshrs`).
    mshrs: usize,
    /// Outstanding coherent transactions: txn -> original packet.
    outstanding: FxHashMap<u64, Packet>,
    /// Coherent packets queued behind a full MSHR file, FIFO. One drains
    /// per coherent completion, preserving arrival order deterministically.
    coherent_waiting: VecDeque<Packet>,
    /// IO packets waiting for a layer retry.
    io_waiting: Vec<Packet>,
    /// IO packets in flight (for layer release on response).
    io_outstanding: FxHashMap<u64, Packet>,
    // stats
    coherent_reqs: u64,
    io_reqs: u64,
    io_retries: u64,
    latency_sum: Tick,
    responses: u64,
    /// Requests that found all MSHRs busy and queued.
    mshr_stalls: u64,
    /// Reusable wakeup drain buffer (perf: no alloc per wakeup).
    scratch: Vec<RubyMsg>,
}

impl Sequencer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        inbox: SharedInbox,
        to_l1d: OutLink,
        to_l1i: OutLink,
        cpu: CompId,
        xbar: std::sync::Arc<XbarState>,
        io_base: u64,
        mshrs: usize,
    ) -> Self {
        Sequencer {
            name,
            inbox,
            to_l1d,
            to_l1i,
            cpu,
            xbar,
            io_base,
            mshrs: mshrs.max(1),
            outstanding: FxHashMap::default(),
            coherent_waiting: VecDeque::new(),
            io_waiting: Vec::new(),
            io_outstanding: FxHashMap::default(),
            coherent_reqs: 0,
            io_reqs: 0,
            io_retries: 0,
            latency_sum: 0,
            responses: 0,
            mshr_stalls: 0,
            scratch: Vec::new(),
        }
    }

    fn issue_coherent(&mut self, pkt: Packet, ctx: &mut Ctx) {
        if self.outstanding.len() >= self.mshrs {
            self.mshr_stalls += 1;
            self.coherent_waiting.push_back(pkt);
            return;
        }
        self.send_coherent(pkt, ctx);
    }

    fn send_coherent(&mut self, pkt: Packet, ctx: &mut Ctx) {
        self.coherent_reqs += 1;
        let is_ifetch = pkt.size == IFETCH_SIZE;
        let link = if is_ifetch { &self.to_l1i } else { &self.to_l1d };
        let msg = RubyMsg {
            kind: MsgKind::SeqReq { is_store: pkt.cmd == Cmd::WriteReq },
            addr: pkt.addr,
            value: pkt.value,
            src: ctx.self_id(),
            dst: link.consumer,
            txn: pkt.id,
            core: pkt.core,
            issued: pkt.issued,
        };
        self.outstanding.insert(pkt.id, pkt);
        let ok = link.send(ctx, msg, 0);
        debug_assert!(ok, "seq->L1 buffers are unbounded");
    }

    fn issue_io(&mut self, pkt: Packet, ctx: &mut Ctx) {
        self.io_reqs += 1;
        // The device answers to `requester`; reroute it through this
        // sequencer so the response releases the layer before completing
        // back to the CPU (`Sequencer::complete`).
        let mut fwd = pkt;
        fwd.requester = ctx.self_id();
        if ctx.xbar_border() {
            // Border-staged arbitration (`--xbar-arb border`, the
            // default): stage the layer request; the shared-domain
            // arbiter grants it at the quantum border in canonical
            // `(request_tick, sender_domain, seq)` order and delivers
            // the packet to the device itself (docs/XBAR.md). Busy
            // layers keep the request queued in the crossbar — no retry
            // events, no mid-window reads of shared layer state.
            self.io_outstanding.insert(pkt.id, pkt);
            let staged = self.xbar.stage_occupy(
                ctx.domain().0,
                ctx.self_id(),
                ctx.now(),
                fwd,
                &ctx.shared().pdes,
            );
            if !staged {
                panic!(
                    "{}: IO address {:#x} matches no crossbar target",
                    self.name, pkt.addr
                );
            }
            return;
        }
        match self.xbar.try_occupy(pkt.addr, ctx.self_id()) {
            Occupy::Granted { target } => {
                self.io_outstanding.insert(pkt.id, pkt);
                ctx.schedule(
                    self.xbar.latency,
                    target,
                    EventKind::MemReq { pkt: fwd },
                );
            }
            Occupy::Busy => {
                // A retry event will arrive when the layer frees up.
                self.io_waiting.push(pkt);
            }
            Occupy::Contended => {
                // Host-time mutex collision (§4.3): transient, retry soon.
                self.io_retries += 1;
                self.io_waiting.push(pkt);
                ctx.schedule_self(self.xbar.retry_delay, EventKind::RetryReq);
            }
            Occupy::NoTarget => panic!(
                "{}: IO address {:#x} matches no crossbar target",
                self.name, pkt.addr
            ),
        }
    }

    fn retry_io(&mut self, ctx: &mut Ctx) {
        let waiting = std::mem::take(&mut self.io_waiting);
        for pkt in waiting {
            self.issue_io(pkt, ctx);
        }
    }

    fn complete(&mut self, pkt: Packet, ctx: &mut Ctx) {
        self.responses += 1;
        self.latency_sum += ctx.now().saturating_sub(pkt.issued);
        ctx.schedule(0, self.cpu, EventKind::MemResp { pkt });
    }
}

impl Component for Sequencer {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            // CPU request (classic protocol in).
            EventKind::MemReq { pkt } => {
                if pkt.addr >= self.io_base {
                    self.issue_io(pkt, ctx);
                } else {
                    self.issue_coherent(pkt, ctx);
                }
            }
            // Ruby side completed a coherent access.
            EventKind::ConsumerWakeup => {
                let mut ready = std::mem::take(&mut self.scratch);
                super::inbox::drain_for_wakeup_into(&self.inbox, ctx, &mut ready);
                for msg in ready.drain(..) {
                    match msg.kind {
                        MsgKind::SeqResp | MsgKind::Comp => {
                            let Some(pkt) =
                                self.outstanding.remove(&msg.txn)
                            else {
                                panic!(
                                    "{}: response for unknown txn {}",
                                    self.name, msg.txn
                                );
                            };
                            let resp = pkt.make_response(msg.value);
                            // A completion frees one MSHR: drain the
                            // oldest queued coherent request into it.
                            if let Some(next) =
                                self.coherent_waiting.pop_front()
                            {
                                self.send_coherent(next, ctx);
                            }
                            self.complete(resp, ctx);
                        }
                        other => {
                            panic!("{}: unexpected msg {other:?}", self.name)
                        }
                    }
                }
                self.scratch = ready;
            }
            // IO target responded: release the layer, wake one waiter.
            // Under the border-staged arbitration nothing waits in the
            // layer (pending requests queue in the crossbar and are
            // granted at the next border), so the release returns no
            // waiter and no retry event is ever scheduled.
            EventKind::MemResp { pkt } => {
                let orig = self
                    .io_outstanding
                    .remove(&pkt.id)
                    .expect("io response matches an outstanding request");
                if let Some(waiter) =
                    self.xbar.release(orig.addr, ctx.self_id())
                {
                    ctx.schedule(0, waiter, EventKind::RetryReq);
                }
                self.complete(pkt, ctx);
            }
            // Layer freed (or local backoff expired): retry waiting IO.
            EventKind::RetryReq => self.retry_io(ctx),
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    /// Border-ordered handoff (`--inbox-order border`): merge the
    /// cross-domain deliveries staged for this inbox during the closed
    /// window, in canonical order (DESIGN.md §6).
    fn border_merge(&mut self, ctx: &mut Ctx) {
        super::inbox::merge_staged_for_border(&self.inbox, ctx);
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("coherent_reqs", self.coherent_reqs);
        out.add_u64("io_reqs", self.io_reqs);
        out.add_u64("io_lock_retries", self.io_retries);
        out.add_u64("mshr_stalls", self.mshr_stalls);
        out.add_u64("responses", self.responses);
        out.add_u64("latency_sum_ticks", self.latency_sum);
        if self.responses > 0 {
            out.add(
                "avg_latency_ns",
                self.latency_sum as f64 / self.responses as f64 / 1000.0,
            );
        }
    }

    fn save_state(&self, w: &mut StateWriter) {
        self.inbox.lock().unwrap().save_ckpt(w);
        let mut coherent: Vec<&Packet> = self.outstanding.values().collect();
        coherent.sort_unstable_by_key(|p| p.id);
        w.usize(coherent.len());
        for pkt in coherent {
            w.packet(pkt);
        }
        w.usize(self.io_waiting.len());
        for pkt in &self.io_waiting {
            w.packet(pkt);
        }
        let mut io: Vec<&Packet> = self.io_outstanding.values().collect();
        io.sort_unstable_by_key(|p| p.id);
        w.usize(io.len());
        for pkt in io {
            w.packet(pkt);
        }
        w.u64(self.coherent_reqs);
        w.u64(self.io_reqs);
        w.u64(self.io_retries);
        w.u64(self.latency_sum);
        w.u64(self.responses);
        w.usize(self.coherent_waiting.len());
        for pkt in &self.coherent_waiting {
            w.packet(pkt);
        }
        w.u64(self.mshr_stalls);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.inbox.lock().unwrap().restore_ckpt(r)?;
        self.outstanding.clear();
        for _ in 0..r.usize()? {
            let pkt = r.packet()?;
            self.outstanding.insert(pkt.id, pkt);
        }
        self.io_waiting.clear();
        for _ in 0..r.usize()? {
            self.io_waiting.push(r.packet()?);
        }
        self.io_outstanding.clear();
        for _ in 0..r.usize()? {
            let pkt = r.packet()?;
            self.io_outstanding.insert(pkt.id, pkt);
        }
        self.coherent_reqs = r.u64()?;
        self.io_reqs = r.u64()?;
        self.io_retries = r.u64()?;
        self.latency_sum = r.u64()?;
        self.responses = r.u64()?;
        self.coherent_waiting.clear();
        for _ in 0..r.usize()? {
            self.coherent_waiting.push_back(r.packet()?);
        }
        self.mshr_stalls = r.u64()?;
        Ok(())
    }
}
