//! System elaboration: a declarative [`SystemSpec`] becomes components and
//! time domains, partitioned per §4.1 of the paper.
//!
//! Per core `i` (domain `i` when parallel, else domain 0), every topology
//! builds the same private stack:
//! `cpu_i, seq_i, l1i_i, l1d_i, l2_i, router r_i, throttle t_i`.
//! The shared domain (`N` when parallel) holds the interconnect fabric —
//! its shape is the spec's [`Interconnect`] — plus the HN-F, the DRAM
//! channel controllers, UART + timer behind the IO crossbar, the per-core
//! central throttles `tc_i`, and the crossbar's border arbiter
//! (docs/XBAR.md):
//!
//! * **Star** (Fig. 4): one central station `rc`; `t_i → rc`, `rc → tc_i`,
//!   `rc ↔ HN-F`. Exactly the legacy hard-wired system, bit-for-bit.
//! * **Ring**: stations `s_0..s_{n-1}` linked uni-directionally
//!   (`s_i → s_{i+1 mod n}`); `t_i → s_i`, `s_i → tc_i`, HN-F at `s_0`.
//!   Messages ride the ring accumulating one NoC hop per station.
//! * **Mesh `{cols}`**: stations on a full `cols × rows` grid with
//!   deterministic X-then-Y routing; `t_i → s_i`, `s_i → tc_i`, HN-F at
//!   `s_0` (the north-west corner).
//!
//! The only domain-crossing links on every topology are `t_i → fabric` and
//! `tc_i → r_i` (Ruby protocol, both uni-directional through throttles —
//! Fig. 5c) plus the sequencer↔crossbar path (classic timing protocol,
//! §4.3). Stations never cross domains (they all live in the shared
//! domain), so the inbox lock graph stays acyclic and the PDES kernels,
//! quantum policies and the border-ordered inbox handoff work unchanged on
//! every topology (`tests/platforms.rs` gates bit-identity per preset).
//!
//! [`Layout`] is no longer hand-maintained arithmetic: it is an id table
//! *planned* from the spec ([`Layout::plan`]) and asserted against the
//! actual `add` order during elaboration.

use std::sync::Arc;

use rustc_hash::FxHashMap;

use crate::config::{Mode, RunConfig};
use crate::cpu::{
    AtomicCpu, AtomicLatencies, AtomicMem, CpuModel, CpuParams, KvmCpu, O3Cpu,
    TimingCpu,
};
use crate::mem::{DramCtrl, DramTiming, Timer, Uart};
use crate::pdes::{Machine, MachineBuilder};
use crate::sim::ids::{CompId, DomainId};
use crate::sim::time::{Clock, Tick, NS};
use crate::spec::{Interconnect, SystemSpec};
use crate::workload::Workload;
use crate::xbar::{default_xbar, XbarState, IO_BASE};

use super::hnf::HnfCtrl;
use super::inbox::{new_inbox, OutLink, SharedInbox};
use super::l1::L1Ctrl;
use super::l2::L2Ctrl;
use super::router::Router;
use super::sequencer::Sequencer;
use super::throttle::Throttle;

const UNB: usize = usize::MAX;

/// The fabric station the HN-F attaches to on ring/mesh topologies.
const HNF_STATION: usize = 0;

/// Component-id table, planned from the spec before elaboration and
/// asserted against the actual `add` order while components are built.
///
/// This replaces the old `CompId(i*7+k)` arithmetic: adding a component or
/// a topology changes [`Layout::plan`] in one place and every consumer of
/// the table follows.
#[derive(Clone, Debug)]
pub struct Layout {
    cpu: Vec<CompId>,
    seq: Vec<CompId>,
    l1i: Vec<CompId>,
    l1d: Vec<CompId>,
    l2: Vec<CompId>,
    router: Vec<CompId>,
    throttle: Vec<CompId>,
    /// Interconnect stations in the shared domain (the star's single
    /// central router `rc`, or one ring/mesh station per core).
    pub stations: Vec<CompId>,
    hnf_id: CompId,
    drams: Vec<CompId>,
    uart_id: CompId,
    timer_id: CompId,
    tc_ids: Vec<CompId>,
    /// The IO-crossbar border arbiter (shared domain, after the central
    /// throttles so every pre-existing id is unchanged).
    xbar_arb_id: CompId,
}

impl Layout {
    /// Plan the id table for `spec`: ids follow the elaboration `add`
    /// order (per-core stacks first, then the shared domain — stations,
    /// HN-F, DRAM channels, peripherals, central throttles, and the IO
    /// crossbar's border arbiter last).
    pub fn plan(spec: &SystemSpec) -> Layout {
        let n = spec.cores;
        let mut next = 0u32;
        let mut id = || {
            let c = CompId(next);
            next += 1;
            c
        };
        let mut cpu = Vec::with_capacity(n);
        let mut seq = Vec::with_capacity(n);
        let mut l1i = Vec::with_capacity(n);
        let mut l1d = Vec::with_capacity(n);
        let mut l2 = Vec::with_capacity(n);
        let mut router = Vec::with_capacity(n);
        let mut throttle = Vec::with_capacity(n);
        for _ in 0..n {
            cpu.push(id());
            seq.push(id());
            l1i.push(id());
            l1d.push(id());
            l2.push(id());
            router.push(id());
            throttle.push(id());
        }
        let stations = (0..spec.n_stations()).map(|_| id()).collect();
        let hnf_id = id();
        let drams = (0..spec.mem_channels).map(|_| id()).collect();
        let uart_id = id();
        let timer_id = id();
        let tc_ids = (0..n).map(|_| id()).collect();
        let xbar_arb_id = id();
        Layout {
            cpu,
            seq,
            l1i,
            l1d,
            l2,
            router,
            throttle,
            stations,
            hnf_id,
            drams,
            uart_id,
            timer_id,
            tc_ids,
            xbar_arb_id,
        }
    }

    pub fn cores(&self) -> usize {
        self.cpu.len()
    }
    pub fn cpu(&self, i: usize) -> CompId {
        self.cpu[i]
    }
    pub fn seq(&self, i: usize) -> CompId {
        self.seq[i]
    }
    pub fn l1i(&self, i: usize) -> CompId {
        self.l1i[i]
    }
    pub fn l1d(&self, i: usize) -> CompId {
        self.l1d[i]
    }
    pub fn l2(&self, i: usize) -> CompId {
        self.l2[i]
    }
    pub fn router(&self, i: usize) -> CompId {
        self.router[i]
    }
    pub fn throttle(&self, i: usize) -> CompId {
        self.throttle[i]
    }
    /// The star's central router (panics on ring/mesh — use
    /// [`Layout::stations`]).
    pub fn rc(&self) -> CompId {
        assert_eq!(
            self.stations.len(),
            1,
            "rc() is the star's single station; this layout has {}",
            self.stations.len()
        );
        self.stations[0]
    }
    pub fn hnf(&self) -> CompId {
        self.hnf_id
    }
    /// First (or only) DRAM channel controller.
    pub fn dram(&self) -> CompId {
        self.drams[0]
    }
    /// All DRAM channel controllers (line-interleaved by the HN-F).
    pub fn drams(&self) -> &[CompId] {
        &self.drams
    }
    pub fn uart(&self) -> CompId {
        self.uart_id
    }
    pub fn timer(&self) -> CompId {
        self.timer_id
    }
    pub fn tc(&self, i: usize) -> CompId {
        self.tc_ids[i]
    }
    /// The IO-crossbar border arbiter (docs/XBAR.md).
    pub fn xbar_arb(&self) -> CompId {
        self.xbar_arb_id
    }
    /// Total number of components in the table.
    pub fn n_components(&self) -> usize {
        self.cpu.len() * 8
            + self.stations.len()
            + self.drams.len()
            + 4 // hnf, uart, timer, xbar arbiter
    }
}

/// A constructed machine plus the handles the harness needs.
pub struct BuiltSystem {
    pub machine: Machine,
    pub xbar: Arc<XbarState>,
    pub layout: Layout,
}

/// Build the timing-mode system described by the legacy `RunConfig` flag
/// surface (a thin conversion into [`SystemSpec`] — see
/// [`RunConfig::spec`]).
pub fn build_system(cfg: &RunConfig, workload: &Workload) -> BuiltSystem {
    build_from_spec(&cfg.spec(), cfg, workload)
}

/// Elaborate `spec` into a timing-mode machine (Minor/O3 + Ruby
/// CHI-lite). Run knobs (kernel mode, quantum, queue, border policy) come
/// from `cfg`; the platform comes entirely from the spec.
pub fn build_from_spec(
    spec: &SystemSpec,
    cfg: &RunConfig,
    workload: &Workload,
) -> BuiltSystem {
    if let Err(e) = spec.validate() {
        panic!("{e}");
    }
    assert!(
        spec.cpu.is_timing(),
        "build_from_spec is for timing models; use build_atomic_system"
    );
    assert_eq!(workload.n_cores(), spec.cores, "workload/core mismatch");
    let n = spec.cores;
    let lay = Layout::plan(spec);

    let (n_domains, quantum) = match cfg.mode {
        Mode::Serial => (1, Tick::MAX),
        Mode::Parallel | Mode::Virtual => (n + 1, cfg.quantum),
    };
    let dom = |i: usize| match cfg.mode {
        Mode::Serial => DomainId(0),
        _ => DomainId(i as u32),
    };
    let shared_dom = match cfg.mode {
        Mode::Serial => DomainId(0),
        _ => DomainId(n as u32),
    };

    let mut b = MachineBuilder::new(n_domains, quantum);
    b.set_queue(cfg.queue);
    b.set_bucket_shape(cfg.bucket_shape);
    b.set_policy(cfg.run_policy());
    b.set_cores(n as u32);

    let noc = spec.noc_latency();
    let rbuf = spec.router_buffer;
    let clock = Clock::from_mhz(spec.cpu_mhz);
    let xbar = default_xbar(&[lay.uart(), lay.timer()]);

    // ---- create all inboxes up front (ids are known from the layout) ----
    let seq_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, UNB])).collect();
    let l1i_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, UNB])).collect();
    let l1d_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, UNB])).collect();
    let l2_inbox: Vec<_> =
        (0..n).map(|_| new_inbox(&[UNB, UNB, UNB])).collect();
    // r_i: [0] from L2 (unbounded), [1] from tc_i (finite).
    let r_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, rbuf])).collect();
    // t_i: [0] from r_i (finite).
    let t_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[rbuf])).collect();
    // tc_i: [0] from its fabric station (finite).
    let tc_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[rbuf])).collect();
    let hnf_inbox = new_inbox(&[UNB]);

    // Fabric station inboxes. Buffer layouts per topology:
    //   star  (1 station): [0..n) from t_i (finite), [n] from HNF.
    //   ring  (n stations): [0] from t_i (finite), [1] from the ring
    //         predecessor, [2] from the HNF (used on s_0 only).
    //   mesh  (n stations): [0] from t_i (finite), [1..=4] from the
    //         W/E/N/S neighbours, [5] from the HNF (s_0 only).
    let st_inbox: Vec<SharedInbox> = match spec.interconnect {
        Interconnect::Star => {
            let caps: Vec<usize> =
                (0..n).map(|_| rbuf).chain(std::iter::once(UNB)).collect();
            vec![new_inbox(&caps)]
        }
        Interconnect::Ring => {
            (0..n).map(|_| new_inbox(&[rbuf, UNB, UNB])).collect()
        }
        Interconnect::Mesh { .. } => (0..n)
            .map(|_| new_inbox(&[rbuf, UNB, UNB, UNB, UNB, UNB]))
            .collect(),
    };
    // Where a core's local throttle t_i injects into the fabric.
    let fabric_entry = |i: usize| -> OutLink {
        match spec.interconnect {
            Interconnect::Star => OutLink {
                inbox: st_inbox[0].clone(),
                buf: i,
                consumer: lay.stations[0],
                latency: noc,
            },
            Interconnect::Ring | Interconnect::Mesh { .. } => OutLink {
                inbox: st_inbox[i].clone(),
                buf: 0,
                consumer: lay.stations[i],
                latency: noc,
            },
        }
    };

    // ---- per-core components (identical private stack, any fabric) ----
    for i in 0..n {
        let d = dom(i);

        // CPU
        let mut params = match spec.cpu {
            CpuModel::Minor => CpuParams::minor(),
            CpuModel::O3 => CpuParams::o3(),
            _ => unreachable!(),
        };
        if spec.io_milli > 0 {
            params.io_every = (1000 / spec.io_milli).max(1) as usize;
        }
        let code_base =
            crate::workload::apps::PRIVATE_BASE + i as u64 * crate::workload::apps::PRIVATE_SPAN
                + 32 * 1024 * 1024; // code region in the upper private half
        let id = match spec.cpu {
            CpuModel::O3 => b.add(
                d,
                Box::new(O3Cpu::new(
                    format!("cpu{i}"),
                    i as u16,
                    clock,
                    spec.cpu_spec,
                    params,
                    lay.seq(i),
                    workload.cores[i].clone(),
                    workload.barrier_every,
                    code_base,
                    4 * 1024, // loop body: 64 I-lines, fits any L1I
                )),
            ),
            _ => b.add(
                d,
                Box::new(TimingCpu::new(
                    format!("cpu{i}"),
                    i as u16,
                    clock,
                    params,
                    lay.seq(i),
                    workload.cores[i].clone(),
                    workload.barrier_every,
                    code_base,
                    4 * 1024, // loop body: 64 I-lines, fits any L1I
                )),
            ),
        };
        debug_assert_eq!(id, lay.cpu(i));

        // Sequencer
        let seq = Sequencer::new(
            format!("seq{i}"),
            seq_inbox[i].clone(),
            OutLink {
                inbox: l1d_inbox[i].clone(),
                buf: 0,
                consumer: lay.l1d(i),
                latency: 0,
            },
            OutLink {
                inbox: l1i_inbox[i].clone(),
                buf: 0,
                consumer: lay.l1i(i),
                latency: 0,
            },
            lay.cpu(i),
            xbar.clone(),
            IO_BASE,
            spec.cpu_spec.mshrs,
        );
        let id = b.add(d, Box::new(seq));
        debug_assert_eq!(id, lay.seq(i));

        // L1I / L1D
        for (is_d, name, inbox, cache) in [
            (false, format!("cpu{i}.l1i"), &l1i_inbox[i], &spec.l1i),
            (true, format!("cpu{i}.l1d"), &l1d_inbox[i], &spec.l1d),
        ] {
            let l1 = L1Ctrl::new(
                name,
                cache.size_bytes,
                cache.assoc,
                spec.line_bytes,
                cache.latency_ns * NS,
                inbox.clone(),
                OutLink {
                    inbox: l2_inbox[i].clone(),
                    buf: if is_d { 1 } else { 0 },
                    consumer: lay.l2(i),
                    latency: 0,
                },
                OutLink {
                    inbox: seq_inbox[i].clone(),
                    buf: if is_d { 0 } else { 1 },
                    consumer: lay.seq(i),
                    latency: 0,
                },
            );
            let id = b.add(d, Box::new(l1));
            debug_assert_eq!(id, if is_d { lay.l1d(i) } else { lay.l1i(i) });
        }

        // L2
        let l2 = L2Ctrl::new(
            format!("cpu{i}.l2"),
            spec.l2.size_bytes,
            spec.l2.assoc,
            spec.line_bytes,
            spec.l2.latency_ns * NS,
            l2_inbox[i].clone(),
            OutLink {
                inbox: l1i_inbox[i].clone(),
                buf: 1,
                consumer: lay.l1i(i),
                latency: 0,
            },
            OutLink {
                inbox: l1d_inbox[i].clone(),
                buf: 1,
                consumer: lay.l1d(i),
                latency: 0,
            },
            OutLink {
                inbox: r_inbox[i].clone(),
                buf: 0,
                consumer: lay.router(i),
                latency: noc,
            },
            lay.hnf(),
        );
        let id = b.add(d, Box::new(l2));
        debug_assert_eq!(id, lay.l2(i));

        // Local router r_i: out[0] -> t_i (default), out[1] -> l2_i.
        let mut routes = FxHashMap::default();
        routes.insert(lay.l2(i), 1usize);
        let r = Router::new(
            format!("r{i}"),
            r_inbox[i].clone(),
            vec![
                OutLink {
                    inbox: t_inbox[i].clone(),
                    buf: 0,
                    consumer: lay.throttle(i),
                    latency: noc,
                },
                OutLink {
                    inbox: l2_inbox[i].clone(),
                    buf: 2,
                    consumer: lay.l2(i),
                    latency: noc,
                },
            ],
            routes,
            Some(0),
            noc,
        );
        let id = b.add(d, Box::new(r));
        debug_assert_eq!(id, lay.router(i));

        // Local throttle t_i -> fabric (DOMAIN-CROSSING link).
        let t = Throttle::new(
            format!("t{i}"),
            t_inbox[i].clone(),
            fabric_entry(i),
            noc,
            spec.data_flits,
        );
        let id = b.add(d, Box::new(t));
        debug_assert_eq!(id, lay.throttle(i));
    }

    // ---- shared-domain fabric stations -------------------------------
    match spec.interconnect {
        Interconnect::Star => {
            // Central router rc: out[j] -> tc_j, out[n] -> HNF.
            let mut rc_routes = FxHashMap::default();
            let mut rc_outs = Vec::new();
            for j in 0..n {
                rc_routes.insert(lay.l2(j), j);
                rc_outs.push(OutLink {
                    inbox: tc_inbox[j].clone(),
                    buf: 0,
                    consumer: lay.tc(j),
                    latency: noc,
                });
            }
            rc_routes.insert(lay.hnf(), n);
            rc_outs.push(OutLink {
                inbox: hnf_inbox.clone(),
                buf: 0,
                consumer: lay.hnf(),
                latency: noc,
            });
            let rc = Router::new(
                "rc".to_string(),
                st_inbox[0].clone(),
                rc_outs,
                rc_routes,
                None,
                noc,
            );
            let id = b.add(shared_dom, Box::new(rc));
            debug_assert_eq!(id, lay.stations[0]);
        }
        Interconnect::Ring => {
            // Uni-directional ring s_i -> s_{i+1 mod n}; HNF at s_0.
            for i in 0..n {
                let next = (i + 1) % n;
                let mut routes = FxHashMap::default();
                routes.insert(lay.l2(i), 0usize);
                let mut outs = vec![
                    OutLink {
                        inbox: tc_inbox[i].clone(),
                        buf: 0,
                        consumer: lay.tc(i),
                        latency: noc,
                    },
                    OutLink {
                        inbox: st_inbox[next].clone(),
                        buf: 1,
                        consumer: lay.stations[next],
                        latency: noc,
                    },
                ];
                if i == HNF_STATION {
                    routes.insert(lay.hnf(), outs.len());
                    outs.push(OutLink {
                        inbox: hnf_inbox.clone(),
                        buf: 0,
                        consumer: lay.hnf(),
                        latency: noc,
                    });
                }
                let s = Router::new(
                    format!("s{i}"),
                    st_inbox[i].clone(),
                    outs,
                    routes,
                    Some(1), // everything else rides the ring
                    noc,
                );
                let id = b.add(shared_dom, Box::new(s));
                debug_assert_eq!(id, lay.stations[i]);
            }
        }
        Interconnect::Mesh { cols } => {
            // Full cols x rows grid, X-then-Y routing; HNF at s_0.
            // Neighbour buffer convention in the *receiver's* inbox:
            // [1] = from its W neighbour, [2] = from E, [3] = from N,
            // [4] = from S.
            let pos = |s: usize| (s % cols, s / cols);
            for i in 0..n {
                let (xi, yi) = pos(i);
                let mut outs = vec![OutLink {
                    inbox: tc_inbox[i].clone(),
                    buf: 0,
                    consumer: lay.tc(i),
                    latency: noc,
                }];
                let mut dir_out = [usize::MAX; 4]; // E, W, S, N
                // (neighbour station, buffer index at the receiver):
                // sending east lands in the receiver's "from W" buffer,
                // and so on.
                let neighbours = [
                    if xi + 1 < cols { Some((i + 1, 1usize)) } else { None },
                    if xi > 0 { Some((i - 1, 2usize)) } else { None },
                    if i + cols < n { Some((i + cols, 3usize)) } else { None },
                    if yi > 0 { Some((i - cols, 4usize)) } else { None },
                ];
                for (dir, nb) in neighbours.into_iter().enumerate() {
                    if let Some((s, buf)) = nb {
                        dir_out[dir] = outs.len();
                        outs.push(OutLink {
                            inbox: st_inbox[s].clone(),
                            buf,
                            consumer: lay.stations[s],
                            latency: noc,
                        });
                    }
                }
                // First hop from station i towards station `to`, X first.
                let first_hop = |to: usize| -> usize {
                    let (xt, yt) = pos(to);
                    let dir = if xt > xi {
                        0 // E
                    } else if xt < xi {
                        1 // W
                    } else if yt > yi {
                        2 // S
                    } else {
                        3 // N
                    };
                    let out = dir_out[dir];
                    debug_assert_ne!(out, usize::MAX, "hop off the grid");
                    out
                };
                let mut routes = FxHashMap::default();
                for j in 0..n {
                    let out = if j == i { 0 } else { first_hop(j) };
                    routes.insert(lay.l2(j), out);
                }
                if i == HNF_STATION {
                    routes.insert(lay.hnf(), outs.len());
                    outs.push(OutLink {
                        inbox: hnf_inbox.clone(),
                        buf: 0,
                        consumer: lay.hnf(),
                        latency: noc,
                    });
                } else {
                    routes.insert(lay.hnf(), first_hop(HNF_STATION));
                }
                let s = Router::new(
                    format!("s{i}"),
                    st_inbox[i].clone(),
                    outs,
                    routes,
                    None, // every destination is mapped explicitly
                    noc,
                );
                let id = b.add(shared_dom, Box::new(s));
                debug_assert_eq!(id, lay.stations[i]);
            }
        }
    }

    // ---- HN-F (enters the fabric at its attachment station) ----------
    let hnf_to_noc = match spec.interconnect {
        Interconnect::Star => OutLink {
            inbox: st_inbox[0].clone(),
            buf: n,
            consumer: lay.stations[0],
            latency: noc,
        },
        Interconnect::Ring => OutLink {
            inbox: st_inbox[HNF_STATION].clone(),
            buf: 2,
            consumer: lay.stations[HNF_STATION],
            latency: noc,
        },
        Interconnect::Mesh { .. } => OutLink {
            inbox: st_inbox[HNF_STATION].clone(),
            buf: 5,
            consumer: lay.stations[HNF_STATION],
            latency: noc,
        },
    };
    let hnf = HnfCtrl::new(
        "hnf".to_string(),
        spec.l3.size_bytes,
        spec.l3.assoc,
        spec.line_bytes,
        spec.l3.latency_ns * NS,
        hnf_inbox.clone(),
        hnf_to_noc,
        lay.drams().to_vec(),
    );
    let id = b.add(shared_dom, Box::new(hnf));
    debug_assert_eq!(id, lay.hnf());

    // ---- DRAM channels (line-interleaved by the HN-F) ----------------
    let dram_timing = DramTiming {
        clk_period: 1_000_000 / spec.dram_mhz,
        ..DramTiming::default()
    };
    for c in 0..spec.mem_channels {
        let name = if spec.mem_channels == 1 {
            "dram".to_string() // legacy stat names stay intact
        } else {
            format!("dram{c}")
        };
        let dram = DramCtrl::new(name, dram_timing, spec.line_bytes);
        let id = b.add(shared_dom, Box::new(dram));
        debug_assert_eq!(id, lay.drams()[c]);
    }

    // ---- Peripherals behind the IO crossbar --------------------------
    let id = b.add(shared_dom, Box::new(Uart::new("uart".to_string())));
    debug_assert_eq!(id, lay.uart());
    let id = b.add(shared_dom, Box::new(Timer::new("timer".to_string())));
    debug_assert_eq!(id, lay.timer());

    // ---- Central throttles tc_i -> r_i (DOMAIN-CROSSING links) -------
    for i in 0..n {
        let t = Throttle::new(
            format!("tc{i}"),
            tc_inbox[i].clone(),
            OutLink {
                inbox: r_inbox[i].clone(),
                buf: 1,
                consumer: lay.router(i),
                latency: noc,
            },
            noc,
            spec.data_flits,
        );
        let id = b.add(shared_dom, Box::new(t));
        debug_assert_eq!(id, lay.tc(i));
    }

    // ---- IO-crossbar border arbiter (docs/XBAR.md) -------------------
    // Lives in the shared domain — the domain of every crossbar target —
    // so its border grants are local schedules inside the quiescent span.
    // Inert under `--xbar-arb host` and on the serial kernel.
    let arb = crate::xbar::XbarArbiter::new("xbar".to_string(), xbar.clone());
    let id = b.add(shared_dom, Box::new(arb));
    debug_assert_eq!(id, lay.xbar_arb());

    let machine = b.finish();
    // Seed the offered-load side of the offered/accepted backpressure
    // pair (docs/TRAFFIC.md): both are pure functions of the workload,
    // so they participate in the bit-identity gate.
    machine.shared.pdes.traffic_offered.store(
        workload.total_ops() as u64,
        std::sync::atomic::Ordering::Relaxed,
    );
    machine.shared.pdes.traffic_phases.store(
        workload.phases() as u64,
        std::sync::atomic::Ordering::Relaxed,
    );
    BuiltSystem { machine, xbar, layout: lay }
}

/// Build the atomic-protocol system (AtomicCPU / KVMCPU; serial only).
/// Atomic protocols bypass the interconnect entirely, so the spec's
/// topology is irrelevant here.
pub fn build_atomic_system(
    cfg: &RunConfig,
    workload: &Workload,
    kvm: bool,
) -> (Machine, std::sync::Arc<std::sync::Mutex<AtomicMem>>) {
    let n = cfg.system.cores;
    let sys = &cfg.system;
    assert_eq!(workload.n_cores(), n);
    let clock = Clock::from_mhz(sys.cpu_mhz);

    let mem = AtomicMem::new(
        n,
        sys.l1d.size_bytes,
        sys.l1d.assoc,
        sys.l2.size_bytes,
        sys.l2.assoc,
        sys.l3.size_bytes,
        sys.l3.assoc,
        sys.line_bytes,
        AtomicLatencies {
            l1: sys.l1d.latency_ns * NS,
            l2: sys.l2.latency_ns * NS,
            l3: sys.l3.latency_ns * NS,
            dram: 50 * NS,
        },
    );

    let mut b = MachineBuilder::new(1, Tick::MAX);
    b.set_queue(cfg.queue);
    b.set_bucket_shape(cfg.bucket_shape);
    b.set_cores(n as u32);
    for i in 0..n {
        if kvm {
            b.add(
                DomainId(0),
                Box::new(KvmCpu::new(
                    format!("kvm{i}"),
                    i as u16,
                    mem.clone(),
                    workload.cores[i].clone(),
                )),
            );
        } else {
            b.add(
                DomainId(0),
                Box::new(AtomicCpu::new(
                    format!("atomic{i}"),
                    i as u16,
                    clock,
                    mem.clone(),
                    workload.cores[i].clone(),
                )),
            );
        }
    }
    let machine = b.finish();
    machine.shared.pdes.traffic_offered.store(
        workload.total_ops() as u64,
        std::sync::atomic::Ordering::Relaxed,
    );
    machine.shared.pdes.traffic_phases.store(
        workload.phases() as u64,
        std::sync::atomic::Ordering::Relaxed,
    );
    (machine, mem)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(cores: usize, ic: Interconnect) -> SystemSpec {
        SystemSpec { cores, interconnect: ic, ..SystemSpec::default() }
    }

    fn all_ids(lay: &Layout) -> Vec<CompId> {
        let mut all = Vec::new();
        for i in 0..lay.cores() {
            all.extend([
                lay.cpu(i),
                lay.seq(i),
                lay.l1i(i),
                lay.l1d(i),
                lay.l2(i),
                lay.router(i),
                lay.throttle(i),
                lay.tc(i),
            ]);
        }
        all.extend(lay.stations.iter().copied());
        all.extend(lay.drams().iter().copied());
        all.extend([lay.hnf(), lay.uart(), lay.timer(), lay.xbar_arb()]);
        all
    }

    #[test]
    fn planned_ids_are_dense_and_disjoint_on_every_topology() {
        for ic in [
            Interconnect::Star,
            Interconnect::Ring,
            Interconnect::Mesh { cols: 3 },
        ] {
            let s = spec(6, ic);
            let lay = Layout::plan(&s);
            let mut all = all_ids(&lay);
            let total = all.len();
            assert_eq!(
                total,
                lay.n_components(),
                "{ic:?}: Layout::n_components disagrees"
            );
            all.sort();
            all.dedup();
            assert_eq!(all.len(), total, "{ic:?}: duplicate ids");
            assert_eq!(all[0], CompId(0), "{ic:?}: ids must start at 0");
            assert_eq!(
                all[total - 1],
                CompId(total as u32 - 1),
                "{ic:?}: ids must be dense"
            );
        }
    }

    #[test]
    fn star_plan_matches_legacy_arithmetic() {
        // The old hand-maintained layout: CompId(i*7 + k) per core, then
        // rc, hnf, dram, uart, timer, tc_i. The spec-derived plan must
        // reproduce it exactly so legacy runs stay bit-for-bit.
        let n = 3;
        let lay = Layout::plan(&spec(n, Interconnect::Star));
        for i in 0..n {
            let base = i as u32 * 7;
            assert_eq!(lay.cpu(i), CompId(base));
            assert_eq!(lay.seq(i), CompId(base + 1));
            assert_eq!(lay.l1i(i), CompId(base + 2));
            assert_eq!(lay.l1d(i), CompId(base + 3));
            assert_eq!(lay.l2(i), CompId(base + 4));
            assert_eq!(lay.router(i), CompId(base + 5));
            assert_eq!(lay.throttle(i), CompId(base + 6));
        }
        let sb = n as u32 * 7;
        assert_eq!(lay.rc(), CompId(sb));
        assert_eq!(lay.hnf(), CompId(sb + 1));
        assert_eq!(lay.dram(), CompId(sb + 2));
        assert_eq!(lay.uart(), CompId(sb + 3));
        assert_eq!(lay.timer(), CompId(sb + 4));
        for i in 0..n {
            assert_eq!(lay.tc(i), CompId(sb + 5 + i as u32));
        }
    }

    #[test]
    #[should_panic(expected = "rc() is the star's single station")]
    fn rc_panics_on_ring() {
        let lay = Layout::plan(&spec(4, Interconnect::Ring));
        let _ = lay.rc();
    }

    #[test]
    fn multi_channel_plan_is_disjoint() {
        let s = SystemSpec {
            mem_channels: 4,
            ..spec(4, Interconnect::Mesh { cols: 2 })
        };
        let lay = Layout::plan(&s);
        assert_eq!(lay.drams().len(), 4);
        let mut all = all_ids(&lay);
        let total = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), total);
    }
}
