//! System construction: Fig. 4's hierarchical star topology, partitioned
//! into time domains per §4.1.
//!
//! Per core `i` (domain `i` when parallel, else domain 0):
//! `cpu_i, seq_i, l1i_i, l1d_i, l2_i, router r_i, throttle t_i`.
//! Shared domain (`N` when parallel): central router `rc`, per-core central
//! throttles `tc_i`, the HN-F, the DRAM controller, UART + timer behind the
//! IO crossbar.
//!
//! The only domain-crossing links are `t_i → rc` and `tc_i → r_i` (Ruby
//! protocol, both uni-directional through throttles — Fig. 5c) plus the
//! sequencer↔crossbar path (classic timing protocol, §4.3).

use std::sync::Arc;

use rustc_hash::FxHashMap;

use crate::config::{Mode, RunConfig};
use crate::cpu::{AtomicCpu, AtomicLatencies, AtomicMem, CpuModel, CpuParams, KvmCpu, TimingCpu};
use crate::mem::{DramCtrl, DramTiming, Timer, Uart};
use crate::pdes::{Machine, MachineBuilder};
use crate::sim::ids::{CompId, DomainId};
use crate::sim::time::{Clock, Tick, NS};
use crate::workload::Workload;
use crate::xbar::{default_xbar, XbarState, IO_BASE};

use super::hnf::HnfCtrl;
use super::inbox::{new_inbox, OutLink};
use super::l1::L1Ctrl;
use super::l2::L2Ctrl;
use super::router::Router;
use super::sequencer::Sequencer;
use super::throttle::Throttle;

const UNB: usize = usize::MAX;

/// Component-id layout (must match the `add` order in `build_system`).
#[derive(Clone, Copy, Debug)]
pub struct Layout {
    pub cores: usize,
}

impl Layout {
    const PER_CORE: u32 = 7;

    pub fn cpu(&self, i: usize) -> CompId {
        CompId(i as u32 * Self::PER_CORE)
    }
    pub fn seq(&self, i: usize) -> CompId {
        CompId(i as u32 * Self::PER_CORE + 1)
    }
    pub fn l1i(&self, i: usize) -> CompId {
        CompId(i as u32 * Self::PER_CORE + 2)
    }
    pub fn l1d(&self, i: usize) -> CompId {
        CompId(i as u32 * Self::PER_CORE + 3)
    }
    pub fn l2(&self, i: usize) -> CompId {
        CompId(i as u32 * Self::PER_CORE + 4)
    }
    pub fn router(&self, i: usize) -> CompId {
        CompId(i as u32 * Self::PER_CORE + 5)
    }
    pub fn throttle(&self, i: usize) -> CompId {
        CompId(i as u32 * Self::PER_CORE + 6)
    }
    fn shared_base(&self) -> u32 {
        self.cores as u32 * Self::PER_CORE
    }
    pub fn rc(&self) -> CompId {
        CompId(self.shared_base())
    }
    pub fn hnf(&self) -> CompId {
        CompId(self.shared_base() + 1)
    }
    pub fn dram(&self) -> CompId {
        CompId(self.shared_base() + 2)
    }
    pub fn uart(&self) -> CompId {
        CompId(self.shared_base() + 3)
    }
    pub fn timer(&self) -> CompId {
        CompId(self.shared_base() + 4)
    }
    pub fn tc(&self, i: usize) -> CompId {
        CompId(self.shared_base() + 5 + i as u32)
    }
}

/// A constructed machine plus the handles the harness needs.
pub struct BuiltSystem {
    pub machine: Machine,
    pub xbar: Arc<XbarState>,
    pub layout: Layout,
}

/// Build the timing-mode system (Minor/O3 + Ruby CHI-lite).
pub fn build_system(cfg: &RunConfig, workload: &Workload) -> BuiltSystem {
    assert!(
        cfg.cpu_model.is_timing(),
        "build_system is for timing models; use build_atomic_system"
    );
    assert_eq!(workload.n_cores(), cfg.system.cores, "workload/core mismatch");
    let n = cfg.system.cores;
    let sys = &cfg.system;
    let lay = Layout { cores: n };

    let (n_domains, quantum) = match cfg.mode {
        Mode::Serial => (1, Tick::MAX),
        Mode::Parallel | Mode::Virtual => (n + 1, cfg.quantum),
    };
    let dom = |i: usize| match cfg.mode {
        Mode::Serial => DomainId(0),
        _ => DomainId(i as u32),
    };
    let shared_dom = match cfg.mode {
        Mode::Serial => DomainId(0),
        _ => DomainId(n as u32),
    };

    let mut b = MachineBuilder::new(n_domains, quantum);
    b.set_queue(cfg.queue);
    b.set_policy(cfg.run_policy());
    b.set_cores(n as u32);

    let noc = sys.noc_latency();
    let rbuf = sys.router_buffer;
    let clock = Clock::from_mhz(sys.cpu_mhz);
    let xbar = default_xbar(&[lay.uart(), lay.timer()]);

    // ---- create all inboxes up front (ids are known from the layout) ----
    let seq_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, UNB])).collect();
    let l1i_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, UNB])).collect();
    let l1d_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, UNB])).collect();
    let l2_inbox: Vec<_> =
        (0..n).map(|_| new_inbox(&[UNB, UNB, UNB])).collect();
    // r_i: [0] from L2 (unbounded), [1] from tc_i (finite).
    let r_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[UNB, rbuf])).collect();
    // t_i: [0] from r_i (finite).
    let t_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[rbuf])).collect();
    // rc: [0..n] from t_i (finite), [n] from HNF (unbounded).
    let rc_caps: Vec<usize> =
        (0..n).map(|_| rbuf).chain(std::iter::once(UNB)).collect();
    let rc_inbox = new_inbox(&rc_caps);
    // tc_i: [0] from rc (finite).
    let tc_inbox: Vec<_> = (0..n).map(|_| new_inbox(&[rbuf])).collect();
    let hnf_inbox = new_inbox(&[UNB]);

    // ---- per-core components ----
    for i in 0..n {
        let d = dom(i);

        // CPU
        let mut params = match cfg.cpu_model {
            CpuModel::Minor => CpuParams::minor(),
            CpuModel::O3 => CpuParams::o3(),
            _ => unreachable!(),
        };
        if sys.io_milli > 0 {
            params.io_every = (1000 / sys.io_milli).max(1) as usize;
        }
        let code_base =
            crate::workload::apps::PRIVATE_BASE + i as u64 * crate::workload::apps::PRIVATE_SPAN
                + 32 * 1024 * 1024; // code region in the upper private half
        let cpu = TimingCpu::new(
            format!("cpu{i}"),
            i as u16,
            clock,
            params,
            lay.seq(i),
            workload.cores[i].clone(),
            workload.barrier_every,
            code_base,
            4 * 1024, // loop body: 64 I-lines, fits any L1I (Table 2)
        );
        let id = b.add(d, Box::new(cpu));
        debug_assert_eq!(id, lay.cpu(i));

        // Sequencer
        let seq = Sequencer::new(
            format!("seq{i}"),
            seq_inbox[i].clone(),
            OutLink {
                inbox: l1d_inbox[i].clone(),
                buf: 0,
                consumer: lay.l1d(i),
                latency: 0,
            },
            OutLink {
                inbox: l1i_inbox[i].clone(),
                buf: 0,
                consumer: lay.l1i(i),
                latency: 0,
            },
            lay.cpu(i),
            xbar.clone(),
            IO_BASE,
        );
        let id = b.add(d, Box::new(seq));
        debug_assert_eq!(id, lay.seq(i));

        // L1I / L1D
        for (is_d, name, inbox, cache) in [
            (false, format!("cpu{i}.l1i"), &l1i_inbox[i], &sys.l1i),
            (true, format!("cpu{i}.l1d"), &l1d_inbox[i], &sys.l1d),
        ] {
            let l1 = L1Ctrl::new(
                name,
                cache.size_bytes,
                cache.assoc,
                sys.line_bytes,
                cache.latency_ns * NS,
                inbox.clone(),
                OutLink {
                    inbox: l2_inbox[i].clone(),
                    buf: if is_d { 1 } else { 0 },
                    consumer: lay.l2(i),
                    latency: 0,
                },
                OutLink {
                    inbox: seq_inbox[i].clone(),
                    buf: if is_d { 0 } else { 1 },
                    consumer: lay.seq(i),
                    latency: 0,
                },
            );
            let id = b.add(d, Box::new(l1));
            debug_assert_eq!(id, if is_d { lay.l1d(i) } else { lay.l1i(i) });
        }

        // L2
        let l2 = L2Ctrl::new(
            format!("cpu{i}.l2"),
            sys.l2.size_bytes,
            sys.l2.assoc,
            sys.line_bytes,
            sys.l2.latency_ns * NS,
            l2_inbox[i].clone(),
            OutLink {
                inbox: l1i_inbox[i].clone(),
                buf: 1,
                consumer: lay.l1i(i),
                latency: 0,
            },
            OutLink {
                inbox: l1d_inbox[i].clone(),
                buf: 1,
                consumer: lay.l1d(i),
                latency: 0,
            },
            OutLink {
                inbox: r_inbox[i].clone(),
                buf: 0,
                consumer: lay.router(i),
                latency: noc,
            },
            lay.hnf(),
        );
        let id = b.add(d, Box::new(l2));
        debug_assert_eq!(id, lay.l2(i));

        // Local router r_i: out[0] -> t_i (default), out[1] -> l2_i.
        let mut routes = FxHashMap::default();
        routes.insert(lay.l2(i), 1usize);
        let r = Router::new(
            format!("r{i}"),
            r_inbox[i].clone(),
            vec![
                OutLink {
                    inbox: t_inbox[i].clone(),
                    buf: 0,
                    consumer: lay.throttle(i),
                    latency: noc,
                },
                OutLink {
                    inbox: l2_inbox[i].clone(),
                    buf: 2,
                    consumer: lay.l2(i),
                    latency: noc,
                },
            ],
            routes,
            Some(0),
            noc,
        );
        let id = b.add(d, Box::new(r));
        debug_assert_eq!(id, lay.router(i));

        // Local throttle t_i -> central router (DOMAIN-CROSSING link).
        let t = Throttle::new(
            format!("t{i}"),
            t_inbox[i].clone(),
            OutLink {
                inbox: rc_inbox.clone(),
                buf: i,
                consumer: lay.rc(),
                latency: noc,
            },
            noc,
            sys.data_flits,
        );
        let id = b.add(d, Box::new(t));
        debug_assert_eq!(id, lay.throttle(i));
    }

    // ---- shared-domain components ----
    // Central router: out[j] -> tc_j, out[n] -> HNF.
    let mut rc_routes = FxHashMap::default();
    let mut rc_outs = Vec::new();
    for j in 0..n {
        rc_routes.insert(lay.l2(j), j);
        rc_outs.push(OutLink {
            inbox: tc_inbox[j].clone(),
            buf: 0,
            consumer: lay.tc(j),
            latency: noc,
        });
    }
    rc_routes.insert(lay.hnf(), n);
    rc_outs.push(OutLink {
        inbox: hnf_inbox.clone(),
        buf: 0,
        consumer: lay.hnf(),
        latency: noc,
    });
    let rc = Router::new(
        "rc".to_string(),
        rc_inbox.clone(),
        rc_outs,
        rc_routes,
        None,
        noc,
    );
    let id = b.add(shared_dom, Box::new(rc));
    debug_assert_eq!(id, lay.rc());

    // HN-F
    let hnf = HnfCtrl::new(
        "hnf".to_string(),
        sys.l3.size_bytes,
        sys.l3.assoc,
        sys.line_bytes,
        sys.l3.latency_ns * NS,
        hnf_inbox.clone(),
        OutLink {
            inbox: rc_inbox.clone(),
            buf: n,
            consumer: lay.rc(),
            latency: noc,
        },
        lay.dram(),
    );
    let id = b.add(shared_dom, Box::new(hnf));
    debug_assert_eq!(id, lay.hnf());

    // DRAM
    let dram_timing = DramTiming {
        clk_period: 1_000_000 / sys.dram_mhz,
        ..DramTiming::default()
    };
    let dram =
        DramCtrl::new("dram".to_string(), dram_timing, sys.line_bytes);
    let id = b.add(shared_dom, Box::new(dram));
    debug_assert_eq!(id, lay.dram());

    // Peripherals behind the IO crossbar.
    let id = b.add(shared_dom, Box::new(Uart::new("uart".to_string())));
    debug_assert_eq!(id, lay.uart());
    let id = b.add(shared_dom, Box::new(Timer::new("timer".to_string())));
    debug_assert_eq!(id, lay.timer());

    // Central throttles tc_i -> r_i (DOMAIN-CROSSING links).
    for i in 0..n {
        let t = Throttle::new(
            format!("tc{i}"),
            tc_inbox[i].clone(),
            OutLink {
                inbox: r_inbox[i].clone(),
                buf: 1,
                consumer: lay.router(i),
                latency: noc,
            },
            noc,
            sys.data_flits,
        );
        let id = b.add(shared_dom, Box::new(t));
        debug_assert_eq!(id, lay.tc(i));
    }

    BuiltSystem { machine: b.finish(), xbar, layout: lay }
}

/// Build the atomic-protocol system (AtomicCPU / KVMCPU; serial only).
pub fn build_atomic_system(
    cfg: &RunConfig,
    workload: &Workload,
    kvm: bool,
) -> (Machine, std::sync::Arc<std::sync::Mutex<AtomicMem>>) {
    let n = cfg.system.cores;
    let sys = &cfg.system;
    assert_eq!(workload.n_cores(), n);
    let clock = Clock::from_mhz(sys.cpu_mhz);

    let mem = AtomicMem::new(
        n,
        sys.l1d.size_bytes,
        sys.l1d.assoc,
        sys.l2.size_bytes,
        sys.l2.assoc,
        sys.l3.size_bytes,
        sys.l3.assoc,
        sys.line_bytes,
        AtomicLatencies {
            l1: sys.l1d.latency_ns * NS,
            l2: sys.l2.latency_ns * NS,
            l3: sys.l3.latency_ns * NS,
            dram: 50 * NS,
        },
    );

    let mut b = MachineBuilder::new(1, Tick::MAX);
    b.set_queue(cfg.queue);
    b.set_cores(n as u32);
    for i in 0..n {
        if kvm {
            b.add(
                DomainId(0),
                Box::new(KvmCpu::new(
                    format!("kvm{i}"),
                    i as u16,
                    mem.clone(),
                    workload.cores[i].clone(),
                )),
            );
        } else {
            b.add(
                DomainId(0),
                Box::new(AtomicCpu::new(
                    format!("atomic{i}"),
                    i as u16,
                    clock,
                    mem.clone(),
                    workload.cores[i].clone(),
                )),
            );
        }
    }
    (b.finish(), mem)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_ids_disjoint() {
        let lay = Layout { cores: 3 };
        let mut all = vec![];
        for i in 0..3 {
            all.extend([
                lay.cpu(i),
                lay.seq(i),
                lay.l1i(i),
                lay.l1d(i),
                lay.l2(i),
                lay.router(i),
                lay.throttle(i),
                lay.tc(i),
            ]);
        }
        all.extend([lay.rc(), lay.hnf(), lay.dram(), lay.uart(), lay.timer()]);
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n);
    }
}
