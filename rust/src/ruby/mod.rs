//! The Ruby-like coherent memory subsystem (§3.4) plus the paper's
//! thread-safe message passing (§4.2).
//!
//! * [`msg`] — the CHI-lite protocol vocabulary (plus [`StagedMsg`], the
//!   border-ordered handoff's staging record).
//! * [`inbox`] — MessageBuffers behind per-consumer shared wakeup mutexes,
//!   and the deterministic border-ordered cross-domain handoff
//!   (`--inbox-order`, DESIGN.md §6).
//! * [`l1`], [`l2`], [`hnf`] — the cache-controller state machines.
//! * [`router`], [`throttle`] — the NoC (Fig. 5c deadlock-free links).
//! * [`sequencer`] — packet ↔ message conversion + the IO-crossbar path.
//! * [`topology`] — [`crate::spec::SystemSpec`] elaboration (star / ring /
//!   mesh fabrics) and domain partitioning.

pub mod hnf;
pub mod inbox;
pub mod l1;
pub mod l2;
pub mod msg;
pub mod router;
pub mod sequencer;
pub mod throttle;
pub mod topology;

pub use inbox::{
    merge_staged_for_border, new_inbox, Inbox, MessageBuffer, OutLink,
    SharedInbox,
};
pub use msg::{MsgKind, RubyMsg, StagedMsg};
pub use topology::{
    build_atomic_system, build_from_spec, build_system, BuiltSystem, Layout,
};
