//! Private L2 cache controller — the per-core RN-F coherence point.
//!
//! Holds the MESI state for all lines the core caches (inclusive of its
//! L1s). Misses and upgrades go to the HN-F over the NoC; snoops from the
//! HN-F are answered here and back-propagated to the L1s as fire-and-forget
//! invalidations/downgrades.
//!
//! Races handled (all observable in the parallel runs):
//! * late write-back: a `WriteBackFull` in flight when a snoop arrives is
//!   answered from the local write-back buffer; the HN-F drops stale WBs
//!   whose directory owner has already changed.
//! * snoop-while-pending: a snoop for a line with an outstanding fill
//!   answers from the current (usually Invalid) state; the pending fill
//!   installs fresh permission granted *after* the snooping transaction by
//!   the HN-F's per-line serialisation.
//! * shared-fill-then-store: a store waiting on a `ReadShared` fill
//!   re-issues as `ReadUnique` when the granted state is not writable.

use rustc_hash::FxHashMap;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::mem::{CacheArray, LineState};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::ids::CompId;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

use super::inbox::{OutLink, SharedInbox};
use super::msg::{MsgKind, RubyMsg};

pub const L2_BUF_FROM_L1I: usize = 0;
pub const L2_BUF_FROM_L1D: usize = 1;
pub const L2_BUF_FROM_NOC: usize = 2;

struct Mshr {
    /// Waiting original requests (SeqReq stores / ReadShared loads).
    waiters: Vec<RubyMsg>,
    /// The request in flight asks for unique (write) permission (kept for
    /// asserts/debugging; replay re-derives the need from the grant).
    #[allow(dead_code)]
    want_unique: bool,
}

pub struct L2Ctrl {
    name: String,
    array: CacheArray,
    inbox: SharedInbox,
    to_l1i: OutLink,
    to_l1d: OutLink,
    to_noc: OutLink,
    /// Protocol destination of NoC requests (the HN-F).
    hnf: CompId,
    latency: Tick,
    mshr: FxHashMap<u64, Mshr>,
    /// Dirty evictions awaiting the HN-F's Comp ack: line -> data.
    wb_buffer: FxHashMap<u64, u64>,
    // stats
    stores: u64,
    store_hits_writable: u64,
    upgrades: u64,
    writebacks: u64,
    snoops: u64,
    snoop_hits: u64,
    replays: u64,
    /// Reusable wakeup drain buffer (perf: no alloc per wakeup).
    scratch: Vec<RubyMsg>,
}

impl L2Ctrl {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        size_bytes: u64,
        assoc: usize,
        line_bytes: u64,
        latency: Tick,
        inbox: SharedInbox,
        to_l1i: OutLink,
        to_l1d: OutLink,
        to_noc: OutLink,
        hnf: CompId,
    ) -> Self {
        L2Ctrl {
            name,
            array: CacheArray::new(size_bytes, assoc, line_bytes),
            inbox,
            to_l1i,
            to_l1d,
            to_noc,
            hnf,
            latency,
            mshr: FxHashMap::default(),
            wb_buffer: FxHashMap::default(),
            stores: 0,
            store_hits_writable: 0,
            upgrades: 0,
            writebacks: 0,
            snoops: 0,
            snoop_hits: 0,
            replays: 0,
            scratch: Vec::new(),
        }
    }

    fn l1_link(&self, l1: CompId) -> &OutLink {
        if l1 == self.to_l1i.consumer {
            &self.to_l1i
        } else {
            &self.to_l1d
        }
    }

    /// Send a request to the HN-F over the NoC.
    fn request_noc(&mut self, ctx: &mut Ctx, kind: MsgKind, template: &RubyMsg) {
        let req = RubyMsg {
            kind,
            addr: template.addr,
            value: template.value,
            src: ctx.self_id(),
            dst: self.hnf,
            txn: template.txn,
            core: template.core,
            issued: template.issued,
        };
        let ok = self.to_noc.send(ctx, req, 0);
        debug_assert!(ok, "L2->router request buffer is unbounded");
    }

    /// Evict a victim produced by an allocation: write back dirty data,
    /// notify clean evictions, back-invalidate the L1s (inclusivity).
    fn evict_victim(&mut self, ctx: &mut Ctx, victim: crate::mem::Victim) {
        let inval = RubyMsg {
            kind: MsgKind::SnpUnique,
            addr: victim.addr,
            value: 0,
            src: ctx.self_id(),
            dst: CompId::NONE,
            txn: 0,
            core: 0,
            issued: ctx.now(),
        };
        let ok = self
            .to_l1i
            .send(ctx, RubyMsg { dst: self.to_l1i.consumer, ..inval }, 0);
        debug_assert!(ok);
        let ok = self
            .to_l1d
            .send(ctx, RubyMsg { dst: self.to_l1d.consumer, ..inval }, 0);
        debug_assert!(ok);

        let template = RubyMsg {
            kind: MsgKind::Evict,
            addr: victim.addr,
            value: victim.data,
            src: ctx.self_id(),
            dst: self.hnf,
            txn: 0,
            core: 0,
            issued: ctx.now(),
        };
        if victim.state == LineState::Modified {
            self.writebacks += 1;
            self.wb_buffer.insert(victim.addr, victim.data);
            self.request_noc(ctx, MsgKind::WriteBackFull, &template);
        } else {
            self.request_noc(ctx, MsgKind::Evict, &template);
        }
    }

    /// A load request from an L1 (ReadShared) or a store (SeqReq).
    fn on_l1_request(&mut self, msg: RubyMsg, ctx: &mut Ctx) {
        let line = self.array.line_addr(msg.addr);
        let is_store = matches!(msg.kind, MsgKind::SeqReq { is_store: true });
        if is_store {
            self.stores += 1;
        }

        if let Some(pending) = self.mshr.get_mut(&line) {
            pending.waiters.push(msg);
            return;
        }

        if let Some(l) = self.array.access(line) {
            if !is_store {
                // Load hit at any valid state.
                let value = l.data;
                let resp = msg.respond(
                    MsgKind::CompData { state: LineState::Shared },
                    ctx.self_id(),
                    value,
                );
                let link = self.l1_link(msg.src);
                let ok = link.send(ctx, resp, self.latency);
                debug_assert!(ok);
                return;
            }
            if l.state.is_writable() {
                // Store hit with permission.
                l.data = msg.value;
                l.state = LineState::Modified;
                self.store_hits_writable += 1;
                let resp = msg.respond(MsgKind::Comp, ctx.self_id(), 0);
                let link = self.l1_link(msg.src);
                let ok = link.send(ctx, resp, self.latency);
                debug_assert!(ok);
                return;
            }
            // Store hit on Shared: upgrade.
            self.upgrades += 1;
            self.mshr
                .insert(line, Mshr { waiters: vec![msg], want_unique: true });
            let template = RubyMsg { addr: line, ..msg };
            self.request_noc(ctx, MsgKind::ReadUnique, &template);
            return;
        }

        // Miss.
        let want_unique = is_store;
        self.mshr
            .insert(line, Mshr { waiters: vec![msg], want_unique });
        let template = RubyMsg { addr: line, ..msg };
        self.request_noc(
            ctx,
            if want_unique { MsgKind::ReadUnique } else { MsgKind::ReadShared },
            &template,
        );
    }

    /// Fill from the HN-F: install, then replay waiters.
    fn on_comp_data(&mut self, msg: RubyMsg, granted: LineState, ctx: &mut Ctx) {
        let line = msg.addr;
        if let Some(v) = self.array.allocate(line, granted, msg.value) {
            self.evict_victim(ctx, v);
        }
        let Some(pending) = self.mshr.remove(&line) else {
            return; // spurious (e.g. upgrade raced with invalidation)
        };
        let mut unsatisfied_stores: Vec<RubyMsg> = Vec::new();
        for w in pending.waiters {
            self.replays += 1;
            let is_store =
                matches!(w.kind, MsgKind::SeqReq { is_store: true });
            if !is_store {
                let l = self.array.peek(line).expect("just installed");
                let resp = w.respond(
                    MsgKind::CompData { state: LineState::Shared },
                    ctx.self_id(),
                    l.data,
                );
                let link = self.l1_link(w.src);
                let ok = link.send(ctx, resp, self.latency);
                debug_assert!(ok);
                continue;
            }
            let l = self.array.peek_mut(line).expect("just installed");
            if l.state.is_writable() {
                l.data = w.value;
                l.state = LineState::Modified;
                let resp = w.respond(MsgKind::Comp, ctx.self_id(), 0);
                let link = self.l1_link(w.src);
                let ok = link.send(ctx, resp, self.latency);
                debug_assert!(ok);
            } else {
                unsatisfied_stores.push(w);
            }
        }
        if let Some(first) = unsatisfied_stores.first().copied() {
            // Granted Shared but stores still waiting: re-issue as unique.
            self.upgrades += 1;
            let template = RubyMsg { addr: line, ..first };
            self.mshr.insert(
                line,
                Mshr { waiters: unsatisfied_stores, want_unique: true },
            );
            self.request_noc(ctx, MsgKind::ReadUnique, &template);
        }
    }

    /// Snoop from the HN-F.
    fn on_snoop(&mut self, msg: RubyMsg, ctx: &mut Ctx) {
        self.snoops += 1;
        let line = msg.addr;
        let invalidate = msg.kind == MsgKind::SnpUnique;

        // Late-WB race: answer from the write-back buffer.
        if let Some(&data) = self.wb_buffer.get(&line) {
            let resp = msg.respond(
                MsgKind::SnpResp { dirty: true, had_copy: true },
                ctx.self_id(),
                data,
            );
            let ok = self.to_noc.send(ctx, resp, 0);
            debug_assert!(ok);
            return;
        }

        let (dirty, had_copy, data) = match self.array.peek(line) {
            None => (false, false, 0),
            Some(l) => (l.state == LineState::Modified, true, l.data),
        };
        if had_copy {
            self.snoop_hits += 1;
            if invalidate {
                self.array.invalidate(line);
            } else if let Some(l) = self.array.peek_mut(line) {
                l.state = LineState::Shared;
            }
            // Back-propagate to the L1s (inclusive hierarchy).
            let snp = RubyMsg {
                kind: if invalidate { MsgKind::SnpUnique } else { MsgKind::SnpShared },
                addr: line,
                value: 0,
                src: ctx.self_id(),
                dst: CompId::NONE,
                txn: 0,
                core: 0,
                issued: ctx.now(),
            };
            if invalidate {
                let ok = self
                    .to_l1i
                    .send(ctx, RubyMsg { dst: self.to_l1i.consumer, ..snp }, 0);
                debug_assert!(ok);
                let ok = self
                    .to_l1d
                    .send(ctx, RubyMsg { dst: self.to_l1d.consumer, ..snp }, 0);
                debug_assert!(ok);
            }
        }
        let resp = msg.respond(
            MsgKind::SnpResp { dirty, had_copy },
            ctx.self_id(),
            data,
        );
        let ok = self.to_noc.send(ctx, resp, self.latency);
        debug_assert!(ok);
    }
}

impl Component for L2Ctrl {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::ConsumerWakeup => {
                let mut ready = std::mem::take(&mut self.scratch);
                super::inbox::drain_for_wakeup_into(&self.inbox, ctx, &mut ready);
                for msg in ready.drain(..) {
                    match msg.kind {
                        MsgKind::ReadShared | MsgKind::SeqReq { .. } => {
                            self.on_l1_request(msg, ctx)
                        }
                        MsgKind::CompData { state } => {
                            self.on_comp_data(msg, state, ctx)
                        }
                        MsgKind::SnpShared | MsgKind::SnpUnique => {
                            self.on_snoop(msg, ctx)
                        }
                        // HN-F acknowledged our write-back.
                        MsgKind::Comp => {
                            self.wb_buffer.remove(&msg.addr);
                        }
                        other => {
                            panic!("{}: unexpected msg {other:?}", self.name)
                        }
                    }
                }
                self.scratch = ready;
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    /// Border-ordered handoff (`--inbox-order border`): merge the
    /// cross-domain deliveries staged for this inbox during the closed
    /// window, in canonical order (DESIGN.md §6).
    fn border_merge(&mut self, ctx: &mut Ctx) {
        super::inbox::merge_staged_for_border(&self.inbox, ctx);
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("hits", self.array.hits);
        out.add_u64("misses", self.array.misses);
        out.add("miss_rate", self.array.miss_rate());
        out.add_u64("stores", self.stores);
        out.add_u64("store_hits_writable", self.store_hits_writable);
        out.add_u64("upgrades", self.upgrades);
        out.add_u64("writebacks", self.writebacks);
        out.add_u64("snoops", self.snoops);
        out.add_u64("snoop_hits", self.snoop_hits);
        out.add_u64("replays", self.replays);
    }

    fn save_state(&self, w: &mut StateWriter) {
        self.array.save_ckpt(w);
        self.inbox.lock().unwrap().save_ckpt(w);
        let mut mshr: Vec<(&u64, &Mshr)> = self.mshr.iter().collect();
        mshr.sort_unstable_by_key(|&(&line, _)| line);
        w.usize(mshr.len());
        for (&line, m) in mshr {
            w.u64(line);
            w.bool(m.want_unique);
            w.usize(m.waiters.len());
            for msg in &m.waiters {
                w.msg(msg);
            }
        }
        let mut wb: Vec<(u64, u64)> =
            self.wb_buffer.iter().map(|(&k, &v)| (k, v)).collect();
        wb.sort_unstable_by_key(|&(k, _)| k);
        w.usize(wb.len());
        for (line, data) in wb {
            w.u64(line);
            w.u64(data);
        }
        w.u64(self.stores);
        w.u64(self.store_hits_writable);
        w.u64(self.upgrades);
        w.u64(self.writebacks);
        w.u64(self.snoops);
        w.u64(self.snoop_hits);
        w.u64(self.replays);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.array.restore_ckpt(r)?;
        self.inbox.lock().unwrap().restore_ckpt(r)?;
        self.mshr.clear();
        for _ in 0..r.usize()? {
            let line = r.u64()?;
            let want_unique = r.bool()?;
            let mut waiters = Vec::new();
            for _ in 0..r.usize()? {
                waiters.push(r.msg()?);
            }
            self.mshr.insert(line, Mshr { waiters, want_unique });
        }
        self.wb_buffer.clear();
        for _ in 0..r.usize()? {
            let line = r.u64()?;
            let data = r.u64()?;
            self.wb_buffer.insert(line, data);
        }
        self.stores = r.u64()?;
        self.store_hits_writable = r.u64()?;
        self.upgrades = r.u64()?;
        self.writebacks = r.u64()?;
        self.snoops = r.u64()?;
        self.snoop_hits = r.u64()?;
        self.replays = r.u64()?;
        Ok(())
    }
}
