//! HN-F: the home node — shared L3, full-map directory, per-line
//! transaction serialisation, and the DRAM gateway.
//!
//! Every line has at most one transaction in flight; requests for a busy
//! line queue at the HN-F and are replayed on completion. This per-line
//! serialisation is what makes the L2-side race handling sound (see
//! [`super::l2`]).
//!
//! The directory is a precise full map (owner + sharers per line); the L3
//! array has finite capacity and writes dirty victims back to DRAM. DRAM is
//! reached with the classic timing protocol (`MemReq`/`MemResp` events) —
//! both HN-F and DRAM live in the shared domain, so this link never crosses
//! domains.

use std::collections::VecDeque;

use rustc_hash::FxHashMap;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::mem::{CacheArray, LineState};
use crate::proto::{Cmd, Packet};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::ids::CompId;
use crate::sim::stats::StatSink;
use crate::sim::time::Tick;

use super::inbox::{OutLink, SharedInbox};
use super::msg::{MsgKind, RubyMsg};

pub const HNF_BUF_FROM_NOC: usize = 0;

#[derive(Default, Clone, Debug)]
struct DirEntry {
    /// L2 holding the line Exclusive/Modified.
    owner: Option<CompId>,
    /// L2s holding the line Shared.
    sharers: Vec<CompId>,
}

impl DirEntry {
    fn is_empty(&self) -> bool {
        self.owner.is_none() && self.sharers.is_empty()
    }

    fn remove(&mut self, who: CompId) {
        if self.owner == Some(who) {
            self.owner = None;
        }
        self.sharers.retain(|&s| s != who);
    }
}

struct Txn {
    req: RubyMsg,
    pending_acks: u32,
    data: Option<u64>,
    data_dirty: bool,
    mem_pending: bool,
}

pub struct HnfCtrl {
    name: String,
    l3: CacheArray,
    dir: FxHashMap<u64, DirEntry>,
    inbox: SharedInbox,
    to_noc: OutLink,
    /// DRAM channel controllers, line-interleaved by address
    /// ([`HnfCtrl::dram_for`]); a single-channel system has one entry.
    drams: Vec<CompId>,
    line_bytes: u64,
    latency: Tick,
    busy: FxHashMap<u64, Txn>,
    waiting: FxHashMap<u64, VecDeque<RubyMsg>>,
    // stats
    read_shared: u64,
    read_unique: u64,
    snoops_sent: u64,
    writebacks: u64,
    stale_writebacks: u64,
    dram_reads: u64,
    dram_wbs: u64,
    requeued: u64,
    self_owner_refetch: u64,
    /// Reusable wakeup drain buffer (perf: no alloc per wakeup).
    scratch: Vec<RubyMsg>,
}

impl HnfCtrl {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        size_bytes: u64,
        assoc: usize,
        line_bytes: u64,
        latency: Tick,
        inbox: SharedInbox,
        to_noc: OutLink,
        drams: Vec<CompId>,
    ) -> Self {
        assert!(!drams.is_empty(), "HN-F needs at least one DRAM channel");
        HnfCtrl {
            name,
            l3: CacheArray::new(size_bytes, assoc, line_bytes),
            dir: FxHashMap::default(),
            inbox,
            to_noc,
            drams,
            line_bytes,
            latency,
            busy: FxHashMap::default(),
            waiting: FxHashMap::default(),
            read_shared: 0,
            read_unique: 0,
            snoops_sent: 0,
            writebacks: 0,
            stale_writebacks: 0,
            dram_reads: 0,
            dram_wbs: 0,
            requeued: 0,
            self_owner_refetch: 0,
            scratch: Vec::new(),
        }
    }

    fn send_noc(&self, ctx: &mut Ctx, msg: RubyMsg, extra: Tick) {
        let ok = self.to_noc.send(ctx, msg, extra);
        debug_assert!(ok, "HNF->router buffer is unbounded");
    }

    /// The DRAM channel serving `addr` (line-interleaved).
    fn dram_for(&self, addr: u64) -> CompId {
        self.drams[(addr / self.line_bytes) as usize % self.drams.len()]
    }

    /// Allocate in L3, writing dirty victims back to DRAM.
    fn l3_fill(&mut self, ctx: &mut Ctx, line: u64, state: LineState, data: u64) {
        if let Some(v) = self.l3.allocate(line, state, data) {
            if v.state == LineState::Modified {
                self.dram_wbs += 1;
                let pkt = Packet::request(
                    v.addr,
                    Cmd::WriteReq,
                    v.addr,
                    64,
                    v.data,
                    ctx.self_id(),
                    u16::MAX,
                    ctx.now(),
                );
                let ch = self.dram_for(v.addr);
                ctx.schedule(0, ch, EventKind::MemReq { pkt });
            }
        }
    }

    /// Get data for a txn from L3 or start a DRAM read.
    fn l3_or_mem(&mut self, ctx: &mut Ctx, line: u64) {
        let hit = self.l3.access(line).map(|l| l.data);
        let txn = self.busy.get_mut(&line).expect("txn exists");
        match hit {
            Some(data) => {
                txn.data = Some(data);
                self.try_complete(ctx, line);
            }
            None => {
                txn.mem_pending = true;
                self.dram_reads += 1;
                let pkt = Packet::request(
                    line,
                    Cmd::ReadReq,
                    line,
                    64,
                    0,
                    ctx.self_id(),
                    txn.req.core,
                    txn.req.issued,
                );
                let ch = self.dram_for(line);
                ctx.schedule(0, ch, EventKind::MemReq { pkt });
            }
        }
    }

    /// Begin (or queue) a coherent request.
    fn start_request(&mut self, msg: RubyMsg, ctx: &mut Ctx) {
        let line = msg.addr;
        if self.busy.contains_key(&line) {
            self.requeued += 1;
            self.waiting.entry(line).or_default().push_back(msg);
            return;
        }
        let requester = msg.src;
        let entry = self.dir.entry(line).or_default().clone();

        match msg.kind {
            MsgKind::ReadShared => {
                self.read_shared += 1;
                let txn = Txn {
                    req: msg,
                    pending_acks: 0,
                    data: None,
                    data_dirty: false,
                    mem_pending: false,
                };
                self.busy.insert(line, txn);
                match entry.owner {
                    Some(owner) if owner != requester => {
                        self.snoops_sent += 1;
                        self.busy.get_mut(&line).unwrap().pending_acks = 1;
                        let snp = RubyMsg {
                            kind: MsgKind::SnpShared,
                            addr: line,
                            value: 0,
                            src: ctx.self_id(),
                            dst: owner,
                            txn: msg.txn,
                            core: msg.core,
                            issued: msg.issued,
                        };
                        self.send_noc(ctx, snp, self.latency);
                    }
                    Some(_) => {
                        // Requester believes it misses while we track it as
                        // owner: a stale-directory refetch race; clear and
                        // serve from L3/DRAM.
                        self.self_owner_refetch += 1;
                        self.dir.get_mut(&line).unwrap().owner = None;
                        self.l3_or_mem(ctx, line);
                    }
                    None => self.l3_or_mem(ctx, line),
                }
            }
            MsgKind::ReadUnique => {
                self.read_unique += 1;
                let mut to_snoop: Vec<CompId> = Vec::new();
                if let Some(owner) = entry.owner {
                    if owner != requester {
                        to_snoop.push(owner);
                    }
                }
                for &s in &entry.sharers {
                    if s != requester {
                        to_snoop.push(s);
                    }
                }
                // The requester's own stale copy is invalidated implicitly
                // by the grant; drop it from the directory now.
                self.dir.entry(line).or_default().remove(requester);

                let txn = Txn {
                    req: msg,
                    pending_acks: to_snoop.len() as u32,
                    data: None,
                    data_dirty: false,
                    mem_pending: false,
                };
                self.busy.insert(line, txn);
                for target in to_snoop {
                    self.snoops_sent += 1;
                    let snp = RubyMsg {
                        kind: MsgKind::SnpUnique,
                        addr: line,
                        value: 0,
                        src: ctx.self_id(),
                        dst: target,
                        txn: msg.txn,
                        core: msg.core,
                        issued: msg.issued,
                    };
                    self.send_noc(ctx, snp, self.latency);
                }
                if self.busy[&line].pending_acks == 0 {
                    self.l3_or_mem(ctx, line);
                }
            }
            other => panic!("start_request: {other:?}"),
        }
    }

    /// Instant (non-transactional) handlers: write-backs and evict notices.
    fn on_writeback(&mut self, msg: RubyMsg, full: bool, ctx: &mut Ctx) {
        let line = msg.addr;
        if self.busy.contains_key(&line) {
            self.requeued += 1;
            self.waiting.entry(line).or_default().push_back(msg);
            return;
        }
        let entry = self.dir.entry(line).or_default();
        if full {
            if entry.owner == Some(msg.src) {
                self.writebacks += 1;
                entry.owner = None;
                self.l3_fill(ctx, line, LineState::Modified, msg.value);
            } else {
                // Stale WB: a snoop already collected newer data.
                self.stale_writebacks += 1;
            }
            let ack = msg.respond(MsgKind::Comp, ctx.self_id(), 0);
            self.send_noc(ctx, ack, self.latency);
        } else {
            // Clean evict notice, fire-and-forget.
            entry.remove(msg.src);
        }
    }

    fn on_snoop_resp(
        &mut self,
        msg: RubyMsg,
        dirty: bool,
        had_copy: bool,
        ctx: &mut Ctx,
    ) {
        let line = msg.addr;
        let Some(txn) = self.busy.get_mut(&line) else {
            return; // response to a cancelled txn (cannot happen; defensive)
        };
        let entry = self.dir.entry(line).or_default();
        let was_shared_snoop = txn.req.kind == MsgKind::ReadShared;
        if was_shared_snoop {
            // SnpShared: old owner downgrades to sharer (if it had a copy).
            if entry.owner == Some(msg.src) {
                entry.owner = None;
                if had_copy {
                    entry.sharers.push(msg.src);
                }
            }
        } else {
            entry.remove(msg.src);
        }
        let txn = self.busy.get_mut(&line).unwrap();
        txn.pending_acks -= 1;
        if dirty {
            txn.data = Some(msg.value);
            txn.data_dirty = true;
        }
        if txn.pending_acks == 0 {
            if txn.data.is_some() {
                self.try_complete(ctx, line);
            } else {
                self.l3_or_mem(ctx, line);
            }
        }
    }

    fn on_mem_resp(&mut self, pkt: Packet, ctx: &mut Ctx) {
        if pkt.cmd == Cmd::WriteResp {
            return; // dirty-victim write-back acknowledged
        }
        let line = pkt.id;
        // Fill L3 with clean data from memory.
        self.l3_fill(ctx, line, LineState::Shared, pkt.value);
        if let Some(txn) = self.busy.get_mut(&line) {
            txn.mem_pending = false;
            txn.data = Some(pkt.value);
            self.try_complete(ctx, line);
        }
    }

    /// Complete the transaction for `line` if data is ready and acks are in.
    fn try_complete(&mut self, ctx: &mut Ctx, line: u64) {
        let Some(txn) = self.busy.get(&line) else { return };
        if txn.pending_acks > 0 || txn.mem_pending || txn.data.is_none() {
            return;
        }
        let txn = self.busy.remove(&line).unwrap();
        let requester = txn.req.src;
        let data = txn.data.unwrap();
        let entry = self.dir.entry(line).or_default();

        let grant = match txn.req.kind {
            MsgKind::ReadShared => {
                if txn.data_dirty {
                    // Absorb dirty data into the L3.
                    self.l3_fill(ctx, line, LineState::Modified, data);
                }
                let entry = self.dir.entry(line).or_default();
                if entry.is_empty() {
                    entry.owner = Some(requester);
                    LineState::Exclusive
                } else {
                    entry.sharers.push(requester);
                    LineState::Shared
                }
            }
            MsgKind::ReadUnique => {
                entry.sharers.clear();
                entry.owner = Some(requester);
                LineState::Modified
            }
            other => panic!("try_complete: {other:?}"),
        };

        let resp = txn.req.respond(
            MsgKind::CompData { state: grant },
            ctx.self_id(),
            data,
        );
        self.send_noc(ctx, resp, self.latency);

        // Replay the next queued message for this line.
        if let Some(q) = self.waiting.get_mut(&line) {
            if let Some(next) = q.pop_front() {
                if q.is_empty() {
                    self.waiting.remove(&line);
                }
                self.dispatch(next, ctx);
            } else {
                self.waiting.remove(&line);
            }
        }
    }

    fn dispatch(&mut self, msg: RubyMsg, ctx: &mut Ctx) {
        match msg.kind {
            MsgKind::ReadShared | MsgKind::ReadUnique => {
                self.start_request(msg, ctx)
            }
            MsgKind::WriteBackFull => self.on_writeback(msg, true, ctx),
            MsgKind::Evict => self.on_writeback(msg, false, ctx),
            MsgKind::SnpResp { dirty, had_copy } => {
                self.on_snoop_resp(msg, dirty, had_copy, ctx)
            }
            other => panic!("{}: unexpected msg {other:?}", self.name),
        }
    }
}

impl Component for HnfCtrl {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::ConsumerWakeup => {
                let mut ready = std::mem::take(&mut self.scratch);
                super::inbox::drain_for_wakeup_into(&self.inbox, ctx, &mut ready);
                for msg in ready.drain(..) {
                    self.dispatch(msg, ctx);
                }
                self.scratch = ready;
            }
            EventKind::MemResp { pkt } => self.on_mem_resp(pkt, ctx),
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    /// Border-ordered handoff (`--inbox-order border`): merge the
    /// cross-domain deliveries staged for this inbox during the closed
    /// window, in canonical order (DESIGN.md §6).
    fn border_merge(&mut self, ctx: &mut Ctx) {
        super::inbox::merge_staged_for_border(&self.inbox, ctx);
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("hits", self.l3.hits);
        out.add_u64("misses", self.l3.misses);
        out.add("miss_rate", self.l3.miss_rate());
        out.add_u64("read_shared", self.read_shared);
        out.add_u64("read_unique", self.read_unique);
        out.add_u64("snoops_sent", self.snoops_sent);
        out.add_u64("writebacks", self.writebacks);
        out.add_u64("stale_writebacks", self.stale_writebacks);
        out.add_u64("dram_reads", self.dram_reads);
        out.add_u64("dram_writebacks", self.dram_wbs);
        out.add_u64("requeued", self.requeued);
        out.add_u64("self_owner_refetch", self.self_owner_refetch);
    }

    fn save_state(&self, w: &mut StateWriter) {
        self.l3.save_ckpt(w);
        self.inbox.lock().unwrap().save_ckpt(w);
        // Directory: sorted by line; empty entries elided (they are
        // recreated on demand and would otherwise make the bytes depend on
        // access history rather than architectural state).
        let mut dir: Vec<(&u64, &DirEntry)> =
            self.dir.iter().filter(|(_, e)| !e.is_empty()).collect();
        dir.sort_unstable_by_key(|&(&line, _)| line);
        w.usize(dir.len());
        for (&line, e) in dir {
            w.u64(line);
            w.opt_comp_id(e.owner);
            // Sharer order is architectural: `try_complete` pushes in
            // arrival order and snoop fan-out follows it.
            w.usize(e.sharers.len());
            for &s in &e.sharers {
                w.comp_id(s);
            }
        }
        let mut busy: Vec<(&u64, &Txn)> = self.busy.iter().collect();
        busy.sort_unstable_by_key(|&(&line, _)| line);
        w.usize(busy.len());
        for (&line, t) in busy {
            w.u64(line);
            w.msg(&t.req);
            w.u32(t.pending_acks);
            w.opt_u64(t.data);
            w.bool(t.data_dirty);
            w.bool(t.mem_pending);
        }
        let mut waiting: Vec<(&u64, &VecDeque<RubyMsg>)> =
            self.waiting.iter().collect();
        waiting.sort_unstable_by_key(|&(&line, _)| line);
        w.usize(waiting.len());
        for (&line, q) in waiting {
            w.u64(line);
            w.usize(q.len());
            for msg in q {
                w.msg(msg);
            }
        }
        w.u64(self.read_shared);
        w.u64(self.read_unique);
        w.u64(self.snoops_sent);
        w.u64(self.writebacks);
        w.u64(self.stale_writebacks);
        w.u64(self.dram_reads);
        w.u64(self.dram_wbs);
        w.u64(self.requeued);
        w.u64(self.self_owner_refetch);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.l3.restore_ckpt(r)?;
        self.inbox.lock().unwrap().restore_ckpt(r)?;
        self.dir.clear();
        for _ in 0..r.usize()? {
            let line = r.u64()?;
            let owner = r.opt_comp_id()?;
            let mut sharers = Vec::new();
            for _ in 0..r.usize()? {
                sharers.push(r.comp_id()?);
            }
            self.dir.insert(line, DirEntry { owner, sharers });
        }
        self.busy.clear();
        for _ in 0..r.usize()? {
            let line = r.u64()?;
            let req = r.msg()?;
            let pending_acks = r.u32()?;
            let data = r.opt_u64()?;
            let data_dirty = r.bool()?;
            let mem_pending = r.bool()?;
            self.busy.insert(
                line,
                Txn { req, pending_acks, data, data_dirty, mem_pending },
            );
        }
        self.waiting.clear();
        for _ in 0..r.usize()? {
            let line = r.u64()?;
            let mut q = VecDeque::new();
            for _ in 0..r.usize()? {
                q.push_back(r.msg()?);
            }
            self.waiting.insert(line, q);
        }
        self.read_shared = r.u64()?;
        self.read_unique = r.u64()?;
        self.snoops_sent = r.u64()?;
        self.writebacks = r.u64()?;
        self.stale_writebacks = r.u64()?;
        self.dram_reads = r.u64()?;
        self.dram_wbs = r.u64()?;
        self.requeued = r.u64()?;
        self.self_owner_refetch = r.u64()?;
        Ok(())
    }
}
