//! Ruby messages: the CHI-lite coherence protocol vocabulary.
//!
//! A trimmed-down ARM AMBA CHI dialect (DESIGN.md §3 maps it to the paper's
//! full CHI-via-SLICC configuration): requests flow RN(L2) → HN-F, snoops
//! HN-F → RN, data/ack responses complete the transaction. The sequencer
//! speaks `SeqReq`/`SeqResp` to the L1s, mirroring gem5's packet↔message
//! conversion (§3.4).

use crate::mem::LineState;
use crate::sim::ids::CompId;
use crate::sim::time::Tick;

#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum MsgKind {
    // ---- sequencer <-> L1 --------------------------------------------
    /// CPU access (load if `!is_store`), line-granular.
    SeqReq { is_store: bool },
    /// Completion back to the sequencer; `value` holds load data.
    SeqResp,

    // ---- RN requests (L1->L2, L2->HNF) --------------------------------
    /// Read with shared permission (CHI ReadShared).
    ReadShared,
    /// Read with unique/write permission (CHI ReadUnique / CleanUnique).
    ReadUnique,
    /// Dirty eviction carrying data (CHI WriteBackFull).
    WriteBackFull,
    /// Clean-eviction notice keeping the directory precise (CHI Evict).
    Evict,

    // ---- snoops (HNF->L2, L2->L1 back-invalidation) --------------------
    /// Downgrade to Shared, return data if dirty (CHI SnpShared).
    SnpShared,
    /// Invalidate, return data if dirty (CHI SnpUnique).
    SnpUnique,

    // ---- responses -----------------------------------------------------
    /// Data grant with the state the receiver may install (CHI CompData).
    CompData { state: LineState },
    /// Snoop response; `dirty` means `value` carries modified data.
    SnpResp { dirty: bool, had_copy: bool },
    /// Write-back / evict acknowledgement (CHI Comp).
    Comp,
}

impl MsgKind {
    /// Control messages (no payload) vs data-carrying messages — used by
    /// the throttle to charge link occupancy.
    pub fn carries_data(self) -> bool {
        matches!(
            self,
            MsgKind::WriteBackFull
                | MsgKind::CompData { .. }
                | MsgKind::SnpResp { dirty: true, .. }
        )
    }
}

/// A message travelling between Ruby nodes.
#[derive(Copy, Clone, Debug)]
pub struct RubyMsg {
    pub kind: MsgKind,
    /// Line-aligned address.
    pub addr: u64,
    /// Functional payload.
    pub value: u64,
    /// Protocol-level sender (where responses should go back to).
    pub src: CompId,
    /// Final destination consumer — routers forward until it is reached.
    pub dst: CompId,
    /// Transaction id allocated by the issuing CPU (matching).
    pub txn: u64,
    /// Issuing core (stats / functional checks).
    pub core: u16,
    /// Tick the original CPU op was issued (latency stats).
    pub issued: Tick,
}

impl RubyMsg {
    /// A response to this message, swapping src/dst.
    pub fn respond(&self, kind: MsgKind, from: CompId, value: u64) -> RubyMsg {
        RubyMsg {
            kind,
            addr: self.addr,
            value,
            src: from,
            dst: self.src,
            txn: self.txn,
            core: self.core,
            issued: self.issued,
        }
    }

    /// Forward this message to a new destination, updating the
    /// protocol-level sender.
    pub fn forward(&self, kind: MsgKind, from: CompId, to: CompId) -> RubyMsg {
        RubyMsg { kind, src: from, dst: to, ..*self }
    }
}

/// A cross-domain delivery captured by the border-ordered inbox handoff
/// (`--inbox-order border`, DESIGN.md §6): the message plus its canonical
/// merge key.
///
/// During a quantum window, cross-domain sends do not touch the consumer's
/// [`super::inbox::MessageBuffer`]s; they are staged as `StagedMsg`s inside
/// the consumer's inbox, grouped into one *run* per sending domain. At the
/// border — while every producer is parked at the freeze barrier — the runs
/// are k-way merged into the buffers in `(arrival, sender_dom, seq)` order
/// (the sending domain is the run's key, not stored per message), which is
/// a pure function of the simulation content, never of host thread
/// interleaving.
#[derive(Copy, Clone, Debug)]
pub struct StagedMsg {
    /// Arrival tick at the consumer (`send tick + link latency + extra`).
    pub arrival: Tick,
    /// Per-(inbox, sender-domain) staging sequence — the sender's program
    /// order within the window (its position in the run), deterministic
    /// because a domain's window is executed by exactly one thread (the
    /// claim-list exactly-once guarantee, `sched/steal.rs`).
    pub seq: u64,
    /// Global host-append position within the window, across all runs of
    /// this inbox. Only used to *measure* how far the host order diverged
    /// from the canonical merge order (the `inbox_reordered` counter) —
    /// never to order anything.
    pub host_idx: u32,
    /// Target buffer index within the consumer's inbox.
    pub buf: usize,
    pub msg: RubyMsg,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respond_swaps_endpoints() {
        let m = RubyMsg {
            kind: MsgKind::ReadShared,
            addr: 0x40,
            value: 0,
            src: CompId(1),
            dst: CompId(2),
            txn: 9,
            core: 0,
            issued: 5,
        };
        let r = m.respond(
            MsgKind::CompData { state: LineState::Shared },
            CompId(2),
            77,
        );
        assert_eq!(r.dst, CompId(1));
        assert_eq!(r.src, CompId(2));
        assert_eq!(r.txn, 9);
        assert_eq!(r.value, 77);
    }

    #[test]
    fn data_classification() {
        assert!(MsgKind::WriteBackFull.carries_data());
        assert!(MsgKind::CompData { state: LineState::Shared }.carries_data());
        assert!(!MsgKind::ReadShared.carries_data());
        assert!(!MsgKind::SnpResp { dirty: false, had_copy: true }.carries_data());
        assert!(MsgKind::SnpResp { dirty: true, had_copy: true }.carries_data());
    }
}
