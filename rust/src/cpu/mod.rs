//! CPU models (Table 1 of the paper).
//!
//! | model      | pipeline     | protocol | Ruby | parallel |
//! |------------|--------------|----------|------|----------|
//! | [`KvmCpu`] | n/a (native) | n/a      | ✗    | ffwd only|
//! | [`AtomicCpu`] | none      | atomic   | ✗    | serial   |
//! | [`TimingCpu`] (Minor) | in-order, 1 outstanding | timing | ✓ | **this work** |
//! | [`O3Cpu`] | staged out-of-order (ROB/IQ/LSQ) | timing | ✓ | **this work** |
//!
//! Minor is the flat one-access-at-a-time issue loop; O3 is the staged
//! pipeline of docs/O3.md — fetch/dispatch/issue/writeback/commit per
//! core cycle with many memory requests in flight per sequencer. At the
//! degenerate geometry (every [`crate::spec::CpuSpec`] knob = 1) O3
//! issues the identical memory-request stream as Minor, tick for tick —
//! `tests/o3.rs` gates that equivalence.

pub mod atomic;
pub mod kvm;
pub mod o3;
pub mod timing;

pub use atomic::{AtomicCpu, AtomicLatencies, AtomicMem};
pub use kvm::KvmCpu;
pub use o3::O3Cpu;
pub use timing::{CpuParams, PipelineKind, TimingCpu};

/// Which CPU model drives the cores of a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuModel {
    Kvm,
    Atomic,
    Minor,
    O3,
}

impl CpuModel {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "kvm" => CpuModel::Kvm,
            "atomic" => CpuModel::Atomic,
            "minor" => CpuModel::Minor,
            "o3" => CpuModel::O3,
            _ => return None,
        })
    }

    /// Does this model use the timing protocol + Ruby hierarchy?
    pub fn is_timing(self) -> bool {
        matches!(self, CpuModel::Minor | CpuModel::O3)
    }
}
