//! CPU models (Table 1 of the paper).
//!
//! | model      | pipeline     | protocol | Ruby | parallel |
//! |------------|--------------|----------|------|----------|
//! | [`KvmCpu`] | n/a (native) | n/a      | ✗    | ffwd only|
//! | [`AtomicCpu`] | none      | atomic   | ✗    | serial   |
//! | [`TimingCpu`] Minor | in-order | timing | ✓  | **this work** |
//! | [`TimingCpu`] O3 | out-of-order | timing | ✓ | **this work** |

pub mod atomic;
pub mod kvm;
pub mod timing;

pub use atomic::{AtomicCpu, AtomicLatencies, AtomicMem};
pub use kvm::KvmCpu;
pub use timing::{CpuParams, PipelineKind, TimingCpu};

/// Which CPU model drives the cores of a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuModel {
    Kvm,
    Atomic,
    Minor,
    O3,
}

impl CpuModel {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "kvm" => CpuModel::Kvm,
            "atomic" => CpuModel::Atomic,
            "minor" => CpuModel::Minor,
            "o3" => CpuModel::O3,
            _ => return None,
        })
    }

    /// Does this model use the timing protocol + Ruby hierarchy?
    pub fn is_timing(self) -> bool {
        matches!(self, CpuModel::Minor | CpuModel::O3)
    }
}
