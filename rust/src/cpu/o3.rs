//! The staged out-of-order pipeline model (docs/O3.md, DESIGN.md §12).
//!
//! [`O3Cpu`] replaces the flat issue loop of [`super::TimingCpu`] for
//! `--cpu o3`: every trace op flows through explicit stages — **fetch**
//! (into a small fetch buffer), **dispatch** (in-order, allocates a
//! reorder-buffer and issue-queue slot, pays the compute gap, takes
//! software barriers and blocking ifetches), **issue** (oldest-first out
//! of the issue queue into a split load/store queue, with store-to-load
//! forwarding), **writeback** (Ruby responses mark entries done and free
//! their LSQ slot) and **commit** (in-order retirement from the ROB
//! head). Stages advance inside one core cycle until a fixpoint, so a
//! dependence-free op can flow fetch→dispatch→issue in the cycle it
//! arrives — which is exactly what makes the `width=1, rob=1, iq=1,
//! lsq=1, fetch_buf=1` degeneracy gate hold: the minimal O3 issues every
//! memory request on the same tick as the Minor pipeline
//! (`tests/o3.rs`).
//!
//! Memory-level parallelism is the point: up to `lsq_size` loads and
//! `lsq_size` stores can be in flight at once through the sequencer
//! (whose MSHR-style cap is `CpuSpec::mshrs`,
//! [`crate::ruby::sequencer`]), and compute gaps of younger ops overlap
//! older misses. Same-address ops stay ordered: a load forwards from the
//! youngest older in-ROB store to its address (never issuing a stale
//! read), and a store waits until every older same-address op has
//! completed. IO-window ops ([`crate::xbar`]) never forward and issue in
//! strict program order among themselves, so device side effects happen
//! exactly as the trace orders them.
//!
//! Everything here is a pure function of the simulation — stall
//! counters, the occupancy integral and the forwarding decisions are
//! deterministic, so threaded ≡ virtual bit-identity holds with all
//! counters included, and the whole pipeline state (ROB/IQ/LSQ entries,
//! in-flight map, gap cursor) freezes into the `FLAG_O3` checkpoint
//! format (docs/CHECKPOINT.md §3).

use std::collections::VecDeque;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::proto::{Cmd, Packet};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::{prio, EventKind};
use crate::sim::ids::CompId;
use crate::sim::shared::BarrierOutcome;
use crate::sim::stats::StatSink;
use crate::sim::time::{Clock, Tick};
use crate::spec::CpuSpec;
use crate::workload::CoreTrace;

use super::timing::CpuParams;
use crate::ruby::sequencer::IFETCH_SIZE;

/// Low txn-id bit marking instruction fetches (same scheme as
/// [`super::TimingCpu`]).
const IFETCH_BIT: u64 = 1;

/// Lifecycle of one reorder-buffer entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OpState {
    /// Dispatched, sitting in the issue queue.
    WaitIssue,
    /// Issued to the sequencer, waiting for the Ruby response.
    WaitResp,
    /// Completed (response received or store-to-load forwarded); retires
    /// when it reaches the ROB head.
    Done,
}

impl OpState {
    fn to_u8(self) -> u8 {
        match self {
            OpState::WaitIssue => 0,
            OpState::WaitResp => 1,
            OpState::Done => 2,
        }
    }

    fn from_u8(v: u8, off: usize) -> Result<Self, CkptError> {
        Ok(match v {
            0 => OpState::WaitIssue,
            1 => OpState::WaitResp,
            2 => OpState::Done,
            _ => {
                return Err(CkptError::Corrupt {
                    offset: off,
                    what: format!("bad O3 op state {v}"),
                })
            }
        })
    }
}

/// One in-flight op in the reorder buffer (kept in program order, so the
/// deque is sorted by `idx`).
#[derive(Clone, Debug)]
struct RobEntry {
    /// Trace index (unique — the writeback key).
    idx: usize,
    /// Effective address after IO substitution.
    addr: u64,
    is_store: bool,
    /// Routed through the crossbar IO window (never forwards, strict
    /// program order among IO ops).
    is_io: bool,
    /// Store payload from the trace (the forwarding source value).
    value: u64,
    state: OpState,
    /// Load satisfied by store-to-load forwarding (no LSQ slot, no
    /// memory request).
    forwarded: bool,
}

/// What one dispatch attempt did.
enum Dispatch {
    /// Dispatched an op or sent a blocking ifetch.
    Progress,
    /// Head op cannot move this cycle (capacity, gap, drain, ...).
    Blocked,
    /// Entered a barrier wait — the tick must stop immediately.
    Parked,
}

/// The staged out-of-order core (module docs above; knobs in
/// [`CpuSpec`], ifetch/IO plumbing shared with [`CpuParams`]).
pub struct O3Cpu {
    name: String,
    core: u16,
    clock: Clock,
    /// Pipeline geometry (width, rob/iq/lsq/fetch_buf sizes).
    spec: CpuSpec,
    /// Shared ifetch/IO knobs (`lsq_size`/`width` in here are unused —
    /// [`CpuSpec`] owns the geometry).
    params: CpuParams,
    seq: CompId,
    trace: Arc<CoreTrace>,
    barrier_every: usize,
    /// Private code region for ifetches.
    code_base: u64,
    code_size: u64,

    /// Next trace index the fetch stage will buffer.
    fetch_idx: usize,
    /// Fetched-but-not-dispatched trace indices (≤ `fetch_buf`).
    fetch_q: VecDeque<usize>,
    /// Reorder buffer in program order (≤ `rob_size`).
    rob: VecDeque<RobEntry>,
    /// Entries in [`OpState::WaitIssue`] (≤ `iq_size`).
    iq_used: usize,
    /// Loads in flight to memory (≤ `lsq_size`).
    lq_used: usize,
    /// Stores in flight to memory (≤ `lsq_size`).
    sq_used: usize,
    /// Memory requests in flight, including ifetches.
    outstanding: usize,
    /// Unpaid compute gap of the next dispatch candidate, in cycles.
    gap_left: u64,
    /// Absolute tick the current gap payment completes (dispatch may not
    /// proceed earlier even if a response wakes the core mid-gap).
    gap_ready_at: Tick,
    next_txn: u64,
    /// In-flight data ops: txn -> trace index (the writeback key).
    inflight_idx: rustc_hash::FxHashMap<u64, usize>,
    fetches: u64,
    /// A blocking ifetch is in flight — dispatch stalls until it lands.
    ifetch_pending: bool,
    waiting_barrier: bool,
    last_barrier_idx: usize,
    /// Earliest scheduled-but-unfired CpuTick (later stale events may
    /// remain queued; spurious wake-ups are idempotent).
    pending_tick: Option<Tick>,
    done: bool,

    /// Cycle the per-cycle width budgets below belong to.
    cur_tick: Tick,
    dispatched_t: usize,
    issued_t: usize,
    committed_t: usize,
    /// Per-invocation once-only stall notes (reset every tick call).
    noted_rob: bool,
    noted_iq: bool,
    noted_lsq: bool,
    /// Last tick the ROB-occupancy integral was folded up to.
    occ_last: Tick,

    // stats (Minor-compatible names first, then the O3-only taxonomy)
    committed_ops: u64,
    loads: u64,
    stores: u64,
    lsq_stalls: u64,
    barriers_hit: u64,
    pub load_checksum: u64,
    /// Loads whose observed value differed from `trace.expected`.
    pub value_mismatches: u64,
    finish_tick: Tick,
    issued_ops: u64,
    squashed: u64,
    rob_full_stalls: u64,
    iq_full_stalls: u64,
    /// Time integral of ROB occupancy (entries × ticks).
    rob_occupancy_sum: u64,
    stl_forwards: u64,
}

impl O3Cpu {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        core: u16,
        clock: Clock,
        spec: CpuSpec,
        params: CpuParams,
        seq: CompId,
        trace: Arc<CoreTrace>,
        barrier_every: usize,
        code_base: u64,
        code_size: u64,
    ) -> Self {
        let gap0 = trace.gap.first().copied().unwrap_or(0) as u64;
        O3Cpu {
            name,
            core,
            clock,
            spec,
            params,
            seq,
            trace,
            barrier_every,
            code_base,
            code_size,
            fetch_idx: 0,
            fetch_q: VecDeque::new(),
            rob: VecDeque::new(),
            iq_used: 0,
            lq_used: 0,
            sq_used: 0,
            outstanding: 0,
            gap_left: gap0,
            gap_ready_at: 0,
            next_txn: 0,
            inflight_idx: rustc_hash::FxHashMap::default(),
            fetches: 0,
            ifetch_pending: false,
            waiting_barrier: false,
            last_barrier_idx: usize::MAX,
            pending_tick: None,
            done: false,
            cur_tick: 0,
            dispatched_t: 0,
            issued_t: 0,
            committed_t: 0,
            noted_rob: false,
            noted_iq: false,
            noted_lsq: false,
            occ_last: 0,
            committed_ops: 0,
            loads: 0,
            stores: 0,
            lsq_stalls: 0,
            barriers_hit: 0,
            load_checksum: 0,
            value_mismatches: 0,
            finish_tick: 0,
            issued_ops: 0,
            squashed: 0,
            rob_full_stalls: 0,
            iq_full_stalls: 0,
            rob_occupancy_sum: 0,
            stl_forwards: 0,
        }
    }

    fn alloc_txn(&mut self, ifetch: bool) -> u64 {
        let id = ((self.core as u64) << 48)
            | (self.next_txn << 1)
            | if ifetch { IFETCH_BIT } else { 0 };
        self.next_txn += 1;
        id
    }

    /// Request a CpuTick at `at` (clamped to now). Only an *earlier*
    /// request than the pending one schedules — later stale events stay
    /// queued and wake the core spuriously, which is harmless.
    fn want_tick_at(&mut self, ctx: &mut Ctx, at: Tick) {
        let at = at.max(ctx.now());
        if self.pending_tick.map_or(true, |p| at < p) {
            self.pending_tick = Some(at);
            ctx.schedule_abs_prio(
                at,
                ctx.self_id(),
                EventKind::CpuTick,
                prio::CPU,
            );
        }
    }

    /// Fold the ROB-occupancy integral up to `now` (call before any ROB
    /// length change).
    fn occ_accrue(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        let dt = now - self.occ_last;
        if dt > 0 && !self.rob.is_empty() {
            let add = (self.rob.len() as u64).wrapping_mul(dt);
            self.rob_occupancy_sum = self.rob_occupancy_sum.wrapping_add(add);
            ctx.shared().pdes.rob_occupancy_sum.fetch_add(add, Relaxed);
        }
        self.occ_last = now;
    }

    fn send_mem(
        &mut self,
        ctx: &mut Ctx,
        addr: u64,
        store: bool,
        value: u64,
        ifetch: bool,
    ) -> u64 {
        let txn = self.alloc_txn(ifetch);
        let pkt = Packet::request(
            txn,
            if store { Cmd::WriteReq } else { Cmd::ReadReq },
            addr,
            if ifetch { IFETCH_SIZE } else { 64 },
            value,
            ctx.self_id(),
            self.core,
            ctx.now(),
        );
        self.outstanding += 1;
        ctx.schedule(0, self.seq, EventKind::MemReq { pkt });
        txn
    }

    fn finish(&mut self, ctx: &mut Ctx) {
        if !self.done {
            self.done = true;
            self.finish_tick = ctx.now();
            ctx.core_done();
        }
    }

    fn note_lsq_stall(&mut self, ctx: &mut Ctx) {
        if !self.noted_lsq {
            self.noted_lsq = true;
            self.lsq_stalls += 1;
            // Offered load the memory system pushed back on — paired
            // with the lsq_stalls counter so the retries ≡ Σ lsq_stalls
            // mirror holds for every CPU model (tests/traffic.rs).
            ctx.shared().pdes.traffic_retries.fetch_add(1, Relaxed);
        }
    }

    /// In-order retirement from the ROB head, up to `width` per cycle.
    fn commit(&mut self, ctx: &mut Ctx) -> bool {
        let mut progress = false;
        while self.committed_t < self.spec.width {
            match self.rob.front() {
                Some(e) if e.state == OpState::Done => {}
                _ => break,
            }
            self.occ_accrue(ctx);
            self.rob.pop_front();
            self.committed_t += 1;
            self.committed_ops += 1;
            // One offered trace op accepted to completion (the
            // offered/accepted pair is the saturation signal).
            ctx.shared().pdes.traffic_accepted.fetch_add(1, Relaxed);
            progress = true;
        }
        progress
    }

    /// Oldest-first issue out of the issue queue, up to `width` per
    /// cycle, respecting same-address ordering and LSQ capacity.
    fn issue(&mut self, ctx: &mut Ctx) -> bool {
        let mut progress = false;
        let mut k = 0;
        while k < self.rob.len() && self.issued_t < self.spec.width {
            if self.rob[k].state != OpState::WaitIssue {
                k += 1;
                continue;
            }
            let (idx, addr, is_store, is_io, value) = {
                let e = &self.rob[k];
                (e.idx, e.addr, e.is_store, e.is_io, e.value)
            };
            if is_io {
                // Device side effects happen in program order: an IO op
                // waits for every older IO op to complete, and never
                // forwards.
                if self.rob.iter().take(k).any(|o| o.is_io && o.state != OpState::Done) {
                    k += 1;
                    continue;
                }
            } else if is_store {
                // A store becomes globally visible at issue — every
                // older same-address op must have completed first.
                if self
                    .rob
                    .iter()
                    .take(k)
                    .any(|o| !o.is_io && o.addr == addr && o.state != OpState::Done)
                {
                    k += 1;
                    continue;
                }
            } else {
                // Load: the youngest older in-ROB store to this address
                // forwards its value (memory may not hold it yet).
                let fwd = self
                    .rob
                    .iter()
                    .take(k)
                    .rev()
                    .find(|o| !o.is_io && o.is_store && o.addr == addr)
                    .map(|o| o.value);
                if let Some(v) = fwd {
                    // Consume a txn id anyway so the tag stream (and the
                    // checksum rotation) stays uniform with issued loads.
                    let txn = self.alloc_txn(false);
                    let e = &mut self.rob[k];
                    e.state = OpState::Done;
                    e.forwarded = true;
                    self.iq_used -= 1;
                    self.loads += 1;
                    self.issued_t += 1;
                    self.issued_ops += 1;
                    ctx.shared().pdes.issued.fetch_add(1, Relaxed);
                    self.stl_forwards += 1;
                    let tag = ((txn >> 1) & 63) as u32;
                    self.load_checksum =
                        self.load_checksum.wrapping_add(v.rotate_left(tag));
                    if !self.trace.expected.is_empty() {
                        let want = self.trace.expected[idx];
                        if want != crate::workload::trace::NO_EXPECT
                            && v != want
                        {
                            self.value_mismatches += 1;
                        }
                    }
                    progress = true;
                    k += 1;
                    continue;
                }
            }
            // Split LSQ capacity gate.
            let q_full = if is_store {
                self.sq_used >= self.spec.lsq_size
            } else {
                self.lq_used >= self.spec.lsq_size
            };
            if q_full {
                self.note_lsq_stall(ctx);
                k += 1;
                continue;
            }
            let txn_serial = self.next_txn;
            self.send_mem(ctx, addr, is_store, value, false);
            self.inflight_idx
                .insert(((self.core as u64) << 48) | (txn_serial << 1), idx);
            self.rob[k].state = OpState::WaitResp;
            self.iq_used -= 1;
            if is_store {
                self.sq_used += 1;
                self.stores += 1;
            } else {
                self.lq_used += 1;
                self.loads += 1;
            }
            self.issued_t += 1;
            self.issued_ops += 1;
            ctx.shared().pdes.issued.fetch_add(1, Relaxed);
            progress = true;
            k += 1;
        }
        progress
    }

    /// Squash the fetch buffer on entering a barrier wait (the frontend
    /// refetches past the sync point, like a pipeline flush).
    fn squash_fetch(&mut self, ctx: &mut Ctx) {
        let n = self.fetch_q.len() as u64;
        if n > 0 {
            self.squashed += n;
            ctx.shared().pdes.squashed.fetch_add(n, Relaxed);
            self.fetch_idx -= self.fetch_q.len();
            self.fetch_q.clear();
        }
    }

    /// In-order dispatch of the fetch-buffer head: capacity gates, gap
    /// payment, software barriers and blocking ifetches in the same
    /// order the Minor loop takes them (the degeneracy gate depends on
    /// this ordering).
    fn dispatch(&mut self, ctx: &mut Ctx) -> Dispatch {
        if self.dispatched_t >= self.spec.width || self.ifetch_pending {
            return Dispatch::Blocked;
        }
        let Some(&i) = self.fetch_q.front() else {
            return Dispatch::Blocked;
        };
        if self.rob.len() >= self.spec.rob_size {
            if !self.noted_rob {
                self.noted_rob = true;
                self.rob_full_stalls += 1;
                ctx.shared().pdes.rob_full_stalls.fetch_add(1, Relaxed);
            }
            return Dispatch::Blocked;
        }
        if self.iq_used >= self.spec.iq_size {
            if !self.noted_iq {
                self.noted_iq = true;
                self.iq_full_stalls += 1;
                ctx.shared().pdes.iq_full_stalls.fetch_add(1, Relaxed);
            }
            return Dispatch::Blocked;
        }
        if self.gap_left > 0 {
            let at = ctx.now() + self.clock.cycles(self.gap_left);
            self.gap_left = 0;
            self.gap_ready_at = at;
            self.want_tick_at(ctx, at);
        }
        if ctx.now() < self.gap_ready_at {
            return Dispatch::Blocked;
        }
        // Software barrier boundary?
        if self.barrier_every > 0
            && i > 0
            && i % self.barrier_every == 0
            && self.last_barrier_idx != i
        {
            // Barriers drain the whole pipeline first.
            if !self.rob.is_empty() || self.outstanding > 0 {
                return Dispatch::Blocked; // resume on MemResp
            }
            self.last_barrier_idx = i;
            self.barriers_hit += 1;
            match ctx.shared().wl_barrier.arrive(ctx.self_id(), ctx.now()) {
                BarrierOutcome::Wait => {
                    self.squash_fetch(ctx);
                    self.waiting_barrier = true;
                    return Dispatch::Parked;
                }
                BarrierOutcome::Release { waiters, release_at } => {
                    let at = release_at.max(ctx.now());
                    for w in waiters {
                        ctx.schedule_abs(at, w, EventKind::WlBarrierRelease);
                    }
                    if ctx.border_ordered() {
                        // Same border-postponed resume as TimingCpu: the
                        // releasing arrival waits for its own release
                        // event, so the resume tick is a pure function
                        // of the simulation (docs/DETERMINISM.md).
                        self.squash_fetch(ctx);
                        self.waiting_barrier = true;
                        ctx.schedule_self_postponed(
                            at,
                            EventKind::WlBarrierRelease,
                        );
                        return Dispatch::Parked;
                    }
                    // Host order: last arriver proceeds immediately.
                }
            }
        }
        // Periodic blocking instruction fetch (before the op).
        if self.params.ifetch_every > 0
            && i % self.params.ifetch_every == 0
            && self.fetches <= (i / self.params.ifetch_every) as u64
        {
            let line = (self.fetches / 4 * 64) % self.code_size.max(64);
            let addr = self.code_base + line;
            self.fetches += 1;
            self.send_mem(ctx, addr, false, 0, true);
            self.ifetch_pending = true;
            return Dispatch::Progress;
        }
        // Allocate the op into the ROB + IQ.
        let (mut addr, mut store, value) = (
            self.trace.addr[i],
            self.trace.is_store[i],
            self.trace.value[i],
        );
        // Periodic IO access through the crossbar (§4.3 traffic).
        if self.params.io_every > 0 && i > 0 && i % self.params.io_every == 0
        {
            let page = (self.core as u64
                + i as u64 / self.params.io_every as u64)
                % self.params.io_pages;
            addr = self.params.io_base + page * crate::xbar::IO_PAGE;
            store = i % (2 * self.params.io_every) == 0;
        }
        let is_io = addr >= self.params.io_base;
        self.fetch_q.pop_front();
        self.occ_accrue(ctx);
        self.rob.push_back(RobEntry {
            idx: i,
            addr,
            is_store: store,
            is_io,
            value,
            state: OpState::WaitIssue,
            forwarded: false,
        });
        self.iq_used += 1;
        self.dispatched_t += 1;
        self.gap_left =
            self.trace.gap.get(i + 1).copied().unwrap_or(0) as u64;
        Dispatch::Progress
    }

    /// Refill the fetch buffer up to `fetch_buf` entries.
    fn refill_fetch(&mut self) -> bool {
        let mut progress = false;
        while self.fetch_q.len() < self.spec.fetch_buf
            && self.fetch_idx < self.trace.len()
        {
            self.fetch_q.push_back(self.fetch_idx);
            self.fetch_idx += 1;
            progress = true;
        }
        progress
    }

    fn tick(&mut self, ctx: &mut Ctx) {
        if self.pending_tick == Some(ctx.now()) {
            self.pending_tick = None;
        }
        if self.done || self.waiting_barrier {
            return;
        }
        if ctx.now() != self.cur_tick {
            self.cur_tick = ctx.now();
            self.dispatched_t = 0;
            self.issued_t = 0;
            self.committed_t = 0;
        }
        self.noted_rob = false;
        self.noted_iq = false;
        self.noted_lsq = false;
        // Advance all stages to a fixpoint within this cycle.
        loop {
            let mut progress = self.commit(ctx);
            progress |= self.issue(ctx);
            match self.dispatch(ctx) {
                Dispatch::Progress => progress = true,
                Dispatch::Blocked => {}
                Dispatch::Parked => return,
            }
            progress |= self.refill_fetch();
            if !progress {
                break;
            }
        }
        if self.fetch_idx >= self.trace.len()
            && self.fetch_q.is_empty()
            && self.rob.is_empty()
            && self.outstanding == 0
        {
            self.finish(ctx);
            return;
        }
        // A saturated width budget means more work next cycle.
        if self.dispatched_t >= self.spec.width
            || self.issued_t >= self.spec.width
            || self.committed_t >= self.spec.width
        {
            let at = ctx.now() + self.clock.cycles(1);
            self.want_tick_at(ctx, at);
        }
    }

    fn on_resp(&mut self, pkt: Packet, ctx: &mut Ctx) {
        debug_assert!(self.outstanding > 0);
        self.outstanding -= 1;
        if pkt.id & IFETCH_BIT != 0 {
            self.ifetch_pending = false;
        } else {
            let idx = self.inflight_idx.remove(&pkt.id).unwrap_or_else(|| {
                panic!("{}: response for unknown txn {:#x}", self.name, pkt.id)
            });
            // The ROB is in program order, so the writeback target is a
            // binary search away.
            let k = self
                .rob
                .binary_search_by(|e| e.idx.cmp(&idx))
                .unwrap_or_else(|_| {
                    panic!("{}: response for retired op {idx}", self.name)
                });
            let e = &mut self.rob[k];
            debug_assert_eq!(e.state, OpState::WaitResp);
            e.state = OpState::Done;
            if e.is_store {
                self.sq_used -= 1;
            } else {
                self.lq_used -= 1;
            }
            if pkt.cmd == Cmd::ReadResp {
                // Commutative fold: responses arrive out of order.
                let tag = ((pkt.id >> 1) & 63) as u32;
                self.load_checksum = self
                    .load_checksum
                    .wrapping_add(pkt.value.rotate_left(tag));
                if !self.trace.expected.is_empty() {
                    let want = self.trace.expected[idx];
                    if want != crate::workload::trace::NO_EXPECT
                        && pkt.value != want
                    {
                        self.value_mismatches += 1;
                    }
                }
            }
        }
        if self.done {
            return;
        }
        self.want_tick_at(ctx, ctx.now());
    }
}

impl Component for O3Cpu {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::CpuTick => self.tick(ctx),
            EventKind::MemResp { pkt } => self.on_resp(pkt, ctx),
            EventKind::WlBarrierRelease => {
                self.waiting_barrier = false;
                let now = ctx.now();
                self.want_tick_at(ctx, now);
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        if self.trace.is_empty() {
            self.finish(ctx);
        } else {
            let now = ctx.now();
            self.want_tick_at(ctx, now);
        }
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("committed_ops", self.committed_ops);
        out.add_u64("loads", self.loads);
        out.add_u64("stores", self.stores);
        out.add_u64("ifetches", self.fetches);
        out.add_u64("lsq_stalls", self.lsq_stalls);
        out.add_u64("barriers", self.barriers_hit);
        out.add_u64("finish_tick", self.finish_tick);
        out.add_u64("load_checksum", self.load_checksum);
        out.add_u64("value_mismatches", self.value_mismatches);
        out.add_u64("issued", self.issued_ops);
        out.add_u64("squashed", self.squashed);
        out.add_u64("rob_full_stalls", self.rob_full_stalls);
        out.add_u64("iq_full_stalls", self.iq_full_stalls);
        out.add_u64("rob_occupancy_sum", self.rob_occupancy_sum);
        out.add_u64("stl_forwards", self.stl_forwards);
    }

    fn save_state(&self, w: &mut StateWriter) {
        w.usize(self.fetch_idx);
        w.usize(self.fetch_q.len());
        for &i in &self.fetch_q {
            w.usize(i);
        }
        w.usize(self.rob.len());
        for e in &self.rob {
            w.usize(e.idx);
            w.u64(e.addr);
            w.bool(e.is_store);
            w.bool(e.is_io);
            w.u64(e.value);
            w.u8(e.state.to_u8());
            w.bool(e.forwarded);
        }
        w.usize(self.outstanding);
        w.u64(self.gap_left);
        w.u64(self.gap_ready_at);
        w.u64(self.next_txn);
        let mut inflight: Vec<(u64, usize)> =
            self.inflight_idx.iter().map(|(&k, &v)| (k, v)).collect();
        inflight.sort_unstable_by_key(|&(k, _)| k);
        w.usize(inflight.len());
        for (txn, op_idx) in inflight {
            w.u64(txn);
            w.usize(op_idx);
        }
        w.u64(self.fetches);
        w.bool(self.ifetch_pending);
        w.bool(self.waiting_barrier);
        w.usize(self.last_barrier_idx);
        w.opt_u64(self.pending_tick);
        w.bool(self.done);
        w.u64(self.cur_tick);
        w.usize(self.dispatched_t);
        w.usize(self.issued_t);
        w.usize(self.committed_t);
        w.u64(self.occ_last);
        w.u64(self.committed_ops);
        w.u64(self.loads);
        w.u64(self.stores);
        w.u64(self.lsq_stalls);
        w.u64(self.barriers_hit);
        w.u64(self.load_checksum);
        w.u64(self.value_mismatches);
        w.u64(self.finish_tick);
        w.u64(self.issued_ops);
        w.u64(self.squashed);
        w.u64(self.rob_full_stalls);
        w.u64(self.iq_full_stalls);
        w.u64(self.rob_occupancy_sum);
        w.u64(self.stl_forwards);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.fetch_idx = r.usize()?;
        self.fetch_q.clear();
        for _ in 0..r.usize()? {
            self.fetch_q.push_back(r.usize()?);
        }
        self.rob.clear();
        for _ in 0..r.usize()? {
            let idx = r.usize()?;
            let addr = r.u64()?;
            let is_store = r.bool()?;
            let is_io = r.bool()?;
            let value = r.u64()?;
            let state_off = r.offset();
            let state = OpState::from_u8(r.u8()?, state_off)?;
            let forwarded = r.bool()?;
            self.rob.push_back(RobEntry {
                idx,
                addr,
                is_store,
                is_io,
                value,
                state,
                forwarded,
            });
        }
        // Derived queue occupancy is recomputed, not stored.
        self.iq_used =
            self.rob.iter().filter(|e| e.state == OpState::WaitIssue).count();
        self.lq_used = self
            .rob
            .iter()
            .filter(|e| e.state == OpState::WaitResp && !e.is_store)
            .count();
        self.sq_used = self
            .rob
            .iter()
            .filter(|e| e.state == OpState::WaitResp && e.is_store)
            .count();
        self.outstanding = r.usize()?;
        self.gap_left = r.u64()?;
        self.gap_ready_at = r.u64()?;
        self.next_txn = r.u64()?;
        self.inflight_idx.clear();
        for _ in 0..r.usize()? {
            let txn = r.u64()?;
            let op_idx = r.usize()?;
            self.inflight_idx.insert(txn, op_idx);
        }
        self.fetches = r.u64()?;
        self.ifetch_pending = r.bool()?;
        self.waiting_barrier = r.bool()?;
        self.last_barrier_idx = r.usize()?;
        self.pending_tick = r.opt_u64()?;
        self.done = r.bool()?;
        self.cur_tick = r.u64()?;
        self.dispatched_t = r.usize()?;
        self.issued_t = r.usize()?;
        self.committed_t = r.usize()?;
        self.occ_last = r.u64()?;
        self.committed_ops = r.u64()?;
        self.loads = r.u64()?;
        self.stores = r.u64()?;
        self.lsq_stalls = r.u64()?;
        self.barriers_hit = r.u64()?;
        self.load_checksum = r.u64()?;
        self.value_mismatches = r.u64()?;
        self.finish_tick = r.u64()?;
        self.issued_ops = r.u64()?;
        self.squashed = r.u64()?;
        self.rob_full_stalls = r.u64()?;
        self.iq_full_stalls = r.u64()?;
        self.rob_occupancy_sum = r.u64()?;
        self.stl_forwards = r.u64()?;
        Ok(())
    }
}
