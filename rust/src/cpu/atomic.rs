//! Atomic-mode CPU and memory (§3.3's atomic protocol, Table 1's
//! AtomicCPU).
//!
//! The atomic protocol completes a whole transaction in one synchronous
//! call chain, so the memory hierarchy here is a plain function: per-core
//! L1/L2 arrays, a shared L3 and the functional backing store, returning a
//! latency. No coherence protocol is modelled (Table 1: Ruby ✗ for
//! Atomic/KVM) — stores write through to the shared levels. Used for
//! fast-forwarding to ROIs and for the atomic-vs-timing throughput
//! comparison (§3.3 reports timing+O3 ≈ 20% of atomic throughput).
//!
//! Batching: each event executes up to `batch` ops, accumulating simulated
//! latency — this mirrors gem5's atomic mode executing long instruction
//! runs without event-queue round trips.

use std::sync::{Arc, Mutex};

use rustc_hash::FxHashMap;

use crate::mem::{CacheArray, LineState};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::{prio, EventKind};
use crate::sim::stats::StatSink;
use crate::sim::time::{Clock, Tick};
use crate::workload::CoreTrace;

/// Latencies for the synchronous hierarchy walk.
#[derive(Clone, Copy, Debug)]
pub struct AtomicLatencies {
    pub l1: Tick,
    pub l2: Tick,
    pub l3: Tick,
    pub dram: Tick,
}

/// The shared functional memory for atomic/KVM modes.
pub struct AtomicMem {
    l1d: Vec<CacheArray>,
    l2: Vec<CacheArray>,
    l3: CacheArray,
    store: FxHashMap<u64, u64>,
    lat: AtomicLatencies,
    line_bytes: u64,
}

impl AtomicMem {
    pub fn new(
        n_cores: usize,
        l1_bytes: u64,
        l1_assoc: usize,
        l2_bytes: u64,
        l2_assoc: usize,
        l3_bytes: u64,
        l3_assoc: usize,
        line_bytes: u64,
        lat: AtomicLatencies,
    ) -> Arc<Mutex<Self>> {
        Arc::new(Mutex::new(AtomicMem {
            l1d: (0..n_cores)
                .map(|_| CacheArray::new(l1_bytes, l1_assoc, line_bytes))
                .collect(),
            l2: (0..n_cores)
                .map(|_| CacheArray::new(l2_bytes, l2_assoc, line_bytes))
                .collect(),
            l3: CacheArray::new(l3_bytes, l3_assoc, line_bytes),
            store: FxHashMap::default(),
            lat,
            line_bytes,
        }))
    }

    /// Synchronous access: functional effect + latency (the atomic call
    /// chain of Fig. 2a).
    pub fn access(&mut self, core: usize, addr: u64, is_store: bool, value: u64) -> (Tick, u64) {
        let line = addr & !(self.line_bytes - 1);
        if is_store {
            // Write-through everywhere (no coherence in atomic mode);
            // invalidate other cores' copies functionally so later reads
            // see the new data.
            self.store.insert(line, value);
            if let Some(l) = self.l1d[core].peek_mut(line) {
                l.data = value;
            }
            if let Some(l) = self.l2[core].peek_mut(line) {
                l.data = value;
            }
            if let Some(l) = self.l3.peek_mut(line) {
                l.data = value;
            }
            for (i, c) in self.l1d.iter_mut().enumerate() {
                if i != core {
                    c.invalidate(line);
                }
            }
            for (i, c) in self.l2.iter_mut().enumerate() {
                if i != core {
                    c.invalidate(line);
                }
            }
            return (self.lat.l1, 0);
        }
        // Load walk.
        if let Some(l) = self.l1d[core].access(line) {
            return (self.lat.l1, l.data);
        }
        if let Some(l) = self.l2[core].access(line) {
            let data = l.data;
            self.l1d[core].allocate(line, LineState::Shared, data);
            return (self.lat.l1 + self.lat.l2, data);
        }
        if let Some(l) = self.l3.access(line) {
            let data = l.data;
            self.l2[core].allocate(line, LineState::Shared, data);
            self.l1d[core].allocate(line, LineState::Shared, data);
            return (self.lat.l1 + self.lat.l2 + self.lat.l3, data);
        }
        let data = *self.store.get(&line).unwrap_or(&0);
        self.l3.allocate(line, LineState::Shared, data);
        self.l2[core].allocate(line, LineState::Shared, data);
        self.l1d[core].allocate(line, LineState::Shared, data);
        (self.lat.l1 + self.lat.l2 + self.lat.l3 + self.lat.dram, data)
    }

    pub fn l1_miss_rate(&self, core: usize) -> f64 {
        self.l1d[core].miss_rate()
    }
}

/// The interpreter-like atomic CPU: fixed issue cost per op plus the
/// synchronous memory latency.
pub struct AtomicCpu {
    name: String,
    core: u16,
    clock: Clock,
    mem: Arc<Mutex<AtomicMem>>,
    trace: Arc<CoreTrace>,
    batch: usize,
    idx: usize,
    committed_ops: u64,
    pub load_checksum: u64,
    finish_tick: Tick,
    done: bool,
}

impl AtomicCpu {
    pub fn new(
        name: String,
        core: u16,
        clock: Clock,
        mem: Arc<Mutex<AtomicMem>>,
        trace: Arc<CoreTrace>,
    ) -> Self {
        AtomicCpu {
            name,
            core,
            clock,
            mem,
            trace,
            // gem5's atomic mode still interprets instruction-by-
            // instruction; a modest batch keeps per-op interpreter
            // overhead in the model (§3.3 calibration).
            batch: 24,
            idx: 0,
            committed_ops: 0,
            load_checksum: 0,
            finish_tick: 0,
            done: false,
        }
    }
}

impl Component for AtomicCpu {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::CpuTick => {
                if self.done {
                    return;
                }
                let mut elapsed: Tick = 0;
                let end = (self.idx + self.batch).min(self.trace.len());
                {
                    let mut mem = self.mem.lock().unwrap();
                    while self.idx < end {
                        let i = self.idx;
                        elapsed += self
                            .clock
                            .cycles(self.trace.gap[i] as u64 + 1);
                        let (lat, data) = mem.access(
                            self.core as usize,
                            self.trace.addr[i],
                            self.trace.is_store[i],
                            self.trace.value[i],
                        );
                        elapsed += lat;
                        if !self.trace.is_store[i] {
                            let tag = (i & 63) as u32;
                            self.load_checksum = self
                                .load_checksum
                                .wrapping_add(data.rotate_left(tag));
                        }
                        self.committed_ops += 1;
                        self.idx += 1;
                    }
                }
                if self.idx >= self.trace.len() {
                    self.done = true;
                    self.finish_tick = ctx.now() + elapsed;
                    ctx.core_done();
                } else {
                    ctx.schedule_abs_prio(
                        ctx.now() + elapsed,
                        ctx.self_id(),
                        EventKind::CpuTick,
                        prio::CPU,
                    );
                }
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        if self.trace.is_empty() {
            self.done = true;
            ctx.core_done();
        } else {
            ctx.schedule_self(0, EventKind::CpuTick);
        }
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("committed_ops", self.committed_ops);
        out.add_u64("finish_tick", self.finish_tick);
        out.add_u64("load_checksum", self.load_checksum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem2() -> Arc<Mutex<AtomicMem>> {
        AtomicMem::new(
            2,
            1024,
            2,
            4096,
            4,
            16384,
            8,
            64,
            AtomicLatencies { l1: 1000, l2: 4000, l3: 6000, dram: 50_000 },
        )
    }

    #[test]
    fn store_visible_to_other_core() {
        let m = mem2();
        let mut mem = m.lock().unwrap();
        mem.access(0, 0x100, true, 99);
        let (_, v) = mem.access(1, 0x100, false, 0);
        assert_eq!(v, 99);
    }

    #[test]
    fn second_load_is_l1_hit() {
        let m = mem2();
        let mut mem = m.lock().unwrap();
        let (cold, _) = mem.access(0, 0x200, false, 0);
        let (hot, _) = mem.access(0, 0x200, false, 0);
        assert!(hot < cold);
        assert_eq!(hot, 1000);
    }

    #[test]
    fn store_invalidate_other_l1() {
        let m = mem2();
        let mut mem = m.lock().unwrap();
        mem.access(1, 0x300, false, 0); // core1 caches line
        mem.access(0, 0x300, true, 7); // core0 stores
        let (lat, v) = mem.access(1, 0x300, false, 0);
        assert_eq!(v, 7);
        assert!(lat > 1000, "core1's copy must have been invalidated");
    }
}
