//! KVM-like fast-forward CPU (Table 1's KVMCPU).
//!
//! Executes the whole trace functionally in a single event at near-zero
//! simulated cost (the paper: "near-native execution speeds ... should only
//! be used to fast-forward to ROIs"). Warms the functional memory and the
//! atomic cache arrays so a subsequent detailed run starts from a warmed
//! checkpoint (`parti-sim ffwd`).

use std::sync::{Arc, Mutex};

use crate::sim::component::{Component, Ctx};
use crate::sim::event::EventKind;
use crate::sim::stats::StatSink;
use crate::sim::time::NS;
use crate::workload::CoreTrace;

use super::atomic::AtomicMem;

pub struct KvmCpu {
    name: String,
    core: u16,
    mem: Arc<Mutex<AtomicMem>>,
    trace: Arc<CoreTrace>,
    committed_ops: u64,
    pub load_checksum: u64,
}

impl KvmCpu {
    pub fn new(
        name: String,
        core: u16,
        mem: Arc<Mutex<AtomicMem>>,
        trace: Arc<CoreTrace>,
    ) -> Self {
        KvmCpu { name, core, mem, trace, committed_ops: 0, load_checksum: 0 }
    }
}

impl Component for KvmCpu {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::CpuTick => {
                {
                    let mut mem = self.mem.lock().unwrap();
                    for i in 0..self.trace.len() {
                        let (_lat, data) = mem.access(
                            self.core as usize,
                            self.trace.addr[i],
                            self.trace.is_store[i],
                            self.trace.value[i],
                        );
                        if !self.trace.is_store[i] {
                            let tag = (i & 63) as u32;
                            self.load_checksum = self
                                .load_checksum
                                .wrapping_add(data.rotate_left(tag));
                        }
                        self.committed_ops += 1;
                    }
                }
                ctx.core_done();
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        // Stagger cores by 1 ns so the serial kernel interleaves them.
        ctx.schedule_self(self.core as u64 * NS, EventKind::CpuTick);
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("committed_ops", self.committed_ops);
        out.add_u64("load_checksum", self.load_checksum);
    }
}
