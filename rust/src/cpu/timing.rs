//! Timing CPU models: the in-order MinorCPU and the out-of-order O3CPU.
//!
//! Both replay a [`CoreTrace`] through the timing protocol (§3.3): every
//! memory op becomes a two-phase transaction through the sequencer and the
//! Ruby hierarchy. The two models share this implementation and differ in
//! their issue discipline (DESIGN.md §3 abstraction of gem5's pipelines):
//!
//! * **Minor** (in-order): one outstanding memory access; compute gaps and
//!   memory latency fully serialise.
//! * **O3** (out-of-order): up to `lsq_size` outstanding accesses and
//!   `width` issues per cycle; compute gaps overlap with in-flight misses
//!   (memory-level parallelism), retirement is counted at response.
//!
//! Instruction fetch is modelled architecturally: one line-granular ifetch
//! through the L1I every `ifetch_every` ops, walking a private code region.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

use crate::ckpt::io::{CkptError, StateReader, StateWriter};
use crate::proto::{Cmd, Packet};
use crate::sim::component::{Component, Ctx};
use crate::sim::event::{prio, EventKind};
use crate::sim::ids::CompId;
use crate::sim::shared::BarrierOutcome;
use crate::sim::stats::StatSink;
use crate::sim::time::{Clock, Tick};
use crate::workload::CoreTrace;

use crate::ruby::sequencer::IFETCH_SIZE;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineKind {
    Minor,
    O3,
}

#[derive(Clone, Copy, Debug)]
pub struct CpuParams {
    pub kind: PipelineKind,
    /// Max outstanding memory accesses (LSQ entries).
    pub lsq_size: usize,
    /// Issues per cycle.
    pub width: usize,
    /// Instruction fetch every N ops (0 = never).
    pub ifetch_every: usize,
    /// Replace every Nth op with an IO access through the crossbar
    /// (0 = never). Exercises the §4.3 path.
    pub io_every: usize,
    /// Base address of the IO window (see [`crate::xbar`]).
    pub io_base: u64,
    /// Number of IO pages to rotate over.
    pub io_pages: u64,
}

impl CpuParams {
    pub fn minor() -> Self {
        CpuParams {
            kind: PipelineKind::Minor,
            lsq_size: 1,
            width: 1,
            ifetch_every: 16,
            io_every: 0,
            io_base: crate::xbar::IO_BASE,
            io_pages: 2,
        }
    }

    pub fn o3() -> Self {
        CpuParams {
            kind: PipelineKind::O3,
            lsq_size: 12,
            width: 4,
            ifetch_every: 16,
            io_every: 0,
            io_base: crate::xbar::IO_BASE,
            io_pages: 2,
        }
    }
}

const IFETCH_BIT: u64 = 1;

pub struct TimingCpu {
    name: String,
    core: u16,
    clock: Clock,
    params: CpuParams,
    seq: CompId,
    trace: Arc<CoreTrace>,
    barrier_every: usize,
    /// Private code region for ifetches.
    code_base: u64,
    code_size: u64,

    idx: usize,
    outstanding: usize,
    gap_left: u64,
    next_txn: u64,
    /// In-flight data ops: txn -> trace index (for expected-value checks).
    inflight_idx: rustc_hash::FxHashMap<u64, usize>,
    fetches: u64,
    waiting_barrier: bool,
    last_barrier_idx: usize,
    tick_pending: bool,
    done: bool,

    // stats
    committed_ops: u64,
    loads: u64,
    stores: u64,
    lsq_stalls: u64,
    barriers_hit: u64,
    pub load_checksum: u64,
    /// Loads whose observed value differed from `trace.expected`.
    pub value_mismatches: u64,
    finish_tick: Tick,
}

impl TimingCpu {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        core: u16,
        clock: Clock,
        params: CpuParams,
        seq: CompId,
        trace: Arc<CoreTrace>,
        barrier_every: usize,
        code_base: u64,
        code_size: u64,
    ) -> Self {
        let gap0 = trace.gap.first().copied().unwrap_or(0) as u64;
        TimingCpu {
            name,
            core,
            clock,
            params,
            seq,
            trace,
            barrier_every,
            code_base,
            code_size,
            idx: 0,
            outstanding: 0,
            gap_left: gap0,
            next_txn: 0,
            inflight_idx: rustc_hash::FxHashMap::default(),
            fetches: 0,
            waiting_barrier: false,
            last_barrier_idx: usize::MAX,
            tick_pending: false,
            done: false,
            committed_ops: 0,
            loads: 0,
            stores: 0,
            lsq_stalls: 0,
            barriers_hit: 0,
            load_checksum: 0,
            value_mismatches: 0,
            finish_tick: 0,
        }
    }

    fn alloc_txn(&mut self, ifetch: bool) -> u64 {
        let id = ((self.core as u64) << 48)
            | (self.next_txn << 1)
            | if ifetch { IFETCH_BIT } else { 0 };
        self.next_txn += 1;
        id
    }

    fn schedule_tick(&mut self, ctx: &mut Ctx, delay_cycles: u64) {
        if !self.tick_pending {
            self.tick_pending = true;
            ctx.schedule_abs_prio(
                ctx.now() + self.clock.cycles(delay_cycles),
                ctx.self_id(),
                EventKind::CpuTick,
                prio::CPU,
            );
        }
    }

    fn send_mem(&mut self, ctx: &mut Ctx, addr: u64, store: bool, value: u64, ifetch: bool) {
        let txn = self.alloc_txn(ifetch);
        let pkt = Packet::request(
            txn,
            if store { Cmd::WriteReq } else { Cmd::ReadReq },
            addr,
            if ifetch { IFETCH_SIZE } else { 64 },
            value,
            ctx.self_id(),
            self.core,
            ctx.now(),
        );
        self.outstanding += 1;
        ctx.schedule(0, self.seq, EventKind::MemReq { pkt });
    }

    fn finish(&mut self, ctx: &mut Ctx) {
        if !self.done {
            self.done = true;
            self.finish_tick = ctx.now();
            ctx.core_done();
        }
    }

    fn tick(&mut self, ctx: &mut Ctx) {
        self.tick_pending = false;
        if self.done || self.waiting_barrier {
            return;
        }
        let mut issued = 0usize;
        loop {
            // Retired everything and trace exhausted?
            if self.idx >= self.trace.len() {
                if self.outstanding == 0 {
                    self.finish(ctx);
                }
                return;
            }
            if self.outstanding >= self.params.lsq_size {
                self.lsq_stalls += 1;
                // Offered load the memory system pushed back on — the
                // global backpressure signal next to offered/accepted
                // (deterministic: a pure function of the simulation).
                ctx.shared().pdes.traffic_retries.fetch_add(1, Relaxed);
                return; // resume on MemResp
            }
            if self.gap_left > 0 {
                let d = self.gap_left;
                self.gap_left = 0;
                self.schedule_tick(ctx, d);
                return;
            }
            // Software barrier boundary?
            if self.barrier_every > 0
                && self.idx > 0
                && self.idx % self.barrier_every == 0
                && self.last_barrier_idx != self.idx
            {
                // In-order semantics: barriers drain the LSQ first.
                if self.outstanding > 0 {
                    return; // resume on MemResp
                }
                self.last_barrier_idx = self.idx;
                self.barriers_hit += 1;
                match ctx.shared().wl_barrier.arrive(ctx.self_id(), ctx.now())
                {
                    BarrierOutcome::Wait => {
                        self.waiting_barrier = true;
                        return;
                    }
                    BarrierOutcome::Release { waiters, release_at } => {
                        let at = release_at.max(ctx.now());
                        for w in waiters {
                            ctx.schedule_abs(
                                at,
                                w,
                                EventKind::WlBarrierRelease,
                            );
                        }
                        if ctx.border_ordered() {
                            // Border-ordered mode: the last arriver
                            // resumes through the same border-postponed
                            // release event as every waiter, so the
                            // resume tick no longer depends on which
                            // core the host happened to run last — the
                            // releasing call always executes in the
                            // window of the simulated-last arrival, so
                            // the effective tick is a pure function of
                            // the simulation (docs/DETERMINISM.md).
                            self.waiting_barrier = true;
                            ctx.schedule_self_postponed(
                                at,
                                EventKind::WlBarrierRelease,
                            );
                            return;
                        }
                        // Host order: last arriver proceeds immediately
                        // (the paper's behaviour).
                    }
                }
            }
            // Periodic instruction fetch (before the op).
            if self.params.ifetch_every > 0
                && self.idx % self.params.ifetch_every == 0
                && self.fetches <= (self.idx / self.params.ifetch_every) as u64
            {
                // The fetch line advances every 4 fetches (~64 ops/line) and
                // wraps around the loop body, giving realistic I-locality.
                let line = (self.fetches / 4 * 64) % self.code_size.max(64);
                let addr = self.code_base + line;
                self.fetches += 1;
                self.send_mem(ctx, addr, false, 0, true);
                if self.params.kind == PipelineKind::Minor {
                    // In-order frontend: the fetch blocks issue.
                    return; // resume on MemResp
                }
                continue;
            }
            // Issue the memory op.
            let i = self.idx;
            let (mut addr, mut store, value) = (
                self.trace.addr[i],
                self.trace.is_store[i],
                self.trace.value[i],
            );
            // Periodic IO access through the crossbar (§4.3 traffic).
            if self.params.io_every > 0
                && i > 0
                && i % self.params.io_every == 0
            {
                let page = (self.core as u64
                    + i as u64 / self.params.io_every as u64)
                    % self.params.io_pages;
                addr = self.params.io_base + page * crate::xbar::IO_PAGE;
                store = i % (2 * self.params.io_every) == 0;
            }
            if store {
                self.stores += 1;
            } else {
                self.loads += 1;
            }
            let txn_serial = self.next_txn; // id allocated inside send_mem
            self.send_mem(ctx, addr, store, value, false);
            if !store && !self.trace.expected.is_empty() {
                let id = ((self.core as u64) << 48) | (txn_serial << 1);
                self.inflight_idx.insert(id, i);
            }
            self.idx += 1;
            self.gap_left =
                self.trace.gap.get(self.idx).copied().unwrap_or(0) as u64;
            issued += 1;
            if issued >= self.params.width {
                self.schedule_tick(ctx, 1);
                return;
            }
        }
    }

    fn on_resp(&mut self, pkt: Packet, ctx: &mut Ctx) {
        debug_assert!(self.outstanding > 0);
        self.outstanding -= 1;
        let is_ifetch = pkt.id & IFETCH_BIT != 0;
        if !is_ifetch {
            self.committed_ops += 1;
            // One offered trace op accepted to completion; compared
            // against `traffic_offered` in the summary, the gap is the
            // unaccepted (truncated) remainder of a saturating run.
            ctx.shared().pdes.traffic_accepted.fetch_add(1, Relaxed);
            if pkt.cmd == Cmd::ReadResp {
                // Commutative fold: O3 responses arrive out of order, and
                // serial/parallel runs may reorder same-tick completions.
                let tag = ((pkt.id >> 1) & 63) as u32;
                self.load_checksum = self
                    .load_checksum
                    .wrapping_add(pkt.value.rotate_left(tag));
                if let Some(op_idx) = self.inflight_idx.remove(&pkt.id) {
                    let want = self.trace.expected[op_idx];
                    if want != crate::workload::trace::NO_EXPECT
                        && pkt.value != want
                    {
                        self.value_mismatches += 1;
                    }
                }
            }
        }
        if self.done {
            return;
        }
        self.schedule_tick(ctx, 0);
    }
}

impl Component for TimingCpu {
    fn handle(&mut self, kind: EventKind, ctx: &mut Ctx) {
        match kind {
            EventKind::CpuTick => self.tick(ctx),
            EventKind::MemResp { pkt } => self.on_resp(pkt, ctx),
            EventKind::WlBarrierRelease => {
                self.waiting_barrier = false;
                self.schedule_tick(ctx, 0);
            }
            other => panic!("{}: unexpected event {other:?}", self.name),
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        if self.trace.is_empty() {
            self.finish(ctx);
        } else {
            self.schedule_tick(ctx, 0);
        }
    }

    fn stats(&self, out: &mut StatSink) {
        out.add_u64("committed_ops", self.committed_ops);
        out.add_u64("loads", self.loads);
        out.add_u64("stores", self.stores);
        out.add_u64("ifetches", self.fetches);
        out.add_u64("lsq_stalls", self.lsq_stalls);
        out.add_u64("barriers", self.barriers_hit);
        out.add_u64("finish_tick", self.finish_tick);
        out.add_u64("load_checksum", self.load_checksum);
        out.add_u64("value_mismatches", self.value_mismatches);
    }

    fn save_state(&self, w: &mut StateWriter) {
        w.usize(self.idx);
        w.usize(self.outstanding);
        w.u64(self.gap_left);
        w.u64(self.next_txn);
        let mut inflight: Vec<(u64, usize)> =
            self.inflight_idx.iter().map(|(&k, &v)| (k, v)).collect();
        inflight.sort_unstable_by_key(|&(k, _)| k);
        w.usize(inflight.len());
        for (txn, op_idx) in inflight {
            w.u64(txn);
            w.usize(op_idx);
        }
        w.u64(self.fetches);
        w.bool(self.waiting_barrier);
        w.usize(self.last_barrier_idx);
        w.bool(self.tick_pending);
        w.bool(self.done);
        w.u64(self.committed_ops);
        w.u64(self.loads);
        w.u64(self.stores);
        w.u64(self.lsq_stalls);
        w.u64(self.barriers_hit);
        w.u64(self.load_checksum);
        w.u64(self.value_mismatches);
        w.u64(self.finish_tick);
    }

    fn restore_state(&mut self, r: &mut StateReader) -> Result<(), CkptError> {
        self.idx = r.usize()?;
        self.outstanding = r.usize()?;
        self.gap_left = r.u64()?;
        self.next_txn = r.u64()?;
        self.inflight_idx.clear();
        for _ in 0..r.usize()? {
            let txn = r.u64()?;
            let op_idx = r.usize()?;
            self.inflight_idx.insert(txn, op_idx);
        }
        self.fetches = r.u64()?;
        self.waiting_barrier = r.bool()?;
        self.last_barrier_idx = r.usize()?;
        self.tick_pending = r.bool()?;
        self.done = r.bool()?;
        self.committed_ops = r.u64()?;
        self.loads = r.u64()?;
        self.stores = r.u64()?;
        self.lsq_stalls = r.u64()?;
        self.barriers_hit = r.u64()?;
        self.load_checksum = r.u64()?;
        self.value_mismatches = r.u64()?;
        self.finish_tick = r.u64()?;
        Ok(())
    }
}
